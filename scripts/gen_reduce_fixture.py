#!/usr/bin/env python3
"""Generate the reduction-math cross-check fixture.

Builds K synthetic 16^3 volumes from a 32-bit LCG (exactly reproducible
in Rust with wrapping u64 arithmetic), computes their voxelwise mean in
float64, and records summary values — L2 norm, plain sum, and a handful
of probed voxels — in rust/tests/fixtures/reduce_mean_16.json. The Rust
property test (tests/prop_reduce.rs) regenerates the same volumes,
reduces them through `groupwise::mean_scalar`, and compares against
these float64 references.

Uses NumPy when available; falls back to pure python (same arithmetic,
float64 either way). Run from the repo root:

    python3 scripts/gen_reduce_fixture.py
"""

import json
import os

N = 16
K = 4
SEED = 0x5EED
# Numerical Recipes LCG constants, 32-bit state.
A = 1664525
C = 1013904223
MOD = 1 << 32
PROBES = [0, 1, 255, 1024, 2048, 3071, 4000, 4095]


def lcg_volume(subject):
    """One n^3 volume in [0,1): f32-rounded samples of a 32-bit LCG."""
    state = (SEED + subject * 9973) % MOD
    out = []
    for _ in range(N * N * N):
        state = (A * state + C) % MOD
        # Round through f32 the way the Rust store holds samples, so the
        # float64 mean below is over *identical* inputs.
        out.append(f32(state / MOD))
    return out


def f32(x):
    import struct

    return struct.unpack("f", struct.pack("f", x))[0]


def main():
    try:
        import numpy as np

        vols = [np.array(lcg_volume(s), dtype=np.float64) for s in range(K)]
        mean = sum(vols) / K
        l2 = float(np.sqrt(np.sum(mean * mean)))
        total = float(np.sum(mean))
        probes = [float(mean[i]) for i in PROBES]
    except ImportError:
        vols = [lcg_volume(s) for s in range(K)]
        m = N * N * N
        mean = [sum(v[i] for v in vols) / K for i in range(m)]
        l2 = sum(x * x for x in mean) ** 0.5
        total = sum(mean)
        probes = [mean[i] for i in PROBES]

    fixture = {
        "n": N,
        "k": K,
        "seed": SEED,
        "lcg_a": A,
        "lcg_c": C,
        "probe_indices": PROBES,
        "mean_l2": l2,
        "mean_sum": total,
        "mean_probes": probes,
    }
    out = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "rust", "tests", "fixtures", "reduce_mean_16.json",
    )
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as fh:
        json.dump(fixture, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"wrote {out} (n={N}, k={K}, l2={l2:.12f})")


if __name__ == "__main__":
    main()
