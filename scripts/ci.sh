#!/usr/bin/env bash
# Tier-1 verify + invariant lint + formatting + serve round-trip smoke,
# plus toolchain-gated concurrency-analysis stages (loom / TSan / Miri).
# Usage: scripts/ci.sh  (from anywhere; cd's to the rust crate)
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== invariant lint (hard gate: shim-imports, lock-order, store-journal, error-codes, emit-guards, template-sync) =="
if command -v cargo >/dev/null 2>&1; then
  cargo xtask lint
elif command -v python3 >/dev/null 2>&1; then
  echo "WARNING: cargo not found; running the dependency-free Python mirror"
  python3 ../scripts/lint_invariants.py
  python3 ../scripts/lint_invariants.py --selftest
else
  echo "ERROR: neither cargo nor python3 available to run the invariant lint" >&2
  exit 1
fi

echo "== semantic invariant analysis (hard gate: lifecycle, wire-schema, panic-budget) =="
# Extracts the job/round lifecycle machines and the wire schema from the
# source, diffs both against the declared tables in DESIGN.md, and holds
# every non-test file to its panic budget (scripts/panic_budget.toml).
# Writes artifacts/lifecycle.dot + artifacts/wire_schema.json on success.
if command -v cargo >/dev/null 2>&1; then
  cargo xtask analyze
elif command -v python3 >/dev/null 2>&1; then
  echo "WARNING: cargo not found; running the dependency-free Python mirror"
  python3 ../scripts/analyze_invariants.py
  python3 ../scripts/analyze_invariants.py --selftest
else
  echo "ERROR: neither cargo nor python3 available to run the invariant analyzer" >&2
  exit 1
fi
echo "analysis artifacts: artifacts/lifecycle.dot artifacts/wire_schema.json"

echo "== python -m compileall (syntax gate for the L1/L2 layers) =="
if command -v python3 >/dev/null 2>&1; then
  python3 -m compileall -q ../python
else
  echo "WARNING: python3 not found; skipping compileall"
fi

if ! command -v cargo >/dev/null 2>&1; then
  echo "ERROR: cargo not found; the build/test stages below require a Rust toolchain" >&2
  exit 1
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo fmt --check (advisory) =="
# Advisory until the tree is normalized: the seed predates rustfmt and
# carries >100-col lines in a dozen files. First session with a Rust
# toolchain: run `cargo fmt`, commit, then drop the `|| true`.
cargo fmt --check || echo "WARNING: tree is not rustfmt-clean (see scripts/ci.sh note)"

echo "== cargo clippy -- -D warnings =="
cargo clippy -- -D warnings

echo "== serve round-trip smoke (fail-fast) =="
cargo test -q serve_round_trip_smoke

echo "== serve data-plane smoke: upload -> submit -> status (stub executor) =="
cargo test -q --test integration_serve upload_submit_status_round_trip

echo "== protocol v1-compat smoke: raw pre-hello lines round-trip byte-identically =="
cargo test -q --test integration_serve v1_raw_lines_are_byte_compatible

echo "== protocol v2 watch smoke: queued,running,done event stream for one job =="
cargo test -q --test integration_serve watch_streams_job_lifecycle

echo "== cancel-running-job smoke: running -> cancelled at an iteration boundary (stub daemon) =="
cargo test -q --test integration_serve cancel_running_job_over_the_wire

echo "== fleet router smoke: upload/submit/watch/cancel through a 2-backend router (affinity + global ids) =="
cargo test -q --test integration_router router_upload_submit_watch_affinity

echo "== coalesced-batch smoke: 4 compatible jobs -> 1 batched dispatch, per-job lifecycles + mid-batch cancel (live daemon) =="
cargo test -q --test integration_serve coalesced_batch_keeps_per_job_lifecycles_over_the_wire

echo "== exactly-once smoke: dedup token resubmission across a daemon restart =="
cargo test -q --test integration_serve dedup_resubmission_is_exactly_once_across_restart

echo "== journal crash-safety properties: torn/truncated/interleaved tails =="
cargo test -q --test prop_journal

echo "== template smoke: group-wise build converges + journaled restart resumes exactly-once =="
cargo test -q --test integration_template

echo "== reduction-math properties: log-mean/warp invariants + float64 NumPy fixture =="
cargo test -q --test prop_reduce

echo "== service bench smoke: batched-vs-sequential throughput -> BENCH_service.json =="
CLAIRE_BENCH_SMOKE=1 cargo bench --bench bench_service

echo "== template bench smoke: round/reduce latency sweep -> BENCH_template.json =="
CLAIRE_BENCH_SMOKE=1 cargo bench --bench bench_template

echo "== cargo doc --no-deps (public API docs, warnings as errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== cargo test -q (tier-1) =="
cargo test -q

# -- Concurrency-analysis stages (toolchain-gated; skips are loud) ----------
# See DESIGN.md "Concurrency model & analysis" for what each stage proves.

echo "== loom model checking: scheduler submit/cancel/dwell/bus/dedup/shutdown races =="
# Bounded exploration (3 preemptions) keeps the stage minutes-scale; drop
# LOOM_MAX_PREEMPTIONS for the exhaustive run. The loom crate only enters
# the build graph under --cfg loom; offline images without it vendored
# skip here rather than losing the tier-1 stages above.
if RUSTFLAGS="--cfg loom" cargo fetch --quiet >/dev/null 2>&1; then
  RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 \
    cargo test --release --test loom_serve
else
  echo "WARNING: loom dependency unresolvable (offline, not vendored); skipping loom model checking"
fi

echo "== ThreadSanitizer: scheduler/router integration tests =="
# Needs nightly (+ rust-src for an instrumented std). Catches data races
# the model checker's stub-level scenarios don't reach (TCP paths, PJRT
# wrappers).
if command -v rustup >/dev/null 2>&1 \
  && rustup toolchain list 2>/dev/null | grep -q nightly \
  && rustup component list --toolchain nightly 2>/dev/null | grep -q 'rust-src (installed)'; then
  host="$(rustc -vV | sed -n 's/^host: //p')"
  RUSTFLAGS="-Zsanitizer=thread" \
    cargo +nightly test -Zbuild-std --target "$host" \
    --test integration_serve --test integration_router
else
  echo "WARNING: nightly toolchain (with rust-src) unavailable; skipping ThreadSanitizer stage"
fi

echo "== Miri: pure-marshalling modules (half, base64, json) =="
# UB check on the byte-twiddling modules; the rest of the crate is
# forbid(unsafe_code) and exercises I/O Miri cannot model.
if command -v cargo >/dev/null 2>&1 && cargo +nightly miri --version >/dev/null 2>&1; then
  cargo +nightly miri test --lib -- math::half util::base64 util::json
else
  echo "WARNING: Miri unavailable (needs nightly + miri component); skipping Miri stage"
fi

echo "CI OK"
