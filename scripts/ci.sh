#!/usr/bin/env bash
# Tier-1 verify + formatting + lint + serve round-trip smoke test.
# Usage: scripts/ci.sh  (from anywhere; cd's to the rust crate)
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo fmt --check (advisory) =="
# Advisory until the tree is normalized: the seed predates rustfmt and
# carries >100-col lines in a dozen files. First session with a Rust
# toolchain: run `cargo fmt`, commit, then drop the `|| true`.
cargo fmt --check || echo "WARNING: tree is not rustfmt-clean (see scripts/ci.sh note)"

echo "== cargo clippy -- -D warnings =="
cargo clippy -- -D warnings

echo "== python -m compileall (syntax gate for the L1/L2 layers) =="
if command -v python3 >/dev/null 2>&1; then
  python3 -m compileall -q ../python
else
  echo "WARNING: python3 not found; skipping compileall"
fi

echo "== serve round-trip smoke (fail-fast) =="
cargo test -q serve_round_trip_smoke

echo "== serve data-plane smoke: upload -> submit -> status (stub executor) =="
cargo test -q --test integration_serve upload_submit_status_round_trip

echo "== protocol v1-compat smoke: raw pre-hello lines round-trip byte-identically =="
cargo test -q --test integration_serve v1_raw_lines_are_byte_compatible

echo "== protocol v2 watch smoke: queued,running,done event stream for one job =="
cargo test -q --test integration_serve watch_streams_job_lifecycle

echo "== cancel-running-job smoke: running -> cancelled at an iteration boundary (stub daemon) =="
cargo test -q --test integration_serve cancel_running_job_over_the_wire

echo "== fleet router smoke: upload/submit/watch/cancel through a 2-backend router (affinity + global ids) =="
cargo test -q --test integration_router router_upload_submit_watch_affinity

echo "== coalesced-batch smoke: 4 compatible jobs -> 1 batched dispatch, per-job lifecycles + mid-batch cancel (live daemon) =="
cargo test -q --test integration_serve coalesced_batch_keeps_per_job_lifecycles_over_the_wire

echo "== exactly-once smoke: dedup token resubmission across a daemon restart =="
cargo test -q --test integration_serve dedup_resubmission_is_exactly_once_across_restart

echo "== service bench smoke: batched-vs-sequential throughput -> BENCH_service.json =="
CLAIRE_BENCH_SMOKE=1 cargo bench --bench bench_service

echo "== cargo doc --no-deps (public API docs, warnings as errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== cargo test -q (tier-1) =="
cargo test -q

echo "CI OK"
