#!/usr/bin/env python3
"""Invariant lint for the claire crate — dependency-free mirror of
`cargo xtask lint` (rust/xtask/src/main.rs).

Both implementations are generated from ONE rule list (kept in lockstep by
hand; the rule IDs and semantics below must match xtask's RULES table):

  R1 shim-imports   No direct `std::sync::{Mutex,Condvar,RwLock,atomic}` or
                    `std::thread` import/use anywhere in rust/src outside
                    util/sync.rs. `std::sync::Arc` is allowed (the shim
                    re-exports std's Arc under loom too — see its docs).
  R2 lock-order     serve/scheduler.rs declared order: Inner.st(1) before
                    sink(2) before subs(3) before events(4). Taking an
                    earlier-ranked lock while a later-ranked guard is in
                    scope (intraprocedural, nested `.lock()` scopes) is an
                    inversion.
  R3 store-journal  The volume-store lock is never held across a journal
                    write (`.append(` / `journal` inside a lock scope in
                    serve/store.rs).
  R4 error-codes    error.rs::ErrorCode stays in sync with DESIGN.md's
                    "Structured errors" registry: every code appears
                    backticked in the section; every table row's code
                    exists with matching `retryable` and CLI exit code.
                    (`unavailable` lives in the section's prose, not the
                    table — presence is still required.)
  R5 emit-guards    Back-compat emit-only-when-present fields must stay
                    behind a conditional: every emission site of a field
                    declared in DESIGN.md's "#### Conditional wire
                    fields" table must have an enclosing `if` opener
                    before the enclosing `fn`. The obligations are
                    parsed from that table (no hand-maintained needle
                    list); `analyze` checks the table itself for
                    completeness against the source, so the two passes
                    close the drift loop in both directions.
  R6 template-sync  The template subsystem and the reduce verb's module
                    must take sync primitives through the util/sync.rs
                    shim: any file under template/ (or serve/daemon.rs)
                    that mentions Mutex/RwLock/Condvar/`thread::` must
                    import `crate::util::sync`.

Exit 0 with no output (beyond the summary) when clean; exit 1 listing
violations otherwise. Runs on bare python3 — no Rust toolchain, no pip.
`--selftest` runs the rules against synthetic bad/good fixtures (the
negative tests mirroring rust/xtask's `cargo test -p xtask`).
"""

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "rust", "src")
DESIGN = os.path.join(REPO, "DESIGN.md")

# -- The rule list (mirror of xtask's RULES) --------------------------------

SHIM_EXEMPT = ("util/sync.rs",)
SHIM_FORBIDDEN = [
    re.compile(r"use\s+std::sync::atomic"),
    re.compile(r"use\s+std::sync::[^;]*\b(Mutex|Condvar|RwLock|Barrier|Once)\b"),
    re.compile(r"use\s+std::thread\b"),
    re.compile(r"std::sync::(Mutex|Condvar|RwLock)\b"),
    re.compile(r"std::sync::atomic::"),
    re.compile(r"std::thread::"),
]

LOCK_ORDER_FILE = "serve/scheduler.rs"
# (needle, human name, rank) — lower ranks must be taken first.
LOCK_RANKS = [
    ("inner.st.lock(", "Inner.st", 1),
    (".sink.lock(", "sink", 2),
    (".subs.lock(", "subs", 3),
    (".events.lock(", "events", 4),
]

STORE_JOURNAL_FILE = "serve/store.rs"
STORE_JOURNAL_TOKENS = ("journal", ".append(")

DESIGN_SECTION = "### Structured errors"

# R5's (file, field) obligations are parsed from this DESIGN.md table —
# the same table `analyze` checks for completeness against the source.
EMIT_GUARDS_SECTION = "#### Conditional wire fields"

# R6 scope: template subsystem files (prefix) + the reduce verb's home.
TEMPLATE_SYNC_SCOPE = ("template/", "serve/daemon.rs")
TEMPLATE_SYNC_TOKENS = ("Mutex", "RwLock", "Condvar", "thread::")
TEMPLATE_SYNC_SHIM = "crate::util::sync"

violations = []


def flag(path, lineno, rule, msg):
    rel = os.path.relpath(path, REPO)
    violations.append(f"{rel}:{lineno}: [{rule}] {msg}")


def strip_comment(line):
    # Good enough for this tree: no `//` inside string literals on the
    # lines these rules look at.
    i = line.find("//")
    return line if i < 0 else line[:i]


def rs_files():
    out = []
    for root, _dirs, files in os.walk(SRC):
        for f in sorted(files):
            if f.endswith(".rs"):
                out.append(os.path.join(root, f))
    return sorted(out)


# -- R1: shim imports -------------------------------------------------------

def rule_shim_imports():
    for path in rs_files():
        rel = os.path.relpath(path, SRC).replace(os.sep, "/")
        if rel in SHIM_EXEMPT:
            continue
        with open(path, encoding="utf-8") as fh:
            for lineno, line in enumerate(fh, 1):
                code = strip_comment(line)
                for pat in SHIM_FORBIDDEN:
                    if pat.search(code):
                        flag(path, lineno, "shim-imports",
                             f"direct std sync/thread use ({pat.pattern!r}); "
                             "import via crate::util::sync instead")
                        break


# -- R2/R3 shared scope machinery ------------------------------------------

GUARD_BIND = re.compile(r"\blet\s+(?:mut\s+)?(\w+)(?:\s*:\s*[^=]+)?\s*=\s*[^;]*\.lock\(\)\s*\.unwrap\(\)\s*;\s*$")
DROP_CALL = re.compile(r"\bdrop\(\s*(\w+)\s*\)")


def scan_lock_scopes(path, on_acquire, on_line=None):
    """Walk a file tracking brace depth and bound lock guards.

    `on_acquire(lineno, line, held)` is called for every line containing a
    `.lock(` call, with `held` = list of (needle, name, rank, var, depth)
    currently in scope. Guards bound with `let` (statement ending right at
    `.unwrap();` — i.e. the guard itself is bound, not a derived value)
    are held until their block closes or an explicit `drop(var)`.
    `on_line(lineno, line, held)` is called for every line.
    """
    held = []
    depth = 0
    with open(path, encoding="utf-8") as fh:
        for lineno, raw in enumerate(fh, 1):
            line = strip_comment(raw)
            # Guards whose block closed on a previous line are gone.
            m = DROP_CALL.search(line)
            if m:
                held = [h for h in held if h[3] != m.group(1)]
            if on_line:
                on_line(lineno, line, held)
            if ".lock(" in line:
                on_acquire(lineno, line, held)
                bind = GUARD_BIND.search(line)
                if bind:
                    for needle, name, rank in LOCK_RANKS:
                        if needle in line:
                            held.append((needle, name, rank, bind.group(1), depth))
                            break
                    else:
                        held.append((None, "unranked", None, bind.group(1), depth))
            depth += line.count("{") - line.count("}")
            # A guard bound at depth d lives while depth >= d.
            held = [h for h in held if depth >= h[4]]


def rule_lock_order():
    path = os.path.join(SRC, LOCK_ORDER_FILE)

    def on_acquire(lineno, line, held):
        for needle, name, rank in LOCK_RANKS:
            if needle in line:
                for _n, hname, hrank, _v, _d in held:
                    if hrank is not None and hrank > rank:
                        flag(path, lineno, "lock-order",
                             f"acquires {name} (rank {rank}) while holding "
                             f"{hname} (rank {hrank}); declared order is "
                             "Inner.st < sink < subs < events")
                break

    scan_lock_scopes(path, on_acquire)


def rule_store_journal():
    path = os.path.join(SRC, STORE_JOURNAL_FILE)

    def on_line(lineno, line, held):
        if held and any(tok in line.lower() for tok in STORE_JOURNAL_TOKENS):
            flag(path, lineno, "store-journal",
                 "journal write while the store lock is held")

    scan_lock_scopes(path, lambda *_: None, on_line=on_line)


# -- R4: ErrorCode <-> DESIGN.md -------------------------------------------

def parse_error_rs():
    path = os.path.join(SRC, "error.rs")
    text = open(path, encoding="utf-8").read()
    codes = dict(re.findall(r'ErrorCode::(\w+)\s*=>\s*"(\w+)"', text))
    if not codes:
        flag(path, 1, "error-codes", "could not parse ErrorCode::as_str")
        return None
    m = re.search(r"fn retryable[^{]*\{(.*?)\n    \}", text, re.S)
    retryable = set(re.findall(r"ErrorCode::(\w+)", m.group(1))) if m else set()
    m = re.search(r"fn exit_code[^{]*\{(.*?)\n    \}", text, re.S)
    exits = {}
    if m:
        for arms, num in re.findall(r"((?:ErrorCode::\w+\s*\|?\s*)+)=>\s*(\d+)", m.group(1)):
            for variant in re.findall(r"ErrorCode::(\w+)", arms):
                exits[variant] = int(num)
    return path, codes, retryable, exits


def rule_error_codes():
    parsed = parse_error_rs()
    if parsed is None:
        return
    path, codes, retryable, exits = parsed
    design = open(DESIGN, encoding="utf-8").read()
    start = design.find(DESIGN_SECTION)
    if start < 0:
        flag(DESIGN, 1, "error-codes", f"section {DESIGN_SECTION!r} not found")
        return
    end = design.find("\n### ", start + 1)
    section = design[start:end if end > 0 else len(design)]
    sec_line = design[:start].count("\n") + 1

    rows = re.findall(r"^\|\s*`(\w+)`\s*\|[^|]*\|\s*(yes|no)\s*\|\s*(\d+)\s*\|",
                      section, re.M)
    by_wire = {wire: var for var, wire in codes.items()}
    for wire, retry, exit_code in rows:
        var = by_wire.get(wire)
        if var is None:
            flag(DESIGN, sec_line, "error-codes",
                 f"table lists `{wire}` but error.rs has no such code")
            continue
        code_retry = "yes" if var in retryable else "no"
        if code_retry != retry:
            flag(DESIGN, sec_line, "error-codes",
                 f"`{wire}`: table says retryable={retry}, error.rs says {code_retry}")
        if exits.get(var) != int(exit_code):
            flag(DESIGN, sec_line, "error-codes",
                 f"`{wire}`: table says exit {exit_code}, error.rs says {exits.get(var)}")
    for var, wire in codes.items():
        if f"`{wire}`" not in section:
            flag(path, 1, "error-codes",
                 f"ErrorCode::{var} (`{wire}`) is not documented in DESIGN.md's "
                 f"{DESIGN_SECTION!r} section")


# -- R5: emit-only-when-present guards --------------------------------------

FN_DEF = re.compile(r"\bfn\b")
IF_KW = re.compile(r"\bif\b")


def emit_guard_obligations():
    """(rel file, field) rows from DESIGN.md's declared table."""
    design = open(DESIGN, encoding="utf-8").read()
    start = design.find(EMIT_GUARDS_SECTION)
    if start < 0:
        flag(DESIGN, 1, "emit-guards",
             f"section {EMIT_GUARDS_SECTION!r} not found")
        return []
    tail = design[start:]
    end = len(tail)
    for stop in ("\n## ", "\n### ", "\n#### "):
        i = tail.find(stop, 1)
        if 0 < i < end:
            end = i
    rows = re.findall(r"^\|\s*`([\w/.]+)`\s*\|\s*`(\w+)`\s*\|", tail[:end], re.M)
    if not rows:
        flag(DESIGN, design[:start].count("\n") + 1, "emit-guards",
             f"{EMIT_GUARDS_SECTION!r} holds no | `file` | `field` | rows")
    return rows


def emission_sites(lines, field):
    """Line indices emitting `field` via the post-hoc insert/push idioms
    (including the two-line rustfmt split), non-test code only."""
    sites = []
    single = re.compile(r'(?:\.insert\(|\.push\(\()"' + re.escape(field) + '"')
    for i, raw in enumerate(lines):
        if "#[cfg(test)]" in raw:
            break  # test modules are file-final by crate convention
        code = strip_comment(raw)
        if single.search(code):
            sites.append(i)
        elif (re.search(r"\.(?:push\(\(|insert\()\s*$", code)
              and i + 1 < len(lines)
              and re.match(r'\s*"' + re.escape(field) + '"',
                           strip_comment(lines[i + 1]))):
            sites.append(i)
    return sites


def rule_emit_guards():
    for rel, field in emit_guard_obligations():
        path = os.path.join(SRC, rel)
        if not os.path.exists(path):
            flag(path, 1, "emit-guards",
                 f"DESIGN.md declares conditional field `{field}` in a "
                 "file that does not exist (stale row?)")
            continue
        lines = open(path, encoding="utf-8").read().splitlines()
        sites = emission_sites(lines, field)
        for i in sites:
            bal = 0
            guarded = False
            for j in range(i - 1, -1, -1):
                code = strip_comment(lines[j])
                bal += code.count("{") - code.count("}")
                if bal > 0:  # an enclosing opener
                    if IF_KW.search(code):
                        guarded = True
                        break
                    if FN_DEF.search(code):
                        break
                    bal = 0  # consumed this level; keep climbing
            if not guarded:
                flag(path, i + 1, "emit-guards",
                     f"`{field}` emitted unconditionally — this field is "
                     "emit-only-when-present for wire/journal back-compat")
        if not sites:
            flag(path, 1, "emit-guards",
                 f"declared conditional field `{field}` has no emission "
                 "site (stale DESIGN.md row?)")


# -- R6: template/reduce sync discipline -------------------------------------

def rule_template_sync():
    """R1 bans std::sync tree-wide; R6 adds the *positive* requirement in
    the template subsystem and the reduce verb's module: a scoped file
    mentioning a sync primitive must import crate::util::sync, even if
    the primitive comes from somewhere R1 does not know about."""
    for path in rs_files():
        rel = os.path.relpath(path, SRC).replace(os.sep, "/")
        scoped = any(
            rel == s or (s.endswith("/") and rel.startswith(s))
            for s in TEMPLATE_SYNC_SCOPE
        )
        if not scoped:
            continue
        text = open(path, encoding="utf-8").read()
        has_shim = TEMPLATE_SYNC_SHIM in text
        if has_shim:
            continue
        for lineno, raw in enumerate(text.splitlines(), 1):
            code = strip_comment(raw)
            tok = next((t for t in TEMPLATE_SYNC_TOKENS if t in code), None)
            if tok:
                flag(path, lineno, "template-sync",
                     f"uses sync primitive `{tok}` but never imports "
                     f"{TEMPLATE_SYNC_SHIM} — template/reduce modules must "
                     "go through the util/sync.rs shim")
                break  # one flag per file is enough signal


# -- Negative-fixture selftest ------------------------------------------------

def selftest():
    """Run R5/R6 against synthetic bad/good fixtures. Mirrors xtask's
    `#[cfg(test)]` negatives for containers with no Rust toolchain."""
    global SRC, DESIGN, violations
    import tempfile
    saved = (SRC, DESIGN, violations)
    with tempfile.TemporaryDirectory() as td:
        os.makedirs(os.path.join(td, "template"))
        os.makedirs(os.path.join(td, "serve"))
        with open(os.path.join(td, "template", "bad.rs"), "w") as fh:
            fh.write("use other::sync::Mutex;\nfn f() { let _ = Mutex::new(0); }\n")
        with open(os.path.join(td, "template", "good.rs"), "w") as fh:
            fh.write("use crate::util::sync::Mutex;\nfn f() { let _ = Mutex::new(0); }\n")
        with open(os.path.join(td, "serve", "daemon.rs"), "w") as fh:
            fh.write("fn f() { let h = thread::spawn(|| {}); h.join().unwrap(); }\n")
        # Out of R6 scope: primitives elsewhere are R1's business.
        with open(os.path.join(td, "serve", "router.rs"), "w") as fh:
            fh.write("use other::sync::RwLock;\nfn f() { let _ = RwLock::new(0); }\n")
        with open(os.path.join(td, "serve", "proto.rs"), "w") as fh:
            fh.write(
                'fn encode_bad(m, v) {\n'
                '    m.insert("velocity".into(), Json::str(x));\n'
                '}\n'
                'fn encode_good(m, v) {\n'
                '    if let Some(w) = &v.warped {\n'
                '        m.insert("warped".into(), Json::str(w));\n'
                '    }\n'
                '}\n')
        with open(os.path.join(td, "DESIGN.md"), "w") as fh:
            fh.write(
                "#### Conditional wire fields\n\n"
                "| File | Field | Emitted when |\n"
                "|---|---|---|\n"
                "| `serve/proto.rs` | `velocity` | retained |\n"
                "| `serve/proto.rs` | `warped` | retained |\n")
        SRC = td
        DESIGN = os.path.join(td, "DESIGN.md")
        violations = []
        rule_template_sync()
        r6 = list(violations)
        assert any("template-sync" in v and "bad.rs" in v for v in r6), r6
        assert any("daemon.rs" in v and "thread::" in v for v in r6), r6
        assert not any("good.rs" in v for v in r6), r6
        assert not any("router.rs" in v for v in r6), r6
        violations = []
        rule_emit_guards()
        r5 = list(violations)
        assert any("emit-guards" in v and "velocity" in v for v in r5), r5
        assert not any("warped" in v for v in r5), r5
    SRC, DESIGN, violations = saved
    print("lint_invariants: selftest OK (template-sync + emit-guards negatives)")


def main():
    if "--selftest" in sys.argv:
        selftest()
        return 0
    rule_shim_imports()
    rule_lock_order()
    rule_store_journal()
    rule_error_codes()
    rule_emit_guards()
    rule_template_sync()
    if violations:
        for v in violations:
            print(v)
        print(f"lint_invariants: {len(violations)} violation(s)")
        return 1
    print("lint_invariants: OK (shim-imports, lock-order, store-journal, "
          "error-codes, emit-guards, template-sync)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
