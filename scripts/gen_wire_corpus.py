#!/usr/bin/env python3
"""Regenerate the golden wire corpus at
rust/tests/fixtures/wire_corpus.ndjson.

One request line per (verb, form): every verb in the wire protocol in
its v1 form (no `seq`) and its v2 form (with `seq`), every line
decodable by `Request::parse_line`. The corpus is consumed twice:

  * rust/tests/wire_corpus.rs decodes every line — a decoder change
    that breaks a committed line is a wire-compat break, caught in CI;
  * scripts/analyze_invariants.py (and `cargo xtask analyze`)
    cross-checks every field name on every line against the schema it
    extracts from serve/proto.rs into artifacts/wire_schema.json, so a
    corpus line cannot silently carry a field the decoder ignores.

Deterministic output — field values are fixed here, objects render in
insertion order — so regeneration is diff-stable. `upload` uses the
smallest legal payload: n=1, one zero f32 (4 LE bytes, base64
"AAAAAA==").
"""

import json
import os

OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "rust", "tests", "fixtures", "wire_corpus.ndjson")

FULL_JOB = {
    "subject": "na02",
    "n": 16,
    "variant": "opt-fd8-cubic",
    "precision": "mixed",
    "priority": "emergency",
    "algorithm": "gn",
    "multires": 3,
    "max_iter": 50,
    "max_krylov": 10,
    "beta": 0.0005,
    "gamma": 1.0,
    "gtol": 0.05,
    "continuation": True,
    "incompressible": False,
    "verbose": False,
}

UPLOADED_JOB = {
    "n": 16,
    "source": {"m0": "vol-a", "m1": "vol-b"},
    "dedup": "client-1/try-1",
    "warm_start": "vel-prev",
}

# (verb, v1 body, v2 body) — bodies exclude cmd/seq.
LINES = [
    ("ping", {}, {}),
    ("hello", {"proto": 1}, {"proto": 2}),
    ("upload", {"n": 1, "data": "AAAAAA=="}, {"n": 1, "data": "AAAAAA=="}),
    ("submit", {"job": FULL_JOB}, {"job": UPLOADED_JOB}),
    ("submit_batch",
     {"jobs": [{"subject": "na02", "n": 16}, {"subject": "na03", "n": 16}]},
     {"jobs": [{"subject": "na02", "n": 16}]}),
    ("status", {}, {"id": 7}),
    ("cancel", {"id": 7}, {"id": 7}),
    ("watch", {}, {}),
    ("reduce",
     {"ids": ["vol-a", "vol-b"], "pin": True},
     {"jobs": [3, 4, 5], "field": "velocity", "scale": 1.0,
      "apply": "tpl-1", "ref": "tpl-1", "pin": True, "unpin": "tpl-0"}),
    ("stats", {}, {}),
    ("shutdown", {"drain": True}, {"drain": False}),
]


def main():
    lines = []
    seq = 0
    for verb, v1, v2 in LINES:
        lines.append({"cmd": verb, **v1})
        seq += 1
        lines.append({"cmd": verb, **v2, "seq": seq})
    with open(OUT, "w") as fh:
        for obj in lines:
            fh.write(json.dumps(obj, separators=(",", ":")) + "\n")
    print(f"wrote {len(lines)} lines to {os.path.relpath(OUT)}")


if __name__ == "__main__":
    main()
