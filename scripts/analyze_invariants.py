#!/usr/bin/env python3
"""Semantic invariant analysis for the claire crate — dependency-free
mirror of `cargo xtask analyze` (rust/xtask/src/analyze.rs).

Where `lint_invariants.py` greps for needles, this pass *extracts facts*
from the source and checks them against declared models in DESIGN.md.
Both implementations are kept in lockstep by hand (rule IDs and
semantics below must match xtask's analyze module):

  A1 lifecycle     Extract the real job-lifecycle transition graph from
                   serve/scheduler.rs (every `rec.state = JobState::X`
                   with its guarding `if rec.state != …` / `match
                   rec.state` arm / `// lifecycle: from -> to`
                   annotation, plus the JobRecord construction state)
                   and the template round-state machine from
                   template/journal.rs (journal line kinds + `//
                   lifecycle:` annotations). Check both against the
                   declared tables in DESIGN.md ("#### Job lifecycle
                   transitions" / "#### Template round-state
                   transitions"): an extracted transition missing from
                   the table fails, and so does a declared row no code
                   implements. Declared terminal states must have no
                   outgoing edges. Emits artifacts/lifecycle.dot.

  A2 wire-schema   Walk serve/proto.rs (and request.rs) encode/decode
                   paths: per-verb request field sets from
                   `Request::from_json` match arms and
                   `Request::to_json`, object field sets from the
                   job/stats/node-stats/job-request/event codec pairs.
                   Check: encoded fields are a subset of decoded fields
                   (we can always parse what we emit), the verb set
                   matches DESIGN.md's "### Requests" table, and every
                   *conditionally* emitted field (`insert("f"`/
                   `push(("f"` behind an `if`) appears in DESIGN.md's
                   "#### Conditional wire fields" table — and every
                   declared row is still conditional in the source.
                   This table is what lint R5's emit-guard obligations
                   are derived from (the old hand-maintained needle
                   table is gone). Cross-checks the golden corpus
                   (rust/tests/fixtures/wire_corpus.ndjson): every verb
                   covered in v1 (no seq) and v2 (seq) form, every
                   field decodable. Emits artifacts/wire_schema.json.

  A3 panic-budget  Inventory of panic-shaped sites (`unwrap()`,
                   `expect(`, `panic!`, `unreachable!`, `todo!`,
                   `unimplemented!`) and slice-indexing sites in
                   non-test rust/src code (counting stops at the first
                   `#[cfg(test)]`), checked against
                   scripts/panic_budget.toml. A file over budget fails;
                   a file *under* budget also fails until the budget is
                   ratcheted down (budgets only ever decrease); missing
                   and stale entries fail. Wire-decode files
                   (serve/proto.rs, request.rs, util/json.rs) must
                   budget zero panic sites — malformed client input
                   must surface as structured errors, never a panic.

Exit 0 when clean; exit 1 listing violations. Runs on bare python3 —
no Rust toolchain, no pip. `--selftest` runs the analyses against
synthetic bad/good fixtures (mirroring xtask's `#[cfg(test)]`
negatives): an injected illegal state transition, a schema/DESIGN.md
conditional-field mismatch, and a panic-budget overrun.
"""

import json
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "rust", "src")
DESIGN = os.path.join(REPO, "DESIGN.md")
BUDGET = os.path.join(REPO, "scripts", "panic_budget.toml")
CORPUS = os.path.join(REPO, "rust", "tests", "fixtures", "wire_corpus.ndjson")
ARTIFACTS = os.path.join(REPO, "artifacts")

SCHED_FILE = "serve/scheduler.rs"
TEMPLATE_JOURNAL_FILE = "template/journal.rs"
PROTO_FILE = "serve/proto.rs"
REQUEST_FILE = "request.rs"

# Files whose insert("f")/push(("f") emission sites feed the
# conditional-wire-field extraction (the wire/journal encoders).
CONDITIONAL_SCAN_FILES = (
    "serve/proto.rs",
    "request.rs",
    "serve/journal.rs",
    "template/journal.rs",
)

# Decode-path files that must budget ZERO panic sites: everything
# reachable from a malformed client line must be a structured error.
ZERO_PANIC_FILES = ("serve/proto.rs", "request.rs", "util/json.rs")

JOB_TABLE_ANCHOR = "#### Job lifecycle transitions"
ROUND_TABLE_ANCHOR = "#### Template round-state transitions"
COND_TABLE_ANCHOR = "#### Conditional wire fields"
REQUESTS_ANCHOR = "### Requests"

NEW_STATE = "(new)"
START_STATE = "(start)"

violations = []


def flag(path, lineno, rule, msg):
    rel = os.path.relpath(path, REPO)
    violations.append(f"{rel}:{lineno}: [{rule}] {msg}")


def strip_comment(line):
    # Good enough for this tree: no `//` inside string literals on the
    # lines these analyses look at.
    i = line.find("//")
    return line if i < 0 else line[:i]


def read(path):
    with open(path, encoding="utf-8") as fh:
        return fh.read()


def design_section(design_text, anchor):
    """(section text, 1-based start line) or (None, 0). A section runs
    from its anchor heading to the next heading of same-or-higher level."""
    start = design_text.find(anchor)
    if start < 0:
        return None, 0
    level = anchor.split(" ", 1)[0]  # "####" or "###"
    stops = ["\n## "]
    if len(level) >= 3:
        stops.append("\n### ")
    if len(level) >= 4:
        stops.append("\n#### ")
    tail = design_text[start:]
    end = len(tail)
    for s in stops:
        i = tail.find(s, 1)
        if 0 < i < end:
            end = i
    return tail[:end], design_text[:start].count("\n") + 1


def parse_pair_table(section):
    """First-two-backticked-cell rows: | `a` | `b` | ... -> [(a, b)]."""
    rows = []
    for line in section.splitlines():
        m = re.match(r"^\|\s*`([\w()./|-]+)`\s*\|\s*`([\w()./|-]+)`\s*\|", line)
        if m:
            rows.append((m.group(1), m.group(2)))
    return rows


def fn_region(text, marker):
    """Brace-matched body of the first fn whose definition contains
    `marker` (e.g. "fn job_to_json"). Returns (body, 1-based line) or
    (None, 0). Brace counting is string-naive, which is fine here:
    braces inside the format! literals of these codecs come in pairs."""
    start = text.find(marker)
    if start < 0:
        return None, 0
    open_i = text.find("{", start)
    if open_i < 0:
        return None, 0
    depth = 0
    for i in range(open_i, len(text)):
        c = text[i]
        if c == "{":
            depth += 1
        elif c == "}":
            depth -= 1
            if depth == 0:
                return text[open_i : i + 1], text[:start].count("\n") + 1
    return None, 0


def is_guarded(lines, i):
    """Emit-guard climb (same algorithm as lint R5): does line i have an
    enclosing `if` opener before the enclosing `fn`?"""
    bal = 0
    for j in range(i - 1, -1, -1):
        code = strip_comment(lines[j])
        bal += code.count("{") - code.count("}")
        if bal > 0:  # an enclosing opener
            if re.search(r"\bif\b", code):
                return True
            if re.search(r"\bfn\b", code):
                return False
            bal = 0  # consumed this level; keep climbing
    return False


# -- A1: lifecycle state-machine extraction ---------------------------------

LIFECYCLE_ANN = re.compile(r"//\s*lifecycle:\s*([\w()|]+)\s*->\s*([\w()]+)")
STATE_MUT = re.compile(r"rec\.state\s*=\s*JobState::(\w+)\s*;")
STATE_CONSTRUCT = re.compile(r"\bstate:\s*JobState::(\w+)\s*,")
GUARD_NEQ = re.compile(r"if\s+rec\.state\s*!=\s*JobState::(\w+)")
MATCH_ARM = re.compile(r"^\s*JobState::(\w+)\s*=>")


def lower(name):
    # JobState::Queued -> "queued" (as_str is lowercase of the variant).
    return name.lower()


def extract_job_edges(sched_path):
    """[(from, to, lineno)] from scheduler source, plus flagged sites the
    analysis cannot resolve."""
    text = read(sched_path)
    raw_lines = text.splitlines()
    edges = []
    for i, raw in enumerate(raw_lines):
        code = strip_comment(raw)
        m = STATE_MUT.search(code)
        if m:
            to = lower(m.group(1))
            ann = LIFECYCLE_ANN.search(raw)
            if ann:
                if lower(ann.group(2)) != to:
                    flag(sched_path, i + 1, "lifecycle",
                         f"annotation says `-> {ann.group(2)}` but the "
                         f"assignment sets JobState::{m.group(1)}")
                for frm in ann.group(1).split("|"):
                    edges.append((lower(frm), to, i + 1))
                continue
            frm = None
            for j in range(i - 1, -1, -1):
                prev = strip_comment(raw_lines[j])
                g = GUARD_NEQ.search(prev)
                if g:
                    frm = lower(g.group(1))
                    break
                a = MATCH_ARM.match(prev)
                if a:
                    frm = lower(a.group(1))
                    break
                if re.search(r"\bfn\b", prev):
                    break
            if frm is None:
                flag(sched_path, i + 1, "lifecycle",
                     "cannot derive the from-state of this transition "
                     "(no `if rec.state != …` guard, `match rec.state` "
                     "arm, or `// lifecycle: from -> to` annotation)")
            else:
                edges.append((frm, to, i + 1))
            continue
        m = STATE_CONSTRUCT.search(code)
        if m:
            # Initial state of a freshly constructed record — but only
            # in a JobRecord literal (WatchEvent snapshots are views of
            # existing state, not transitions).
            for j in range(i, -1, -1):
                prev = strip_comment(raw_lines[j])
                if "JobRecord {" in prev:
                    edges.append((NEW_STATE, lower(m.group(1)), i + 1))
                    break
                if "WatchEvent {" in prev:
                    break
    return edges


def extract_job_states(sched_path):
    """(variant names lowercased, terminal names lowercased)."""
    text = read(sched_path)
    m = re.search(r"enum JobState\s*\{(.*?)\}", text, re.S)
    states = []
    if m:
        states = [lower(v) for v in re.findall(r"\b([A-Z]\w*)\b", m.group(1))]
    t = re.search(r"fn is_terminal[^{]*\{\s*matches!\(self,\s*(.*?)\)\s*\}", text, re.S)
    terminals = [lower(v) for v in re.findall(r"JobState::(\w+)", t.group(1))] if t else []
    return states, terminals


def extract_round_machine(journal_path):
    """(appended kinds, replayed kinds, annotated edges [(from,to,line)],
    has sequential-order guard)."""
    text = read(journal_path)
    appended = sorted(set(re.findall(r'\("kind",\s*Json::str\("(\w+)"\)\)', text)))
    replay_body, _ = fn_region(text, "fn replay")
    replay_body = replay_body or ""
    replayed = sorted(set(re.findall(r'Some\("(\w+)"\)\s*=>', replay_body)))
    edges = []
    for i, raw in enumerate(text.splitlines()):
        ann = LIFECYCLE_ANN.search(raw)
        if ann:
            for frm in ann.group(1).split("|"):
                edges.append((frm, ann.group(2), i + 1))
    has_seq_guard = "rounds.len() + 1" in replay_body
    return appended, replayed, edges, has_seq_guard


def check_machine(rule, path, design_path, extracted, declared, sec_line, what):
    """Extracted-vs-declared edge diff, both directions."""
    extracted_set = {(f, t) for f, t, _ in extracted}
    declared_set = set(declared)
    for f, t, lineno in extracted:
        if (f, t) not in declared_set:
            flag(path, lineno, rule,
                 f"implements undeclared {what} transition `{f}` -> `{t}` "
                 f"(add it to DESIGN.md's table or fix the code)")
    for f, t in declared:
        if (f, t) not in extracted_set:
            flag(design_path, sec_line, rule,
                 f"declares {what} transition `{f}` -> `{t}` that no "
                 "code implements")


def analysis_lifecycle(write_artifacts=True):
    sched_path = os.path.join(SRC, SCHED_FILE)
    tj_path = os.path.join(SRC, TEMPLATE_JOURNAL_FILE)
    design = read(DESIGN)

    # Job lifecycle.
    edges = extract_job_edges(sched_path)
    states, terminals = extract_job_states(sched_path)
    section, sec_line = design_section(design, JOB_TABLE_ANCHOR)
    if section is None:
        flag(DESIGN, 1, "lifecycle", f"section {JOB_TABLE_ANCHOR!r} not found")
        declared = []
    else:
        declared = parse_pair_table(section)
        if not declared:
            flag(DESIGN, sec_line, "lifecycle",
                 f"{JOB_TABLE_ANCHOR!r} holds no | `from` | `to` | rows")
    check_machine("lifecycle", sched_path, DESIGN, edges, declared, sec_line, "job")
    for f, t in declared:
        if f in terminals:
            flag(DESIGN, sec_line, "lifecycle",
                 f"terminal state `{f}` (JobState::is_terminal) has a "
                 f"declared outgoing transition to `{t}`")
        for s in (x for x in (f, t) if x != NEW_STATE):
            if states and s not in states:
                flag(DESIGN, sec_line, "lifecycle",
                     f"declared transition names unknown state `{s}` "
                     f"(JobState has {', '.join(states)})")

    # Template round-state machine.
    appended, replayed, redges, has_seq_guard = extract_round_machine(tj_path)
    for kind in appended:
        if kind not in replayed:
            flag(tj_path, 1, "lifecycle",
                 f"journal line kind `{kind}` is appended but replay() "
                 "never handles it (restart would silently drop it)")
    rsection, rsec_line = design_section(design, ROUND_TABLE_ANCHOR)
    if rsection is None:
        flag(DESIGN, 1, "lifecycle", f"section {ROUND_TABLE_ANCHOR!r} not found")
        rdeclared = []
    else:
        rdeclared = parse_pair_table(rsection)
    check_machine("lifecycle", tj_path, DESIGN, redges, rdeclared, rsec_line, "round-state")
    declared_kinds = {t for _, t in rdeclared}
    for kind in appended:
        if rdeclared and kind not in declared_kinds:
            flag(tj_path, 1, "lifecycle",
                 f"journal line kind `{kind}` does not appear in the "
                 "declared round-state table")
    if not has_seq_guard:
        flag(tj_path, 1, "lifecycle",
             "replay() no longer enforces sequential round order "
             "(`rounds.len() + 1` guard missing) — the `round` -> "
             "`round` row in DESIGN.md promises strict sequencing")

    if write_artifacts and not violations:
        os.makedirs(ARTIFACTS, exist_ok=True)
        with open(os.path.join(ARTIFACTS, "lifecycle.dot"), "w") as fh:
            fh.write("// Generated by the invariant analyzer (cargo xtask "
                     "analyze / scripts/analyze_invariants.py). Do not edit.\n")
            fh.write("digraph job_lifecycle {\n  rankdir=LR;\n")
            for f, t in sorted({(f, t) for f, t, _ in edges}):
                fh.write(f'  "{f}" -> "{t}";\n')
            for s in terminals:
                fh.write(f'  "{s}" [shape=doublecircle];\n')
            fh.write("}\n")
            fh.write("digraph template_rounds {\n  rankdir=LR;\n")
            for f, t in sorted({(f, t) for f, t, _ in redges}):
                fh.write(f'  "{f}" -> "{t}";\n')
            fh.write("}\n")


# -- A2: wire-schema extraction & conformance --------------------------------

GET_FIELD = re.compile(r'\bget\("(\w+)"\)')
PAIR_FIELD = re.compile(r'\("(\w+)",')
ENVELOPE = {"cmd", "seq"}


def split_str_arms(region):
    """`"verb" => …` arms of a match-on-string region: {verb: chunk}."""
    parts = re.split(r'\n\s*"(\w+)"\s*=>', region)
    arms = {}
    for k in range(1, len(parts), 2):
        arms.setdefault(parts[k], []).append(parts[k + 1])
    return {v: "\n".join(chunks) for v, chunks in arms.items()}


def decode_fields(chunk):
    fields = set(GET_FIELD.findall(chunk))
    # Local reader closures: str_opt("k") in the reduce arm, num("k") in
    # the progress-event arm (both wrap j.get(k) with a typed error).
    fields |= set(re.findall(r'\bstr_opt\("(\w+)"\)', chunk))
    fields |= set(re.findall(r'\bnum\("(\w+)"\)', chunk))
    if "id_of(" in chunk:
        fields.add("id")
    return fields - ENVELOPE


def extract_request_schema(proto_text, proto_path):
    """{verb: {"decode": set, "encode": set}}."""
    start = proto_text.find("match cmd {")
    end = proto_text.find("unknown command")
    if start < 0 or end < 0:
        flag(proto_path, 1, "wire-schema",
             "cannot locate Request::from_json's `match cmd` dispatch")
        return {}
    arms = split_str_arms(proto_text[start:end])
    schema = {v: {"decode": decode_fields(chunk), "encode": set()}
              for v, chunk in arms.items()}

    # Encode side: chunks of Request::to_json keyed by ("cmd", …"verb").
    to_json_end = proto_text.find("pub fn to_line")
    encode_region = proto_text[:to_json_end] if to_json_end > 0 else proto_text
    marks = [(m.start(), m.group(1))
             for m in re.finditer(r'\("cmd",\s*Json::str\("(\w+)"\)\)', encode_region)]
    for k, (pos, verb) in enumerate(marks):
        stop = marks[k + 1][0] if k + 1 < len(marks) else len(encode_region)
        fields = set(PAIR_FIELD.findall(encode_region[pos:stop])) - {"cmd"}
        fields -= {"m0", "m1"}  # nested source-object keys, not verb fields
        if verb not in schema:
            flag(proto_path, 1, "wire-schema",
                 f"Request::to_json encodes verb `{verb}` that "
                 "Request::from_json cannot decode")
            continue
        schema[verb]["encode"] |= fields
    for verb, s in schema.items():
        extra = s["encode"] - s["decode"]
        if extra:
            flag(proto_path, 1, "wire-schema",
                 f"verb `{verb}` encodes field(s) {sorted(extra)} its "
                 "decode arm never reads — a round-trip would drop them")
    return schema


def extract_codec_pair(text, path, name, enc_marker, dec_marker,
                       enc_extra=(), dec_extra_re=()):
    """Field sets of an encode/decode fn pair; checks encode ⊆ decode."""
    enc_body, enc_line = fn_region(text, enc_marker)
    dec_body, _ = fn_region(text, dec_marker)
    if enc_body is None or dec_body is None:
        flag(path, 1, "wire-schema",
             f"cannot locate codec pair {enc_marker!r}/{dec_marker!r}")
        return None
    enc = set(PAIR_FIELD.findall(enc_body))
    enc |= set(re.findall(r'insert\("(\w+)"', enc_body))
    enc |= set(enc_extra)
    dec = set(GET_FIELD.findall(dec_body))
    for pat in dec_extra_re:
        dec |= set(re.findall(pat, dec_body))
    extra = enc - dec - ENVELOPE
    if extra:
        flag(path, enc_line, "wire-schema",
             f"object `{name}` encodes field(s) {sorted(extra)} the "
             "decoder never reads — a round-trip would drop them")
    return {"encode": sorted(enc), "decode": sorted(dec)}


def extract_event_schema(proto_text, proto_path):
    """{kind: {"encode": set, "decode": set}} for EventMsg."""
    enc_body, enc_line = fn_region(proto_text, "pub fn to_line(&self) -> String {\n        let mut pairs")
    if enc_body is None:
        # Fall back: the EventMsg impl is the last to_line in the file.
        idx = proto_text.rfind("pub fn to_line")
        enc_body, enc_line = fn_region(proto_text[idx:], "pub fn to_line") if idx >= 0 else (None, 0)
    dec_start = proto_text.find("fn from_json", proto_text.find("impl EventMsg"))
    dec_body, _ = fn_region(proto_text[dec_start:], "fn from_json") if dec_start >= 0 else (None, 0)
    if enc_body is None or dec_body is None:
        flag(proto_path, 1, "wire-schema", "cannot locate EventMsg codec")
        return {}
    marks = [(m.start(), m.group(1))
             for m in re.finditer(r'\("event",\s*Json::str\("(\w+)"\)\)', enc_body)]
    enc_by_kind = {}
    for k, (pos, kind) in enumerate(marks):
        stop = marks[k + 1][0] if k + 1 < len(marks) else len(enc_body)
        enc_by_kind[kind] = set(PAIR_FIELD.findall(enc_body[pos:stop])) - {"event"}
    dec_arms = split_str_arms(dec_body)
    out = {}
    for kind, enc in enc_by_kind.items():
        if kind not in dec_arms:
            flag(proto_path, enc_line, "wire-schema",
                 f"event kind `{kind}` is emitted but EventMsg::from_json "
                 "never decodes it")
            continue
        dec = decode_fields(dec_arms[kind]) | {"seq"}
        extra = enc - dec - {"seq"}
        if extra:
            flag(proto_path, enc_line, "wire-schema",
                 f"event `{kind}` encodes field(s) {sorted(extra)} its "
                 "decode arm never reads")
        out[kind] = {"encode": sorted(enc), "decode": sorted(dec)}
    return out


EMIT_SITE = re.compile(r'(?:\.insert\(|\.push\(\()"(\w+)"')


def extract_conditional_fields():
    """{(rel file, field): {"guarded": [lines], "unguarded": [lines]}}
    over every insert("f")/push(("f") emission site in the wire/journal
    encoders (the post-hoc-append idioms used for optional fields —
    always-present fields live in Json::object literals instead)."""
    sites = {}
    for rel in CONDITIONAL_SCAN_FILES:
        path = os.path.join(SRC, rel)
        lines = read(path).splitlines()
        in_tests = False
        for i, raw in enumerate(lines):
            if "#[cfg(test)]" in raw:
                in_tests = True
            if in_tests:
                continue
            code = strip_comment(raw)
            fields = [m.group(1) for m in EMIT_SITE.finditer(code)]
            # rustfmt splits wide pushes over two lines:
            #   pairs.push((
            #       "field", …
            if re.search(r"\.(?:push\(\(|insert\()\s*$", code) and i + 1 < len(lines):
                m = re.match(r'\s*"(\w+)"', strip_comment(lines[i + 1]))
                if m:
                    fields.append(m.group(1))
            for field in fields:
                entry = sites.setdefault((rel, field),
                                         {"guarded": [], "unguarded": []})
                key = "guarded" if is_guarded(lines, i) else "unguarded"
                entry[key].append(i + 1)
    return sites


def analysis_wire_schema(write_artifacts=True):
    proto_path = os.path.join(SRC, PROTO_FILE)
    request_path = os.path.join(SRC, REQUEST_FILE)
    proto = read(proto_path)
    request = read(request_path)
    design = read(DESIGN)

    verbs = extract_request_schema(proto, proto_path)

    # DESIGN.md's Requests table must list exactly the decodable verbs.
    rsection, rsec_line = design_section(design, REQUESTS_ANCHOR)
    if rsection is None:
        flag(DESIGN, 1, "wire-schema", f"section {REQUESTS_ANCHOR!r} not found")
    else:
        documented = set(re.findall(r'"cmd"\s*:\s*"(\w+)"', rsection))
        for v in sorted(set(verbs) - documented):
            flag(DESIGN, rsec_line, "wire-schema",
                 f"verb `{v}` is decodable but missing from the "
                 f"{REQUESTS_ANCHOR!r} table")
        for v in sorted(documented - set(verbs)):
            flag(DESIGN, rsec_line, "wire-schema",
                 f"{REQUESTS_ANCHOR!r} documents verb `{v}` that "
                 "Request::from_json does not decode")

    objects = {}
    spec = extract_codec_pair(
        proto, proto_path, "job", "fn job_to_json", "fn job_from_json")
    if spec:
        objects["job"] = spec
    spec = extract_codec_pair(
        proto, proto_path, "node_stats",
        "fn node_stats_to_json", "fn node_stats_from_json")
    if spec:
        objects["node_stats"] = spec
    spec = extract_codec_pair(
        proto, proto_path, "stats", "fn stats_to_json", "fn stats_from_json",
        dec_extra_re=(r'\bg\("(\w+)"\)', r'\bgs\("(\w+)"\)'))
    if spec:
        objects["stats"] = spec
    spec = extract_codec_pair(
        request, request_path, "job_request", "pub fn to_json", "pub fn from_json",
        dec_extra_re=(r'field\(j,\s*"(\w+)"', r'id_of\("(\w+)"\)'))
    if spec:
        objects["job_request"] = spec
    events = extract_event_schema(proto, proto_path)

    # Conditional (emit-only-when-present) fields vs the declared table.
    sites = extract_conditional_fields()
    csection, csec_line = design_section(design, COND_TABLE_ANCHOR)
    if csection is None:
        flag(DESIGN, 1, "wire-schema", f"section {COND_TABLE_ANCHOR!r} not found")
        declared = []
    else:
        declared = parse_pair_table(csection)
    declared_set = set(declared)
    conditional = []
    for (rel, field), entry in sorted(sites.items()):
        path = os.path.join(SRC, rel)
        if entry["guarded"] and entry["unguarded"]:
            flag(path, entry["unguarded"][0], "wire-schema",
                 f"field `{field}` is emitted both guarded (line(s) "
                 f"{entry['guarded']}) and unguarded — emit-only-when-"
                 "present discipline must be all-or-nothing per file")
        elif entry["guarded"]:
            conditional.append({"file": rel, "field": field,
                                "lines": entry["guarded"]})
            if (rel, field) not in declared_set:
                flag(path, entry["guarded"][0], "wire-schema",
                     f"conditionally emitted field `{field}` is not "
                     f"declared in DESIGN.md's {COND_TABLE_ANCHOR!r} table")
    for rel, field in declared:
        entry = sites.get((rel, field))
        if entry is None:
            flag(DESIGN, csec_line, "wire-schema",
                 f"declared conditional field `{field}` has no "
                 f"insert/push emission site in {rel} (stale row?)")
        elif entry["unguarded"] and not entry["guarded"]:
            flag(os.path.join(SRC, rel), entry["unguarded"][0], "wire-schema",
                 f"declared conditional field `{field}` is emitted "
                 "unconditionally — this field is emit-only-when-present "
                 "for wire/journal back-compat")

    # Golden corpus: every verb in v1 (bare) and v2 (seq) form, every
    # field decodable per the extracted schema.
    seen = {}  # verb -> set of forms ("v1"/"v2")
    if not os.path.exists(CORPUS):
        flag(CORPUS, 1, "wire-schema", "golden wire corpus missing")
    else:
        with open(CORPUS, encoding="utf-8") as fh:
            for lineno, line in enumerate(fh, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    obj = json.loads(line)
                except ValueError:
                    flag(CORPUS, lineno, "wire-schema", "line is not valid JSON")
                    continue
                verb = obj.get("cmd")
                if verb not in verbs:
                    flag(CORPUS, lineno, "wire-schema",
                         f"unknown verb {verb!r}")
                    continue
                seen.setdefault(verb, set()).add("v2" if "seq" in obj else "v1")
                extra = set(obj) - ENVELOPE - verbs[verb]["decode"]
                if extra:
                    flag(CORPUS, lineno, "wire-schema",
                         f"verb `{verb}` carries field(s) {sorted(extra)} "
                         "its decode arm never reads")
                jr = objects.get("job_request")
                jobs = []
                if verb == "submit" and isinstance(obj.get("job"), dict):
                    jobs = [obj["job"]]
                elif verb == "submit_batch" and isinstance(obj.get("jobs"), list):
                    jobs = [j for j in obj["jobs"] if isinstance(j, dict)]
                for j in jobs:
                    extra = set(j) - set(jr["decode"] if jr else [])
                    if jr and extra:
                        flag(CORPUS, lineno, "wire-schema",
                             f"job object carries field(s) {sorted(extra)} "
                             "JobRequest::from_json never reads")
        for verb in sorted(verbs):
            for form in ("v1", "v2"):
                if form not in seen.get(verb, set()):
                    flag(CORPUS, 1, "wire-schema",
                         f"verb `{verb}` has no {form} "
                         f"({'with' if form == 'v2' else 'no'} seq) corpus line")

    if write_artifacts and not violations:
        envelope, _ = fn_region(proto, "pub fn from_json(j: &Json) -> Result<Response>")
        os.makedirs(ARTIFACTS, exist_ok=True)
        schema = {
            "generated_by": "cargo xtask analyze / scripts/analyze_invariants.py (lockstep)",
            "verbs": {
                v: {"request": {"decode": sorted(s["decode"]),
                                "encode": sorted(s["encode"])}}
                for v, s in sorted(verbs.items())
            },
            "objects": objects,
            "events": events,
            "response_discriminators":
                sorted(set(GET_FIELD.findall(envelope or ""))),
            "conditional_fields": conditional,
        }
        with open(os.path.join(ARTIFACTS, "wire_schema.json"), "w") as fh:
            json.dump(schema, fh, indent=1, sort_keys=True)
            fh.write("\n")


# -- A3: panic-path ratchet ---------------------------------------------------

# `.expect(` with a `(?!b')` lookahead: the JSON parser's own
# `expect(b'{')` byte-matcher is not Result::expect.
PANIC_RE = re.compile(
    r"\.unwrap\(\)|\.expect\((?!b')|\bpanic!\s*\(|\bunreachable!\s*\(|"
    r"\btodo!\s*\(|\bunimplemented!\s*\(")
# Slice/array indexing proxy: an index expression directly following an
# identifier, call, or index (not `#[attr]`, not array type/literal).
INDEX_RE = re.compile(r"[A-Za-z0-9_\)\]]\[")


def count_sites(path):
    n_panic = n_index = 0
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            if "#[cfg(test)]" in line:
                break  # test modules are file-final by crate convention
            code = strip_comment(line)
            n_panic += len(PANIC_RE.findall(code))
            n_index += len(INDEX_RE.findall(code))
    return n_panic, n_index


def parse_budget(path):
    """{"panics": {file: n}, "index": {file: n}} from the flat two-table
    TOML (no dependency on a TOML parser)."""
    tables = {"panics": {}, "index": {}}
    current = None
    with open(path, encoding="utf-8") as fh:
        for lineno, raw in enumerate(fh, 1):
            line = raw.split("#", 1)[0].strip()
            if not line:
                continue
            m = re.match(r"^\[(\w+)\]$", line)
            if m:
                current = m.group(1)
                if current not in tables:
                    flag(path, lineno, "panic-budget",
                         f"unknown budget table [{current}]")
                    tables[current] = {}
                continue
            m = re.match(r'^"([^"]+)"\s*=\s*(\d+)$', line)
            if m and current:
                tables[current][m.group(1)] = int(m.group(2))
            else:
                flag(path, lineno, "panic-budget",
                     f"unparseable budget line {raw.strip()!r}")
    return tables


def analysis_panic_budget():
    if not os.path.exists(BUDGET):
        flag(BUDGET, 1, "panic-budget", "budget file missing")
        return
    budget = parse_budget(BUDGET)
    actual = {"panics": {}, "index": {}}
    for root, _dirs, files in os.walk(SRC):
        for f in sorted(files):
            if not f.endswith(".rs"):
                continue
            path = os.path.join(root, f)
            rel = os.path.relpath(path, SRC).replace(os.sep, "/")
            n_panic, n_index = count_sites(path)
            if n_panic:
                actual["panics"][rel] = n_panic
            if n_index:
                actual["index"][rel] = n_index
    for table in ("panics", "index"):
        for rel, n in sorted(actual[table].items()):
            path = os.path.join(SRC, rel)
            b = budget[table].get(rel)
            if table == "panics" and rel in ZERO_PANIC_FILES:
                flag(path, 1, "panic-budget",
                     f"decode-path file has {n} panic site(s); malformed "
                     "client input must surface as structured errors "
                     "(budget is pinned to zero)")
                continue
            if b is None:
                flag(path, 1, "panic-budget",
                     f"{n} {table} site(s) but no [{table}] budget entry "
                     "in scripts/panic_budget.toml")
            elif n > b:
                flag(path, 1, "panic-budget",
                     f"{n} {table} site(s) exceed the budget of {b} — "
                     "convert the new sites to structured errors")
            elif n < b:
                flag(path, 1, "panic-budget",
                     f"only {n} {table} site(s) against a budget of {b} — "
                     f"ratchet the budget down to {n} (budgets only "
                     "ever decrease)")
        for rel, b in sorted(budget[table].items()):
            if rel not in actual[table]:
                flag(BUDGET, 1, "panic-budget",
                     f"stale [{table}] entry for {rel} (no such site "
                     "or file) — delete it")


# -- Negative-fixture selftest ------------------------------------------------

FIXTURE_SCHED = """\
pub enum JobState {
    Queued,
    Running,
    Done,
}
impl JobState {
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done)
    }
}
fn submit(st: &mut St) {
    st.jobs.insert(id, JobRecord {
        state: JobState::Queued,
    });
}
fn dispatch(rec: &mut JobRecord) {
    if rec.state != JobState::Done {
        rec.state = JobState::Running;
    }
}
"""

FIXTURE_TJ = """\
fn append_init(&self) {
    // lifecycle: (start) -> init
    let pairs = vec![("kind", Json::str("init"))];
}
fn append_round(&self) {
    // lifecycle: init|round -> round
    let pairs = vec![("kind", Json::str("round"))];
}
fn replay(path: &Path) {
    match kind {
        Some("init") => {}
        Some("round") => {
            if round != st.rounds.len() + 1 {
                return Err(out_of_order());
            }
        }
        _ => {}
    }
}
"""

FIXTURE_DESIGN = """\
### Requests

| Request | Response |
|---|---|
| `{"cmd":"ping"}` | `{"ok":true}` |
| `{"cmd":"status","id":7}` | `{"ok":true}` |

#### Job lifecycle transitions

| From | To | Trigger |
|---|---|---|
| `(new)` | `queued` | admission |
| `queued` | `running` | dispatch |

#### Template round-state transitions

| From | To | Line |
|---|---|---|
| `(start)` | `init` | run header |
| `init` | `round` | first round |
| `round` | `round` | each next round |

#### Conditional wire fields

| File | Field | Emitted when |
|---|---|---|
| `serve/proto.rs` | `velocity` | retained |
| `request.rs` | `dedup` | token supplied |
"""

FIXTURE_PROTO = """\
impl Request {
    fn to_json(&self) -> Json {
        match self {
            Request::Ping => Json::object([("cmd", Json::str("ping"))]),
            Request::Status(Some(id)) => {
                Json::object([("cmd", Json::str("status")), ("id", Json::num(*id as f64))])
            }
        }
    }
    pub fn to_line(&self) -> String { self.to_json().render() }
    pub fn from_json(j: &Json) -> Result<Request> {
        match cmd {
            "ping" => Ok(Request::Ping),
            "status" => match j.get("id") {
                None => Ok(Request::Status(None)),
                Some(_) => Ok(Request::Status(Some(id_of(j)?))),
            },
            other => Err(bad(format!("unknown command '{other}'"))),
        }
    }
}
fn job_to_json(v: &JobView) -> Json {
    let mut j = Json::object([("id", Json::num(v.id as f64))]);
    if let Json::Obj(m) = &mut j {
        m.insert("velocity".into(), Json::str(vel));
    }
    m.insert("ghost".into(), Json::str(g));
    j
}
fn job_from_json(j: &Json) -> Result<JobView> {
    let id = j.get("id");
    let v = j.get("velocity");
    let g = j.get("ghost");
}
fn node_stats_to_json(n: &NodeStats) -> Json {
    Json::object([("node", Json::str(&n.node))])
}
fn node_stats_from_json(j: &Json) -> Result<NodeStats> {
    let node = j.get("node");
}
fn stats_to_json(s: &ServeStats) -> Json {
    Json::object([("queued", Json::num(s.queued as f64))])
}
fn stats_from_json(j: &Json) -> Result<ServeStats> {
    let queued = g("queued");
}
impl EventMsg {
    pub fn to_line(&self) -> String {
        let mut pairs: Vec<(&str, Json)> = Vec::new();
        pairs.push(("event", Json::str("job")));
        Json::object(pairs).render()
    }
    pub fn from_json(j: &Json) -> Result<EventMsg> {
        match kind {
            "job" => Ok(EventMsg::Job {}),
            other => Err(unknown()),
        }
    }
}
"""

FIXTURE_REQUEST = """\
impl JobRequest {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("subject", Json::str(&self.subject))];
        if let Some(t) = &self.dedup {
            pairs.push(("dedup", Json::str(t)));
        }
        Json::object(pairs)
    }
    pub fn from_json(j: &Json) -> Result<JobRequest> {
        let subject = field(j, "subject", Json::as_str, "a string")?;
        let dedup = field(j, "dedup", Json::as_str, "a string")?;
    }
}
"""

FIXTURE_CORPUS = """\
{"cmd":"ping"}
{"cmd":"ping","seq":1}
{"cmd":"status","id":7}
{"cmd":"status","id":7,"seq":2}
"""


def selftest():
    global SRC, DESIGN, BUDGET, CORPUS, violations
    import tempfile
    saved = (SRC, DESIGN, BUDGET, CORPUS, violations)
    with tempfile.TemporaryDirectory() as td:
        src = os.path.join(td, "src")
        os.makedirs(os.path.join(src, "serve"))
        os.makedirs(os.path.join(src, "template"))
        fixtures = {
            os.path.join(src, "serve", "scheduler.rs"): FIXTURE_SCHED,
            os.path.join(src, "template", "journal.rs"): FIXTURE_TJ,
            os.path.join(src, "serve", "proto.rs"): FIXTURE_PROTO,
            os.path.join(src, "request.rs"): FIXTURE_REQUEST,
            os.path.join(src, "serve", "journal.rs"): "fn f() {}\n",
            os.path.join(td, "DESIGN.md"): FIXTURE_DESIGN,
            os.path.join(td, "corpus.ndjson"): FIXTURE_CORPUS,
            os.path.join(td, "panic_budget.toml"):
                '[panics]\n"over.rs" = 1\n"under.rs" = 5\n"gone.rs" = 1\n'
                "[index]\n",
            os.path.join(src, "over.rs"):
                "fn f() { a.unwrap(); b.unwrap(); }\n",
            os.path.join(src, "under.rs"):
                "fn f() { a.unwrap(); }\n",
            os.path.join(src, "unbudgeted.rs"):
                "fn f() { panic!(\"boom\"); }\n",
            os.path.join(src, "tested.rs"):
                "fn f() {}\n#[cfg(test)]\nmod tests { fn t() { x.unwrap(); } }\n",
        }
        for path, body in fixtures.items():
            with open(path, "w") as fh:
                fh.write(body)
        SRC, DESIGN = src, os.path.join(td, "DESIGN.md")
        BUDGET = os.path.join(td, "panic_budget.toml")
        CORPUS = os.path.join(td, "corpus.ndjson")

        # A1: the fixture implements `done -> running` (an injected
        # illegal transition: its guard admits any non-done state) which
        # the declared table does not list; the declared `queued ->
        # running` row is then unimplemented. Round-state tables agree.
        violations = []
        analysis_lifecycle(write_artifacts=False)
        a1 = list(violations)
        assert any("undeclared job transition `done` -> `running`" in v
                   for v in a1), a1
        assert any("declares job transition `queued` -> `running`" in v
                   for v in a1), a1
        assert not any("round-state" in v for v in a1), a1

        # A2: `ghost` is emitted guarded... no — unguarded and undeclared
        # decode-wise; `velocity` is declared AND guarded (clean); the
        # corpus and verb tables agree. The unguarded `ghost` insert is
        # fine for R5 (always-present), but job_to_json round-trips it,
        # so only the undeclared-conditional check must stay quiet.
        violations = []
        analysis_wire_schema(write_artifacts=False)
        a2 = list(violations)
        assert not a2, a2

        # A2 negative: unguard `velocity` (schema/DESIGN.md mismatch —
        # a declared conditional field emitted unconditionally) and emit
        # a new guarded `extra` field nobody declared.
        proto_path = os.path.join(src, "serve", "proto.rs")
        bad = FIXTURE_PROTO.replace(
            "    if let Json::Obj(m) = &mut j {\n"
            "        m.insert(\"velocity\".into(), Json::str(vel));\n"
            "    }\n",
            "    m.insert(\"velocity\".into(), Json::str(vel));\n"
            "    if let Some(x) = &v.extra {\n"
            "        m.insert(\"extra\".into(), Json::str(x));\n"
            "    }\n").replace(
            "    let g = j.get(\"ghost\");\n",
            "    let g = j.get(\"ghost\");\n    let x = j.get(\"extra\");\n")
        with open(proto_path, "w") as fh:
            fh.write(bad)
        violations = []
        analysis_wire_schema(write_artifacts=False)
        a2 = list(violations)
        assert any("`velocity` is emitted unconditionally" in v for v in a2), a2
        assert any("`extra` is not declared" in v for v in a2), a2

        # A2 negative: a corpus line with a field the verb cannot decode.
        with open(proto_path, "w") as fh:
            fh.write(FIXTURE_PROTO)
        with open(CORPUS, "a") as fh:
            fh.write('{"cmd":"ping","bogus":1}\n')
        violations = []
        analysis_wire_schema(write_artifacts=False)
        a2 = list(violations)
        assert any("field(s) ['bogus']" in v for v in a2), a2

        # A3: over budget, under budget (ratchet), unbudgeted, stale —
        # and test-module sites are not counted.
        violations = []
        analysis_panic_budget()
        a3 = list(violations)
        assert any("over.rs" in v and "exceed the budget" in v for v in a3), a3
        assert any("under.rs" in v and "ratchet the budget down" in v
                   for v in a3), a3
        assert any("unbudgeted.rs" in v and "no [panics] budget entry" in v
                   for v in a3), a3
        assert any("stale [panics] entry for gone.rs" in v for v in a3), a3
        assert not any("tested.rs" in v for v in a3), a3
    SRC, DESIGN, BUDGET, CORPUS, violations = saved
    print("analyze_invariants: selftest OK (lifecycle, wire-schema, "
          "panic-budget negatives)")


def main():
    if "--selftest" in sys.argv:
        selftest()
        return 0
    analysis_lifecycle()
    analysis_wire_schema()
    analysis_panic_budget()
    if violations:
        for v in violations:
            print(v)
        print(f"analyze_invariants: {len(violations)} violation(s)")
        return 1
    print("analyze_invariants: OK (lifecycle, wire-schema, panic-budget; "
          "artifacts/lifecycle.dot + artifacts/wire_schema.json written)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
