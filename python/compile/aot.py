"""AOT compile path: lower every operator to HLO text artifacts.

Python runs ONCE, at build time (``make artifacts``); the Rust coordinator
loads the HLO text with ``HloModuleProto::from_text_file``, compiles it on
the PJRT CPU client and executes it on the request path. Python is never on
the request path.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifact naming: ``<op>__<variant>__n<N>.hlo.txt`` plus a ``manifest.json``
describing inputs/outputs of every artifact (the Rust side is manifest
driven; no shapes are hard-coded over there). Mixed-precision artifacts
append ``__mixed`` to the key, carry ``"precision": "mixed"`` and declare
per-tensor ``dtype`` entries (``f16`` cache inputs) — the Rust runtime
marshals literals by these dtypes.

Batched artifacts append ``__b{B}`` (after any ``__mixed``) and carry
``"batch": B``: the solver ops are ``jax.vmap``-ed over a leading subject
dimension (``bg`` stays shared), so one warm executable evaluates
objective/newton_setup/hess_matvec/precond for B subjects per dispatch.
Unbatched entries omit the field (= batch 1, back-compat).

Usage:
    python -m compile.aot --out-dir ../artifacts --sizes 16,32,64
    python -m compile.aot --out-dir ../artifacts --precisions full,mixed
    python -m compile.aot --out-dir ../artifacts --batches 4,8
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

# Manifest dtype tags by numpy dtype name (runtime/manifest.rs mirrors).
DTYPE_TAGS = {"float32": "f32", "float16": "f16", "bfloat16": "bf16"}


def dtype_tag(dt) -> str:
    name = np.dtype(dt).name
    try:
        return DTYPE_TAGS[name]
    except KeyError:
        raise ValueError(f"no manifest tag for dtype {dt!r}") from None


def to_hlo_text(lowered) -> str:
    """Convert a jax-lowered computation to XLA HLO text.

    CRITICAL: the default printer elides constants larger than a few
    elements as ``constant({...})``; the XLA text *parser* then silently
    materializes zeros. Every spectral operator bakes wavenumber grids in
    as constants, so we must print with ``print_large_constants``.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # The consuming parser (xla_extension 0.5.1) predates newer metadata
    # attributes (source_end_line etc.); strip metadata entirely.
    opts.print_metadata = False
    text = comp.as_hlo_module().to_string(opts)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


@dataclasses.dataclass
class OpDef:
    """One artifact: a callable plus its example input specs."""

    name: str
    fn: object
    inputs: list  # [(name, ShapeDtypeStruct)]


def op_defs(p: model.Problem, kernel_level: bool) -> list:
    """Operator definitions for one (variant, n) pair."""
    n, nt = p.n, p.nt
    m = n * n * n
    v3 = spec(3, n, n, n)
    s3 = spec(n, n, n)
    q3 = spec(3, m)
    traj = spec(nt + 1, n, n, n)
    bg = spec(2)

    ops = [
        OpDef("objective", model.build_objective(p), [("v", v3), ("m0", s3), ("m1", s3), ("bg", bg)]),
        OpDef(
            "newton_setup",
            model.build_newton_setup(p),
            [("v", v3), ("m0", s3), ("m1", s3), ("bg", bg)],
        ),
        OpDef(
            "hess_matvec",
            model.build_hess_matvec(p),
            [("vt", v3), ("m_traj", traj), ("yb", q3), ("yf", q3), ("divv", s3), ("bg", bg)],
        ),
        OpDef("transport", model.build_transport(p), [("v", v3), ("f", s3)]),
    ]
    if kernel_level:
        kops = model.build_kernel_ops(p)
        sigs = {
            "grad_fft": [("f", s3)],
            "grad_fd8": [("f", s3)],
            "grad_fd8_jnp": [("f", s3)],
            "div_fft": [("w", v3)],
            "div_fd8": [("w", v3)],
            "interp_lin": [("f", s3), ("q", q3)],
            "interp_linbf16": [("f", s3), ("q", q3)],
            "interp_lin_f16": [("f", s3), ("q", q3)],
            "interp_lag": [("f", s3), ("q", q3)],
            "interp_spl": [("f", s3), ("q", q3)],
            "interp_spl_f16": [("f", s3), ("q", q3)],
            "interp_lag_jnp": [("f", s3), ("q", q3)],
            "prefilter": [("f", s3)],
            "reg_apply": [("w", v3)],
            "precond_fixed": [("w", v3)],
            "leray": [("w", v3)],
            "gauss_smooth": [("f", s3)],
            "sl_step": [("v", v3), ("m", s3)],
        }
        for name, fn in kops.items():
            ops.append(OpDef(name, fn, sigs[name]))
        # Shared (variant-independent) solver ops live with the kernel set.
        ops.append(OpDef("precond", model.build_precond(p), [("r", v3), ("bg", bg)]))
        ops.append(OpDef("defmap", model.build_defmap(p), [("v", v3)]))
        ops.append(OpDef("detf", model.build_detf(p), [("v", v3)]))
        # Grid-continuation transfer operators (CLAIRE multi-resolution):
        # upsample from this level (emitted below the top size), restrict
        # to the previous level (emitted above the bottom size).
        if n <= 32:
            ops.append(OpDef("upsample2x", model.build_upsample2x(p), [("v", v3)]))
        if n >= 32:
            ops.append(OpDef("restrict2x", model.build_restrict2x(p), [("f", s3)]))
    return ops


def mixed_op_defs(p: model.Problem) -> list:
    """Reduced-precision artifacts for one (variant, n) pair.

    The solver's precision split (paper §3) runs only the Hessian matvec
    inner loop reduced, so ``mixed`` lowers exactly that operator: the
    *field-valued* caches (``m_traj``, ``divv``) marshal as fp16 (halved
    boundary bytes), fp16-storage interpolation/stencil kernels run inside,
    and ``vt`` in / ``H vt`` out stay f32. The characteristic coordinates
    ``yb``/``yf`` also stay f32 — they carry absolute positions whose f16
    ulp grows with n (a quarter voxel at 256^3); the paper's texture unit
    reduces interpolation *data*, never query coordinates. Gradient/
    objective/line-search artifacts stay full precision.
    """
    assert p.precision == "mixed"
    n, nt = p.n, p.nt
    m = n * n * n
    v3 = spec(3, n, n, n)
    q3 = spec(3, m)
    bg = spec(2)
    traj16 = spec(nt + 1, n, n, n, dtype=jnp.float16)
    s16 = spec(n, n, n, dtype=jnp.float16)
    return [
        OpDef(
            "hess_matvec",
            model.build_hess_matvec(p),
            [("vt", v3), ("m_traj", traj16), ("yb", q3), ("yf", q3), ("divv", s16), ("bg", bg)],
        ),
    ]


def batched_op_defs(p: model.Problem, B: int, shared: bool) -> list:
    """Batched solver artifacts for one (variant, n, precision) triple.

    The per-iteration solver ops are ``jax.vmap``-ed over a leading subject
    axis: every subject tensor gains a ``(B, ...)`` dim while ``bg`` (the
    beta/gamma scalars) stays shared — the scheduler only coalesces jobs
    whose regularization parameters agree, so one broadcast pair serves the
    whole batch. ``transport``/``defmap``/``detf`` stay unbatched (they run
    on the report path, not the hot loop). With ``precision == "mixed"``
    only the reduced hess_matvec is lowered, mirroring ``mixed_op_defs``.
    ``shared`` gates the variant-independent ``precond`` (emitted once per
    size, attached to the default variant, like the kernel-level set).
    """
    n, nt = p.n, p.nt
    m = n * n * n
    bv3 = spec(B, 3, n, n, n)
    bs3 = spec(B, n, n, n)
    bq3 = spec(B, 3, m)
    bg = spec(2)
    if p.precision == "mixed":
        btraj16 = spec(B, nt + 1, n, n, n, dtype=jnp.float16)
        bs16 = spec(B, n, n, n, dtype=jnp.float16)
        return [
            OpDef(
                "hess_matvec",
                jax.vmap(model.build_hess_matvec(p), in_axes=(0, 0, 0, 0, 0, None)),
                [
                    ("vt", bv3),
                    ("m_traj", btraj16),
                    ("yb", bq3),
                    ("yf", bq3),
                    ("divv", bs16),
                    ("bg", bg),
                ],
            ),
        ]
    btraj = spec(B, nt + 1, n, n, n)
    ops = [
        OpDef(
            "objective",
            jax.vmap(model.build_objective(p), in_axes=(0, 0, 0, None)),
            [("v", bv3), ("m0", bs3), ("m1", bs3), ("bg", bg)],
        ),
        OpDef(
            "newton_setup",
            jax.vmap(model.build_newton_setup(p), in_axes=(0, 0, 0, None)),
            [("v", bv3), ("m0", bs3), ("m1", bs3), ("bg", bg)],
        ),
        OpDef(
            "hess_matvec",
            jax.vmap(model.build_hess_matvec(p), in_axes=(0, 0, 0, 0, 0, None)),
            [
                ("vt", bv3),
                ("m_traj", btraj),
                ("yb", bq3),
                ("yf", bq3),
                ("divv", bs3),
                ("bg", bg),
            ],
        ),
    ]
    if shared:
        ops.append(
            OpDef(
                "precond",
                jax.vmap(model.build_precond(p), in_axes=(0, None)),
                [("r", bv3), ("bg", bg)],
            )
        )
    return ops


def lower_one(opdef: OpDef, out_path: pathlib.Path) -> dict:
    """Lower one op, write HLO text, return its manifest entry."""
    t0 = time.time()
    specs = [s for _, s in opdef.inputs]
    lowered = jax.jit(opdef.fn).lower(*specs)
    text = to_hlo_text(lowered)
    out_path.write_text(text)
    outs = [
        {
            "shape": list(map(int, getattr(s, "shape", ()))),
            "dtype": dtype_tag(getattr(s, "dtype", np.float32)),
        }
        for s in jax.tree.leaves(lowered.out_info)
    ]
    dt = time.time() - t0
    print(f"  {out_path.name}: {len(text) / 1e6:.2f} MB in {dt:.1f}s")
    return {
        "file": out_path.name,
        "inputs": [
            {"name": nm, "shape": list(map(int, s.shape)), "dtype": dtype_tag(s.dtype)}
            for nm, s in opdef.inputs
        ],
        "outputs": outs,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default="16,32,64")
    ap.add_argument("--variants", default=",".join(model.VARIANTS))
    ap.add_argument(
        "--precisions",
        default=",".join(model.PRECISIONS),
        help="comma list of full,mixed; mixed lowers the reduced hess_matvec",
    )
    ap.add_argument(
        "--batches",
        default="4,8",
        help="comma list of batch sizes B to lower the solver ops at "
        "(__b{B} keys; empty disables batched artifacts)",
    )
    ap.add_argument("--nt", type=int, default=model.DEFAULT_NT)
    ap.add_argument("--ops", default="", help="only lower ops whose name is listed")
    ap.add_argument("--force", action="store_true", help="re-lower even if file exists")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    sizes = [int(s) for s in args.sizes.split(",") if s]
    variants = [v for v in args.variants.split(",") if v]
    precisions = [p for p in args.precisions.split(",") if p]
    for prec in precisions:
        assert prec in model.PRECISIONS, f"unknown precision {prec!r}"
    batches = [int(b) for b in args.batches.split(",") if b]
    for b in batches:
        assert b >= 2, f"batch size {b} makes no sense (unbatched entries are batch 1)"
    only = set(args.ops.split(",")) if args.ops else None

    manifest_path = out_dir / "manifest.json"
    manifest = {"nt": args.nt, "artifacts": {}}
    if manifest_path.exists():
        try:
            manifest = json.loads(manifest_path.read_text())
            manifest.setdefault("artifacts", {})
        except json.JSONDecodeError:
            pass
    manifest["nt"] = args.nt

    for n in sizes:
        for variant in variants:
            for prec in precisions:
                if prec == "full":
                    p = model.Problem(n=n, nt=args.nt, variant=variant)
                    # Kernel-level + shared ops are variant-independent;
                    # emit them once per size, attached to the default
                    # optimized variant.
                    defs = op_defs(p, kernel_level=variant == "opt-fd8-cubic")
                    suffix = ""
                else:
                    p = model.Problem(n=n, nt=args.nt, variant=variant, precision="mixed")
                    defs = mixed_op_defs(p)
                    suffix = "__mixed"
                print(f"[aot] n={n} variant={variant} precision={prec}")
                # Batch 1 = the historical unbatched set; B >= 2 lowers the
                # vmap-ed solver ops under __b{B} keys.
                for B in [1] + batches:
                    if B == 1:
                        bdefs, bsuffix = defs, ""
                    else:
                        bdefs = batched_op_defs(p, B, shared=variant == "opt-fd8-cubic")
                        bsuffix = f"__b{B}"
                    for opdef in bdefs:
                        if only and opdef.name not in only:
                            continue
                        key = f"{opdef.name}__{variant}__n{n}{suffix}{bsuffix}"
                        fname = out_dir / f"{key}.hlo.txt"
                        if fname.exists() and not args.force and key in manifest["artifacts"]:
                            continue
                        entry = lower_one(opdef, fname)
                        entry.update(
                            {"op": opdef.name, "variant": variant, "n": n, "nt": args.nt}
                        )
                        if prec != "full":
                            entry["precision"] = prec
                        if B > 1:
                            entry["batch"] = B
                        manifest["artifacts"][key] = entry
                        manifest_path.write_text(
                            json.dumps(manifest, indent=1, sort_keys=True)
                        )

    print(f"[aot] manifest: {manifest_path} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
