"""L2: CLAIRE's PDE operators as JAX compute graphs.

This module builds every operator the Rust Gauss-Newton-Krylov coordinator
executes at runtime (paper Algorithm 2.1). Each builder returns a pure
function of arrays that ``aot.py`` lowers to a shape-specialized HLO artifact.

Operator inventory (see DESIGN.md section 2):

* ``objective(v, m0, m1)``       -> scalars [J, msumsq, reg]
* ``newton_setup(v, m0, m1)``    -> g, m_traj, yb, yf, divv, scalars
* ``hess_matvec(vt, m_traj, yb, yf, divv)`` -> H vt  (Gauss-Newton)
* ``precond(r)``                 -> (beta A + gamma grad div)^{-1} r
* ``transport(v, f)``            -> f advected over [0, 1]
* ``defmap(v)``                  -> full characteristic map y (grid units)
* ``detf(v)``                    -> det of deformation gradient
* kernel-level ops (grad/div/interp/prefilter/sl_step/...) for benches

The discretization follows CLAIRE (Mang & Biros, SISC 2017): semi-Lagrangian
transport with an RK2 (explicit midpoint) characteristic trace and
trapezoidal handling of source terms; Nt = 4 time steps; spectral
regularization operators (see ``kernels/spectral.py``); FD8 or FFT first
derivatives and one of four interpolation kernels selected per variant
(paper Table 6).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import fd8, interp, ref, spectral

# ---------------------------------------------------------------------------
# Variants (paper Table 6)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Variant:
    """A combination of computational kernels (paper Table 6)."""

    tag: str
    deriv: str  # "fft" | "fd8"  (first-order derivatives)
    interp: str  # "lin" | "linbf16" | "lag" | "spl"
    impl: str  # "pallas" | "jnp"


VARIANTS = {
    # Baseline: direct translation of CPU CLAIRE (FFT derivatives, cubic
    # Lagrange interpolation, plain-XLA kernels). Analog of cpu-fft-cubic.
    "ref-fft-cubic": Variant("ref-fft-cubic", "fft", "lag", "jnp"),
    # Optimized kernels, FFT derivatives retained. Analog of gpu-fft-cubic
    # (which pairs FFT derivatives with the GPU-TXTSPL B-spline kernel).
    "opt-fft-cubic": Variant("opt-fft-cubic", "fft", "spl", "pallas"),
    # FD8 derivatives + prefiltered B-spline. Analog of gpu-fd8-cubic.
    "opt-fd8-cubic": Variant("opt-fd8-cubic", "fd8", "spl", "pallas"),
    # FD8 + reduced-precision trilinear. Analog of gpu-fd8-linear
    # (GPU-TXTLIN's 9-bit texture weights -> bf16 weights here).
    "opt-fd8-linear": Variant("opt-fd8-linear", "fd8", "linbf16", "pallas"),
}

DEFAULT_NT = 4  # paper: Nt = 4

# Precision policies (paper section 3). "full" is f32 everywhere; "mixed"
# holds interpolation/stencil storage at fp16 with f32 accumulators —
# applied to the operators the solver runs at reduced precision (the
# Hessian matvec inner loop). Spectral operators stay f32 under both
# policies (they are inverted; see kernels/spectral.py).
PRECISIONS = ("full", "mixed")


@dataclasses.dataclass(frozen=True)
class Problem:
    """Static description of one registration problem instance."""

    n: int
    nt: int = DEFAULT_NT
    beta: float = 5e-4  # target regularization weight (paper section 4.1.2)
    gamma: float = 1e-4  # divergence penalty (paper section 4.1.2)
    variant: str = "opt-fd8-cubic"
    precision: str = "full"

    def __post_init__(self):
        assert self.precision in PRECISIONS, f"unknown precision {self.precision!r}"

    @property
    def h(self) -> float:
        return 2.0 * np.pi / self.n

    @property
    def dt(self) -> float:
        return 1.0 / self.nt

    @property
    def var(self) -> Variant:
        return VARIANTS[self.variant]

    @property
    def storage(self):
        """Reduced storage dtype for this policy (None = keep f32)."""
        return jnp.float16 if self.precision == "mixed" else None


# ---------------------------------------------------------------------------
# Kernel dispatch
# ---------------------------------------------------------------------------


def grad_op(p: Problem) -> Callable:
    v = p.var
    st = p.storage
    if v.deriv == "fft":
        # Spectral first derivatives stay f32 under both policies.
        return lambda f: ref.fft_grad(f, p.h)
    if v.impl == "pallas":
        return lambda f: fd8.grad(f, p.h, storage=st)
    return lambda f: ref.fd8_grad(f, p.h, storage=st)


def div_op(p: Problem) -> Callable:
    v = p.var
    st = p.storage
    if v.deriv == "fft":
        return lambda w: ref.fft_div(w, p.h)
    if v.impl == "pallas":
        return lambda w: fd8.div(w, p.h, storage=st)
    return lambda w: ref.fd8_div(w, p.h, storage=st)


def interp_op(p: Problem) -> Callable:
    """Scalar interpolation ``(f[N,N,N], q[3,M]) -> [M]`` for the variant.

    For the B-spline kernel the prefilter is applied per call (its cost is
    part of the kernel, as in the paper's GPU-TXTSPL timings; the prefilter
    itself is f32 under every policy — it inverts a stencil). Under the
    mixed policy the variant's kernel runs with fp16 storage / f32
    accumulation; the bf16 "linbf16" variant keeps its own reduction.
    """
    v = p.var
    st = p.storage
    if v.impl == "pallas":
        table = {
            "lin": lambda f, q: interp.linear(f, q, storage=st),
            "linbf16": interp.linear_bf16,
            "lag": lambda f, q: interp.cubic_lagrange(f, q, storage=st),
            "spl": lambda f, q: interp.cubic_bspline(interp.prefilter(f), q, storage=st),
        }
    else:
        table = {
            "lin": (
                ref.interp_linear
                if st is None
                else lambda f, q: ref.interp_linear_rp(f, q, st)
            ),
            "linbf16": ref.interp_linear_bf16,
            "lag": lambda f, q: ref.interp_cubic_lagrange(f, q, storage=st),
            "spl": lambda f, q: ref.interp_cubic_bspline(ref.prefilter(f), q, storage=st),
        }
    return table[v.interp]


# ---------------------------------------------------------------------------
# Semi-Lagrangian machinery
# ---------------------------------------------------------------------------


def grid_coords(n: int) -> jnp.ndarray:
    """Regular grid coordinates in grid units, ``[3, N^3]``."""
    r = jnp.arange(n, dtype=jnp.float32)
    g = jnp.meshgrid(r, r, r, indexing="ij")
    return jnp.stack([c.reshape(-1) for c in g])


def interp_vec(p: Problem, w: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Interpolate a vector field component-wise at query points."""
    ip = interp_op(p)
    return jnp.stack([ip(w[a], q) for a in range(3)])


def characteristics(p: Problem, v: jnp.ndarray):
    """RK2 characteristic end points for one time step, both directions.

    Because v is *stationary* the characteristics are identical for every
    step of every transport solve; CLAIRE computes them once per velocity
    iterate and so do we (they are part of the ``newton_setup`` cache).

    Returns ``(yb, yf)`` as ``[3, N^3]`` grid-unit coordinates:
    ``yb = x - dt*v(x - dt/2 v(x))`` (backward trace; state equation) and
    ``yf = x + dt*v(x + dt/2 v(x))`` (forward trace; adjoint equation).
    """
    n = p.n
    x = grid_coords(n)
    vg = v.reshape(3, -1) / np.float32(p.h)  # displacement field, grid units
    half = np.float32(0.5 * p.dt)
    full = np.float32(p.dt)
    vb = interp_vec(p, v, x - half * vg) / np.float32(p.h)
    yb = x - full * vb
    vf = interp_vec(p, v, x + half * vg) / np.float32(p.h)
    yf = x + full * vf
    return yb, yf


def state_step(p: Problem, m: jnp.ndarray, yb: jnp.ndarray) -> jnp.ndarray:
    """One semi-Lagrangian step of the state equation: m <- m o yb."""
    ip = interp_op(p)
    return ip(m, yb).reshape(m.shape)


def state_solve(p: Problem, v_unused, m0: jnp.ndarray, yb: jnp.ndarray):
    """Forward transport; returns the trajectory ``[Nt+1, N, N, N]``."""
    ms = [m0]
    for _ in range(p.nt):
        ms.append(state_step(p, ms[-1], yb))
    return jnp.stack(ms)


def adjoint_step(p: Problem, lam, yf, divv, divv_flat):
    """One semi-Lagrangian step of the adjoint equation in tau = 1 - t.

    The adjoint transport ``lam_tau = v . grad(lam) + lam div v`` is solved
    along forward characteristics with an explicit Heun (trapezoidal
    predictor-corrector) source term:

        a    = lam(yf),  b = (lam divv)(yf)
        pred = a + dt b                       (Euler predictor)
        lam' = a + dt/2 (b + pred divv(x))    (trapezoid corrector)

    A semi-implicit variant (dividing by ``1 - dt/2 divv``) is second-order
    too but has a pole at ``divv = 2/dt`` that destabilizes strongly
    compressive iterates at high resolution; Heun has no pole.
    """
    ip = interp_op(p)
    a = ip(lam, yf)
    b = ip(lam * divv, yf)
    dt = np.float32(p.dt)
    half = np.float32(0.5 * p.dt)
    pred = a + dt * b
    out = a + half * (b + pred * divv_flat)
    return out.reshape(lam.shape)


def adjoint_solve(p: Problem, lam1: jnp.ndarray, yf, divv):
    """Backward (adjoint) transport; trajectory indexed by tau = 1 - t."""
    divv_flat = divv.reshape(-1)
    ls = [lam1]
    for _ in range(p.nt):
        ls.append(adjoint_step(p, ls[-1], yf, divv, divv_flat))
    return jnp.stack(ls)


def time_quadrature(p: Problem) -> np.ndarray:
    """Trapezoidal weights over the Nt+1 time nodes."""
    w = np.full(p.nt + 1, p.dt, dtype=np.float32)
    w[0] *= 0.5
    w[-1] *= 0.5
    return w


# ---------------------------------------------------------------------------
# Reduced-space operators (the AOT artifacts)
# ---------------------------------------------------------------------------


def build_objective(p: Problem) -> Callable:
    """J(v) evaluation for the line search. Returns [J, msumsq, reg].

    ``bg`` is the runtime ``[beta, gamma]`` pair: the regularization weights
    are *inputs*, not compile-time constants, so the coordinator can run the
    paper's beta-continuation scheme against a single compiled artifact.
    """

    def objective(v, m0, m1, bg):
        yb, _ = characteristics(p, v)
        m = m0
        for _ in range(p.nt):
            m = state_step(p, m, yb)
        h3 = np.float32(p.h**3)
        msumsq = jnp.sum((m - m1) ** 2) * h3
        reg = spectral.reg_energy(v, bg[0], bg[1], p.h)
        return (jnp.stack([0.5 * msumsq + reg, msumsq, reg]),)

    return objective


def build_newton_setup(p: Problem) -> Callable:
    """State + adjoint solve and reduced gradient; emits the per-Newton-
    iteration caches reused by every Hessian matvec of the PCG solve."""

    def newton_setup(v, m0, m1, bg):
        yb, yf = characteristics(p, v)
        divv = div_op(p)(v)
        m_traj = state_solve(p, v, m0, yb)
        lam1 = m1 - m_traj[-1]
        l_traj = adjoint_solve(p, lam1, yf, divv)
        g_op = grad_op(p)
        w = time_quadrature(p)
        body = None
        for nidx in range(p.nt + 1):
            gm = g_op(m_traj[nidx])
            lam = l_traj[p.nt - nidx]  # lam at t_n is tau index Nt - n
            term = np.float32(w[nidx]) * lam[None, ...] * gm
            body = term if body is None else body + term
        av = spectral.reg_apply(v, bg[0], bg[1])
        g = av + body
        h3 = np.float32(p.h**3)
        msumsq = jnp.sum((m_traj[-1] - m1) ** 2) * h3
        # <reg_apply(v), v>/2 equals the regularization energy (both the
        # Laplacian and the div-penalty terms are quadratic forms of A).
        reg = 0.5 * jnp.sum(av * v) * h3
        scalars = jnp.stack([0.5 * msumsq + reg, msumsq, reg])
        return g, m_traj, yb, yf, divv, scalars

    return newton_setup


def build_hess_matvec(p: Problem) -> Callable:
    """Gauss-Newton Hessian matvec using the newton_setup caches.

    H vt = beta A vt + gamma ... + int lamt grad(m) dt, with the incremental
    state (forced transport) and incremental adjoint solves of Algorithm 2.1.

    Under ``p.precision == "mixed"`` the cached tensors arrive as fp16
    artifact inputs (halved marshalling; see aot.py) and are widened here —
    reduced precision then re-enters *inside* the interpolation/stencil
    kernels via the storage dispatch, keeping all transport algebra and the
    regularization term at f32 (paper §3: matvec inner loop reduced, outer
    quantities full).
    """

    def hess_matvec(vt, m_traj, yb, yf, divv, bg):
        if p.precision == "mixed":
            m_traj = m_traj.astype(jnp.float32)
            yb = yb.astype(jnp.float32)
            yf = yf.astype(jnp.float32)
            divv = divv.astype(jnp.float32)
        ip = interp_op(p)
        g_op = grad_op(p)
        half = np.float32(0.5 * p.dt)
        grads_m = [g_op(m_traj[nidx]) for nidx in range(p.nt + 1)]

        # Incremental state: mt_t + v.grad(mt) = -vt.grad(m), mt(0) = 0,
        # i.e. d(mt)/dt = -s along the backward characteristic with
        # s = vt.grad(m); trapezoid:
        #   mt'(x) = mt(yb) - dt/2 [ s^n(yb) + s^{n+1}(x) ].
        def source(nidx):
            return jnp.sum(vt * grads_m[nidx], axis=0)

        mt = jnp.zeros_like(m_traj[0])
        s_prev = source(0)
        for nidx in range(p.nt):
            s_next = source(nidx + 1)
            adv = ip(mt, yb) - half * ip(s_prev, yb)
            mt = adv.reshape(mt.shape) - half * s_next
            s_prev = s_next

        # Incremental adjoint: terminal condition -mt(1) (Gauss-Newton).
        lt_traj = adjoint_solve(p, -mt, yf, divv)

        # H vt = beta A vt + gamma ... + int lt grad(m) dt. With the
        # terminal condition above the data term is J'J (positive
        # semi-definite), mirroring how the gradient's data term pairs
        # lambda(1) = -(m(1) - m1) with +int lambda grad(m).
        w = time_quadrature(p)
        body = None
        for nidx in range(p.nt + 1):
            lt = lt_traj[p.nt - nidx]
            term = np.float32(w[nidx]) * lt[None, ...] * grads_m[nidx]
            body = term if body is None else body + term
        hv = spectral.reg_apply(vt, bg[0], bg[1]) + body
        return (hv,)

    return hess_matvec


def build_precond(p: Problem) -> Callable:
    """Spectral preconditioner ``(beta A + gamma grad div)^{-1}``."""

    def precond(r, bg):
        return (spectral.precond_apply(r, bg[0], bg[1]),)

    return precond


def build_transport(p: Problem) -> Callable:
    """Advect an arbitrary scalar field over [0, 1] with velocity v."""

    def transport(v, f):
        yb, _ = characteristics(p, v)
        m = f
        for _ in range(p.nt):
            m = state_step(p, m, yb)
        return (m,)

    return transport


def build_defmap(p: Problem) -> Callable:
    """Full backward characteristic map y with m(1) = m0(y(x)).

    Composes the per-step map Nt times: y = Y o Y o ... o Y where
    Y(x) = x + D(x) and D is the (periodic) one-step displacement.
    Interpolation of D uses cubic Lagrange regardless of variant so that the
    deformation-quality metrics (det F, DICE) are measured consistently
    across variants.
    """

    def defmap(v):
        n = p.n
        x = grid_coords(n)
        pq = dataclasses.replace(p, variant="ref-fft-cubic")  # lag/jnp interp
        yb, _ = characteristics(p, v)
        d = yb - x  # one-step displacement, grid units (periodic field)
        dg = d.reshape(3, n, n, n)
        y = yb
        for _ in range(p.nt - 1):
            y = y + interp_vec(pq, dg, y)
        return (y.reshape(3, n, n, n),)

    return defmap


def build_detf(p: Problem) -> Callable:
    """Determinant of the deformation gradient F = grad(y) per voxel."""

    defmap = build_defmap(p)

    def detf(v):
        n = p.n
        (y,) = defmap(v)
        x = grid_coords(n).reshape(3, n, n, n)
        d = (y - x) * np.float32(p.h)  # displacement in physical units
        # J[a][b] = d(d_a)/d(x_b), FD8 (consistent metric across variants)
        jac = [[ref.fd8_partial(d[a], b, p.h) for b in range(3)] for a in range(3)]
        f00 = 1.0 + jac[0][0]
        f11 = 1.0 + jac[1][1]
        f22 = 1.0 + jac[2][2]
        det = (
            f00 * (f11 * f22 - jac[1][2] * jac[2][1])
            - jac[0][1] * (jac[1][0] * f22 - jac[1][2] * jac[2][0])
            + jac[0][2] * (jac[1][0] * jac[2][1] - f11 * jac[2][0])
        )
        return (det,)

    return detf


# ---------------------------------------------------------------------------
# Kernel-level ops (benches, instrumented breakdown solver, data generation)
# ---------------------------------------------------------------------------


def build_kernel_ops(p: Problem) -> dict:
    """Standalone kernel executables for the paper's kernel tables."""
    h = p.h

    def sl_step(v, m):
        yb, _ = characteristics(p, v)
        return (state_step(p, m, yb),)

    ops = {
        "grad_fft": lambda f: (ref.fft_grad(f, h),),
        "grad_fd8": lambda f: (fd8.grad(f, h),),
        "grad_fd8_jnp": lambda f: (ref.fd8_grad(f, h),),
        "div_fft": lambda w: (ref.fft_div(w, h),),
        "div_fd8": lambda w: (fd8.div(w, h),),
        "interp_lin": lambda f, q: (interp.linear(f, q),),
        "interp_linbf16": lambda f, q: (interp.linear_bf16(f, q),),
        "interp_lin_f16": lambda f, q: (interp.linear_f16(f, q),),
        "interp_lag": lambda f, q: (interp.cubic_lagrange(f, q),),
        "interp_spl": lambda f, q: (interp.cubic_bspline(interp.prefilter(f), q),),
        "interp_spl_f16": lambda f, q: (interp.cubic_bspline_f16(interp.prefilter(f), q),),
        "interp_lag_jnp": lambda f, q: (ref.interp_cubic_lagrange(f, q),),
        "prefilter": lambda f: (interp.prefilter(f),),
        "reg_apply": lambda w: (spectral.reg_apply(w, p.beta, p.gamma),),
        "precond_fixed": lambda w: (spectral.precond_apply(w, p.beta, p.gamma),),
        "leray": lambda w: (spectral.leray(w),),
        "gauss_smooth": lambda f: (spectral.gauss_smooth(f, 1.0),),
        "sl_step": sl_step,
    }
    return ops


# ---------------------------------------------------------------------------
# Grid continuation (CLAIRE's multi-resolution scheme): spectral transfer
# operators between levels. Upsampling zero-pads the spectrum; restriction
# truncates it. Both are exact on band-limited fields.
# ---------------------------------------------------------------------------


def _spectral_pad(fh: jnp.ndarray, n: int, n2: int) -> jnp.ndarray:
    """Zero-pad an n^3 complex spectrum into an n2^3 spectrum (n2 = 2n)."""
    h = n // 2
    out = jnp.zeros((n2, n2, n2), fh.dtype)
    # Scatter the 8 corner blocks (positive/negative frequency octants).
    for sx in (0, 1):
        for sy in (0, 1):
            for sz in (0, 1):
                src_ix = slice(0, h) if sx == 0 else slice(n - h, n)
                dst_ix = slice(0, h) if sx == 0 else slice(n2 - h, n2)
                src_iy = slice(0, h) if sy == 0 else slice(n - h, n)
                dst_iy = slice(0, h) if sy == 0 else slice(n2 - h, n2)
                src_iz = slice(0, h) if sz == 0 else slice(n - h, n)
                dst_iz = slice(0, h) if sz == 0 else slice(n2 - h, n2)
                out = out.at[dst_ix, dst_iy, dst_iz].set(fh[src_ix, src_iy, src_iz])
    return out


def upsample2x_scalar(f: jnp.ndarray) -> jnp.ndarray:
    n = f.shape[0]
    n2 = 2 * n
    fh = jnp.fft.fftn(f)
    out = jnp.fft.ifftn(_spectral_pad(fh, n, n2)) * np.float32(8.0)
    return jnp.real(out).astype(f.dtype)


def build_upsample2x(p: Problem) -> Callable:
    """Prolong a velocity field to the next grid level (spectral)."""

    def upsample2x(v):
        return (jnp.stack([upsample2x_scalar(v[a]) for a in range(3)]),)

    return upsample2x


def restrict2x_scalar(f: jnp.ndarray) -> jnp.ndarray:
    n = f.shape[0]
    h = n // 4
    fh = jnp.fft.fftn(f)
    n2 = n // 2
    out = jnp.zeros((n2, n2, n2), fh.dtype)
    for sx in (0, 1):
        for sy in (0, 1):
            for sz in (0, 1):
                src_ix = slice(0, h) if sx == 0 else slice(n - h, n)
                dst_ix = slice(0, h) if sx == 0 else slice(n2 - h, n2)
                src_iy = slice(0, h) if sy == 0 else slice(n - h, n)
                dst_iy = slice(0, h) if sy == 0 else slice(n2 - h, n2)
                src_iz = slice(0, h) if sz == 0 else slice(n - h, n)
                dst_iz = slice(0, h) if sz == 0 else slice(n2 - h, n2)
                out = out.at[dst_ix, dst_iy, dst_iz].set(fh[src_ix, src_iy, src_iz])
    return jnp.real(jnp.fft.ifftn(out) / np.float32(8.0)).astype(f.dtype)


def build_restrict2x(p: Problem) -> Callable:
    """Restrict a scalar image to the previous grid level (spectral)."""

    def restrict2x(f):
        return (restrict2x_scalar(f),)

    return restrict2x


# ---------------------------------------------------------------------------
# Complexity accounting (paper Table 1)
# ---------------------------------------------------------------------------


def complexity(p: Problem) -> dict:
    """Analytic kernel counts per operator evaluation (paper Table 1).

    Counts are per call, d = 3 ambient dimensions. "first" are first-order
    derivative applications (FFT or FD8 by variant), "fft_other" are
    high-order/inverse spectral operators (always FFT), "ips" are scalar
    interpolation kernel calls.
    """
    d, nt = 3, p.nt
    char = 2 * d  # two RK2 stages x d components per characteristic trace
    return {
        "objective": {"first": 0, "fft_other": 2 * d, "ips": char + nt},
        "newton_setup": {
            # div v + (Nt+1) gradients of m for the reduced gradient
            "first": 1 + d * (nt + 1),
            # reg_apply in g + reg_energy (objective part)
            "fft_other": 4 * d,
            # both characteristic traces + Nt state + 2*Nt adjoint interps
            "ips": 2 * char + nt + 2 * nt,
        },
        "hess_matvec": {
            "first": d * (nt + 1),  # gradients of cached m trajectory
            "fft_other": 2 * d,  # reg_apply(vt)
            # inc. state: 2 interps per step; inc. adjoint: 2 per step
            "ips": 2 * nt + 2 * nt,
        },
    }
