"""Pallas FD8 kernels: 8th-order first derivatives (gradient / divergence).

Paper section 2.3.2: the V100 kernel stages a 2-D tile plus halo points in
shared memory, evaluates the 9-point axis-aligned stencil, and writes the
tile back. The TPU-style restatement here: the grid iterates over slabs of
the (periodically pre-padded) volume; each grid step loads ``slab + halo``
into the kernel's fast-memory window (VMEM analog), evaluates all partials as
vectorized shifted-slice FMAs, and writes the interior slab.

Periodic boundaries are handled by wrap-padding with ``HALO = 4`` cells
outside the kernel (the analog of the CUDA kernel's out-of-bound halo loads
from global memory, which the paper measures at ~2% bandwidth overhead).

All kernels run with ``interpret=True``: on this image's CPU-only PJRT stack
a real TPU lowering would emit Mosaic custom-calls that cannot execute; the
interpret lowering emits plain HLO with identical arithmetic.

Mixed precision: every kernel takes a static ``storage`` dtype. With
``storage=jnp.float16`` the padded field is held (and the stencil taps are
read) at fp16 while each tap *difference* is widened to f32 before the
coefficient FMA — fp16 storage under f32 accumulators, the paper's §3
scheme. ``storage=None`` is the full-precision f32 path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref

HALO = 4  # FD8 stencil half-width


# Fast-memory budget for one kernel block (bytes). Real TPU VMEM is ~16 MiB;
# we keep the same discipline on the CPU-interpret path so the BlockSpec
# schedule documented in DESIGN.md is the one we actually measure.
VMEM_BUDGET = 8 * 1024 * 1024


def _slab_size(n: int) -> int:
    """Slab height per grid step.

    Perf pass (EXPERIMENTS.md section Perf, L1): a single whole-volume block
    is fastest whenever block + halo fits the fast-memory budget — the grid
    loop's per-step window loads dominate otherwise (measured 14.5 ms ->
    8.3 ms for grad_fd8 at 64^3). Fall back to 8-slab tiling beyond the
    budget (the 256^3-class sizes the paper runs on the V100).
    """
    full_bytes = (n + 2 * HALO) ** 3 * 4
    if full_bytes <= VMEM_BUDGET:
        return n
    return min(8, n)


def pad_periodic(f: jnp.ndarray, w: int = HALO) -> jnp.ndarray:
    """Wrap-pad all three axes by ``w`` cells."""
    return jnp.pad(f, ((w, w), (w, w), (w, w)), mode="wrap")


def _fd8_axis(win: jnp.ndarray, axis: int, lo: tuple, hi: tuple, h: float) -> jnp.ndarray:
    """Apply the FD8 stencil along ``axis`` of a padded window.

    ``lo``/``hi`` give the interior slice bounds per axis (halo trimmed on
    the non-derivative axes). The window may be stored at reduced precision;
    tap pairs subtract at storage precision, then every product and the
    running sum are f32 (explicit widening — the f32-accumulator rule).
    """
    acc = None
    for k, c in enumerate(ref.FD8_COEFFS, start=1):

        def cut(off: int):
            idx = []
            for a in range(3):
                start = lo[a] + (off if a == axis else 0)
                stop = hi[a] + (off if a == axis else 0)
                idx.append(slice(start, stop))
            return win[tuple(idx)]

        term = np.float32(c) * (cut(+k) - cut(-k)).astype(jnp.float32)
        acc = term if acc is None else acc + term
    return acc / np.float32(h)


def _grad_kernel(slab: int, n: int, h: float, fp_ref, o1_ref, o2_ref, o3_ref):
    i = pl.program_id(0)
    win = pl.load(
        fp_ref,
        (pl.dslice(i * slab, slab + 2 * HALO), slice(None), slice(None)),
    )
    lo = (HALO, HALO, HALO)
    hi = (HALO + slab, HALO + n, HALO + n)
    o1_ref[...] = _fd8_axis(win, 0, lo, hi, h)
    o2_ref[...] = _fd8_axis(win, 1, lo, hi, h)
    o3_ref[...] = _fd8_axis(win, 2, lo, hi, h)


@functools.partial(jax.jit, static_argnames=("h", "storage"))
def grad(f: jnp.ndarray, h: float, storage=None) -> jnp.ndarray:
    """FD8 gradient of a scalar field -> ``[3, N, N, N]`` (Pallas).

    ``storage`` (e.g. ``jnp.float16``) holds the padded field at reduced
    precision inside the kernel window; output stays f32.
    """
    n = f.shape[0]
    slab = _slab_size(n)
    fp = pad_periodic(f if storage is None else f.astype(storage))
    out_shape = jax.ShapeDtypeStruct((n, n, n), jnp.float32)
    o1, o2, o3 = pl.pallas_call(
        functools.partial(_grad_kernel, slab, n, h),
        grid=(n // slab,),
        in_specs=[pl.BlockSpec(fp.shape, lambda i: (0, 0, 0))],
        out_specs=[
            pl.BlockSpec((slab, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((slab, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((slab, n, n), lambda i: (i, 0, 0)),
        ],
        out_shape=[out_shape, out_shape, out_shape],
        interpret=True,
    )(fp)
    return jnp.stack([o1, o2, o3])


def _div_kernel(slab: int, n: int, h: float, v1_ref, v2_ref, v3_ref, o_ref):
    i = pl.program_id(0)
    idx = (pl.dslice(i * slab, slab + 2 * HALO), slice(None), slice(None))
    lo = (HALO, HALO, HALO)
    hi = (HALO + slab, HALO + n, HALO + n)
    w1 = pl.load(v1_ref, idx)
    w2 = pl.load(v2_ref, idx)
    w3 = pl.load(v3_ref, idx)
    o_ref[...] = (
        _fd8_axis(w1, 0, lo, hi, h)
        + _fd8_axis(w2, 1, lo, hi, h)
        + _fd8_axis(w3, 2, lo, hi, h)
    )


@functools.partial(jax.jit, static_argnames=("h", "storage"))
def div(v: jnp.ndarray, h: float, storage=None) -> jnp.ndarray:
    """FD8 divergence of a vector field ``v[3, N, N, N]`` (Pallas).

    ``storage`` reduces the in-window component precision; output is f32.
    """
    n = v.shape[-1]
    slab = _slab_size(n)
    vs = v if storage is None else v.astype(storage)
    vp = [pad_periodic(vs[a]) for a in range(3)]
    full = pl.BlockSpec(vp[0].shape, lambda i: (0, 0, 0))
    return pl.pallas_call(
        functools.partial(_div_kernel, slab, n, h),
        grid=(n // slab,),
        in_specs=[full, full, full],
        out_specs=pl.BlockSpec((slab, n, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n, n), jnp.float32),
        interpret=True,
    )(*vp)
