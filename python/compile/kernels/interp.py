"""Pallas scattered-data interpolation kernels (paper section 2.3.1).

The paper's kernels are built around the V100 texture unit; the table below
gives the mapping used here (see DESIGN.md section "Hardware adaptation").

    paper kernel   idea                               this module
    ------------   --------------------------------   -----------------------
    GPU-TXTLIN     HW trilinear, 9-bit weights        ``linear_bf16`` (bf16
                                                      weights/loads, f32 acc)
    GPU-LAG        cubic Lagrange, table-lookup       ``cubic_lagrange``
    GPU-TXTSPL     prefiltered cubic B-spline as 8    ``cubic_bspline`` +
                   trilinear texture fetches          ``prefilter`` stencil
    (full f32)     reference trilinear                ``linear``

Every kernel is dtype-parameterized by a static ``storage`` argument: the
coefficient volume (and, for the linear kernels, the weights) are held at
``storage`` precision while the tensor-product sum accumulates in f32 —
the paper's fp16-storage / f32-accumulate split. ``linear_f16`` /
``cubic_bspline_f16`` are the mixed-policy entry points used by the
``*__mixed`` artifacts; ``storage=None`` keeps everything f32.

Structure: the kernel grid tiles the *target points* (the scattered reads of
the semi-Lagrangian characteristic ends); each grid step holds one tile of
query coordinates plus the full coefficient volume in its fast-memory window
and evaluates the tensor-product basis fully vectorized over the tile. The
gathers are CFL-bounded in the registration solver (|v| dt small), which is
what makes the block+halo VMEM schedule viable on real hardware; in interpret
mode the gather is an advanced-indexed load from the flattened volume.

All queries are in grid units with periodic wraparound; ``q`` is ``[3, M]``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref

# Query points per grid step. Perf pass (EXPERIMENTS.md section Perf, L1):
# the linear kernels are fastest with a single whole-set tile (4.8 ms vs
# 14.7 ms at 64^3) — their working set (8 gathers) stays cache-resident;
# the cubic kernels' 64-gather working set thrashes beyond ~64k points
# (50 ms single-tile vs 42 ms at 65536), so they stay tiled.
LINEAR_TILE_MAX = 1 << 22
CUBIC_TILE = 65536


def _tile_size(m: int, cubic: bool) -> int:
    t = min(CUBIC_TILE, m) if cubic else min(LINEAR_TILE_MAX, m)
    # The grid requires an exact division; shrink to the largest divisor.
    while m % t != 0:
        t //= 2
    return max(t, 1)


def _flat_index(n: int, ix, iy, iz):
    return (jnp.mod(ix, n) * n + jnp.mod(iy, n)) * n + jnp.mod(iz, n)


def _linear_kernel(n, storage, f_ref, q_ref, o_ref):
    """Trilinear gather; ``storage`` (None = f32) sets the precision the
    weights and coefficient loads are held at, accumulation is f32."""
    q = q_ref[...]
    i0 = jnp.floor(q).astype(jnp.int32)
    frac = q - i0
    t = frac if storage is None else frac.astype(storage)
    one = t.dtype.type(1.0)
    acc = jnp.zeros(q.shape[1], dtype=jnp.float32)
    for dx in range(2):
        wx = t[0] if dx else one - t[0]
        for dy in range(2):
            wy = t[1] if dy else one - t[1]
            for dz in range(2):
                wz = t[2] if dz else one - t[2]
                idx = _flat_index(n, i0[0] + dx, i0[1] + dy, i0[2] + dz)
                c = f_ref[idx]
                if storage is None:
                    w = wx * wy * wz
                else:
                    # Coefficient volume already holds `storage` (see
                    # _call); widen load and weight product to f32.
                    c = c.astype(jnp.float32)
                    w = (wx * wy * wz).astype(jnp.float32)
                acc = acc + w * c
    o_ref[...] = acc.astype(jnp.float32)


def _cubic_kernel(n, weight_fn, f_ref, q_ref, o_ref):
    """64-point tensor-product gather. Weights are f32; the coefficient
    volume carries whatever storage dtype ``_call`` cast it to (reduced
    loads widen on multiply), and both running sums are f32."""
    q = q_ref[...]
    i0 = jnp.floor(q).astype(jnp.int32)
    t = q - i0
    wx = weight_fn(t[0])
    wy = weight_fn(t[1])
    wz = weight_fn(t[2])
    acc = jnp.zeros(q.shape[1], dtype=jnp.float32)
    for dx in range(4):
        for dy in range(4):
            part = jnp.zeros(q.shape[1], dtype=jnp.float32)
            for dz in range(4):
                idx = _flat_index(n, i0[0] + dx - 1, i0[1] + dy - 1, i0[2] + dz - 1)
                part = part + wz[dz] * f_ref[idx].astype(jnp.float32)
            acc = acc + wx[dx] * wy[dy] * part
    o_ref[...] = acc


def _call(kernel, f: jnp.ndarray, q: jnp.ndarray, cubic: bool = False, storage=None) -> jnp.ndarray:
    n = f.shape[0]
    m = q.shape[1]
    tile = _tile_size(m, cubic)
    assert m % tile == 0, f"query count {m} not divisible by tile {tile}"
    if storage is not None:
        f = f.astype(storage)  # coefficient volume at storage precision
    return pl.pallas_call(
        functools.partial(kernel, n),
        grid=(m // tile,),
        in_specs=[
            pl.BlockSpec((n * n * n,), lambda i: (0,)),
            pl.BlockSpec((3, tile), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(f.reshape(-1), q)


@functools.partial(jax.jit, static_argnames=("storage",))
def linear(f: jnp.ndarray, q: jnp.ndarray, storage=None) -> jnp.ndarray:
    """Trilinear interpolation (Pallas); ``storage`` reduces weight/load
    precision under the f32 accumulator (None = full f32)."""
    return _call(
        lambda n, *refs: _linear_kernel(n, storage, *refs), f, q, storage=storage
    )


@jax.jit
def linear_bf16(f: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Reduced-precision trilinear (GPU-TXTLIN analog; Pallas)."""
    return linear(f, q, storage=jnp.bfloat16)


@jax.jit
def linear_f16(f: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """fp16-storage trilinear: the mixed policy's linear kernel."""
    return linear(f, q, storage=jnp.float16)


@functools.partial(jax.jit, static_argnames=("storage",))
def cubic_lagrange(f: jnp.ndarray, q: jnp.ndarray, storage=None) -> jnp.ndarray:
    """Cubic Lagrange interpolation (GPU-LAG analog; Pallas)."""
    return _call(
        lambda n, *refs: _cubic_kernel(n, ref.lagrange_weights, *refs),
        f,
        q,
        cubic=True,
        storage=storage,
    )


@functools.partial(jax.jit, static_argnames=("storage",))
def cubic_bspline(c: jnp.ndarray, q: jnp.ndarray, storage=None) -> jnp.ndarray:
    """Cubic B-spline interpolation over prefiltered coefficients ``c``
    (GPU-TXTSPL analog; Pallas). Apply :func:`prefilter` to grid values
    first. ``storage`` holds the coefficient volume reduced (the texture
    analog: the prefilter itself stays f32)."""
    return _call(
        lambda n, *refs: _cubic_kernel(n, ref.bspline_weights, *refs),
        c,
        q,
        cubic=True,
        storage=storage,
    )


@jax.jit
def cubic_bspline_f16(c: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """fp16-storage B-spline: the mixed policy's cubic kernel."""
    return cubic_bspline(c, q, storage=jnp.float16)


# ---------------------------------------------------------------------------
# B-spline prefilter: separable 15-point stencil (paper section 2.3.1,
# GPU-TXTSPL bullet: "a 15-point axis aligned stencil operation ...
# implemented using the FD scheme used in the CUDA SDK example")
# ---------------------------------------------------------------------------

PF_HALF = 7  # taps per side; 15-point stencil


def _prefilter_kernel(slab: int, n: int, axis: int, taps: np.ndarray, fp_ref, o_ref):
    i = pl.program_id(0)
    pad = PF_HALF if axis == 0 else 0
    win = pl.load(
        fp_ref,
        (pl.dslice(i * slab, slab + 2 * pad), slice(None), slice(None)),
    )
    lo = [PF_HALF if a == axis else 0 for a in range(3)]
    if axis == 0:
        lo[0] = PF_HALF
    acc = None
    for j, w in enumerate(taps):
        off = j - PF_HALF
        idx = []
        for a in range(3):
            start = lo[a] + (off if a == axis else 0)
            size = slab if a == 0 else n
            idx.append(slice(start, start + size))
        term = np.float32(w) * win[tuple(idx)]
        acc = term if acc is None else acc + term
    o_ref[...] = acc


def _prefilter_axis(f: jnp.ndarray, axis: int) -> jnp.ndarray:
    n = f.shape[0]
    # Same whole-volume-block policy as the FD8 stencils (perf pass).
    slab = n if (n + 2 * PF_HALF) ** 3 * 4 <= 8 * 1024 * 1024 else min(8, n)
    taps = ref.prefilter_taps(PF_HALF)
    pad = [(0, 0)] * 3
    pad[axis] = (PF_HALF, PF_HALF)
    fp = jnp.pad(f, pad, mode="wrap")
    return pl.pallas_call(
        functools.partial(_prefilter_kernel, slab, n, axis, taps),
        grid=(n // slab,),
        in_specs=[pl.BlockSpec(fp.shape, lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((slab, n, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n, n), f.dtype),
        interpret=True,
    )(fp)


@jax.jit
def prefilter(f: jnp.ndarray) -> jnp.ndarray:
    """Separable 3-D cubic-B-spline prefilter (Pallas, 15-pt per axis)."""
    for axis in range(3):
        f = _prefilter_axis(f, axis)
    return f
