"""Spectral (FFT) operators retained from CLAIRE.

The paper replaces *first-order* derivatives with FD8 but deliberately keeps
spectral differentiation for the high-order regularization operator ``A``,
its inverse (the Newton-Krylov preconditioner), and the Leray projection,
because these must be *inverted* and are diagonal in the spectral domain
(paper section 2.3: "Notice that we keep the spectral differentiation for
high-order differential operators, since we need to evaluate their inverses
in our solver").

Operator definitions (default CLAIRE H1-div regularization):

    reg(v)      = beta/2 <A v, v> + gamma/2 ||div v||^2,  A = -Laplacian
    reg_grad(v) = beta * A v - gamma * grad(div v)
    precond(r)  = (beta * A + gamma * grad div + eps I)^{-1} r   (Sherman-
                  Morrison closed form per spectral mode)
    leray(v)    = v - grad(Delta^{-1} div v)   (projection onto div-free)

Precision policy: spectral operators are pinned to f32 regardless of the
caller's storage dtype — they are exactly the operators the solver must
*invert*, and the mixed policy (paper §3) keeps all outer/regularization
quantities at full precision. ``_f32`` widens reduced-storage inputs at
entry; every operator returns f32.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def _f32(x: jnp.ndarray) -> jnp.ndarray:
    """Widen a (possibly reduced-storage) field to the f32 compute type."""
    return x if x.dtype == jnp.float32 else x.astype(jnp.float32)


def wavenumber_grids(n: int, zero_nyquist: bool = False):
    """Integer wavenumber meshgrid ``(k1, k2, k3)`` for an n^3 grid.

    ``zero_nyquist=True`` matches the first-derivative convention used by
    ``ref.fft_grad``/``ref.fft_div`` (the Nyquist mode of an odd-order
    derivative of a real field is not representable); operators that must
    commute with the discrete divergence (the Leray projection) need it.
    """
    k = np.fft.fftfreq(n, d=1.0 / n).astype(np.float32)
    if zero_nyquist and n % 2 == 0:
        k = k.copy()
        k[n // 2] = 0.0
    k1 = k.reshape(n, 1, 1)
    k2 = k.reshape(1, n, 1)
    k3 = k.reshape(1, 1, n)
    return k1, k2, k3


def _ksq(n: int) -> np.ndarray:
    k1, k2, k3 = wavenumber_grids(n)
    return (k1 * k1 + k2 * k2 + k3 * k3).astype(np.float32)


def reg_apply(v: jnp.ndarray, beta: float, gamma: float) -> jnp.ndarray:
    """Gradient of the regularization: ``beta*(-Lap) v - gamma*grad(div v)``.

    Applied mode-by-mode: ``(beta*|k|^2 I + gamma * k k^T) v_hat``.
    """
    v = _f32(v)
    n = v.shape[-1]
    k1, k2, k3 = (jnp.asarray(k) for k in wavenumber_grids(n))
    ksq = jnp.asarray(_ksq(n))
    vh = [jnp.fft.fftn(v[a]) for a in range(3)]
    kdotv = k1 * vh[0] + k2 * vh[1] + k3 * vh[2]
    out = []
    for a, ka in enumerate((k1, k2, k3)):
        oh = beta * ksq * vh[a] + gamma * ka * kdotv
        out.append(jnp.real(jnp.fft.ifftn(oh)).astype(jnp.float32))
    return jnp.stack(out)


def reg_energy(v: jnp.ndarray, beta: float, gamma: float, h: float) -> jnp.ndarray:
    """``beta/2 <Av, v> + gamma/2 ||div v||^2`` with h^3 quadrature weights."""
    av = reg_apply(v, beta, gamma)
    return 0.5 * jnp.sum(av * v) * np.float32(h**3)


def precond_apply(r: jnp.ndarray, beta: float, gamma: float) -> jnp.ndarray:
    """Inverse of ``beta*|k|^2 I + gamma*k k^T`` per mode (Sherman-Morrison).

    For ``M = a I + g k k^T`` with ``a = beta|k|^2``:
        ``M^{-1} = (1/a) (I - g k k^T / (a + g |k|^2))``.
    The zero mode (a = 0) is mapped to the identity: the regularization has a
    null space of constant fields, on which the Hessian is the data term.
    """
    r = _f32(r)
    n = r.shape[-1]
    k1, k2, k3 = (jnp.asarray(k) for k in wavenumber_grids(n))
    ksq = jnp.asarray(_ksq(n))
    a = beta * ksq
    safe_a = jnp.where(a > 0, a, 1.0)
    rh = [jnp.fft.fftn(r[c]) for c in range(3)]
    kdotr = k1 * rh[0] + k2 * rh[1] + k3 * rh[2]
    coef = gamma / (safe_a * (safe_a + gamma * ksq))
    out = []
    for c, kc in enumerate((k1, k2, k3)):
        oh = rh[c] / safe_a - coef * kc * kdotr
        oh = jnp.where(a > 0, oh, rh[c])  # identity on the zero mode
        out.append(jnp.real(jnp.fft.ifftn(oh)).astype(jnp.float32))
    return jnp.stack(out)


def leray(v: jnp.ndarray) -> jnp.ndarray:
    """Leray projection onto divergence-free fields (spectral).

    Uses Nyquist-zeroed wavenumbers so the output is divergence-free under
    the same discrete divergence as ``ref.fft_div`` (and FD8, which has no
    Nyquist pathology).
    """
    v = _f32(v)
    n = v.shape[-1]
    k1, k2, k3 = (jnp.asarray(k) for k in wavenumber_grids(n, zero_nyquist=True))
    ksq = k1 * k1 + k2 * k2 + k3 * k3
    safe = jnp.where(ksq > 0, ksq, 1.0)
    vh = [jnp.fft.fftn(v[a]) for a in range(3)]
    kdotv = (k1 * vh[0] + k2 * vh[1] + k3 * vh[2]) / safe
    kdotv = jnp.where(ksq > 0, kdotv, 0.0)
    out = []
    for a, ka in enumerate((k1, k2, k3)):
        out.append(jnp.real(jnp.fft.ifftn(vh[a] - ka * kdotv)).astype(jnp.float32))
    return jnp.stack(out)


def gauss_smooth(f: jnp.ndarray, sigma_h: float) -> jnp.ndarray:
    """Periodic Gaussian smoothing with std ``sigma_h`` grid cells (spectral).

    CLAIRE smooths input images with a Gaussian of one grid cell before
    registration; we reproduce that preprocessing here so it can be fused
    into the AOT artifacts.
    """
    f = _f32(f)
    n = f.shape[-1]
    ksq = jnp.asarray(_ksq(n))
    # x is in grid units: exp(-sigma^2 |k|^2 / 2) with k in cycles scaled by
    # 2*pi/N per grid unit.
    scale = (2.0 * np.pi / n) * sigma_h
    kern = jnp.exp(-0.5 * (scale**2) * ksq)
    return jnp.real(jnp.fft.ifftn(jnp.fft.fftn(f) * kern)).astype(jnp.float32)
