"""Pure-jnp reference oracles for the L1 kernels.

Every Pallas kernel in this package is validated (pytest, hypothesis sweeps)
against the implementations here. These are also the kernels used by the
``fft-cubic`` baseline variant (the analog of the paper's cpu-fft-cubic).

Conventions
-----------
* Domain is the periodic box ``Omega = (0, 2*pi)^3`` discretized with ``N``
  equispaced points per axis, spacing ``h = 2*pi/N``.
* Scalar fields are ``f32[N, N, N]`` with axes ``(x1, x2, x3)``.
* Interpolation query points are given in *grid units* (i.e. ``x / h``),
  flattened to shape ``[3, M]``; periodic wraparound is implied.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# 8th-order central finite differences (paper: FD8, section 2.3.2)
# ---------------------------------------------------------------------------

# Centered 8th-order first-derivative coefficients for offsets 1..4; the
# stencil is antisymmetric: df/dx ~ (1/h) * sum_k c_k (f_{+k} - f_{-k}).
FD8_COEFFS = np.array([4.0 / 5.0, -1.0 / 5.0, 4.0 / 105.0, -1.0 / 280.0])


def fd8_partial(f: jnp.ndarray, axis: int, h: float, storage=None) -> jnp.ndarray:
    """8th-order accurate periodic first derivative along ``axis``.

    ``storage`` (e.g. ``jnp.float16``) emulates reduced-precision field
    storage: tap pairs subtract at storage precision, the coefficient FMA
    and running sum are f32 (the mixed policy's accumulator rule).
    """
    if storage is not None:
        f = f.astype(storage)
    out = jnp.zeros(f.shape, dtype=jnp.float32)
    for k, c in enumerate(FD8_COEFFS, start=1):
        diff = jnp.roll(f, -k, axis=axis) - jnp.roll(f, k, axis=axis)
        out = out + np.float32(c) * diff.astype(jnp.float32)
    return out / np.float32(h)


def fd8_grad(f: jnp.ndarray, h: float, storage=None) -> jnp.ndarray:
    """Gradient of a scalar field, stacked as ``[3, N, N, N]``."""
    return jnp.stack([fd8_partial(f, a, h, storage=storage) for a in range(3)])


def fd8_div(v: jnp.ndarray, h: float, storage=None) -> jnp.ndarray:
    """Divergence of a vector field ``v[3, N, N, N]``."""
    return sum(fd8_partial(v[a], a, h, storage=storage) for a in range(3))


# ---------------------------------------------------------------------------
# Spectral (FFT) first derivatives (the paper's CPU-CLAIRE scheme)
# ---------------------------------------------------------------------------


def fft_partial(f: jnp.ndarray, axis: int, h: float) -> jnp.ndarray:
    """Spectral first derivative along ``axis`` (exact for band-limited f).

    ``h`` is accepted for interface symmetry with :func:`fd8_partial`; the
    spectral derivative is computed from integer wavenumbers on (0, 2*pi).
    """
    n = f.shape[axis]
    k = jnp.fft.fftfreq(n, d=1.0 / n)
    if n % 2 == 0:
        # The Nyquist mode of an odd-order derivative of a real signal is not
        # representable; zero it (standard spectral-differentiation choice).
        k = k.at[n // 2].set(0.0)
    shape = [1, 1, 1]
    shape[axis] = n
    fh = jnp.fft.fft(f, axis=axis)
    df = jnp.fft.ifft(1j * k.reshape(shape) * fh, axis=axis)
    return jnp.real(df).astype(f.dtype)


def fft_grad(f: jnp.ndarray, h: float) -> jnp.ndarray:
    """Spectral gradient via a single 3-D FFT (paper section 2.3.2: 3-D FFTs
    avoid transposes and re-reads of spectral data)."""
    n1, n2, n3 = f.shape
    fh = jnp.fft.fftn(f)
    out = []
    for axis, n in enumerate((n1, n2, n3)):
        k = jnp.fft.fftfreq(n, d=1.0 / n)
        if n % 2 == 0:
            k = k.at[n // 2].set(0.0)
        shape = [1, 1, 1]
        shape[axis] = n
        out.append(jnp.real(jnp.fft.ifftn(1j * k.reshape(shape) * fh)).astype(f.dtype))
    return jnp.stack(out)


def fft_div(v: jnp.ndarray, h: float) -> jnp.ndarray:
    """Spectral divergence; sums partials in the spectral domain (one inverse
    3-D FFT total, mirroring the paper's single-store divergence kernel)."""
    acc = None
    for axis in range(3):
        n = v.shape[axis + 1]
        k = jnp.fft.fftfreq(n, d=1.0 / n)
        if n % 2 == 0:
            k = k.at[n // 2].set(0.0)
        shape = [1, 1, 1]
        shape[axis] = n
        term = 1j * k.reshape(shape) * jnp.fft.fftn(v[axis])
        acc = term if acc is None else acc + term
    return jnp.real(jnp.fft.ifftn(acc)).astype(v.dtype)


# ---------------------------------------------------------------------------
# Interpolation (paper: section 2.3.1); all periodic, queries in grid units
# ---------------------------------------------------------------------------


def _gather(f: jnp.ndarray, ix: jnp.ndarray, iy: jnp.ndarray, iz: jnp.ndarray) -> jnp.ndarray:
    n1, n2, n3 = f.shape
    flat = (jnp.mod(ix, n1) * n2 + jnp.mod(iy, n2)) * n3 + jnp.mod(iz, n3)
    return jnp.take(f.reshape(-1), flat)


def interp_linear(f: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Trilinear interpolation. ``q`` is ``[3, M]`` in grid units."""
    i0 = jnp.floor(q).astype(jnp.int32)
    t = (q - i0).astype(f.dtype)
    out = jnp.zeros(q.shape[1], dtype=f.dtype)
    for dx in range(2):
        wx = t[0] if dx else 1.0 - t[0]
        for dy in range(2):
            wy = t[1] if dy else 1.0 - t[1]
            for dz in range(2):
                wz = t[2] if dz else 1.0 - t[2]
                c = _gather(f, i0[0] + dx, i0[1] + dy, i0[2] + dz)
                out = out + wx * wy * wz * c
    return out


def interp_linear_rp(f: jnp.ndarray, q: jnp.ndarray, storage) -> jnp.ndarray:
    """Reduced-precision trilinear interpolation at ``storage`` dtype.

    The analog of the paper's GPU-TXTLIN kernel: the V100 texture unit
    stores interpolation weights in 9-bit fixed point. We re-express that
    hardware trade on our substrate as ``storage`` (bf16/f16) weights and
    corner values with an f32 accumulator.
    """
    i0 = jnp.floor(q).astype(jnp.int32)
    t = (q - i0).astype(storage)
    out = jnp.zeros(q.shape[1], dtype=jnp.float32)
    one = t.dtype.type(1.0)
    for dx in range(2):
        wx = t[0] if dx else one - t[0]
        for dy in range(2):
            wy = t[1] if dy else one - t[1]
            for dz in range(2):
                wz = t[2] if dz else one - t[2]
                c = _gather(f, i0[0] + dx, i0[1] + dy, i0[2] + dz)
                w = (wx * wy * wz).astype(jnp.float32)
                out = out + w * c.astype(storage).astype(jnp.float32)
    return out


def interp_linear_bf16(f: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """bf16-storage trilinear (GPU-TXTLIN analog)."""
    return interp_linear_rp(f, q, jnp.bfloat16)


def interp_linear_f16(f: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """fp16-storage trilinear: the mixed policy's linear oracle."""
    return interp_linear_rp(f, q, jnp.float16)


def lagrange_weights(t: jnp.ndarray):
    """Cubic Lagrange basis at offsets (-1, 0, 1, 2) evaluated at t in [0,1)."""
    w0 = -t * (t - 1.0) * (t - 2.0) / 6.0
    w1 = (t + 1.0) * (t - 1.0) * (t - 2.0) / 2.0
    w2 = -(t + 1.0) * t * (t - 2.0) / 2.0
    w3 = (t + 1.0) * t * (t - 1.0) / 6.0
    return w0, w1, w2, w3


def bspline_weights(t: jnp.ndarray):
    """Uniform cubic B-spline basis at offsets (-1, 0, 1, 2) at t in [0,1)."""
    s = 1.0 - t
    w0 = s * s * s / 6.0
    w1 = (4.0 - 6.0 * t * t + 3.0 * t * t * t) / 6.0
    w2 = (4.0 - 6.0 * s * s + 3.0 * s * s * s) / 6.0
    w3 = t * t * t / 6.0
    return w0, w1, w2, w3


def _interp_cubic(f: jnp.ndarray, q: jnp.ndarray, weight_fn, storage=None) -> jnp.ndarray:
    """Tensor-product cubic; ``storage`` reduces coefficient fetches while
    both running sums accumulate in f32."""
    if storage is not None:
        f = f.astype(storage)
    i0 = jnp.floor(q).astype(jnp.int32)
    t = (q - i0).astype(jnp.float32)
    wx = weight_fn(t[0])
    wy = weight_fn(t[1])
    wz = weight_fn(t[2])
    out = jnp.zeros(q.shape[1], dtype=jnp.float32)
    for dx in range(4):
        for dy in range(4):
            part = jnp.zeros(q.shape[1], dtype=jnp.float32)
            for dz in range(4):
                c = _gather(f, i0[0] + dx - 1, i0[1] + dy - 1, i0[2] + dz - 1)
                part = part + wz[dz] * c.astype(jnp.float32)
            out = out + wx[dx] * wy[dy] * part
    return out


def interp_cubic_lagrange(f: jnp.ndarray, q: jnp.ndarray, storage=None) -> jnp.ndarray:
    """Cubic Lagrange interpolation (the paper's GPU-LAG / CPU-LAG kernel).

    Coefficients equal grid values; 64-point tensor-product stencil.
    """
    return _interp_cubic(f, q, lagrange_weights, storage=storage)


def interp_cubic_bspline(c: jnp.ndarray, q: jnp.ndarray, storage=None) -> jnp.ndarray:
    """Cubic B-spline interpolation given *prefiltered* coefficients ``c``.

    The paper's GPU-TXTSPL kernel: B-spline basis over prefiltered
    coefficients. On the GPU the 64-point sum is factored into 8 trilinear
    texture fetches; here the tensor-product weights are vectorized directly
    (the factorization is a scheduling detail of the texture unit).
    """
    return _interp_cubic(c, q, bspline_weights, storage=storage)


def interp_cubic_bspline_f16(c: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """fp16-storage B-spline: the mixed policy's cubic oracle."""
    return interp_cubic_bspline(c, q, storage=jnp.float16)


# ---------------------------------------------------------------------------
# B-spline prefilter (paper: 15-point finite convolution, Champagnat/Le Sant)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def prefilter_taps(half_width: int = 7) -> np.ndarray:
    """Truncated impulse response of the inverse cubic-B-spline filter.

    The cubic B-spline sampled at integers is ``[1/6, 4/6, 1/6]``; exact
    prefiltering divides by its transfer function ``B(w) = (4 + 2 cos w)/6``
    (a causal/anticausal IIR in Unser's classic scheme). Following the paper
    we replace the IIR with a *finite* convolution (default 15 taps): the
    impulse response of ``1/B`` decays like ``r^|n|`` with ``r = sqrt(3)-2``
    (|r| ~ 0.268), so 7 taps per side reach ~1e-4. Taps come from the
    analytic pole expansion, renormalized to invert B exactly at DC.
    """
    r = np.sqrt(3.0) - 2.0  # pole of 6 / (z + 4 + z^-1)
    n = np.arange(-half_width, half_width + 1)
    taps = -6.0 * r / (1.0 - r * r) * (r ** np.abs(n))
    taps *= 1.0 / np.sum(taps)
    return taps.astype(np.float32)


def prefilter_1d(f: jnp.ndarray, axis: int, half_width: int = 7) -> jnp.ndarray:
    taps = prefilter_taps(half_width)
    out = jnp.zeros_like(f)
    for i, w in enumerate(taps):
        out = out + w * jnp.roll(f, half_width - i, axis=axis)
    return out


def prefilter(f: jnp.ndarray, half_width: int = 7) -> jnp.ndarray:
    """Separable 3-D B-spline prefilter: 15-point stencil along each axis."""
    for axis in range(3):
        f = prefilter_1d(f, axis, half_width)
    return f


def prefilter_exact(f: jnp.ndarray) -> jnp.ndarray:
    """Exact spectral prefilter (oracle for the truncated version)."""
    out = f.astype(jnp.float32)
    for axis in range(3):
        n = f.shape[axis]
        w = 2.0 * np.pi * np.fft.fftfreq(n)
        b = (4.0 + 2.0 * np.cos(w)) / 6.0
        shape = [1, 1, 1]
        shape[axis] = n
        fh = jnp.fft.fft(out, axis=axis)
        out = jnp.real(jnp.fft.ifft(fh / jnp.asarray(b.reshape(shape)), axis=axis))
    return out.astype(f.dtype)
