"""Shared fixtures for the kernel/model test suite."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xC1A12E)


def band_limited_field(rng, n, kmax=3, terms=4, dtype=np.float32):
    """Smooth random periodic field (shared helper)."""
    x = np.linspace(0, 2 * np.pi, n, endpoint=False)
    X = np.meshgrid(x, x, x, indexing="ij")
    f = np.zeros((n, n, n))
    for _ in range(terms):
        k = rng.integers(1, kmax + 1, 3)
        ph = rng.uniform(0, 2 * np.pi, 3)
        a = rng.standard_normal()
        f += a * np.sin(k[0] * X[0] + ph[0]) * np.sin(k[1] * X[1] + ph[1]) * np.sin(
            k[2] * X[2] + ph[2]
        )
    return f.astype(dtype)
