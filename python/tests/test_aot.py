"""AOT compile-path tests: HLO text emission, manifest integrity, and the
regression guards for the two interchange-format pitfalls (64-bit proto ids
-> text format; constant elision -> print_large_constants)."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def test_to_hlo_text_keeps_large_constants():
    # Regression: the default HLO printer elides constants > a few elements
    # as 'constant({...})' and the parser silently zero-fills them.
    k = jnp.asarray(np.arange(4096, dtype=np.float32))
    lowered = jax.jit(lambda x: (x * k,)).lower(
        jax.ShapeDtypeStruct((4096,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "{...}" not in text
    assert "4096" in text


def test_to_hlo_text_no_metadata():
    # xla_extension 0.5.1's parser rejects newer metadata attributes
    # (source_end_line etc.); aot must strip metadata.
    lowered = jax.jit(lambda x: (x + 1.0,)).lower(
        jax.ShapeDtypeStruct((8,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "metadata=" not in text
    assert "source_end_line" not in text


def test_op_defs_cover_solver_interface():
    p = model.Problem(n=8)
    names = {o.name for o in aot.op_defs(p, kernel_level=True)}
    required = {
        "objective",
        "newton_setup",
        "hess_matvec",
        "transport",
        "precond",
        "defmap",
        "detf",
        "grad_fft",
        "grad_fd8",
        "div_fft",
        "div_fd8",
        "interp_lin",
        "interp_linbf16",
        "interp_lag",
        "interp_spl",
        "prefilter",
        "reg_apply",
        "leray",
        "gauss_smooth",
        "sl_step",
    }
    assert required <= names
    # Non-kernel-level variants only emit the solver core.
    slim = {o.name for o in aot.op_defs(p, kernel_level=False)}
    assert slim == {"objective", "newton_setup", "hess_matvec", "transport"}


def test_newton_setup_signature_matches_solver_expectation():
    p = model.Problem(n=8)
    (setup,) = [o for o in aot.op_defs(p, False) if o.name == "newton_setup"]
    assert [nm for nm, _ in setup.inputs] == ["v", "m0", "m1", "bg"]
    out = setup.fn(
        jnp.zeros((3, 8, 8, 8), jnp.float32),
        jnp.zeros((8, 8, 8), jnp.float32),
        jnp.zeros((8, 8, 8), jnp.float32),
        jnp.asarray([1e-2, 1e-3], jnp.float32),
    )
    # (g, m_traj, yb, yf, divv, scalars)
    assert len(out) == 6
    assert out[0].shape == (3, 8, 8, 8)
    assert out[1].shape == (p.nt + 1, 8, 8, 8)
    assert out[5].shape == (3,)


@pytest.mark.skipif(not (ARTIFACTS / "manifest.json").exists(), reason="no artifacts")
def test_manifest_consistent_with_files():
    manifest = json.loads((ARTIFACTS / "manifest.json").read_text())
    assert manifest["nt"] == model.DEFAULT_NT
    arts = manifest["artifacts"]
    assert len(arts) >= 100
    for key, entry in arts.items():
        f = ARTIFACTS / entry["file"]
        assert f.exists(), f"missing {f}"
        assert entry["op"] in key
        assert f"n{entry['n']}" in key
        prec = entry.get("precision", "full")
        assert prec in model.PRECISIONS
        assert (prec == "mixed") == key.endswith("__mixed")
        for sig in entry["inputs"]:
            if prec == "full":
                assert sig["dtype"] == "f32"
            else:
                assert sig["dtype"] in ("f32", "f16", "bf16")
            assert all(isinstance(d, int) for d in sig["shape"])
        # Outputs are f32 under every policy (runtime unmarshals f32).
        for sig in entry["outputs"]:
            assert sig["dtype"] == "f32"


@pytest.mark.skipif(not (ARTIFACTS / "manifest.json").exists(), reason="no artifacts")
def test_no_artifact_has_elided_constants():
    for f in ARTIFACTS.glob("*.hlo.txt"):
        head = f.read_text()
        assert "constant({...})" not in head, f"elided constants in {f.name}"


def test_complexity_table1_structure():
    """Paper Table 1 consistency: our operator composition's kernel counts."""
    p = model.Problem(n=8, nt=4)
    c = model.complexity(p)
    d, nt = 3, 4
    # Objective: no first-order derivatives, only reg FFTs; Nt interps +
    # the characteristic trace.
    assert c["objective"]["first"] == 0
    assert c["objective"]["ips"] == 2 * d + nt
    # Gradient: div v once + (Nt+1) image gradients (d partials each is
    # counted as one grad application here).
    assert c["newton_setup"]["first"] == 1 + d * (nt + 1)
    # Hessian matvec: d(Nt+1) firsts (paper: d(Nt+1) for the incremental
    # state's source terms), 4*Nt interpolations.
    assert c["hess_matvec"]["first"] == d * (nt + 1)
    assert c["hess_matvec"]["ips"] == 4 * nt
