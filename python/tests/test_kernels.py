"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles (ref.py),
plus analytic properties of the oracles themselves.

This is the core correctness signal of the compile path: every kernel that
ends up inside an HLO artifact is exercised here, including hypothesis
sweeps over shapes and query distributions.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fd8, interp, ref

from .conftest import band_limited_field


def rand_field(seed, n):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.standard_normal((n, n, n)).astype(np.float32))


def rand_queries(seed, n, m):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.uniform(-n, 2 * n, (3, m)).astype(np.float32))


# ---------------------------------------------------------------------------
# FD8 (Pallas) vs reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [8, 16, 32])
def test_fd8_grad_matches_ref(n):
    h = 2 * np.pi / n
    f = rand_field(n, n)
    got = fd8.grad(f, h)
    want = ref.fd8_grad(f, h)
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("n", [8, 16, 32])
def test_fd8_div_matches_ref(n):
    h = 2 * np.pi / n
    r = np.random.default_rng(n)
    v = jnp.asarray(r.standard_normal((3, n, n, n)).astype(np.float32))
    got = fd8.div(v, h)
    want = ref.fd8_div(v, h)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_fd8_constant_is_zero():
    n, h = 16, 2 * np.pi / 16
    f = jnp.full((n, n, n), 3.25, jnp.float32)
    np.testing.assert_allclose(fd8.grad(f, h), 0.0, atol=1e-5)


def test_fd8_low_freq_trig_accuracy():
    n = 32
    h = 2 * np.pi / n
    x = np.linspace(0, 2 * np.pi, n, endpoint=False)
    X = np.meshgrid(x, x, x, indexing="ij")
    f = jnp.asarray(np.sin(2 * X[1]).astype(np.float32))
    got = fd8.grad(f, h)
    want = 2 * np.cos(2 * X[1])
    np.testing.assert_allclose(got[1], want, atol=5e-5)
    np.testing.assert_allclose(got[0], 0.0, atol=5e-5)
    np.testing.assert_allclose(got[2], 0.0, atol=5e-5)


def test_fd8_error_grows_with_frequency():
    # Paper Fig 2: FD8 error increases toward Nyquist.
    n = 32
    h = 2 * np.pi / n
    x = np.linspace(0, 2 * np.pi, n, endpoint=False)
    X = np.meshgrid(x, x, x, indexing="ij")

    def err(w):
        f = jnp.asarray(np.sin(w * X[2]).astype(np.float32))
        d = fd8.grad(f, h)[2]
        return float(jnp.max(jnp.abs(d - w * np.cos(w * X[2]))))

    assert err(2) < err(6) < err(12)


def test_fft_first_derivative_exact_below_nyquist():
    n = 32
    h = 2 * np.pi / n
    x = np.linspace(0, 2 * np.pi, n, endpoint=False)
    X = np.meshgrid(x, x, x, indexing="ij")
    for w in (2, 9, 14):
        f = jnp.asarray(np.sin(w * X[2]).astype(np.float32))
        d = ref.fft_grad(f, h)[2]
        np.testing.assert_allclose(d, w * np.cos(w * X[2]), atol=5e-3)


def test_fft_div_matches_sum_of_partials():
    n = 16
    h = 2 * np.pi / n
    r = np.random.default_rng(5)
    v = jnp.asarray(r.standard_normal((3, n, n, n)).astype(np.float32))
    want = sum(ref.fft_partial(v[a], a, h) for a in range(3))
    got = ref.fft_div(v, h)
    np.testing.assert_allclose(got, want, atol=1e-4)


# ---------------------------------------------------------------------------
# Interpolation (Pallas) vs reference
# ---------------------------------------------------------------------------

PALLAS_VS_REF = [
    (interp.linear, ref.interp_linear, 1e-5),
    (interp.cubic_lagrange, ref.interp_cubic_lagrange, 1e-5),
]


@pytest.mark.parametrize("n", [8, 16])
@pytest.mark.parametrize("pk,rk,tol", PALLAS_VS_REF)
def test_interp_pallas_matches_ref(n, pk, rk, tol):
    f = rand_field(n + 1, n)
    q = rand_queries(n + 2, n, 2048)
    np.testing.assert_allclose(pk(f, q), rk(f, q), atol=tol)


def test_interp_bf16_close_to_f32():
    # The reduced-precision texture analog: error bounded by bf16 epsilon.
    n = 16
    f = rand_field(3, n)
    q = rand_queries(4, n, 2048)
    a = interp.linear_bf16(f, q)
    b = ref.interp_linear(f, q)
    err = float(jnp.max(jnp.abs(a - b)))
    assert 1e-7 < err < 0.05, err


def test_bspline_pallas_matches_ref():
    n = 16
    f = rand_field(9, n)
    q = rand_queries(10, n, 2048)
    got = interp.cubic_bspline(interp.prefilter(f), q)
    want = ref.interp_cubic_bspline(ref.prefilter(f), q)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_interp_at_grid_points_identity():
    n = 8
    f = rand_field(11, n)
    g = jnp.arange(n, dtype=jnp.float32)
    qg = jnp.stack(jnp.meshgrid(g, g, g, indexing="ij")).reshape(3, -1)
    for fn in (interp.linear, interp.cubic_lagrange):
        np.testing.assert_allclose(fn(f, qg), f.reshape(-1), atol=1e-5)
    # B-spline with *exact* prefilter also interpolates at nodes.
    c = ref.prefilter_exact(f)
    np.testing.assert_allclose(ref.interp_cubic_bspline(c, qg), f.reshape(-1), atol=1e-4)


def test_interp_periodic_wrap():
    n = 8
    f = rand_field(12, n)
    q = rand_queries(13, n, 512)
    shifted = q + jnp.float32(n)  # one full period
    np.testing.assert_allclose(
        interp.linear(f, q), interp.linear(f, shifted), atol=1e-4
    )
    np.testing.assert_allclose(
        interp.cubic_lagrange(f, q), interp.cubic_lagrange(f, shifted), atol=1e-4
    )


def test_cubic_interp_order_of_accuracy():
    # Error of cubic interpolation on a smooth function drops ~h^4.
    r = np.random.default_rng(14)

    def max_err(n):
        x = np.linspace(0, 2 * np.pi, n, endpoint=False)
        X = np.meshgrid(x, x, x, indexing="ij")
        f = jnp.asarray(np.sin(2 * X[0]) * np.cos(X[1]) * np.sin(X[2]), jnp.float32)
        m = 4096
        q = jnp.asarray(r.uniform(0, n, (3, m)).astype(np.float32))
        got = ref.interp_cubic_lagrange(f, q)
        h = 2 * np.pi / n
        xs = np.asarray(q) * h
        want = np.sin(2 * xs[0]) * np.cos(xs[1]) * np.sin(xs[2])
        return float(jnp.max(jnp.abs(got - want)))

    e16, e32 = max_err(16), max_err(32)
    assert e32 < e16 / 8, (e16, e32)  # ~16x expected; 8x with f32 headroom


def test_bspline_more_accurate_than_lagrange_on_smooth():
    # Paper Table 4: GPU-TXTSPL is ~2x more accurate than LAG at moderate
    # resolution on band-limited data.
    n = 16
    r = np.random.default_rng(15)
    x = np.linspace(0, 2 * np.pi, n, endpoint=False)
    X = np.meshgrid(x, x, x, indexing="ij")
    f64 = (np.sin(8 * X[0]) ** 2 + np.sin(2 * X[1]) ** 2 + np.sin(4 * X[2]) ** 2) / 3
    f = jnp.asarray(f64.astype(np.float32))
    m = 4096
    q = jnp.asarray((r.uniform(-0.5, 0.5, (3, m)) + r.integers(0, n, (3, m))).astype(np.float32))
    h = 2 * np.pi / n
    xs = np.asarray(q) * h
    want = (np.sin(8 * xs[0]) ** 2 + np.sin(2 * xs[1]) ** 2 + np.sin(4 * xs[2]) ** 2) / 3
    e_lag = float(jnp.sqrt(jnp.mean((ref.interp_cubic_lagrange(f, q) - want) ** 2)))
    e_spl = float(
        jnp.sqrt(jnp.mean((ref.interp_cubic_bspline(ref.prefilter(f), q) - want) ** 2))
    )
    e_lin = float(jnp.sqrt(jnp.mean((ref.interp_linear(f, q) - want) ** 2)))
    assert e_spl < e_lag < e_lin, (e_spl, e_lag, e_lin)


# ---------------------------------------------------------------------------
# Prefilter
# ---------------------------------------------------------------------------


def test_prefilter_taps_sum_and_symmetry():
    taps = ref.prefilter_taps()
    assert taps[7] == max(taps)  # center dominates
    np.testing.assert_allclose(taps, taps[::-1], rtol=1e-6)  # symmetric
    np.testing.assert_allclose(np.sum(taps), 1.0 / ((4 + 2) / 6), rtol=1e-6)


def test_prefilter_close_to_exact():
    n = 16
    f = jnp.asarray(band_limited_field(np.random.default_rng(16), n))
    approx = ref.prefilter(f)
    exact = ref.prefilter_exact(f)
    err = float(jnp.max(jnp.abs(approx - exact))) / float(jnp.max(jnp.abs(exact)))
    assert err < 5e-3, err


def test_prefilter_pallas_matches_ref():
    n = 16
    f = rand_field(17, n)
    np.testing.assert_allclose(interp.prefilter(f), ref.prefilter(f), atol=1e-5)


# ---------------------------------------------------------------------------
# Hypothesis sweeps
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    n=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
    scale=st.floats(0.1, 10.0),
)
def test_hyp_interp_linear_within_data_range(n, seed, scale):
    """Trilinear interpolation never overshoots the data range."""
    r = np.random.default_rng(seed)
    f = jnp.asarray((r.standard_normal((n, n, n)) * scale).astype(np.float32))
    q = jnp.asarray(r.uniform(-2 * n, 2 * n, (3, 1024)).astype(np.float32))
    out = ref.interp_linear(f, q)
    assert float(jnp.min(out)) >= float(jnp.min(f)) - 1e-4 * scale
    assert float(jnp.max(out)) <= float(jnp.max(f)) + 1e-4 * scale


@settings(max_examples=8, deadline=None)
@given(n=st.sampled_from([8, 16]), seed=st.integers(0, 2**16), axis=st.integers(0, 2))
def test_hyp_fd8_antisymmetry(n, seed, axis):
    """FD8 anticommutes with axis reversal: d(flip f) = -flip(d f)."""
    r = np.random.default_rng(seed)
    f = jnp.asarray(r.standard_normal((n, n, n)).astype(np.float32))
    h = 2 * np.pi / n
    d = ref.fd8_partial(f, axis, h)
    dr = ref.fd8_partial(jnp.flip(f, axis=axis), axis, h)
    np.testing.assert_allclose(dr, -jnp.flip(d, axis=axis), atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_hyp_pallas_interp_agrees_on_random_queries(seed):
    n = 8
    r = np.random.default_rng(seed)
    f = jnp.asarray(r.standard_normal((n, n, n)).astype(np.float32))
    q = jnp.asarray(r.uniform(-n, 2 * n, (3, 512)).astype(np.float32))
    np.testing.assert_allclose(interp.linear(f, q), ref.interp_linear(f, q), atol=1e-5)
    np.testing.assert_allclose(
        interp.cubic_lagrange(f, q), ref.interp_cubic_lagrange(f, q), atol=1e-5
    )
