"""L2 model correctness: transport invariants, adjoint/gradient
consistency, Gauss-Newton Hessian structure, preconditioner and spectral
operator identities. These are the tests that make the registration solver
trustworthy; the Rust integration tests then verify the same operators
*through the artifacts*.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import spectral

from .conftest import band_limited_field

N = 16


def _unit_velocity(r, scale):
    """Band-limited velocity normalized to a max amplitude.

    Unnormalized draws can stack to |v| ~ 1 with |div v| ~ 3, producing
    non-diffeomorphic unit-time maps (det F < 0) — outside the regime any
    of the consistency identities below are meant to hold in.
    """
    v = np.stack([band_limited_field(r, N) for _ in range(3)])
    v *= scale / np.abs(v).max()
    return jnp.asarray(v.astype(np.float32))


@pytest.fixture(scope="module")
def fields():
    r = np.random.default_rng(0xA11CE)
    m0 = jnp.asarray(band_limited_field(r, N) * 0.5 + 1.0)
    m1 = jnp.asarray(band_limited_field(r, N) * 0.5 + 1.0)
    v = _unit_velocity(r, 0.3)
    vt = _unit_velocity(r, 0.3)
    return m0, m1, v, vt


def prob(variant="ref-fft-cubic", **kw):
    return model.Problem(n=N, variant=variant, **kw)


BG = jnp.asarray([1e-2, 1e-3], jnp.float32)


# ---------------------------------------------------------------------------
# Transport
# ---------------------------------------------------------------------------


def test_transport_zero_velocity_is_identity(fields):
    m0, *_ = fields
    p = prob()
    (out,) = model.build_transport(p)(jnp.zeros((3, N, N, N), jnp.float32), m0)
    np.testing.assert_allclose(out, m0, atol=1e-6)


def test_transport_constant_field_invariant(fields):
    *_, v, _ = fields
    p = prob()
    c = jnp.full((N, N, N), 2.5, jnp.float32)
    (out,) = model.build_transport(p)(v, c)
    np.testing.assert_allclose(out, c, atol=1e-4)


def test_transport_forward_backward_roundtrip(fields):
    # Paper Table 3's experiment: advect forward then backward, compare.
    m0, _, v, _ = fields
    p = prob()
    tr = model.build_transport(p)
    (fwd,) = tr(v, m0)
    (back,) = tr(-v, fwd)
    rel = float(jnp.linalg.norm(back - m0) / jnp.linalg.norm(m0))
    assert rel < 0.15, rel


@pytest.mark.parametrize("variant", list(model.VARIANTS))
def test_transport_all_variants_close(fields, variant):
    # All kernel variants must transport to within interpolation accuracy.
    m0, _, v, _ = fields
    p_ref = prob()
    p_var = prob(variant=variant)
    (a,) = model.build_transport(p_ref)(v, m0)
    (b,) = model.build_transport(p_var)(v, m0)
    rel = float(jnp.linalg.norm(a - b) / jnp.linalg.norm(a))
    # Cubic variants within 7%; the bf16 trilinear texture analog trades
    # accuracy for speed (paper Table 4: TXTLIN ~5x worse) — allow 10%.
    tol = 0.10 if variant == 'opt-fd8-linear' else 0.07
    assert rel < tol, (variant, rel)


def test_translation_transport_shifts_image():
    # Constant velocity translates: m(1, x) = m0(x - v) for div-free const v.
    p = prob()
    x = np.linspace(0, 2 * np.pi, N, endpoint=False)
    X = np.meshgrid(x, x, x, indexing="ij")
    m0 = jnp.asarray(np.sin(X[0]).astype(np.float32))
    shift = 2 * np.pi / N * 2  # two grid cells
    v = jnp.zeros((3, N, N, N), jnp.float32).at[0].set(shift)
    (out,) = model.build_transport(p)(v, m0)
    want = np.sin(X[0] - shift)
    # Half-cell interp offsets per step: cubic error ~ h^4 * max|f_xxxx|.
    np.testing.assert_allclose(out, want, atol=5e-3)


# ---------------------------------------------------------------------------
# Objective / gradient / Hessian consistency
# ---------------------------------------------------------------------------


def test_objective_scalars_consistent(fields):
    m0, m1, v, _ = fields
    p = prob()
    (s,) = model.build_objective(p)(v, m0, m1, BG)
    j, msq, reg = (float(x) for x in s)
    assert abs(j - (0.5 * msq + reg)) < 1e-5 * max(1.0, j)
    assert msq >= 0 and reg >= 0


def test_newton_setup_matches_objective(fields):
    m0, m1, v, _ = fields
    p = prob()
    _, _, _, _, _, s1 = model.build_newton_setup(p)(v, m0, m1, BG)
    (s2,) = model.build_objective(p)(v, m0, m1, BG)
    np.testing.assert_allclose(s1, s2, rtol=1e-5)


def test_gradient_directional_derivative_at_zero(fields):
    # At v = 0 the transport is the identity and the reduced gradient has
    # the closed form (m1 - m0) grad(m0): the FD check must be tight.
    m0, m1, _, vt = fields
    p = prob()
    setup = model.build_newton_setup(p)
    obj = model.build_objective(p)
    v0 = jnp.zeros((3, N, N, N), jnp.float32)
    g = setup(v0, m0, m1, BG)[0]
    h3 = p.h**3
    gd = float(jnp.sum(g * vt)) * h3
    eps = 1e-2
    jp = float(obj(v0 + eps * vt, m0, m1, BG)[0][0])
    jm = float(obj(v0 - eps * vt, m0, m1, BG)[0][0])
    fd = (jp - jm) / (2 * eps)
    rel = abs(fd - gd) / abs(fd)
    assert rel < 0.05, rel


def test_gradient_descends_objective(fields):
    # At finite deformation the continuous-adjoint gradient is *inexact*
    # (CLAIRE's choice too: the discrete forward and the discretized
    # adjoint are not exact transposes; the mismatch grows with |v| and
    # div v). What Gauss-Newton needs is that -g is a descent direction
    # and that the inexactness shrinks with the deformation.
    m0, m1, v, _ = fields
    p = prob()
    setup = model.build_newton_setup(p)
    obj = model.build_objective(p)
    h3 = p.h**3
    j0 = float(obj(v, m0, m1, BG)[0][0])
    g = setup(v, m0, m1, BG)[0]
    gnorm2 = float(jnp.sum(g * g)) * h3
    step = 1e-2 / np.sqrt(gnorm2)
    j1 = float(obj(v - np.float32(step) * g, m0, m1, BG)[0][0])
    assert j1 < j0, (j1, j0)
    # FD-vs-analytic relative error decreases as the deformation shrinks.
    def rel_err(scale):
        vs = v * scale
        gs = setup(vs, m0, m1, BG)[0]
        gd = float(jnp.sum(gs * gs)) * h3  # directional derivative along g
        e = 1e-2
        d = gs / np.float32(np.sqrt(float(jnp.sum(gs * gs)) * h3))
        gd = float(jnp.sum(gs * d)) * h3
        jp = float(obj(vs + e * d, m0, m1, BG)[0][0])
        jm = float(obj(vs - e * d, m0, m1, BG)[0][0])
        fd = (jp - jm) / (2 * e)
        return abs(fd - gd) / abs(fd)
    assert rel_err(0.1) < 0.2, rel_err(0.1)


def test_gradient_zero_at_identical_images(fields):
    m0, *_ = fields
    p = prob()
    v0 = jnp.zeros((3, N, N, N), jnp.float32)
    g = model.build_newton_setup(p)(v0, m0, m0, BG)[0]
    assert float(jnp.max(jnp.abs(g))) < 1e-5


def test_gauss_newton_hessian_psd_and_data_term(fields):
    m0, m1, v, vt = fields
    p = prob()
    bg0 = jnp.asarray([0.0, 0.0], jnp.float32)  # isolate the data term
    setup = model.build_newton_setup(p)
    hmv = model.build_hess_matvec(p)
    _, m_traj, yb, yf, divv, _ = setup(v, m0, m1, bg0)
    (hv,) = hmv(vt, m_traj, yb, yf, divv, bg0)
    h3 = p.h**3
    quad = float(jnp.sum(hv * vt)) * h3
    assert quad > 0
    # Data term equals || mt(1) ||^2 with mt(1) from FD of the state solve.
    tr = model.build_transport(p)
    eps = 1e-3
    (mp,) = tr(v + eps * vt, m0)
    (mm,) = tr(v - eps * vt, m0)
    mt1 = (mp - mm) / (2 * eps)
    want = float(jnp.sum(mt1 * mt1)) * h3
    assert abs(quad - want) / want < 0.1, (quad, want)


def test_hessian_approximately_symmetric(fields):
    m0, m1, v, vt = fields
    r = np.random.default_rng(77)
    u = jnp.asarray(np.stack([band_limited_field(r, N) for _ in range(3)]) * 0.3)
    p = prob()
    setup = model.build_newton_setup(p)
    hmv = model.build_hess_matvec(p)
    _, m_traj, yb, yf, divv, _ = setup(v, m0, m1, BG)
    (hv,) = hmv(vt, m_traj, yb, yf, divv, BG)
    (hu,) = hmv(u, m_traj, yb, yf, divv, BG)
    a = float(jnp.sum(hu * vt))
    b = float(jnp.sum(hv * u))
    assert abs(a - b) / max(abs(a), abs(b)) < 0.15


def test_hessian_reduces_to_reg_on_constant_image():
    # With a *constant* image the data term of the GN Hessian vanishes
    # identically (grad m = 0), so H must equal the regularization alone.
    # (Zero *mismatch* with a non-constant image does NOT suffice: J'J is
    # the squared linearized-residual operator and is nonzero there.)
    p = prob()
    c = jnp.full((N, N, N), 1.0, jnp.float32)
    v0 = jnp.zeros((3, N, N, N), jnp.float32)
    r = np.random.default_rng(78)
    vt = jnp.asarray(np.stack([band_limited_field(r, N) for _ in range(3)]))
    setup = model.build_newton_setup(p)
    hmv = model.build_hess_matvec(p)
    _, m_traj, yb, yf, divv, _ = setup(v0, c, c, BG)
    (hv,) = hmv(vt, m_traj, yb, yf, divv, BG)
    want = spectral.reg_apply(vt, BG[0], BG[1])
    np.testing.assert_allclose(hv, want, atol=2e-4)


# ---------------------------------------------------------------------------
# Spectral operators
# ---------------------------------------------------------------------------


def test_precond_inverts_reg_apply(fields):
    *_, v, _ = fields
    beta, gamma = 1e-2, 1e-3
    av = spectral.reg_apply(v, beta, gamma)
    back = spectral.precond_apply(av, beta, gamma)
    # Identity up to the zero mode (where reg_apply annihilates constants).
    vm = v - jnp.mean(v, axis=(1, 2, 3), keepdims=True)
    np.testing.assert_allclose(back, vm, atol=1e-4)


def test_leray_projection_kills_divergence(fields):
    *_, v, _ = fields
    from compile.kernels import ref

    w = spectral.leray(v)
    div_w = ref.fft_div(w, 2 * np.pi / N)
    assert float(jnp.max(jnp.abs(div_w))) < 1e-4
    # Idempotent.
    w2 = spectral.leray(w)
    np.testing.assert_allclose(w, w2, atol=1e-5)


def test_leray_kills_divergence_of_white_noise():
    # White noise has Nyquist content: the projection must use the same
    # wavenumber convention as the discrete divergence (regression test).
    from compile.kernels import ref

    r = np.random.default_rng(99)
    v = jnp.asarray(r.standard_normal((3, N, N, N)).astype(np.float32))
    w = spectral.leray(v)
    div_w = ref.fft_div(w, 2 * np.pi / N)
    div_v = ref.fft_div(v, 2 * np.pi / N)
    assert float(jnp.linalg.norm(div_w)) < 1e-4 * float(jnp.linalg.norm(div_v))


def test_reg_energy_is_quadratic_form(fields):
    *_, v, _ = fields
    beta, gamma, h = 1e-2, 1e-3, 2 * np.pi / N
    e = float(spectral.reg_energy(v, beta, gamma, h))
    av = spectral.reg_apply(v, beta, gamma)
    e2 = 0.5 * float(jnp.sum(av * v)) * h**3
    assert abs(e - e2) / abs(e) < 1e-5
    # Scaling: E(2v) = 4 E(v).
    e4 = float(spectral.reg_energy(2.0 * v, beta, gamma, h))
    assert abs(e4 - 4 * e) / e4 < 1e-5


def test_gauss_smooth_preserves_mean_and_smooths(fields):
    m0, *_ = fields
    sm = spectral.gauss_smooth(m0, 1.0)
    assert abs(float(jnp.mean(sm) - jnp.mean(m0))) < 1e-6
    # High-frequency content decreases.
    from compile.kernels import ref

    g_orig = ref.fft_grad(m0, 2 * np.pi / N)
    g_sm = ref.fft_grad(sm, 2 * np.pi / N)
    assert float(jnp.linalg.norm(g_sm)) < float(jnp.linalg.norm(g_orig))


# ---------------------------------------------------------------------------
# Deformation map / det F
# ---------------------------------------------------------------------------


def test_defmap_zero_velocity_is_identity_map():
    p = prob()
    v0 = jnp.zeros((3, N, N, N), jnp.float32)
    (y,) = model.build_defmap(p)(v0)
    x = model.grid_coords(N).reshape(3, N, N, N)
    np.testing.assert_allclose(y, x, atol=1e-5)


def test_detf_identity_is_one():
    p = prob()
    v0 = jnp.zeros((3, N, N, N), jnp.float32)
    (d,) = model.build_detf(p)(v0)
    np.testing.assert_allclose(d, 1.0, atol=1e-5)


def test_detf_translation_is_one(fields):
    p = prob()
    v = jnp.full((3, N, N, N), 0.3, jnp.float32)
    (d,) = model.build_detf(p)(v)
    np.testing.assert_allclose(d, 1.0, atol=1e-3)


def test_detf_positive_for_smooth_small_velocity(fields):
    *_, v, _ = fields
    p = prob()
    (d,) = model.build_detf(p)(v)
    assert float(jnp.min(d)) > 0.2, float(jnp.min(d))
    assert abs(float(jnp.mean(d)) - 1.0) < 0.1


def test_detf_flags_violent_velocity_as_nondiffeomorphic():
    # An unnormalized strong field must be flagged by det F — the quality
    # metric the paper relies on (Table 7).
    r = np.random.default_rng(0xA11CE)
    _ = band_limited_field(r, N), band_limited_field(r, N)
    v = jnp.asarray(np.stack([band_limited_field(r, N) for _ in range(3)]) * 0.3)
    p = prob()
    (d,) = model.build_detf(p)(v)
    assert float(jnp.min(d)) < 0.2


def test_defmap_consistent_with_transport(fields):
    # m(1) = m0 o y: composing transport should equal sampling m0 at y.
    m0, _, v, _ = fields
    p = prob()
    (mfinal,) = model.build_transport(p)(v, m0)
    (y,) = model.build_defmap(p)(v)
    from compile.kernels import ref

    direct = ref.interp_cubic_lagrange(m0, y.reshape(3, -1)).reshape(N, N, N)
    rel = float(jnp.linalg.norm(mfinal - direct) / jnp.linalg.norm(mfinal))
    # Nt repeated interpolation vs one composed sample: O(h^4) per step.
    assert rel < 0.08, rel


# ---------------------------------------------------------------------------
# Variant structure
# ---------------------------------------------------------------------------


def test_variant_table_complete():
    assert set(model.VARIANTS) == {
        "ref-fft-cubic",
        "opt-fft-cubic",
        "opt-fd8-cubic",
        "opt-fd8-linear",
    }
    v = model.VARIANTS["opt-fd8-linear"]
    assert v.deriv == "fd8" and v.interp == "linbf16" and v.impl == "pallas"
    assert model.VARIANTS["ref-fft-cubic"].impl == "jnp"


def test_complexity_counts_scale_with_nt():
    c4 = model.complexity(model.Problem(n=8, nt=4))
    c8 = model.complexity(model.Problem(n=8, nt=8))
    assert c8["hess_matvec"]["ips"] == 2 * c4["hess_matvec"]["ips"]
    assert c8["newton_setup"]["first"] > c4["newton_setup"]["first"]
    # Regularization FFT counts are Nt-independent.
    assert c8["hess_matvec"]["fft_other"] == c4["hess_matvec"]["fft_other"]
