"""Mixed-precision policy tests: fp16-storage kernels against their f32
siblings and oracles, the mixed hess_matvec against the full one, and the
aot-level dtype/manifest plumbing."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import fd8, interp, ref, spectral

from .conftest import band_limited_field

N = 16
# f16 has a 10-bit mantissa: storage eps ~ 2^-11 per value; a handful of
# rounded loads/weights per output keeps errors within a few eps.
F16_TOL = 5e-3


@pytest.fixture(scope="module")
def field(rng):
    return jnp.asarray(band_limited_field(rng, N))


@pytest.fixture(scope="module")
def queries(rng):
    q = rng.uniform(-N, 2 * N, size=(3, N * N * N)).astype(np.float32)
    return jnp.asarray(q)


def test_linear_f16_close_to_f32_and_matches_oracle(field, queries):
    full = interp.linear(field, queries)
    reduced = interp.linear_f16(field, queries)
    oracle = ref.interp_linear_f16(field, queries)
    assert reduced.dtype == jnp.float32
    err = float(jnp.max(jnp.abs(reduced - full)))
    assert 0 < err < F16_TOL, f"f16 trilinear err {err}"
    # Pallas kernel and jnp oracle implement the same storage rounding.
    assert float(jnp.max(jnp.abs(reduced - oracle))) < 1e-6


def test_bspline_f16_close_to_f32(field, queries):
    coeff = interp.prefilter(field)
    full = interp.cubic_bspline(coeff, queries)
    reduced = interp.cubic_bspline_f16(coeff, queries)
    err = float(jnp.max(jnp.abs(reduced - full)))
    assert 0 < err < 4 * F16_TOL, f"f16 B-spline err {err}"
    oracle = ref.interp_cubic_bspline_f16(coeff, queries)
    assert float(jnp.max(jnp.abs(reduced - oracle))) < 1e-6


def test_fd8_f16_storage_tracks_f32(field):
    p = model.Problem(n=N)
    full = fd8.grad(field, p.h)
    reduced = fd8.grad(field, p.h, storage=jnp.float16)
    assert reduced.dtype == jnp.float32
    rel = float(
        jnp.linalg.norm((reduced - full).ravel()) / jnp.linalg.norm(full.ravel())
    )
    assert 0 < rel < 5e-3, f"f16 FD8 rel {rel}"
    oracle = ref.fd8_grad(field, p.h, storage=jnp.float16)
    assert float(jnp.max(jnp.abs(reduced - oracle))) < 1e-5


def test_spectral_ops_pin_f32(field):
    v = jnp.stack([field, field, field]).astype(jnp.float16)
    out = spectral.reg_apply(v, 1e-2, 1e-3)
    assert out.dtype == jnp.float32
    assert spectral.precond_apply(v, 1e-2, 1e-3).dtype == jnp.float32
    assert spectral.leray(v).dtype == jnp.float32


def _setup_caches(p, rng):
    """Run newton_setup at full precision (the solver's split) and return
    the caches a hess_matvec consumes."""
    m0 = jnp.asarray(band_limited_field(rng, p.n)) * 0.5 + 0.5
    m1 = jnp.asarray(band_limited_field(rng, p.n)) * 0.5 + 0.5
    v = jnp.asarray(
        np.stack([band_limited_field(rng, p.n) for _ in range(3)]) * 0.1
    )
    bg = jnp.asarray([p.beta, p.gamma], jnp.float32)
    setup = model.build_newton_setup(p)
    _, m_traj, yb, yf, divv, _ = setup(v, m0, m1, bg)
    return v, m_traj, yb, yf, divv, bg


def test_mixed_hess_matvec_close_to_full(rng):
    nt = 2
    full_p = model.Problem(n=N, nt=nt)
    mixed_p = model.Problem(n=N, nt=nt, precision="mixed")
    v, m_traj, yb, yf, divv, bg = _setup_caches(full_p, rng)
    vt = jnp.asarray(np.stack([band_limited_field(rng, N) for _ in range(3)]) * 0.1)

    (hv_full,) = model.build_hess_matvec(full_p)(vt, m_traj, yb, yf, divv, bg)
    # Mixed consumes the caches as the artifact would: f16 field values.
    (hv_mixed,) = model.build_hess_matvec(mixed_p)(
        vt,
        m_traj.astype(jnp.float16),
        yb,
        yf,
        divv.astype(jnp.float16),
        bg,
    )
    assert hv_mixed.dtype == jnp.float32
    rel = float(
        jnp.linalg.norm((hv_mixed - hv_full).ravel())
        / jnp.linalg.norm(hv_full.ravel())
    )
    assert 0 < rel < 5e-2, f"mixed matvec drifted: rel {rel}"
    # The Gauss-Newton operator must stay positive on the test direction
    # under reduced precision (PCG relies on it).
    h3 = np.float32(full_p.h**3)
    curv = float(jnp.sum(vt * hv_mixed) * h3)
    assert curv > 0.0


def test_mixed_op_defs_declare_f16_caches():
    p = model.Problem(n=8, precision="mixed")
    defs = aot.mixed_op_defs(p)
    assert [o.name for o in defs] == ["hess_matvec"]
    sig = {nm: s for nm, s in defs[0].inputs}
    assert sig["vt"].dtype == jnp.float32  # PCG vector stays f32
    assert sig["m_traj"].dtype == jnp.float16
    assert sig["divv"].dtype == jnp.float16
    # Query coordinates stay f32 (absolute positions; see mixed_op_defs).
    assert sig["yb"].dtype == jnp.float32
    assert sig["yf"].dtype == jnp.float32


def test_dtype_tags_roundtrip():
    assert aot.dtype_tag(np.float32) == "f32"
    assert aot.dtype_tag(jnp.float16) == "f16"
    assert aot.dtype_tag(jnp.bfloat16) == "bf16"
    with pytest.raises(ValueError):
        aot.dtype_tag(np.float64)


def test_problem_rejects_unknown_precision():
    with pytest.raises(AssertionError):
        model.Problem(n=8, precision="fp8")
