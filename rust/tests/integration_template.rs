//! End-to-end template building against a live daemon over the real
//! wire protocol: convergence of the round loop (strictly decreasing
//! template drift under a contractive stub executor), the journaled
//! kill/restart contract (a rebuilt driver resumes at the last
//! completed round, and resubmitted rounds dedup to the original job
//! ids), and warm starts (round 2+ solves report fewer iterations).

use std::sync::Arc;

use claire::error::Result;
use claire::serve::{
    scheduler::stub_report, Client, Daemon, DaemonConfig, DaemonHandle, ExecOutcome, Executor,
    ExecutorFactory, JobPayload, ReduceField, VolumeStore,
};
use claire::template::{TemplateConfig, TemplateDriver};

/// Template-loop stub: warps the fixed image toward the moving one,
/// `warped = m0 + alpha * (m1 - m0)`, with a per-subject `alpha` read
/// off the subject's first voxel. The warped-image mean update is then
/// `t' = t + mean(alpha_i * (s_i - t))`, a contraction toward the
/// alpha-weighted subject blend — which differs from the round-0
/// bootstrap (the plain mean), so the loop has real work to do and the
/// drift shrinks geometrically by `1 - mean(alpha)` per round.
///
/// With `velocity` set, it also retains a constant velocity field and
/// reports 10 solver iterations cold versus 3 warm-started — the
/// telemetry the warm-start acceptance checks.
struct BlendExec {
    store: Option<Arc<VolumeStore>>,
    velocity: bool,
}

impl Executor for BlendExec {
    fn attach_store(&mut self, store: Arc<VolumeStore>) {
        self.store = Some(store);
    }

    fn execute(
        &mut self,
        payload: &JobPayload,
        _cx: &claire::registration::SolveCx,
    ) -> Result<ExecOutcome> {
        let JobPayload::Volumes { spec, m0, m1, warm_start } = payload else {
            return Ok(stub_report("synthetic").into());
        };
        let store = self.store.as_ref().expect("daemon attaches its store");
        let alpha = 0.25 + 0.5 * m1.data[0].clamp(0.0, 1.0);
        let warped: Vec<f32> =
            m0.data.iter().zip(&m1.data).map(|(t, s)| t + alpha * (s - t)).collect();
        let wrec = store.put(spec.n, warped)?;
        let mut report = stub_report(&spec.name());
        report.iters = if warm_start.is_some() { 3 } else { 10 };
        let mut out = ExecOutcome::from(report);
        out.warped = Some(wrec.id);
        if self.velocity {
            // A small constant velocity keyed off the subject, so the
            // log-domain mean is a nonzero constant field (exact
            // translation under the exponential — groupwise's pinned
            // contract) and round templates keep changing.
            let n = spec.n;
            let c = 0.02 * (0.5 + m1.data[0]);
            let vrec = store.put_vec(n, vec![c; 3 * n * n * n])?;
            out.velocity = Some(vrec.id);
        }
        Ok(out)
    }
}

fn blend_factory(velocity: bool) -> ExecutorFactory {
    Arc::new(move |_w| Ok(Box::new(BlendExec { store: None, velocity }) as Box<dyn Executor>))
}

fn start_daemon(velocity: bool) -> DaemonHandle {
    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        queue_cap: 32,
        journal: None,
        ..Default::default()
    };
    Daemon::start(cfg, blend_factory(velocity)).unwrap()
}

fn connect_v2(addr: &str) -> Client {
    let mut c = Client::connect(addr).unwrap();
    c.hello().unwrap();
    c
}

/// Four 16^3 subjects whose first voxel encodes distinct blend weights.
fn upload_subjects(client: &mut Client, n: usize) -> Vec<String> {
    (0..4u32)
        .map(|i| {
            let mut data: Vec<f32> =
                (0..n * n * n).map(|v| ((v as f32 * 0.37 + i as f32).sin() + 1.0) * 0.5).collect();
            data[0] = i as f32 / 4.0;
            client.upload(n, &data).unwrap().id
        })
        .collect()
}

fn tmp_state(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("claire_template_itest");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join(name);
    let _ = std::fs::remove_file(&p);
    p
}

/// The convergence acceptance scenario: a template build over 4 uploaded
/// subjects reaches tolerance within budget with a *strictly decreasing*
/// residual, entirely server-side (warped-mean fallback — the stub
/// retains no velocities here).
#[test]
fn template_build_converges_with_decreasing_residual() {
    let handle = start_daemon(false);
    let addr = handle.addr().to_string();
    let mut client = connect_v2(&addr);
    let subjects = upload_subjects(&mut client, 16);

    let cfg = TemplateConfig { rounds: 10, tol: 2e-3, ..Default::default() };
    let mut driver = TemplateDriver::new(connect_v2(&addr), subjects, cfg).unwrap();
    let t0 = driver.template().to_string();
    let outcomes = driver.run(|_| {}).unwrap();

    assert!(outcomes.len() >= 3, "contraction ratio ~0.5 needs several rounds: {outcomes:?}");
    assert!(outcomes.len() < 10, "must converge inside the budget");
    assert!(outcomes.last().unwrap().converged);
    let deltas: Vec<f64> = outcomes.iter().map(|o| o.delta_rel.unwrap()).collect();
    for w in deltas.windows(2) {
        assert!(w[1] < w[0], "residual must strictly decrease: {deltas:?}");
    }
    for o in &outcomes {
        assert_eq!(o.field, ReduceField::Warped, "no velocities retained => warped fallback");
        assert_eq!(o.jobs.len(), 4);
    }
    // The template moved off the round-0 bootstrap and each round's id is
    // a fresh pinned volume; exactly one pin remains at the end (the
    // final template — intermediates were handed back round by round).
    assert_ne!(driver.template(), t0);
    let stats = client.wait_idle(10.0).unwrap();
    assert_eq!(stats.store.pinned, 1, "only the final template stays pinned: {stats:?}");

    client.shutdown(true).unwrap();
    handle.join().unwrap();
}

/// The kill/restart acceptance scenario, against one live daemon:
///
/// 1. driver A (round budget 1) completes round 1 and is dropped;
/// 2. driver B resumes from the journal — same run id, same template,
///    next round 2 — and runs round 2 with warm starts (3 iters vs 10);
/// 3. driver C resumes from a copy of the journal *truncated to round 1*
///    (a driver killed after round 2's submits but before its journal
///    append): re-running round 2 dedups to B's exact job ids and
///    reduces to B's exact template — the round is exactly-once.
#[test]
fn template_driver_restarts_at_last_completed_round() {
    let handle = start_daemon(true);
    let addr = handle.addr().to_string();
    let mut client = connect_v2(&addr);
    let subjects = upload_subjects(&mut client, 16);
    let state = tmp_state("restart.ndjson");

    // Driver A: exactly one round, then "killed" (dropped).
    let cfg_a = TemplateConfig {
        rounds: 1,
        tol: 0.0, // never converge: every delta is > 0 under the stub
        state: Some(state.clone()),
        ..Default::default()
    };
    let mut a = TemplateDriver::new(connect_v2(&addr), subjects.clone(), cfg_a).unwrap();
    let a_out = a.run(|_| {}).unwrap();
    assert_eq!(a_out.len(), 1);
    assert_eq!(a_out[0].field, ReduceField::Velocity, "stub retains velocities here");
    assert!(a_out[0].iters.iter().all(|i| *i == Some(10)), "round 1 is cold: {a_out:?}");
    let run_id = a.state().run_id.clone();
    let t1 = a.template().to_string();
    drop(a);

    // Driver B: resumes (empty subject list adopts the journaled set).
    let cfg_b = TemplateConfig {
        rounds: 2,
        tol: 0.0,
        state: Some(state.clone()),
        ..Default::default()
    };
    let mut b = TemplateDriver::new(connect_v2(&addr), Vec::new(), cfg_b.clone()).unwrap();
    assert_eq!(b.state().run_id, run_id, "resume keeps the run identity");
    assert_eq!(b.state().subjects, subjects, "subjects adopted from the journal");
    assert_eq!(b.template(), t1, "resume points at the last completed round's template");
    assert_eq!(b.state().next_round(), 2);
    assert_eq!(b.rounds_remaining(), 1, "budget counts the resumed round");

    // Mismatched subjects are refused rather than silently rebuilt.
    let err = TemplateDriver::new(
        connect_v2(&addr),
        vec!["deadbeef".into()],
        cfg_b.clone(),
    )
    .unwrap_err();
    assert!(err.to_string().contains("same --subjects"), "{err}");

    let b2 = b.run_round().unwrap();
    assert_eq!(b2.round, 2);
    assert!(
        b2.iters.iter().all(|i| *i == Some(3)),
        "round 2 warm-starts from round 1's velocities: {b2:?}"
    );

    // Driver C: journal truncated to round 1 — the post-submit,
    // pre-journal crash window. Its round 2 must be the same round.
    let text = std::fs::read_to_string(&state).unwrap();
    let torn = tmp_state("restart_torn.ndjson");
    let keep: Vec<&str> = text.lines().take(2).collect(); // init + round 1
    std::fs::write(&torn, format!("{}\n", keep.join("\n"))).unwrap();
    let cfg_c = TemplateConfig { rounds: 2, tol: 0.0, state: Some(torn), ..Default::default() };
    let mut c = TemplateDriver::new(connect_v2(&addr), Vec::new(), cfg_c).unwrap();
    assert_eq!(c.state().next_round(), 2, "torn journal resumes at the lost round");
    let c2 = c.run_round().unwrap();
    assert_eq!(c2.jobs, b2.jobs, "per-(run,round,subject) dedup tokens: no re-solve");
    assert_eq!(c2.template, b2.template, "content-addressed reduce replays to the same id");

    client.shutdown(true).unwrap();
    handle.join().unwrap();
}
