//! Journal crash-safety properties (via the in-tree `util/prop.rs`
//! mini-framework): replay of a journal with a torn, truncated, or
//! interleaved tail — the on-disk states a daemon killed mid-write can
//! leave behind — never panics and never loses a fully-written line
//! other than (at most) the one the tear landed on; `max_id` is
//! monotone over appends; `completed_count` matches the surviving
//! prefix.

use std::fs::OpenOptions;
use std::io::Write;
use std::path::PathBuf;

use claire::serve::scheduler::{JobEvent, JobState};
use claire::serve::{Journal, JournalEntry, Priority};
use claire::util::prop::{self, Config};
use claire::util::rng::Rng;

fn tmp(case: u64) -> PathBuf {
    let dir = std::env::temp_dir().join("claire_prop_journal");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("case_{}_{case}.ndjson", std::process::id()))
}

/// A journaled event plus the entry replay should reconstruct from it.
fn gen_event(r: &mut Rng, id: u64) -> (JobEvent, JournalEntry) {
    let name = format!("na{:02}-{id}", r.below(30));
    match r.below(4) {
        0 => {
            let dedup = if r.below(2) == 0 { Some(format!("tok{}", r.below(1000))) } else { None };
            let ev = JobEvent::Submitted {
                id,
                name: name.clone(),
                priority: Priority::Normal,
                dedup: dedup.clone(),
            };
            let want = JournalEntry { event: "submitted".into(), id, name, unix_s: 0.0, dedup };
            (ev, want)
        }
        1 => {
            let ev = JobEvent::Cancelled { id, name: name.clone() };
            let want = JournalEntry { event: "cancelled".into(), id, name, unix_s: 0.0, dedup: None };
            (ev, want)
        }
        k => {
            let state = if k == 2 { JobState::Done } else { JobState::Failed };
            let ev = JobEvent::Finished {
                id,
                name: name.clone(),
                state,
                wall_s: r.uniform_in(0.0, 10.0),
                error: None,
            };
            let event = if state == JobState::Done { "done" } else { "failed" };
            let want = JournalEntry { event: event.into(), id, name, unix_s: 0.0, dedup: None };
            (ev, want)
        }
    }
}

/// Random non-line-shaped tail damage: a torn JSON prefix, raw bytes
/// including invalid UTF-8, an interleaved half-line, or empty lines.
/// None of these can form a complete valid journal line.
fn garbage(r: &mut Rng) -> Vec<u8> {
    match r.below(4) {
        0 => {
            // Torn write: a valid-looking line cut mid-object (and
            // possibly mid-UTF-8: the name holds a multi-byte char).
            let line = format!(r#"{{"event":"done","id":{},"name":"μtorn"#, r.below(100));
            let cut = 1 + r.below(line.len() as u64 - 1) as usize;
            line.as_bytes()[..cut].to_vec()
        }
        1 => {
            // Raw bytes, deliberately invalid UTF-8.
            let mut b = vec![0xC3, 0x28, 0xFF, 0xFE];
            for _ in 0..r.below(16) {
                b.push((r.next_u64() & 0xFF) as u8);
            }
            b
        }
        2 => {
            // Interleaved writers: two half-lines sharing one line.
            let a = r#"{"event":"submitted","id":7,"#;
            let b = r#""name":"x"}{"event":"done""#;
            format!("{a}{b}").into_bytes()
        }
        _ => b"\n\n   \n".to_vec(),
    }
}

fn entry_key(e: &JournalEntry) -> (String, u64, String, Option<String>) {
    (e.event.clone(), e.id, e.name.clone(), e.dedup.clone())
}

#[test]
fn replay_survives_torn_tails_and_max_id_is_monotone() {
    let mut case_no = 0u64;
    prop::check_msg(
        Config { cases: 96, ..Config::default() },
        |r| {
            case_no += 1;
            let k = 1 + r.below(6);
            let events: Vec<_> = (0..k)
                .map(|i| {
                    let id = 1 + i * (1 + r.below(3));
                    gen_event(r, id)
                })
                .collect();
            // 0 = truncate the tail, 1..=2 = append garbage, 3 = both.
            let damage = r.below(4);
            (case_no, events, damage, r.split())
        },
        |(case_no, events, damage, rng)| {
            let path = tmp(*case_no);
            let mut r = rng.clone();
            let journal = Journal::open(&path).map_err(|e| e.to_string())?;

            // max_id is monotone while valid lines are appended.
            let mut prev_max = 0u64;
            for (ev, _) in events {
                journal.append(ev).map_err(|e| e.to_string())?;
                let entries = Journal::replay(&path).map_err(|e| e.to_string())?;
                let max = Journal::max_id(&entries);
                if max < prev_max {
                    return Err(format!("max_id shrank: {prev_max} -> {max}"));
                }
                prev_max = max;
            }

            // Damage the tail the way a crash can: truncate into the last
            // line, then (or instead) append garbage that never forms a
            // complete valid line.
            let valid_len = std::fs::metadata(&path).map_err(|e| e.to_string())?.len();
            if *damage == 0 || *damage == 3 {
                let cut = 1 + r.below(valid_len.min(40));
                OpenOptions::new()
                    .write(true)
                    .open(&path)
                    .and_then(|f| f.set_len(valid_len - cut))
                    .map_err(|e| e.to_string())?;
            }
            if *damage != 0 {
                let mut f =
                    OpenOptions::new().append(true).open(&path).map_err(|e| e.to_string())?;
                for _ in 0..1 + r.below(3) {
                    f.write_all(&garbage(&mut r)).map_err(|e| e.to_string())?;
                }
            }

            // Replay never errors (and, being a plain function under
            // `prop`, a panic fails the whole test run).
            let entries = Journal::replay(&path).map_err(|e| e.to_string())?;
            std::fs::remove_file(&path).ok();

            // The survivors are an in-order prefix of what was written —
            // damage may cost valid lines from the tear point on, plus
            // whatever a truncation chopped, but never reorders, invents,
            // or drops an earlier intact line.
            if entries.len() > events.len() {
                return Err(format!("replayed {} > appended {}", entries.len(), events.len()));
            }
            let min_intact = if *damage == 0 || *damage == 3 {
                // A <= 40-byte truncation cannot reach past the final
                // line (every journal line is longer than 40 bytes), so
                // at most that one line is lost.
                events.len().saturating_sub(1)
            } else {
                events.len()
            };
            if entries.len() < min_intact {
                return Err(format!("replayed {} < {min_intact} intact lines", entries.len()));
            }
            for (got, (_, want)) in entries.iter().zip(events) {
                if entry_key(got) != entry_key(want) {
                    return Err(format!("entry mismatch: got {got:?}, want {want:?}"));
                }
            }
            if Journal::completed_count(&entries)
                != entries.iter().filter(|e| e.event == "done").count() as u64
            {
                return Err("completed_count disagrees with replayed entries".into());
            }
            if Journal::max_id(&entries) > prev_max {
                return Err("max_id exceeds anything ever appended".into());
            }
            Ok(())
        },
    );
}

/// A journal that is *pure* garbage — no valid line at all — replays to
/// an empty history with `max_id` 0 rather than failing startup.
#[test]
fn replay_of_pure_garbage_is_empty() {
    let mut r = Rng::new(0xBAD_F00D);
    let path = tmp(u64::MAX);
    let mut f = OpenOptions::new().create(true).append(true).open(&path).unwrap();
    for _ in 0..8 {
        f.write_all(&garbage(&mut r)).unwrap();
        f.write_all(b"\n").unwrap();
    }
    drop(f);
    let entries = Journal::replay(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(entries.is_empty(), "garbage parsed as entries: {entries:?}");
    assert_eq!(Journal::max_id(&entries), 0);
    assert_eq!(Journal::completed_count(&entries), 0);
}
