//! Property-based wire-protocol tests (via the in-tree `util/prop.rs`
//! mini-framework): randomized `Request`/`Response`/`JobRequest` JSON
//! encode→parse round-trips, v1-subset lines decoded by the v2 parser,
//! and a fuzz pass of invalid lines against a *live* daemon — every one
//! must come back as a structured `bad_request` on a connection that
//! stays usable; none may panic the daemon or drop the peer.

use std::sync::Arc;

use claire::error::Result;
use claire::serve::{
    scheduler::stub_report, Daemon, DaemonConfig, Executor, ExecutorFactory, JobPayload,
    JobRequest, JobSource, Priority, Request, Response,
};
use claire::util::json::Json;
use claire::util::prop::{self, Config};
use claire::util::rng::Rng;
use claire::{ErrorCode, Precision};

fn gen_job_request(r: &mut Rng) -> JobRequest {
    let mut req = JobRequest {
        subject: format!("na{:02}", r.below(30)),
        n: 1 + r.below(512) as usize,
        ..Default::default()
    };
    if r.below(2) == 1 {
        req.variant = "opt-fd8-linear".into();
    }
    if r.below(2) == 1 {
        req.precision = Precision::Mixed;
    }
    req.algorithm = match r.below(4) {
        0 => claire::registration::AlgorithmKind::GradientDescent,
        1 => claire::registration::AlgorithmKind::Lbfgs,
        _ => claire::registration::AlgorithmKind::GaussNewton,
    };
    if r.below(3) == 0 {
        req.source = JobSource::Uploaded {
            m0: format!("{:016x}", r.next_u64()),
            m1: format!("{:016x}", r.next_u64()),
        };
    }
    if r.below(2) == 1 {
        req.multires = Some(1 + r.below(6) as usize);
    }
    req.priority = match r.below(3) {
        0 => Priority::Batch,
        1 => Priority::Urgent,
        _ => Priority::Emergency,
    };
    if r.below(2) == 1 {
        req.max_iter = Some(1 + r.below(200) as usize);
    }
    if r.below(3) == 0 {
        req.max_krylov = Some(1 + r.below(500) as usize);
    }
    if r.below(2) == 1 {
        req.beta = Some((1 + r.below(100_000)) as f64 * 1e-8);
    }
    if r.below(3) == 0 {
        req.gamma = Some(r.below(1000) as f64 * 1e-6);
    }
    if r.below(2) == 1 {
        req.gtol = Some((1 + r.below(1000)) as f64 * 1e-4);
    }
    if r.below(2) == 1 {
        req.continuation = Some(r.below(2) == 1);
    }
    if r.below(3) == 0 {
        req.incompressible = Some(r.below(2) == 1);
    }
    if r.below(3) == 0 {
        req.verbose = Some(r.below(2) == 1);
    }
    if r.below(4) == 0 {
        req.dedup = Some(format!("tok-{:08x}", r.next_u64() as u32));
    }
    req
}

#[test]
fn prop_job_request_json_roundtrip() {
    prop::check_msg(
        Config { cases: 200, seed: 0x11 },
        gen_job_request,
        |req| {
            let decoded = JobRequest::from_json(&req.to_json())
                .map_err(|e| format!("decode failed: {e}"))?;
            if &decoded != req {
                return Err(format!("mismatch: {decoded:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_request_lines_roundtrip_with_seq() {
    prop::check_msg(
        Config { cases: 200, seed: 0x12 },
        |r| {
            let req = match r.below(8) {
                0 => Request::Ping,
                1 => Request::Hello { proto: 1 + r.below(4) },
                2 => Request::Submit(gen_job_request(r)),
                3 => Request::SubmitBatch(
                    (0..1 + r.below(4)).map(|_| gen_job_request(r)).collect(),
                ),
                4 => Request::Status(if r.below(2) == 1 { Some(r.below(1000)) } else { None }),
                5 => Request::Cancel(r.below(1000)),
                6 => Request::Watch,
                _ => Request::Shutdown { drain: r.below(2) == 1 },
            };
            let seq = if r.below(2) == 1 { Some(r.below(1 << 40)) } else { None };
            (req, seq)
        },
        |(req, seq)| {
            let line = req.to_line_with_seq(*seq);
            if line.contains('\n') {
                return Err("line discipline broken".into());
            }
            let (got_seq, parsed) = Request::parse_line(&line);
            if got_seq != *seq {
                return Err(format!("seq mismatch: {got_seq:?} vs {seq:?}"));
            }
            let parsed = parsed.map_err(|e| format!("parse failed: {e} ({line})"))?;
            if &parsed != req {
                return Err(format!("request mismatch: {parsed:?}"));
            }
            Ok(())
        },
    );
}

/// A v1-era client encodes only the original field subset; the v2 parser
/// must decode those lines with identical defaults.
#[test]
fn prop_v1_subset_job_lines_decode_with_defaults() {
    prop::check_msg(
        Config { cases: 100, seed: 0x13 },
        |r| {
            let mut fields = Vec::new();
            if r.below(2) == 1 {
                fields.push(("subject", Json::str(format!("na{:02}", r.below(30)))));
            }
            if r.below(2) == 1 {
                fields.push(("n", Json::num((1 + r.below(256)) as f64)));
            }
            if r.below(2) == 1 {
                fields.push(("priority", Json::str("urgent")));
            }
            if r.below(2) == 1 {
                fields.push(("max_iter", Json::num((1 + r.below(50)) as f64)));
            }
            Json::object(fields).render()
        },
        |line| {
            let req = JobRequest::from_json(&Json::parse(line).unwrap())
                .map_err(|e| format!("v1 subset rejected: {e} ({line})"))?;
            // Absent v2 knobs take the same defaults a v1 JobSpec had.
            if req.multires.is_some() || req.max_krylov.is_some() || req.gamma.is_some() {
                return Err("phantom v2 fields decoded".into());
            }
            if req.dedup.is_some() {
                return Err("phantom dedup token decoded from a v1 line".into());
            }
            if req.precision != Precision::Full || req.source != JobSource::Synthetic {
                return Err("v1 defaults drifted".into());
            }
            req.validate().map_err(|e| format!("v1 subset fails validate: {e}"))?;
            Ok(())
        },
    );
}

#[test]
fn prop_response_error_roundtrip_v1_and_v2() {
    let codes = [
        ErrorCode::BadRequest,
        ErrorCode::QueueFull,
        ErrorCode::ShuttingDown,
        ErrorCode::UnknownJob,
        ErrorCode::UnknownVolume,
        ErrorCode::ShapeMismatch,
        ErrorCode::InvalidState,
        ErrorCode::Internal,
    ];
    prop::check_msg(
        Config { cases: 100, seed: 0x14 },
        |r| {
            let code = codes[r.below(codes.len() as u64) as usize];
            let msg = format!("failure {:x} \"quoted\" \\slash", r.next_u64());
            let seq = if r.below(2) == 1 { Some(r.below(1 << 30)) } else { None };
            (code, msg, seq)
        },
        |(code, msg, seq)| {
            let resp =
                Response::Error { code: *code, retryable: code.retryable(), msg: msg.clone() };
            // v2 line carries the code and echoes seq.
            match Response::parse(&resp.to_line_v2(*seq)) {
                Ok(Response::Error { code: c, retryable, msg: m }) => {
                    if c != *code || retryable != code.retryable() || &m != msg {
                        return Err(format!("v2 roundtrip drifted: {c:?} {retryable} {m}"));
                    }
                }
                other => return Err(format!("v2 parse: {other:?}")),
            }
            // v1 line hides the code but keeps the exact message.
            match Response::parse(&resp.to_line()) {
                Ok(Response::Error { code: c, msg: m, .. }) if m == *msg => {
                    if c != ErrorCode::Internal {
                        return Err(format!("v1 line leaked a code: {c:?}"));
                    }
                }
                other => return Err(format!("v1 parse: {other:?}")),
            }
            Ok(())
        },
    );
}

/// The satellite contract for the `algorithm` field: random tokens (valid
/// spellings, near-misses, junk) must round-trip or be rejected
/// *identically* across the wire decoder, the config-file adapter and the
/// CLI flag surface — one accept set, one error string, one code.
#[test]
fn prop_algorithm_roundtrips_identically_across_wire_config_cli() {
    use claire::config::Config as FileConfig;
    use claire::util::args::{opt, Args, OptSpec};

    fn cli_args(token: &str) -> claire::error::Result<Args> {
        let specs: Vec<OptSpec> = vec![opt("algorithm", "", "gn")];
        Args::parse(vec!["--algorithm".to_string(), token.to_string()], &specs)
    }

    prop::check_msg(
        Config { cases: 150, seed: 0x15 },
        |r| match r.below(4) {
            // Valid spellings and deliberate near-misses...
            0 => ["gn", "gd", "lbfgs"][r.below(3) as usize].to_string(),
            1 => ["GN", "newton", "l-bfgs", "sgd", "adam", "gauss"][r.below(6) as usize]
                .to_string(),
            // ... and random short lowercase tokens.
            _ => {
                let len = 1 + r.below(6) as usize;
                (0..len).map(|_| (b'a' + r.below(26) as u8) as char).collect()
            }
        },
        |token| {
            let wire = JobRequest::from_json(
                &Json::parse(&format!(r#"{{"algorithm":{}}}"#, Json::str(token.as_str()).render()))
                    .unwrap(),
            );
            let cfg = FileConfig::parse(&format!("algorithm = {token}\n"))
                .map_err(|e| format!("config line rejected outright: {e}"))?
                .job_request();
            let args =
                cli_args(token).map_err(|e| format!("flag parse rejected outright: {e}"))?;
            let cli = JobRequest::from_args(&args);
            match (&wire, &cfg) {
                (Ok(w), Ok(c)) => {
                    let a = cli.map_err(|e| format!("cli rejected accepted token: {e}"))?;
                    if w != c || w != &a {
                        return Err(format!("accepted differently: {w:?} vs {c:?} vs {a:?}"));
                    }
                    // And the one validate() path materializes the same
                    // params downstream.
                    let pw = w.validate().map_err(|e| e.to_string())?;
                    if pw.algorithm.as_str() != token.as_str() {
                        return Err(format!("algorithm drifted: {} vs {token}", pw.algorithm));
                    }
                    Ok(())
                }
                (Err(ew), Err(ec)) => {
                    let Err(ea) = cli else {
                        return Err(format!("cli accepted rejected token '{token}'"));
                    };
                    if ew.to_string() != ec.to_string() || ew.to_string() != ea.to_string() {
                        return Err(format!(
                            "rejection drifted: '{ew}' vs '{ec}' vs '{ea}'"
                        ));
                    }
                    if ew.code() != ErrorCode::BadRequest {
                        return Err(format!("rejection must be bad_request, got {:?}", ew.code()));
                    }
                    Ok(())
                }
                (w, c) => Err(format!("surfaces disagree on '{token}': {w:?} vs {c:?}")),
            }
        },
    );
}

/// The coalescing contract, two halves:
///
/// 1. **Soundness** (the one that matters for correctness): two requests
///    with equal `coalesce_key` must materialize identical
///    solver-relevant `RegParams` through the one `validate()` path —
///    coalescing them onto one batched executable changes nothing about
///    either solve. (`verbose` is masked: it drives progress printing,
///    not the solve.)
/// 2. **Surface agreement**: the same solver policy expressed over the
///    wire, in a config file, and as CLI flags produces the same key —
///    so jobs submitted through different front doors still coalesce.
///    Subject, priority, dedup and verbose never split a batch.
#[test]
fn prop_coalesce_key_agrees_with_validated_params_across_surfaces() {
    use claire::config::Config as FileConfig;
    use claire::registration::RegParams;
    use claire::util::args::{flag, opt, Args, OptSpec};

    fn solver_view(p: &RegParams) -> RegParams {
        RegParams { verbose: false, ..p.clone() }
    }

    fn cli_args(raw: Vec<String>) -> Args {
        let specs: Vec<OptSpec> = vec![
            opt("variant", "", "opt-fd8-cubic"),
            opt("precision", "", "full"),
            opt("algorithm", "", "gn"),
            opt("beta", "", "5e-4"),
            opt("gamma", "", "1e-4"),
            opt("gtol", "", "5e-2"),
            opt("max-iter", "", "50"),
            opt("multires", "", "1"),
            flag("no-continuation", ""),
            flag("incompressible", ""),
        ];
        Args::parse(raw, &specs).unwrap()
    }

    /// Solver knobs expressible on every surface (the CLI has no
    /// `--max-krylov` and can only switch continuation *off*).
    #[derive(Debug)]
    struct Knobs {
        variant: Option<&'static str>,
        precision: Option<&'static str>,
        algorithm: Option<&'static str>,
        beta: Option<String>,
        gamma: Option<String>,
        gtol: Option<String>,
        max_iter: Option<usize>,
        multires: Option<usize>,
        no_continuation: bool,
        incompressible: bool,
    }

    fn gen_knobs(r: &mut Rng) -> Knobs {
        Knobs {
            variant: (r.below(2) == 1).then_some("opt-fd8-linear"),
            precision: (r.below(2) == 1).then_some("mixed"),
            algorithm: match r.below(4) {
                0 => Some("gd"),
                1 => Some("lbfgs"),
                2 => Some("gn"),
                _ => None,
            },
            // Decimal strings shared verbatim across surfaces: every
            // parser sees the same text, so every f64 comes out identical.
            beta: (r.below(2) == 1).then(|| format!("{}e-8", 1 + r.below(100_000))),
            gamma: (r.below(2) == 1).then(|| format!("{}e-6", 1 + r.below(999))),
            gtol: (r.below(2) == 1).then(|| format!("{}e-4", 1 + r.below(1000))),
            max_iter: (r.below(2) == 1).then(|| 1 + r.below(200) as usize),
            multires: (r.below(2) == 1).then(|| 1 + r.below(3) as usize),
            no_continuation: r.below(2) == 1,
            incompressible: r.below(2) == 1,
        }
    }

    fn from_all_surfaces(k: &Knobs) -> (JobRequest, JobRequest, JobRequest) {
        // Wire JSON line.
        let mut json = Vec::new();
        if let Some(v) = k.variant {
            json.push(format!(r#""variant":"{v}""#));
        }
        if let Some(v) = k.precision {
            json.push(format!(r#""precision":"{v}""#));
        }
        if let Some(v) = k.algorithm {
            json.push(format!(r#""algorithm":"{v}""#));
        }
        if let Some(v) = &k.beta {
            json.push(format!(r#""beta":{v}"#));
        }
        if let Some(v) = &k.gamma {
            json.push(format!(r#""gamma":{v}"#));
        }
        if let Some(v) = &k.gtol {
            json.push(format!(r#""gtol":{v}"#));
        }
        if let Some(v) = k.max_iter {
            json.push(format!(r#""max_iter":{v}"#));
        }
        if let Some(v) = k.multires {
            json.push(format!(r#""multires":{v}"#));
        }
        if k.no_continuation {
            json.push(r#""continuation":false"#.into());
        }
        if k.incompressible {
            json.push(r#""incompressible":true"#.into());
        }
        let wire =
            JobRequest::from_json(&Json::parse(&format!("{{{}}}", json.join(","))).unwrap())
                .unwrap();

        // Config file.
        let mut text = String::new();
        if let Some(v) = k.variant {
            text.push_str(&format!("variant = {v}\n"));
        }
        if let Some(v) = k.precision {
            text.push_str(&format!("precision = {v}\n"));
        }
        if let Some(v) = k.algorithm {
            text.push_str(&format!("algorithm = {v}\n"));
        }
        if let Some(v) = &k.beta {
            text.push_str(&format!("beta = {v}\n"));
        }
        if let Some(v) = &k.gamma {
            text.push_str(&format!("gamma = {v}\n"));
        }
        if let Some(v) = &k.gtol {
            text.push_str(&format!("gtol = {v}\n"));
        }
        if let Some(v) = k.max_iter {
            text.push_str(&format!("max_iter = {v}\n"));
        }
        if let Some(v) = k.multires {
            text.push_str(&format!("multires = {v}\n"));
        }
        if k.no_continuation {
            text.push_str("continuation = false\n");
        }
        if k.incompressible {
            text.push_str("incompressible = true\n");
        }
        let config = FileConfig::parse(&text).unwrap().job_request().unwrap();

        // CLI flags.
        let mut raw: Vec<String> = Vec::new();
        let mut push_opt = |name: &str, v: String| {
            raw.push(format!("--{name}"));
            raw.push(v);
        };
        if let Some(v) = k.variant {
            push_opt("variant", v.into());
        }
        if let Some(v) = k.precision {
            push_opt("precision", v.into());
        }
        if let Some(v) = k.algorithm {
            push_opt("algorithm", v.into());
        }
        if let Some(v) = &k.beta {
            push_opt("beta", v.clone());
        }
        if let Some(v) = &k.gamma {
            push_opt("gamma", v.clone());
        }
        if let Some(v) = &k.gtol {
            push_opt("gtol", v.clone());
        }
        if let Some(v) = k.max_iter {
            push_opt("max-iter", v.to_string());
        }
        if let Some(v) = k.multires {
            push_opt("multires", v.to_string());
        }
        if k.no_continuation {
            raw.push("--no-continuation".into());
        }
        if k.incompressible {
            raw.push("--incompressible".into());
        }
        let cli = JobRequest::from_args(&cli_args(raw)).unwrap();
        (wire, config, cli)
    }

    prop::check_msg(
        Config { cases: 200, seed: 0x17 },
        |r| (gen_knobs(r), gen_knobs(r)),
        |(ka, kb)| {
            let (wa, ca, fa) = from_all_surfaces(ka);
            let (wb, _, _) = from_all_surfaces(kb);

            // Surface agreement: one policy, three front doors, one key.
            if wa.coalesce_key() != ca.coalesce_key() || wa.coalesce_key() != fa.coalesce_key()
            {
                return Err(format!(
                    "surfaces disagree on the key for {ka:?}: wire '{}', config '{}', cli '{}'",
                    wa.coalesce_key(),
                    ca.coalesce_key(),
                    fa.coalesce_key()
                ));
            }
            // Execution-irrelevant fields never split a batch.
            let decorated = JobRequest {
                subject: "zz99".into(),
                priority: Priority::Emergency,
                dedup: Some("tok".into()),
                verbose: Some(true),
                ..wa.clone()
            };
            if decorated.coalesce_key() != wa.coalesce_key() {
                return Err("subject/priority/dedup/verbose split the coalesce key".into());
            }

            // Rejected combinations (e.g. a first-order baseline asking
            // for a multires pyramid) never reach the scheduler — but all
            // three surfaces must reject them identically.
            let pw = match (wa.validate(), ca.validate(), fa.validate()) {
                (Ok(w), Ok(c), Ok(f)) => {
                    if solver_view(&w) != solver_view(&c) || solver_view(&w) != solver_view(&f)
                    {
                        return Err(format!(
                            "surfaces materialize different params for {ka:?}"
                        ));
                    }
                    w
                }
                (Err(ew), Err(ec), Err(ef)) => {
                    if ew.to_string() != ec.to_string() || ew.to_string() != ef.to_string() {
                        return Err(format!(
                            "rejection drifted across surfaces: '{ew}' vs '{ec}' vs '{ef}'"
                        ));
                    }
                    return Ok(());
                }
                _ => return Err(format!("surfaces disagree on rejecting {ka:?}")),
            };

            // Soundness across independent draws: equal keys => identical
            // solver-relevant params (the batch-safety invariant).
            let Ok(pb) = wb.validate() else {
                return Ok(()); // b never admitted, so never coalesced
            };
            if wa.coalesce_key() == wb.coalesce_key()
                && (wa.n != wb.n || solver_view(&pw) != solver_view(&pb))
            {
                return Err(format!(
                    "key '{}' coalesces incompatible solves: {ka:?} vs {kb:?}",
                    wa.coalesce_key()
                ));
            }
            Ok(())
        },
    );
}

// -- Fuzz against a live daemon ---------------------------------------------

struct InstantStub;

impl Executor for InstantStub {
    fn execute(
        &mut self,
        payload: &JobPayload,
        _cx: &claire::registration::SolveCx,
    ) -> Result<claire::serve::ExecOutcome> {
        Ok(stub_report(&payload.name()).into())
    }
}

fn stub_factory() -> ExecutorFactory {
    Arc::new(|_w| Ok(Box::new(InstantStub) as Box<dyn Executor>))
}

/// Generate one invalid-but-bounded request line. Three families: raw
/// garbage (prefixed so it can never parse as JSON), structurally valid
/// JSON with wrong types, and valid requests with a corrupted body.
fn gen_invalid_line(r: &mut Rng) -> String {
    match r.below(3) {
        0 => {
            let len = 1 + r.below(200) as usize;
            let mut s = String::from("@");
            for _ in 0..len {
                // Printable ASCII minus newline; '@' prefix keeps it
                // un-JSON regardless of what follows.
                s.push((0x20 + r.below(0x5e) as u8) as char);
            }
            s
        }
        1 => {
            let bodies = [
                r#"{"cmd":5}"#,
                r#"{"cmd":"submit","job":5}"#,
                r#"{"cmd":"submit","job":{"n":"x"}}"#,
                r#"{"cmd":"submit_batch","jobs":{}}"#,
                r#"{"cmd":"cancel","id":1.5}"#,
                r#"{"cmd":"status","id":[]}"#,
                r#"{"cmd":"shutdown","drain":"maybe"}"#,
                r#"{"cmd":"upload","n":2,"data":"!!"}"#,
                r#"{"cmd":"hello","proto":0}"#,
                r#"{"nothing":"here"}"#,
                r#"[1,2,3]"#,
            ];
            bodies[r.below(bodies.len() as u64) as usize].to_string()
        }
        _ => {
            // Truncate a valid submit line mid-body.
            let line = Request::Submit(gen_job_request(r)).to_line();
            let cut = 1 + r.below((line.len() - 1) as u64) as usize;
            line[..cut].to_string()
        }
    }
}

/// Every fuzzed invalid line must yield a structured `bad_request` (v2
/// session) and leave the connection serving — never a panic, hang, or
/// disconnect. `[1,2,3]` style non-object JSON included.
#[test]
fn fuzzed_invalid_lines_yield_bad_request_not_connection_drops() {
    use std::io::{BufRead, BufReader, Write};

    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 4,
        journal: None,
        ..Default::default()
    };
    let handle = Daemon::start(cfg, stub_factory()).unwrap();
    let mut stream = std::net::TcpStream::connect(handle.addr()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut call = |line: &str| -> String {
        stream.write_all(line.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        stream.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        assert!(!resp.is_empty(), "connection dropped after: {line}");
        resp.trim_end_matches('\n').to_string()
    };
    // Upgrade to v2 so errors are structured.
    assert!(call(r#"{"cmd":"hello","proto":2}"#).contains(r#""proto":2"#));

    let mut r = Rng::new(0xF00D);
    for case in 0..120 {
        let line = gen_invalid_line(&mut r);
        let resp = call(&line);
        let parsed = Response::parse(&resp)
            .unwrap_or_else(|e| panic!("case {case}: unparseable response {resp}: {e}"));
        match parsed {
            Response::Error { code, retryable, .. } => {
                assert_eq!(code, ErrorCode::BadRequest, "case {case}: {line} -> {resp}");
                assert!(!retryable, "bad requests are never retryable: {resp}");
            }
            other => panic!("case {case}: fuzz line accepted: {line} -> {other:?}"),
        }
        // The connection still serves after every piece of garbage.
        if case % 20 == 0 {
            assert!(call(r#"{"cmd":"ping"}"#).contains(r#""ok":true"#));
        }
    }
    // And well-formed traffic still flows end to end.
    let resp = call(r#"{"cmd":"submit","job":{"max_iter":1},"seq":1}"#);
    assert!(resp.contains(r#""ok":true"#), "{resp}");
    assert!(resp.contains(r#""seq":1"#), "{resp}");
    // submit_batch verdicts survive fuzz too.
    let resp = call(r#"{"cmd":"submit_batch","jobs":[{"max_iter":1},{"n":5000}],"seq":2}"#);
    assert!(resp.contains(r#""results":"#), "{resp}");
    assert!(resp.contains(r#""code":"bad_request""#), "{resp}");
    drop(stream);

    let mut client = claire::serve::Client::connect(&handle.addr().to_string()).unwrap();
    client.wait_idle(10.0).unwrap();
    client.shutdown(false).unwrap();
    handle.join().unwrap();
}
