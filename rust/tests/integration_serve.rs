//! Daemon integration tests over the real wire protocol (TCP loopback).
//!
//! The executors are stubs (sleep + per-(variant, n) warm-cache emulation)
//! so scheduling, admission control, cancellation, stats, and journal
//! restart behavior are exercised deterministically without compiled
//! artifacts; the PJRT execution path itself is covered by the
//! artifact-gated tests in `integration_registration.rs` and
//! `coordinator::service`.

use std::collections::BTreeSet;
use std::sync::Arc;

use claire::error::Result;
use claire::serve::{
    scheduler::stub_report, Client, Daemon, DaemonConfig, EventMsg, Executor, ExecutorFactory,
    JobPayload, JobSource, JobSpec, JobState, Priority, Verdict,
};
use claire::{ErrorCode, Precision};

/// Stub worker: sleeps `max_iter` milliseconds per job (so tests control
/// service time through the spec) and emulates the shared-warm operator
/// cache: the first job at a given (variant, n, precision) "compiles" a
/// handful of operators, every later same-shape same-policy job hits them
/// warm — mirroring the registry's precision-separated cache keys.
struct StubExec {
    warm: BTreeSet<(String, usize, Precision)>,
    compiles: u64,
    hits: u64,
}

impl Executor for StubExec {
    fn execute(
        &mut self,
        payload: &JobPayload,
        _cx: &claire::registration::SolveCx,
    ) -> Result<claire::serve::ExecOutcome> {
        let spec = match payload {
            JobPayload::Spec(s) => s,
            JobPayload::Volumes { spec, m0, m1, .. } => {
                // The daemon resolved real volume data at admission time;
                // sanity-check the contract the executor relies on.
                assert_eq!(m0.n, spec.n, "admission validated m0 shape");
                assert_eq!(m1.n, spec.n, "admission validated m1 shape");
                spec
            }
            JobPayload::Problem { .. } => return Ok(stub_report("problem").into()),
        };
        if self.warm.insert((spec.variant.clone(), spec.n, spec.precision)) {
            self.compiles += 5;
        } else {
            self.hits += 5;
        }
        let delay_ms = spec.max_iter.unwrap_or(1) as u64;
        std::thread::sleep(std::time::Duration::from_millis(delay_ms));
        let mut report = stub_report(&spec.name());
        // Mirror the real executor: the report carries the realized level
        // count (equal to the request under a stub).
        report.levels = spec.multires.unwrap_or(1);
        Ok(report.into())
    }

    fn cache_stats(&self) -> (u64, u64) {
        (self.compiles, self.hits)
    }
}

fn stub_factory() -> ExecutorFactory {
    Arc::new(|_w| {
        Ok(Box::new(StubExec { warm: BTreeSet::new(), compiles: 0, hits: 0 })
            as Box<dyn Executor>)
    })
}

fn spec(subject: &str, priority: Priority, delay_ms: usize) -> JobSpec {
    JobSpec {
        subject: subject.into(),
        priority,
        max_iter: Some(delay_ms),
        ..Default::default()
    }
}

/// Block until `running` workers are busy (so subsequent submissions are
/// queueing decisions, not dispatch races).
fn wait_running(client: &mut Client, running: usize) {
    let t0 = std::time::Instant::now();
    while client.stats().unwrap().running < running {
        assert!(t0.elapsed().as_secs_f64() < 10.0, "workers never picked up blockers");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
}

fn tmp_journal(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("claire_serve_itest");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join(name);
    let _ = std::fs::remove_file(&p);
    p
}

/// The acceptance scenario: in-process daemon, >= 8 concurrent jobs with
/// mixed priorities over the wire, priority dispatch order, cancellation
/// of a queued job, and compiled-operator reuse visible in stats.
#[test]
fn daemon_schedules_by_priority_cancels_and_reports_reuse() {
    let journal = tmp_journal("accept.ndjson");
    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        queue_cap: 32,
        journal: Some(journal.clone()),
        ..Default::default()
    };
    let handle = Daemon::start(cfg, stub_factory()).unwrap();
    let addr = handle.addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    client.ping().unwrap();

    // Two long blockers occupy both workers so the next 8 submissions are
    // genuinely concurrent in the queue when dispatch decisions happen.
    let blocker_a = client.submit(&spec("na02", Priority::Batch, 600)).unwrap();
    let blocker_b = client.submit(&spec("na03", Priority::Batch, 600)).unwrap();
    wait_running(&mut client, 2);

    // 8 queued jobs, mixed priorities, submitted batch-first so priority
    // (not submission order) must explain the dispatch order.
    let subjects = ["na02", "na03", "na10"];
    let batch: Vec<u64> = (0..3)
        .map(|i| client.submit(&spec(subjects[i], Priority::Batch, 10)).unwrap())
        .collect();
    let urgent: Vec<u64> =
        (0..2).map(|_| client.submit(&spec("na02", Priority::Urgent, 10)).unwrap()).collect();
    let emergency: Vec<u64> =
        (0..3).map(|_| client.submit(&spec("na03", Priority::Emergency, 10)).unwrap()).collect();

    // Cancel one still-queued batch job before the blockers finish.
    client.cancel(batch[2]).unwrap();
    // Cancelling again (or cancelling a finished job) is a wire error, not
    // a dead connection.
    assert!(client.cancel(batch[2]).is_err());
    client.ping().unwrap();

    let stats = client.wait_idle(30.0).unwrap();

    // Every job terminal; the cancelled one never ran.
    let cancelled = client.status(batch[2]).unwrap();
    assert_eq!(cancelled.state, JobState::Cancelled);
    assert_eq!(cancelled.dispatch_seq, None);
    for &id in [blocker_a, blocker_b].iter().chain(&batch[..2]).chain(&urgent).chain(&emergency) {
        assert_eq!(client.status(id).unwrap().state, JobState::Done, "job {id}");
    }

    // Priority order: every emergency job dispatched before every urgent
    // job, every urgent before every surviving batch job (blockers aside —
    // they were dispatched first, while the queue was empty).
    let mut dseq = |id: u64| client.status(id).unwrap().dispatch_seq.unwrap();
    let max_emergency = emergency.iter().map(|&id| dseq(id)).max().unwrap();
    let min_urgent = urgent.iter().map(|&id| dseq(id)).min().unwrap();
    let max_urgent = urgent.iter().map(|&id| dseq(id)).max().unwrap();
    let min_batch = batch[..2].iter().map(|&id| dseq(id)).min().unwrap();
    assert!(
        max_emergency < min_urgent,
        "emergency jobs must dispatch before urgent (max_e {max_emergency} vs min_u {min_urgent})"
    );
    assert!(
        max_urgent < min_batch,
        "urgent jobs must dispatch before batch (max_u {max_urgent} vs min_b {min_batch})"
    );

    // Shared-warm operator cache: all jobs share (variant, n), so every
    // job after each worker's first is a warm hit.
    assert!(stats.cache_hits > 0, "expected compiled-operator reuse, got {stats:?}");
    assert!(stats.cache_compiles > 0);
    assert_eq!(stats.completed, 9);
    assert_eq!(stats.cancelled, 1);
    assert_eq!(stats.submitted, 10);

    client.shutdown(true).unwrap();
    handle.join().unwrap();

    // Restarted daemon replays the journal and reports prior work.
    let cfg2 = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 8,
        journal: Some(journal),
        ..Default::default()
    };
    let handle2 = Daemon::start(cfg2, stub_factory()).unwrap();
    let mut client2 = Client::connect(&handle2.addr().to_string()).unwrap();
    let s2 = client2.stats().unwrap();
    assert_eq!(s2.prior_completed, 9, "restarted daemon must report journaled work");
    assert_eq!(s2.submitted, 0);
    // Journal-audit id continuity: the first incarnation used ids 1..=10,
    // so the restarted daemon's first id must continue past them — audit
    // lines from different incarnations never collide on `id`.
    let fresh = client2.submit(&spec("na02", Priority::Batch, 1)).unwrap();
    assert!(fresh > 10, "id counter must be seeded past the journal (got {fresh})");
    client2.wait_idle(10.0).unwrap();
    client2.shutdown(false).unwrap();
    handle2.join().unwrap();
}

/// Admission control over the wire: once `queue_cap` batch jobs wait, new
/// batch submissions are rejected with a useful error while emergency
/// submissions still get through.
#[test]
fn daemon_applies_backpressure_but_admits_emergencies() {
    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 2,
        journal: None,
        ..Default::default()
    };
    let handle = Daemon::start(cfg, stub_factory()).unwrap();
    let mut client = Client::connect(&handle.addr().to_string()).unwrap();

    // One running blocker + two queued fill the bound.
    client.submit(&spec("na02", Priority::Batch, 500)).unwrap();
    wait_running(&mut client, 1);
    client.submit(&spec("na02", Priority::Batch, 10)).unwrap();
    client.submit(&spec("na03", Priority::Batch, 10)).unwrap();
    let err = client.submit(&spec("na10", Priority::Batch, 10)).unwrap_err();
    assert!(err.to_string().contains("queue full"), "{err}");
    let ok = client.submit(&spec("na10", Priority::Emergency, 10));
    assert!(ok.is_ok(), "emergency must bypass the bound: {ok:?}");

    let stats = client.wait_idle(30.0).unwrap();
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.completed, 4);

    client.shutdown(true).unwrap();
    handle.join().unwrap();
}

/// A `precision:"mixed"` job round-trips through submit/status over the
/// real wire protocol, artifact-free: the status view carries the policy
/// in the job name and the stub cache treats the two precisions as
/// distinct warm keys (the registry contract).
#[test]
fn mixed_precision_job_roundtrips_over_the_wire() {
    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 8,
        journal: None,
        ..Default::default()
    };
    let handle = Daemon::start(cfg, stub_factory()).unwrap();
    let mut client = Client::connect(&handle.addr().to_string()).unwrap();

    let mixed = JobSpec { precision: Precision::Mixed, ..spec("na02", Priority::Urgent, 1) };
    let full = spec("na02", Priority::Batch, 1);
    let id_mixed = client.submit(&mixed).unwrap();
    let id_full = client.submit(&full).unwrap();

    let vm = client.wait_terminal(id_mixed, 10.0).unwrap();
    assert_eq!(vm.state, JobState::Done);
    assert!(vm.name.ends_with("+mixed"), "status must show the policy: {}", vm.name);
    let vf = client.wait_terminal(id_full, 10.0).unwrap();
    assert_eq!(vf.state, JobState::Done);
    assert!(!vf.name.contains("mixed"), "{}", vf.name);

    // Same (variant, n), different precision: no warm-cache sharing, so
    // both jobs "compiled" (the stub mirrors the registry cache keys).
    let stats = client.stats().unwrap();
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.cache_compiles, 10, "full and mixed must not share cache entries");
    assert_eq!(stats.cache_hits, 0);

    client.shutdown(true).unwrap();
    handle.join().unwrap();
}

/// Multiple concurrent client connections against one daemon.
#[test]
fn daemon_serves_concurrent_clients() {
    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        queue_cap: 64,
        journal: None,
        ..Default::default()
    };
    let handle = Daemon::start(cfg, stub_factory()).unwrap();
    let addr = handle.addr().to_string();
    let ids: Vec<u64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    (0..3)
                        .map(|_| c.submit(&spec("na02", Priority::Batch, 5)).unwrap())
                        .collect::<Vec<u64>>()
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    // All 12 ids are distinct.
    assert_eq!(ids.iter().collect::<BTreeSet<_>>().len(), 12);
    let mut client = Client::connect(&addr).unwrap();
    let stats = client.wait_idle(30.0).unwrap();
    assert_eq!(stats.completed, 12);
    assert_eq!(client.jobs().unwrap().len(), 12);
    client.shutdown(true).unwrap();
    handle.join().unwrap();
}

/// The data-plane acceptance scenario: an upload -> submit -> status
/// round-trip over the real NDJSON protocol registers an uploaded volume
/// pair with `multires >= 2` end-to-end under a stub executor, with
/// content-addressed dedup observable in store stats.
#[test]
fn upload_submit_status_round_trip() {
    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 8,
        journal: None,
        ..Default::default()
    };
    let handle = Daemon::start(cfg, stub_factory()).unwrap();
    let mut client = Client::connect(&handle.addr().to_string()).unwrap();

    // Ship an 8^3 pair (distinct volumes) and re-upload the first one to
    // prove content-addressed dedup.
    let n = 8usize;
    let m0: Vec<f32> = (0..n * n * n).map(|i| (i as f32 * 0.25).sin()).collect();
    let m1: Vec<f32> = (0..n * n * n).map(|i| (i as f32 * 0.125).cos()).collect();
    let r0 = client.upload(n, &m0).unwrap();
    let r1 = client.upload(n, &m1).unwrap();
    assert!(!r0.dedup && !r1.dedup);
    assert_ne!(r0.id, r1.id);
    let r0_again = client.upload(n, &m0).unwrap();
    assert!(r0_again.dedup, "identical content must dedup");
    assert_eq!(r0_again.id, r0.id);

    // Submit the uploaded pair with a 3-level grid continuation.
    let job = JobSpec {
        n,
        source: JobSource::Uploaded { m0: r0.id.clone(), m1: r1.id.clone() },
        multires: Some(3),
        priority: Priority::Urgent,
        ..Default::default()
    };
    let id = client.submit(&job).unwrap();
    let view = client.wait_terminal(id, 10.0).unwrap();
    assert_eq!(view.state, JobState::Done);
    assert!(view.name.starts_with("up:"), "uploaded jobs are named by content: {}", view.name);
    assert!(view.name.ends_with("+mr3"), "multires visible in the name: {}", view.name);
    assert_eq!(view.levels, Some(3), "realized level count travels in the job view");

    // Store stats over the wire: 2 volumes resident, 3 uploads, 1 dedup.
    let stats = client.stats().unwrap();
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.store.volumes, 2);
    assert_eq!(stats.store.uploads, 3);
    assert_eq!(stats.store.dedup_hits, 1);
    assert_eq!(stats.store.evictions, 0);
    assert_eq!(stats.store.bytes, (2 * n * n * n * 4) as u64);

    client.shutdown(true).unwrap();
    handle.join().unwrap();
}

/// Admission-time validation of uploaded-source submissions: unknown
/// content ids and grid-size mismatches are rejected with useful errors on
/// a connection that stays usable, and nothing is queued.
#[test]
fn uploaded_source_submissions_are_validated_at_admission() {
    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 8,
        journal: None,
        ..Default::default()
    };
    let handle = Daemon::start(cfg, stub_factory()).unwrap();
    let mut client = Client::connect(&handle.addr().to_string()).unwrap();

    let r = client.upload(4, &[1.0f32; 64]).unwrap();

    // Unknown id.
    let unknown = JobSpec {
        n: 4,
        source: JobSource::Uploaded { m0: r.id.clone(), m1: "0000beef".into() },
        ..Default::default()
    };
    let err = client.submit(&unknown).unwrap_err();
    assert!(err.to_string().contains("unknown volume id"), "{err}");

    // Grid-size mismatch between the spec and the stored volume.
    let mismatched = JobSpec {
        n: 8,
        source: JobSource::Uploaded { m0: r.id.clone(), m1: r.id.clone() },
        ..Default::default()
    };
    let err = client.submit(&mismatched).unwrap_err();
    assert!(err.to_string().contains("does not match"), "{err}");

    // Malformed upload payloads are wire errors, not poison.
    let err = client.upload(4, &[1.0f32; 63]).unwrap_err();
    assert!(err.to_string().contains("expected 256"), "{err}");

    // Connection still healthy; nothing was admitted.
    client.ping().unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.submitted, 0);
    assert_eq!(stats.queued, 0);

    client.shutdown(false).unwrap();
    handle.join().unwrap();
}

/// A pre-data-plane client — raw NDJSON with no `source`/`multires`
/// fields, exactly what a PR-1-era `claire submit` sends — still submits
/// synthetic jobs unchanged against the upgraded daemon.
#[test]
fn pre_data_plane_clients_still_submit_synthetic_jobs() {
    use std::io::{BufRead, BufReader, Write};

    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 8,
        journal: None,
        ..Default::default()
    };
    let handle = Daemon::start(cfg, stub_factory()).unwrap();
    let mut stream = std::net::TcpStream::connect(handle.addr()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // Verbatim legacy submit line (old field set only).
    stream
        .write_all(
            b"{\"cmd\":\"submit\",\"job\":{\"subject\":\"na03\",\"n\":16,\
              \"priority\":\"urgent\",\"max_iter\":1}}\n",
        )
        .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "legacy submit accepted: {line}");
    assert!(line.contains("\"id\":"), "{line}");
    drop(stream);

    // The job runs to completion as a plain synthetic single-grid solve.
    let mut client = Client::connect(&handle.addr().to_string()).unwrap();
    client.wait_idle(10.0).unwrap();
    let jobs = client.jobs().unwrap();
    assert_eq!(jobs.len(), 1);
    assert_eq!(jobs[0].state, JobState::Done);
    assert!(jobs[0].name.starts_with("na03@16^3/"), "{}", jobs[0].name);
    assert_eq!(jobs[0].levels, Some(1), "no multires field = single grid");

    client.shutdown(true).unwrap();
    handle.join().unwrap();
}

/// LRU eviction is observable over the wire, and an admitted job survives
/// eviction of its volumes (payload resolution happens at admission).
#[test]
fn store_eviction_over_the_wire() {
    // Budget: exactly two 16^3 volumes (16^3 * 4 = 16384 bytes each; 16^3
    // is also the store's budget floor, so the configured value is taken
    // as-is).
    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 8,
        journal: None,
        store_bytes: 2 * 16 * 16 * 16 * 4,
        ..Default::default()
    };
    let handle = Daemon::start(cfg, stub_factory()).unwrap();
    let mut client = Client::connect(&handle.addr().to_string()).unwrap();

    let n = 16usize;
    let vol = |seed: f32| -> Vec<f32> { (0..n * n * n).map(|i| seed + i as f32).collect() };
    let a = client.upload(n, &vol(0.0)).unwrap();
    let b = client.upload(n, &vol(1.0)).unwrap();

    // Admit a job against (a, b), then evict both with fresh uploads.
    let id = client
        .submit(&JobSpec {
            n,
            source: JobSource::Uploaded { m0: a.id.clone(), m1: b.id.clone() },
            multires: Some(2),
            ..Default::default()
        })
        .unwrap();
    client.upload(n, &vol(2.0)).unwrap();
    client.upload(n, &vol(3.0)).unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.store.evictions, 2, "budget forced both old volumes out");
    assert_eq!(stats.store.volumes, 2);

    // The admitted job still completes (volumes were resolved at submit).
    let view = client.wait_terminal(id, 10.0).unwrap();
    assert_eq!(view.state, JobState::Done);

    // But a new submit referencing the evicted ids is rejected.
    let err = client
        .submit(&JobSpec {
            n,
            source: JobSource::Uploaded { m0: a.id.clone(), m1: b.id.clone() },
            ..Default::default()
        })
        .unwrap_err();
    assert!(err.to_string().contains("unknown volume id"), "{err}");

    client.shutdown(true).unwrap();
    handle.join().unwrap();
}

// -- Protocol v2 ------------------------------------------------------------

/// Write one raw line, read one raw line (trailing newline stripped).
fn raw_call(
    stream: &mut std::net::TcpStream,
    reader: &mut std::io::BufReader<std::net::TcpStream>,
    line: &str,
) -> String {
    use std::io::{BufRead, Write};
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    resp.trim_end_matches('\n').to_string()
}

fn raw_conn(
    addr: std::net::SocketAddr,
) -> (std::net::TcpStream, std::io::BufReader<std::net::TcpStream>) {
    let stream = std::net::TcpStream::connect(addr).unwrap();
    let reader = std::io::BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

/// The v1 compatibility guarantee, pinned byte-for-byte: a connection that
/// never sends `hello` gets exactly the responses the pre-v2 daemon
/// produced — same keys, same error strings, no `code`/`retryable`/`seq`
/// fields, and v2-only verbs answered as unknown commands.
#[test]
fn v1_raw_lines_are_byte_compatible() {
    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 8,
        journal: None,
        ..Default::default()
    };
    let handle = Daemon::start(cfg, stub_factory()).unwrap();
    let (mut s, mut r) = raw_conn(handle.addr());

    assert_eq!(raw_call(&mut s, &mut r, r#"{"cmd":"ping"}"#), r#"{"ok":true}"#);
    // A v1 line that happens to carry a seq field: ignored, never echoed.
    assert_eq!(raw_call(&mut s, &mut r, r#"{"cmd":"ping","seq":9}"#), r#"{"ok":true}"#);
    // First submitted job gets id 1 (fresh daemon, no journal).
    assert_eq!(
        raw_call(
            &mut s,
            &mut r,
            r#"{"cmd":"submit","job":{"subject":"na03","n":16,"priority":"urgent","max_iter":1}}"#,
        ),
        r#"{"id":1,"ok":true}"#
    );
    // Error strings are byte-identical opaque messages in v1.
    assert_eq!(
        raw_call(&mut s, &mut r, r#"{"cmd":"status","id":999}"#),
        r#"{"error":"no such job 999","ok":false}"#
    );
    // `cancel` historically routed through Error::Serve, so its message
    // carries the legacy prefix (unlike `status`, formatted inline).
    assert_eq!(
        raw_call(&mut s, &mut r, r#"{"cmd":"cancel","id":999}"#),
        r#"{"error":"serve error: no such job 999","ok":false}"#
    );
    assert_eq!(
        raw_call(&mut s, &mut r, r#"{"cmd":"warp"}"#),
        r#"{"error":"serve error: unknown command 'warp'","ok":false}"#
    );
    // v2-only verbs on an un-negotiated connection keep v1 semantics.
    assert_eq!(
        raw_call(&mut s, &mut r, r#"{"cmd":"watch"}"#),
        r#"{"error":"serve error: unknown command 'watch'","ok":false}"#
    );
    assert_eq!(
        raw_call(&mut s, &mut r, r#"{"cmd":"submit_batch","jobs":[{}]}"#),
        r#"{"error":"serve error: unknown command 'submit_batch'","ok":false}"#
    );
    // Range rejection happens at admission now, with the same message the
    // v1 decoder produced.
    assert_eq!(
        raw_call(&mut s, &mut r, r#"{"cmd":"submit","job":{"n":5000}}"#),
        r#"{"error":"serve error: job field 'n' = 5000 out of range (1..=512)","ok":false}"#
    );
    // Unparseable lines answer an opaque error and keep the connection.
    let resp = raw_call(&mut s, &mut r, "not json");
    assert!(resp.starts_with(r#"{"error":"JSON parse error"#), "{resp}");
    assert!(resp.ends_with(r#","ok":false}"#), "{resp}");
    assert_eq!(raw_call(&mut s, &mut r, r#"{"cmd":"ping"}"#), r#"{"ok":true}"#);
    drop(s);

    let mut client = Client::connect(&handle.addr().to_string()).unwrap();
    client.wait_idle(10.0).unwrap();
    client.shutdown(false).unwrap();
    handle.join().unwrap();
}

/// `hello` negotiation: the response advertises proto + features, and the
/// session switches to seq echo + structured errors (pinned bytes).
#[test]
fn hello_negotiates_v2_sessions() {
    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 1,
        journal: None,
        ..Default::default()
    };
    let handle = Daemon::start(cfg, stub_factory()).unwrap();
    let (mut s, mut r) = raw_conn(handle.addr());

    assert_eq!(
        raw_call(&mut s, &mut r, r#"{"cmd":"hello","proto":2,"seq":1}"#),
        r#"{"features":["seq","watch","submit_batch","structured_errors","probe"],"ok":true,"proto":2,"seq":1}"#
    );
    // v2 ping is the enriched health probe: node identity + load snapshot,
    // nested under "node" so pre-probe clients decode it as a plain Ok.
    let pong = raw_call(&mut s, &mut r, r#"{"cmd":"ping","seq":7}"#);
    assert!(pong.contains(r#""node":{"#), "{pong}");
    assert!(pong.contains(r#""proto":2"#), "{pong}");
    assert!(pong.contains(r#""queued":0"#), "{pong}");
    assert!(pong.contains(r#""seq":7"#), "{pong}");
    // Structured bad_request with the seq echoed even though the body was
    // rejected.
    assert_eq!(
        raw_call(&mut s, &mut r, r#"{"cmd":"submit","job":{"n":5000},"seq":8}"#),
        concat!(
            r#"{"code":"bad_request","error":"serve error: job field 'n' = 5000 out of range (1..=512)","#,
            r#""ok":false,"retryable":false,"seq":8}"#
        )
    );
    // Unparseable lines are structured bad_request too (no seq: unknown).
    let resp = raw_call(&mut s, &mut r, "@@@@");
    assert!(resp.contains(r#""code":"bad_request""#), "{resp}");
    assert!(resp.contains(r#""retryable":false"#), "{resp}");

    // queue_full is retryable. Occupy the worker, fill the 1-slot queue.
    let mut helper = Client::connect(&handle.addr().to_string()).unwrap();
    let blocker =
        raw_call(&mut s, &mut r, r#"{"cmd":"submit","job":{"max_iter":400},"seq":9}"#);
    assert!(blocker.contains(r#""ok":true"#), "{blocker}");
    wait_running(&mut helper, 1);
    let queued = raw_call(&mut s, &mut r, r#"{"cmd":"submit","job":{"max_iter":1},"seq":10}"#);
    assert!(queued.contains(r#""ok":true"#), "{queued}");
    let full = raw_call(&mut s, &mut r, r#"{"cmd":"submit","job":{"max_iter":1},"seq":11}"#);
    assert!(full.contains(r#""code":"queue_full""#), "{full}");
    assert!(full.contains(r#""retryable":true"#), "{full}");
    assert!(full.contains(r#""seq":11"#), "{full}");
    drop(s);

    helper.wait_idle(30.0).unwrap();
    helper.shutdown(false).unwrap();
    handle.join().unwrap();
}

/// A client that only speaks v1 sends `hello` with proto 1: the daemon
/// acknowledges and the session stays v1 (no seq echo).
#[test]
fn hello_proto1_stays_v1() {
    let handle = Daemon::start(
        DaemonConfig { addr: "127.0.0.1:0".into(), workers: 1, ..Default::default() },
        stub_factory(),
    )
    .unwrap();
    let (mut s, mut r) = raw_conn(handle.addr());
    assert_eq!(
        raw_call(&mut s, &mut r, r#"{"cmd":"hello","proto":1}"#),
        r#"{"features":[],"ok":true,"proto":1}"#
    );
    assert_eq!(raw_call(&mut s, &mut r, r#"{"cmd":"ping","seq":3}"#), r#"{"ok":true}"#);
    drop(s);
    handle.shutdown(false);
    handle.join().unwrap();
}

/// The watch acceptance scenario (and the CI watch smoke): a v2 session
/// subscribes, another connection submits, and the full
/// queued -> running -> done lifecycle streams back with the watch seq on
/// every event.
#[test]
fn watch_streams_job_lifecycle() {
    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 8,
        journal: None,
        ..Default::default()
    };
    let handle = Daemon::start(cfg, stub_factory()).unwrap();
    let addr = handle.addr().to_string();

    let mut watcher = Client::connect(&addr).unwrap();
    let features = watcher.hello().unwrap();
    assert!(features.contains(&"watch".to_string()), "{features:?}");
    let wseq = watcher.watch().unwrap();
    assert!(wseq.is_some(), "v2 session correlates the subscription");

    let mut submitter = Client::connect(&addr).unwrap();
    submitter.hello().unwrap();
    let id = submitter.submit(&spec("na02", Priority::Urgent, 30)).unwrap();

    let mut events = Vec::new();
    while events.len() < 3 {
        match watcher.next_event().unwrap() {
            EventMsg::Job { id: eid, name, state, seq, wall_s, error } if eid == id => {
                assert_eq!(seq, wseq, "every event echoes the watch seq");
                assert!(name.starts_with("na02@16^3/"), "{name}");
                events.push((state, wall_s, error));
            }
            EventMsg::Job { .. } => {}
            // Stub executors don't notify the solve context, so no
            // progress beats are expected here.
            EventMsg::Progress { .. } => {}
            EventMsg::Lagged { .. } => panic!("watcher should not lag"),
        }
    }
    let states: Vec<&str> = events.iter().map(|(s, _, _)| s.as_str()).collect();
    assert_eq!(states, vec!["queued", "running", "done"]);
    assert!(events[2].1.is_some(), "terminal event carries wall_s");
    assert!(events[2].2.is_none(), "successful job has no error");

    // The watching connection still answers requests (multiplexed writes).
    watcher.ping().unwrap();

    submitter.shutdown(true).unwrap();
    drop(watcher);
    handle.join().unwrap();
}

/// `submit_batch`: one line, many jobs, per-job admission verdicts — and
/// rejected jobs do not poison admitted ones.
#[test]
fn submit_batch_returns_per_job_verdicts() {
    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 2,
        journal: None,
        ..Default::default()
    };
    let handle = Daemon::start(cfg, stub_factory()).unwrap();
    let mut client = Client::connect(&handle.addr().to_string()).unwrap();
    client.hello().unwrap();

    // Occupy the worker so the batch is a pure queueing decision.
    let blocker = client.submit(&spec("na02", Priority::Batch, 400)).unwrap();
    wait_running(&mut client, 1);

    let jobs = vec![
        spec("na02", Priority::Batch, 1),
        spec("na03", Priority::Batch, 1),
        spec("na10", Priority::Batch, 1),          // queue full by now
        JobSpec { n: 5000, ..JobSpec::default() }, // invalid: bad_request
        spec("na10", Priority::Emergency, 1),      // bypasses the bound
    ];
    let verdicts = client.submit_batch(&jobs).unwrap();
    assert_eq!(verdicts.len(), 5);
    let mut admitted_ids = Vec::new();
    for (i, v) in verdicts.iter().enumerate() {
        match (i, v) {
            (0 | 1 | 4, Verdict::Admitted { id }) => admitted_ids.push(*id),
            (2, Verdict::Rejected { code, retryable, .. }) => {
                assert_eq!(*code, ErrorCode::QueueFull);
                assert!(*retryable);
            }
            (3, Verdict::Rejected { code, retryable, .. }) => {
                assert_eq!(*code, ErrorCode::BadRequest);
                assert!(!*retryable);
            }
            other => panic!("unexpected verdict {other:?}"),
        }
    }
    assert_eq!(admitted_ids.len(), 3);
    assert!(admitted_ids.windows(2).all(|w| w[0] < w[1]), "ids in order: {admitted_ids:?}");
    assert!(admitted_ids.iter().all(|&id| id > blocker));

    let stats = client.wait_idle(30.0).unwrap();
    assert_eq!(stats.completed, 4, "blocker + three admitted batch jobs");
    assert_eq!(stats.rejected, 1, "only the queue_full rejection counts");

    client.shutdown(true).unwrap();
    handle.join().unwrap();
}

/// Structured codes cover every daemon error path in a v2 session, and
/// the typed client surfaces them as `Error::Wire` with the right CLI
/// exit codes.
#[test]
fn v2_errors_carry_stable_codes() {
    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 8,
        journal: None,
        ..Default::default()
    };
    let handle = Daemon::start(cfg, stub_factory()).unwrap();
    let mut client = Client::connect(&handle.addr().to_string()).unwrap();
    client.hello().unwrap();

    // unknown_job (status + cancel).
    let err = client.status(999).unwrap_err();
    assert_eq!(err.code(), ErrorCode::UnknownJob);
    assert_eq!(err.exit_code(), 66);
    assert_eq!(client.cancel(999).unwrap_err().code(), ErrorCode::UnknownJob);

    // unknown_volume.
    let err = client
        .submit(&JobSpec {
            n: 4,
            source: JobSource::Uploaded { m0: "00beef".into(), m1: "00dead".into() },
            ..Default::default()
        })
        .unwrap_err();
    assert_eq!(err.code(), ErrorCode::UnknownVolume);

    // shape_mismatch: stored shape disagrees with the job's n.
    let receipt = client.upload(4, &[1.0f32; 64]).unwrap();
    let err = client
        .submit(&JobSpec {
            n: 8,
            source: JobSource::Uploaded { m0: receipt.id.clone(), m1: receipt.id.clone() },
            ..Default::default()
        })
        .unwrap_err();
    assert_eq!(err.code(), ErrorCode::ShapeMismatch);
    assert_eq!(err.exit_code(), 65);

    // invalid_state: cancelling a finished job.
    let id = client.submit(&spec("na02", Priority::Batch, 1)).unwrap();
    client.wait_terminal(id, 10.0).unwrap();
    let err = client.cancel(id).unwrap_err();
    assert_eq!(err.code(), ErrorCode::InvalidState);

    // bad_request from a malformed upload payload.
    let err = client.upload(4, &[1.0f32; 63]).unwrap_err();
    assert_eq!(err.code(), ErrorCode::BadRequest);
    assert_eq!(err.exit_code(), 64);

    client.shutdown(true).unwrap();
    handle.join().unwrap();
}

/// `connect_with_timeout` bounds the whole exchange: a daemon that
/// accepts but never answers fails the call with an I/O error (CLI exit
/// 69) instead of wedging forever.
#[test]
fn client_timeout_fails_instead_of_wedging() {
    use std::time::{Duration, Instant};
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    // Accept and hold the connection open without ever responding.
    let holder = std::thread::spawn(move || {
        let conn = listener.accept();
        std::thread::sleep(Duration::from_millis(600));
        drop(conn);
    });
    let mut client = Client::connect_with_timeout(&addr, Duration::from_millis(120)).unwrap();
    let t0 = Instant::now();
    let err = client.ping().unwrap_err();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "timed out promptly, not wedged: {:?}",
        t0.elapsed()
    );
    assert!(matches!(err, claire::Error::Io(_)), "transport failure: {err}");
    assert_eq!(err.exit_code(), 69, "scripts see EX_UNAVAILABLE");
    holder.join().unwrap();
}

// -- Cooperative cancellation of running jobs -------------------------------

/// Cooperative stub executor: "iterates" until cancelled, notifying the
/// scheduler's `SolveCx` each step — the stub analog of what
/// `Session::solve_cx` does inside the real `PjrtExecutor`.
fn cooperative_factory(step_ms: u64) -> ExecutorFactory {
    use claire::serve::scheduler::stub_iter;
    struct Coop {
        step_ms: u64,
    }
    impl Executor for Coop {
        fn execute(
            &mut self,
            payload: &JobPayload,
            cx: &claire::registration::SolveCx,
        ) -> Result<claire::serve::ExecOutcome> {
            let iters = match payload {
                JobPayload::Spec(s) | JobPayload::Volumes { spec: s, .. } => {
                    s.max_iter.unwrap_or(1)
                }
                JobPayload::Problem { params, .. } => params.max_iter,
            };
            let mut history = Vec::new();
            for i in 0..iters {
                if cx.cancelled() {
                    return Err(claire::Error::Cancelled { history });
                }
                let rec = stub_iter(i);
                cx.notify(i, &rec);
                history.push(rec);
                std::thread::sleep(std::time::Duration::from_millis(self.step_ms));
            }
            Ok(stub_report(&payload.name()).into())
        }
    }
    let factory: ExecutorFactory = Arc::new(move |_w| {
        Ok(Box::new(Coop { step_ms }) as Box<dyn Executor>)
    });
    factory
}

/// The cancellation acceptance scenario (and the CI cancel smoke): cancel
/// a *running* multi-iteration job over the wire and observe the
/// `running → cancelled` transition everywhere it must show — the journal
/// line, the watch stream, the partial history in the status view — while
/// the worker immediately picks up the next job.
#[test]
fn cancel_running_job_over_the_wire() {
    let journal = tmp_journal("cancel_running.ndjson");
    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 8,
        journal: Some(journal.clone()),
        ..Default::default()
    };
    let handle = Daemon::start(cfg, cooperative_factory(3)).unwrap();
    let addr = handle.addr().to_string();

    let mut watcher = Client::connect(&addr).unwrap();
    watcher.hello().unwrap();
    watcher.watch().unwrap();

    let mut client = Client::connect(&addr).unwrap();
    client.hello().unwrap();
    // 10_000 "iterations" at 3 ms each: runs ~30 s unless interrupted.
    let long = client.submit(&spec("na02", Priority::Batch, 10_000)).unwrap();
    let next = client.submit(&spec("na03", Priority::Batch, 1)).unwrap();

    // Wait until the job is running and visibly progressing in the
    // poll-only control plane (the satellite surface: iters_done +
    // grad_rel in the status view with no watch needed).
    let t0 = std::time::Instant::now();
    let running = loop {
        let v = client.status(long).unwrap();
        if v.state == JobState::Running && v.iters_done.unwrap_or(0) >= 2 {
            break v;
        }
        assert!(t0.elapsed().as_secs() < 15, "job never progressed: {v:?}");
        std::thread::sleep(std::time::Duration::from_millis(3));
    };
    assert!(running.grad_rel.is_some(), "live grad_rel for a running job");

    // Cancel the RUNNING job: accepted (no invalid_state), interrupts at
    // the next iteration boundary.
    client.cancel(long).unwrap();
    let t_cancel = std::time::Instant::now();
    let view = client.wait_terminal(long, 10.0).unwrap();
    assert!(
        t_cancel.elapsed().as_secs_f64() < 5.0,
        "cancel must land within an iteration boundary, not after the full solve"
    );
    assert_eq!(view.state, JobState::Cancelled, "running → cancelled");
    assert!(view.iters_done.unwrap() >= 2, "partial history survives: {view:?}");
    assert!(view.error.is_none(), "cancellation is not a failure");
    assert!(view.wall_s.is_some());

    // The worker immediately picked up the next job.
    let v2 = client.wait_terminal(next, 10.0).unwrap();
    assert_eq!(v2.state, JobState::Done);

    // Watch stream: progress beats while running, then the terminal
    // cancelled transition (never failed).
    let mut progress_beats = 0usize;
    let mut states = Vec::new();
    loop {
        match watcher.next_event().unwrap() {
            EventMsg::Progress { id, .. } if id == long => progress_beats += 1,
            EventMsg::Job { id, state, .. } if id == long => {
                assert_ne!(state, JobState::Failed);
                states.push(state.as_str().to_string());
                if state == JobState::Cancelled {
                    break;
                }
            }
            _ => {}
        }
    }
    assert!(progress_beats >= 2, "progress events streamed live");
    assert_eq!(states, vec!["queued", "running", "cancelled"]);

    // Stats count the cooperative cancel once, as cancelled (not failed).
    let stats = client.wait_idle(10.0).unwrap();
    assert_eq!(stats.cancelled, 1);
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.completed, 1);

    client.shutdown(true).unwrap();
    drop(watcher);
    handle.join().unwrap();

    // The journal holds a `cancelled` audit line for the running job (and
    // no per-iteration noise).
    let entries = claire::serve::Journal::replay(&journal).unwrap();
    let cancelled: Vec<_> = entries.iter().filter(|e| e.event == "cancelled").collect();
    assert_eq!(cancelled.len(), 1);
    assert_eq!(cancelled[0].id, long);
    assert_eq!(
        entries.len(),
        4,
        "submitted x2 + cancelled + done, nothing else: {entries:?}"
    );
}

// -- Batched dispatch (job coalescing) --------------------------------------

/// The batching acceptance scenario (and the CI coalesce smoke): four
/// compatible batch jobs submitted inside the dwell window coalesce into
/// ONE dispatch on a one-worker daemon — observable as four
/// simultaneously-running jobs and in the wire-level coalesce counters —
/// while every job keeps its own lifecycle: four distinct
/// queued -> running -> terminal watch streams, one of them cancelled
/// mid-batch without disturbing its peers.
#[test]
fn coalesced_batch_keeps_per_job_lifecycles_over_the_wire() {
    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 16,
        journal: None,
        coalesce_b: 8,
        // Long dwell: all four submissions land well inside it, so the
        // fill is deterministic even on a slow machine.
        coalesce_ms: 1_500,
        ..Default::default()
    };
    let handle = Daemon::start(cfg, cooperative_factory(2)).unwrap();
    let addr = handle.addr().to_string();

    let mut watcher = Client::connect(&addr).unwrap();
    watcher.hello().unwrap();
    watcher.watch().unwrap();

    let mut client = Client::connect(&addr).unwrap();
    client.hello().unwrap();

    // Same (n, variant, precision, algorithm, multires, knobs) => same
    // coalesce key. Subjects differ on purpose: subject identity selects
    // data, not the executable, and must never split a batch.
    let subjects = ["na02", "na03", "na10", "na02"];
    let ids: Vec<u64> = subjects
        .iter()
        .map(|s| client.submit(&spec(s, Priority::Batch, 300)).unwrap())
        .collect();

    // One worker, four running jobs: only a coalesced dispatch can do
    // that. (The leader went running when popped; the other three were
    // claimed during the dwell.)
    wait_running(&mut client, 4);

    // Cancel the last member mid-batch: its slot is masked out at its
    // next iteration boundary while the other three run to completion.
    client.cancel(ids[3]).unwrap();

    for &id in &ids[..3] {
        assert_eq!(client.wait_terminal(id, 30.0).unwrap().state, JobState::Done, "job {id}");
    }
    let cancelled = client.wait_terminal(ids[3], 30.0).unwrap();
    assert_eq!(cancelled.state, JobState::Cancelled, "mid-batch cancel lands as cancelled");
    assert!(cancelled.error.is_none(), "cancellation is not a failure");

    // Every member was individually dispatched: four distinct seqs.
    let seqs: BTreeSet<u64> =
        ids.iter().map(|&id| client.status(id).unwrap().dispatch_seq.unwrap()).collect();
    assert_eq!(seqs.len(), 4);

    // The coalesce counters travel the wire: one batched dispatch
    // holding all four jobs.
    let stats = client.wait_idle(10.0).unwrap();
    assert_eq!(stats.batches, 1, "{stats:?}");
    assert_eq!(stats.coalesced, 4, "{stats:?}");
    assert_eq!(stats.completed, 3);
    assert_eq!(stats.cancelled, 1);
    assert_eq!(stats.failed, 0);

    // Four distinct lifecycle streams on the watch connection, each with
    // its own full transition history.
    let mut streams: std::collections::BTreeMap<u64, Vec<String>> =
        ids.iter().map(|&id| (id, Vec::new())).collect();
    let mut terminal = 0usize;
    while terminal < 4 {
        match watcher.next_event().unwrap() {
            EventMsg::Job { id, state, .. } if streams.contains_key(&id) => {
                let done =
                    matches!(state, JobState::Done | JobState::Cancelled | JobState::Failed);
                streams.get_mut(&id).unwrap().push(state.as_str().to_string());
                if done {
                    terminal += 1;
                }
            }
            _ => {}
        }
    }
    for &id in &ids[..3] {
        assert_eq!(streams[&id], vec!["queued", "running", "done"], "job {id}");
    }
    assert_eq!(streams[&ids[3]], vec!["queued", "running", "cancelled"]);

    client.shutdown(true).unwrap();
    drop(watcher);
    handle.join().unwrap();
}

/// Exactly-once admission over the wire: resubmitting with the same
/// `dedup` token returns the original job id without creating a second
/// job — including across a daemon restart, where tokens are reseeded
/// from the journal's `submitted` audit lines.
#[test]
fn dedup_resubmission_is_exactly_once_across_restart() {
    let journal = tmp_journal("dedup.ndjson");
    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 8,
        journal: Some(journal.clone()),
        ..Default::default()
    };
    let handle = Daemon::start(cfg, stub_factory()).unwrap();
    let mut client = Client::connect(&handle.addr().to_string()).unwrap();
    client.hello().unwrap();

    let job = JobSpec {
        dedup: Some("ct-na02-scan7".into()),
        ..spec("na02", Priority::Batch, 1)
    };
    let id = client.submit(&job).unwrap();
    // A retry after a lost response: same token, same id, no second job.
    assert_eq!(client.submit(&job).unwrap(), id);
    let stats = client.wait_idle(10.0).unwrap();
    assert_eq!(stats.submitted, 1, "duplicate admission must not create a job");
    assert_eq!(stats.completed, 1);

    // A different token is a different job.
    let other = JobSpec {
        dedup: Some("ct-na03-scan7".into()),
        ..spec("na03", Priority::Batch, 1)
    };
    let id2 = client.submit(&other).unwrap();
    assert_ne!(id2, id);
    client.wait_idle(10.0).unwrap();

    client.shutdown(true).unwrap();
    handle.join().unwrap();

    // Restart on the same journal: the admission map is reseeded, so the
    // same retry still answers the original id instead of re-running the
    // solve.
    let cfg2 = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 8,
        journal: Some(journal),
        ..Default::default()
    };
    let handle2 = Daemon::start(cfg2, stub_factory()).unwrap();
    let mut client2 = Client::connect(&handle2.addr().to_string()).unwrap();
    client2.hello().unwrap();
    assert_eq!(client2.submit(&job).unwrap(), id, "token reseeded from the journal");
    assert_eq!(client2.stats().unwrap().submitted, 0, "the retry admitted nothing new");
    client2.shutdown(false).unwrap();
    handle2.join().unwrap();
}

/// An `algorithm: gd` job travels the wire, shows its `+gd` name suffix
/// in the status view, and an unknown algorithm is rejected at the same
/// admission path every surface shares.
#[test]
fn algorithm_field_selects_and_rejects_over_the_wire() {
    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 8,
        journal: None,
        ..Default::default()
    };
    let handle = Daemon::start(cfg, stub_factory()).unwrap();
    let mut client = Client::connect(&handle.addr().to_string()).unwrap();
    client.hello().unwrap();

    let gd = JobSpec {
        algorithm: claire::registration::AlgorithmKind::GradientDescent,
        ..spec("na02", Priority::Batch, 1)
    };
    let id = client.submit(&gd).unwrap();
    let view = client.wait_terminal(id, 10.0).unwrap();
    assert_eq!(view.state, JobState::Done);
    assert!(view.name.contains("+gd"), "algorithm visible in the job name: {}", view.name);

    // Unknown algorithm: structured bad_request at decode, nothing queued.
    use std::io::{BufRead, BufReader, Write};
    let mut raw = std::net::TcpStream::connect(handle.addr()).unwrap();
    let mut reader = BufReader::new(raw.try_clone().unwrap());
    raw.write_all(b"{\"cmd\":\"submit\",\"job\":{\"algorithm\":\"newton\"}}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("unknown algorithm 'newton'"), "{line}");
    assert!(line.contains("\"ok\":false"), "{line}");
    drop(raw);
    assert_eq!(client.stats().unwrap().submitted, 1);

    client.shutdown(true).unwrap();
    handle.join().unwrap();
}
