//! End-to-end registration integration tests: the full Gauss-Newton-Krylov
//! solver against synthetic NIREP-analog pairs through the artifacts.

use claire::data::synth;
use claire::registration::metrics::{dice_union, warp_labels};
use claire::registration::{
    run_baseline, BaselineKind, GnSolver, RegParams, RegProblem, RunReport,
};
use claire::runtime::OpRegistry;

fn registry() -> Option<OpRegistry> {
    match OpRegistry::open_default() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping integration tests: {e}");
            None
        }
    }
}

fn quick_params(variant: &str) -> RegParams {
    RegParams { variant: variant.into(), verbose: false, ..Default::default() }
}

#[test]
fn gn_solver_registers_na02_at_16() {
    let Some(reg) = registry() else { return };
    let prob = synth::nirep_analog_pair(&reg, 16, "na02").unwrap();
    let solver = GnSolver::new(&reg, quick_params("opt-fd8-cubic"));
    let res = solver.solve(&prob).unwrap();

    // Mismatch must drop substantially (paper reaches ~1e-2 at 256^3; at
    // 16^3 with f32 SL error the floor is higher).
    assert!(res.mismatch_rel < 0.5, "mismatch {:.3}", res.mismatch_rel);
    assert!(res.iters >= 2 && res.iters <= 50);
    assert!(res.matvecs >= res.iters);
    // Objective history decreases monotonically within each level.
    for w in res.history.windows(2) {
        if w[0].level_beta == w[1].level_beta {
            assert!(w[1].j <= w[0].j * (1.0 + 1e-6), "J increased: {w:?}");
        }
    }
}

#[test]
fn report_quality_metrics_match_paper_shape() {
    let Some(reg) = registry() else { return };
    // na02 at 16^3 starts at DICE ~0.59 (na03's 16^3 label overlap starts
    // too high to show a +0.05 uplift).
    let prob = synth::nirep_analog_pair(&reg, 16, "na02").unwrap();
    let solver = GnSolver::new(&reg, quick_params("opt-fd8-cubic"));
    let res = solver.solve(&prob).unwrap();
    let report = RunReport::build(&solver, &prob, &res).unwrap();

    // Deformation gradient well-behaved (paper: det F in ~[0.4, 10]).
    assert!(report.detf.min > 0.0, "non-diffeomorphic: min det F {}", report.detf.min);
    assert!(report.detf.max < 20.0);
    assert!((report.detf.mean - 1.0).abs() < 0.3);
    assert!(report.nondiffeo_frac == 0.0);
    // DICE improves after registration.
    let (before, after) = (report.dice_before.unwrap(), report.dice_after.unwrap());
    assert!(after > before + 0.05, "DICE {before:.3} -> {after:.3}");
}

#[test]
fn all_variants_converge_similarly() {
    // Paper Table 7's central claim: iteration counts and quality are
    // nearly identical across kernel variants.
    let Some(reg) = registry() else { return };
    let prob = synth::nirep_analog_pair(&reg, 16, "na02").unwrap();
    let mut mismatches = Vec::new();
    for variant in ["ref-fft-cubic", "opt-fft-cubic", "opt-fd8-cubic", "opt-fd8-linear"] {
        let solver = GnSolver::new(&reg, quick_params(variant));
        let res = solver.solve(&prob).unwrap();
        assert!(res.mismatch_rel < 0.5, "{variant}: {:.3}", res.mismatch_rel);
        mismatches.push((variant, res.mismatch_rel, res.iters));
    }
    let best = mismatches.iter().map(|m| m.1).fold(f64::INFINITY, f64::min);
    let worst = mismatches.iter().map(|m| m.1).fold(0.0, f64::max);
    assert!(
        worst < 2.5 * best,
        "variants diverge in quality: {mismatches:?}"
    );
}

#[test]
fn no_continuation_still_converges() {
    let Some(reg) = registry() else { return };
    let prob = synth::nirep_analog_pair(&reg, 16, "na02").unwrap();
    let params = RegParams { continuation: false, ..quick_params("opt-fd8-linear") };
    let solver = GnSolver::new(&reg, params);
    let res = solver.solve(&prob).unwrap();
    assert!(res.mismatch_rel < 0.6);
}

#[test]
fn identical_images_terminate_with_negligible_velocity() {
    let Some(reg) = registry() else { return };
    let (atlas, _) = synth::brain_atlas(16);
    let prob = RegProblem::new("self", atlas.clone(), atlas);
    let solver = GnSolver::new(&reg, quick_params("opt-fd8-cubic"));
    let res = solver.solve(&prob).unwrap();
    // With m0 == m1 the initial gradient is at the B-spline node-error
    // floor (~1e-3 of a real gradient); the solver may take a few floor-
    // level iterations but must terminate fast with a negligible velocity.
    // Iteration count at the numerical floor is scheduler noise (a handful
    // of continuation levels each probing once); the substantive assertion
    // is that the recovered velocity is negligible.
    assert!(res.iters <= 12, "took {} iterations on identical images", res.iters);
    assert!(res.v.max_abs() < 5e-2, "|v| = {}", res.v.max_abs());
}

#[test]
fn baselines_run_and_are_worse_per_iteration() {
    let Some(reg) = registry() else { return };
    let prob = synth::nirep_analog_pair(&reg, 16, "na02").unwrap();
    let params = quick_params("opt-fd8-cubic");

    let gd = run_baseline(&reg, &prob, &params, BaselineKind::GradientDescent, 10).unwrap();
    let lb = run_baseline(&reg, &prob, &params, BaselineKind::Lbfgs, 10).unwrap();
    assert!(gd.mismatch_rel <= 1.05, "gd mismatch {:.3}", gd.mismatch_rel);
    assert!(lb.mismatch_rel <= 1.05);

    // Paper Table 8 shape: the second-order method reaches much lower
    // mismatch than equally-capped first-order baselines.
    let solver = GnSolver::new(&reg, params);
    let gn = solver.solve(&prob).unwrap();
    assert!(
        gn.mismatch_rel < gd.mismatch_rel,
        "GN {:.3} !< GD {:.3}",
        gn.mismatch_rel,
        gd.mismatch_rel
    );
    assert!(gn.mismatch_rel < lb.mismatch_rel);
}

#[test]
fn recovered_map_warps_labels_consistently() {
    let Some(reg) = registry() else { return };
    let prob = synth::nirep_analog_pair(&reg, 16, "na10").unwrap();
    let solver = GnSolver::new(&reg, quick_params("opt-fd8-cubic"));
    let res = solver.solve(&prob).unwrap();
    let ymap = solver.defmap(&res.v).unwrap();
    let warped = warp_labels(prob.labels0.as_ref().unwrap(), 16, &ymap);
    // Warped template labels overlap the reference labels better than the
    // unwarped ones.
    let before = dice_union(prob.labels0.as_ref().unwrap(), prob.labels1.as_ref().unwrap());
    let after = dice_union(&warped, prob.labels1.as_ref().unwrap());
    assert!(after > before, "{before:.3} -> {after:.3}");
    // Label set is preserved under NN warping.
    let max_before = *prob.labels0.as_ref().unwrap().iter().max().unwrap();
    let max_after = *warped.iter().max().unwrap();
    assert!(max_after <= max_before);
}

#[test]
fn solver_errors_cleanly_without_artifacts_for_size() {
    let Some(reg) = registry() else { return };
    let (atlas, _) = synth::brain_atlas(8); // no artifacts at 8^3
    let prob = RegProblem::new("bad", atlas.clone(), atlas);
    let solver = GnSolver::new(&reg, quick_params("opt-fd8-cubic"));
    assert!(solver.solve(&prob).is_err());
}
