//! Cross-language integration: every operator family executed through the
//! PJRT runtime and validated against pure-Rust references or analytic
//! identities. These tests catch interchange-format regressions (e.g. the
//! HLO text printer eliding large constants) that unit tests on either
//! side cannot see.

use claire::field::ops;
use claire::math::{fft, kernels_ref, stats};
use claire::runtime::OpRegistry;
use claire::util::rng::Rng;

fn registry() -> Option<OpRegistry> {
    match OpRegistry::open_default() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping integration tests: {e}");
            None
        }
    }
}

const N: usize = 16;
const M: usize = N * N * N;

fn rand_scalar(seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..M).map(|_| rng.uniform_f32(-1.0, 1.0)).collect()
}

fn rand_vector(seed: u64, scale: f32) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..3 * M).map(|_| rng.uniform_f32(-scale, scale)).collect()
}

#[test]
fn div_fd8_matches_rust_reference() {
    let Some(reg) = registry() else { return };
    let h = 2.0 * std::f64::consts::PI / N as f64;
    let v = rand_vector(1, 1.0);
    let op = reg.get("div_fd8", "opt-fd8-cubic", N).unwrap();
    let got = op.call(&[&v]).unwrap().remove(0);
    let want = kernels_ref::fd8_div(&v, N, h);
    assert!(stats::rel_l2(&got, &want) < 1e-5);
}

#[test]
fn grad_fft_matches_rust_spectral_oracle() {
    let Some(reg) = registry() else { return };
    let f = rand_scalar(2);
    let op = reg.get("grad_fft", "opt-fd8-cubic", N).unwrap();
    let got = op.call(&[&f]).unwrap().remove(0);
    for axis in 0..3 {
        let want = fft::spectral_partial(&f, N, axis);
        let rel = stats::rel_l2(&got[axis * M..(axis + 1) * M], &want);
        assert!(rel < 1e-4, "axis {axis} rel {rel}");
    }
}

#[test]
fn interp_cubic_matches_rust_reference() {
    let Some(reg) = registry() else { return };
    let f = rand_scalar(3);
    let mut rng = Rng::new(4);
    let q: Vec<f32> = (0..3 * M).map(|_| rng.uniform_f32(-8.0, 24.0)).collect();
    let op = reg.get("interp_lag", "opt-fd8-cubic", N).unwrap();
    let got = op.call(&[&f, &q]).unwrap().remove(0);
    for idx in (0..M).step_by(271) {
        let qp = [q[idx] as f64, q[M + idx] as f64, q[2 * M + idx] as f64];
        let want = kernels_ref::interp_cubic_at(&f, N, qp);
        assert!((got[idx] as f64 - want).abs() < 5e-4, "{} vs {want}", got[idx]);
    }
}

#[test]
fn prefilter_then_bspline_interpolates_at_nodes() {
    let Some(reg) = registry() else { return };
    let f = rand_scalar(5);
    let pf = reg.get("prefilter", "opt-fd8-cubic", N).unwrap();
    let c = pf.call(&[&f]).unwrap().remove(0);
    let ip = reg.get("interp_spl", "opt-fd8-cubic", N).unwrap();
    // interp_spl prefilters internally: feed raw f and grid-point queries.
    let mut q = vec![0f32; 3 * M];
    for i in 0..N {
        for j in 0..N {
            for k in 0..N {
                let idx = (i * N + j) * N + k;
                q[idx] = i as f32;
                q[M + idx] = j as f32;
                q[2 * M + idx] = k as f32;
            }
        }
    }
    let got = ip.call(&[&f, &q]).unwrap().remove(0);
    // Truncated 15-tap prefilter: near-interpolating (~5e-3 on random data).
    let rel = stats::rel_l2(&got, &f);
    assert!(rel < 2e-2, "node interpolation rel {rel}");
    // And the standalone prefilter output must be non-trivial (regression
    // guard for the elided-constant bug).
    assert!(ops::norm2(&c) > 0.1);
}

#[test]
fn gauss_smooth_preserves_mean_reduces_energy() {
    let Some(reg) = registry() else { return };
    let f = rand_scalar(6);
    let op = reg.get("gauss_smooth", "opt-fd8-cubic", N).unwrap();
    let s = op.call(&[&f]).unwrap().remove(0);
    let mean_f: f64 = f.iter().map(|&x| x as f64).sum::<f64>() / M as f64;
    let mean_s: f64 = s.iter().map(|&x| x as f64).sum::<f64>() / M as f64;
    assert!((mean_f - mean_s).abs() < 1e-5);
    let e_f = ops::norm2(&f);
    let e_s = ops::norm2(&s);
    assert!(e_s > 0.01 * e_f, "smoothing must not annihilate the field");
    assert!(e_s < 0.9 * e_f, "smoothing must damp high frequencies");
}

#[test]
fn reg_apply_annihilates_constants_and_matches_laplacian_symbol() {
    let Some(reg) = registry() else { return };
    let op = reg.get("reg_apply", "opt-fd8-cubic", N).unwrap();
    // Constant field -> zero.
    let c = vec![1.0f32; 3 * M];
    let out = op.call(&[&c]).unwrap().remove(0);
    assert!(ops::norm2(&out) < 1e-4);
    // Plane wave sin(k x1) in component 0 (div-free in x2/x3 directions is
    // not needed; check the Laplacian part dominates): A v = beta |k|^2 v +
    // gamma k (k . v). For v = (0, sin(k x1), 0): k.v = 0 in x2 -> pure
    // Laplacian response beta k^2 sin(k x1).
    let mut v = vec![0f32; 3 * M];
    let kk = 2.0;
    for i in 0..N {
        let x1 = 2.0 * std::f64::consts::PI * i as f64 / N as f64;
        for j in 0..N {
            for l in 0..N {
                v[M + (i * N + j) * N + l] = (kk * x1).sin() as f32;
            }
        }
    }
    let out = op.call(&[&v]).unwrap().remove(0);
    // The kernel-level reg_apply artifact is baked with the default
    // beta = 5e-4 (runtime-beta variants are exercised via `precond`).
    let beta = 5e-4f64;
    let want: Vec<f32> = v[M..2 * M]
        .iter()
        .map(|&x| ((beta * kk * kk) as f32) * x)
        .collect();
    let rel = stats::rel_l2(&out[M..2 * M], &want);
    assert!(rel < 1e-3, "Laplacian symbol mismatch: rel {rel}");
}

#[test]
fn precond_inverts_reg_apply_runtime_beta() {
    let Some(reg) = registry() else { return };
    let ra = reg.get("reg_apply", "opt-fd8-cubic", N).unwrap();
    let pc = reg.get("precond", "opt-fd8-cubic", N).unwrap();
    let v = rand_vector(7, 1.0);
    // Remove the constant mode first (reg_apply annihilates it).
    let mut v0 = v.clone();
    for c in 0..3 {
        let mean: f64 =
            v0[c * M..(c + 1) * M].iter().map(|&x| x as f64).sum::<f64>() / M as f64;
        for x in &mut v0[c * M..(c + 1) * M] {
            *x -= mean as f32;
        }
    }
    let av = ra.call(&[&v0]).unwrap().remove(0);
    // The precond artifact takes runtime [beta, gamma]; must match the
    // baked defaults of reg_apply for the roundtrip to be the identity.
    let bg = [5e-4f32, 1e-4];
    let back = pc.call(&[&av, &bg]).unwrap().remove(0);
    // Roundtrip through two f32 spectral ops with beta = 5e-4 amplifies
    // rounding by ~1/beta on the smallest modes; ~2e-3 is the f32 floor.
    let rel = stats::rel_l2(&back, &v0);
    assert!(rel < 1e-2, "P(A v) != v: rel {rel}");
}

#[test]
fn leray_output_is_divergence_free() {
    let Some(reg) = registry() else { return };
    let lr = reg.get("leray", "opt-fd8-cubic", N).unwrap();
    let dv = reg.get("div_fft", "opt-fd8-cubic", N).unwrap();
    let v = rand_vector(8, 1.0);
    let w = lr.call(&[&v]).unwrap().remove(0);
    let div_w = dv.call(&[&w]).unwrap().remove(0);
    let div_v = dv.call(&[&v]).unwrap().remove(0);
    assert!(ops::norm2(&div_w) < 1e-3 * ops::norm2(&div_v).max(1.0));
}

#[test]
fn transport_identity_and_constant_invariance() {
    let Some(reg) = registry() else { return };
    let f = rand_scalar(9);
    let v0 = vec![0f32; 3 * M];
    // Cubic Lagrange interpolates exactly at the nodes: identity to f32
    // precision. The truncated 15-tap B-spline prefilter is only a
    // near-interpolant (~1e-3 over Nt = 4 steps on white noise).
    let exact = reg.get("transport", "ref-fft-cubic", N).unwrap();
    let out = exact.call(&[&v0, &f]).unwrap().remove(0);
    assert!(stats::rel_l2(&out, &f) < 1e-5, "zero velocity must be identity");
    let spl = reg.get("transport", "opt-fd8-cubic", N).unwrap();
    let out = spl.call(&[&v0, &f]).unwrap().remove(0);
    assert!(stats::rel_l2(&out, &f) < 5e-3, "B-spline node error bound");
    let c = vec![2.5f32; M];
    let v = rand_vector(10, 0.4);
    let out = spl.call(&[&v, &c]).unwrap().remove(0);
    assert!(stats::rel_l2(&out, &c) < 1e-3, "constants must be invariant");
}

#[test]
fn defmap_detf_identity_for_zero_velocity() {
    let Some(reg) = registry() else { return };
    let dm = reg.get("defmap", "opt-fd8-cubic", N).unwrap();
    let df = reg.get("detf", "opt-fd8-cubic", N).unwrap();
    let v0 = vec![0f32; 3 * M];
    let y = dm.call(&[&v0]).unwrap().remove(0);
    for i in 0..N {
        for j in 0..N {
            for k in 0..N {
                let idx = (i * N + j) * N + k;
                assert!((y[idx] - i as f32).abs() < 1e-4);
                assert!((y[M + idx] - j as f32).abs() < 1e-4);
                assert!((y[2 * M + idx] - k as f32).abs() < 1e-4);
            }
        }
    }
    let d = df.call(&[&v0]).unwrap().remove(0);
    for &x in d.iter().step_by(97) {
        assert!((x - 1.0).abs() < 1e-4);
    }
}

#[test]
fn sl_step_matches_transport_single_step_structure() {
    let Some(reg) = registry() else { return };
    let sl = reg.get("sl_step", "opt-fd8-cubic", N).unwrap();
    let f = rand_scalar(11);
    let v = rand_vector(12, 0.3);
    let one = sl.call(&[&v, &f]).unwrap().remove(0);
    // One SL step with v for dt = 1/Nt displaces less than the full
    // transport; both must differ from f and from each other.
    let tr = reg.get("transport", "opt-fd8-cubic", N).unwrap();
    let full = tr.call(&[&v, &f]).unwrap().remove(0);
    assert!(stats::rel_l2(&one, &f) > 1e-4);
    assert!(stats::rel_l2(&full, &one) > 1e-4);
}

#[test]
fn newton_setup_outputs_consistent_with_objective() {
    let Some(reg) = registry() else { return };
    let setup = reg.get("newton_setup", "opt-fd8-cubic", N).unwrap();
    let obj = reg.get("objective", "opt-fd8-cubic", N).unwrap();
    let m0 = rand_scalar(13);
    let m1 = rand_scalar(14);
    let v = rand_vector(15, 0.3);
    let bg = [1e-2f32, 1e-3];
    let outs = setup.call(&[&v, &m0, &m1, &bg]).unwrap();
    assert_eq!(outs.len(), 6);
    let s1 = &outs[5];
    let s2 = obj.call(&[&v, &m0, &m1, &bg]).unwrap().remove(0);
    for (a, b) in s1.iter().zip(&s2) {
        assert!((a - b).abs() < 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
    }
    // Gradient at zero mismatch is far below the mismatched gradient
    // (not exactly zero: the truncated B-spline prefilter makes the
    // transported m(1) differ from m0 at ~1e-3 even for v = 0).
    let v0 = vec![0f32; 3 * M];
    let g_mismatched = ops::norm2(&setup.call(&[&v0, &m0, &m1, &bg]).unwrap()[0]);
    let outs = setup.call(&[&v0, &m0, &m0, &bg]).unwrap();
    assert!(
        ops::norm2(&outs[0]) < 0.02 * g_mismatched,
        "{} vs {}",
        ops::norm2(&outs[0]),
        g_mismatched
    );
}

#[test]
fn hess_matvec_is_positive_on_random_directions() {
    let Some(reg) = registry() else { return };
    let setup = reg.get("newton_setup", "opt-fd8-cubic", N).unwrap();
    let hess = reg.get("hess_matvec", "opt-fd8-cubic", N).unwrap();
    let m0 = rand_scalar(16);
    let m1 = rand_scalar(17);
    let v = rand_vector(18, 0.3);
    let bg = [1e-2f32, 1e-3];
    let outs = setup.call(&[&v, &m0, &m1, &bg]).unwrap();
    let (m_traj, yb, yf, divv) = (&outs[1], &outs[2], &outs[3], &outs[4]);
    for seed in 19..22 {
        let vt = rand_vector(seed, 0.3);
        let hv = hess.call(&[&vt, m_traj, yb, yf, divv, &bg]).unwrap().remove(0);
        let quad = ops::dot(&hv, &vt);
        assert!(quad > 0.0, "seed {seed}: vt' H vt = {quad}");
    }
}

#[test]
fn artifacts_exist_for_all_documented_sizes_and_variants() {
    let Some(reg) = registry() else { return };
    for n in [16usize, 32, 64] {
        for variant in ["ref-fft-cubic", "opt-fft-cubic", "opt-fd8-cubic", "opt-fd8-linear"] {
            for op in ["newton_setup", "hess_matvec", "objective", "transport"] {
                assert!(
                    reg.manifest.find(op, variant, n).is_ok(),
                    "missing {op}/{variant}/{n}"
                );
            }
        }
        for op in ["precond", "defmap", "detf", "grad_fd8", "interp_spl", "gauss_smooth"] {
            assert!(reg.manifest.find(op, "opt-fd8-cubic", n).is_ok(), "missing {op}/{n}");
        }
    }
}
