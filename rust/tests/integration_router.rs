//! Fleet-router integration tests: 2 stub daemons behind a router, all
//! over the real wire protocol on loopback.
//!
//! The acceptance contract under test: an *unmodified* v2 `Client`
//! pointed at the router can upload a volume pair, submit jobs that
//! land on a backend holding both volumes (affinity), stream the watch
//! fan-in to a terminal state under router-global job ids, cancel by
//! global id, and survive a backend dying mid-stream (failover).

use std::sync::Arc;
use std::time::Duration;

use claire::error::Result;
use claire::serve::{
    scheduler::stub_report, Client, Daemon, DaemonConfig, DaemonHandle, EventMsg, Executor,
    ExecutorFactory, JobPayload, JobSource, JobSpec, JobState, Router, RouterConfig,
    RouterHandle,
};
use claire::ErrorCode;

/// Stub worker: sleeps `max_iter` milliseconds per job, so specs control
/// service time (same trick as the daemon integration tests).
struct StubExec;

impl Executor for StubExec {
    fn execute(
        &mut self,
        payload: &JobPayload,
        _cx: &claire::registration::SolveCx,
    ) -> Result<claire::serve::ExecOutcome> {
        let spec = match payload {
            JobPayload::Spec(s) => s,
            JobPayload::Volumes { spec, .. } => spec,
            JobPayload::Problem { .. } => return Ok(stub_report("problem").into()),
        };
        std::thread::sleep(Duration::from_millis(spec.max_iter.unwrap_or(1) as u64));
        Ok(stub_report(&spec.name()).into())
    }

    fn cache_stats(&self) -> (u64, u64) {
        (0, 0)
    }
}

fn stub_factory() -> ExecutorFactory {
    Arc::new(|_w| Ok(Box::new(StubExec) as Box<dyn Executor>))
}

fn start_daemon(node_id: &str) -> DaemonHandle {
    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 32,
        journal: None,
        node_id: Some(node_id.into()),
        ..Default::default()
    };
    Daemon::start(cfg, stub_factory()).unwrap()
}

fn start_router(backends: Vec<String>, replication: usize) -> RouterHandle {
    Router::start(RouterConfig {
        addr: "127.0.0.1:0".into(),
        backends,
        replication,
        probe_interval: Duration::from_millis(50),
        timeout: Duration::from_secs(5),
        journal: None,
        node_id: Some("router-under-test".into()),
        ..RouterConfig::default()
    })
    .unwrap()
}

fn connect(addr: &str) -> Client {
    let mut c = Client::connect_with_timeout(addr, Duration::from_secs(10)).unwrap();
    c.set_io_timeout(Some(Duration::from_secs(10))).unwrap();
    assert_eq!(c.negotiate().unwrap(), 2, "router must offer protocol v2");
    c
}

fn volume(n: usize, phase: f32) -> Vec<f32> {
    (0..n * n * n).map(|i| (i as f32 * 0.013 + phase).sin()).collect()
}

fn pair_spec(m0: &str, m1: &str, delay_ms: usize) -> JobSpec {
    JobSpec {
        subject: "fleet".into(),
        n: 16,
        source: JobSource::Uploaded { m0: m0.into(), m1: m1.into() },
        max_iter: Some(delay_ms),
        ..Default::default()
    }
}

/// Wait (bounded) for the watch stream to report `id` terminal; returns
/// the terminal state.
fn wait_terminal_event(client: &mut Client, id: u64) -> JobState {
    let t0 = std::time::Instant::now();
    loop {
        assert!(t0.elapsed().as_secs() < 30, "no terminal event for job {id}");
        match client.next_event().unwrap() {
            EventMsg::Job { id: got, state, .. } if got == id && state.is_terminal() => {
                return state;
            }
            _ => {}
        }
    }
}

/// The ci smoke: upload a pair through the router (replicated to both
/// backends), submit twice, assert both jobs landed on the *same*
/// backend (affinity via the pair key), watch the fan-in to terminal
/// under global ids, cancel a queued job by global id, and drain the
/// whole fleet with one shutdown verb.
#[test]
fn router_upload_submit_watch_affinity() {
    let a = start_daemon("alpha");
    let b = start_daemon("beta");
    let router = start_router(vec![a.addr().to_string(), b.addr().to_string()], 2);
    let addr = router.addr().to_string();

    let mut client = connect(&addr);
    // Enriched ping against the router reports *its* identity.
    let probe = client.probe().unwrap();
    assert_eq!(probe.node, "router-under-test");

    // Upload the pair through the router. replication=2 on a 2-node
    // fleet puts both volumes everywhere, so the pair shares a holder.
    let m0 = client.upload(16, &volume(16, 0.0)).unwrap();
    let m1 = client.upload(16, &volume(16, 1.0)).unwrap();
    assert_ne!(m0.id, m1.id);
    // Re-uploading is a dedup hit on every holder.
    assert!(client.upload(16, &volume(16, 0.0)).unwrap().dedup);

    // A separate watcher connection (events + requests multiplex on one
    // connection too, but a dedicated one keeps the test readable).
    let mut watcher = connect(&addr);
    watcher.watch().unwrap();

    // Two identical-pair jobs: both must route to the same backend.
    let j1 = client.submit(&pair_spec(&m0.id, &m1.id, 200)).unwrap();
    let j2 = client.submit(&pair_spec(&m0.id, &m1.id, 200)).unwrap();
    assert_ne!(j1, j2, "router-global ids are distinct");

    assert_eq!(wait_terminal_event(&mut watcher, j1), JobState::Done);
    assert_eq!(wait_terminal_event(&mut watcher, j2), JobState::Done);

    // Affinity is visible in the merged stats: one node ran both routed
    // jobs, the other none — and both rows carry real node identities.
    let stats = client.stats().unwrap();
    assert_eq!(stats.nodes.len(), 2);
    let mut routed: Vec<u64> = stats.nodes.iter().map(|n| n.routed).collect();
    routed.sort_unstable();
    assert_eq!(routed, vec![0, 2], "both pair jobs pinned to one backend");
    let ids: Vec<&str> = stats.nodes.iter().map(|n| n.node.as_str()).collect();
    assert!(ids.contains(&"alpha") && ids.contains(&"beta"), "probe identities: {ids:?}");
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.workers, 2, "fleet-summed worker count");

    // Cancel by global id: occupy the single worker of the affine
    // backend, queue another pair job behind it, cancel the queued one.
    let blocker = client.submit(&pair_spec(&m0.id, &m1.id, 800)).unwrap();
    let victim = client.submit(&pair_spec(&m0.id, &m1.id, 800)).unwrap();
    client.cancel(victim).unwrap();
    let view = client.status(victim).unwrap();
    assert_eq!(view.id, victim, "status answers in global ids");
    assert_eq!(view.state, JobState::Cancelled);
    assert_eq!(wait_terminal_event(&mut watcher, blocker), JobState::Done);

    // The merged job table speaks global ids exclusively.
    let jobs = client.jobs().unwrap();
    let listed: Vec<u64> = jobs.iter().map(|v| v.id).collect();
    for id in [j1, j2, blocker, victim] {
        assert!(listed.contains(&id), "job {id} missing from merged table {listed:?}");
    }

    // One shutdown verb drains the whole fleet.
    client.shutdown(true).unwrap();
    router.join().unwrap();
    a.join().unwrap();
    b.join().unwrap();
}

/// Failover: kill the backend that owns a pair mid-stream. The next
/// submit of the same pair re-routes to the survivor (the volumes are
/// replicated), the watch fan-in keeps streaming events for the new job,
/// and the dead node shows up as down in the merged stats.
#[test]
fn router_failover_reroutes_and_watch_keeps_streaming() {
    let a = start_daemon("alpha");
    let b = start_daemon("beta");
    let router = start_router(vec![a.addr().to_string(), b.addr().to_string()], 0);
    let addr = router.addr().to_string();
    let mut daemons = vec![a, b];

    let mut client = connect(&addr);
    let m0 = client.upload(16, &volume(16, 2.0)).unwrap();
    let m1 = client.upload(16, &volume(16, 3.0)).unwrap();

    let mut watcher = connect(&addr);
    watcher.watch().unwrap();

    // First job pins the pair's affine backend.
    let j1 = client.submit(&pair_spec(&m0.id, &m1.id, 100)).unwrap();
    assert_eq!(wait_terminal_event(&mut watcher, j1), JobState::Done);
    let stats = client.stats().unwrap();
    let affine = stats.nodes.iter().position(|n| n.routed == 1).unwrap();

    // Kill the affine backend out from under the fleet. Its listener is
    // gone once join returns — no half-dead window.
    let dead = daemons.remove(affine);
    dead.shutdown(false);
    dead.join().unwrap();

    // Same pair again: the submit fails over to the survivor (first
    // attempt marks the dead node down, candidate walk continues), and
    // the fan-in still delivers its events to the old subscription.
    let j2 = client.submit(&pair_spec(&m0.id, &m1.id, 100)).unwrap();
    assert_ne!(j1, j2);
    assert_eq!(wait_terminal_event(&mut watcher, j2), JobState::Done);

    let stats = client.stats().unwrap();
    assert_eq!(stats.nodes.len(), 2, "dead nodes stay visible in the breakdown");
    assert!(!stats.nodes[affine].up, "killed backend reported down");
    assert_eq!(stats.nodes[1 - affine].routed, 1, "failover routed to the survivor");

    // Status for a job routed to the dead backend is a retryable
    // unavailable, not a hang or an unknown-job lie.
    let err = client.status(j1).unwrap_err();
    assert_eq!(err.code(), ErrorCode::Unavailable);

    router.shutdown(true);
    router.join().unwrap();
    daemons.pop().unwrap().join().unwrap();
}
