//! Property tests for the fleet router's consistent-hash placement ring.
//!
//! The three properties the router tier stakes its correctness on:
//!
//! 1. **Stability** — placement is a pure function of (fleet size, key):
//!    any two routers (or restarts) agree on where a volume lives.
//! 2. **Balance** — over a uniform key population, no node owns wildly
//!    more than its share (max/min primary-owner load ratio bounded).
//! 3. **Minimal disruption** — growing the fleet from N to N+1 nodes
//!    moves roughly 1/(N+1) of the keys, nowhere near the ~N/(N+1) a
//!    modulo hash would reshuffle.

use claire::serve::router::placement::{Ring, DEFAULT_VNODES};
use claire::util::prop::{check_msg, Config};
use claire::util::rng::Rng;

fn random_key(r: &mut Rng) -> String {
    // Content-id-shaped keys: 32 hex chars.
    (0..32).map(|_| char::from_digit(r.below(16) as u32, 16).unwrap()).collect()
}

#[test]
fn placement_is_stable_across_ring_instances() {
    check_msg(
        Config { cases: 64, ..Config::default() },
        |r| (2 + r.below(7) as usize, random_key(r), 1 + r.below(3) as usize),
        |(nodes, key, replicas)| {
            let a = Ring::new(*nodes, DEFAULT_VNODES).place(key, *replicas, |_| true);
            let b = Ring::new(*nodes, DEFAULT_VNODES).place(key, *replicas, |_| true);
            if a != b {
                return Err(format!("same fleet, same key, different placement: {a:?} vs {b:?}"));
            }
            if a.len() != (*replicas).min(*nodes) {
                return Err(format!("wanted {replicas} distinct holders, got {a:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn load_is_balanced_over_uniform_keys() {
    // Primary-owner histogram over many random keys: with 64 vnodes the
    // max/min ratio stays within a small constant factor. The bound is
    // deliberately loose (4x) — we are guarding against pathological
    // skew (one node owning ~everything), not chasing perfect balance.
    check_msg(
        Config { cases: 8, ..Config::default() },
        |r| (2 + r.below(5) as usize, r.below(u64::MAX)),
        |(nodes, seed)| {
            let ring = Ring::new(*nodes, DEFAULT_VNODES);
            let mut counts = vec![0usize; *nodes];
            let mut r = Rng::new(*seed);
            let keys = 2000;
            for _ in 0..keys {
                counts[ring.place(&random_key(&mut r), 1, |_| true)[0]] += 1;
            }
            let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
            if *min == 0 || *max / *min >= 4 {
                return Err(format!("unbalanced primary ownership: {counts:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn adding_a_node_moves_about_one_in_n_plus_one_keys() {
    check_msg(
        Config { cases: 8, ..Config::default() },
        |r| (2 + r.below(5) as usize, r.below(u64::MAX)),
        |(nodes, seed)| {
            let before = Ring::new(*nodes, DEFAULT_VNODES);
            let after = Ring::new(*nodes + 1, DEFAULT_VNODES);
            let mut r = Rng::new(*seed);
            let keys = 2000;
            let mut moved = 0usize;
            for _ in 0..keys {
                let key = random_key(&mut r);
                if before.place(&key, 1, |_| true) != after.place(&key, 1, |_| true) {
                    moved += 1;
                }
            }
            // Expect ≈ keys/(nodes+1) moves; accept up to 2.5x that (vnode
            // granularity wobbles) and reject a modulo-style reshuffle,
            // which would move ≈ keys * nodes/(nodes+1).
            let expected = keys / (*nodes + 1);
            if moved > expected * 5 / 2 {
                return Err(format!(
                    "{moved}/{keys} keys moved going {nodes}->{} nodes (expected ~{expected})",
                    *nodes + 1
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn failover_spills_only_the_dead_nodes_keys() {
    // Killing one node must not move any key whose owner is still alive,
    // and the dead node's keys must land on live nodes.
    check_msg(
        Config { cases: 16, ..Config::default() },
        |r| (3 + r.below(4) as usize, r.below(u64::MAX)),
        |(nodes, seed)| {
            let ring = Ring::new(*nodes, DEFAULT_VNODES);
            let dead = (*seed % *nodes as u64) as usize;
            let mut r = Rng::new(*seed);
            for _ in 0..500 {
                let key = random_key(&mut r);
                let home = ring.place(&key, 1, |_| true)[0];
                let now = ring.place(&key, 1, |n| n != dead)[0];
                if home != dead && now != home {
                    return Err(format!(
                        "key {key} moved {home}->{now} though only node {dead} died"
                    ));
                }
                if home == dead && now == dead {
                    return Err(format!("key {key} still placed on dead node {dead}"));
                }
            }
            Ok(())
        },
    );
}
