//! Property tests for the template-building reduction math
//! (`registration::groupwise`), via the in-tree `util/prop.rs`
//! mini-framework:
//!
//! * the log-domain mean is identity-preserving — averaging k copies of
//!   one velocity returns it, and a template warped through the
//!   exponential of that zero-update mean is unchanged;
//! * `log_mean` / `mean_scalar` are invariant under permutation of
//!   their inputs (the `reduce` verb must not care about job order);
//! * the warped-image mean on a 16^3 grid matches a float64 reference
//!   computed outside Rust (fixture from `scripts/gen_reduce_fixture.py`,
//!   NumPy when available) at probed voxels, in L2, and in total mass.

use claire::field::{Field3, VecField3};
use claire::registration::groupwise::{
    exponential, log_mean, mean_scalar, rel_change, scale, warp_scalar,
};
use claire::util::json::Json;
use claire::util::prop::{self, Config};
use claire::util::rng::Rng;

fn gen_vec_field(r: &mut Rng, n: usize, amp: f32) -> VecField3 {
    VecField3::from_vec(n, prop::vec_f32(r, 3 * n * n * n, -amp, amp)).unwrap()
}

fn gen_field(r: &mut Rng, n: usize) -> Field3 {
    Field3::from_vec(n, prop::vec_f32(r, n * n * n, 0.0, 1.0)).unwrap()
}

#[test]
fn log_mean_of_identical_velocities_is_identity() {
    prop::check_msg(
        Config { cases: 32, ..Config::default() },
        |r| {
            let n = prop::pow2(r, 4, 8);
            let k = 2 + r.below(5) as usize;
            (gen_vec_field(r, n, 0.3), k)
        },
        |(v, k)| {
            let copies: Vec<&VecField3> = std::iter::repeat(v).take(*k).collect();
            let mean = log_mean(&copies).map_err(|e| e.to_string())?;
            // k identical f32 addends accumulate exactly in f64 and the
            // division by k restores each sample bit-for-bit.
            if mean != *v {
                return Err(format!("mean of {k} copies differs from the input"));
            }
            Ok(())
        },
    );
}

#[test]
fn zero_update_mean_leaves_template_unchanged() {
    // If the cohort's velocities cancel (here: v and -v), the log-domain
    // mean is zero, its exponential is the identity map, and warping the
    // template through it is a no-op — the fixed-point property the
    // template loop's convergence test relies on.
    prop::check_msg(
        Config { cases: 16, ..Config::default() },
        |r| {
            let n = prop::pow2(r, 4, 8);
            (gen_vec_field(r, n, 0.2), gen_field(r, n))
        },
        |(v, template)| {
            let neg = scale(v, -1.0);
            let mean = log_mean(&[v, &neg]).map_err(|e| e.to_string())?;
            if mean.data.iter().any(|&x| x != 0.0) {
                return Err("mean of v and -v is not exactly zero".into());
            }
            let warped =
                warp_scalar(template, &exponential(&mean)).map_err(|e| e.to_string())?;
            let d = rel_change(&warped, template).map_err(|e| e.to_string())?;
            if d > 1e-6 {
                return Err(format!("zero-velocity warp moved the template: delta {d:e}"));
            }
            Ok(())
        },
    );
}

#[test]
fn reductions_are_permutation_invariant() {
    prop::check_msg(
        Config { cases: 32, ..Config::default() },
        |r| {
            let n = prop::pow2(r, 4, 8);
            let k = 2 + r.below(5) as usize;
            let vels: Vec<VecField3> = (0..k).map(|_| gen_vec_field(r, n, 0.3)).collect();
            let imgs: Vec<Field3> = (0..k).map(|_| gen_field(r, n)).collect();
            let mut perm: Vec<usize> = (0..k).collect();
            r.shuffle(&mut perm);
            (vels, imgs, perm)
        },
        |(vels, imgs, perm)| {
            let fwd: Vec<&VecField3> = vels.iter().collect();
            let shuf: Vec<&VecField3> = perm.iter().map(|&i| &vels[i]).collect();
            let a = log_mean(&fwd).map_err(|e| e.to_string())?;
            let b = log_mean(&shuf).map_err(|e| e.to_string())?;
            // f64 accumulation of <=6 f32 addends; reassociation under
            // the permutation stays within one f32 ulp of each sample.
            for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
                if (x - y).abs() > 1e-6 * x.abs().max(1.0) {
                    return Err(format!("log_mean sample {i}: {x} vs {y} under {perm:?}"));
                }
            }
            let fwd_s: Vec<&Field3> = imgs.iter().collect();
            let shuf_s: Vec<&Field3> = perm.iter().map(|&i| &imgs[i]).collect();
            let a = mean_scalar(&fwd_s).map_err(|e| e.to_string())?;
            let b = mean_scalar(&shuf_s).map_err(|e| e.to_string())?;
            for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
                if (x - y).abs() > 1e-6 * x.abs().max(1.0) {
                    return Err(format!("mean_scalar sample {i}: {x} vs {y} under {perm:?}"));
                }
            }
            Ok(())
        },
    );
}

// -- Fixture cross-check ------------------------------------------------------

/// The 32-bit LCG from `scripts/gen_reduce_fixture.py`, bit-exact: f32
/// samples of `state / 2^32` with state advanced as `a*s + c mod 2^32`.
fn lcg_volume(n: usize, seed: u64, a: u64, c: u64, subject: u64) -> Vec<f32> {
    const MOD: u64 = 1 << 32;
    let mut state = (seed + subject * 9973) % MOD;
    (0..n * n * n)
        .map(|_| {
            state = (a.wrapping_mul(state).wrapping_add(c)) % MOD;
            (state as f64 / MOD as f64) as f32
        })
        .collect()
}

#[test]
fn warped_mean_matches_float64_fixture() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/reduce_mean_16.json");
    let text = std::fs::read_to_string(path).expect("fixture present (scripts/gen_reduce_fixture.py)");
    let j = Json::parse(&text).unwrap();
    let n = j.get("n").and_then(Json::as_usize).unwrap();
    let k = j.get("k").and_then(Json::as_usize).unwrap();
    let seed = j.get("seed").and_then(Json::as_f64).unwrap() as u64;
    let a = j.get("lcg_a").and_then(Json::as_f64).unwrap() as u64;
    let c = j.get("lcg_c").and_then(Json::as_f64).unwrap() as u64;

    let vols: Vec<Field3> = (0..k as u64)
        .map(|s| Field3::from_vec(n, lcg_volume(n, seed, a, c, s)).unwrap())
        .collect();
    let refs: Vec<&Field3> = vols.iter().collect();
    let mean = mean_scalar(&refs).unwrap();

    let probes = j.get("probe_indices").and_then(Json::as_arr).unwrap();
    let expected = j.get("mean_probes").and_then(Json::as_arr).unwrap();
    assert_eq!(probes.len(), expected.len());
    for (pi, pe) in probes.iter().zip(expected) {
        let idx = pi.as_usize().unwrap();
        let want = pe.as_f64().unwrap();
        let got = mean.data[idx] as f64;
        // The fixture keeps full f64 precision; the crate's f64
        // accumulate + f32 store rounds once at the end.
        assert!(
            (got - want).abs() <= 1e-6,
            "probe {idx}: rust {got} vs fixture {want}"
        );
    }

    let (mut l2, mut total) = (0.0f64, 0.0f64);
    for &x in &mean.data {
        l2 += (x as f64) * (x as f64);
        total += x as f64;
    }
    let l2 = l2.sqrt();
    let want_l2 = j.get("mean_l2").and_then(Json::as_f64).unwrap();
    let want_sum = j.get("mean_sum").and_then(Json::as_f64).unwrap();
    assert!((l2 - want_l2).abs() <= 1e-4 * want_l2, "L2 {l2} vs {want_l2}");
    assert!(
        (total - want_sum).abs() <= 1e-4 * want_sum.abs(),
        "sum {total} vs {want_sum}"
    );
}
