//! Loom model checking of the serve core (`cfg(loom)` builds only).
//!
//! Run with:
//!
//! ```sh
//! RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 \
//!     cargo test --release --test loom_serve
//! ```
//!
//! Under `--cfg loom`, `util::sync` swaps the scheduler's Mutex/Condvar/
//! atomics for loom's model-checked versions, and loom explores every
//! interleaving of the threads spawned inside each `loom::model` closure
//! (bounded by `LOOM_MAX_PREEMPTIONS`; unset = exhaustive). A missed
//! notify or lock-order deadlock shows up as a loom "deadlock: all
//! threads blocked" failure with the interleaving that produced it.
//!
//! Five scenarios cover the scheduler races that matter:
//!   1. submit vs cancel on a queued job — exactly one terminal outcome
//!   2. coalesce dwell vs other-priority arrival — the dweller must
//!      re-notify after re-pushing non-matching work (the missed-notify
//!      fix in `next_batch`; reverting it makes loom report a deadlock
//!      here)
//!   3. event-bus publish vs a slow/terminating subscriber — in-order
//!      prefix, terminal `Lagged`, no duplicates
//!   4. concurrent dedup resubmission — one admission, both callers get
//!      the same id
//!   5. shutdown(drain) vs worker dispatch — every admitted job still
//!      completes; late submits are refused
//!
//! Notes on fidelity: wall-clock (`Instant`) is NOT modeled by loom, so
//! the dwell scenario uses a window far beyond any model run and relies
//! on notifies (interrupt, shutdown) — never the deadline — to finish.
//! Executors are inline stubs (`stub_report`); no TCP or PJRT here.

#![cfg(loom)]

use claire::serve::scheduler::stub_report;
use claire::serve::{BusMsg, JobPayload, JobSpec, JobState, Priority, Scheduler};
use loom::thread;

fn spec(subject: &str, priority: Priority) -> JobPayload {
    JobPayload::Spec(JobSpec { subject: subject.into(), priority, ..Default::default() })
}

/// 1. A queued job raced by cancel and a dispatching worker lands in
/// exactly one terminal state, and the admission counters agree.
#[test]
fn submit_vs_cancel_queued() {
    loom::model(|| {
        let sched = Scheduler::new(4, 1);
        let id = sched.submit(Priority::Normal, spec("a", Priority::Normal)).unwrap();

        let s = sched.clone();
        let canceller = thread::spawn(move || {
            // Queued -> cancelled directly; Running -> sets the flag (the
            // stub completes Ok, so that arm lands in Done). Both legal.
            let _ = s.cancel(id);
            s.shutdown(true);
        });
        let s = sched.clone();
        let worker = thread::spawn(move || {
            // Drain: stale heap entries for the cancelled job are skipped;
            // None once the queue is empty under Drain.
            while let Some((jid, _payload)) = s.next_job(0) {
                s.complete(jid, Ok(stub_report("a").into()), 0.0);
            }
        });
        canceller.join().unwrap();
        worker.join().unwrap();

        let state = sched.status(id).expect("job is retained").state;
        assert!(
            state == JobState::Done || state == JobState::Cancelled,
            "non-terminal state {state:?} after both racers joined"
        );
        let stats = sched.stats();
        assert_eq!(stats.submitted, 1);
        assert_eq!(stats.completed + stats.cancelled, 1, "exactly one terminal outcome");
        assert_eq!(stats.queued, 0);
        assert_eq!(stats.running, 0);
    });
}

/// 2. A worker dwelling on a partial batch races an other-priority
/// arrival. The arrival's notify may be consumed by the dweller, which
/// sets the job aside and re-pushes it; without the `notify_all` in
/// `next_batch` the second worker sleeps forever with work queued (loom
/// reports the deadlock). The dwell window is far beyond any model run,
/// so only notifies can end the dwell — which is exactly the property
/// under test.
#[test]
fn dwell_interrupt_renotifies() {
    loom::model(|| {
        let sched = Scheduler::new(8, 2);
        sched.set_coalesce(4, 60_000);
        let lead = sched.submit(Priority::Batch, spec("lead", Priority::Batch)).unwrap();

        let s = sched.clone();
        let dweller = thread::spawn(move || {
            // One batch, no loop: a looping worker would re-pop the
            // re-pushed urgent job itself and rescue a lost wakeup, hiding
            // the very bug this scenario exists to expose. `None` is legal
            // when `second` served the only admitted job and drained.
            if let Some(batch) = s.next_batch(0) {
                for (jid, _payload) in batch {
                    s.complete(jid, Ok(stub_report("b").into()), 0.0);
                }
            }
        });
        let s = sched.clone();
        let second = thread::spawn(move || {
            // The urgent arrival never coalesces into the dweller's batch
            // (priority mismatch), so this pop is the only way it runs
            // when the dweller consumed its notify. Shutdown afterwards —
            // and only afterwards — releases the dweller from its window;
            // an earlier shutdown would mask a missed notify.
            if let Some((jid, _payload)) = s.next_job(1) {
                s.complete(jid, Ok(stub_report("u").into()), 0.0);
            }
            s.shutdown(true);
        });
        let s = sched.clone();
        let submitter = thread::spawn(move || {
            // May be refused when `second` already served the lead and
            // flipped to Drain; the scenario's liveness property holds
            // either way.
            s.submit(Priority::Emergency, spec("urgent", Priority::Emergency)).is_ok()
        });

        let admitted = submitter.join().unwrap();
        second.join().unwrap();
        dweller.join().unwrap();

        let stats = sched.stats();
        // Two pops exist (dweller's batch + second's single), urgent never
        // joins the batch, so every admitted job completes — provided no
        // wakeup was lost (loom reports the deadlock otherwise).
        assert_eq!(stats.completed, if admitted { 2 } else { 1 });
        assert_eq!(stats.queued, 0);
        assert_eq!(stats.running, 0);
        assert_eq!(sched.status(lead).unwrap().state, JobState::Done);
    });
}

/// 3. Publish vs a bounded subscriber: the consumer sees an in-order
/// prefix of the published events; a terminal `Lagged` only ever arrives
/// last; closing the subscription ends the stream with `None`.
#[test]
fn event_bus_publish_vs_lag() {
    loom::model(|| {
        let sched = Scheduler::new(4, 1);
        let handle = sched.watch_with_cap(1);
        let sub_id = handle.id();

        let consumer = thread::spawn(move || {
            let mut ids = Vec::new();
            let mut lagged = false;
            while let Some(msg) = handle.recv() {
                assert!(!lagged, "message delivered after the terminal Lagged marker");
                match msg {
                    BusMsg::Event(ev) => ids.push(ev.id),
                    BusMsg::Lagged => lagged = true,
                }
            }
            (ids, lagged)
        });
        let s = sched.clone();
        let publisher = thread::spawn(move || {
            let a = s.submit(Priority::Normal, spec("a", Priority::Normal)).unwrap();
            let b = s.submit(Priority::Normal, spec("b", Priority::Normal)).unwrap();
            // Close the stream so the consumer's recv loop terminates even
            // when it kept up (no Lagged marker).
            s.unwatch(sub_id);
            (a, b)
        });

        let (a, b) = publisher.join().unwrap();
        let (ids, _lagged) = consumer.join().unwrap();
        // In-order prefix of [a, b]: possibly empty (closed or lagged
        // before draining), never reordered, never duplicated.
        let expect = [a, b];
        assert!(ids.len() <= 2, "more events than published: {ids:?}");
        assert_eq!(ids.as_slice(), &expect[..ids.len()], "not an in-order prefix");
    });
}

/// 4. Two racing resubmissions with one exactly-once token admit one job;
/// both callers get the same id.
#[test]
fn concurrent_dedup_admits_once() {
    loom::model(|| {
        let sched = Scheduler::new(4, 1);
        let submit = |s: Scheduler| {
            move || {
                s.submit_dedup(
                    Priority::Normal,
                    spec("dup", Priority::Normal),
                    Some("tok-1".to_string()),
                )
                .unwrap()
            }
        };
        let t1 = thread::spawn(submit(sched.clone()));
        let t2 = thread::spawn(submit(sched.clone()));
        let id1 = t1.join().unwrap();
        let id2 = t2.join().unwrap();

        assert_eq!(id1, id2, "dedup token admitted two distinct jobs");
        assert_eq!(sched.stats().submitted, 1);
        assert_eq!(sched.jobs().len(), 1);
    });
}

/// 5. shutdown(drain) racing a dispatching worker: every admitted job
/// still completes, the worker's pop loop terminates, and submits after
/// the mode flips are refused.
#[test]
fn shutdown_drain_vs_dispatch() {
    loom::model(|| {
        let sched = Scheduler::new(4, 1);
        sched.submit(Priority::Normal, spec("a", Priority::Normal)).unwrap();
        sched.submit(Priority::Normal, spec("b", Priority::Normal)).unwrap();

        let s = sched.clone();
        let worker = thread::spawn(move || {
            while let Some((jid, _payload)) = s.next_job(0) {
                s.complete(jid, Ok(stub_report("d").into()), 0.0);
            }
        });
        let s = sched.clone();
        let stopper = thread::spawn(move || {
            s.shutdown(true);
            // Drain refuses new work but serves what was admitted.
            s.submit(Priority::Normal, spec("late", Priority::Normal)).unwrap_err()
        });

        let err = stopper.join().unwrap();
        worker.join().unwrap();

        assert!(err.to_string().contains("shutting down"), "late submit error: {err}");
        let stats = sched.stats();
        assert_eq!(stats.completed, 2, "drain served every admitted job");
        assert_eq!(stats.submitted, 2);
        assert_eq!(stats.queued, 0);
        assert!(sched.idle());
    });
}
