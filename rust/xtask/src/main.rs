//! `cargo xtask lint` / `cargo xtask analyze` — the repo's invariant
//! gates (canonical CI entries).
//!
//! Table-driven source analysis of `rust/src` + `DESIGN.md`. The rule list
//! is defined ONCE conceptually and implemented twice: here (when a Rust
//! toolchain is present) and in `scripts/lint_invariants.py` /
//! `scripts/analyze_invariants.py` (dependency-free mirrors for
//! toolchain-less containers). Rule IDs, semantics, and the tables below
//! must stay in lockstep with the Python mirrors.
//!
//!   R1 shim-imports   no direct `std::sync::{Mutex,Condvar,RwLock,atomic}`
//!                     or `std::thread` outside `util/sync.rs` (`Arc` is
//!                     allowed — the shim re-exports std's Arc under loom).
//!   R2 lock-order     serve/scheduler.rs: Inner.st(1) < sink(2) < subs(3)
//!                     < events(4); nested `.lock()` scopes must not invert.
//!   R3 store-journal  the volume-store lock is never held across a
//!                     journal write.
//!   R4 error-codes    error.rs::ErrorCode in sync with DESIGN.md's
//!                     "Structured errors" registry (backtick presence for
//!                     every code; retryable + exit match for table rows).
//!   R5 emit-guards    every emission site of a field declared in
//!                     DESIGN.md's "#### Conditional wire fields" table
//!                     stays behind a conditional (`if` opener before
//!                     `fn`). Obligations are parsed from that table (no
//!                     hand-maintained needle list); `analyze` checks the
//!                     table itself for completeness against the source,
//!                     closing the drift loop in both directions.
//!   R6 template-sync  the template subsystem and the reduce verb's
//!                     module must take sync primitives through the
//!                     `util/sync.rs` shim: any file under `template/`
//!                     (or serve/daemon.rs) that mentions Mutex/RwLock/
//!                     Condvar/`thread::` must import `crate::util::sync`.
//!
//! The semantic analyses (A1 lifecycle, A2 wire-schema, A3 panic-budget)
//! live in [`analyze`].

mod analyze;

use std::fs;
use std::path::{Path, PathBuf};

const SHIM_EXEMPT: &[&str] = &["util/sync.rs"];

/// (needle, human name, rank) — lower ranks must be taken first.
const LOCK_RANKS: &[(&str, &str, u32)] = &[
    ("inner.st.lock(", "Inner.st", 1),
    (".sink.lock(", "sink", 2),
    (".subs.lock(", "subs", 3),
    (".events.lock(", "events", 4),
];

const LOCK_ORDER_FILE: &str = "serve/scheduler.rs";
const STORE_JOURNAL_FILE: &str = "serve/store.rs";
const STORE_JOURNAL_TOKENS: &[&str] = &["journal", ".append("];
const DESIGN_SECTION: &str = "### Structured errors";

/// R5's (file, field) obligations are parsed from this DESIGN.md table —
/// the same table `analyze` checks for completeness against the source.
const EMIT_GUARDS_SECTION: &str = "#### Conditional wire fields";

/// R6 scope: template subsystem files (prefix) + the reduce verb's home.
const TEMPLATE_SYNC_SCOPE: &[&str] = &["template/", "serve/daemon.rs"];
const TEMPLATE_SYNC_TOKENS: &[&str] = &["Mutex", "RwLock", "Condvar", "thread::"];
const TEMPLATE_SYNC_SHIM: &str = "crate::util::sync";

struct Lint {
    repo: PathBuf,
    src: PathBuf,
    violations: Vec<String>,
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("lint");
    // xtask lives at <repo>/rust/xtask; walk up to the repo root.
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let rust_dir = manifest.parent().expect("xtask has a parent").to_path_buf();
    let repo = rust_dir.parent().expect("rust/ has a parent").to_path_buf();
    let src = rust_dir.join("src");
    match cmd {
        "lint" => {
            let mut lint = Lint { src, repo, violations: Vec::new() };
            lint.rule_shim_imports();
            lint.rule_lock_order();
            lint.rule_store_journal();
            lint.rule_error_codes();
            lint.rule_emit_guards();
            lint.rule_template_sync();
            finish("xtask lint", "shim-imports, lock-order, store-journal, \
                    error-codes, emit-guards, template-sync", lint.violations);
        }
        "analyze" => {
            let mut an = analyze::Analyze::new(repo, src);
            an.run();
            finish(
                "xtask analyze",
                "lifecycle, wire-schema, panic-budget; artifacts/lifecycle.dot \
                 + artifacts/wire_schema.json written",
                an.violations,
            );
        }
        _ => {
            eprintln!("usage: cargo xtask [lint|analyze]");
            std::process::exit(2);
        }
    }
}

fn finish(what: &str, passes: &str, violations: Vec<String>) {
    if violations.is_empty() {
        println!("{what}: OK ({passes})");
    } else {
        for v in &violations {
            println!("{v}");
        }
        println!("{what}: {} violation(s)", violations.len());
        std::process::exit(1);
    }
}

fn strip_comment(line: &str) -> &str {
    // Good enough for this tree: no `//` inside string literals on the
    // lines these rules look at.
    match line.find("//") {
        Some(i) => &line[..i],
        None => line,
    }
}

fn brace_delta(line: &str) -> i64 {
    let opens = line.matches('{').count() as i64;
    let closes = line.matches('}').count() as i64;
    opens - closes
}

/// `let [mut] NAME = ... .lock().unwrap();` — the guard itself is bound
/// (statement ends right at `.unwrap();`), so it lives to end of block.
fn guard_binding(line: &str) -> Option<String> {
    let t = line.trim();
    if !t.starts_with("let ") || !t.ends_with(".lock().unwrap();") {
        return None;
    }
    let rest = t[4..].trim_start().strip_prefix("mut ").unwrap_or(&t[4..]);
    let name: String = rest
        .trim_start()
        .chars()
        .take_while(|c| c.is_alphanumeric() || *c == '_')
        .collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

fn drop_call(line: &str) -> Option<String> {
    let i = line.find("drop(")?;
    let name: String = line[i + 5..]
        .trim_start()
        .chars()
        .take_while(|c| c.is_alphanumeric() || *c == '_')
        .collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

/// A lock guard currently in scope.
struct Held {
    name: &'static str,
    rank: Option<u32>,
    var: String,
    depth: i64,
}

impl Lint {
    fn flag(&mut self, path: &Path, lineno: usize, rule: &str, msg: &str) {
        let rel = path
            .strip_prefix(&self.repo)
            .unwrap_or(path)
            .display()
            .to_string();
        self.violations.push(format!("{rel}:{lineno}: [{rule}] {msg}"));
    }

    fn rs_files(&self) -> Vec<PathBuf> {
        let mut out = Vec::new();
        let mut stack = vec![self.src.clone()];
        while let Some(dir) = stack.pop() {
            let Ok(entries) = fs::read_dir(&dir) else { continue };
            for entry in entries.flatten() {
                let p = entry.path();
                if p.is_dir() {
                    stack.push(p);
                } else if p.extension().is_some_and(|e| e == "rs") {
                    out.push(p);
                }
            }
        }
        out.sort();
        out
    }

    // R1 -------------------------------------------------------------------

    fn rule_shim_imports(&mut self) {
        for path in self.rs_files() {
            let rel = path
                .strip_prefix(&self.src)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            if SHIM_EXEMPT.contains(&rel.as_str()) {
                continue;
            }
            let Ok(text) = fs::read_to_string(&path) else { continue };
            for (i, raw) in text.lines().enumerate() {
                let code = strip_comment(raw);
                if let Some(why) = shim_forbidden(code) {
                    self.flag(
                        &path,
                        i + 1,
                        "shim-imports",
                        &format!(
                            "direct std sync/thread use ({why}); import via \
                             crate::util::sync instead"
                        ),
                    );
                }
            }
        }
    }

    // R2 / R3 --------------------------------------------------------------

    fn scan_lock_scopes<F, G>(&mut self, path: &Path, mut on_acquire: F, mut on_line: G)
    where
        F: FnMut(&mut Lint, usize, &str, &[Held]),
        G: FnMut(&mut Lint, usize, &str, &[Held]),
    {
        let Ok(text) = fs::read_to_string(path) else { return };
        let mut held: Vec<Held> = Vec::new();
        let mut depth: i64 = 0;
        for (i, raw) in text.lines().enumerate() {
            let line = strip_comment(raw);
            if let Some(var) = drop_call(line) {
                held.retain(|h| h.var != var);
            }
            on_line(self, i + 1, line, &held);
            if line.contains(".lock(") {
                on_acquire(self, i + 1, line, &held);
                if let Some(var) = guard_binding(line) {
                    let ranked = LOCK_RANKS
                        .iter()
                        .find(|(needle, _, _)| line.contains(needle));
                    held.push(match ranked {
                        Some(&(_, name, rank)) => Held { name, rank: Some(rank), var, depth },
                        None => Held { name: "unranked", rank: None, var, depth },
                    });
                }
            }
            depth += brace_delta(line);
            // A guard bound at depth d lives while depth >= d.
            held.retain(|h| depth >= h.depth);
        }
    }

    fn rule_lock_order(&mut self) {
        let path = self.src.join(LOCK_ORDER_FILE);
        self.scan_lock_scopes(
            &path.clone(),
            |lint, lineno, line, held| {
                let Some(&(_, name, rank)) =
                    LOCK_RANKS.iter().find(|(n, _, _)| line.contains(n))
                else {
                    return;
                };
                for h in held {
                    if h.rank.is_some_and(|hr| hr > rank) {
                        let msg = format!(
                            "acquires {name} (rank {rank}) while holding {} \
                             (rank {}); declared order is Inner.st < sink < \
                             subs < events",
                            h.name,
                            h.rank.unwrap()
                        );
                        lint.flag(&path, lineno, "lock-order", &msg);
                    }
                }
            },
            |_, _, _, _| {},
        );
    }

    fn rule_store_journal(&mut self) {
        let path = self.src.join(STORE_JOURNAL_FILE);
        self.scan_lock_scopes(
            &path.clone(),
            |_, _, _, _| {},
            |lint, lineno, line, held| {
                let lower = line.to_lowercase();
                if !held.is_empty() && STORE_JOURNAL_TOKENS.iter().any(|t| lower.contains(t)) {
                    lint.flag(
                        &path,
                        lineno,
                        "store-journal",
                        "journal write while the store lock is held",
                    );
                }
            },
        );
    }

    // R4 -------------------------------------------------------------------

    fn rule_error_codes(&mut self) {
        let err_path = self.src.join("error.rs");
        let design_path = self.repo.join("DESIGN.md");
        let Ok(err) = fs::read_to_string(&err_path) else {
            self.flag(&err_path, 1, "error-codes", "cannot read error.rs");
            return;
        };
        let Ok(design) = fs::read_to_string(&design_path) else {
            self.flag(&design_path, 1, "error-codes", "cannot read DESIGN.md");
            return;
        };
        let codes = parse_as_str(&err);
        if codes.is_empty() {
            self.flag(&err_path, 1, "error-codes", "could not parse ErrorCode::as_str");
            return;
        }
        let retryable = fn_body(&err, "fn retryable").map(collect_variants).unwrap_or_default();
        let exits = fn_body(&err, "fn exit_code").map(parse_exit_arms).unwrap_or_default();

        let Some(start) = design.find(DESIGN_SECTION) else {
            self.flag(&design_path, 1, "error-codes", "section '### Structured errors' not found");
            return;
        };
        let tail = &design[start..];
        let end = tail[1..].find("\n### ").map(|i| i + 1).unwrap_or(tail.len());
        let section = &tail[..end];
        let sec_line = design[..start].lines().count() + 1;

        for (wire, retry, exit_code) in parse_table_rows(section) {
            let Some(var) = codes.iter().find(|(_, w)| *w == wire).map(|(v, _)| v.clone())
            else {
                let msg = format!("table lists `{wire}` but error.rs has no such code");
                self.flag(&design_path, sec_line, "error-codes", &msg);
                continue;
            };
            let code_retry = if retryable.contains(&var) { "yes" } else { "no" };
            if code_retry != retry {
                let msg = format!(
                    "`{wire}`: table says retryable={retry}, error.rs says {code_retry}"
                );
                self.flag(&design_path, sec_line, "error-codes", &msg);
            }
            if exits.get(&var).copied() != Some(exit_code) {
                let msg = format!(
                    "`{wire}`: table says exit {exit_code}, error.rs says {:?}",
                    exits.get(&var)
                );
                self.flag(&design_path, sec_line, "error-codes", &msg);
            }
        }
        for (var, wire) in &codes {
            if !section.contains(&format!("`{wire}`")) {
                let msg = format!(
                    "ErrorCode::{var} (`{wire}`) is not documented in DESIGN.md's \
                     '### Structured errors' section"
                );
                self.flag(&err_path, 1, "error-codes", &msg);
            }
        }
    }

    // R5 -------------------------------------------------------------------

    /// `(rel file, field)` rows from DESIGN.md's declared table.
    fn emit_guard_obligations(&mut self) -> Vec<(String, String)> {
        let design_path = self.repo.join("DESIGN.md");
        let Ok(design) = fs::read_to_string(&design_path) else {
            self.flag(&design_path, 1, "emit-guards", "cannot read DESIGN.md");
            return Vec::new();
        };
        let Some(start) = design.find(EMIT_GUARDS_SECTION) else {
            self.flag(
                &design_path,
                1,
                "emit-guards",
                &format!("section {EMIT_GUARDS_SECTION:?} not found"),
            );
            return Vec::new();
        };
        let tail = &design[start..];
        let mut end = tail.len();
        for stop in ["\n## ", "\n### ", "\n#### "] {
            if let Some(i) = tail[1..].find(stop) {
                end = end.min(i + 1);
            }
        }
        let rows = parse_field_rows(&tail[..end]);
        if rows.is_empty() {
            self.flag(
                &design_path,
                design[..start].lines().count() + 1,
                "emit-guards",
                &format!("{EMIT_GUARDS_SECTION:?} holds no | `file` | `field` | rows"),
            );
        }
        rows
    }

    fn rule_emit_guards(&mut self) {
        for (rel, field) in self.emit_guard_obligations() {
            let path = self.src.join(&rel);
            let Ok(text) = fs::read_to_string(&path) else {
                let msg = format!(
                    "DESIGN.md declares conditional field `{field}` in a \
                     file that does not exist (stale row?)"
                );
                self.flag(&path, 1, "emit-guards", &msg);
                continue;
            };
            let lines: Vec<&str> = text.lines().collect();
            let sites = emission_sites(&lines, &field);
            for &i in &sites {
                if !is_guarded(&lines, i) {
                    let msg = format!(
                        "`{field}` emitted unconditionally — this field is \
                         emit-only-when-present for wire/journal back-compat"
                    );
                    self.flag(&path, i + 1, "emit-guards", &msg);
                }
            }
            if sites.is_empty() {
                let msg = format!(
                    "declared conditional field `{field}` has no emission \
                     site (stale DESIGN.md row?)"
                );
                self.flag(&path, 1, "emit-guards", &msg);
            }
        }
    }

    // R6 -------------------------------------------------------------------

    /// Template/reduce modules must take sync primitives through the
    /// `util/sync.rs` shim. R1 already bans `std::sync` tree-wide; this
    /// rule additionally requires the *positive* shim import in the new
    /// subsystem — a scoped file mentioning a sync primitive without a
    /// `crate::util::sync` path is flagged even if the primitive comes
    /// from somewhere R1 does not know about.
    fn rule_template_sync(&mut self) {
        for path in self.rs_files() {
            let rel = path
                .strip_prefix(&self.src)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            let scoped = TEMPLATE_SYNC_SCOPE
                .iter()
                .any(|s| rel == *s || (s.ends_with('/') && rel.starts_with(s)));
            if !scoped {
                continue;
            }
            let Ok(text) = fs::read_to_string(&path) else { continue };
            let has_shim = text.contains(TEMPLATE_SYNC_SHIM);
            for (i, raw) in text.lines().enumerate() {
                let code = strip_comment(raw);
                let Some(tok) =
                    TEMPLATE_SYNC_TOKENS.iter().find(|t| code.contains(*t))
                else {
                    continue;
                };
                if !has_shim {
                    let msg = format!(
                        "uses sync primitive `{tok}` but never imports \
                         {TEMPLATE_SYNC_SHIM} — template/reduce modules must \
                         go through the util/sync.rs shim"
                    );
                    self.flag(&path, i + 1, "template-sync", &msg);
                    break; // one flag per file is enough signal
                }
            }
        }
    }
}

/// Which forbidden-pattern did this line hit, if any (mirror of the Python
/// SHIM_FORBIDDEN list)?
fn shim_forbidden(code: &str) -> Option<&'static str> {
    if code.contains("use std::sync::atomic") {
        return Some("use std::sync::atomic");
    }
    if let Some(i) = code.find("use std::sync::") {
        let rest = code[i..].split(';').next().unwrap_or("");
        for t in ["Mutex", "Condvar", "RwLock", "Barrier", "Once"] {
            if has_word(rest, t) {
                return Some("use std::sync::{Mutex|Condvar|RwLock|Barrier|Once}");
            }
        }
    }
    if code.contains("use std::thread") {
        return Some("use std::thread");
    }
    for t in ["std::sync::Mutex", "std::sync::Condvar", "std::sync::RwLock"] {
        if code.contains(t) {
            return Some("inline std::sync::{Mutex|Condvar|RwLock}");
        }
    }
    if code.contains("std::sync::atomic::") {
        return Some("inline std::sync::atomic::");
    }
    if code.contains("std::thread::") {
        return Some("inline std::thread::");
    }
    None
}

fn has_word(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(i) = code[from..].find(word) {
        let start = from + i;
        let end = start + word.len();
        let left_ok = start == 0 || !(bytes[start - 1].is_ascii_alphanumeric() || bytes[start - 1] == b'_');
        let right_ok =
            end >= bytes.len() || !(bytes[end].is_ascii_alphanumeric() || bytes[end] == b'_');
        if left_ok && right_ok {
            return true;
        }
        from = end;
    }
    false
}

/// Leading | `file` | `field` | cells of the declared conditional-field
/// table rows (header/separator rows carry no backticks and are skipped).
fn parse_field_rows(section: &str) -> Vec<(String, String)> {
    let tick = |s: &str| {
        s.len() > 2
            && s.starts_with('`')
            && s.ends_with('`')
            && s[1..s.len() - 1]
                .chars()
                .all(|c| c.is_alphanumeric() || matches!(c, '_' | '/' | '.'))
    };
    let mut out = Vec::new();
    for line in section.lines() {
        let t = line.trim();
        if !t.starts_with('|') {
            continue;
        }
        let cells: Vec<&str> = t.split('|').map(str::trim).collect();
        // ["", "`file`", "`field`", when, ""]
        if cells.len() < 4 || !tick(cells[1]) || !tick(cells[2]) {
            continue;
        }
        let (file, field) = (cells[1], cells[2]);
        if field[1..field.len() - 1].contains(['/', '.']) {
            continue; // field cells are bare identifiers
        }
        out.push((
            file[1..file.len() - 1].to_string(),
            field[1..field.len() - 1].to_string(),
        ));
    }
    out
}

/// Line indices emitting `field` via the post-hoc insert/push idioms
/// (including the two-line rustfmt split), non-test code only. Shared by
/// R5 and the wire-schema analysis.
fn emission_sites(lines: &[&str], field: &str) -> Vec<usize> {
    let single_insert = format!(".insert(\"{field}\"");
    let single_push = format!(".push((\"{field}\"");
    let continuation = format!("\"{field}\"");
    let mut sites = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        if raw.contains("#[cfg(test)]") {
            break; // test modules are file-final by crate convention
        }
        let code = strip_comment(raw);
        if code.contains(&single_insert) || code.contains(&single_push) {
            sites.push(i);
        } else if (code.trim_end().ends_with(".push((") || code.trim_end().ends_with(".insert("))
            && i + 1 < lines.len()
            && strip_comment(lines[i + 1]).trim_start().starts_with(&continuation)
        {
            sites.push(i);
        }
    }
    sites
}

/// Climb enclosing openers outward from line `i`: an `if` opener before
/// any `fn` opener means the site is conditionally reached.
fn is_guarded(lines: &[&str], i: usize) -> bool {
    let mut bal: i64 = 0;
    for j in (0..i).rev() {
        let code = strip_comment(lines[j]);
        bal += brace_delta(code);
        if bal > 0 {
            // An enclosing opener.
            if has_word(code, "if") {
                return true;
            }
            if has_word(code, "fn") {
                return false;
            }
            bal = 0; // consumed this level; keep climbing
        }
    }
    false
}

/// `ErrorCode::Variant => "wire",` pairs from as_str (and parse, harmlessly —
/// identical pairs reversed are deduped by the Vec contains check).
fn parse_as_str(err: &str) -> Vec<(String, String)> {
    let mut out: Vec<(String, String)> = Vec::new();
    let mut rest = err;
    while let Some(i) = rest.find("ErrorCode::") {
        rest = &rest[i + 11..];
        let var: String = rest
            .chars()
            .take_while(|c| c.is_alphanumeric() || *c == '_')
            .collect();
        let after = &rest[var.len()..];
        let Some(arrow) = after.find("=>") else { continue };
        let tail = after[arrow + 2..].trim_start();
        if let Some(stripped) = tail.strip_prefix('"') {
            let wire: String = stripped.chars().take_while(|c| *c != '"').collect();
            if !var.is_empty()
                && !wire.is_empty()
                && !out.iter().any(|(v, _)| *v == var)
            {
                out.push((var, wire));
            }
        }
    }
    out
}

/// Body of `fn name ... { ... }` up to the `\n    }` that closes a method at
/// impl-block indentation (same heuristic as the Python mirror).
fn fn_body<'a>(text: &'a str, name: &str) -> Option<&'a str> {
    let start = text.find(name)?;
    let open = text[start..].find('{')? + start;
    let close = text[open..].find("\n    }")? + open;
    Some(&text[open..close])
}

fn collect_variants(body: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = body;
    while let Some(i) = rest.find("ErrorCode::") {
        rest = &rest[i + 11..];
        let var: String = rest
            .chars()
            .take_while(|c| c.is_alphanumeric() || *c == '_')
            .collect();
        if !var.is_empty() && !out.contains(&var) {
            out.push(var);
        }
    }
    out
}

/// `ErrorCode::A | ErrorCode::B => 75,` arms → {A: 75, B: 75}.
fn parse_exit_arms(body: &str) -> std::collections::HashMap<String, u32> {
    let mut out = std::collections::HashMap::new();
    for line in body.lines() {
        let Some(arrow) = line.find("=>") else { continue };
        let num: String = line[arrow + 2..]
            .trim_start()
            .chars()
            .take_while(|c| c.is_ascii_digit())
            .collect();
        let Ok(exit) = num.parse::<u32>() else { continue };
        for var in collect_variants(&line[..arrow]) {
            out.insert(var, exit);
        }
    }
    out
}

/// `| \`code\` | meaning | yes/no | exit |` rows.
fn parse_table_rows(section: &str) -> Vec<(String, &'static str, u32)> {
    let mut out = Vec::new();
    for line in section.lines() {
        let t = line.trim();
        if !t.starts_with('|') {
            continue;
        }
        let cells: Vec<&str> = t.split('|').map(str::trim).collect();
        // ["", "`code`", meaning, yes/no, exit, ""]
        if cells.len() < 6 {
            continue;
        }
        let code = cells[1];
        if !(code.starts_with('`') && code.ends_with('`') && code.len() > 2) {
            continue;
        }
        let retry = match cells[3] {
            "yes" => "yes",
            "no" => "no",
            _ => continue,
        };
        let Ok(exit) = cells[4].parse::<u32>() else { continue };
        out.push((code[1..code.len() - 1].to_string(), retry, exit));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a Lint over a throwaway src tree.
    fn fixture(name: &str, files: &[(&str, &str)]) -> Lint {
        let root = std::env::temp_dir()
            .join(format!("claire-xtask-lint-{}-{name}", std::process::id()));
        let _ = fs::remove_dir_all(&root);
        let src = root.join("src");
        for (rel, body) in files {
            let p = src.join(rel);
            fs::create_dir_all(p.parent().unwrap()).unwrap();
            fs::write(&p, body).unwrap();
        }
        Lint { repo: root.clone(), src, violations: Vec::new() }
    }

    // R6 negative: a template/ file reaching for a sync primitive without
    // the shim import is flagged; the shim-importing twin is not.
    #[test]
    fn template_sync_flags_shimless_primitives() {
        let mut lint = fixture(
            "r6",
            &[
                (
                    "template/bad.rs",
                    "use other::sync::Mutex;\nfn f() { let _ = Mutex::new(0); }\n",
                ),
                (
                    "template/good.rs",
                    "use crate::util::sync::Mutex;\nfn f() { let _ = Mutex::new(0); }\n",
                ),
                // Out of scope: primitives elsewhere are R1's business.
                (
                    "serve/router/mod.rs",
                    "use other::sync::RwLock;\nfn f() { let _ = RwLock::new(0); }\n",
                ),
            ],
        );
        lint.rule_template_sync();
        assert_eq!(lint.violations.len(), 1, "{:?}", lint.violations);
        assert!(lint.violations[0].contains("template-sync"), "{:?}", lint.violations);
        assert!(lint.violations[0].contains("bad.rs"), "{:?}", lint.violations);
    }

    // R6 negative: the reduce verb's module (serve/daemon.rs) is in scope.
    #[test]
    fn template_sync_covers_the_reduce_module() {
        let mut lint = fixture(
            "r6d",
            &[(
                "serve/daemon.rs",
                "fn f() { let h = thread::spawn(|| {}); h.join().unwrap(); }\n",
            )],
        );
        lint.rule_template_sync();
        assert_eq!(lint.violations.len(), 1, "{:?}", lint.violations);
        assert!(lint.violations[0].contains("thread::"), "{:?}", lint.violations);
    }

    // R5 negative: obligations come from the fixture's DESIGN.md table.
    // The unconditional `velocity` emission is flagged; the `if`-guarded
    // `warped` twin (two-line rustfmt push idiom) passes; a declared row
    // with no emission site is flagged as stale.
    #[test]
    fn emit_guards_flag_unconditional_new_wire_fields() {
        let proto = concat!(
            "fn encode_bad(m: &mut Map, v: &View) {\n",
            "    m.insert(\"velocity\".into(), Json::str(x));\n",
            "}\n",
            "fn encode_good(m: &mut Map, v: &View) {\n",
            "    if let Some(w) = &v.warped {\n",
            "        m.insert(\n",
            "            \"warped\".into(),\n",
            "            Json::str(w),\n",
            "        );\n",
            "    }\n",
            "}\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    fn t(m: &mut Map) { m.insert(\"velocity\".into(), Json::num(0.0)); }\n",
            "}\n",
        );
        let design = concat!(
            "#### Conditional wire fields\n",
            "\n",
            "| File | Field | Emitted when |\n",
            "| --- | --- | --- |\n",
            "| `serve/proto.rs` | `velocity` | reduce pinned a velocity |\n",
            "| `serve/proto.rs` | `warped` | reduce pinned a warp |\n",
            "| `serve/proto.rs` | `ghost` | stale row, no such site |\n",
            "\n",
            "## Next section\n",
        );
        let mut lint = fixture("r5", &[("serve/proto.rs", proto)]);
        fs::write(lint.repo.join("DESIGN.md"), design).unwrap();
        lint.rule_emit_guards();
        assert_eq!(lint.violations.len(), 2, "{:?}", lint.violations);
        assert!(
            lint.violations[0].contains("`velocity` emitted unconditionally"),
            "{:?}",
            lint.violations
        );
        assert!(
            lint.violations[1].contains("`ghost` has no emission site"),
            "{:?}",
            lint.violations
        );
    }
}
