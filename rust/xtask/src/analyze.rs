//! `cargo xtask analyze` — semantic invariant analyses. Canonical entry
//! when a Rust toolchain is present; `scripts/analyze_invariants.py` is
//! the dependency-free lockstep mirror for toolchain-less containers
//! (rule IDs, messages, and artifact bytes must match — see its module
//! docstring for the full semantics).
//!
//!   A1 lifecycle     Extract the job-lifecycle transition graph from
//!                    serve/scheduler.rs (state assignments with their
//!                    guarding context or `// lifecycle: from -> to`
//!                    annotation) and the template round-state machine
//!                    from template/journal.rs; check both against the
//!                    declared tables in DESIGN.md in both directions.
//!                    Emits artifacts/lifecycle.dot.
//!   A2 wire-schema   Walk serve/proto.rs / request.rs encode/decode
//!                    paths into per-verb and per-object field sets;
//!                    check encode ⊆ decode, the verb set against
//!                    DESIGN.md's "### Requests" table, conditionally
//!                    emitted fields against the "#### Conditional wire
//!                    fields" table (R5's obligation source), and the
//!                    golden corpus. Emits artifacts/wire_schema.json.
//!   A3 panic-budget  Inventory of panic-shaped and slice-indexing
//!                    sites in non-test rust/src vs
//!                    scripts/panic_budget.toml; over budget fails,
//!                    under budget demands a ratchet-down, decode-path
//!                    files are pinned to zero.
//!
//! Like the rest of xtask this module is dependency-free: string
//! scanning is hand-rolled (no regex crate) and the corpus check uses
//! the minimal JSON parser at the bottom of this file.

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::path::{Path, PathBuf};

use crate::{has_word, is_guarded, strip_comment};

const SCHED_FILE: &str = "serve/scheduler.rs";
const TEMPLATE_JOURNAL_FILE: &str = "template/journal.rs";
const PROTO_FILE: &str = "serve/proto.rs";
const REQUEST_FILE: &str = "request.rs";

/// Files whose insert("f")/push(("f") emission sites feed the
/// conditional-wire-field extraction (the wire/journal encoders).
const CONDITIONAL_SCAN_FILES: &[&str] =
    &["serve/proto.rs", "request.rs", "serve/journal.rs", "template/journal.rs"];

/// Decode-path files that must budget ZERO panic sites.
const ZERO_PANIC_FILES: &[&str] = &["serve/proto.rs", "request.rs", "util/json.rs"];

const JOB_TABLE_ANCHOR: &str = "#### Job lifecycle transitions";
const ROUND_TABLE_ANCHOR: &str = "#### Template round-state transitions";
const COND_TABLE_ANCHOR: &str = "#### Conditional wire fields";
const REQUESTS_ANCHOR: &str = "### Requests";

const NEW_STATE: &str = "(new)";

pub struct Analyze {
    pub repo: PathBuf,
    pub src: PathBuf,
    pub design: PathBuf,
    pub budget: PathBuf,
    pub corpus: PathBuf,
    pub artifacts: PathBuf,
    pub violations: Vec<String>,
}

impl Analyze {
    pub fn new(repo: PathBuf, src: PathBuf) -> Self {
        Analyze {
            design: repo.join("DESIGN.md"),
            budget: repo.join("scripts").join("panic_budget.toml"),
            corpus: repo.join("rust").join("tests").join("fixtures").join("wire_corpus.ndjson"),
            artifacts: repo.join("artifacts"),
            repo,
            src,
            violations: Vec::new(),
        }
    }

    pub fn run(&mut self) {
        self.analysis_lifecycle(true);
        self.analysis_wire_schema(true);
        self.analysis_panic_budget();
    }

    fn flag(&mut self, path: &Path, lineno: usize, rule: &str, msg: &str) {
        let rel = path.strip_prefix(&self.repo).unwrap_or(path).display().to_string();
        self.violations.push(format!("{rel}:{lineno}: [{rule}] {msg}"));
    }

    fn read(&mut self, path: &Path, rule: &str) -> Option<String> {
        match fs::read_to_string(path) {
            Ok(t) => Some(t),
            Err(_) => {
                self.flag(path, 1, rule, "cannot read file");
                None
            }
        }
    }
}

// -- shared scanning helpers -------------------------------------------------

/// Longest leading identifier run (`\w+`).
fn ident(s: &str) -> &str {
    let end = s.find(|c: char| !(c.is_alphanumeric() || c == '_')).unwrap_or(s.len());
    &s[..end]
}

/// Is position `i` preceded by a non-word character (regex `\b`)?
fn left_boundary(text: &str, i: usize) -> bool {
    i == 0 || {
        let c = text.as_bytes()[i - 1];
        !(c.is_ascii_alphanumeric() || c == b'_')
    }
}

/// Captures of `needle"FIELD"` (left word boundary on the needle); with
/// `closed`, a `)` must follow the closing quote. Mirrors the Python
/// GET_FIELD-family regexes `\bNAME\("(\w+)"\)`.
fn quoted_calls(text: &str, needle: &str, closed: bool) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let mut from = 0;
    while let Some(i) = text[from..].find(needle) {
        let start = from + i;
        from = start + needle.len();
        if !left_boundary(text, start) {
            continue;
        }
        let Some(r) = text[from..].strip_prefix('"') else { continue };
        let name = ident(r);
        if name.is_empty() {
            continue;
        }
        if let Some(a) = r[name.len()..].strip_prefix('"') {
            if !closed || a.starts_with(')') {
                out.insert(name.to_string());
            }
        }
    }
    out
}

/// Captures of `("FIELD",` — the pair-literal idiom (Python PAIR_FIELD).
fn pair_fields(text: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let mut from = 0;
    while let Some(i) = text[from..].find("(\"") {
        let start = from + i + 2;
        from = start;
        let name = ident(&text[start..]);
        if !name.is_empty() && text[start + name.len()..].starts_with("\",") {
            out.insert(name.to_string());
        }
    }
    out
}

/// Captures of `field(j, "FIELD"` (JobRequest decode helper).
fn field_j_calls(text: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let mut from = 0;
    while let Some(i) = text[from..].find("field(j,") {
        let start = from + i + 8;
        from = start;
        if let Some(r) = text[start..].trim_start().strip_prefix('"') {
            let name = ident(r);
            if !name.is_empty() && r[name.len()..].starts_with('"') {
                out.insert(name.to_string());
            }
        }
    }
    out
}

/// Decode-side field set of a match-arm chunk: `get("f")` plus the local
/// reader closures `str_opt("f")` / `num("f")`, plus `id` when the arm
/// goes through `id_of(` — minus the envelope keys.
fn decode_fields(chunk: &str) -> BTreeSet<String> {
    let mut fields = quoted_calls(chunk, "get(", true);
    fields.extend(quoted_calls(chunk, "str_opt(", true));
    fields.extend(quoted_calls(chunk, "num(", true));
    if chunk.contains("id_of(") {
        fields.insert("id".to_string());
    }
    fields.remove("cmd");
    fields.remove("seq");
    fields
}

/// `"verb" => …` arms of a match-on-string region, keyed by verb; a
/// verb's repeated arms are concatenated (Python split_str_arms).
fn split_str_arms(region: &str) -> BTreeMap<String, String> {
    let mut arms: BTreeMap<String, String> = BTreeMap::new();
    let mut current: Option<String> = None;
    for line in region.lines() {
        let head = line.trim_start().strip_prefix('"').and_then(|r| {
            let name = ident(r);
            let rest = r.get(name.len()..)?.strip_prefix('"')?.trim_start();
            let tail = rest.strip_prefix("=>")?;
            if name.is_empty() {
                None
            } else {
                Some((name.to_string(), tail.to_string()))
            }
        });
        match head {
            Some((verb, tail)) => {
                let entry = arms.entry(verb.clone()).or_default();
                if !entry.is_empty() {
                    entry.push('\n');
                }
                entry.push_str(&tail);
                current = Some(verb);
            }
            None => {
                if let Some(v) = &current {
                    let entry = arms.get_mut(v).expect("current arm exists");
                    entry.push('\n');
                    entry.push_str(line);
                }
            }
        }
    }
    arms
}

/// Brace-matched body of the first fn whose definition contains `marker`,
/// plus its 1-based line. String-naive brace counting (fine here: braces
/// inside these codecs' literals come in pairs).
fn fn_region(text: &str, marker: &str) -> Option<(String, usize)> {
    let start = text.find(marker)?;
    let open = start + text[start..].find('{')?;
    let mut depth = 0i64;
    for (off, c) in text[open..].char_indices() {
        match c {
            '{' => depth += 1,
            '}' => {
                depth -= 1;
                if depth == 0 {
                    let line = text[..start].matches('\n').count() + 1;
                    return Some((text[open..open + off + 1].to_string(), line));
                }
            }
            _ => {}
        }
    }
    None
}

/// (section text, 1-based start line). A section runs from its anchor
/// heading to the next heading of same-or-higher level.
fn design_section(design: &str, anchor: &str) -> Option<(String, usize)> {
    let start = design.find(anchor)?;
    let level = anchor.split(' ').next().unwrap_or("").len();
    let mut stops = vec!["\n## "];
    if level >= 3 {
        stops.push("\n### ");
    }
    if level >= 4 {
        stops.push("\n#### ");
    }
    let tail = &design[start..];
    let mut end = tail.len();
    for s in stops {
        if let Some(i) = tail[1..].find(s) {
            end = end.min(i + 1);
        }
    }
    Some((tail[..end].to_string(), design[..start].matches('\n').count() + 1))
}

/// First-two-backticked-cell rows: `| \`a\` | \`b\` | …` -> [(a, b)].
fn parse_pair_table(section: &str) -> Vec<(String, String)> {
    fn cell(s: &str) -> Option<(String, &str)> {
        let s = s.trim_start().strip_prefix('`')?;
        let end = s.find('`')?;
        let c = &s[..end];
        if c.is_empty()
            || !c.chars().all(|ch| ch.is_alphanumeric() || "_()./|-".contains(ch))
        {
            return None;
        }
        Some((c.to_string(), s[end + 1..].trim_start()))
    }
    let mut rows = Vec::new();
    for line in section.lines() {
        let Some(r) = line.strip_prefix('|') else { continue };
        let Some((a, r)) = cell(r) else { continue };
        let Some(r) = r.strip_prefix('|') else { continue };
        let Some((b, r)) = cell(r) else { continue };
        if r.starts_with('|') {
            rows.push((a, b));
        }
    }
    rows
}

// -- A1: lifecycle state-machine extraction ----------------------------------

/// `// lifecycle: from -> to` (from may be `a|b` alternatives).
fn lifecycle_ann(raw: &str) -> Option<(Vec<String>, String)> {
    let c = raw.find("//")?;
    let rest = raw[c + 2..].trim_start().strip_prefix("lifecycle:")?.trim_start();
    let from_end = rest
        .find(|ch: char| !(ch.is_alphanumeric() || "_()|".contains(ch)))
        .unwrap_or(rest.len());
    let from = &rest[..from_end];
    let rest = rest[from_end..].trim_start().strip_prefix("->")?.trim_start();
    let to_end = rest
        .find(|ch: char| !(ch.is_alphanumeric() || "_()".contains(ch)))
        .unwrap_or(rest.len());
    let to = &rest[..to_end];
    if from.is_empty() || to.is_empty() {
        return None;
    }
    Some((from.split('|').map(str::to_string).collect(), to.to_string()))
}

/// `rec.state = JobState::X;` -> X (rejects `==` comparisons).
fn state_mut(code: &str) -> Option<String> {
    let i = code.find("rec.state")?;
    let rest = code[i + 9..].trim_start().strip_prefix('=')?;
    if rest.starts_with('=') {
        return None;
    }
    let rest = rest.trim_start().strip_prefix("JobState::")?;
    let name = ident(rest);
    if name.is_empty() || !rest[name.len()..].trim_start().starts_with(';') {
        return None;
    }
    Some(name.to_string())
}

/// `if rec.state != JobState::X` -> X.
fn guard_neq(code: &str) -> Option<String> {
    let i = code.find("rec.state")?;
    let before = code[..i].trim_end();
    if !(before.ends_with("if") && left_boundary(before, before.len() - 2)) {
        return None;
    }
    let rest = code[i + 9..].trim_start().strip_prefix("!=")?.trim_start();
    let name = ident(rest.strip_prefix("JobState::")?);
    if name.is_empty() {
        None
    } else {
        Some(name.to_string())
    }
}

/// Line-leading `JobState::X =>` match arm -> X.
fn match_arm(code: &str) -> Option<String> {
    let rest = code.trim_start().strip_prefix("JobState::")?;
    let name = ident(rest);
    if name.is_empty() || !rest[name.len()..].trim_start().starts_with("=>") {
        return None;
    }
    Some(name.to_string())
}

/// `state: JobState::X,` struct-literal field -> X.
fn state_construct(code: &str) -> Option<String> {
    let mut from = 0;
    while let Some(i) = code[from..].find("state:") {
        let start = from + i;
        from = start + 6;
        if !left_boundary(code, start) {
            continue;
        }
        let Some(rest) = code[start + 6..].trim_start().strip_prefix("JobState::") else {
            continue;
        };
        let name = ident(rest);
        if !name.is_empty() && rest[name.len()..].trim_start().starts_with(',') {
            return Some(name.to_string());
        }
    }
    None
}

/// JobState variants (lowercased) and the is_terminal variant list.
fn extract_job_states(text: &str) -> (Vec<String>, Vec<String>) {
    let mut states = Vec::new();
    if let Some(i) = text.find("enum JobState") {
        if let Some(open) = text[i..].find('{') {
            let body_start = i + open + 1;
            if let Some(close) = text[body_start..].find('}') {
                let body = &text[body_start..body_start + close];
                let bytes = body.as_bytes();
                let mut k = 0;
                while k < body.len() {
                    if (bytes[k] as char).is_ascii_uppercase() && left_boundary(body, k) {
                        let name = ident(&body[k..]);
                        states.push(name.to_lowercase());
                        k += name.len();
                    } else {
                        k += 1;
                    }
                }
            }
        }
    }
    let mut terminals = Vec::new();
    if let Some(i) = text.find("fn is_terminal") {
        if let Some(m) = text[i..].find("matches!(self,") {
            let rest = &text[i + m + 14..];
            let span = &rest[..rest.find(')').unwrap_or(rest.len())];
            let mut from = 0;
            while let Some(p) = span[from..].find("JobState::") {
                let s = from + p + 10;
                let name = ident(&span[s..]);
                if !name.is_empty() {
                    terminals.push(name.to_lowercase());
                }
                from = s + name.len().max(1);
            }
        }
    }
    (states, terminals)
}

type Edge = (String, String, usize);

impl Analyze {
    /// (from, to, lineno) transitions from scheduler source; unresolvable
    /// assignment sites are flagged.
    fn extract_job_edges(&mut self, sched_path: &Path) -> Vec<Edge> {
        let Some(text) = self.read(&sched_path.to_path_buf(), "lifecycle") else {
            return Vec::new();
        };
        let raw_lines: Vec<&str> = text.lines().collect();
        let mut edges = Vec::new();
        for (i, raw) in raw_lines.iter().enumerate() {
            let code = strip_comment(raw);
            if let Some(to_var) = state_mut(code) {
                let to = to_var.to_lowercase();
                if let Some((froms, ann_to)) = lifecycle_ann(raw) {
                    if ann_to.to_lowercase() != to {
                        let msg = format!(
                            "annotation says `-> {ann_to}` but the assignment \
                             sets JobState::{to_var}"
                        );
                        self.flag(sched_path, i + 1, "lifecycle", &msg);
                    }
                    for frm in froms {
                        edges.push((frm.to_lowercase(), to.clone(), i + 1));
                    }
                    continue;
                }
                let mut frm = None;
                for j in (0..i).rev() {
                    let prev = strip_comment(raw_lines[j]);
                    if let Some(g) = guard_neq(prev) {
                        frm = Some(g.to_lowercase());
                        break;
                    }
                    if let Some(a) = match_arm(prev) {
                        frm = Some(a.to_lowercase());
                        break;
                    }
                    if has_word(prev, "fn") {
                        break;
                    }
                }
                match frm {
                    Some(f) => edges.push((f, to, i + 1)),
                    None => self.flag(
                        sched_path,
                        i + 1,
                        "lifecycle",
                        "cannot derive the from-state of this transition \
                         (no `if rec.state != …` guard, `match rec.state` \
                         arm, or `// lifecycle: from -> to` annotation)",
                    ),
                }
                continue;
            }
            if let Some(to) = state_construct(code) {
                // Initial state of a freshly constructed record — but only
                // in a JobRecord literal (WatchEvent snapshots are views of
                // existing state, not transitions).
                for j in (0..=i).rev() {
                    let prev = strip_comment(raw_lines[j]);
                    if prev.contains("JobRecord {") {
                        edges.push((NEW_STATE.to_string(), to.to_lowercase(), i + 1));
                        break;
                    }
                    if prev.contains("WatchEvent {") {
                        break;
                    }
                }
            }
        }
        edges
    }

    /// (appended kinds, replayed kinds, annotated edges, has the
    /// sequential-order guard) from template/journal.rs.
    fn extract_round_machine(
        &mut self,
        path: &Path,
    ) -> (Vec<String>, Vec<String>, Vec<Edge>, bool) {
        let Some(text) = self.read(&path.to_path_buf(), "lifecycle") else {
            return (Vec::new(), Vec::new(), Vec::new(), true);
        };
        let mut appended = BTreeSet::new();
        let mut from = 0;
        while let Some(i) = text[from..].find("(\"kind\",") {
            let start = from + i + 8;
            from = start;
            if let Some(r) = text[start..].trim_start().strip_prefix("Json::str(\"") {
                let name = ident(r);
                if !name.is_empty() && r[name.len()..].starts_with("\"))") {
                    appended.insert(name.to_string());
                }
            }
        }
        let replay = fn_region(&text, "fn replay").map(|(b, _)| b).unwrap_or_default();
        let mut replayed = BTreeSet::new();
        let mut from = 0;
        while let Some(i) = replay[from..].find("Some(\"") {
            let s = from + i + 6;
            let name = ident(&replay[s..]);
            from = s + name.len().max(1);
            if !name.is_empty()
                && replay[s + name.len()..].starts_with("\")")
                && replay[s + name.len() + 2..].trim_start().starts_with("=>")
            {
                replayed.insert(name.to_string());
            }
        }
        let mut edges = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            if let Some((froms, to)) = lifecycle_ann(raw) {
                for f in froms {
                    edges.push((f, to.clone(), i + 1));
                }
            }
        }
        let has_seq_guard = replay.contains("rounds.len() + 1");
        (
            appended.into_iter().collect(),
            replayed.into_iter().collect(),
            edges,
            has_seq_guard,
        )
    }

    /// Extracted-vs-declared edge diff, both directions.
    fn check_machine(
        &mut self,
        path: &Path,
        extracted: &[Edge],
        declared: &[(String, String)],
        sec_line: usize,
        what: &str,
    ) {
        let extracted_set: BTreeSet<(&str, &str)> =
            extracted.iter().map(|(f, t, _)| (f.as_str(), t.as_str())).collect();
        let declared_set: BTreeSet<(&str, &str)> =
            declared.iter().map(|(f, t)| (f.as_str(), t.as_str())).collect();
        for (f, t, lineno) in extracted {
            if !declared_set.contains(&(f.as_str(), t.as_str())) {
                let msg = format!(
                    "implements undeclared {what} transition `{f}` -> `{t}` \
                     (add it to DESIGN.md's table or fix the code)"
                );
                self.flag(path, *lineno, "lifecycle", &msg);
            }
        }
        let design = self.design.clone();
        for (f, t) in declared {
            if !extracted_set.contains(&(f.as_str(), t.as_str())) {
                let msg =
                    format!("declares {what} transition `{f}` -> `{t}` that no code implements");
                self.flag(&design, sec_line, "lifecycle", &msg);
            }
        }
    }

    fn analysis_lifecycle(&mut self, write_artifacts: bool) {
        let sched_path = self.src.join(SCHED_FILE);
        let tj_path = self.src.join(TEMPLATE_JOURNAL_FILE);
        let design_path = self.design.clone();
        let Some(design) = self.read(&design_path, "lifecycle") else { return };

        // Job lifecycle.
        let edges = self.extract_job_edges(&sched_path);
        let sched_text = fs::read_to_string(&sched_path).unwrap_or_default();
        let (states, terminals) = extract_job_states(&sched_text);
        let mut declared = Vec::new();
        let mut sec_line = 0;
        match design_section(&design, JOB_TABLE_ANCHOR) {
            None => {
                let msg = format!("section {JOB_TABLE_ANCHOR:?} not found");
                self.flag(&design_path, 1, "lifecycle", &msg);
            }
            Some((section, line)) => {
                sec_line = line;
                declared = parse_pair_table(&section);
                if declared.is_empty() {
                    let msg = format!("{JOB_TABLE_ANCHOR:?} holds no | `from` | `to` | rows");
                    self.flag(&design_path, sec_line, "lifecycle", &msg);
                }
            }
        }
        self.check_machine(&sched_path, &edges, &declared, sec_line, "job");
        for (f, t) in &declared {
            if terminals.contains(f) {
                let msg = format!(
                    "terminal state `{f}` (JobState::is_terminal) has a \
                     declared outgoing transition to `{t}`"
                );
                self.flag(&design_path, sec_line, "lifecycle", &msg);
            }
            for s in [f, t] {
                if s != NEW_STATE && !states.is_empty() && !states.contains(s) {
                    let msg = format!(
                        "declared transition names unknown state `{s}` \
                         (JobState has {})",
                        states.join(", ")
                    );
                    self.flag(&design_path, sec_line, "lifecycle", &msg);
                }
            }
        }

        // Template round-state machine.
        let (appended, replayed, redges, has_seq_guard) = self.extract_round_machine(&tj_path);
        for kind in &appended {
            if !replayed.contains(kind) {
                let msg = format!(
                    "journal line kind `{kind}` is appended but replay() \
                     never handles it (restart would silently drop it)"
                );
                self.flag(&tj_path, 1, "lifecycle", &msg);
            }
        }
        let mut rdeclared = Vec::new();
        let mut rsec_line = 0;
        match design_section(&design, ROUND_TABLE_ANCHOR) {
            None => {
                let msg = format!("section {ROUND_TABLE_ANCHOR:?} not found");
                self.flag(&design_path, 1, "lifecycle", &msg);
            }
            Some((section, line)) => {
                rsec_line = line;
                rdeclared = parse_pair_table(&section);
            }
        }
        self.check_machine(&tj_path, &redges, &rdeclared, rsec_line, "round-state");
        let declared_kinds: BTreeSet<&str> = rdeclared.iter().map(|(_, t)| t.as_str()).collect();
        for kind in &appended {
            if !rdeclared.is_empty() && !declared_kinds.contains(kind.as_str()) {
                let msg = format!(
                    "journal line kind `{kind}` does not appear in the \
                     declared round-state table"
                );
                self.flag(&tj_path, 1, "lifecycle", &msg);
            }
        }
        if !has_seq_guard {
            self.flag(
                &tj_path,
                1,
                "lifecycle",
                "replay() no longer enforces sequential round order \
                 (`rounds.len() + 1` guard missing) — the `round` -> \
                 `round` row in DESIGN.md promises strict sequencing",
            );
        }

        if write_artifacts && self.violations.is_empty() {
            let mut out = String::new();
            out.push_str(
                "// Generated by the invariant analyzer (cargo xtask analyze / \
                 scripts/analyze_invariants.py). Do not edit.\n",
            );
            out.push_str("digraph job_lifecycle {\n  rankdir=LR;\n");
            let eset: BTreeSet<(&str, &str)> =
                edges.iter().map(|(f, t, _)| (f.as_str(), t.as_str())).collect();
            for (f, t) in &eset {
                out.push_str(&format!("  \"{f}\" -> \"{t}\";\n"));
            }
            for s in &terminals {
                out.push_str(&format!("  \"{s}\" [shape=doublecircle];\n"));
            }
            out.push_str("}\n");
            out.push_str("digraph template_rounds {\n  rankdir=LR;\n");
            let rset: BTreeSet<(&str, &str)> =
                redges.iter().map(|(f, t, _)| (f.as_str(), t.as_str())).collect();
            for (f, t) in &rset {
                out.push_str(&format!("  \"{f}\" -> \"{t}\";\n"));
            }
            out.push_str("}\n");
            let _ = fs::create_dir_all(&self.artifacts);
            let _ = fs::write(self.artifacts.join("lifecycle.dot"), out);
        }
    }
}

// -- A2: wire-schema extraction & conformance --------------------------------

/// Python repr of a sorted string set: `['a', 'b']` — message lockstep.
fn pylist(items: &BTreeSet<String>) -> String {
    let inner: Vec<String> = items.iter().map(|s| format!("'{s}'")).collect();
    format!("[{}]", inner.join(", "))
}

/// `("KEY", Json::str("NAME"))` markers: (offset of the marker, NAME).
fn tag_marks(region: &str, key: &str) -> Vec<(usize, String)> {
    let needle = format!("(\"{key}\",");
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(i) = region[from..].find(&needle) {
        let start = from + i;
        from = start + needle.len();
        if let Some(r) = region[from..].trim_start().strip_prefix("Json::str(\"") {
            let name = ident(r);
            if !name.is_empty() && r[name.len()..].starts_with("\"))") {
                out.push((start, name.to_string()));
            }
        }
    }
    out
}

/// All `.insert("F"` / `.push(("F"` captures on one line.
fn emit_site_fields(code: &str) -> Vec<String> {
    let mut out = Vec::new();
    for needle in [".insert(\"", ".push((\""] {
        let mut from = 0;
        while let Some(i) = code[from..].find(needle) {
            let s = from + i + needle.len();
            from = s;
            let name = ident(&code[s..]);
            if !name.is_empty() && code[s + name.len()..].starts_with('"') {
                out.push(name.to_string());
            }
        }
    }
    out
}

/// Extra decode-side capture idioms beyond `get("f")`.
enum DecExtra {
    /// `NAME("f")` local reader closure.
    Call(&'static str),
    /// `field(j, "f"` typed-field helper.
    FieldJ,
}

/// verb -> (decode fields, encode fields).
type VerbSchema = BTreeMap<String, (BTreeSet<String>, BTreeSet<String>)>;

impl Analyze {
    fn extract_request_schema(&mut self, proto: &str, proto_path: &Path) -> VerbSchema {
        let (Some(start), Some(end)) = (proto.find("match cmd {"), proto.find("unknown command"))
        else {
            self.flag(
                proto_path,
                1,
                "wire-schema",
                "cannot locate Request::from_json's `match cmd` dispatch",
            );
            return BTreeMap::new();
        };
        let mut schema: VerbSchema = split_str_arms(&proto[start..end])
            .into_iter()
            .map(|(v, chunk)| (v, (decode_fields(&chunk), BTreeSet::new())))
            .collect();

        // Encode side: chunks of Request::to_json keyed by ("cmd", …"verb").
        let encode_region = match proto.find("pub fn to_line") {
            Some(i) if i > 0 => &proto[..i],
            _ => proto,
        };
        let marks = tag_marks(encode_region, "cmd");
        for (k, (pos, verb)) in marks.iter().enumerate() {
            let stop = marks.get(k + 1).map(|(p, _)| *p).unwrap_or(encode_region.len());
            let mut fields = pair_fields(&encode_region[*pos..stop]);
            for drop in ["cmd", "m0", "m1"] {
                // m0/m1 are nested source-object keys, not verb fields.
                fields.remove(drop);
            }
            match schema.get_mut(verb) {
                None => {
                    let msg = format!(
                        "Request::to_json encodes verb `{verb}` that \
                         Request::from_json cannot decode"
                    );
                    self.flag(proto_path, 1, "wire-schema", &msg);
                }
                Some((_, encode)) => encode.extend(fields),
            }
        }
        let mut round_trip = Vec::new();
        for (verb, (decode, encode)) in &schema {
            let extra: BTreeSet<String> = encode.difference(decode).cloned().collect();
            if !extra.is_empty() {
                round_trip.push(format!(
                    "verb `{verb}` encodes field(s) {} its decode arm never \
                     reads — a round-trip would drop them",
                    pylist(&extra)
                ));
            }
        }
        for msg in round_trip {
            self.flag(proto_path, 1, "wire-schema", &msg);
        }
        schema
    }

    /// Field sets of an encode/decode fn pair; checks encode ⊆ decode.
    fn extract_codec_pair(
        &mut self,
        text: &str,
        path: &Path,
        name: &str,
        enc_marker: &str,
        dec_marker: &str,
        dec_extra: &[DecExtra],
    ) -> Option<(Vec<String>, Vec<String>)> {
        let enc = fn_region(text, enc_marker);
        let dec = fn_region(text, dec_marker);
        let (Some((enc_body, enc_line)), Some((dec_body, _))) = (enc, dec) else {
            let msg = format!("cannot locate codec pair {enc_marker:?}/{dec_marker:?}");
            self.flag(path, 1, "wire-schema", &msg);
            return None;
        };
        let mut enc_fields = pair_fields(&enc_body);
        enc_fields.extend(quoted_calls(&enc_body, "insert(", false));
        let mut dec_fields = quoted_calls(&dec_body, "get(", true);
        for extra in dec_extra {
            match extra {
                DecExtra::Call(fn_name) => {
                    dec_fields.extend(quoted_calls(&dec_body, &format!("{fn_name}("), true));
                }
                DecExtra::FieldJ => dec_fields.extend(field_j_calls(&dec_body)),
            }
        }
        let mut extra: BTreeSet<String> =
            enc_fields.difference(&dec_fields).cloned().collect();
        extra.remove("cmd");
        extra.remove("seq");
        if !extra.is_empty() {
            let msg = format!(
                "object `{name}` encodes field(s) {} the decoder never \
                 reads — a round-trip would drop them",
                pylist(&extra)
            );
            self.flag(path, enc_line, "wire-schema", &msg);
        }
        Some((
            enc_fields.into_iter().collect(),
            dec_fields.into_iter().collect(),
        ))
    }

    /// kind -> (encode fields, decode fields) for EventMsg.
    fn extract_event_schema(
        &mut self,
        proto: &str,
        proto_path: &Path,
    ) -> BTreeMap<String, (Vec<String>, Vec<String>)> {
        let pairs_marker = "pub fn to_line(&self) -> String {\n        let mut pairs";
        let enc = fn_region(proto, pairs_marker).or_else(|| {
            // Fall back: the EventMsg impl is the last to_line in the file.
            let idx = proto.rfind("pub fn to_line")?;
            fn_region(&proto[idx..], "pub fn to_line")
        });
        let dec = proto.find("impl EventMsg").and_then(|imp| {
            fn_region(&proto[imp..], "fn from_json")
        });
        let (Some((enc_body, enc_line)), Some((dec_body, _))) = (enc, dec) else {
            self.flag(proto_path, 1, "wire-schema", "cannot locate EventMsg codec");
            return BTreeMap::new();
        };
        let marks = tag_marks(&enc_body, "event");
        let mut enc_by_kind: Vec<(String, BTreeSet<String>)> = Vec::new();
        for (k, (pos, kind)) in marks.iter().enumerate() {
            let stop = marks.get(k + 1).map(|(p, _)| *p).unwrap_or(enc_body.len());
            let mut fields = pair_fields(&enc_body[*pos..stop]);
            fields.remove("event");
            enc_by_kind.push((kind.clone(), fields));
        }
        let dec_arms = split_str_arms(&dec_body);
        let mut out = BTreeMap::new();
        for (kind, enc_fields) in enc_by_kind {
            let Some(arm) = dec_arms.get(&kind) else {
                let msg = format!(
                    "event kind `{kind}` is emitted but EventMsg::from_json \
                     never decodes it"
                );
                self.flag(proto_path, enc_line, "wire-schema", &msg);
                continue;
            };
            let mut dec_fields = decode_fields(arm);
            dec_fields.insert("seq".to_string());
            let extra: BTreeSet<String> =
                enc_fields.difference(&dec_fields).cloned().collect();
            if !extra.is_empty() {
                let msg = format!(
                    "event `{kind}` encodes field(s) {} its decode arm never reads",
                    pylist(&extra)
                );
                self.flag(proto_path, enc_line, "wire-schema", &msg);
            }
            out.insert(
                kind,
                (enc_fields.into_iter().collect(), dec_fields.into_iter().collect()),
            );
        }
        out
    }

    /// (rel file, field) -> (guarded lines, unguarded lines), 1-based,
    /// over every insert("f")/push(("f") emission site in the
    /// wire/journal encoders.
    fn extract_conditional_fields(
        &mut self,
    ) -> BTreeMap<(String, String), (Vec<usize>, Vec<usize>)> {
        let mut sites: BTreeMap<(String, String), (Vec<usize>, Vec<usize>)> = BTreeMap::new();
        for rel in CONDITIONAL_SCAN_FILES {
            let path = self.src.join(rel);
            let Some(text) = self.read(&path, "wire-schema") else { continue };
            let lines: Vec<&str> = text.lines().collect();
            for (i, raw) in lines.iter().enumerate() {
                if raw.contains("#[cfg(test)]") {
                    break; // test modules are file-final by crate convention
                }
                let code = strip_comment(raw);
                let mut fields = emit_site_fields(code);
                // rustfmt splits wide pushes over two lines:
                //   pairs.push((
                //       "field", …
                let t = code.trim_end();
                if (t.ends_with(".push((") || t.ends_with(".insert(")) && i + 1 < lines.len() {
                    if let Some(r) =
                        strip_comment(lines[i + 1]).trim_start().strip_prefix('"')
                    {
                        let name = ident(r);
                        if !name.is_empty() && r[name.len()..].starts_with('"') {
                            fields.push(name.to_string());
                        }
                    }
                }
                for field in fields {
                    let entry = sites
                        .entry((rel.to_string(), field))
                        .or_default();
                    if is_guarded(&lines, i) {
                        entry.0.push(i + 1);
                    } else {
                        entry.1.push(i + 1);
                    }
                }
            }
        }
        sites
    }
}

impl Analyze {
    fn analysis_wire_schema(&mut self, write_artifacts: bool) {
        let proto_path = self.src.join(PROTO_FILE);
        let request_path = self.src.join(REQUEST_FILE);
        let design_path = self.design.clone();
        let Some(proto) = self.read(&proto_path, "wire-schema") else { return };
        let Some(request) = self.read(&request_path, "wire-schema") else { return };
        let Some(design) = self.read(&design_path, "wire-schema") else { return };

        let verbs = self.extract_request_schema(&proto, &proto_path);

        // DESIGN.md's Requests table must list exactly the decodable verbs.
        match design_section(&design, REQUESTS_ANCHOR) {
            None => {
                let msg = format!("section {REQUESTS_ANCHOR:?} not found");
                self.flag(&design_path, 1, "wire-schema", &msg);
            }
            Some((section, sec_line)) => {
                let documented = documented_verbs(&section);
                for v in verbs.keys() {
                    if !documented.contains(v) {
                        let msg = format!(
                            "verb `{v}` is decodable but missing from the \
                             {REQUESTS_ANCHOR:?} table"
                        );
                        self.flag(&design_path, sec_line, "wire-schema", &msg);
                    }
                }
                for v in &documented {
                    if !verbs.contains_key(v) {
                        let msg = format!(
                            "{REQUESTS_ANCHOR:?} documents verb `{v}` that \
                             Request::from_json does not decode"
                        );
                        self.flag(&design_path, sec_line, "wire-schema", &msg);
                    }
                }
            }
        }

        let mut objects: BTreeMap<&str, (Vec<String>, Vec<String>)> = BTreeMap::new();
        if let Some(spec) =
            self.extract_codec_pair(&proto, &proto_path, "job", "fn job_to_json", "fn job_from_json", &[])
        {
            objects.insert("job", spec);
        }
        if let Some(spec) = self.extract_codec_pair(
            &proto,
            &proto_path,
            "node_stats",
            "fn node_stats_to_json",
            "fn node_stats_from_json",
            &[],
        ) {
            objects.insert("node_stats", spec);
        }
        if let Some(spec) = self.extract_codec_pair(
            &proto,
            &proto_path,
            "stats",
            "fn stats_to_json",
            "fn stats_from_json",
            &[DecExtra::Call("g"), DecExtra::Call("gs")],
        ) {
            objects.insert("stats", spec);
        }
        if let Some(spec) = self.extract_codec_pair(
            &request,
            &request_path,
            "job_request",
            "pub fn to_json",
            "pub fn from_json",
            &[DecExtra::FieldJ, DecExtra::Call("id_of")],
        ) {
            objects.insert("job_request", spec);
        }
        let events = self.extract_event_schema(&proto, &proto_path);

        // Conditional (emit-only-when-present) fields vs the declared table.
        let sites = self.extract_conditional_fields();
        let mut declared = Vec::new();
        let mut csec_line = 0;
        match design_section(&design, COND_TABLE_ANCHOR) {
            None => {
                let msg = format!("section {COND_TABLE_ANCHOR:?} not found");
                self.flag(&design_path, 1, "wire-schema", &msg);
            }
            Some((section, line)) => {
                csec_line = line;
                declared = parse_pair_table(&section);
            }
        }
        let declared_set: BTreeSet<(&str, &str)> =
            declared.iter().map(|(f, t)| (f.as_str(), t.as_str())).collect();
        let mut conditional: Vec<(String, String, Vec<usize>)> = Vec::new();
        for ((rel, field), (guarded, unguarded)) in &sites {
            let path = self.src.join(rel);
            if !guarded.is_empty() && !unguarded.is_empty() {
                let msg = format!(
                    "field `{field}` is emitted both guarded (line(s) \
                     {guarded:?}) and unguarded — emit-only-when-present \
                     discipline must be all-or-nothing per file"
                );
                self.flag(&path, unguarded[0], "wire-schema", &msg);
            } else if !guarded.is_empty() {
                conditional.push((rel.clone(), field.clone(), guarded.clone()));
                if !declared_set.contains(&(rel.as_str(), field.as_str())) {
                    let msg = format!(
                        "conditionally emitted field `{field}` is not \
                         declared in DESIGN.md's {COND_TABLE_ANCHOR:?} table"
                    );
                    self.flag(&path, guarded[0], "wire-schema", &msg);
                }
            }
        }
        for (rel, field) in &declared {
            match sites.get(&(rel.clone(), field.clone())) {
                None => {
                    let msg = format!(
                        "declared conditional field `{field}` has no \
                         insert/push emission site in {rel} (stale row?)"
                    );
                    self.flag(&design_path, csec_line, "wire-schema", &msg);
                }
                Some((guarded, unguarded)) => {
                    if !unguarded.is_empty() && guarded.is_empty() {
                        let path = self.src.join(rel);
                        let msg = format!(
                            "declared conditional field `{field}` is emitted \
                             unconditionally — this field is emit-only-when-\
                             present for wire/journal back-compat"
                        );
                        self.flag(&path, unguarded[0], "wire-schema", &msg);
                    }
                }
            }
        }

        // Golden corpus: every verb in v1 (bare) and v2 (seq) form, every
        // field decodable per the extracted schema.
        let corpus_path = self.corpus.clone();
        let mut seen: BTreeMap<String, BTreeSet<&'static str>> = BTreeMap::new();
        match fs::read_to_string(&corpus_path) {
            Err(_) => self.flag(&corpus_path, 1, "wire-schema", "golden wire corpus missing"),
            Ok(corpus) => {
                for (k, raw) in corpus.lines().enumerate() {
                    let lineno = k + 1;
                    let line = raw.trim();
                    if line.is_empty() {
                        continue;
                    }
                    let Some(JVal::Obj(obj)) = parse_json(line) else {
                        self.flag(&corpus_path, lineno, "wire-schema", "line is not valid JSON");
                        continue;
                    };
                    let verb = match obj.iter().find(|(k, _)| k == "cmd") {
                        Some((_, JVal::Str(s))) => s.clone(),
                        _ => String::new(),
                    };
                    let Some((decode, _)) = verbs.get(&verb) else {
                        let shown =
                            if verb.is_empty() { "None".to_string() } else { format!("'{verb}'") };
                        let msg = format!("unknown verb {shown}");
                        self.flag(&corpus_path, lineno, "wire-schema", &msg);
                        continue;
                    };
                    let form = if obj.iter().any(|(k, _)| k == "seq") { "v2" } else { "v1" };
                    seen.entry(verb.clone()).or_default().insert(form);
                    let extra: BTreeSet<String> = obj
                        .iter()
                        .map(|(k, _)| k.clone())
                        .filter(|k| k != "cmd" && k != "seq" && !decode.contains(k))
                        .collect();
                    if !extra.is_empty() {
                        let msg = format!(
                            "verb `{verb}` carries field(s) {} its decode arm \
                             never reads",
                            pylist(&extra)
                        );
                        self.flag(&corpus_path, lineno, "wire-schema", &msg);
                    }
                    let jr = objects.get("job_request").map(|(_, dec)| dec);
                    let mut jobs: Vec<&Vec<(String, JVal)>> = Vec::new();
                    if verb == "submit" {
                        if let Some((_, JVal::Obj(j))) = obj.iter().find(|(k, _)| k == "job") {
                            jobs.push(j);
                        }
                    } else if verb == "submit_batch" {
                        if let Some((_, JVal::Arr(items))) =
                            obj.iter().find(|(k, _)| k == "jobs")
                        {
                            for item in items {
                                if let JVal::Obj(j) = item {
                                    jobs.push(j);
                                }
                            }
                        }
                    }
                    if let Some(jr) = jr {
                        for j in jobs {
                            let extra: BTreeSet<String> = j
                                .iter()
                                .map(|(k, _)| k.clone())
                                .filter(|k| !jr.contains(k))
                                .collect();
                            if !extra.is_empty() {
                                let msg = format!(
                                    "job object carries field(s) {} \
                                     JobRequest::from_json never reads",
                                    pylist(&extra)
                                );
                                self.flag(&corpus_path, lineno, "wire-schema", &msg);
                            }
                        }
                    }
                }
                for verb in verbs.keys() {
                    for form in ["v1", "v2"] {
                        if !seen.get(verb).is_some_and(|forms| forms.contains(form)) {
                            let with = if form == "v2" { "with" } else { "no" };
                            let msg =
                                format!("verb `{verb}` has no {form} ({with} seq) corpus line");
                            self.flag(&corpus_path, 1, "wire-schema", &msg);
                        }
                    }
                }
            }
        }

        if write_artifacts && self.violations.is_empty() {
            let envelope = fn_region(&proto, "pub fn from_json(j: &Json) -> Result<Response>")
                .map(|(b, _)| b)
                .unwrap_or_default();
            let discriminators: Vec<String> =
                quoted_calls(&envelope, "get(", true).into_iter().collect();
            let field_sets = |enc: &[String], dec: &[String]| {
                JOut::Map(vec![
                    ("decode".into(), JOut::list_of(dec)),
                    ("encode".into(), JOut::list_of(enc)),
                ])
            };
            let schema = JOut::Map(vec![
                (
                    "generated_by".into(),
                    JOut::Str(
                        "cargo xtask analyze / scripts/analyze_invariants.py (lockstep)".into(),
                    ),
                ),
                (
                    "verbs".into(),
                    JOut::Map(
                        verbs
                            .iter()
                            .map(|(v, (dec, enc))| {
                                let dec: Vec<String> = dec.iter().cloned().collect();
                                let enc: Vec<String> = enc.iter().cloned().collect();
                                (
                                    v.clone(),
                                    JOut::Map(vec![("request".into(), field_sets(&enc, &dec))]),
                                )
                            })
                            .collect(),
                    ),
                ),
                (
                    "objects".into(),
                    JOut::Map(
                        objects
                            .iter()
                            .map(|(n, (enc, dec))| (n.to_string(), field_sets(enc, dec)))
                            .collect(),
                    ),
                ),
                (
                    "events".into(),
                    JOut::Map(
                        events
                            .iter()
                            .map(|(k, (enc, dec))| (k.clone(), field_sets(enc, dec)))
                            .collect(),
                    ),
                ),
                ("response_discriminators".into(), JOut::list_of(&discriminators)),
                (
                    "conditional_fields".into(),
                    JOut::List(
                        conditional
                            .iter()
                            .map(|(file, field, lines)| {
                                JOut::Map(vec![
                                    ("file".into(), JOut::Str(file.clone())),
                                    ("field".into(), JOut::Str(field.clone())),
                                    (
                                        "lines".into(),
                                        JOut::List(
                                            lines.iter().map(|n| JOut::Int(*n)).collect(),
                                        ),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]);
            let mut out = String::new();
            schema.render(0, &mut out);
            out.push('\n');
            let _ = fs::create_dir_all(&self.artifacts);
            let _ = fs::write(self.artifacts.join("wire_schema.json"), out);
        }
    }
}

/// `"cmd": "verb"` captures in the Requests table (tolerating spaces
/// around the colon, as the Python mirror's regex does).
fn documented_verbs(section: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let mut from = 0;
    while let Some(i) = section[from..].find("\"cmd\"") {
        let start = from + i + 5;
        from = start;
        let rest = section[start..].trim_start();
        let Some(r) = rest.strip_prefix(':') else { continue };
        let Some(r) = r.trim_start().strip_prefix('"') else { continue };
        let name = ident(r);
        if !name.is_empty() && r[name.len()..].starts_with('"') {
            out.insert(name.to_string());
        }
    }
    out
}

// -- minimal JSON: corpus reader + artifact writer ---------------------------

/// Parsed JSON value — just enough for the corpus cross-check. Objects
/// keep insertion order (key lookup is a linear scan; corpus objects are
/// tiny).
enum JVal {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JVal>),
    Obj(Vec<(String, JVal)>),
}

fn parse_json(text: &str) -> Option<JVal> {
    let bytes = text.as_bytes();
    let mut pos = 0;
    let val = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos == bytes.len() {
        Some(val)
    } else {
        None
    }
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize) -> Option<JVal> {
    skip_ws(bytes, pos);
    match bytes.get(*pos)? {
        b'{' => {
            *pos += 1;
            let mut obj = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Some(JVal::Obj(obj));
            }
            loop {
                skip_ws(bytes, pos);
                let key = match parse_value(bytes, pos)? {
                    JVal::Str(s) => s,
                    _ => return None,
                };
                skip_ws(bytes, pos);
                if bytes.get(*pos) != Some(&b':') {
                    return None;
                }
                *pos += 1;
                obj.push((key, parse_value(bytes, pos)?));
                skip_ws(bytes, pos);
                match bytes.get(*pos)? {
                    b',' => *pos += 1,
                    b'}' => {
                        *pos += 1;
                        return Some(JVal::Obj(obj));
                    }
                    _ => return None,
                }
            }
        }
        b'[' => {
            *pos += 1;
            let mut arr = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b']') {
                *pos += 1;
                return Some(JVal::Arr(arr));
            }
            loop {
                arr.push(parse_value(bytes, pos)?);
                skip_ws(bytes, pos);
                match bytes.get(*pos)? {
                    b',' => *pos += 1,
                    b']' => {
                        *pos += 1;
                        return Some(JVal::Arr(arr));
                    }
                    _ => return None,
                }
            }
        }
        b'"' => {
            *pos += 1;
            let mut s = String::new();
            loop {
                match bytes.get(*pos)? {
                    b'"' => {
                        *pos += 1;
                        return Some(JVal::Str(s));
                    }
                    b'\\' => {
                        *pos += 1;
                        match bytes.get(*pos)? {
                            b'n' => s.push('\n'),
                            b't' => s.push('\t'),
                            b'r' => s.push('\r'),
                            b'b' => s.push('\u{8}'),
                            b'f' => s.push('\u{c}'),
                            b'u' => {
                                let hex = bytes.get(*pos + 1..*pos + 5)?;
                                let code =
                                    u32::from_str_radix(std::str::from_utf8(hex).ok()?, 16)
                                        .ok()?;
                                s.push(char::from_u32(code)?);
                                *pos += 4;
                            }
                            c => s.push(*c as char),
                        }
                        *pos += 1;
                    }
                    c => {
                        s.push(*c as char);
                        *pos += 1;
                    }
                }
            }
        }
        b't' => {
            lit(bytes, pos, b"true")?;
            Some(JVal::Bool(true))
        }
        b'f' => {
            lit(bytes, pos, b"false")?;
            Some(JVal::Bool(false))
        }
        b'n' => {
            lit(bytes, pos, b"null")?;
            Some(JVal::Null)
        }
        _ => {
            let start = *pos;
            while *pos < bytes.len()
                && matches!(bytes[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *pos += 1;
            }
            let s = std::str::from_utf8(&bytes[start..*pos]).ok()?;
            s.parse::<f64>().ok().map(JVal::Num)
        }
    }
}

fn lit(bytes: &[u8], pos: &mut usize, word: &[u8]) -> Option<()> {
    if bytes.get(*pos..*pos + word.len()) == Some(word) {
        *pos += word.len();
        Some(())
    } else {
        None
    }
}

/// Output JSON value for the schema artifact. `render` replicates
/// Python's `json.dump(obj, fh, indent=1, sort_keys=True)` byte for byte
/// (maps sort their keys; 1-space indent; `", "`/`": "` separators).
enum JOut {
    Str(String),
    Int(usize),
    List(Vec<JOut>),
    Map(Vec<(String, JOut)>),
}

impl JOut {
    fn list_of(items: &[String]) -> JOut {
        JOut::List(items.iter().map(|s| JOut::Str(s.clone())).collect())
    }

    fn render(&self, level: usize, out: &mut String) {
        let pad = |out: &mut String, n: usize| {
            for _ in 0..n {
                out.push(' ');
            }
        };
        match self {
            JOut::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 || (c as u32) > 0x7e => {
                            out.push_str(&format!("\\u{:04x}", c as u32));
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            JOut::Int(n) => out.push_str(&n.to_string()),
            JOut::List(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (k, item) in items.iter().enumerate() {
                    pad(out, level + 1);
                    item.render(level + 1, out);
                    if k + 1 < items.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, level);
                out.push(']');
            }
            JOut::Map(entries) => {
                if entries.is_empty() {
                    out.push_str("{}");
                    return;
                }
                let mut sorted: Vec<&(String, JOut)> = entries.iter().collect();
                sorted.sort_by(|a, b| a.0.cmp(&b.0));
                out.push_str("{\n");
                for (k, (key, val)) in sorted.iter().enumerate() {
                    pad(out, level + 1);
                    JOut::Str(key.clone()).render(level + 1, out);
                    out.push_str(": ");
                    val.render(level + 1, out);
                    if k + 1 < sorted.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, level);
                out.push('}');
            }
        }
    }
}

// -- A3: panic-path ratchet --------------------------------------------------

/// Panic-shaped sites on one comment-stripped line: `.unwrap()`,
/// `.expect(` (excluding the JSON parser's own `expect(b'X')`
/// byte-matcher), and the diverging macros.
fn count_panics(code: &str) -> usize {
    let mut n = code.matches(".unwrap()").count();
    let mut from = 0;
    while let Some(i) = code[from..].find(".expect(") {
        let after = from + i + 8;
        if !code[after..].starts_with("b'") {
            n += 1;
        }
        from = after;
    }
    for mac in ["panic!", "unreachable!", "todo!", "unimplemented!"] {
        let mut from = 0;
        while let Some(i) = code[from..].find(mac) {
            let start = from + i;
            from = start + mac.len();
            if left_boundary(code, start) && code[from..].trim_start().starts_with('(') {
                n += 1;
            }
        }
    }
    n
}

/// Slice/array-indexing proxy: `[` directly after an identifier char,
/// `)`, or `]` (not `#[attr]`, not an array type/literal).
fn count_index(code: &str) -> usize {
    let bytes = code.as_bytes();
    let mut n = 0;
    for w in bytes.windows(2) {
        let head = w[0].is_ascii_alphanumeric() || matches!(w[0], b'_' | b')' | b']');
        if head && w[1] == b'[' {
            n += 1;
        }
    }
    n
}

fn count_sites(text: &str) -> (usize, usize) {
    let mut n_panic = 0;
    let mut n_index = 0;
    for line in text.lines() {
        if line.contains("#[cfg(test)]") {
            break; // test modules are file-final by crate convention
        }
        let code = strip_comment(line);
        n_panic += count_panics(code);
        n_index += count_index(code);
    }
    (n_panic, n_index)
}

impl Analyze {
    /// {"panics": {file: n}, "index": {file: n}} from the flat two-table
    /// TOML (no dependency on a TOML parser).
    fn parse_budget(&mut self, path: &Path) -> BTreeMap<String, BTreeMap<String, usize>> {
        let mut tables: BTreeMap<String, BTreeMap<String, usize>> = BTreeMap::new();
        tables.insert("panics".into(), BTreeMap::new());
        tables.insert("index".into(), BTreeMap::new());
        let Some(text) = self.read(&path.to_path_buf(), "panic-budget") else {
            return tables;
        };
        let mut current: Option<String> = None;
        for (k, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line
                .strip_prefix('[')
                .and_then(|r| r.strip_suffix(']'))
                .filter(|n| !n.is_empty() && n.chars().all(|c| c.is_alphanumeric() || c == '_'))
            {
                if !tables.contains_key(name) {
                    let msg = format!("unknown budget table [{name}]");
                    self.flag(path, k + 1, "panic-budget", &msg);
                    tables.insert(name.to_string(), BTreeMap::new());
                }
                current = Some(name.to_string());
                continue;
            }
            let entry = line.strip_prefix('"').and_then(|r| {
                let close = r.find('"')?;
                let file = &r[..close];
                if file.is_empty() {
                    return None;
                }
                let rest = r[close + 1..].trim_start().strip_prefix('=')?.trim();
                if rest.is_empty() || !rest.chars().all(|c| c.is_ascii_digit()) {
                    return None;
                }
                Some((file.to_string(), rest.parse::<usize>().ok()?))
            });
            match (&current, entry) {
                (Some(table), Some((file, n))) => {
                    tables.get_mut(table).expect("table exists").insert(file, n);
                }
                _ => {
                    let msg = format!("unparseable budget line {:?}", raw.trim());
                    self.flag(path, k + 1, "panic-budget", &msg);
                }
            }
        }
        tables
    }

    fn analysis_panic_budget(&mut self) {
        let budget_path = self.budget.clone();
        if !budget_path.exists() {
            self.flag(&budget_path, 1, "panic-budget", "budget file missing");
            return;
        }
        let budget = self.parse_budget(&budget_path);
        let mut actual: BTreeMap<&str, BTreeMap<String, usize>> = BTreeMap::new();
        actual.insert("panics", BTreeMap::new());
        actual.insert("index", BTreeMap::new());
        let mut stack = vec![self.src.clone()];
        let mut files = Vec::new();
        while let Some(dir) = stack.pop() {
            let Ok(entries) = fs::read_dir(&dir) else { continue };
            for entry in entries.flatten() {
                let p = entry.path();
                if p.is_dir() {
                    stack.push(p);
                } else if p.extension().is_some_and(|e| e == "rs") {
                    files.push(p);
                }
            }
        }
        files.sort();
        for path in files {
            let rel = path
                .strip_prefix(&self.src)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            let Ok(text) = fs::read_to_string(&path) else { continue };
            let (n_panic, n_index) = count_sites(&text);
            if n_panic > 0 {
                actual.get_mut("panics").expect("table").insert(rel.clone(), n_panic);
            }
            if n_index > 0 {
                actual.get_mut("index").expect("table").insert(rel, n_index);
            }
        }
        for table in ["panics", "index"] {
            for (rel, n) in &actual[table] {
                let path = self.src.join(rel);
                let b = budget[table].get(rel).copied();
                if table == "panics" && ZERO_PANIC_FILES.contains(&rel.as_str()) {
                    let msg = format!(
                        "decode-path file has {n} panic site(s); malformed \
                         client input must surface as structured errors \
                         (budget is pinned to zero)"
                    );
                    self.flag(&path, 1, "panic-budget", &msg);
                    continue;
                }
                match b {
                    None => {
                        let msg = format!(
                            "{n} {table} site(s) but no [{table}] budget entry \
                             in scripts/panic_budget.toml"
                        );
                        self.flag(&path, 1, "panic-budget", &msg);
                    }
                    Some(b) if *n > b => {
                        let msg = format!(
                            "{n} {table} site(s) exceed the budget of {b} — \
                             convert the new sites to structured errors"
                        );
                        self.flag(&path, 1, "panic-budget", &msg);
                    }
                    Some(b) if *n < b => {
                        let msg = format!(
                            "only {n} {table} site(s) against a budget of {b} \
                             — ratchet the budget down to {n} (budgets only \
                             ever decrease)"
                        );
                        self.flag(&path, 1, "panic-budget", &msg);
                    }
                    Some(_) => {}
                }
            }
            for rel in budget[table].keys() {
                if !actual[table].contains_key(rel) {
                    let msg = format!(
                        "stale [{table}] entry for {rel} (no such site or \
                         file) — delete it"
                    );
                    self.flag(&budget_path, 1, "panic-budget", &msg);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Negative fixtures kept in lockstep with the Python mirror's
    // --selftest (scripts/analyze_invariants.py).

    const FIXTURE_SCHED: &str = r#"pub enum JobState {
    Queued,
    Running,
    Done,
}
impl JobState {
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done)
    }
}
fn submit(st: &mut St) {
    st.jobs.insert(id, JobRecord {
        state: JobState::Queued,
    });
}
fn dispatch(rec: &mut JobRecord) {
    if rec.state != JobState::Done {
        rec.state = JobState::Running;
    }
}
"#;

    const FIXTURE_TJ: &str = r#"fn append_init(&self) {
    // lifecycle: (start) -> init
    let pairs = vec![("kind", Json::str("init"))];
}
fn append_round(&self) {
    // lifecycle: init|round -> round
    let pairs = vec![("kind", Json::str("round"))];
}
fn replay(path: &Path) {
    match kind {
        Some("init") => {}
        Some("round") => {
            if round != st.rounds.len() + 1 {
                return Err(out_of_order());
            }
        }
        _ => {}
    }
}
"#;

    const FIXTURE_DESIGN: &str = r#"### Requests

| Request | Response |
|---|---|
| `{"cmd":"ping"}` | `{"ok":true}` |
| `{"cmd":"status","id":7}` | `{"ok":true}` |

#### Job lifecycle transitions

| From | To | Trigger |
|---|---|---|
| `(new)` | `queued` | admission |
| `queued` | `running` | dispatch |

#### Template round-state transitions

| From | To | Line |
|---|---|---|
| `(start)` | `init` | run header |
| `init` | `round` | first round |
| `round` | `round` | each next round |

#### Conditional wire fields

| File | Field | Emitted when |
|---|---|---|
| `serve/proto.rs` | `velocity` | retained |
| `request.rs` | `dedup` | token supplied |
"#;

    const FIXTURE_PROTO: &str = r#"impl Request {
    fn to_json(&self) -> Json {
        match self {
            Request::Ping => Json::object([("cmd", Json::str("ping"))]),
            Request::Status(Some(id)) => {
                Json::object([("cmd", Json::str("status")), ("id", Json::num(*id as f64))])
            }
        }
    }
    pub fn to_line(&self) -> String { self.to_json().render() }
    pub fn from_json(j: &Json) -> Result<Request> {
        match cmd {
            "ping" => Ok(Request::Ping),
            "status" => match j.get("id") {
                None => Ok(Request::Status(None)),
                Some(_) => Ok(Request::Status(Some(id_of(j)?))),
            },
            other => Err(bad(format!("unknown command '{other}'"))),
        }
    }
}
fn job_to_json(v: &JobView) -> Json {
    let mut j = Json::object([("id", Json::num(v.id as f64))]);
    if let Json::Obj(m) = &mut j {
        m.insert("velocity".into(), Json::str(vel));
    }
    m.insert("ghost".into(), Json::str(g));
    j
}
fn job_from_json(j: &Json) -> Result<JobView> {
    let id = j.get("id");
    let v = j.get("velocity");
    let g = j.get("ghost");
}
fn node_stats_to_json(n: &NodeStats) -> Json {
    Json::object([("node", Json::str(&n.node))])
}
fn node_stats_from_json(j: &Json) -> Result<NodeStats> {
    let node = j.get("node");
}
fn stats_to_json(s: &ServeStats) -> Json {
    Json::object([("queued", Json::num(s.queued as f64))])
}
fn stats_from_json(j: &Json) -> Result<ServeStats> {
    let queued = g("queued");
}
impl EventMsg {
    pub fn to_line(&self) -> String {
        let mut pairs: Vec<(&str, Json)> = Vec::new();
        pairs.push(("event", Json::str("job")));
        Json::object(pairs).render()
    }
    pub fn from_json(j: &Json) -> Result<EventMsg> {
        match kind {
            "job" => Ok(EventMsg::Job {}),
            other => Err(unknown()),
        }
    }
}
"#;

    const FIXTURE_REQUEST: &str = r#"impl JobRequest {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("subject", Json::str(&self.subject))];
        if let Some(t) = &self.dedup {
            pairs.push(("dedup", Json::str(t)));
        }
        Json::object(pairs)
    }
    pub fn from_json(j: &Json) -> Result<JobRequest> {
        let subject = field(j, "subject", Json::as_str, "a string")?;
        let dedup = field(j, "dedup", Json::as_str, "a string")?;
    }
}
"#;

    const FIXTURE_CORPUS: &str = "{\"cmd\":\"ping\"}\n\
                                  {\"cmd\":\"ping\",\"seq\":1}\n\
                                  {\"cmd\":\"status\",\"id\":7}\n\
                                  {\"cmd\":\"status\",\"id\":7,\"seq\":2}\n";

    /// Build an Analyze over a throwaway fixture tree.
    fn fixture(name: &str) -> Analyze {
        let root = std::env::temp_dir()
            .join(format!("claire-xtask-analyze-{}-{name}", std::process::id()));
        let _ = fs::remove_dir_all(&root);
        let src = root.join("src");
        let files: &[(&str, &str)] = &[
            ("src/serve/scheduler.rs", FIXTURE_SCHED),
            ("src/template/journal.rs", FIXTURE_TJ),
            ("src/serve/proto.rs", FIXTURE_PROTO),
            ("src/request.rs", FIXTURE_REQUEST),
            ("src/serve/journal.rs", "fn f() {}\n"),
            ("DESIGN.md", FIXTURE_DESIGN),
            ("corpus.ndjson", FIXTURE_CORPUS),
            (
                "panic_budget.toml",
                "[panics]\n\"over.rs\" = 1\n\"under.rs\" = 5\n\"gone.rs\" = 1\n[index]\n",
            ),
            ("src/over.rs", "fn f() { a.unwrap(); b.unwrap(); }\n"),
            ("src/under.rs", "fn f() { a.unwrap(); }\n"),
            ("src/unbudgeted.rs", "fn f() { panic!(\"boom\"); }\n"),
            (
                "src/tested.rs",
                "fn f() {}\n#[cfg(test)]\nmod tests { fn t() { x.unwrap(); } }\n",
            ),
        ];
        for (rel, body) in files {
            let p = root.join(rel);
            fs::create_dir_all(p.parent().unwrap()).unwrap();
            fs::write(&p, body).unwrap();
        }
        Analyze {
            design: root.join("DESIGN.md"),
            budget: root.join("panic_budget.toml"),
            corpus: root.join("corpus.ndjson"),
            artifacts: root.join("artifacts"),
            repo: root,
            src,
            violations: Vec::new(),
        }
    }

    // A1: the fixture implements `done -> running` (an injected illegal
    // transition: its guard admits any non-done state) which the
    // declared table does not list; the declared `queued -> running`
    // row is then unimplemented. Round-state tables agree.
    #[test]
    fn lifecycle_flags_illegal_and_unimplemented_transitions() {
        let mut an = fixture("a1");
        an.analysis_lifecycle(false);
        let v = &an.violations;
        assert!(
            v.iter().any(|m| m.contains("undeclared job transition `done` -> `running`")),
            "{v:?}"
        );
        assert!(
            v.iter().any(|m| m.contains("declares job transition `queued` -> `running`")),
            "{v:?}"
        );
        assert!(!v.iter().any(|m| m.contains("round-state")), "{v:?}");
    }

    // A2 baseline: the fixture's schema, tables, and corpus agree.
    #[test]
    fn wire_schema_clean_on_conforming_fixture() {
        let mut an = fixture("a2ok");
        an.analysis_wire_schema(false);
        assert!(an.violations.is_empty(), "{:?}", an.violations);
    }

    // A2 negatives: a declared conditional field emitted unconditionally
    // (schema/DESIGN.md mismatch) and a new guarded field nobody declared.
    #[test]
    fn wire_schema_flags_conditional_field_drift() {
        let mut an = fixture("a2bad");
        let proto_path = an.src.join("serve/proto.rs");
        let bad = FIXTURE_PROTO
            .replace(
                "    if let Json::Obj(m) = &mut j {\n\
                 \x20       m.insert(\"velocity\".into(), Json::str(vel));\n\
                 \x20   }\n",
                "    m.insert(\"velocity\".into(), Json::str(vel));\n\
                 \x20   if let Some(x) = &v.extra {\n\
                 \x20       m.insert(\"extra\".into(), Json::str(x));\n\
                 \x20   }\n",
            )
            .replace(
                "    let g = j.get(\"ghost\");\n",
                "    let g = j.get(\"ghost\");\n    let x = j.get(\"extra\");\n",
            );
        assert!(bad != FIXTURE_PROTO, "fixture patch must apply");
        fs::write(&proto_path, bad).unwrap();
        an.analysis_wire_schema(false);
        let v = &an.violations;
        assert!(
            v.iter().any(|m| m.contains("`velocity` is emitted unconditionally")),
            "{v:?}"
        );
        assert!(v.iter().any(|m| m.contains("`extra` is not declared")), "{v:?}");
    }

    // A2 negative: a corpus line with a field the verb cannot decode.
    #[test]
    fn wire_schema_flags_undecodable_corpus_field() {
        let mut an = fixture("a2corpus");
        let mut corpus = FIXTURE_CORPUS.to_string();
        corpus.push_str("{\"cmd\":\"ping\",\"bogus\":1}\n");
        fs::write(&an.corpus, corpus).unwrap();
        an.analysis_wire_schema(false);
        let v = &an.violations;
        assert!(v.iter().any(|m| m.contains("field(s) ['bogus']")), "{v:?}");
    }

    // A3: over budget, under budget (ratchet), unbudgeted, stale — and
    // test-module sites are not counted.
    #[test]
    fn panic_budget_ratchets_in_both_directions() {
        let mut an = fixture("a3");
        an.analysis_panic_budget();
        let v = &an.violations;
        assert!(
            v.iter().any(|m| m.contains("over.rs") && m.contains("exceed the budget")),
            "{v:?}"
        );
        assert!(
            v.iter().any(|m| m.contains("under.rs") && m.contains("ratchet the budget down")),
            "{v:?}"
        );
        assert!(
            v.iter()
                .any(|m| m.contains("unbudgeted.rs") && m.contains("no [panics] budget entry")),
            "{v:?}"
        );
        assert!(v.iter().any(|m| m.contains("stale [panics] entry for gone.rs")), "{v:?}");
        assert!(!v.iter().any(|m| m.contains("tested.rs")), "{v:?}");
    }
}
