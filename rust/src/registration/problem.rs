//! Registration problem definition and solver parameters.

use crate::error::{Error, ErrorCode, Result};
use crate::field::Field3;
use crate::precision::Precision;
use crate::registration::algorithm::AlgorithmKind;

/// Solver parameters (defaults follow the paper, section 4.1.2).
#[derive(Clone, Debug, PartialEq)]
pub struct RegParams {
    /// Which optimizer runs the solve: the paper's Gauss-Newton-Krylov
    /// (default) or a first-order baseline. Selectable by name on every
    /// request surface (`algorithm` job field, `--algorithm`).
    pub algorithm: AlgorithmKind,
    /// Kernel variant tag (paper Table 6 analog; see model.py VARIANTS).
    pub variant: String,
    /// Precision policy: `Mixed` runs the PCG Hessian matvec through the
    /// reduced-precision artifact (fp16 caches, f32 accumulation) while
    /// gradient/objective/line-search stay full precision (paper §3).
    pub precision: Precision,
    /// Target regularization weight (paper: 5e-4).
    pub beta: f64,
    /// Divergence penalty (paper: 1e-4).
    pub gamma: f64,
    /// Relative gradient tolerance (paper: 5e-2).
    pub gtol: f64,
    /// Max Gauss-Newton iterations at the target level (paper: 50).
    pub max_iter: usize,
    /// Max PCG iterations per Newton step (paper: 500).
    pub max_krylov: usize,
    /// Run the beta continuation schedule (paper default: yes).
    pub continuation: bool,
    /// Grid-continuation levels (CLAIRE's coarse-to-fine scheme): 1 runs a
    /// single grid; k > 1 restricts the images down a factor-2 pyramid and
    /// warm-starts each finer level (`solve_auto` dispatches).
    pub multires: usize,
    /// Project iterates onto divergence-free fields (Leray projection):
    /// the incompressible-flow extension of the CLAIRE formulation. The
    /// default H1-div model penalizes divergence via gamma instead.
    pub incompressible: bool,
    /// Print per-iteration progress.
    pub verbose: bool,
}

impl Default for RegParams {
    fn default() -> Self {
        RegParams {
            algorithm: AlgorithmKind::GaussNewton,
            variant: "opt-fd8-cubic".into(),
            precision: Precision::Full,
            beta: 5e-4,
            gamma: 1e-4,
            gtol: 5e-2,
            max_iter: 50,
            max_krylov: 500,
            continuation: true,
            multires: 1,
            incompressible: false,
            verbose: false,
        }
    }
}

impl RegParams {
    /// Numeric invariants shared by every request surface (the tail of
    /// `JobRequest::validate`). Solver math assumes these hold; a zero or
    /// non-finite weight would silently produce garbage iterations, so the
    /// check rejects them up front as a structured `bad_request`.
    pub fn check(&self) -> Result<()> {
        let bad = |msg: String| Err(Error::wire(ErrorCode::BadRequest, msg));
        if self.variant.is_empty() {
            return bad("job field 'variant' must be non-empty".into());
        }
        if !(self.beta.is_finite() && self.beta > 0.0) {
            return bad(format!("job field 'beta' = {} must be finite and > 0", self.beta));
        }
        if !(self.gamma.is_finite() && self.gamma >= 0.0) {
            return bad(format!("job field 'gamma' = {} must be finite and >= 0", self.gamma));
        }
        if !(self.gtol.is_finite() && self.gtol > 0.0) {
            return bad(format!("job field 'gtol' = {} must be finite and > 0", self.gtol));
        }
        if self.max_iter == 0 {
            return bad("job field 'max_iter' must be >= 1".into());
        }
        if self.max_krylov == 0 {
            return bad("job field 'max_krylov' must be >= 1".into());
        }
        if self.multires == 0 || self.multires > crate::request::MAX_MULTIRES_LEVELS {
            return bad(format!(
                "job field 'multires' = {} out of range (1..={})",
                self.multires,
                crate::request::MAX_MULTIRES_LEVELS
            ));
        }
        // Grid continuation is a Gauss-Newton feature: the first-order
        // baselines run single-grid, and silently dropping a requested
        // pyramid (while the job name advertises `+mr<k>`) would violate
        // the degraded-runs-must-be-visible contract — reject up front.
        if self.algorithm != AlgorithmKind::GaussNewton && self.multires > 1 {
            return bad(format!(
                "job field 'multires' = {} requires algorithm 'gn' \
                 (first-order baselines run single-grid)",
                self.multires
            ));
        }
        Ok(())
    }
}

/// One registration instance: reference (fixed) and template (moving)
/// images, optional label maps for DICE evaluation.
#[derive(Clone, Debug)]
pub struct RegProblem {
    pub name: String,
    /// Template image m0 (to be deformed).
    pub m0: Field3,
    /// Reference image m1.
    pub m1: Field3,
    /// Label maps aligned with m0 / m1 (for DICE; 0 = background).
    pub labels0: Option<Vec<u16>>,
    pub labels1: Option<Vec<u16>>,
}

impl RegProblem {
    pub fn n(&self) -> usize {
        self.m0.n
    }

    pub fn new(name: impl Into<String>, m0: Field3, m1: Field3) -> Self {
        assert_eq!(m0.n, m1.n, "image sizes must match");
        RegProblem { name: name.into(), m0, m1, labels0: None, labels1: None }
    }

    pub fn with_labels(mut self, l0: Vec<u16>, l1: Vec<u16>) -> Self {
        assert_eq!(l0.len(), self.m0.len());
        assert_eq!(l1.len(), self.m1.len());
        self.labels0 = Some(l0);
        self.labels1 = Some(l1);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let p = RegParams::default();
        assert_eq!(p.algorithm, AlgorithmKind::GaussNewton, "GN-Krylov unless asked");
        assert_eq!(p.precision, Precision::Full);
        assert_eq!(p.beta, 5e-4);
        assert_eq!(p.gamma, 1e-4);
        assert_eq!(p.gtol, 5e-2);
        assert_eq!(p.max_iter, 50);
        assert_eq!(p.max_krylov, 500);
        assert!(p.continuation);
        assert_eq!(p.multires, 1, "single grid unless asked");
        assert!(!p.incompressible);
    }

    #[test]
    #[should_panic(expected = "image sizes must match")]
    fn size_mismatch_rejected() {
        RegProblem::new("x", Field3::zeros(4), Field3::zeros(8));
    }

    #[test]
    fn check_rejects_degenerate_params() {
        assert!(RegParams::default().check().is_ok());
        assert!(RegParams { beta: 0.0, ..Default::default() }.check().is_err());
        assert!(RegParams { beta: f64::NAN, ..Default::default() }.check().is_err());
        assert!(RegParams { gamma: -1.0, ..Default::default() }.check().is_err());
        assert!(RegParams { gtol: 0.0, ..Default::default() }.check().is_err());
        assert!(RegParams { max_iter: 0, ..Default::default() }.check().is_err());
        assert!(RegParams { max_krylov: 0, ..Default::default() }.check().is_err());
        assert!(RegParams { multires: 0, ..Default::default() }.check().is_err());
        assert!(RegParams { multires: 9, ..Default::default() }.check().is_err());
        // Multires is GN-only: a baseline + pyramid combination must be
        // rejected, not silently degraded to single-grid.
        let gd_mr = RegParams {
            algorithm: AlgorithmKind::GradientDescent,
            multires: 3,
            ..Default::default()
        };
        let err = gd_mr.check().unwrap_err();
        assert!(err.to_string().contains("requires algorithm 'gn'"), "{err}");
        assert!(RegParams {
            algorithm: AlgorithmKind::Lbfgs,
            multires: 1,
            ..Default::default()
        }
        .check()
        .is_ok(), "single-grid baselines stay legal");
        assert!(RegParams { variant: "".into(), ..Default::default() }.check().is_err());
        let err = RegParams { beta: 0.0, ..Default::default() }.check().unwrap_err();
        assert_eq!(err.code(), ErrorCode::BadRequest);
    }
}
