//! The Gauss-Newton-Krylov registration solver (paper Algorithm 2.1).
//!
//! The L3 coordinator owns the outer loops; all PDE work executes through
//! the AOT artifacts:
//!
//! ```text
//! for beta in continuation schedule:
//!   loop (Newton):
//!     newton_setup(v)          -> g, m_traj, yb, yf, divv, [J, msq, reg]
//!     PCG on H dv = -g         -> hess_matvec(dv, caches) per iteration,
//!                                 precond(r) spectral preconditioner
//!     Armijo                   -> objective(v + alpha dv) per trial
//!     v <- v + alpha dv
//! ```
//!
//! The per-Newton-iteration caches (`m_traj`, characteristics, div v) are
//! marshalled into XLA literals once and reused by every Hessian matvec of
//! the PCG solve — the same amortization CLAIRE performs (section 2.2.3).

use std::time::Instant;

use crate::error::{Error, Result};
use crate::field::{ops, VecField3};
use crate::optim::line_search::{armijo, ArmijoOptions};
use crate::optim::pcg::{self, PcgOptions, PcgStop};
use crate::optim::{continuation, Level};
use crate::precision::Precision;
use crate::registration::algorithm::{Algorithm, AlgorithmKind, SolveCx};
use crate::registration::problem::{RegParams, RegProblem};
use crate::runtime::{Operator, OpRegistry};

/// Record of one Gauss-Newton iteration (drives convergence tables/plots).
///
/// The two precision fields record the per-phase policy actually executed:
/// `grad_precision` is the newton_setup/objective/line-search phase (pinned
/// full precision by the paper's §3 split), `matvec_precision` is what the
/// PCG Hessian matvecs ran at — `Mixed` under the mixed policy, or `Full`
/// when the artifact set has no reduced lowering and the solver fell back.
#[derive(Clone, Debug)]
pub struct IterRecord {
    pub level_beta: f64,
    pub j: f64,
    pub mismatch_rel: f64,
    pub grad_rel: f64,
    pub cg_iters: usize,
    pub alpha: f64,
    pub grad_precision: Precision,
    pub matvec_precision: Precision,
}

/// Full result of one registration solve (paper Table 7 row material).
#[derive(Clone, Debug)]
pub struct RegResult {
    pub v: VecField3,
    pub iters: usize,
    pub matvecs: usize,
    pub obj_evals: usize,
    pub j: f64,
    /// ||m(1) - m1|| / ||m0 - m1||.
    pub mismatch_rel: f64,
    /// ||g*|| / ||g0|| with g0 the gradient at v = 0 for the target beta.
    pub grad_rel: f64,
    pub history: Vec<IterRecord>,
    pub time_s: f64,
    pub converged: bool,
    /// Grid levels the solve *actually* ran: 1 for a single-grid solve; for
    /// `solve_multires` the realized pyramid depth, which is smaller than
    /// the requested depth when coarser artifacts are missing. Mirrors how
    /// the mixed-precision fallback is recorded in `IterRecord` — a
    /// degraded run must be visible in the result, never silent.
    pub levels: usize,
}

/// Compile wall time spent warming one grid level's operators (the
/// breakdown `precompile_plan` returns: satellite receipt for multires
/// serve jobs never paying coarse-grid compiles inside a timed solve).
#[derive(Clone, Copy, Debug)]
pub struct CompileLevel {
    /// Grid size of the level.
    pub n: usize,
    /// Wall seconds spent compiling (0 when every operator was warm).
    pub seconds: f64,
}

/// The Gauss-Newton-Krylov solver bound to an operator registry (paper
/// Algorithm 2.1). Implements [`Algorithm`]; drive it through
/// [`Session`](crate::registration::algorithm::Session) unless you need
/// the lower-level `solve_*` entry points directly.
pub struct GaussNewtonKrylov<'a> {
    pub reg: &'a OpRegistry,
    pub params: RegParams,
    /// Session-configured warm start for single-grid solves (`multires`
    /// plans its own coarse-to-fine warm starts). Shared, not owned: a
    /// 256^3 velocity is ~192 MiB, so the one deep copy happens only when
    /// a solve actually consumes it as its iterate buffer.
    warm_start: Option<std::sync::Arc<VecField3>>,
}

/// Deprecated spelling of [`GaussNewtonKrylov`], kept one release so
/// existing tests and benches compile unchanged.
#[deprecated(note = "use registration::GaussNewtonKrylov (or the Session builder)")]
pub type GnSolver<'a> = GaussNewtonKrylov<'a>;

impl<'a> GaussNewtonKrylov<'a> {
    pub fn new(reg: &'a OpRegistry, params: RegParams) -> Self {
        GaussNewtonKrylov { reg, params, warm_start: None }
    }

    /// Construct with a warm-start velocity (what `Session::warm_start`
    /// hands down). The warm start applies to the single-grid path;
    /// multires solves ignore it.
    pub fn with_warm_start(
        reg: &'a OpRegistry,
        params: RegParams,
        warm_start: Option<std::sync::Arc<VecField3>>,
    ) -> Self {
        GaussNewtonKrylov { reg, params, warm_start }
    }

    /// Warm one grid level's operators: the four GN solver ops plus the
    /// reduced-precision matvec when the policy asks for it (absence is
    /// tolerated — `hess_operator` falls back visibly at solve time).
    /// First-order baselines only ever evaluate the gradient/objective
    /// pair, so their warm-up skips the Newton-specific compiles.
    fn warm_level(&self, n: usize) -> Result<f64> {
        let t0 = Instant::now();
        let gn = self.params.algorithm == AlgorithmKind::GaussNewton;
        let warm_ops: &[&str] = if gn {
            &["newton_setup", "hess_matvec", "objective", "precond"]
        } else {
            &["newton_setup", "objective"]
        };
        for op in warm_ops {
            self.reg.get(op, &self.params.variant, n)?;
        }
        if gn && self.params.precision == Precision::Mixed {
            let _ = self.reg.get_p("hess_matvec", &self.params.variant, n, Precision::Mixed);
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    /// Grid sizes (finest first) the configured `multires` depth will
    /// actually realize from a fine grid of `n`, per the artifact set.
    fn plan_sizes(&self, n_fine: usize, levels: usize) -> Vec<usize> {
        let can_descend = |n: usize| -> bool {
            n % 2 == 0
                && self.reg.manifest.find("newton_setup", &self.params.variant, n / 2).is_ok()
                && self.reg.manifest.find("restrict2x", &self.params.variant, n).is_ok()
                && self.reg.manifest.find("upsample2x", &self.params.variant, n / 2).is_ok()
        };
        plan_pyramid(n_fine, levels, can_descend)
    }

    /// Compile (or fetch cached) every operator this solver's configured
    /// solve at `n` needs — including the planned coarse-level operators
    /// and the restriction/prolongation pair when `params.multires > 1`,
    /// so the first multires serve job never pays coarse-grid compiles
    /// inside its timed solve. Returns the total wall time spent
    /// compiling; `precompile_plan` has the per-level breakdown. XLA
    /// compilation is a one-time, per-process cost (the analog of
    /// CLAIRE's CUDA build step, which the paper's runtimes also
    /// exclude); `solve` reports pure solver time.
    pub fn precompile(&self, n: usize) -> Result<f64> {
        Ok(self.precompile_plan(n)?.iter().map(|l| l.seconds).sum())
    }

    /// `precompile` with the per-level compile-time breakdown: one entry
    /// per planned grid level, finest first (a single entry when
    /// `params.multires == 1` or no coarser artifacts exist).
    pub fn precompile_plan(&self, n: usize) -> Result<Vec<CompileLevel>> {
        let sizes = self.plan_sizes(n, self.params.multires.max(1));
        let mut out = Vec::with_capacity(sizes.len());
        for (li, &ln) in sizes.iter().enumerate() {
            let t0 = Instant::now();
            self.warm_level(ln)?;
            if li + 1 < sizes.len() {
                // The inter-level transfer operators belong to this
                // level's budget: restriction runs at `ln`, prolongation
                // back up from `ln / 2`.
                self.reg.get("restrict2x", &self.params.variant, ln)?;
                self.reg.get("upsample2x", &self.params.variant, ln / 2)?;
            }
            out.push(CompileLevel { n: ln, seconds: t0.elapsed().as_secs_f64() });
        }
        Ok(out)
    }

    /// Resolve the Hessian matvec operator for the configured precision.
    ///
    /// The mixed policy prefers the `hess_matvec__…__mixed` artifact (fp16
    /// caches, f32 accumulation); an artifact set that predates mixed
    /// precision (no mixed entry at all) falls back to the full-precision
    /// lowering — the record of what actually ran travels in
    /// `Operator::art.precision`, so the fallback is visible in
    /// `IterRecord`. A *present but broken* mixed artifact (missing file,
    /// compile failure) is a deployment bug and propagates as an error
    /// instead of silently running full precision under a mixed label.
    fn hess_operator(&self, n: usize) -> Result<std::sync::Arc<Operator>> {
        if self.params.precision == Precision::Mixed {
            match self.reg.get_p("hess_matvec", &self.params.variant, n, Precision::Mixed) {
                Ok(op) => return Ok(op),
                Err(Error::ArtifactNotFound { .. }) => {
                    if self.params.verbose {
                        println!(
                            "[gn] no mixed hess_matvec artifact at n={n}; using full precision"
                        );
                    }
                }
                Err(e) => return Err(e),
            }
        }
        self.reg.get("hess_matvec", &self.params.variant, n)
    }

    /// Run the full solve (with continuation if enabled).
    pub fn solve(&self, prob: &RegProblem) -> Result<RegResult> {
        self.solve_from(prob, None)
    }

    /// Run the solve from an optional warm-start velocity (grid
    /// continuation hands the prolonged coarse solution in here).
    pub fn solve_from(&self, prob: &RegProblem, v0: Option<VecField3>) -> Result<RegResult> {
        self.solve_from_cx(prob, v0, &SolveCx::new())
    }

    /// `solve_from` under an observer/cancellation context: `cx.notify`
    /// fires once per accepted Newton iteration, and a tripped
    /// cancellation flag returns `Error::Cancelled` with the partial
    /// history at the next iteration boundary.
    pub fn solve_from_cx(
        &self,
        prob: &RegProblem,
        v0: Option<VecField3>,
        cx: &SolveCx,
    ) -> Result<RegResult> {
        let n = prob.n();
        let p = &self.params;
        // Paper §3 precision split: setup (gradient), objective and
        // preconditioner stay full precision; only the Hessian matvec may
        // run reduced.
        let setup = self.reg.get("newton_setup", &p.variant, n)?;
        let hess = self.hess_operator(n)?;
        let matvec_precision = hess.art.precision;
        let grad_precision = setup.art.precision;
        let obj = self.reg.get("objective", &p.variant, n)?;
        let prec = self.reg.get("precond", &p.variant, n)?;
        let leray = if p.incompressible {
            Some(self.reg.get("leray", &p.variant, n)?)
        } else {
            None
        };
        let t0 = Instant::now();

        let m0 = &prob.m0.data;
        let m1 = &prob.m1.data;
        let msq0 = ops::sumsq_diff(m0, m1).max(1e-300);

        let levels: Vec<Level> = if p.continuation {
            continuation::default_schedule(p.beta)
        } else {
            vec![Level { beta: p.beta, gtol_rel: p.gtol, max_iter: p.max_iter }]
        };

        let mut v = match v0 {
            Some(v0) => {
                assert_eq!(v0.n, n, "warm start resolution mismatch");
                v0
            }
            None => VecField3::zeros(n),
        };
        let mut history: Vec<IterRecord> = Vec::new();
        let mut matvecs = 0usize;
        let mut obj_evals = 0usize;
        let mut iters = 0usize;
        // Scratch buffers hoisted out of the Newton/Armijo loops: the
        // all-zero vt placeholder seeding the hess/precond literal caches
        // and the line-search trial iterate are allocated once per solve,
        // not once per iteration (3 n^3 floats each).
        let zeros3 = vec![0f32; 3 * n * n * n];
        let mut trial = vec![0f32; 3 * n * n * n];
        let mut final_state = (f64::NAN, f64::NAN, f64::NAN); // (J, mism, grel)
        let mut converged = false;
        // Reference gradient norm ||g0|| at the initial iterate with the
        // *target* beta: the paper's convergence metric (||g*|| / ||g0||).
        // When the first level already runs at the target beta (no
        // continuation, or a warm-started multires level), all six setup
        // outputs are reused as the first iteration's gradient + caches —
        // saving one full gradient+transport evaluation per solve.
        let (g0_target, mut setup0) = {
            let bg = [p.beta as f32, p.gamma as f32];
            let outs = setup.call(&[&v.data, m0, m1, &bg])?;
            let g0 = ops::norm2(&outs[0]).max(1e-300);
            // gamma never varies across levels, so beta equality is the
            // whole reuse condition.
            let reusable = levels.first().is_some_and(|l| l.beta == p.beta);
            (g0, reusable.then_some(outs))
        };

        for (li, level) in levels.iter().enumerate() {
            let is_final = li == levels.len() - 1;
            let bg = [level.beta as f32, p.gamma as f32];
            let mut g0_level: Option<f64> = None;

            for _it in 0..level.max_iter {
                // Cooperative cancellation: one check per Newton iteration
                // boundary (also covers continuation-level boundaries). The
                // partial history travels with the error so the scheduler
                // can report how far the solve got.
                if cx.cancelled() {
                    return Err(Error::Cancelled { history });
                }
                // -- Newton setup: gradient + caches -----------------------
                // The reference-gradient call above already evaluated this
                // exact (v, beta) point when level 0 runs at the target
                // beta; reuse it instead of paying the setup twice.
                let cached = if li == 0 && _it == 0 { setup0.take() } else { None };
                let outs = match cached {
                    Some(outs) => outs,
                    None => setup.call(&[&v.data, m0, m1, &bg])?,
                };
                let [g, m_traj, yb, yf, divv, scalars] = match <[Vec<f32>; 6]>::try_from(outs) {
                    Ok(a) => a,
                    Err(_) => return Err(Error::Solver("newton_setup arity".into())),
                };
                let j = scalars[0] as f64;
                let msq = scalars[1] as f64;
                let mism = (msq / (prob.m0.h().powi(3) * msq0)).sqrt();
                let gnorm = ops::norm2(&g);
                let g0 = *g0_level.get_or_insert(gnorm);
                // Intermediate levels converge relative to their own entry
                // gradient; the final level uses the paper's metric.
                let grel_target = gnorm / g0_target;
                let grel =
                    if is_final { grel_target } else { gnorm / g0.max(1e-300) };
                final_state = (j, mism, grel_target);

                if p.verbose {
                    println!(
                        "[gn] beta={:.1e} it={_it} J={j:.6e} mism={mism:.4} |g|rel={grel:.3e}",
                        level.beta
                    );
                }
                if grel <= level.gtol_rel {
                    if is_final {
                        converged = true;
                    }
                    break;
                }

                // -- PCG on the Gauss-Newton system ------------------------
                // Literals for the caches are marshalled once per Newton
                // iteration and shared across all matvecs of this solve.
                // Under the mixed policy the cache tensors convert to f16
                // here (operator.rs marshals by manifest dtype), so the
                // reduced-precision cost is amortized exactly like the
                // marshalling itself.
                let hess_lits = hess.literals(&[&zeros3, &m_traj, &yb, &yf, &divv, &bg])?;
                let prec_lits = prec.literals(&[&zeros3, &bg])?;
                let forcing = grel.sqrt().min(0.5); // superlinear forcing
                let mut local_mv = 0usize;
                let pcg_res = pcg::solve(
                    &g.iter().map(|x| -x).collect::<Vec<f32>>(),
                    PcgOptions {
                        rtol: forcing,
                        max_iter: p.max_krylov,
                        matvec_precision,
                    },
                    |vt| {
                        local_mv += 1;
                        let outs = hess.call_mixed(&hess_lits, &[(0, vt)])?;
                        Ok(outs.into_iter().next().unwrap())
                    },
                    |r| {
                        let outs = prec.call_mixed(&prec_lits, &[(0, r)])?;
                        Ok(outs.into_iter().next().unwrap())
                    },
                )?;
                matvecs += local_mv;
                if pcg_res.stop == PcgStop::NegativeCurvature && p.verbose {
                    println!("[gn]   negative curvature after {} CG iters", pcg_res.iters);
                }
                let mut dv = pcg_res.x;
                if let Some(lr) = &leray {
                    // Incompressible extension: project the search
                    // direction onto divergence-free fields. With v kept
                    // divergence-free by induction (v0 = 0), the iterates
                    // remain in the constraint manifold.
                    dv = lr.call(&[&dv])?.remove(0);
                }

                // -- Armijo line search ------------------------------------
                // The objective carries h^3 quadrature weights; the
                // directional derivative of the discrete J along dv is
                // h^3 <g, dv> (g is the function-space gradient field).
                let h3 = prob.m0.h().powi(3);
                let gdx = h3 * ops::dot(&g, &dv);
                if gdx >= 0.0 {
                    return Err(Error::Solver(format!(
                        "PCG returned a non-descent direction (<g,dv>={gdx:.3e})"
                    )));
                }
                let obj_lits = obj.literals(&[&v.data, m0, m1, &bg])?;
                let mut local_evals = 0usize;
                let ls = armijo(j, gdx, ArmijoOptions::default(), |alpha| {
                    local_evals += 1;
                    ops::add_scaled(&v.data, alpha as f32, &dv, &mut trial);
                    let outs = obj.call_mixed(&obj_lits, &[(0, &trial)])?;
                    Ok(outs[0][0] as f64)
                });
                let ls = match ls {
                    Ok(ls) => ls,
                    Err(_) => {
                        // No decrease achievable at f32 resolution: the
                        // iterate is at the numerical floor for this level
                        // (CLAIRE terminates the level the same way).
                        if p.verbose {
                            println!("[gn]   line search stagnated; ending level");
                        }
                        obj_evals += local_evals;
                        if is_final {
                            converged = grel <= 2.0 * level.gtol_rel;
                        }
                        break;
                    }
                };
                obj_evals += local_evals;
                ops::axpy(ls.alpha as f32, &dv, &mut v.data);
                iters += 1;
                history.push(IterRecord {
                    level_beta: level.beta,
                    j,
                    mismatch_rel: mism,
                    grad_rel: grel,
                    cg_iters: pcg_res.iters,
                    alpha: ls.alpha,
                    grad_precision,
                    matvec_precision: pcg_res.matvec_precision,
                });
                cx.notify(history.len() - 1, history.last().expect("just pushed"));
                // Stagnation guard: stop the level when J no longer moves
                // at f32-resolvable scale.
                if history.len() >= 2 {
                    let prev = &history[history.len() - 2];
                    if prev.level_beta == level.beta
                        && (prev.j - j).abs() <= 1e-6 * j.abs().max(1e-12)
                    {
                        if is_final {
                            converged = grel <= 2.0 * level.gtol_rel;
                        }
                        break;
                    }
                }
            }
        }

        let (j, mismatch_rel, grad_rel) = final_state;
        Ok(RegResult {
            v,
            iters,
            matvecs,
            obj_evals,
            j,
            mismatch_rel,
            grad_rel,
            history,
            time_s: t0.elapsed().as_secs_f64(),
            converged,
            levels: 1,
        })
    }

    /// Dispatch on the configured `multires` level count: the serve
    /// executor, the batch service and the CLI all funnel through here so
    /// a job's `multires` field selects grid continuation uniformly.
    pub fn solve_auto(&self, prob: &RegProblem) -> Result<RegResult> {
        self.solve_auto_cx(prob, &SolveCx::new())
    }

    /// `solve_auto` under an observer/cancellation context (what
    /// `Algorithm::solve` runs).
    pub fn solve_auto_cx(&self, prob: &RegProblem, cx: &SolveCx) -> Result<RegResult> {
        if self.params.multires > 1 {
            self.solve_multires_cx(prob, self.params.multires, cx)
        } else {
            // The only deep copy of a configured warm start: the solve
            // consumes it as its mutable iterate buffer.
            let v0 = self.warm_start.as_ref().map(|v| (**v).clone());
            self.solve_from_cx(prob, v0, cx)
        }
    }

    /// Compute the deformation map y (grid units) for a solved velocity.
    pub fn defmap(&self, v: &VecField3) -> Result<Vec<f32>> {
        let op = self.reg.get("defmap", &self.params.variant, v.n)?;
        Ok(op.call(&[&v.data])?.remove(0))
    }

    /// Determinant of the deformation gradient field.
    pub fn detf(&self, v: &VecField3) -> Result<Vec<f32>> {
        let op = self.reg.get("detf", &self.params.variant, v.n)?;
        Ok(op.call(&[&v.data])?.remove(0))
    }

    /// Transport an arbitrary scalar field with the solved velocity.
    pub fn transport(&self, v: &VecField3, f: &[f32]) -> Result<Vec<f32>> {
        let op = self.reg.get("transport", &self.params.variant, v.n)?;
        Ok(op.call(&[&v.data, f])?.remove(0))
    }

    /// Grid continuation (CLAIRE's multi-resolution scheme): restrict the
    /// images down a pyramid of factor-2 levels, solve coarse-to-fine and
    /// prolong the velocity spectrally between levels. `levels` is the
    /// number of grid levels including the finest (e.g. 3 for 16-32-64).
    ///
    /// The coarse levels run with loose tolerances (they only produce warm
    /// starts); the finest level uses the configured convergence criteria.
    pub fn solve_multires(&self, prob: &RegProblem, levels: usize) -> Result<RegResult> {
        self.solve_multires_cx(prob, levels, &SolveCx::new())
    }

    /// `solve_multires` under an observer/cancellation context: iteration
    /// events carry the grid-level index, and a cancellation mid-pyramid
    /// returns the history accumulated across every level solved so far.
    pub fn solve_multires_cx(
        &self,
        prob: &RegProblem,
        levels: usize,
        cx: &SolveCx,
    ) -> Result<RegResult> {
        let n_fine = prob.n();
        assert!(levels >= 1);
        // A coarser level is only usable if solver artifacts exist for it;
        // the realized pyramid may therefore be shallower than requested —
        // the degradation is reported in `RegResult::levels`.
        let sizes = self.plan_sizes(n_fine, levels);
        // Compile every level's operators up front so the reported solve
        // time is pure solver time (same convention as `solve`).
        for (li, &n) in sizes.iter().enumerate() {
            self.warm_level(n)?;
            if li + 1 < sizes.len() {
                self.reg.get("restrict2x", &self.params.variant, n)?;
                self.reg.get("upsample2x", &self.params.variant, n / 2)?;
            }
        }
        let t0 = Instant::now();
        // Build the image pyramid via the spectral restriction operator.
        let mut pyramid: Vec<RegProblem> = vec![prob.clone()];
        for &n in &sizes[..sizes.len() - 1] {
            let cur = pyramid.last().unwrap();
            let restrict = self.reg.get("restrict2x", &self.params.variant, n)?;
            let m0 = restrict.call(&[&cur.m0.data])?.remove(0);
            let m1 = restrict.call(&[&cur.m1.data])?.remove(0);
            pyramid.push(RegProblem::new(
                format!("{}@{}", prob.name, n / 2),
                crate::field::Field3::from_vec(n / 2, m0)?,
                crate::field::Field3::from_vec(n / 2, m1)?,
            ));
        }
        pyramid.reverse(); // coarse to fine

        let mut v: Option<VecField3> = None;
        let mut total = RegResult {
            v: VecField3::zeros(n_fine),
            iters: 0,
            matvecs: 0,
            obj_evals: 0,
            j: f64::NAN,
            mismatch_rel: f64::NAN,
            grad_rel: f64::NAN,
            history: Vec::new(),
            time_s: 0.0,
            converged: false,
            levels: pyramid.len(),
        };
        for (li, p) in pyramid.iter().enumerate() {
            let is_finest = li == pyramid.len() - 1;
            let mut params = self.params.clone();
            if !is_finest {
                // Coarse levels: loose gradient tolerance, few iterations.
                params.gtol = (params.gtol * 4.0).min(0.5);
                params.max_iter = params.max_iter.min(10);
            }
            if li > 0 {
                // Warm-started levels go straight to the target beta; the
                // beta continuation already happened on the coarsest level
                // (running it again from beta_init would discard the warm
                // start's progress).
                params.continuation = false;
            }
            let level_solver = GaussNewtonKrylov::new(self.reg, params);
            let mut res = match level_solver.solve_from_cx(p, v.take(), &cx.at_level(li)) {
                Ok(res) => res,
                Err(Error::Cancelled { history }) => {
                    // Surface everything solved so far, not just the
                    // interrupted level's partial history.
                    let mut full = total.history;
                    full.extend(history);
                    return Err(Error::Cancelled { history: full });
                }
                Err(e) => return Err(e),
            };
            total.iters += res.iters;
            total.matvecs += res.matvecs;
            total.obj_evals += res.obj_evals;
            total.history.append(&mut res.history);
            if is_finest {
                total.j = res.j;
                total.mismatch_rel = res.mismatch_rel;
                total.grad_rel = res.grad_rel;
                total.converged = res.converged;
                total.v = res.v;
            } else {
                // Prolong the velocity to the next level.
                let up = self.reg.get("upsample2x", &self.params.variant, p.n())?;
                let vd = up.call(&[&res.v.data])?.remove(0);
                v = Some(VecField3::from_vec(p.n() * 2, vd)?);
            }
        }
        total.time_s = t0.elapsed().as_secs_f64();
        Ok(total)
    }
}

impl Algorithm for GaussNewtonKrylov<'_> {
    fn name(&self) -> &'static str {
        "gn"
    }

    fn solve(&self, cx: &SolveCx, prob: &RegProblem) -> Result<RegResult> {
        self.solve_auto_cx(prob, cx)
    }
}

/// Grid sizes (finest first) a `levels`-deep factor-2 pyramid will
/// actually use: descend while `can_descend(n)` holds (artifacts exist
/// for n/2, restriction/prolongation available). Pure planning logic —
/// `solve_multires` uses it for both precompilation and pyramid
/// construction, and it is unit-testable without compiled artifacts.
pub fn plan_pyramid(
    n_fine: usize,
    levels: usize,
    can_descend: impl Fn(usize) -> bool,
) -> Vec<usize> {
    let mut sizes = vec![n_fine];
    while sizes.len() < levels.max(1) {
        let n = *sizes.last().expect("sizes starts non-empty");
        if !can_descend(n) {
            break;
        }
        sizes.push(n / 2);
    }
    sizes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_descends_to_requested_depth() {
        assert_eq!(plan_pyramid(64, 3, |_| true), vec![64, 32, 16]);
        assert_eq!(plan_pyramid(64, 1, |_| true), vec![64]);
        // levels = 0 is treated as 1 (the finest grid always runs).
        assert_eq!(plan_pyramid(64, 0, |_| true), vec![64]);
    }

    #[test]
    fn plan_stops_where_artifacts_stop() {
        // Artifact set covers 16/32/64 only: a 5-level request from 64
        // degrades to 3 realized levels — visible, not silent.
        let have = |n: usize| n % 2 == 0 && n / 2 >= 16;
        assert_eq!(plan_pyramid(64, 5, have), vec![64, 32, 16]);
        // Odd grids cannot halve at all.
        assert_eq!(plan_pyramid(27, 3, |n| n % 2 == 0), vec![27]);
    }

    #[test]
    fn plan_matches_solve_multires_reporting_contract() {
        // The realized depth is what RegResult::levels reports; the
        // requested depth only survives in the job spec/name.
        let planned = plan_pyramid(32, 4, |n| n == 32);
        assert_eq!(planned.len(), 2, "one descent allowed from 32");
        assert_eq!(planned, vec![32, 16]);
    }
}
