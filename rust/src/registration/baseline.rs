//! First-order baseline registration algorithms (paper Table 8).
//!
//! `PyCA` uses plain gradient descent and `deformetrica` L-BFGS; both are
//! recreated here over the *same* objective/gradient artifacts as the
//! Gauss-Newton solver, so the comparison isolates the optimizer exactly.
//! Since the unified solve API they implement the shared
//! [`Algorithm`] trait and record their steps in the same
//! `IterRecord`/`SolveOutcome` history as GN-Krylov — select them through
//! `Session::new(&reg).algorithm(AlgorithmKind::GradientDescent)` or the
//! `algorithm` job field (`claire submit --algorithm gd`).

use std::time::Instant;

use crate::error::{Error, Result};
use crate::field::{ops, VecField3};
use crate::optim::first_order::{self, FoIter, FoOptions, Oracle};
use crate::precision::Precision;
use crate::registration::algorithm::{Algorithm, SolveCx, SolveOutcome};
use crate::registration::problem::{RegParams, RegProblem};
use crate::registration::solver::{IterRecord, RegResult};
use crate::runtime::OpRegistry;

/// Which baseline optimizer to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BaselineKind {
    /// Gradient descent with Armijo backtracking (PyCA analog).
    GradientDescent,
    /// L-BFGS (deformetrica analog).
    Lbfgs,
}

impl BaselineKind {
    pub fn label(&self) -> &'static str {
        match self {
            BaselineKind::GradientDescent => "gd (PyCA-like)",
            BaselineKind::Lbfgs => "lbfgs (deformetrica-like)",
        }
    }
}

/// Result of a baseline run (Table 8 row material). Retained for the
/// deprecated [`run_baseline`] shim; new code reads the shared
/// `SolveOutcome` instead.
#[derive(Clone, Debug)]
pub struct BaselineResult {
    pub v: VecField3,
    pub iters: usize,
    pub evals: usize,
    pub mismatch_rel: f64,
    pub j: f64,
    pub time_s: f64,
}

/// Oracle over the objective / newton_setup artifacts.
struct ArtifactOracle<'a> {
    setup: std::sync::Arc<crate::runtime::Operator>,
    obj: std::sync::Arc<crate::runtime::Operator>,
    m0: &'a [f32],
    m1: &'a [f32],
    bg: [f32; 2],
    pub msq_last: f64,
}

impl Oracle for ArtifactOracle<'_> {
    fn value_grad(&mut self, v: &[f32]) -> Result<(f64, Vec<f32>)> {
        let outs = self.setup.call(&[v, self.m0, self.m1, &self.bg])?;
        let scalars = &outs[5];
        self.msq_last = scalars[1] as f64;
        Ok((scalars[0] as f64, outs.into_iter().next().unwrap()))
    }

    fn value(&mut self, v: &[f32]) -> Result<f64> {
        let outs = self.obj.call(&[v, self.m0, self.m1, &self.bg])?;
        Ok(outs[0][0] as f64)
    }
}

/// A first-order baseline behind the unified [`Algorithm`] trait: same
/// entry point, same observer/cancellation context, same
/// `IterRecord`/`SolveOutcome` history as the Gauss-Newton solver.
/// Always runs single-grid — `RegParams::check` rejects a baseline +
/// `multires > 1` combination up front, so a request can never silently
/// lose its pyramid.
pub struct FirstOrderBaseline<'a> {
    pub reg: &'a OpRegistry,
    pub params: RegParams,
    pub kind: BaselineKind,
}

impl<'a> FirstOrderBaseline<'a> {
    pub fn new(reg: &'a OpRegistry, params: RegParams, kind: BaselineKind) -> Self {
        FirstOrderBaseline { reg, params, kind }
    }
}

impl Algorithm for FirstOrderBaseline<'_> {
    fn name(&self) -> &'static str {
        match self.kind {
            BaselineKind::GradientDescent => "gd",
            BaselineKind::Lbfgs => "lbfgs",
        }
    }

    fn solve(&self, cx: &SolveCx, prob: &RegProblem) -> Result<SolveOutcome> {
        let t0 = Instant::now();
        let n = prob.n();
        let p = &self.params;
        let mut oracle = ArtifactOracle {
            setup: self.reg.get("newton_setup", &p.variant, n)?,
            obj: self.reg.get("objective", &p.variant, n)?,
            m0: &prob.m0.data,
            m1: &prob.m1.data,
            bg: [p.beta as f32, p.gamma as f32],
            msq_last: f64::NAN,
        };
        let mut v = vec![0f32; 3 * n * n * n];
        // PyCA and deformetrica terminate on their iteration budget, not
        // on a gradient tolerance (paper section 4.2.2: "The two other
        // methods ... terminate when they reach the set upper bound for
        // the iterations"); the near-zero gtol mirrors that, so the
        // Table-8 iteration sweep stays meaningful. `max_iter` is the
        // shared budget knob — the wire's `max_iter` drives it directly.
        let opts = FoOptions { max_iter: p.max_iter, gtol_rel: 1e-9, history: 8 };
        let beta = p.beta;
        let mut history: Vec<IterRecord> = Vec::new();
        let trace = {
            // Fold each accepted step into the shared history, mirror it
            // to the observer, and honor cancellation at the boundary —
            // the exact contract the GN solver implements.
            let mut observe = |it: &FoIter| {
                let rec = IterRecord {
                    level_beta: beta,
                    // First-order steps never evaluate the mismatch term
                    // separately; the final value lands in the outcome.
                    mismatch_rel: f64::NAN,
                    j: it.j,
                    grad_rel: it.grad_rel,
                    cg_iters: 0,
                    alpha: it.alpha,
                    grad_precision: Precision::Full,
                    matvec_precision: Precision::Full,
                };
                cx.notify(it.iter, &rec);
                history.push(rec);
                !cx.cancelled()
            };
            match self.kind {
                BaselineKind::GradientDescent => {
                    first_order::gradient_descent_observed(&mut oracle, &mut v, opts, &mut observe)?
                }
                BaselineKind::Lbfgs => {
                    first_order::lbfgs_observed(&mut oracle, &mut v, opts, &mut observe)?
                }
            }
        };
        if trace.cancelled {
            return Err(Error::Cancelled { history });
        }
        // Final metrics from one more oracle evaluation at the solution.
        let (j, _) = oracle.value_grad(&v)?;
        let msq0 = ops::sumsq_diff(&prob.m0.data, &prob.m1.data).max(1e-300);
        let h3 = prob.m0.h().powi(3);
        let mismatch_rel = (oracle.msq_last / (h3 * msq0)).sqrt();
        let grad_rel = history.last().map(|r| r.grad_rel).unwrap_or(f64::NAN);
        Ok(RegResult {
            v: VecField3::from_vec(n, v)?,
            iters: trace.iters,
            matvecs: 0,
            obj_evals: trace.evals + 1,
            j,
            mismatch_rel,
            grad_rel,
            history,
            time_s: t0.elapsed().as_secs_f64(),
            // Budget-terminated methods rarely reach the GN tolerance;
            // when they do, say so with the shared metric.
            converged: grad_rel <= p.gtol,
            levels: 1,
        })
    }
}

/// Run a baseline registration with the paper's default parameters but the
/// chosen first-order optimizer.
#[deprecated(
    note = "use registration::Session with AlgorithmKind::GradientDescent / Lbfgs; \
            the outcome's history replaces BaselineResult"
)]
pub fn run_baseline(
    reg: &OpRegistry,
    prob: &RegProblem,
    params: &RegParams,
    kind: BaselineKind,
    max_iter: usize,
) -> Result<BaselineResult> {
    let params = RegParams { max_iter, ..params.clone() };
    let res = FirstOrderBaseline::new(reg, params, kind).solve(&SolveCx::new(), prob)?;
    Ok(BaselineResult {
        v: res.v,
        iters: res.iters,
        evals: res.obj_evals,
        mismatch_rel: res.mismatch_rel,
        j: res.j,
        time_s: res.time_s,
    })
}
