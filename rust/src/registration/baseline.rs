//! Baseline first-order registration drivers (paper Table 8).
//!
//! `PyCA` uses plain gradient descent and `deformetrica` L-BFGS; both are
//! recreated here over the *same* objective/gradient artifacts as the
//! Gauss-Newton solver, so the comparison isolates the optimizer exactly.

use std::time::Instant;

use crate::error::Result;
use crate::field::{ops, VecField3};
use crate::optim::first_order::{self, FoOptions, Oracle};
use crate::registration::problem::{RegParams, RegProblem};
use crate::runtime::OpRegistry;

/// Which baseline optimizer to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BaselineKind {
    /// Gradient descent with Armijo backtracking (PyCA analog).
    GradientDescent,
    /// L-BFGS (deformetrica analog).
    Lbfgs,
}

impl BaselineKind {
    pub fn label(&self) -> &'static str {
        match self {
            BaselineKind::GradientDescent => "gd (PyCA-like)",
            BaselineKind::Lbfgs => "lbfgs (deformetrica-like)",
        }
    }
}

/// Result of a baseline run (Table 8 row material).
#[derive(Clone, Debug)]
pub struct BaselineResult {
    pub v: VecField3,
    pub iters: usize,
    pub evals: usize,
    pub mismatch_rel: f64,
    pub j: f64,
    pub time_s: f64,
}

/// Oracle over the objective / newton_setup artifacts.
struct ArtifactOracle<'a> {
    setup: std::sync::Arc<crate::runtime::Operator>,
    obj: std::sync::Arc<crate::runtime::Operator>,
    m0: &'a [f32],
    m1: &'a [f32],
    bg: [f32; 2],
    pub msq_last: f64,
}

impl<'a> Oracle for ArtifactOracle<'a> {
    fn value_grad(&mut self, v: &[f32]) -> Result<(f64, Vec<f32>)> {
        let outs = self.setup.call(&[v, self.m0, self.m1, &self.bg])?;
        let scalars = &outs[5];
        self.msq_last = scalars[1] as f64;
        Ok((scalars[0] as f64, outs.into_iter().next().unwrap()))
    }

    fn value(&mut self, v: &[f32]) -> Result<f64> {
        let outs = self.obj.call(&[v, self.m0, self.m1, &self.bg])?;
        Ok(outs[0][0] as f64)
    }
}

/// Run a baseline registration with the paper's default parameters but the
/// chosen first-order optimizer.
pub fn run_baseline(
    reg: &OpRegistry,
    prob: &RegProblem,
    params: &RegParams,
    kind: BaselineKind,
    max_iter: usize,
) -> Result<BaselineResult> {
    let t0 = Instant::now();
    let n = prob.n();
    let mut oracle = ArtifactOracle {
        setup: reg.get("newton_setup", &params.variant, n)?,
        obj: reg.get("objective", &params.variant, n)?,
        m0: &prob.m0.data,
        m1: &prob.m1.data,
        bg: [params.beta as f32, params.gamma as f32],
        msq_last: f64::NAN,
    };
    let mut v = vec![0f32; 3 * n * n * n];
    // PyCA and deformetrica terminate on their iteration budget, not on a
    // gradient tolerance (paper section 4.2.2: "The two other methods ...
    // terminate when they reach the set upper bound for the iterations");
    // mirror that so the Table-8 iteration sweep is meaningful.
    let opts = FoOptions { max_iter, gtol_rel: 1e-9, history: 8 };
    let trace = match kind {
        BaselineKind::GradientDescent => first_order::gradient_descent(&mut oracle, &mut v, opts)?,
        BaselineKind::Lbfgs => first_order::lbfgs(&mut oracle, &mut v, opts)?,
    };
    // Final mismatch from one more oracle evaluation at the solution.
    let (j, _) = oracle.value_grad(&v)?;
    let msq0 = ops::sumsq_diff(&prob.m0.data, &prob.m1.data).max(1e-300);
    let h3 = prob.m0.h().powi(3);
    let mismatch_rel = (oracle.msq_last / (h3 * msq0)).sqrt();
    Ok(BaselineResult {
        v: VecField3::from_vec(n, v)?,
        iters: trace.iters,
        evals: trace.evals,
        mismatch_rel,
        j,
        time_s: t0.elapsed().as_secs_f64(),
    })
}
