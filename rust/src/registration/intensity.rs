//! Analytic arithmetic-intensity model (paper Table 2 + section 3).
//!
//! The paper classifies each kernel as memory- or compute-bound by
//! comparing FLOPS/MOPS to the device intensity. We reproduce the analytic
//! half of Table 2 exactly (same FLOP/MOP counting rules) and evaluate it
//! against the *measured* effective bandwidth of our kernels in
//! `examples/kernel_accuracy.rs` / `bench_interp`, replacing the NVIDIA
//! Visual Profiler column with host-side timings.

/// Counting rules: FPADD/FPMUL/FPSP = 1 FLOP, FMA = 2 FLOPS (paper Table 2).
#[derive(Clone, Copy, Debug)]
pub struct KernelModel {
    pub name: &'static str,
    /// FLOPs per target point (analytic, paper Table 2 column 1).
    pub flops: f64,
    /// Bytes moved per target point assuming each grid value is loaded
    /// exactly once (paper's MOPS model; 20 B/point for interpolation).
    pub mops_bytes: f64,
}

impl KernelModel {
    /// FLOPs per byte moved (the paper's "intensity" column divides the
    /// FLOP count by MOPS in bytes: e.g. GPU-TXTLIN 30/20 = 1.50).
    pub fn intensity(&self) -> f64 {
        self.flops / self.mops_bytes
    }

    /// Memory-bound iff kernel intensity is below the device intensity
    /// (peak FLOP/s over peak bytes/s, normalized to f32 words).
    pub fn memory_bound(&self, device: &DeviceModel) -> bool {
        self.flops / self.mops_bytes < device.peak_flops / device.peak_bw_bytes
    }
}

/// Device roofline parameters.
#[derive(Clone, Copy, Debug)]
pub struct DeviceModel {
    pub name: &'static str,
    pub peak_flops: f64,
    pub peak_bw_bytes: f64,
}

/// The paper's reference device (Table 2 bottom row).
pub const V100: DeviceModel =
    DeviceModel { name: "NVIDIA Tesla V100", peak_flops: 14.0e12, peak_bw_bytes: 900.0e9 };

/// Paper Table 2 kernel models (per target point; MOPS = 20 B for all
/// interpolation kernels: 3 floats of coordinates in, 1 value in, 1 out).
pub fn paper_kernels() -> Vec<KernelModel> {
    vec![
        KernelModel { name: "PRE-FILTER", flops: 22.0, mops_bytes: 8.0 },
        KernelModel { name: "GPU-TXTLIN", flops: 30.0, mops_bytes: 20.0 },
        KernelModel { name: "GPU-LAG", flops: 221.0, mops_bytes: 20.0 },
        KernelModel { name: "GPU-TXTLAG", flops: 482.0, mops_bytes: 20.0 },
        KernelModel { name: "GPU-TXTSPL", flops: 294.0, mops_bytes: 20.0 },
    ]
}

/// Our kernels under the same counting rules. Weight algebra:
/// * trilinear: 3 floor/frac + 7 FMA-ish combines per axis-product
/// * cubic Lagrange/B-spline: 12 weight polynomials (4 per axis, ~4 FLOPs
///   each with FMA=2) + 63 FMAs for the 64-point tensor-product sum
/// * FD8: 8 loads, 4 coefficient FMAs + scale per axis
pub fn our_kernels() -> Vec<KernelModel> {
    vec![
        KernelModel { name: "prefilter (15pt x 3 axes)", flops: 3.0 * 15.0 * 2.0 / 3.0, mops_bytes: 8.0 },
        KernelModel { name: "interp_lin (f32)", flops: 6.0 + 8.0 * 3.0, mops_bytes: 20.0 },
        KernelModel { name: "interp_linbf16 (texture analog)", flops: 6.0 + 8.0 * 3.0, mops_bytes: 14.0 },
        KernelModel { name: "interp_lag (cubic Lagrange)", flops: 12.0 * 5.0 + 63.0 * 2.0 + 6.0, mops_bytes: 20.0 },
        KernelModel { name: "interp_spl (B-spline + prefilter)", flops: 12.0 * 5.0 + 63.0 * 2.0 + 6.0 + 30.0, mops_bytes: 28.0 },
        KernelModel { name: "fd8 partial", flops: 4.0 * 2.0 + 1.0, mops_bytes: 8.0 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table2_intensities_match() {
        // Paper Table 2 "Analytic intensity" column: 2.75, 1.50, 11.05,
        // 24.10, 14.70 (FLOPS / MOPS-in-floats).
        let want = [2.75, 1.5, 11.05, 24.1, 14.7];
        for (k, w) in paper_kernels().iter().zip(want) {
            assert!((k.intensity() - w).abs() < 0.01, "{}: {} vs {w}", k.name, k.intensity());
        }
    }

    #[test]
    fn our_kernels_memory_bound_on_v100() {
        for k in our_kernels() {
            assert!(k.memory_bound(&V100), "{} should be memory bound", k.name);
        }
    }

    #[test]
    fn paper_txtlag_analytically_compute_bound_but_measured_memory_bound() {
        // Paper Table 2 subtlety: GPU-TXTLAG's *analytic* intensity (24.10)
        // exceeds the V100 device intensity (15.56), yet its *measured*
        // intensity (8.94, Visual Profiler) is below — the paper classifies
        // every kernel as memory bound based on measurements.
        let txtlag = &paper_kernels()[3];
        assert!(!txtlag.memory_bound(&V100));
        let measured = [2.64, 0.30, 2.36, 8.94, 10.86]; // Table 2 exp. col.
        for m in measured {
            assert!(m < V100.peak_flops / V100.peak_bw_bytes);
        }
        for (i, k) in paper_kernels().iter().enumerate() {
            if i != 3 {
                assert!(k.memory_bound(&V100), "{}", k.name);
            }
        }
    }

    #[test]
    fn device_intensity_value() {
        // Paper Table 2 bottom row: 14000 GFLOP/s over 900 GB/s = 15.56.
        let di = V100.peak_flops / V100.peak_bw_bytes;
        assert!((di - 15.56).abs() < 0.01);
    }
}
