//! Registration quality metrics (paper section 4.1.3): relative mismatch,
//! DICE overlap of label maps, and determinant-of-deformation-gradient
//! statistics.

use crate::math::kernels_ref::sample_nearest;
use crate::math::stats::Summary;

/// DICE coefficient between the *unions* of foreground labels, as used by
/// the paper for the NIREP gray-matter masks: 2|A and B| / (|A| + |B|).
pub fn dice_union(a: &[u16], b: &[u16]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut inter = 0usize;
    let mut na = 0usize;
    let mut nb = 0usize;
    for (&x, &y) in a.iter().zip(b) {
        let fa = x != 0;
        let fb = y != 0;
        na += fa as usize;
        nb += fb as usize;
        inter += (fa && fb) as usize;
    }
    if na + nb == 0 {
        return 1.0;
    }
    2.0 * inter as f64 / (na + nb) as f64
}

/// Mean per-label DICE over the labels present in either map.
pub fn dice_per_label(a: &[u16], b: &[u16], num_labels: u16) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut inter = vec![0usize; num_labels as usize + 1];
    let mut ca = vec![0usize; num_labels as usize + 1];
    let mut cb = vec![0usize; num_labels as usize + 1];
    for (&x, &y) in a.iter().zip(b) {
        ca[x as usize] += 1;
        cb[y as usize] += 1;
        if x == y {
            inter[x as usize] += 1;
        }
    }
    let mut sum = 0.0;
    let mut count = 0usize;
    for l in 1..=num_labels as usize {
        if ca[l] + cb[l] > 0 {
            sum += 2.0 * inter[l] as f64 / (ca[l] + cb[l]) as f64;
            count += 1;
        }
    }
    if count == 0 {
        1.0
    } else {
        sum / count as f64
    }
}

/// Warp a label map through the deformation map `y` (grid-unit coordinates,
/// `[3, N^3]` layout) with nearest-neighbor lookup: the paper resamples
/// label maps with nearest-neighbor interpolation.
pub fn warp_labels(labels: &[u16], n: usize, ymap: &[f32]) -> Vec<u16> {
    let m = n * n * n;
    assert_eq!(labels.len(), m);
    assert_eq!(ymap.len(), 3 * m);
    let mut out = vec![0u16; m];
    for idx in 0..m {
        let q = [ymap[idx] as f64, ymap[m + idx] as f64, ymap[2 * m + idx] as f64];
        out[idx] = sample_nearest(labels, n, q);
    }
    out
}

/// det F statistics (paper Table 7 columns min/mean/max).
pub fn detf_summary(detf: &[f32]) -> Summary {
    Summary::of(detf)
}

/// Fraction of voxels with non-positive Jacobian determinant (a map is
/// locally non-diffeomorphic where det F <= 0).
pub fn nondiffeo_fraction(detf: &[f32]) -> f64 {
    detf.iter().filter(|&&x| x <= 0.0).count() as f64 / detf.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dice_identical_is_one() {
        let a = vec![0u16, 1, 2, 1];
        assert_eq!(dice_union(&a, &a), 1.0);
        assert_eq!(dice_per_label(&a, &a, 2), 1.0);
    }

    #[test]
    fn dice_disjoint_is_zero() {
        let a = vec![1u16, 1, 0, 0];
        let b = vec![0u16, 0, 1, 1];
        assert_eq!(dice_union(&a, &b), 0.0);
    }

    #[test]
    fn dice_half_overlap() {
        let a = vec![1u16, 1, 0, 0];
        let b = vec![1u16, 0, 0, 0];
        // 2*1 / (2+1)
        assert!((dice_union(&a, &b) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn dice_empty_maps() {
        let a = vec![0u16; 8];
        assert_eq!(dice_union(&a, &a), 1.0);
    }

    #[test]
    fn warp_identity_map_is_noop() {
        let n = 4;
        let m = n * n * n;
        let labels: Vec<u16> = (0..m as u16).collect();
        let mut ymap = vec![0f32; 3 * m];
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    let idx = (i * n + j) * n + k;
                    ymap[idx] = i as f32;
                    ymap[m + idx] = j as f32;
                    ymap[2 * m + idx] = k as f32;
                }
            }
        }
        assert_eq!(warp_labels(&labels, n, &ymap), labels);
    }

    #[test]
    fn warp_shift_by_one() {
        let n = 4;
        let m = n * n * n;
        let mut labels = vec![0u16; m];
        labels[(1 * n + 0) * n + 0] = 9; // at (1,0,0)
        // y(x) = x + e1: value at (0,0,0) comes from (1,0,0).
        let mut ymap = vec![0f32; 3 * m];
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    let idx = (i * n + j) * n + k;
                    ymap[idx] = (i + 1) as f32;
                    ymap[m + idx] = j as f32;
                    ymap[2 * m + idx] = k as f32;
                }
            }
        }
        let w = warp_labels(&labels, n, &ymap);
        assert_eq!(w[0], 9);
    }

    #[test]
    fn nondiffeo_fraction_counts() {
        let d = [1.0f32, -0.5, 0.0, 2.0];
        assert!((nondiffeo_fraction(&d) - 0.5).abs() < 1e-12);
    }
}
