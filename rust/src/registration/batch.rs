//! Batched multi-subject Gauss-Newton solves on one warm executable.
//!
//! The paper frames clinical deployment as embarrassingly parallel
//! registrations; this module amortizes compile, dispatch, and transfer
//! cost across B subjects by driving the `__b{B}` artifacts (one HLO,
//! leading batch dim) through a single shared Newton loop:
//!
//! * **One dispatch per phase**: newton_setup / Hessian matvec / precond /
//!   objective each execute once per batch iteration; per-subject tensors
//!   are stacked into one literal (`operator::stacked_literal_for`).
//! * **Per-subject convergence masking**: a subject that converges (or
//!   stagnates, fails, or is cancelled) freezes its velocity slot and is
//!   fed through subsequent dispatches as dead weight instead of stalling
//!   the batch; its `IterRecord` history and observer events stop exactly
//!   where a sequential solve would have stopped.
//! * **Per-subject lifecycle**: the result is one `Result<RegResult>` per
//!   subject — a cancelled slot returns `Error::Cancelled` with its own
//!   partial history, everyone else keeps solving.
//!
//! The batched path covers the coalescing case the scheduler produces:
//! single-grid Gauss-Newton, identical `RegParams`, identical n. Anything
//! else (multires pyramids, first-order baselines, incompressible
//! projection, warm starts, or an artifact set without `__b{B}` entries)
//! falls back to per-subject sequential solves with identical semantics.

use std::time::Instant;

use crate::error::{Error, Result};
use crate::field::{ops, VecField3};
use crate::optim::line_search::ArmijoOptions;
use crate::optim::pcg::PcgStop;
use crate::optim::{continuation, Level};
use crate::precision::Precision;
use crate::registration::algorithm::SolveCx;
use crate::registration::problem::RegProblem;
use crate::registration::solver::{GaussNewtonKrylov, IterRecord, RegResult};
use crate::runtime::manifest::Manifest;
use crate::runtime::Operator;

/// Smallest lowered batch extent that fits `b` subjects for the GN solver
/// op set at grid size `n`, or `None` when the artifact set has no usable
/// batched lowering (the caller then solves sequentially). The extent is
/// planned on `newton_setup` and validated against `objective`, `precond`
/// and `hess_matvec` — all four run on the batched hot loop. The Hessian
/// matvec is checked at full precision: the mixed lowering is preferred at
/// solve time but its absence only degrades precision, never batching.
pub fn plan_batch_extent(manifest: &Manifest, variant: &str, n: usize, b: usize) -> Option<usize> {
    manifest
        .batches_for("newton_setup", n, Precision::Full)
        .into_iter()
        .find(|&ext| {
            ext >= b
                && ["objective", "precond", "hess_matvec"]
                    .iter()
                    .all(|op| manifest.find_b(op, variant, n, Precision::Full, ext).is_ok())
        })
}

/// Copy `data` into slot `idx` of a stacked buffer of `slot_len`-sized
/// subject slots.
fn stack_into(buf: &mut [f32], slot_len: usize, idx: usize, data: &[f32]) {
    buf[idx * slot_len..(idx + 1) * slot_len].copy_from_slice(data);
}

fn slot<'a>(buf: &'a [f32], slot_len: usize, idx: usize) -> &'a [f32] {
    &buf[idx * slot_len..(idx + 1) * slot_len]
}

/// Per-subject solve state inside one batched loop.
struct Slot {
    v: Vec<f32>,
    history: Vec<IterRecord>,
    iters: usize,
    matvecs: usize,
    obj_evals: usize,
    /// (J, mismatch_rel, grad_rel at target beta) of the latest setup.
    final_state: (f64, f64, f64),
    converged: bool,
    msq0: f64,
    g0_target: f64,
    g0_level: Option<f64>,
    /// Terminal per-subject outcome (cancelled / solver failure): the
    /// velocity slot is frozen and the subject is masked out of every
    /// later phase.
    terminal: Option<Error>,
    /// Finished the *current* continuation level (converged or stagnated);
    /// reset when the next level starts.
    level_done: bool,
}

impl Slot {
    fn active(&self) -> bool {
        self.terminal.is_none() && !self.level_done
    }
}

/// State of one subject's PCG solve inside the shared Krylov loop.
struct PcgSlot {
    x: Vec<f32>,
    r: Vec<f32>,
    z: Vec<f32>,
    p: Vec<f32>,
    rz: f64,
    rr: f64,
    r0: f64,
    rtol: f64,
    iters: usize,
    stop: PcgStop,
    done: bool,
}

/// State of one subject's Armijo backtracking inside the shared trial loop.
struct LsSlot {
    alpha: f64,
    j0: f64,
    gdx: f64,
    trials: usize,
    accepted: Option<f64>,
    stagnated: bool,
}

impl GaussNewtonKrylov<'_> {
    /// Resolve the *batched* Hessian matvec at extent `ext`, preferring the
    /// mixed lowering under the mixed policy with the same visible
    /// full-precision fallback as the unbatched `hess_operator`.
    fn hess_operator_b(&self, n: usize, ext: usize) -> Result<std::sync::Arc<Operator>> {
        if self.params.precision == Precision::Mixed {
            match self.reg.get_b("hess_matvec", &self.params.variant, n, Precision::Mixed, ext) {
                Ok(op) => return Ok(op),
                Err(Error::ArtifactNotFound { .. }) => {
                    if self.params.verbose {
                        println!(
                            "[gn] no mixed hess_matvec artifact at n={n} b={ext}; \
                             using full precision"
                        );
                    }
                }
                Err(e) => return Err(e),
            }
        }
        self.reg.get_b("hess_matvec", &self.params.variant, n, Precision::Full, ext)
    }

    /// Solve B single-grid GN problems in one shared Newton loop over the
    /// extent-`ext` batched artifacts (`ext >= probs.len()`; unused slots
    /// are padded with subject 0 and never read). Returns one result per
    /// subject; a whole-batch `Err` means the shared machinery itself
    /// failed (artifact call error) and every member job should fail.
    pub fn solve_batch_from_cx(
        &self,
        probs: &[&RegProblem],
        cxs: &[SolveCx],
        ext: usize,
    ) -> Result<Vec<Result<RegResult>>> {
        let b = probs.len();
        assert!(b >= 1 && b <= ext, "batch {b} exceeds artifact extent {ext}");
        assert_eq!(b, cxs.len(), "one SolveCx per subject");
        let n = probs[0].n();
        assert!(probs.iter().all(|p| p.n() == n), "coalesced subjects must share n");
        let p = &self.params;
        let m3 = 3 * n * n * n;
        let m1s = n * n * n;

        let setup = self.reg.get_b("newton_setup", &p.variant, n, Precision::Full, ext)?;
        let hess = self.hess_operator_b(n, ext)?;
        let obj = self.reg.get_b("objective", &p.variant, n, Precision::Full, ext)?;
        let prec = self.reg.get_b("precond", &p.variant, n, Precision::Full, ext)?;
        let matvec_precision = hess.art.precision;
        let grad_precision = setup.art.precision;
        let t0 = Instant::now();

        // Stacked image buffers (built once; padding slots carry subject 0
        // so the executable always sees well-formed data).
        let mut m0s = vec![0f32; ext * m1s];
        let mut m1sb = vec![0f32; ext * m1s];
        for i in 0..ext {
            let pr = probs[i.min(b - 1)];
            stack_into(&mut m0s, m1s, i, &pr.m0.data);
            stack_into(&mut m1sb, m1s, i, &pr.m1.data);
        }

        let levels: Vec<Level> = if p.continuation {
            continuation::default_schedule(p.beta)
        } else {
            vec![Level { beta: p.beta, gtol_rel: p.gtol, max_iter: p.max_iter }]
        };

        let mut slots: Vec<Slot> = probs
            .iter()
            .map(|pr| Slot {
                v: vec![0f32; m3],
                history: Vec::new(),
                iters: 0,
                matvecs: 0,
                obj_evals: 0,
                final_state: (f64::NAN, f64::NAN, f64::NAN),
                converged: false,
                msq0: ops::sumsq_diff(&pr.m0.data, &pr.m1.data).max(1e-300),
                g0_target: 1.0,
                g0_level: None,
                terminal: None,
                level_done: false,
            })
            .collect();

        // Shared scratch: stacked velocity/trial/krylov buffers.
        let mut vstk = vec![0f32; ext * m3];
        let mut trial = vec![0f32; ext * m3];
        let zeros_b3 = vec![0f32; ext * m3];
        let stack_v = |buf: &mut [f32], slots: &[Slot]| {
            for (i, s) in slots.iter().enumerate() {
                stack_into(buf, m3, i, &s.v);
            }
        };

        // Cached literals: images never change, so the setup/objective
        // calls only re-marshal the stacked velocity (and bg per level).
        let bg0 = [p.beta as f32, p.gamma as f32];
        let setup_lits = setup.literals(&[&zeros_b3, &m0s, &m1sb, &bg0])?;
        let obj_lits = obj.literals(&[&zeros_b3, &m0s, &m1sb, &bg0])?;

        // Reference gradient ||g0|| at v = 0 with the *target* beta, one
        // batched call for all subjects; reused as iteration 0's setup when
        // level 0 already runs at the target beta (same saving as the
        // sequential solver).
        stack_v(&mut vstk, &slots);
        let mut setup0 = {
            let outs = setup.call_mixed(&setup_lits, &[(0, &vstk)])?;
            for (i, s) in slots.iter_mut().enumerate() {
                s.g0_target = ops::norm2(slot(&outs[0], m3, i)).max(1e-300);
            }
            let reusable = levels.first().is_some_and(|l| l.beta == p.beta);
            reusable.then_some(outs)
        };

        let ls_opts = ArmijoOptions::default();
        for (li, level) in levels.iter().enumerate() {
            let is_final = li == levels.len() - 1;
            let bg = [level.beta as f32, p.gamma as f32];
            for s in slots.iter_mut() {
                if s.terminal.is_none() {
                    s.level_done = false;
                    s.g0_level = None;
                }
            }

            for it in 0..level.max_iter {
                // Cooperative cancellation, one check per shared iteration
                // boundary: a cancelled subject becomes a terminal slot
                // (its own partial history), the batch keeps going. A
                // subject that already finished the final level completed
                // its solve — cancellation no longer applies to it, exactly
                // as a sequential solve would have returned by now.
                for (i, s) in slots.iter_mut().enumerate() {
                    if s.terminal.is_none()
                        && !(is_final && s.level_done)
                        && cxs[i].cancelled()
                    {
                        s.terminal =
                            Some(Error::Cancelled { history: std::mem::take(&mut s.history) });
                    }
                }
                if !slots.iter().any(Slot::active) {
                    break;
                }

                // -- Batched Newton setup: gradients + caches --------------
                stack_v(&mut vstk, &slots);
                let outs = match setup0.take() {
                    Some(outs) if li == 0 && it == 0 => outs,
                    _ => setup.call_mixed(&setup_lits, &[(0, &vstk), (3, &bg)])?,
                };
                if outs.len() != 6 {
                    return Err(Error::Solver("newton_setup arity".into()));
                }
                let g_all = &outs[0];
                let scal_all = &outs[5];
                let scal_slot = scal_all.len() / ext;

                let mut grels = vec![0f64; b];
                let mut searching: Vec<usize> = Vec::with_capacity(b);
                for (i, s) in slots.iter_mut().enumerate() {
                    if !s.active() {
                        continue;
                    }
                    let sc = slot(scal_all, scal_slot, i);
                    let j = sc[0] as f64;
                    let msq = sc[1] as f64;
                    let mism = (msq / (probs[i].m0.h().powi(3) * s.msq0)).sqrt();
                    let gnorm = ops::norm2(slot(g_all, m3, i));
                    let g0 = *s.g0_level.get_or_insert(gnorm);
                    let grel_target = gnorm / s.g0_target;
                    let grel = if is_final { grel_target } else { gnorm / g0.max(1e-300) };
                    s.final_state = (j, mism, grel_target);
                    if p.verbose {
                        println!(
                            "[gn:b{ext}] s={i} beta={:.1e} it={it} J={j:.6e} \
                             mism={mism:.4} |g|rel={grel:.3e}",
                            level.beta
                        );
                    }
                    if grel <= level.gtol_rel {
                        if is_final {
                            s.converged = true;
                        }
                        s.level_done = true;
                        continue;
                    }
                    grels[i] = grel;
                    searching.push(i);
                }
                if searching.is_empty() {
                    break;
                }

                // -- Shared PCG on B Gauss-Newton systems ------------------
                // Cache literals once per Newton iteration (the batched
                // setup outputs are already stacked); every Krylov
                // iteration is then one batched matvec + one batched
                // preconditioner dispatch for all still-searching subjects.
                let hess_lits =
                    hess.literals(&[&zeros_b3, &outs[1], &outs[2], &outs[3], &outs[4], &bg])?;
                let prec_lits = prec.literals(&[&zeros_b3, &bg])?;

                let mut pcg: Vec<Option<PcgSlot>> = (0..b).map(|_| None).collect();
                let mut rstk = vec![0f32; ext * m3];
                for &i in &searching {
                    let bvec: Vec<f32> = slot(g_all, m3, i).iter().map(|x| -x).collect();
                    stack_into(&mut rstk, m3, i, &bvec);
                    pcg[i] = Some(PcgSlot {
                        x: vec![0f32; m3],
                        r: bvec,
                        z: Vec::new(),
                        p: Vec::new(),
                        rz: 0.0,
                        rr: 0.0,
                        r0: 0.0,
                        rtol: grels[i].sqrt().min(0.5), // superlinear forcing
                        iters: 0,
                        stop: PcgStop::MaxIter,
                        done: false,
                    });
                }
                {
                    let zouts = prec.call_mixed(&prec_lits, &[(0, &rstk)])?;
                    for &i in &searching {
                        let ps = pcg[i].as_mut().expect("searching slot");
                        ps.r0 = ops::norm2(&ps.r).max(1e-300);
                        ps.rr = ps.r0 * ps.r0;
                        ps.z = slot(&zouts[0], m3, i).to_vec();
                        ps.p = ps.z.clone();
                        ps.rz = ops::dot(&ps.r, &ps.z);
                    }
                }
                let mut pstk = vec![0f32; ext * m3];
                for _k in 0..p.max_krylov {
                    let live: Vec<usize> = searching
                        .iter()
                        .copied()
                        .filter(|&i| pcg[i].as_ref().is_some_and(|ps| !ps.done))
                        .collect();
                    if live.is_empty() {
                        break;
                    }
                    pstk.fill(0.0);
                    for &i in &live {
                        stack_into(&mut pstk, m3, i, &pcg[i].as_ref().unwrap().p);
                    }
                    let hp_all = hess.call_mixed(&hess_lits, &[(0, &pstk)])?;
                    for &i in &live {
                        let ps = pcg[i].as_mut().unwrap();
                        slots[i].matvecs += 1;
                        let hp = slot(&hp_all[0], m3, i);
                        let php = ops::dot(&ps.p, hp);
                        if php <= 0.0 {
                            if ps.iters == 0 {
                                ps.x.copy_from_slice(&ps.z);
                            }
                            ps.stop = PcgStop::NegativeCurvature;
                            ps.done = true;
                            continue;
                        }
                        let alpha = (ps.rz / php) as f32;
                        ops::axpy(alpha, &ps.p, &mut ps.x);
                        ps.rr = ops::axpy_dot_self(-alpha, hp, &mut ps.r);
                        ps.iters += 1;
                        if ps.rr.sqrt() <= ps.rtol * ps.r0 {
                            ps.stop = PcgStop::Converged;
                            ps.done = true;
                        }
                    }
                    let live: Vec<usize> = live
                        .into_iter()
                        .filter(|&i| !pcg[i].as_ref().unwrap().done)
                        .collect();
                    if live.is_empty() {
                        break;
                    }
                    rstk.fill(0.0);
                    for &i in &live {
                        stack_into(&mut rstk, m3, i, &pcg[i].as_ref().unwrap().r);
                    }
                    let zouts = prec.call_mixed(&prec_lits, &[(0, &rstk)])?;
                    for &i in &live {
                        let ps = pcg[i].as_mut().unwrap();
                        ps.z = slot(&zouts[0], m3, i).to_vec();
                        let rz_new = ops::dot(&ps.r, &ps.z);
                        let beta = (rz_new / ps.rz) as f32;
                        ps.rz = rz_new;
                        ops::xpay(&ps.z, beta, &mut ps.p);
                    }
                }
                if p.verbose {
                    for &i in &searching {
                        let ps = pcg[i].as_ref().unwrap();
                        if ps.stop == PcgStop::NegativeCurvature {
                            println!(
                                "[gn:b{ext}] s={i} negative curvature after {} CG iters",
                                ps.iters
                            );
                        }
                    }
                }

                // -- Per-subject descent check -----------------------------
                let mut dvs: Vec<Option<Vec<f32>>> = (0..b).map(|_| None).collect();
                let mut ls: Vec<Option<LsSlot>> = (0..b).map(|_| None).collect();
                for &i in &searching {
                    let ps = pcg[i].as_mut().expect("searching slot");
                    let dv = std::mem::take(&mut ps.x);
                    let h3 = probs[i].m0.h().powi(3);
                    let gdx = h3 * ops::dot(slot(g_all, m3, i), &dv);
                    if gdx >= 0.0 {
                        // A non-descent direction fails this subject only;
                        // the rest of the batch keeps solving.
                        slots[i].terminal = Some(Error::Solver(format!(
                            "PCG returned a non-descent direction (<g,dv>={gdx:.3e})"
                        )));
                        continue;
                    }
                    ls[i] = Some(LsSlot {
                        alpha: 1.0,
                        j0: slots[i].final_state.0,
                        gdx,
                        trials: 0,
                        accepted: None,
                        stagnated: false,
                    });
                    dvs[i] = Some(dv);
                }

                // -- Shared Armijo backtracking ----------------------------
                // Pure backtracking from alpha = 1 (GN's max_alpha = 1.0
                // disables forward expansion), one batched objective call
                // per trial round for every subject still searching.
                loop {
                    let round: Vec<usize> = searching
                        .iter()
                        .copied()
                        .filter(|&i| {
                            ls[i].as_ref().is_some_and(|l| l.accepted.is_none() && !l.stagnated)
                        })
                        .collect();
                    if round.is_empty() {
                        break;
                    }
                    stack_v(&mut trial, &slots);
                    for &i in &round {
                        let a = ls[i].as_ref().unwrap().alpha as f32;
                        let dv = dvs[i].as_ref().unwrap();
                        let dst = &mut trial[i * m3..(i + 1) * m3];
                        for (t, (&vv, &dd)) in slots[i].v.iter().zip(dv).enumerate() {
                            dst[t] = vv + a * dd;
                        }
                    }
                    let outs = obj.call_mixed(&obj_lits, &[(0, &trial), (3, &bg)])?;
                    let obj_slot = outs[0].len() / ext;
                    for &i in &round {
                        let l = ls[i].as_mut().unwrap();
                        slots[i].obj_evals += 1;
                        l.trials += 1;
                        let j = slot(&outs[0], obj_slot, i)[0] as f64;
                        if j.is_finite() && j <= l.j0 + ls_opts.c1 * l.alpha * l.gdx {
                            l.accepted = Some(l.alpha);
                        } else if l.trials >= ls_opts.max_trials {
                            l.stagnated = true;
                        } else {
                            l.alpha *= ls_opts.shrink;
                        }
                    }
                }

                // -- Accept steps, record history, run stagnation guards ---
                for &i in &searching {
                    let Some(l) = ls[i].take() else { continue };
                    let s = &mut slots[i];
                    if l.stagnated {
                        // No decrease at f32 resolution: end the level for
                        // this subject (CLAIRE terminates the same way).
                        if p.verbose {
                            println!("[gn:b{ext}] s={i} line search stagnated; ending level");
                        }
                        if is_final {
                            s.converged = grels[i] <= 2.0 * level.gtol_rel;
                        }
                        s.level_done = true;
                        continue;
                    }
                    let alpha = l.accepted.expect("accepted or stagnated");
                    let dv = dvs[i].take().expect("searching slot");
                    ops::axpy(alpha as f32, &dv, &mut s.v);
                    s.iters += 1;
                    let (j, mism, _) = s.final_state;
                    s.history.push(IterRecord {
                        level_beta: level.beta,
                        j,
                        mismatch_rel: mism,
                        grad_rel: grels[i],
                        cg_iters: pcg[i].as_ref().map_or(0, |ps| ps.iters),
                        alpha,
                        grad_precision,
                        matvec_precision,
                    });
                    cxs[i].notify(s.history.len() - 1, s.history.last().expect("just pushed"));
                    if s.history.len() >= 2 {
                        let prev = &s.history[s.history.len() - 2];
                        if prev.level_beta == level.beta
                            && (prev.j - j).abs() <= 1e-6 * j.abs().max(1e-12)
                        {
                            if is_final {
                                s.converged = grels[i] <= 2.0 * level.gtol_rel;
                            }
                            s.level_done = true;
                        }
                    }
                }
            }
        }

        let time_s = t0.elapsed().as_secs_f64();
        let mut results = Vec::with_capacity(b);
        for s in slots {
            match s.terminal {
                Some(e) => results.push(Err(e)),
                None => {
                    let (j, mismatch_rel, grad_rel) = s.final_state;
                    results.push(Ok(RegResult {
                        v: VecField3::from_vec(n, s.v)?,
                        iters: s.iters,
                        matvecs: s.matvecs,
                        obj_evals: s.obj_evals,
                        j,
                        mismatch_rel,
                        grad_rel,
                        history: s.history,
                        time_s,
                        converged: s.converged,
                        levels: 1,
                    }));
                }
            }
        }
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic_manifest(entries: &[(&str, usize)]) -> Manifest {
        // Build a manifest with the listed (op, batch) artifacts at n=16.
        let mut arts = Vec::new();
        for (op, bsz) in entries {
            let key = if *bsz == 1 {
                format!("{op}__opt-fd8-cubic__n16")
            } else {
                format!("{op}__opt-fd8-cubic__n16__b{bsz}")
            };
            let batch = if *bsz == 1 {
                String::new()
            } else {
                format!("\"batch\": {bsz},")
            };
            arts.push(format!(
                r#""{key}": {{
                    "file": "{key}.hlo.txt",
                    "op": "{op}", "variant": "opt-fd8-cubic", "n": 16, {batch}
                    "inputs": [{{"name": "x", "shape": [3,16,16,16]}}],
                    "outputs": [{{"shape": [3,16,16,16]}}]
                }}"#
            ));
        }
        let body = format!(r#"{{"nt": 4, "artifacts": {{{}}}}}"#, arts.join(","));
        let dir = std::env::temp_dir()
            .join(format!("claire_batchplan_{}_{}", entries.len(), std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn plan_picks_smallest_fitting_extent() {
        let m = synthetic_manifest(&[
            ("newton_setup", 1),
            ("newton_setup", 4),
            ("newton_setup", 8),
            ("objective", 4),
            ("objective", 8),
            ("precond", 4),
            ("precond", 8),
            ("hess_matvec", 4),
            ("hess_matvec", 8),
        ]);
        assert_eq!(plan_batch_extent(&m, "opt-fd8-cubic", 16, 2), Some(4));
        assert_eq!(plan_batch_extent(&m, "opt-fd8-cubic", 16, 4), Some(4));
        assert_eq!(plan_batch_extent(&m, "opt-fd8-cubic", 16, 5), Some(8));
        assert_eq!(plan_batch_extent(&m, "opt-fd8-cubic", 16, 8), Some(8));
        // More subjects than any lowered extent: no batched path.
        assert_eq!(plan_batch_extent(&m, "opt-fd8-cubic", 16, 9), None);
        // Wrong grid size: no batched path.
        assert_eq!(plan_batch_extent(&m, "opt-fd8-cubic", 32, 2), None);
    }

    #[test]
    fn plan_requires_the_full_op_set_at_one_extent() {
        // b4 exists for newton_setup only; b8 has the full set. A 2-subject
        // group must skip b4 (incomplete) and land on b8.
        let m = synthetic_manifest(&[
            ("newton_setup", 4),
            ("newton_setup", 8),
            ("objective", 8),
            ("precond", 8),
            ("hess_matvec", 8),
        ]);
        assert_eq!(plan_batch_extent(&m, "opt-fd8-cubic", 16, 2), Some(8));
        // No batched artifacts at all: sequential fallback.
        let m2 = synthetic_manifest(&[("newton_setup", 1)]);
        assert_eq!(plan_batch_extent(&m2, "opt-fd8-cubic", 16, 2), None);
    }

    #[test]
    fn stacking_helpers_roundtrip_slots() {
        let mut buf = vec![0f32; 6];
        stack_into(&mut buf, 2, 1, &[5.0, 6.0]);
        stack_into(&mut buf, 2, 0, &[1.0, 2.0]);
        assert_eq!(slot(&buf, 2, 0), &[1.0, 2.0]);
        assert_eq!(slot(&buf, 2, 1), &[5.0, 6.0]);
        assert_eq!(slot(&buf, 2, 2), &[0.0, 0.0]);
    }
}
