//! Registration layer: problem definition, the unified `Algorithm` /
//! `Session` solve API over the AOT artifacts (Gauss-Newton-Krylov plus
//! the first-order baselines), metrics, and performance models.

pub mod algorithm;
pub mod baseline;
pub mod batch;
pub mod groupwise;
pub mod intensity;
pub mod metrics;
pub mod problem;
pub mod report;
pub mod solver;

pub use algorithm::{
    Algorithm, AlgorithmKind, IterEvent, Session, SolveCx, SolveObserver, SolveOutcome,
};
pub use baseline::{BaselineKind, BaselineResult, FirstOrderBaseline};
pub use batch::plan_batch_extent;
pub use groupwise::{exp_velocity_with, exponential, log_mean, mean_scalar, rel_change, warp_scalar};
#[allow(deprecated)]
pub use baseline::run_baseline;
pub use problem::{RegParams, RegProblem};
pub use report::RunReport;
#[allow(deprecated)]
pub use solver::GnSolver;
pub use solver::{plan_pyramid, CompileLevel, GaussNewtonKrylov, IterRecord, RegResult};
