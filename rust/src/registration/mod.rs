//! Registration layer: problem definition, the Gauss-Newton-Krylov solver
//! over the AOT artifacts, baselines, metrics, and performance models.

pub mod baseline;
pub mod intensity;
pub mod metrics;
pub mod problem;
pub mod report;
pub mod solver;

pub use baseline::{run_baseline, BaselineKind, BaselineResult};
pub use problem::{RegParams, RegProblem};
pub use report::RunReport;
pub use solver::{plan_pyramid, GnSolver, IterRecord, RegResult};
