//! Group-wise (atlas/template) reduction math: the pure-Rust kernels
//! behind the serve `reduce` verb and the `claire template` driver.
//!
//! Template building iterates "register N subjects to the current mean,
//! average, repeat". The averaging step runs daemon-side (volumes never
//! round-trip through the client), in one of two modes:
//!
//! * **Log-domain velocity mean** — the solver's stationary velocity `v`
//!   *is* the log-space coordinate of the diffeomorphism `exp(v)`, so the
//!   log-Euclidean mean of N transforms is the plain arithmetic mean of
//!   their velocities ([`log_mean`]). The updated template is the old one
//!   warped through `exp(s * v_mean)` ([`exponential`] + [`warp_scalar`]),
//!   with the driver picking the scale (typically negative: move the
//!   template *toward* the population).
//! * **Warped-image mean fallback** — when no velocities were retained
//!   (pre-retention executors, stub tests), the template is the voxelwise
//!   mean of the subjects warped into template space ([`mean_scalar`]).
//!
//! Everything here is deliberately artifact-free (no PJRT, no HLO): the
//! reduction must run on any daemon — including stub/test deployments —
//! and its cost (one trilinear pass per squaring) is negligible next to a
//! registration solve. Accumulation is f64 throughout: a 256^3 mean over
//! dozens of subjects loses digits in f32.

use crate::error::{Error, ErrorCode, Result};
use crate::field::{Field3, VecField3};

fn bad(msg: String) -> Error {
    Error::wire(ErrorCode::BadRequest, msg)
}

/// Voxelwise arithmetic mean of scalar volumes (the warped-image template
/// update). All inputs must share one grid size; f64 accumulation.
pub fn mean_scalar(fields: &[&Field3]) -> Result<Field3> {
    let first = fields.first().ok_or_else(|| bad("mean of zero volumes".into()))?;
    let n = first.n;
    if let Some(f) = fields.iter().find(|f| f.n != n) {
        return Err(bad(format!("mean over mixed grids ({n}^3 vs {}^3)", f.n)));
    }
    let m = n * n * n;
    let mut acc = vec![0.0f64; m];
    for f in fields {
        for (a, &x) in acc.iter_mut().zip(&f.data) {
            *a += x as f64;
        }
    }
    let inv = 1.0 / fields.len() as f64;
    Ok(Field3 { n, data: acc.into_iter().map(|a| (a * inv) as f32).collect() })
}

/// Log-Euclidean mean of stationary velocity fields: the arithmetic mean
/// of the velocities (they are the log-space coordinates). All inputs
/// must share one grid size; f64 accumulation.
pub fn log_mean(fields: &[&VecField3]) -> Result<VecField3> {
    let first = fields.first().ok_or_else(|| bad("mean of zero velocity fields".into()))?;
    let n = first.n;
    if let Some(f) = fields.iter().find(|f| f.n != n) {
        return Err(bad(format!("mean over mixed grids ({n}^3 vs {}^3)", f.n)));
    }
    let m = 3 * n * n * n;
    let mut acc = vec![0.0f64; m];
    for f in fields {
        for (a, &x) in acc.iter_mut().zip(&f.data) {
            *a += x as f64;
        }
    }
    let inv = 1.0 / fields.len() as f64;
    Ok(VecField3 { n, data: acc.into_iter().map(|a| (a * inv) as f32).collect() })
}

/// Scale a velocity field by `s` (log-domain: `s * v` is the log of
/// `exp(v)^s`, so `s = -1` inverts the mean transform).
pub fn scale(v: &VecField3, s: f64) -> VecField3 {
    VecField3 { n: v.n, data: v.data.iter().map(|&x| (x as f64 * s) as f32).collect() }
}

/// Periodic trilinear sample of one n^3 component at grid coordinates
/// `(gi, gj, gk)` (index space, row-major `[x1, x2, x3]`).
fn sample_periodic(data: &[f32], n: usize, gi: f64, gj: f64, gk: f64) -> f32 {
    let ni = n as i64;
    let wrap = |i: i64| (((i % ni) + ni) % ni) as usize;
    let (i0, j0, k0) = (gi.floor(), gj.floor(), gk.floor());
    let (fi, fj, fk) = (gi - i0, gj - j0, gk - k0);
    let (i0, j0, k0) = (i0 as i64, j0 as i64, k0 as i64);
    let mut acc = 0.0f64;
    for di in 0..2i64 {
        let wi = if di == 0 { 1.0 - fi } else { fi };
        for dj in 0..2i64 {
            let wj = if dj == 0 { 1.0 - fj } else { fj };
            for dk in 0..2i64 {
                let wk = if dk == 0 { 1.0 - fk } else { fk };
                let idx = (wrap(i0 + di) * n + wrap(j0 + dj)) * n + wrap(k0 + dk);
                acc += (wi * wj * wk) * data[idx] as f64;
            }
        }
    }
    acc as f32
}

/// Warp a scalar volume through a displacement field (physical units on
/// the `[0, 2pi)^3` periodic domain): `out(x) = f(x + u(x))`, trilinear.
pub fn warp_scalar(f: &Field3, u: &VecField3) -> Result<Field3> {
    let n = f.n;
    if u.n != n {
        return Err(bad(format!("warp grid mismatch ({n}^3 image, {}^3 field)", u.n)));
    }
    let inv_h = n as f64 / (2.0 * std::f64::consts::PI);
    let (ux, uy, uz) = (u.comp(0), u.comp(1), u.comp(2));
    let mut out = vec![0.0f32; n * n * n];
    for i in 0..n {
        for j in 0..n {
            for k in 0..n {
                let idx = (i * n + j) * n + k;
                out[idx] = sample_periodic(
                    &f.data,
                    n,
                    i as f64 + ux[idx] as f64 * inv_h,
                    j as f64 + uy[idx] as f64 * inv_h,
                    k as f64 + uz[idx] as f64 * inv_h,
                );
            }
        }
    }
    Ok(Field3 { n, data: out })
}

/// Compose two displacement fields: `out(x) = a(x + b(x)) + b(x)` — one
/// scaling-and-squaring step when `a == b`.
fn compose_disp(a: &VecField3, b: &VecField3) -> VecField3 {
    let n = a.n;
    let inv_h = n as f64 / (2.0 * std::f64::consts::PI);
    let (bx, by, bz) = (b.comp(0), b.comp(1), b.comp(2));
    let mut out = vec![0.0f32; 3 * n * n * n];
    let m = n * n * n;
    for c in 0..3 {
        let ac = a.comp(c);
        let oc = &mut out[c * m..(c + 1) * m];
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    let idx = (i * n + j) * n + k;
                    oc[idx] = sample_periodic(
                        ac,
                        n,
                        i as f64 + bx[idx] as f64 * inv_h,
                        j as f64 + by[idx] as f64 * inv_h,
                        k as f64 + bz[idx] as f64 * inv_h,
                    ) + b.comp(c)[idx];
                }
            }
        }
    }
    VecField3 { n, data: out }
}

/// Exponentiate a stationary velocity field by scaling and squaring with
/// an explicit squaring count: `u0 = v / 2^k`, then `u <- u o (id+u) + u`
/// k times, yielding the displacement of `exp(v)`. The exact-cases
/// contract (pinned by tests): `exp(0)` is the zero displacement, and a
/// constant velocity exponentiates to the identical constant translation.
pub fn exp_velocity_with(v: &VecField3, squarings: usize) -> VecField3 {
    let s = 1.0 / (1u64 << squarings.min(60)) as f64;
    let mut u = scale(v, s);
    for _ in 0..squarings {
        u = compose_disp(&u, &u);
    }
    u
}

/// [`exp_velocity_with`] under an automatically chosen squaring count:
/// enough that the initial scaled step is below half a voxel (the usual
/// accuracy/diffeomorphy criterion), capped at 12 squarings.
pub fn exponential(v: &VecField3) -> VecField3 {
    let h = v.h();
    let mut k = 0usize;
    let mut step = v.max_abs() as f64;
    while step > 0.5 * h && k < 12 {
        step *= 0.5;
        k += 1;
    }
    exp_velocity_with(v, k)
}

/// Relative L2 change between two same-shape scalar volumes:
/// `||a - b|| / max(||b||, eps)` — the template-convergence criterion the
/// driver stops on. f64 accumulation.
pub fn rel_change(a: &Field3, b: &Field3) -> Result<f64> {
    if a.n != b.n {
        return Err(bad(format!("rel_change grid mismatch ({}^3 vs {}^3)", a.n, b.n)));
    }
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&x, &y) in a.data.iter().zip(&b.data) {
        let d = x as f64 - y as f64;
        num += d * d;
        den += (y as f64) * (y as f64);
    }
    Ok(num.sqrt() / den.sqrt().max(1e-12))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn img(n: usize, f: impl Fn(usize, usize, usize) -> f32) -> Field3 {
        let mut out = Field3::zeros(n);
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    out.set(i, j, k, f(i, j, k));
                }
            }
        }
        out
    }

    #[test]
    fn means_validate_inputs() {
        assert!(mean_scalar(&[]).is_err());
        assert!(log_mean(&[]).is_err());
        let a = Field3::zeros(4);
        let b = Field3::zeros(8);
        assert!(mean_scalar(&[&a, &b]).is_err(), "mixed grids rejected");
        let va = VecField3::zeros(4);
        let vb = VecField3::zeros(8);
        assert!(log_mean(&[&va, &vb]).is_err());
    }

    #[test]
    fn scalar_mean_is_voxelwise() {
        let a = img(4, |i, _, _| i as f32);
        let b = img(4, |i, _, _| 2.0 + i as f32);
        let m = mean_scalar(&[&a, &b]).unwrap();
        assert_eq!(m.at(2, 1, 3), 3.0);
        assert_eq!(m.at(0, 0, 0), 1.0);
    }

    #[test]
    fn exp_of_zero_is_identity() {
        let z = VecField3::zeros(8);
        let u = exponential(&z);
        assert!(u.data.iter().all(|&x| x == 0.0));
        // Warping through the identity leaves the image untouched.
        let f = img(8, |i, j, k| (i * 64 + j * 8 + k) as f32);
        assert_eq!(warp_scalar(&f, &u).unwrap().data, f.data);
    }

    #[test]
    fn constant_velocity_exponentiates_to_exact_translation() {
        // A constant velocity c has exp(c) = translation by c: each
        // squaring doubles the constant displacement exactly (sampling a
        // constant field is exact for any interpolation weights).
        let n = 8;
        let h = 2.0 * std::f64::consts::PI / n as f64;
        let mut v = VecField3::zeros(n);
        // Shift by exactly 2 voxels along x1 so trilinear lands on-grid.
        for x in v.comp_mut(0) {
            *x = (2.0 * h) as f32;
        }
        let u = exp_velocity_with(&v, 6);
        for c in 0..3 {
            for (got, want) in u.comp(c).iter().zip(v.comp(c)) {
                assert!((got - want).abs() < 1e-4, "exp(const) = const: {got} vs {want}");
            }
        }
        // And the warp is an exact circular shift: out(i) = f(i + 2).
        let f = img(n, |i, j, k| (i * 100 + j * 10 + k) as f32);
        let w = warp_scalar(&f, &u).unwrap();
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    let want = f.at((i + 2) % n, j, k);
                    assert!((w.at(i, j, k) - want).abs() < 1e-2, "shift at ({i},{j},{k})");
                }
            }
        }
    }

    #[test]
    fn rel_change_is_zero_on_equal_and_scales() {
        let a = img(4, |i, j, k| (i + j + k) as f32);
        assert_eq!(rel_change(&a, &a).unwrap(), 0.0);
        let b = img(4, |i, j, k| 2.0 * (i + j + k) as f32);
        let r = rel_change(&b, &a).unwrap();
        assert!((r - 1.0).abs() < 1e-9, "||2a - a||/||a|| = 1, got {r}");
        assert!(rel_change(&a, &Field3::zeros(8)).is_err());
    }

    #[test]
    fn scale_matches_log_domain_semantics() {
        let mut v = VecField3::zeros(4);
        v.data[0] = 2.0;
        let s = scale(&v, -0.5);
        assert_eq!(s.data[0], -1.0);
        assert_eq!(s.n, 4);
    }
}
