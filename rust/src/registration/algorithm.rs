//! The unified solve API: one `Algorithm` trait over every optimizer, a
//! `Session` builder as the single entry point, and the `SolveCx`
//! observer/cancellation context threaded through all of them.
//!
//! The paper's Gauss-Newton-Krylov solver is one algorithm among several
//! it benchmarks against (Tables 6-8); the follow-up CLAIRE service work
//! treats the solver as a pluggable component inside a larger system.
//! This module is that framing in code:
//!
//! * [`Algorithm`] — `solve(&self, cx, prob) -> SolveOutcome`, implemented
//!   by `GaussNewtonKrylov` and the first-order baselines
//!   (`FirstOrderBaseline`: gradient descent / L-BFGS), all producing the
//!   same `IterRecord` history and `SolveOutcome`.
//! * [`Session`] — builder binding a registry to solver policy
//!   (`Session::new(&reg).multires(3).precision(Precision::Mixed)
//!   .warm_start(v0).solve(&prob)`), selectable by name end-to-end via
//!   [`AlgorithmKind`] (`claire submit --algorithm gd` reaches it over
//!   the wire).
//! * [`SolveCx`] — a per-solve context carrying an optional
//!   [`SolveObserver`] (typed per-iteration events) and a cooperative
//!   cancellation flag the solver checks at every Newton/first-order
//!   iteration boundary, returning `Error::Cancelled` with the partial
//!   history when tripped. The serve scheduler uses it to interrupt
//!   *running* jobs and to stream live `progress` events.


use crate::error::{Error, ErrorCode, Result};
use crate::field::VecField3;
use crate::precision::Precision;
use crate::registration::baseline::{BaselineKind, FirstOrderBaseline};
use crate::registration::problem::{RegParams, RegProblem};
use crate::registration::solver::{GaussNewtonKrylov, IterRecord, RegResult};
use crate::runtime::OpRegistry;
use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::Arc;

/// Result of one solve, whatever the algorithm: the Gauss-Newton result
/// type is the shared outcome (baselines fill the Krylov-specific counters
/// with zeros and record their steps in the same `IterRecord` history).
pub type SolveOutcome = RegResult;

/// A registration optimizer: turns a problem into a `SolveOutcome` under
/// an observer/cancellation context. One trait drives GN-Krylov, the
/// first-order baselines, and anything a future PR plugs in.
pub trait Algorithm {
    /// Stable name (what `AlgorithmKind` and the wire `algorithm` field
    /// spell).
    fn name(&self) -> &'static str;

    /// Run the solve. Implementations must call `cx.notify` once per
    /// accepted iteration and honor `cx.cancelled()` at every iteration
    /// boundary by returning `Error::Cancelled` with the partial history.
    fn solve(&self, cx: &SolveCx, prob: &RegProblem) -> Result<SolveOutcome>;
}

/// Selectable-by-name algorithm registry, carried in `RegParams` and the
/// canonical `JobRequest` so every surface (CLI, config, wire) picks the
/// optimizer the same way.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AlgorithmKind {
    /// The paper's Gauss-Newton-Krylov solver (Algorithm 2.1).
    #[default]
    GaussNewton,
    /// Gradient descent with Armijo backtracking (PyCA-analog baseline).
    GradientDescent,
    /// L-BFGS (deformetrica-analog baseline).
    Lbfgs,
}

impl AlgorithmKind {
    /// Every selectable algorithm, in help-text order.
    pub const ALL: [AlgorithmKind; 3] =
        [AlgorithmKind::GaussNewton, AlgorithmKind::GradientDescent, AlgorithmKind::Lbfgs];

    pub fn as_str(&self) -> &'static str {
        match self {
            AlgorithmKind::GaussNewton => "gn",
            AlgorithmKind::GradientDescent => "gd",
            AlgorithmKind::Lbfgs => "lbfgs",
        }
    }

    /// Parse a wire/CLI/config spelling. Unknown names are a structured
    /// `bad_request` so all three request surfaces reject identically.
    pub fn parse(s: &str) -> Result<AlgorithmKind> {
        match s {
            "gn" => Ok(AlgorithmKind::GaussNewton),
            "gd" => Ok(AlgorithmKind::GradientDescent),
            "lbfgs" => Ok(AlgorithmKind::Lbfgs),
            other => Err(Error::wire(
                ErrorCode::BadRequest,
                format!("unknown algorithm '{other}' (expected gn | gd | lbfgs)"),
            )),
        }
    }
}

impl std::fmt::Display for AlgorithmKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One accepted iteration, as delivered to a [`SolveObserver`].
#[derive(Debug)]
pub struct IterEvent<'a> {
    /// Grid level of a multires solve (0 = coarsest); 0 for single-grid.
    pub level: usize,
    /// Iteration index within the current level's solve (0-based).
    pub iter: usize,
    /// The full iteration record (beta, J, ‖g‖rel, CG iterations, step
    /// length, per-phase precision).
    pub record: &'a IterRecord,
}

/// Receives typed per-iteration events from a running solve. Implemented
/// by the serve scheduler (live `progress` job events, `JobView`
/// counters) and by anything else that wants to watch a solve without
/// owning its loop. Called synchronously from the solver thread — keep it
/// cheap and never call back into the solver.
pub trait SolveObserver: Send + Sync {
    fn on_iteration(&self, ev: &IterEvent<'_>);
}

/// Observer/cancellation context for one solve. Cheap to clone; the
/// default context observes nothing and can never be cancelled, so
/// plain `solve()` calls cost one branch per iteration.
#[derive(Clone, Default)]
pub struct SolveCx {
    cancel: Option<Arc<AtomicBool>>,
    observer: Option<Arc<dyn SolveObserver>>,
    level: usize,
}

impl SolveCx {
    pub fn new() -> SolveCx {
        SolveCx::default()
    }

    /// Attach a cooperative cancellation flag. Setting it to `true` makes
    /// the solve return `Error::Cancelled` (with the partial history) at
    /// the next iteration boundary.
    pub fn with_cancel(mut self, flag: Arc<AtomicBool>) -> SolveCx {
        self.cancel = Some(flag);
        self
    }

    /// Attach a per-iteration observer.
    pub fn with_observer(mut self, obs: Arc<dyn SolveObserver>) -> SolveCx {
        self.observer = Some(obs);
        self
    }

    /// Derived context tagged with a multires grid level: same flag and
    /// observer, events carry `level`.
    pub fn at_level(&self, level: usize) -> SolveCx {
        SolveCx { cancel: self.cancel.clone(), observer: self.observer.clone(), level }
    }

    /// Whether cancellation has been requested.
    ///
    /// Acquire pairs with the canceller's Release store (the signal-flag
    /// policy in util/sync.rs): whatever the canceller wrote before
    /// requesting the stop is visible to the solver thread that observes
    /// the flag here, at an iteration boundary.
    pub fn cancelled(&self) -> bool {
        self.cancel.as_ref().is_some_and(|f| f.load(Ordering::Acquire))
    }

    /// Deliver one accepted iteration to the observer (no-op without one).
    pub fn notify(&self, iter: usize, record: &IterRecord) {
        if let Some(obs) = &self.observer {
            obs.on_iteration(&IterEvent { level: self.level, iter, record });
        }
    }
}

/// Builder for one solve: registry + solver policy + algorithm selection
/// + observer/cancellation wiring. The single entry point every driver
/// (CLI `register`, batch service, serve executor) funnels through.
///
/// ```ignore
/// let outcome = Session::new(&registry)
///     .multires(3)
///     .precision(Precision::Mixed)
///     .warm_start(v0)
///     .solve(&prob)?;
/// ```
pub struct Session<'a> {
    reg: &'a OpRegistry,
    params: RegParams,
    /// Arc-shared so repeated solves (and the algorithm construction per
    /// solve) never deep-copy the velocity; the solver clones it once,
    /// when a solve consumes it as its iterate buffer.
    warm_start: Option<Arc<VecField3>>,
    observer: Option<Arc<dyn SolveObserver>>,
    cancel: Option<Arc<AtomicBool>>,
}

impl<'a> Session<'a> {
    pub fn new(reg: &'a OpRegistry) -> Session<'a> {
        Session {
            reg,
            params: RegParams::default(),
            warm_start: None,
            observer: None,
            cancel: None,
        }
    }

    /// Replace the whole parameter set (keeps any builder-set fields that
    /// come after this call).
    pub fn params(mut self, params: RegParams) -> Self {
        self.params = params;
        self
    }

    /// Select the optimizer (`RegParams::algorithm`).
    pub fn algorithm(mut self, kind: AlgorithmKind) -> Self {
        self.params.algorithm = kind;
        self
    }

    /// Grid-continuation levels (1 = single grid).
    pub fn multires(mut self, levels: usize) -> Self {
        self.params.multires = levels;
        self
    }

    /// Solver precision policy.
    pub fn precision(mut self, p: Precision) -> Self {
        self.params.precision = p;
        self
    }

    /// Warm-start velocity (single-grid GN solves; multires plans its own
    /// coarse-to-fine warm starts).
    pub fn warm_start(mut self, v0: VecField3) -> Self {
        self.warm_start = Some(Arc::new(v0));
        self
    }

    /// Attach a per-iteration observer to the session's context.
    pub fn observer(mut self, obs: Arc<dyn SolveObserver>) -> Self {
        self.observer = Some(obs);
        self
    }

    /// Attach a cooperative cancellation flag to the session's context.
    pub fn cancel_flag(mut self, flag: Arc<AtomicBool>) -> Self {
        self.cancel = Some(flag);
        self
    }

    /// The context this session's `solve()` will run under.
    pub fn cx(&self) -> SolveCx {
        SolveCx { cancel: self.cancel.clone(), observer: self.observer.clone(), level: 0 }
    }

    /// Materialize the selected algorithm. The trait object is what makes
    /// "one entry point, N optimizers" hold: callers never branch on kind.
    fn algorithm_impl(&self) -> Box<dyn Algorithm + '_> {
        match self.params.algorithm {
            AlgorithmKind::GaussNewton => Box::new(GaussNewtonKrylov::with_warm_start(
                self.reg,
                self.params.clone(),
                self.warm_start.clone(),
            )),
            AlgorithmKind::GradientDescent => Box::new(FirstOrderBaseline::new(
                self.reg,
                self.params.clone(),
                BaselineKind::GradientDescent,
            )),
            AlgorithmKind::Lbfgs => Box::new(FirstOrderBaseline::new(
                self.reg,
                self.params.clone(),
                BaselineKind::Lbfgs,
            )),
        }
    }

    /// Run the solve under the session-built context.
    pub fn solve(&self, prob: &RegProblem) -> Result<SolveOutcome> {
        self.solve_cx(prob, &self.cx())
    }

    /// Solve B problems as one batch, one result per subject. When the
    /// configuration admits the batched Gauss-Newton path (single-grid GN,
    /// no incompressible projection, no warm start, all subjects on one
    /// grid, and `__b{B}` artifacts lowered for it), the subjects share a
    /// single Newton loop over one warm batched executable with
    /// per-subject convergence masking; otherwise each subject solves
    /// sequentially with identical semantics. Per-subject `cxs` carry
    /// independent observers and cancellation flags either way — a
    /// cancelled subject's slot returns `Error::Cancelled` with its own
    /// partial history while the rest of the batch keeps solving. A
    /// whole-call `Err` means shared machinery failed and no subject has a
    /// result.
    pub fn solve_batch_cx(
        &self,
        probs: &[&RegProblem],
        cxs: &[SolveCx],
    ) -> Result<Vec<Result<SolveOutcome>>> {
        assert_eq!(probs.len(), cxs.len(), "one SolveCx per subject");
        if probs.is_empty() {
            return Ok(Vec::new());
        }
        self.params.check()?;
        let p = &self.params;
        let n = probs[0].n();
        let batched = probs.len() >= 2
            && p.algorithm == AlgorithmKind::GaussNewton
            && p.multires == 1
            && !p.incompressible
            && self.warm_start.is_none()
            && probs.iter().all(|pr| pr.n() == n);
        if batched {
            if let Some(ext) = crate::registration::batch::plan_batch_extent(
                &self.reg.manifest,
                &p.variant,
                n,
                probs.len(),
            ) {
                let gn = GaussNewtonKrylov::new(self.reg, p.clone());
                return gn.solve_batch_from_cx(probs, cxs, ext);
            }
        }
        Ok(probs.iter().zip(cxs).map(|(prob, cx)| self.solve_cx(prob, cx)).collect())
    }

    /// Run the solve under an externally-owned context (the serve worker
    /// passes the scheduler's cancellation/progress context here).
    pub fn solve_cx(&self, prob: &RegProblem, cx: &SolveCx) -> Result<SolveOutcome> {
        // The builder can compose combinations the request surfaces would
        // refuse (e.g. a baseline with a multires pyramid); enforce the
        // shared invariants here too, so the documented "rejected up
        // front, never silently degraded" contract holds at the entry
        // point itself — not just behind `JobRequest::validate`.
        self.params.check()?;
        self.algorithm_impl().solve(cx, prob)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrips_names_and_rejects_unknown() {
        for kind in AlgorithmKind::ALL {
            assert_eq!(AlgorithmKind::parse(kind.as_str()).unwrap(), kind);
        }
        for bad in ["newton", "GN", "", "adam"] {
            let err = AlgorithmKind::parse(bad).unwrap_err();
            assert_eq!(err.code(), ErrorCode::BadRequest, "{bad}");
            assert!(err.to_string().contains("unknown algorithm"), "{err}");
        }
        assert_eq!(AlgorithmKind::default(), AlgorithmKind::GaussNewton);
    }

    #[test]
    fn default_cx_is_inert() {
        let cx = SolveCx::new();
        assert!(!cx.cancelled());
        // notify without an observer is a no-op (exercised for coverage).
        let rec = crate::registration::solver::IterRecord {
            level_beta: 1e-3,
            j: 1.0,
            mismatch_rel: 0.5,
            grad_rel: 0.1,
            cg_iters: 0,
            alpha: 1.0,
            grad_precision: Precision::Full,
            matvec_precision: Precision::Full,
        };
        cx.notify(0, &rec);
    }

    #[test]
    fn cx_flag_and_observer_are_live() {
        use crate::util::sync::Mutex;
        struct Tape(Mutex<Vec<(usize, usize, f64)>>);
        impl SolveObserver for Tape {
            fn on_iteration(&self, ev: &IterEvent<'_>) {
                self.0.lock().unwrap().push((ev.level, ev.iter, ev.record.j));
            }
        }
        let flag = Arc::new(AtomicBool::new(false));
        let tape = Arc::new(Tape(Mutex::new(Vec::new())));
        let cx = SolveCx::new().with_cancel(flag.clone()).with_observer(tape.clone());
        assert!(!cx.cancelled());
        flag.store(true, Ordering::Release);
        assert!(cx.cancelled());
        let rec = crate::registration::solver::IterRecord {
            level_beta: 1e-3,
            j: 2.5,
            mismatch_rel: 0.5,
            grad_rel: 0.1,
            cg_iters: 3,
            alpha: 0.5,
            grad_precision: Precision::Full,
            matvec_precision: Precision::Full,
        };
        cx.notify(0, &rec);
        // Level tags survive derivation; flag is shared, not copied.
        let lvl = cx.at_level(2);
        assert!(lvl.cancelled());
        lvl.notify(1, &rec);
        assert_eq!(*tape.0.lock().unwrap(), vec![(0, 0, 2.5), (2, 1, 2.5)]);
    }
}
