//! Full run report: one paper-Table-7 row with quality metrics attached.

use crate::error::Result;
use crate::math::stats::Summary;
use crate::precision::Precision;
use crate::registration::metrics::{dice_union, nondiffeo_fraction, warp_labels};
use crate::registration::problem::RegProblem;
use crate::registration::solver::{GaussNewtonKrylov, RegResult};

/// Everything the paper reports per registration run (Table 7 columns).
#[derive(Clone, Debug)]
pub struct RunReport {
    pub dataset: String,
    pub variant: String,
    /// Precision policy the solve was configured with (the per-iteration
    /// record of what actually executed lives in `IterRecord`).
    pub precision: Precision,
    pub n: usize,
    pub detf: Summary,
    pub nondiffeo_frac: f64,
    pub dice_before: Option<f64>,
    pub dice_after: Option<f64>,
    pub mismatch_rel: f64,
    pub grad_rel: f64,
    pub iters: usize,
    pub matvecs: usize,
    /// Grid levels the solve actually ran (1 = single grid). A multires
    /// job that degraded because coarse artifacts were missing shows fewer
    /// levels here than its spec requested — same visibility contract as
    /// the mixed-precision fallback in `IterRecord`.
    pub levels: usize,
    pub time_s: f64,
    pub converged: bool,
}

impl RunReport {
    /// Assemble the report from a solve result: runs defmap/detf artifacts
    /// and warps labels for DICE if present. The solver argument supplies
    /// the registry + variant for the post-solve operators; the outcome
    /// may come from any `Algorithm` (baselines produce velocities too).
    pub fn build(
        solver: &GaussNewtonKrylov,
        prob: &RegProblem,
        res: &RegResult,
    ) -> Result<RunReport> {
        let n = prob.n();
        let detf_field = solver.detf(&res.v)?;
        let detf = Summary::of(&detf_field);
        let nondiffeo = nondiffeo_fraction(&detf_field);
        let (mut dice_before, mut dice_after) = (None, None);
        if let (Some(l0), Some(l1)) = (&prob.labels0, &prob.labels1) {
            dice_before = Some(dice_union(l0, l1));
            // m(1,x) = m0(y(x)): warped template labels = l0 o y.
            let ymap = solver.defmap(&res.v)?;
            let warped = warp_labels(l0, n, &ymap);
            dice_after = Some(dice_union(&warped, l1));
        }
        Ok(RunReport {
            dataset: prob.name.clone(),
            variant: solver.params.variant.clone(),
            precision: solver.params.precision,
            n,
            detf,
            nondiffeo_frac: nondiffeo,
            dice_before,
            dice_after,
            mismatch_rel: res.mismatch_rel,
            grad_rel: res.grad_rel,
            iters: res.iters,
            matvecs: res.matvecs,
            levels: res.levels,
            time_s: res.time_s,
            converged: res.converged,
        })
    }

    /// Render as a paper-style table row.
    pub fn row(&self) -> Vec<String> {
        let fmt_opt = |o: Option<f64>| o.map(|d| format!("{d:.2}")).unwrap_or_else(|| "-".into());
        vec![
            self.variant.clone(),
            self.precision.as_str().to_string(),
            self.dataset.clone(),
            format!("{:.2}", self.detf.min),
            format!("{:.2}", self.detf.mean),
            format!("{:.2}", self.detf.max),
            fmt_opt(self.dice_before),
            fmt_opt(self.dice_after),
            format!("{:.1e}", self.mismatch_rel),
            format!("{:.1e}", self.grad_rel),
            format!("{}", self.iters),
            format!("{}", self.matvecs),
            format!("{}", self.levels),
            format!("{:.2}", self.time_s),
        ]
    }

    pub fn headers() -> Vec<&'static str> {
        vec![
            "variant", "prec", "data", "detF.min", "detF.mean", "detF.max", "DICE.pre",
            "DICE.post", "mism", "|g|rel", "#iter", "#MV", "lvls", "time[s]",
        ]
    }
}
