//! The iterative template-building driver.
//!
//! Orchestration only: every volume operation (mean, exp, warp, L2
//! drift) runs server-side through the wire `reduce` verb, so the
//! driver moves content ids and job ids, never samples. The step-wise
//! [`TemplateDriver::run_round`] API exists for the restart tests; most
//! callers use [`TemplateDriver::run`].

use std::path::PathBuf;

use crate::error::{Error, ErrorCode, Result};
use crate::request::{JobRequest, JobSource};
use crate::serve::proto::{ReduceField, ReduceRequest, Verdict};
use crate::serve::scheduler::{JobId, JobState, JobView};
use crate::serve::{Client, RetryPolicy};
use crate::template::journal::{self, RoundJournal, RoundRecord, TemplateState};

/// Template-build configuration. `spec` is the base job request every
/// per-subject registration inherits (grid size, variant, tolerances,
/// priority); its `source`, `warm_start` and `dedup` fields are
/// overwritten per subject and round.
#[derive(Clone, Debug)]
pub struct TemplateConfig {
    /// Total round budget (counting rounds completed by a previous,
    /// resumed incarnation).
    pub rounds: usize,
    /// Convergence tolerance on the template's relative L2 change.
    pub tol: f64,
    /// Step scale on the mean velocity before exponentiation (1 = the
    /// full log-domain mean).
    pub scale: f64,
    /// Round-state journal path; `None` disables restartability.
    pub state: Option<PathBuf>,
    /// Retry policy for batch submission.
    pub policy: RetryPolicy,
    /// Base job request (see struct docs).
    pub spec: JobRequest,
    /// Per-job wait bound, seconds.
    pub wait_timeout_s: f64,
}

impl Default for TemplateConfig {
    fn default() -> Self {
        TemplateConfig {
            rounds: 5,
            tol: 1e-3,
            scale: 1.0,
            state: None,
            policy: RetryPolicy::default(),
            spec: JobRequest::default(),
            wait_timeout_s: 300.0,
        }
    }
}

/// What one completed round produced.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    /// 1-based round index.
    pub round: usize,
    /// Content id of the round's template.
    pub template: String,
    /// Relative L2 change against the previous template.
    pub delta_rel: Option<f64>,
    /// Daemon job ids of the round's registrations.
    pub jobs: Vec<JobId>,
    /// Per-subject solver iteration counts.
    pub iters: Vec<Option<usize>>,
    /// Which retained output the round reduced (`velocity`, or the
    /// `warped` fallback when a backend retained no velocities).
    pub field: ReduceField,
    /// True once `delta_rel <= tol`.
    pub converged: bool,
}

/// Iterative group-wise template builder over one daemon or router
/// connection (see the module docs in `template/mod.rs` for the
/// algorithm and the journal contract).
pub struct TemplateDriver {
    client: Client,
    cfg: TemplateConfig,
    st: TemplateState,
    journal: Option<RoundJournal>,
}

impl TemplateDriver {
    /// Build a driver over `client` for the uploaded `subjects`
    /// (content ids). With a journaled `cfg.state` that already holds a
    /// run, the driver *resumes*: run id, current template, completed
    /// rounds and warm-start velocities are replayed, and `subjects`
    /// must match the journaled set (pass an empty slice to adopt it).
    /// Otherwise the round-0 bootstrap runs here: the initial template
    /// is the server-side mean of the subjects, pinned in the store.
    pub fn new(mut client: Client, subjects: Vec<String>, cfg: TemplateConfig) -> Result<Self> {
        if client.proto() < 2 {
            return Err(Error::Serve(
                "template building requires a protocol-v2 daemon (reduce/submit_batch)".into(),
            ));
        }
        if let Some(path) = &cfg.state {
            if let Some(st) = journal::replay(path)? {
                if !subjects.is_empty() && subjects != st.subjects {
                    return Err(Error::Config(format!(
                        "state file {} was built from {} different subject(s); pass the \
                         same --subjects (or none) to resume",
                        path.display(),
                        st.subjects.len()
                    )));
                }
                let journal = Some(RoundJournal::open(path)?);
                return Ok(TemplateDriver { client, cfg, st, journal });
            }
        }
        if subjects.len() < 2 {
            return Err(Error::Config(
                "template building needs at least 2 uploaded subjects".into(),
            ));
        }
        // Fresh build: bootstrap the template as the subjects' mean,
        // computed and pinned server-side.
        let receipt = client.reduce(&ReduceRequest {
            ids: subjects.clone(),
            pin: true,
            ..Default::default()
        })?;
        let st = TemplateState {
            run_id: fresh_run_id(),
            subjects,
            n: receipt.n,
            initial: receipt.id,
            rounds: Vec::new(),
        };
        let journal = match &cfg.state {
            Some(path) => {
                let j = RoundJournal::open(path)?;
                j.append_init(&st)?;
                Some(j)
            }
            None => None,
        };
        Ok(TemplateDriver { client, cfg, st, journal })
    }

    /// The current template's content id.
    pub fn template(&self) -> &str {
        self.st.template()
    }

    /// Replayed + accumulated round state.
    pub fn state(&self) -> &TemplateState {
        &self.st
    }

    /// Rounds still available under the budget.
    pub fn rounds_remaining(&self) -> usize {
        self.cfg.rounds.saturating_sub(self.st.rounds.len())
    }

    /// Run one round: register every subject against the current
    /// template (batch submit, exactly-once tokens, warm starts),
    /// reduce the outputs into the next template, journal, and report.
    pub fn run_round(&mut self) -> Result<RoundOutcome> {
        let round = self.st.next_round();
        let template = self.st.template().to_string();
        let warm = self.st.warm();
        let specs: Vec<JobRequest> = self
            .st
            .subjects
            .iter()
            .enumerate()
            .map(|(i, subject)| {
                let mut spec = self.cfg.spec.clone();
                spec.source =
                    JobSource::Uploaded { m0: template.clone(), m1: subject.clone() };
                spec.warm_start = warm.get(i).cloned().flatten();
                // Deterministic per-(run, round, subject) token: a
                // restarted driver resubmitting this round gets the
                // originally admitted job ids back.
                spec.dedup = Some(format!("tmpl-{}-r{round}-s{i}", self.st.run_id));
                spec
            })
            .collect();
        let verdicts = self.client.submit_batch_with_retry(&specs, &self.cfg.policy)?;
        let mut jobs = Vec::with_capacity(verdicts.len());
        for (i, v) in verdicts.iter().enumerate() {
            match v {
                Verdict::Admitted { id } => jobs.push(*id),
                Verdict::Rejected { code, msg, .. } => {
                    return Err(Error::wire(
                        *code,
                        format!("round {round}, subject {i}: {msg}"),
                    ));
                }
            }
        }
        let mut views: Vec<JobView> = Vec::with_capacity(jobs.len());
        for &id in &jobs {
            let view = self.client.wait_terminal(id, self.cfg.wait_timeout_s)?;
            if view.state != JobState::Done {
                return Err(Error::wire(
                    ErrorCode::Internal,
                    format!(
                        "round {round}: job {id} {}{}",
                        view.state.as_str(),
                        view.error.as_deref().map(|e| format!(" ({e})")).unwrap_or_default()
                    ),
                ));
            }
            views.push(view);
        }
        // Log-domain velocity averaging is the paper-faithful update;
        // fall back to the warped-image mean against backends that
        // retained no velocities (stub executors, transport-less ops).
        let field = if views.iter().all(|v| v.velocity.is_some()) {
            ReduceField::Velocity
        } else {
            ReduceField::Warped
        };
        let req = ReduceRequest {
            jobs: jobs.clone(),
            field,
            scale: (field == ReduceField::Velocity && self.cfg.scale != 1.0)
                .then_some(self.cfg.scale),
            apply: (field == ReduceField::Velocity).then(|| template.clone()),
            ref_id: Some(template.clone()),
            pin: true,
            unpin: Some(template.clone()),
            ..Default::default()
        };
        let receipt = self.client.reduce(&req)?;
        let record = RoundRecord {
            round,
            template: receipt.id.clone(),
            delta_rel: receipt.delta_rel,
            velocities: views.iter().map(|v| v.velocity.clone()).collect(),
            iters: views.iter().map(|v| v.iters).collect(),
        };
        if let Some(j) = &self.journal {
            j.append_round(&record)?;
        }
        self.st.rounds.push(record);
        Ok(RoundOutcome {
            round,
            template: receipt.id,
            delta_rel: receipt.delta_rel,
            jobs,
            iters: views.iter().map(|v| v.iters).collect(),
            field,
            converged: receipt.delta_rel.is_some_and(|d| d <= self.cfg.tol),
        })
    }

    /// Run rounds until convergence or budget exhaustion, calling
    /// `progress` after each. Returns the completed rounds (this
    /// incarnation's — resumed rounds are in [`state`](Self::state)).
    pub fn run(&mut self, mut progress: impl FnMut(&RoundOutcome)) -> Result<Vec<RoundOutcome>> {
        let mut out = Vec::new();
        while self.rounds_remaining() > 0 {
            let o = self.run_round()?;
            let done = o.converged;
            progress(&o);
            out.push(o);
            if done {
                break;
            }
        }
        Ok(out)
    }
}

/// A run id unique enough to namespace dedup tokens across driver
/// incarnations: wall-clock nanos + pid.
fn fresh_run_id() -> String {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    format!("{nanos:016x}-{}", std::process::id())
}
