//! Group-wise atlas/template building over the serve fleet.
//!
//! The classic unbiased-template iteration (Joshi et al., and the
//! group-wise setting CLAIRE's clinical workflow targets) as an
//! orchestration layer on top of the registration daemon:
//!
//! 1. **Bootstrap** (round 0): the initial template is the voxel-wise
//!    mean of the N uploaded subjects — computed *server-side* via the
//!    wire `reduce` verb in ids mode, so no volume ever round-trips
//!    through the driver.
//! 2. **Register**: each round submits one job per subject
//!    (`m0 = template`, `m1 = subject`) in a single `submit_batch`
//!    line, with per-subject exactly-once `dedup` tokens derived from
//!    the run id and round index — a driver killed and restarted
//!    mid-round resubmits the same tokens and receives the originally
//!    admitted job ids instead of doubling the work.
//! 3. **Reduce**: the round's retained outputs are averaged on the
//!    daemon (`reduce` in jobs mode). The default path takes the
//!    log-domain mean of the stationary velocities and warps the
//!    current template through `exp(scale * mean)`; when a backend did
//!    not retain velocities the driver falls back to the plain mean of
//!    the warped images. Either way the daemon answers with the new
//!    template's content id plus `delta_rel`, the relative L2 change
//!    against the previous template — the convergence signal, again
//!    without downloading a volume.
//! 4. **Iterate**: the new template is pinned in the store (the old
//!    one unpinned), each subject's next-round job is warm-started
//!    from its previous velocity, and the loop repeats until
//!    `delta_rel <= tol` or the round budget is exhausted.
//!
//! Every completed round is appended to an NDJSON **round-state
//! journal** ([`journal::RoundJournal`]); a restarted driver replays it
//! and resumes at the last completed round with the same run id,
//! template, and warm-start velocities.
//!
//! Exposed on the CLI as `claire template --subjects ... --rounds R
//! --tol T`; see [`driver::TemplateDriver`] for the step-wise API the
//! restart tests drive directly.

pub mod driver;
pub mod journal;

pub use driver::{RoundOutcome, TemplateConfig, TemplateDriver};
pub use journal::{RoundJournal, RoundRecord, TemplateState};
