//! Round-state journal: crash-safe NDJSON record of a template build.
//!
//! One `init` line (run identity, subjects, bootstrap template) followed
//! by one `round` line per *completed* round. Replay is torn-line
//! tolerant — a driver killed mid-append loses at most the line being
//! written, i.e. the round that had not completed — so a restarted
//! driver resumes exactly at the last completed round. The format is
//! append-only NDJSON like the serve and router journals.

use std::io::Write;
use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::Json;
use crate::util::sync::Mutex;

/// One completed round as journaled.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundRecord {
    /// 1-based round index.
    pub round: usize,
    /// Content id of the template this round produced.
    pub template: String,
    /// Relative L2 change against the previous template.
    pub delta_rel: Option<f64>,
    /// Per-subject retained velocity ids (the next round's warm starts);
    /// `None` for subjects whose backend retained nothing.
    pub velocities: Vec<Option<String>>,
    /// Per-subject solver iteration counts (warm-start telemetry).
    pub iters: Vec<Option<usize>>,
}

/// Everything replay recovers from a journal.
#[derive(Clone, Debug, Default)]
pub struct TemplateState {
    /// Stable run identity (namespaces the exactly-once dedup tokens).
    pub run_id: String,
    /// Subject content ids, in submission order.
    pub subjects: Vec<String>,
    /// Grid size.
    pub n: usize,
    /// Bootstrap template id (round 0).
    pub initial: String,
    /// Completed rounds, in order.
    pub rounds: Vec<RoundRecord>,
}

impl TemplateState {
    /// The current template: the last completed round's, or the
    /// bootstrap mean.
    pub fn template(&self) -> &str {
        self.rounds.last().map(|r| r.template.as_str()).unwrap_or(&self.initial)
    }

    /// Next round to run (1-based).
    pub fn next_round(&self) -> usize {
        self.rounds.len() + 1
    }

    /// Warm-start velocity ids for the next round (empty = cold).
    pub fn warm(&self) -> Vec<Option<String>> {
        self.rounds
            .last()
            .map(|r| r.velocities.clone())
            .unwrap_or_else(|| vec![None; self.subjects.len()])
    }
}

/// Append-only journal handle. All writes flush before returning, so a
/// `round` line on disk means that round fully completed (its reduce
/// succeeded and the new template is pinned server-side).
pub struct RoundJournal {
    file: Mutex<std::fs::File>,
}

impl RoundJournal {
    /// Open (creating or appending). Call [`replay`] first when
    /// resuming — opening never reads.
    pub fn open(path: &Path) -> Result<RoundJournal> {
        let file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        Ok(RoundJournal { file: Mutex::new(file) })
    }

    fn append(&self, j: Json) -> Result<()> {
        let mut f = self.file.lock().unwrap();
        writeln!(f, "{}", j.render())?;
        f.flush()?;
        Ok(())
    }

    /// Journal the run header (once, on a fresh build).
    // lifecycle: (start) -> init
    pub fn append_init(&self, st: &TemplateState) -> Result<()> {
        self.append(Json::object([
            ("kind", Json::str("init")),
            ("run", Json::str(&st.run_id)),
            ("n", Json::num(st.n as f64)),
            (
                "subjects",
                Json::Arr(st.subjects.iter().map(Json::str).collect()),
            ),
            ("template", Json::str(&st.initial)),
        ]))
    }

    /// Journal one completed round.
    // lifecycle: init|round -> round
    pub fn append_round(&self, r: &RoundRecord) -> Result<()> {
        let opt_str = |v: &Option<String>| match v {
            Some(s) => Json::str(s),
            None => Json::Null,
        };
        let mut pairs = vec![
            ("kind", Json::str("round")),
            ("round", Json::num(r.round as f64)),
            ("template", Json::str(&r.template)),
            (
                "velocities",
                Json::Arr(r.velocities.iter().map(opt_str).collect()),
            ),
            (
                "iters",
                Json::Arr(
                    r.iters
                        .iter()
                        .map(|i| i.map(|v| Json::num(v as f64)).unwrap_or(Json::Null))
                        .collect(),
                ),
            ),
        ];
        if let Some(d) = r.delta_rel {
            pairs.push(("delta_rel", Json::num(d)));
        }
        self.append(Json::object(pairs))
    }
}

/// Replay a journal into a [`TemplateState`]. Returns `Ok(None)` when
/// the file is missing or holds no `init` line (fresh build); malformed
/// or torn lines are skipped like the serve journals do. Round lines
/// must arrive in order — an out-of-order round (a corrupted or
/// hand-edited file) is an error rather than a silently wrong resume.
pub fn replay(path: &Path) -> Result<Option<TemplateState>> {
    let Ok(text) = std::fs::read_to_string(path) else {
        return Ok(None);
    };
    let mut st: Option<TemplateState> = None;
    for line in text.lines() {
        let Ok(j) = Json::parse(line.trim()) else {
            continue; // torn tail from a mid-append kill
        };
        match j.get("kind").and_then(Json::as_str) {
            Some("init") => {
                let (Some(run), Some(n), Some(subjects), Some(template)) = (
                    j.get("run").and_then(Json::as_str),
                    j.get("n").and_then(Json::as_usize),
                    j.get("subjects").and_then(Json::as_arr),
                    j.get("template").and_then(Json::as_str),
                ) else {
                    continue;
                };
                st = Some(TemplateState {
                    run_id: run.to_string(),
                    subjects: subjects
                        .iter()
                        .filter_map(|s| s.as_str().map(str::to_string))
                        .collect(),
                    n,
                    initial: template.to_string(),
                    rounds: Vec::new(),
                });
            }
            Some("round") => {
                let Some(st) = st.as_mut() else { continue };
                let (Some(round), Some(template)) = (
                    j.get("round").and_then(Json::as_usize),
                    j.get("template").and_then(Json::as_str),
                ) else {
                    continue;
                };
                if round != st.rounds.len() + 1 {
                    return Err(Error::Serve(format!(
                        "template journal out of order: round {round} after {} completed \
                         rounds (corrupted state file?)",
                        st.rounds.len()
                    )));
                }
                let strs = |key: &str| -> Vec<Option<String>> {
                    j.get(key)
                        .and_then(Json::as_arr)
                        .map(|a| a.iter().map(|v| v.as_str().map(str::to_string)).collect())
                        .unwrap_or_default()
                };
                st.rounds.push(RoundRecord {
                    round,
                    template: template.to_string(),
                    delta_rel: j.get("delta_rel").and_then(Json::as_f64),
                    velocities: strs("velocities"),
                    iters: j
                        .get("iters")
                        .and_then(Json::as_arr)
                        .map(|a| a.iter().map(Json::as_usize).collect())
                        .unwrap_or_default(),
                });
            }
            _ => {}
        }
    }
    Ok(st)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("claire-tmpl-journal-{}-{name}", std::process::id()))
    }

    fn state() -> TemplateState {
        TemplateState {
            run_id: "run-1".into(),
            subjects: vec!["s0".into(), "s1".into()],
            n: 16,
            initial: "t0".into(),
            rounds: Vec::new(),
        }
    }

    #[test]
    fn roundtrip_and_resume_point() {
        let path = tmp("roundtrip.ndjson");
        std::fs::remove_file(&path).ok();
        let st = state();
        let j = RoundJournal::open(&path).unwrap();
        j.append_init(&st).unwrap();
        let r1 = RoundRecord {
            round: 1,
            template: "t1".into(),
            delta_rel: Some(0.5),
            velocities: vec![Some("v0".into()), None],
            iters: vec![Some(10), Some(9)],
        };
        j.append_round(&r1).unwrap();
        let back = replay(&path).unwrap().unwrap();
        assert_eq!(back.run_id, "run-1");
        assert_eq!(back.subjects, vec!["s0", "s1"]);
        assert_eq!(back.template(), "t1");
        assert_eq!(back.next_round(), 2);
        assert_eq!(back.warm(), vec![Some("v0".to_string()), None]);
        assert_eq!(back.rounds, vec![r1]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_tail_line_is_dropped() {
        let path = tmp("torn.ndjson");
        std::fs::remove_file(&path).ok();
        let st = state();
        let j = RoundJournal::open(&path).unwrap();
        j.append_init(&st).unwrap();
        j.append_round(&RoundRecord {
            round: 1,
            template: "t1".into(),
            delta_rel: None,
            velocities: vec![None, None],
            iters: vec![None, None],
        })
        .unwrap();
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{{\"kind\":\"round\",\"round\":2,\"templ").unwrap();
        }
        let back = replay(&path).unwrap().unwrap();
        assert_eq!(back.next_round(), 2, "torn round 2 does not count as completed");
        assert_eq!(back.template(), "t1");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_is_fresh_and_out_of_order_rejected() {
        assert!(replay(&tmp("never-written.ndjson")).unwrap().is_none());

        let path = tmp("ooo.ndjson");
        std::fs::remove_file(&path).ok();
        let j = RoundJournal::open(&path).unwrap();
        j.append_init(&state()).unwrap();
        j.append_round(&RoundRecord {
            round: 3, // rounds 1-2 never journaled
            template: "t3".into(),
            delta_rel: None,
            velocities: vec![],
            iters: vec![],
        })
        .unwrap();
        assert!(replay(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
