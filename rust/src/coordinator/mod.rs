//! Batch registration coordinator.
//!
//! The paper's deployment setting (section 5): "clinical workflows require
//! high-throughput, with one or more registration tasks per node ...
//! multiple registration tasks can take place in an embarrassingly parallel
//! way". This module is that layer's one-shot front door: `BatchService`
//! submits a job vector to the serve scheduler (`crate::serve`), drains it
//! on per-worker PJRT contexts, and aggregates throughput accounting. The
//! long-lived daemon over the same execution backend lives in
//! `crate::serve::daemon`; `workload` models study-scale arrival processes.

pub mod service;
pub mod workload;

pub use service::{run_queue, BatchReport, BatchService, Job, JobOutcome, JobStatus};
pub use workload::{poisson_arrivals, simulate_queue, summarize, LatencySummary, Request};
