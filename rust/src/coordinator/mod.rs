//! Batch registration coordinator.
//!
//! The paper's deployment setting (section 5): "clinical workflows require
//! high-throughput, with one or more registration tasks per node ...
//! multiple registration tasks can take place in an embarrassingly parallel
//! way". This module is that layer: a thread-pool service that schedules
//! many registration jobs against one shared operator registry (compiled
//! executables are shared; each worker runs an independent Gauss-Newton
//! solve), with queueing, cancellation-on-error policy, and throughput
//! accounting.

pub mod service;
pub mod workload;

pub use service::{run_queue, BatchReport, BatchService, Job, JobOutcome, JobStatus};
pub use workload::{poisson_arrivals, simulate_queue, summarize, LatencySummary, Request};
