//! Clinical workload simulation: arrival processes and latency accounting.
//!
//! The paper's motivation (section 1): "large clinical, cross-center,
//! population-study workflows require thousands of registrations, reducing
//! the compute time of a single registration to seconds translates to a
//! reduction of clinical study time from weeks to a few days". This module
//! models that setting: registration requests arriving as a Poisson
//! process at a given rate, served by the batch coordinator, with
//! queueing-latency percentiles as the figure of merit.

use crate::util::rng::Rng;

/// One simulated request: arrival offset (seconds from study start) plus
/// the subject it asks to register.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: usize,
    pub arrival_s: f64,
    pub subject: String,
}

/// Generate Poisson arrivals at `rate_per_s` over `horizon_s`, cycling
/// through the study subjects deterministically.
pub fn poisson_arrivals(seed: u64, rate_per_s: f64, horizon_s: f64, subjects: &[&str]) -> Vec<Request> {
    assert!(rate_per_s > 0.0 && horizon_s > 0.0);
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut out = Vec::new();
    loop {
        // Exponential inter-arrival times.
        t += -rng.uniform().max(1e-12).ln() / rate_per_s;
        if t > horizon_s {
            break;
        }
        out.push(Request {
            id: out.len(),
            arrival_s: t,
            subject: subjects[out.len() % subjects.len()].to_string(),
        });
    }
    out
}

/// Latency record for one served request.
#[derive(Clone, Copy, Debug)]
pub struct Served {
    pub id: usize,
    pub arrival_s: f64,
    pub start_s: f64,
    pub done_s: f64,
}

impl Served {
    /// Queueing delay before service started.
    pub fn wait_s(&self) -> f64 {
        self.start_s - self.arrival_s
    }

    /// End-to-end latency (arrival to completion).
    pub fn latency_s(&self) -> f64 {
        self.done_s - self.arrival_s
    }
}

/// Deterministic queueing simulation: given arrivals and a fixed per-job
/// service time on each of `workers` servers, compute start/finish times
/// (M/D/c queue, first-come-first-served). Used to extrapolate measured
/// single-registration times to study-scale workloads without running
/// thousands of solves.
pub fn simulate_queue(arrivals: &[Request], service_s: f64, workers: usize) -> Vec<Served> {
    assert!(workers >= 1);
    let mut free_at = vec![0.0f64; workers];
    let mut out = Vec::with_capacity(arrivals.len());
    for req in arrivals {
        // Earliest-free server.
        let (w, &t_free) = free_at
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let start = t_free.max(req.arrival_s);
        let done = start + service_s;
        free_at[w] = done;
        out.push(Served { id: req.id, arrival_s: req.arrival_s, start_s: start, done_s: done });
    }
    out
}

/// Latency summary (p50/p95/max end-to-end, mean wait, utilization).
#[derive(Clone, Copy, Debug)]
pub struct LatencySummary {
    pub p50_s: f64,
    pub p95_s: f64,
    pub max_s: f64,
    pub mean_wait_s: f64,
    /// Served requests per second of simulated horizon.
    pub throughput: f64,
}

pub fn summarize(served: &[Served]) -> LatencySummary {
    assert!(!served.is_empty());
    let mut lat: Vec<f64> = served.iter().map(Served::latency_s).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let horizon = served.iter().map(|s| s.done_s).fold(0.0, f64::max);
    LatencySummary {
        p50_s: crate::math::stats::percentile_sorted(&lat, 50.0),
        p95_s: crate::math::stats::percentile_sorted(&lat, 95.0),
        max_s: *lat.last().unwrap(),
        mean_wait_s: served.iter().map(Served::wait_s).sum::<f64>() / served.len() as f64,
        throughput: served.len() as f64 / horizon.max(1e-12),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, Config};

    #[test]
    fn arrivals_are_sorted_and_bounded() {
        let reqs = poisson_arrivals(1, 2.0, 100.0, &["a", "b"]);
        assert!(!reqs.is_empty());
        for w in reqs.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
        assert!(reqs.last().unwrap().arrival_s <= 100.0);
        // Expected count ~ rate * horizon = 200; loose band.
        assert!(reqs.len() > 120 && reqs.len() < 300, "{}", reqs.len());
    }

    #[test]
    fn queue_respects_causality_and_capacity() {
        prop::check_msg(
            Config { cases: 40, seed: 70 },
            |r| {
                let rate = 0.5 + r.uniform() * 4.0;
                let service = 0.1 + r.uniform() * 2.0;
                let workers = 1 + r.below(4) as usize;
                (poisson_arrivals(r.next_u64(), rate, 50.0, &["x"]), service, workers)
            },
            |(reqs, service, workers)| {
                if reqs.is_empty() {
                    return Ok(());
                }
                let served = simulate_queue(reqs, *service, *workers);
                // Causality: no job starts before it arrives.
                for s in &served {
                    if s.start_s < s.arrival_s - 1e-12 {
                        return Err(format!("job {} started early", s.id));
                    }
                }
                // Capacity: at most `workers` jobs in service at any time.
                for s in &served {
                    let mid = s.start_s + service / 2.0;
                    let in_service = served
                        .iter()
                        .filter(|o| o.start_s <= mid && mid < o.done_s)
                        .count();
                    if in_service > *workers {
                        return Err(format!("{in_service} jobs in service at t={mid}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn more_workers_reduce_latency_under_load() {
        let reqs = poisson_arrivals(3, 1.0, 200.0, &["x"]);
        let s1 = summarize(&simulate_queue(&reqs, 1.5, 1)); // overloaded
        let s4 = summarize(&simulate_queue(&reqs, 1.5, 4)); // comfortable
        assert!(s4.p95_s < s1.p95_s, "p95 {} !< {}", s4.p95_s, s1.p95_s);
        assert!(s4.mean_wait_s < s1.mean_wait_s);
    }

    #[test]
    fn idle_system_latency_equals_service_time() {
        // Very low rate: every request finds a free server.
        let reqs = poisson_arrivals(4, 0.01, 1000.0, &["x"]);
        let served = simulate_queue(&reqs, 2.0, 2);
        let s = summarize(&served);
        assert!((s.p50_s - 2.0).abs() < 1e-9);
        assert!(s.mean_wait_s < 1e-9);
    }
}
