//! One-shot batch registration API over the serve scheduler.
//!
//! Historically this module owned its own `Mutex<VecDeque>` thread pool;
//! that pool is now the daemon's execution backend (`serve::scheduler`),
//! and `BatchService` is the one-shot front door: submit a vector of jobs
//! at batch priority, drain, and collect a `BatchReport`. The `xla`
//! crate's PJRT handles are deliberately `!Send` (they wrap `Rc` + raw
//! pointers), so each worker owns its *own* PJRT client and operator
//! cache — the paper's setting exactly: "multiple registration tasks can
//! take place in an embarrassingly parallel way", one device context per
//! task. The generic `run_queue` helper remains for cheap fan-out work
//! that needs no lifecycle tracking.

use std::collections::VecDeque;
use std::time::Instant;

use crate::error::Result;
use crate::registration::problem::{RegParams, RegProblem};
use crate::registration::report::RunReport;
use crate::serve::proto::Priority;
use crate::serve::scheduler::{
    worker_loop, FailingExecutor, JobPayload, JobState as ServeState, PjrtExecutor, Scheduler,
};
use crate::util::sync::{thread, Arc, Mutex};

use std::path::PathBuf;

/// One queued registration job. The job shape matches the serve daemon's:
/// `params` carries the full solver policy — the algorithm, precision
/// *and* the `multires` level count — so a batch entry runs exactly what
/// the wire's `submit` would (the same `Session` entry point dispatches
/// in both paths, and batch jobs inherit cooperative cancellation for
/// free through the shared worker loop).
#[derive(Clone, Debug)]
pub struct Job {
    pub id: usize,
    pub problem: RegProblem,
    pub params: RegParams,
}

/// Job lifecycle state (observable while the batch runs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    Queued,
    Running,
    Done,
    Failed,
}

/// Outcome of one job.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub id: usize,
    pub dataset: String,
    pub status: JobStatus,
    pub report: Option<RunReport>,
    pub error: Option<String>,
    pub wall_s: f64,
}

/// Aggregate statistics for a completed batch.
#[derive(Clone, Debug)]
pub struct BatchReport {
    pub outcomes: Vec<JobOutcome>,
    pub wall_s: f64,
    pub workers: usize,
}

impl BatchReport {
    pub fn succeeded(&self) -> usize {
        self.outcomes.iter().filter(|o| o.status == JobStatus::Done).count()
    }

    pub fn failed(&self) -> usize {
        self.outcomes.iter().filter(|o| o.status == JobStatus::Failed).count()
    }

    /// Registrations per second over the batch (the clinical-throughput
    /// number the paper's motivation is about). Guarded: a zero-duration
    /// batch (empty, or a clock that did not advance) reports 0.0, never
    /// `inf`/`NaN` — these numbers land in BENCH JSON and division by a
    /// degenerate wall clock must not poison downstream parsing.
    pub fn throughput(&self) -> f64 {
        self.rps()
    }

    /// Successful registrations per second; 0.0 when `wall_s` is zero,
    /// negative, or non-finite.
    pub fn rps(&self) -> f64 {
        if self.wall_s <= 0.0 || !self.wall_s.is_finite() {
            return 0.0;
        }
        self.succeeded() as f64 / self.wall_s
    }

    /// Sum of per-job solve times (serial-equivalent work).
    pub fn serial_time(&self) -> f64 {
        self.outcomes.iter().map(|o| o.wall_s).sum()
    }
}

/// Generic work queue: run `items` on `workers` threads; each worker calls
/// `init` once (per-worker context, e.g. a PJRT registry) and `exec` per
/// item. Results are returned in submission order. The scheduling invariant
/// tests in this module run against this function with cheap executors.
pub fn run_queue<T, C, R, I, E>(items: Vec<T>, workers: usize, init: I, exec: E) -> Vec<R>
where
    T: Send,
    R: Send,
    I: Fn(usize) -> C + Sync,
    E: Fn(&mut C, T) -> R + Sync,
{
    let total = items.len();
    let queue: Arc<Mutex<VecDeque<(usize, T)>>> =
        Arc::new(Mutex::new(items.into_iter().enumerate().collect()));
    let results: Arc<Mutex<Vec<(usize, R)>>> = Arc::new(Mutex::new(Vec::with_capacity(total)));
    thread::scope(|scope| {
        for w in 0..workers.max(1) {
            let queue = queue.clone();
            let results = results.clone();
            let init = &init;
            let exec = &exec;
            scope.spawn(move || {
                let mut ctx = init(w);
                loop {
                    let (idx, item) = {
                        let mut q = queue.lock().unwrap();
                        match q.pop_front() {
                            Some(x) => x,
                            None => break,
                        }
                    };
                    let r = exec(&mut ctx, item);
                    results.lock().unwrap().push((idx, r));
                }
            });
        }
    });
    let mut out = Arc::try_unwrap(results).ok().unwrap().into_inner().unwrap();
    out.sort_by_key(|(i, _)| *i);
    out.into_iter().map(|(_, r)| r).collect()
}

/// The batch service: submit jobs, run them on N workers, collect reports.
pub struct BatchService {
    pub artifacts_dir: PathBuf,
    pub workers: usize,
}

impl BatchService {
    pub fn new(artifacts_dir: PathBuf, workers: usize) -> Self {
        BatchService { artifacts_dir, workers: workers.max(1) }
    }

    /// Service rooted at the default artifacts location.
    pub fn new_default(workers: usize) -> Self {
        Self::new(crate::runtime::manifest::default_dir(), workers)
    }

    /// Run all jobs to completion; returns outcomes in submission order.
    ///
    /// Implementation: a drain-mode serve scheduler — submit everything at
    /// batch priority, spawn one PJRT worker per thread, exit when the
    /// queue is dry. Same-priority jobs dispatch FIFO, preserving the old
    /// queue-drain semantics.
    pub fn run(&self, jobs: Vec<Job>) -> Result<BatchReport> {
        let t0 = Instant::now();
        let sched = Scheduler::new(jobs.len().max(1), self.workers);
        let mut submitted = Vec::with_capacity(jobs.len());
        for job in jobs {
            let dataset = job.problem.name.clone();
            let sid = sched.submit(
                Priority::Batch,
                JobPayload::Problem { problem: job.problem, params: job.params },
            )?;
            submitted.push((sid, job.id, dataset));
        }
        // Drain mode before workers start: they exit once the queue is dry.
        sched.shutdown(true);
        thread::scope(|scope| {
            for w in 0..self.workers {
                let sched = sched.clone();
                let dir = self.artifacts_dir.clone();
                scope.spawn(move || match PjrtExecutor::open(&dir) {
                    Ok(mut exec) => worker_loop(&sched, w, &mut exec),
                    Err(e) => {
                        // A worker that cannot open the registry fails its
                        // jobs cleanly instead of poisoning the pool.
                        let mut failing =
                            FailingExecutor { msg: format!("registry open failed: {e}") };
                        worker_loop(&sched, w, &mut failing);
                    }
                });
            }
        });
        let outcomes = submitted
            .into_iter()
            .map(|(sid, id, dataset)| {
                let view = sched.status(sid).expect("submitted job has a record");
                let status = match view.state {
                    ServeState::Done => JobStatus::Done,
                    _ => JobStatus::Failed,
                };
                JobOutcome {
                    id,
                    dataset,
                    status,
                    report: sched.full_report(sid),
                    error: view.error,
                    wall_s: view.wall_s.unwrap_or(0.0),
                }
            })
            .collect();
        Ok(BatchReport { outcomes, wall_s: t0.elapsed().as_secs_f64(), workers: self.workers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::runtime::OpRegistry;
    use crate::util::prop::{self, Config};
    use crate::util::sync::atomic::{AtomicUsize, Ordering};

    fn outcome(id: usize, status: JobStatus) -> JobOutcome {
        JobOutcome { id, dataset: format!("d{id}"), status, report: None, error: None, wall_s: 0.1 }
    }

    #[test]
    fn batch_report_counts_succeeded_and_failed() {
        let rep = BatchReport {
            outcomes: vec![
                outcome(0, JobStatus::Done),
                outcome(1, JobStatus::Failed),
                outcome(2, JobStatus::Done),
                outcome(3, JobStatus::Done),
            ],
            wall_s: 2.0,
            workers: 2,
        };
        assert_eq!(rep.succeeded(), 3);
        assert_eq!(rep.failed(), 1);
        assert!((rep.throughput() - 1.5).abs() < 1e-12);
        assert!((rep.serial_time() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn degenerate_wall_clock_reports_zero_rate_not_inf() {
        let mut rep = BatchReport {
            outcomes: vec![outcome(0, JobStatus::Done)],
            wall_s: 0.0,
            workers: 1,
        };
        assert_eq!(rep.rps(), 0.0);
        assert_eq!(rep.throughput(), 0.0);
        rep.wall_s = -1.0;
        assert_eq!(rep.rps(), 0.0);
        rep.wall_s = f64::NAN;
        assert_eq!(rep.rps(), 0.0);
        rep.wall_s = f64::INFINITY;
        assert_eq!(rep.rps(), 0.0);
        rep.wall_s = 0.5;
        assert!((rep.rps() - 2.0).abs() < 1e-12, "sane clocks still divide");
    }

    /// Problems that need no artifacts (the worker will fail them, which is
    /// the point: lifecycle must be correct even when execution is not).
    fn artifact_free_jobs(count: usize) -> Vec<Job> {
        let (atlas, _) = synth::brain_atlas(8);
        (0..count)
            .map(|i| Job {
                id: i,
                problem: RegProblem::new(format!("j{i}"), atlas.clone(), atlas.clone()),
                params: RegParams::default(),
            })
            .collect()
    }

    #[test]
    fn bad_registry_fails_jobs_cleanly_in_submission_order() {
        // Nonexistent artifacts dir: every worker degrades to the failing
        // executor; all jobs drain, each marked Failed, none lost, pool
        // not poisoned, outcomes in submission order.
        let svc = BatchService::new(PathBuf::from("/nonexistent/claire-artifacts"), 3);
        let rep = svc.run(artifact_free_jobs(7)).unwrap();
        assert_eq!(rep.outcomes.len(), 7);
        assert_eq!(rep.failed(), 7);
        assert_eq!(rep.succeeded(), 0);
        let ids: Vec<usize> = rep.outcomes.iter().map(|o| o.id).collect();
        assert_eq!(ids, (0..7).collect::<Vec<_>>());
        for o in &rep.outcomes {
            assert!(o.error.as_deref().unwrap().contains("registry open failed"), "{o:?}");
        }
    }

    // Dispatch drain order for same-priority jobs is covered at the engine
    // level by serve::scheduler::tests::fifo_within_priority_band; here we
    // pin the API contract that outcomes come back in submission order
    // (bad_registry_fails_jobs_cleanly_in_submission_order, above) and that
    // a mixed batch reports per-job status (failed_job_is_reported_not_fatal,
    // below, artifact-gated).

    #[test]
    fn prop_queue_runs_each_item_exactly_once_in_order() {
        prop::check_msg(
            Config { cases: 40, seed: 60 },
            |r| {
                let items = r.below(64) as usize;
                let workers = 1 + r.below(8) as usize;
                (items, workers)
            },
            |&(items, workers)| {
                let counter = AtomicUsize::new(0);
                let out = run_queue(
                    (0..items).collect::<Vec<_>>(),
                    workers,
                    |_| (),
                    |_, i| {
                        // Relaxed per the counter policy in util/sync.rs;
                        // the scope join supplies the happens-before edge.
                        counter.fetch_add(1, Ordering::Relaxed);
                        i * 2
                    },
                );
                if counter.load(Ordering::Relaxed) != items {
                    return Err(format!("executed {} of {items}", counter.load(Ordering::Relaxed)));
                }
                if out != (0..items).map(|i| i * 2).collect::<Vec<_>>() {
                    return Err("results out of order".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_queue_worker_contexts_are_isolated() {
        // Each worker gets its own context; total per-context work sums to
        // the item count (no item shared, none dropped).
        prop::check_msg(
            Config { cases: 20, seed: 61 },
            |r| (1 + r.below(50) as usize, 1 + r.below(6) as usize),
            |&(items, workers)| {
                let out = run_queue(
                    vec![1usize; items],
                    workers,
                    |w| (w, 0usize),
                    |ctx, x| {
                        ctx.1 += x;
                        (ctx.0, ctx.1)
                    },
                );
                // Reconstruct per-worker totals from the last observation
                // of each worker id.
                let mut per_worker = std::collections::BTreeMap::new();
                for (w, running) in out {
                    let e = per_worker.entry(w).or_insert(0);
                    *e = (*e).max(running);
                }
                let total: usize = per_worker.values().sum();
                if total != items {
                    return Err(format!("work total {total} != {items}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_queue_panics_do_not_deadlock_other_items() {
        // A slow worker must not starve the queue: all items complete even
        // with workers >> items and items >> workers.
        let out = run_queue((0..100).collect::<Vec<i32>>(), 16, |_| (), |_, i| i);
        assert_eq!(out.len(), 100);
        let out = run_queue(vec![7i32; 3], 64, |_| (), |_, i| i);
        assert_eq!(out, vec![7, 7, 7]);
    }

    fn registry() -> Option<OpRegistry> {
        OpRegistry::open_default().ok()
    }

    fn tiny_job(reg: &OpRegistry, id: usize, subject: &str) -> Job {
        let problem = synth::nirep_analog_pair(reg, 16, subject).unwrap();
        let params = RegParams {
            continuation: false,
            max_iter: 3,
            gtol: 1e-1,
            ..Default::default()
        };
        Job { id, problem, params }
    }

    #[test]
    fn batch_runs_all_jobs_once() {
        let Some(reg) = registry() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let jobs = vec![
            tiny_job(&reg, 0, "na02"),
            tiny_job(&reg, 1, "na03"),
            tiny_job(&reg, 2, "na10"),
        ];
        let svc = BatchService::new_default(2);
        let rep = svc.run(jobs).unwrap();
        assert_eq!(rep.outcomes.len(), 3);
        assert_eq!(rep.succeeded(), 3, "failures: {:?}", rep.outcomes);
        // Outcomes are id-ordered and unique.
        let ids: Vec<usize> = rep.outcomes.iter().map(|o| o.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        assert!(rep.throughput() > 0.0);
    }

    #[test]
    fn failed_job_is_reported_not_fatal() {
        let Some(reg) = registry() else {
            return;
        };
        // n = 24 has no artifacts: the job must fail cleanly.
        let (atlas, _) = synth::brain_atlas(24);
        let bad = Job {
            id: 0,
            problem: crate::registration::problem::RegProblem::new(
                "bad",
                atlas.clone(),
                atlas,
            ),
            params: RegParams::default(),
        };
        let good = tiny_job(&reg, 1, "na02");
        let svc = BatchService::new_default(2);
        let rep = svc.run(vec![bad, good]).unwrap();
        assert_eq!(rep.failed(), 1);
        assert_eq!(rep.succeeded(), 1);
        assert!(rep.outcomes[0].error.is_some());
    }
}
