//! Thread-pool batch registration service.
//!
//! std-only (no tokio offline): a work queue over `Mutex<VecDeque>`, N
//! worker threads, and a collector for per-job outcomes. The `xla` crate's
//! PJRT handles are deliberately `!Send` (they wrap `Rc` + raw pointers),
//! so each worker owns its *own* PJRT client and operator cache — the
//! paper's setting exactly: "multiple registration tasks can take place in
//! an embarrassingly parallel way", one device context per task.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::error::Result;
use crate::registration::problem::{RegParams, RegProblem};
use crate::registration::report::RunReport;
use crate::registration::solver::GnSolver;
use crate::runtime::OpRegistry;

use std::path::PathBuf;

/// One queued registration job.
#[derive(Clone, Debug)]
pub struct Job {
    pub id: usize,
    pub problem: RegProblem,
    pub params: RegParams,
}

/// Job lifecycle state (observable while the batch runs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    Queued,
    Running,
    Done,
    Failed,
}

/// Outcome of one job.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub id: usize,
    pub dataset: String,
    pub status: JobStatus,
    pub report: Option<RunReport>,
    pub error: Option<String>,
    pub wall_s: f64,
}

/// Aggregate statistics for a completed batch.
#[derive(Clone, Debug)]
pub struct BatchReport {
    pub outcomes: Vec<JobOutcome>,
    pub wall_s: f64,
    pub workers: usize,
}

impl BatchReport {
    pub fn succeeded(&self) -> usize {
        self.outcomes.iter().filter(|o| o.status == JobStatus::Done).count()
    }

    pub fn failed(&self) -> usize {
        self.outcomes.iter().filter(|o| o.status == JobStatus::Failed).count()
    }

    /// Registrations per second over the batch (the clinical-throughput
    /// number the paper's motivation is about).
    pub fn throughput(&self) -> f64 {
        self.succeeded() as f64 / self.wall_s.max(1e-12)
    }

    /// Sum of per-job solve times (serial-equivalent work).
    pub fn serial_time(&self) -> f64 {
        self.outcomes.iter().map(|o| o.wall_s).sum()
    }
}

/// Generic work queue: run `items` on `workers` threads; each worker calls
/// `init` once (per-worker context, e.g. a PJRT registry) and `exec` per
/// item. Results are returned in submission order. The scheduling invariant
/// tests in this module run against this function with cheap executors.
pub fn run_queue<T, C, R, I, E>(items: Vec<T>, workers: usize, init: I, exec: E) -> Vec<R>
where
    T: Send,
    R: Send,
    I: Fn(usize) -> C + Sync,
    E: Fn(&mut C, T) -> R + Sync,
{
    let total = items.len();
    let queue: Arc<Mutex<VecDeque<(usize, T)>>> =
        Arc::new(Mutex::new(items.into_iter().enumerate().collect()));
    let results: Arc<Mutex<Vec<(usize, R)>>> = Arc::new(Mutex::new(Vec::with_capacity(total)));
    std::thread::scope(|scope| {
        for w in 0..workers.max(1) {
            let queue = queue.clone();
            let results = results.clone();
            let init = &init;
            let exec = &exec;
            scope.spawn(move || {
                let mut ctx = init(w);
                loop {
                    let (idx, item) = {
                        let mut q = queue.lock().unwrap();
                        match q.pop_front() {
                            Some(x) => x,
                            None => break,
                        }
                    };
                    let r = exec(&mut ctx, item);
                    results.lock().unwrap().push((idx, r));
                }
            });
        }
    });
    let mut out = Arc::try_unwrap(results).ok().unwrap().into_inner().unwrap();
    out.sort_by_key(|(i, _)| *i);
    out.into_iter().map(|(_, r)| r).collect()
}

/// The batch service: submit jobs, run them on N workers, collect reports.
pub struct BatchService {
    pub artifacts_dir: PathBuf,
    pub workers: usize,
}

impl BatchService {
    pub fn new(artifacts_dir: PathBuf, workers: usize) -> Self {
        BatchService { artifacts_dir, workers: workers.max(1) }
    }

    /// Service rooted at the default artifacts location.
    pub fn new_default(workers: usize) -> Self {
        Self::new(crate::runtime::manifest::default_dir(), workers)
    }

    /// Run all jobs to completion; returns outcomes in job-id order.
    pub fn run(&self, jobs: Vec<Job>) -> Result<BatchReport> {
        let t0 = Instant::now();
        let dir = self.artifacts_dir.clone();
        let outcomes = run_queue(
            jobs,
            self.workers,
            // Per-worker PJRT client + operator cache (PJRT handles are
            // !Send; compilation cost amortizes over this worker's jobs).
            |_w| OpRegistry::open(&dir),
            |registry, job| {
                let jt0 = Instant::now();
                let registry = match registry {
                    Ok(r) => r,
                    Err(e) => {
                        return JobOutcome {
                            id: job.id,
                            dataset: job.problem.name.clone(),
                            status: JobStatus::Failed,
                            report: None,
                            error: Some(format!("registry open failed: {e}")),
                            wall_s: 0.0,
                        }
                    }
                };
                let solver = GnSolver::new(registry, job.params.clone());
                match solver
                    .solve(&job.problem)
                    .and_then(|res| RunReport::build(&solver, &job.problem, &res))
                {
                    Ok(report) => JobOutcome {
                        id: job.id,
                        dataset: job.problem.name.clone(),
                        status: JobStatus::Done,
                        report: Some(report),
                        error: None,
                        wall_s: jt0.elapsed().as_secs_f64(),
                    },
                    Err(e) => JobOutcome {
                        id: job.id,
                        dataset: job.problem.name.clone(),
                        status: JobStatus::Failed,
                        report: None,
                        error: Some(e.to_string()),
                        wall_s: jt0.elapsed().as_secs_f64(),
                    },
                }
            },
        );
        Ok(BatchReport { outcomes, wall_s: t0.elapsed().as_secs_f64(), workers: self.workers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::prop::{self, Config};
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn prop_queue_runs_each_item_exactly_once_in_order() {
        prop::check_msg(
            Config { cases: 40, seed: 60 },
            |r| {
                let items = r.below(64) as usize;
                let workers = 1 + r.below(8) as usize;
                (items, workers)
            },
            |&(items, workers)| {
                let counter = AtomicUsize::new(0);
                let out = run_queue(
                    (0..items).collect::<Vec<_>>(),
                    workers,
                    |_| (),
                    |_, i| {
                        counter.fetch_add(1, Ordering::SeqCst);
                        i * 2
                    },
                );
                if counter.load(Ordering::SeqCst) != items {
                    return Err(format!("executed {} of {items}", counter.load(Ordering::SeqCst)));
                }
                if out != (0..items).map(|i| i * 2).collect::<Vec<_>>() {
                    return Err("results out of order".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_queue_worker_contexts_are_isolated() {
        // Each worker gets its own context; total per-context work sums to
        // the item count (no item shared, none dropped).
        prop::check_msg(
            Config { cases: 20, seed: 61 },
            |r| (1 + r.below(50) as usize, 1 + r.below(6) as usize),
            |&(items, workers)| {
                let out = run_queue(
                    vec![1usize; items],
                    workers,
                    |w| (w, 0usize),
                    |ctx, x| {
                        ctx.1 += x;
                        (ctx.0, ctx.1)
                    },
                );
                // Reconstruct per-worker totals from the last observation
                // of each worker id.
                let mut per_worker = std::collections::BTreeMap::new();
                for (w, running) in out {
                    let e = per_worker.entry(w).or_insert(0);
                    *e = (*e).max(running);
                }
                let total: usize = per_worker.values().sum();
                if total != items {
                    return Err(format!("work total {total} != {items}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_queue_panics_do_not_deadlock_other_items() {
        // A slow worker must not starve the queue: all items complete even
        // with workers >> items and items >> workers.
        let out = run_queue((0..100).collect::<Vec<i32>>(), 16, |_| (), |_, i| i);
        assert_eq!(out.len(), 100);
        let out = run_queue(vec![7i32; 3], 64, |_| (), |_, i| i);
        assert_eq!(out, vec![7, 7, 7]);
    }

    fn registry() -> Option<OpRegistry> {
        OpRegistry::open_default().ok()
    }

    fn tiny_job(reg: &OpRegistry, id: usize, subject: &str) -> Job {
        let problem = synth::nirep_analog_pair(reg, 16, subject).unwrap();
        let params = RegParams {
            continuation: false,
            max_iter: 3,
            gtol: 1e-1,
            ..Default::default()
        };
        Job { id, problem, params }
    }

    #[test]
    fn batch_runs_all_jobs_once() {
        let Some(reg) = registry() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let jobs = vec![
            tiny_job(&reg, 0, "na02"),
            tiny_job(&reg, 1, "na03"),
            tiny_job(&reg, 2, "na10"),
        ];
        let svc = BatchService::new_default(2);
        let rep = svc.run(jobs).unwrap();
        assert_eq!(rep.outcomes.len(), 3);
        assert_eq!(rep.succeeded(), 3, "failures: {:?}", rep.outcomes);
        // Outcomes are id-ordered and unique.
        let ids: Vec<usize> = rep.outcomes.iter().map(|o| o.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        assert!(rep.throughput() > 0.0);
    }

    #[test]
    fn failed_job_is_reported_not_fatal() {
        let Some(reg) = registry() else {
            return;
        };
        // n = 24 has no artifacts: the job must fail cleanly.
        let (atlas, _) = synth::brain_atlas(24);
        let bad = Job {
            id: 0,
            problem: crate::registration::problem::RegProblem::new(
                "bad",
                atlas.clone(),
                atlas,
            ),
            params: RegParams::default(),
        };
        let good = tiny_job(&reg, 1, "na02");
        let svc = BatchService::new_default(2);
        let rep = svc.run(vec![bad, good]).unwrap();
        assert_eq!(rep.failed(), 1);
        assert_eq!(rep.succeeded(), 1);
        assert!(rep.outcomes[0].error.is_some());
    }
}
