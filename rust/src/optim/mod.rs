//! Optimization algorithms: the Gauss-Newton-Krylov machinery of Algorithm
//! 2.1 plus the first-order baselines used in the paper's comparisons.

pub mod continuation;
pub mod first_order;
pub mod line_search;
pub mod pcg;

pub use continuation::{default_schedule, Level};
pub use first_order::{
    gradient_descent, gradient_descent_observed, lbfgs, lbfgs_observed, FoIter, FoObserver,
    FoOptions, FoTrace, Oracle,
};
pub use line_search::{armijo, ArmijoOptions, LineSearchResult};
pub use pcg::{PcgOptions, PcgResult, PcgStop};
