//! First-order baseline optimizers (paper Table 8 comparators).
//!
//! The paper compares against PyCA (plain gradient descent on the LDDMM
//! energy) and deformetrica (L-BFGS). Both are reimplemented here over the
//! same objective/gradient artifacts so the Table-8 comparison isolates the
//! *optimization algorithm*, exactly the paper's argument: "time per
//! iteration is not a good measure on its own. We need to compare how much
//! work (runtime) it requires to reach a certain accuracy".

use crate::error::Result;
use crate::field::ops;
use crate::optim::line_search::{armijo, ArmijoOptions};

/// Objective/gradient oracle shared by the first-order methods; implemented
/// by the registration layer over the `newton_setup`/`objective` artifacts.
pub trait Oracle {
    /// Returns (J, gradient).
    fn value_grad(&mut self, v: &[f32]) -> Result<(f64, Vec<f32>)>;
    /// Returns J only (cheaper; used by line searches).
    fn value(&mut self, v: &[f32]) -> Result<f64>;
}

/// Trace of one first-order run.
#[derive(Clone, Debug, Default)]
pub struct FoTrace {
    pub iters: usize,
    pub evals: usize,
    pub j_history: Vec<f64>,
    pub grad_norm: f64,
    /// The observer asked the driver to stop (cooperative cancellation);
    /// the trace holds the work completed up to that boundary.
    pub cancelled: bool,
}

/// One accepted first-order step, delivered to the observer of the
/// `*_observed` drivers. The registration layer folds these into the
/// shared `IterRecord` history (there is no private trace format any
/// more); `grad_rel` is `‖g‖ / ‖g0‖`, the same convergence metric the
/// Gauss-Newton solver records.
#[derive(Clone, Copy, Debug)]
pub struct FoIter {
    /// Accepted-step index (0-based).
    pub iter: usize,
    /// Objective value at the step's starting point.
    pub j: f64,
    pub grad_norm: f64,
    pub grad_rel: f64,
    /// Accepted Armijo step length.
    pub alpha: f64,
}

/// Per-iteration observer: return `false` to stop the driver at this
/// boundary (the trace comes back with `cancelled = true`).
pub type FoObserver<'a> = &'a mut dyn FnMut(&FoIter) -> bool;

/// Options for the first-order drivers.
#[derive(Clone, Copy, Debug)]
pub struct FoOptions {
    pub max_iter: usize,
    /// Stop when ||g|| / ||g0|| drops below this.
    pub gtol_rel: f64,
    /// L-BFGS history length.
    pub history: usize,
}

impl Default for FoOptions {
    fn default() -> Self {
        FoOptions { max_iter: 100, gtol_rel: 5e-2, history: 8 }
    }
}

/// Plain gradient descent with Armijo backtracking (PyCA analog).
pub fn gradient_descent(
    oracle: &mut dyn Oracle,
    v: &mut Vec<f32>,
    opts: FoOptions,
) -> Result<FoTrace> {
    gradient_descent_observed(oracle, v, opts, &mut |_| true)
}

/// `gradient_descent` with a per-step observer (cancellation point at
/// every iteration boundary).
pub fn gradient_descent_observed(
    oracle: &mut dyn Oracle,
    v: &mut Vec<f32>,
    opts: FoOptions,
    observe: FoObserver<'_>,
) -> Result<FoTrace> {
    let mut trace = FoTrace::default();
    let mut g0norm: Option<f64> = None;
    for _ in 0..opts.max_iter {
        let (j, g) = oracle.value_grad(v)?;
        trace.evals += 1;
        trace.j_history.push(j);
        let gn = ops::norm2(&g);
        trace.grad_norm = gn;
        let g0 = *g0norm.get_or_insert(gn);
        if gn <= opts.gtol_rel * g0 {
            break;
        }
        let gdx = -ops::dot(&g, &g);
        let ls = {
            let vref = &*v;
            armijo(j, gdx, ArmijoOptions::expanding(), |alpha| {
                let mut trial = vref.clone();
                ops::axpy(-(alpha as f32), &g, &mut trial);
                oracle.value(&trial)
            })
        }?;
        trace.evals += ls.evals;
        ops::axpy(-(ls.alpha as f32), &g, v);
        trace.iters += 1;
        let fo = FoIter {
            iter: trace.iters - 1,
            j,
            grad_norm: gn,
            grad_rel: gn / g0.max(1e-300),
            alpha: ls.alpha,
        };
        if !observe(&fo) {
            trace.cancelled = true;
            break;
        }
    }
    Ok(trace)
}

/// L-BFGS two-loop recursion (deformetrica analog).
pub fn lbfgs(oracle: &mut dyn Oracle, v: &mut Vec<f32>, opts: FoOptions) -> Result<FoTrace> {
    lbfgs_observed(oracle, v, opts, &mut |_| true)
}

/// `lbfgs` with a per-step observer (cancellation point at every
/// iteration boundary).
pub fn lbfgs_observed(
    oracle: &mut dyn Oracle,
    v: &mut Vec<f32>,
    opts: FoOptions,
    observe: FoObserver<'_>,
) -> Result<FoTrace> {
    let mut trace = FoTrace::default();
    let nn = v.len();
    let mut s_hist: Vec<Vec<f32>> = Vec::new();
    let mut y_hist: Vec<Vec<f32>> = Vec::new();
    let mut rho: Vec<f64> = Vec::new();

    let (mut j, mut g) = oracle.value_grad(v)?;
    trace.evals += 1;
    trace.j_history.push(j);
    let g0norm = ops::norm2(&g).max(1e-300);

    for _ in 0..opts.max_iter {
        let gn = ops::norm2(&g);
        trace.grad_norm = gn;
        if gn <= opts.gtol_rel * g0norm {
            break;
        }
        // Two-loop recursion for d = -H g.
        let mut q = g.clone();
        let k = s_hist.len();
        let mut alphas = vec![0f64; k];
        for i in (0..k).rev() {
            alphas[i] = rho[i] * ops::dot(&s_hist[i], &q);
            ops::axpy(-(alphas[i] as f32), &y_hist[i], &mut q);
        }
        // Initial Hessian scaling gamma = <s,y>/<y,y>.
        if k > 0 {
            let sy = 1.0 / rho[k - 1];
            let yy = ops::dot(&y_hist[k - 1], &y_hist[k - 1]);
            ops::scale((sy / yy.max(1e-300)) as f32, &mut q);
        }
        for i in 0..k {
            let beta = rho[i] * ops::dot(&y_hist[i], &q);
            ops::axpy((alphas[i] - beta) as f32, &s_hist[i], &mut q);
        }
        let mut d = q;
        ops::scale(-1.0, &mut d);
        let mut gdx = ops::dot(&g, &d);
        if gdx >= 0.0 {
            // Restart on loss of curvature information.
            s_hist.clear();
            y_hist.clear();
            rho.clear();
            d = g.iter().map(|x| -x).collect();
            gdx = -ops::dot(&g, &g);
        }
        let ls = {
            let vref = &*v;
            let dref = &d;
            armijo(j, gdx, ArmijoOptions::expanding(), |alpha| {
                let mut trial = vref.clone();
                ops::axpy(alpha as f32, dref, &mut trial);
                oracle.value(&trial)
            })
        }?;
        trace.evals += ls.evals;
        let mut s = vec![0f32; nn];
        for i in 0..nn {
            s[i] = (ls.alpha as f32) * d[i];
            v[i] += s[i];
        }
        let (j_new, g_new) = oracle.value_grad(v)?;
        trace.evals += 1;
        let mut y = vec![0f32; nn];
        for i in 0..nn {
            y[i] = g_new[i] - g[i];
        }
        let sy = ops::dot(&s, &y);
        if sy > 1e-12 {
            if s_hist.len() == opts.history {
                s_hist.remove(0);
                y_hist.remove(0);
                rho.remove(0);
            }
            rho.push(1.0 / sy);
            s_hist.push(s);
            y_hist.push(y);
        }
        // Observe with the step's *starting* values (`j`/`gn` are still
        // pre-update here) — the same contract gradient_descent_observed
        // keeps, so one observer sees comparable streams per algorithm.
        let fo = FoIter {
            iter: trace.iters,
            j,
            grad_norm: gn,
            grad_rel: gn / g0norm,
            alpha: ls.alpha,
        };
        j = j_new;
        g = g_new;
        trace.j_history.push(j);
        trace.iters += 1;
        if !observe(&fo) {
            trace.cancelled = true;
            break;
        }
    }
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Convex quadratic J = 1/2 x^T D x - b.x with diagonal D.
    struct Quad {
        d: Vec<f64>,
        b: Vec<f64>,
    }

    impl Oracle for Quad {
        fn value_grad(&mut self, v: &[f32]) -> Result<(f64, Vec<f32>)> {
            let mut j = 0.0;
            let mut g = vec![0f32; v.len()];
            for i in 0..v.len() {
                let x = v[i] as f64;
                j += 0.5 * self.d[i] * x * x - self.b[i] * x;
                g[i] = (self.d[i] * x - self.b[i]) as f32;
            }
            Ok((j, g))
        }

        fn value(&mut self, v: &[f32]) -> Result<f64> {
            Ok(self.value_grad(v)?.0)
        }
    }

    fn quad() -> Quad {
        // Mildly ill-conditioned (cond ~ 18): GD converges within the
        // budget but needs visibly more iterations than L-BFGS.
        Quad { d: vec![1.0, 4.0, 9.0, 0.5, 2.0], b: vec![1.0, -2.0, 3.0, 0.5, -1.0] }
    }

    #[test]
    fn gd_converges_on_quadratic() {
        let mut q = quad();
        let mut v = vec![0f32; 5];
        // gtol 1e-5: the f32 gradient evaluation floors around 1e-7.
        let tr = gradient_descent(&mut q, &mut v, FoOptions { max_iter: 500, gtol_rel: 1e-5, history: 0 })
            .unwrap();
        for i in 0..5 {
            let want = q.b[i] / q.d[i];
            assert!((v[i] as f64 - want).abs() < 1e-3, "x[{i}]={} want {want}", v[i]);
        }
        assert!(tr.iters > 1 && tr.iters < 500);
    }

    #[test]
    fn lbfgs_converges_in_few_iterations_on_quadratic() {
        // On an n-dimensional quadratic, L-BFGS with full history converges
        // in O(n) iterations; this is the sharp correctness check.
        let mut q = quad();
        let mut v = vec![0f32; 5];
        let tr = lbfgs(&mut q, &mut v, FoOptions { max_iter: 100, gtol_rel: 1e-5, history: 8 })
            .unwrap();
        assert!(tr.iters <= 20, "lbfgs took {} iterations", tr.iters);
        for i in 0..5 {
            let want = q.b[i] / q.d[i];
            assert!((v[i] as f64 - want).abs() < 1e-3, "x[{i}]={} want {want}", v[i]);
        }
    }

    #[test]
    fn lbfgs_converges_faster_than_gd() {
        let opts = FoOptions { max_iter: 500, gtol_rel: 1e-5, history: 8 };
        let mut v1 = vec![0f32; 5];
        let t_gd = gradient_descent(
            &mut quad(),
            &mut v1,
            FoOptions { history: 0, ..opts },
        )
        .unwrap();
        let mut v2 = vec![0f32; 5];
        let t_lb = lbfgs(&mut quad(), &mut v2, opts).unwrap();
        assert!(t_lb.iters < t_gd.iters, "lbfgs {} vs gd {}", t_lb.iters, t_gd.iters);
    }

    #[test]
    fn observer_sees_steps_and_cancels_at_boundaries() {
        // Observer receives one event per accepted step with a sane
        // grad_rel sequence...
        let mut q = quad();
        let mut v = vec![0f32; 5];
        let mut seen: Vec<FoIter> = Vec::new();
        let tr = gradient_descent_observed(
            &mut q,
            &mut v,
            FoOptions { max_iter: 50, gtol_rel: 1e-5, history: 0 },
            &mut |it| {
                seen.push(*it);
                true
            },
        )
        .unwrap();
        assert!(!tr.cancelled);
        assert_eq!(seen.len(), tr.iters);
        assert_eq!(seen[0].iter, 0);
        assert!((seen[0].grad_rel - 1.0).abs() < 1e-12, "first step is at g0");
        assert!(seen.last().unwrap().grad_rel < 1.0);
        // ... and returning false stops the driver at that boundary with
        // the partial trace flagged cancelled.
        let opts = FoOptions { max_iter: 50, gtol_rel: 1e-9, history: 4 };
        let mut calls = 0usize;
        let mut stop_at_3 = |_: &FoIter| {
            calls += 1;
            calls < 3
        };
        let mut v = vec![0f32; 5];
        let tr = gradient_descent_observed(&mut quad(), &mut v, opts, &mut stop_at_3).unwrap();
        assert!(tr.cancelled);
        assert_eq!(tr.iters, 3, "gd stopped at the third boundary");
        let mut calls = 0usize;
        let mut v = vec![0f32; 5];
        let tr = lbfgs_observed(&mut quad(), &mut v, opts, &mut |_| {
            calls += 1;
            calls < 3
        })
        .unwrap();
        assert!(tr.cancelled);
        assert_eq!(tr.iters, 3, "lbfgs stopped at the third boundary");
    }

    #[test]
    fn monotone_decrease() {
        let mut q = quad();
        let mut v = vec![1f32; 5];
        let tr = lbfgs(&mut q, &mut v, FoOptions { max_iter: 50, gtol_rel: 1e-10, history: 4 })
            .unwrap();
        for w in tr.j_history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "J increased: {w:?}");
        }
    }

    #[test]
    fn rosenbrock_lbfgs() {
        // Non-quadratic sanity: 2-D Rosenbrock reaches the basin.
        struct Rosen;
        impl Oracle for Rosen {
            fn value_grad(&mut self, v: &[f32]) -> Result<(f64, Vec<f32>)> {
                let (x, y) = (v[0] as f64, v[1] as f64);
                let j = (1.0 - x).powi(2) + 100.0 * (y - x * x).powi(2);
                let gx = -2.0 * (1.0 - x) - 400.0 * x * (y - x * x);
                let gy = 200.0 * (y - x * x);
                Ok((j, vec![gx as f32, gy as f32]))
            }
            fn value(&mut self, v: &[f32]) -> Result<f64> {
                Ok(self.value_grad(v)?.0)
            }
        }
        let mut v = vec![-1.2f32, 1.0];
        let tr = lbfgs(&mut Rosen, &mut v, FoOptions { max_iter: 600, gtol_rel: 1e-9, history: 10 })
            .unwrap();
        // Armijo-only line search over f32 iterates: expect solid progress
        // into the valley (J0 = 24.2), not machine-precision optimality.
        let j_final = *tr.j_history.last().unwrap();
        assert!(j_final < 0.5, "J={j_final}, x={v:?}");
        assert!(j_final < 24.2 * 1e-2, "insufficient decrease: J={j_final}");
    }
}
