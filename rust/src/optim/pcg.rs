//! Preconditioned conjugate gradients on the Gauss-Newton Hessian.
//!
//! The paper (section 2.2.3) inverts the Hessian iteratively with PCG at
//! every Gauss-Newton step; this accounts for >90% of CLAIRE's runtime.
//! The operator is matrix-free: `matvec` executes the `hess_matvec` HLO
//! artifact; `precond` the spectral inverse of the regularization operator.
//! Vector algebra runs host-side through `field::ops` (f64 accumulation).

use crate::error::Result;
use crate::field::ops;
use crate::precision::Precision;

/// Why PCG stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PcgStop {
    /// Residual reduced below the forcing tolerance.
    Converged,
    /// Hit the iteration cap.
    MaxIter,
    /// Encountered a direction of non-positive curvature (kept the iterate
    /// accumulated so far; standard inexact-Newton practice).
    NegativeCurvature,
}

/// Outcome of one PCG solve.
#[derive(Clone, Debug)]
pub struct PcgResult {
    pub x: Vec<f32>,
    pub iters: usize,
    pub stop: PcgStop,
    /// Final residual norm relative to the initial one.
    pub rel_residual: f64,
    /// Precision the Hessian matvec ran at (echoed from the options into
    /// the solve record; the PCG vector algebra itself is always f32
    /// host-side with f64 accumulation).
    pub matvec_precision: Precision,
}

/// Solver options. `rtol` is the Eisenstat-Walker style forcing term chosen
/// by the Newton loop (superlinear: min(0.5, sqrt(||g||rel))).
/// `matvec_precision` labels the precision of the supplied `matvec`
/// operator — the Krylov loop itself is precision-agnostic, but the record
/// of what precision produced an iterate travels with the result.
#[derive(Clone, Copy, Debug)]
pub struct PcgOptions {
    pub rtol: f64,
    pub max_iter: usize,
    pub matvec_precision: Precision,
}

impl Default for PcgOptions {
    fn default() -> Self {
        // paper: PCG cap 500
        PcgOptions { rtol: 1e-1, max_iter: 500, matvec_precision: Precision::Full }
    }
}

/// Solve `H x = b` with preconditioned CG.
///
/// `matvec(p)` must return `H p`; `precond(r)` must return `M^{-1} r` with
/// symmetric positive definite `M`.
pub fn solve<Mv, Pc>(b: &[f32], opts: PcgOptions, mut matvec: Mv, mut precond: Pc) -> Result<PcgResult>
where
    Mv: FnMut(&[f32]) -> Result<Vec<f32>>,
    Pc: FnMut(&[f32]) -> Result<Vec<f32>>,
{
    let nn = b.len();
    let mut x = vec![0f32; nn];
    let mut r = b.to_vec();
    let r0 = ops::norm2(&r).max(1e-300);
    let mut z = precond(&r)?;
    let mut p = z.clone();
    let mut rz = ops::dot(&r, &z);
    let mut rr = r0 * r0;

    for it in 0..opts.max_iter {
        let hp = matvec(&p)?;
        let php = ops::dot(&p, &hp);
        if php <= 0.0 {
            // Non-positive curvature: fall back to the preconditioned
            // gradient if we have made no progress yet.
            if it == 0 {
                x.copy_from_slice(&z);
            }
            return Ok(PcgResult {
                x,
                iters: it,
                stop: PcgStop::NegativeCurvature,
                rel_residual: rr.sqrt() / r0,
                matvec_precision: opts.matvec_precision,
            });
        }
        let alpha = (rz / php) as f32;
        ops::axpy(alpha, &p, &mut x);
        rr = ops::axpy_dot_self(-alpha, &hp, &mut r);
        if rr.sqrt() <= opts.rtol * r0 {
            return Ok(PcgResult {
                x,
                iters: it + 1,
                stop: PcgStop::Converged,
                rel_residual: rr.sqrt() / r0,
                matvec_precision: opts.matvec_precision,
            });
        }
        z = precond(&r)?;
        let rz_new = ops::dot(&r, &z);
        let beta = (rz_new / rz) as f32;
        rz = rz_new;
        ops::xpay(&z, beta, &mut p);
    }
    Ok(PcgResult {
        x,
        iters: opts.max_iter,
        stop: PcgStop::MaxIter,
        rel_residual: rr.sqrt() / r0,
        matvec_precision: opts.matvec_precision,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Dense SPD test operator A = Q diag(d) Q^T realized as matvec.
    #[derive(Debug)]
    struct Spd {
        n: usize,
        a: Vec<f64>, // row-major n x n
    }

    impl Spd {
        fn random(r: &mut Rng, n: usize, cond: f64) -> Spd {
            // A = B^T B + shift I, eigenvalues in ~[shift, ||B||^2].
            let b: Vec<f64> = (0..n * n).map(|_| r.normal()).collect();
            let mut a = vec![0f64; n * n];
            for i in 0..n {
                for j in 0..n {
                    let mut acc = 0.0;
                    for k in 0..n {
                        acc += b[k * n + i] * b[k * n + j];
                    }
                    a[i * n + j] = acc + if i == j { cond } else { 0.0 };
                }
            }
            Spd { n, a }
        }

        fn matvec(&self, x: &[f32]) -> Vec<f32> {
            let mut y = vec![0f32; self.n];
            for i in 0..self.n {
                let mut acc = 0.0f64;
                for j in 0..self.n {
                    acc += self.a[i * self.n + j] * x[j] as f64;
                }
                y[i] = acc as f32;
            }
            y
        }

        fn residual(&self, x: &[f32], b: &[f32]) -> f64 {
            let ax = self.matvec(x);
            let mut num = 0.0;
            let mut den = 0.0;
            for i in 0..self.n {
                num += ((ax[i] - b[i]) as f64).powi(2);
                den += (b[i] as f64).powi(2);
            }
            (num / den.max(1e-300)).sqrt()
        }
    }

    #[test]
    fn solves_spd_systems() {
        prop::check_msg(
            prop::Config { cases: 24, seed: 40 },
            |r| {
                let n = 4 + r.below(29) as usize;
                let a = Spd::random(r, n, 0.5);
                let b = prop::vec_f32(r, n, -1.0, 1.0);
                (a, b)
            },
            |(a, b)| {
                let res = solve(
                    b,
                    PcgOptions { rtol: 1e-8, max_iter: 500, ..Default::default() },
                    |p| Ok(a.matvec(p)),
                    |r| Ok(r.to_vec()),
                )
                .unwrap();
                if res.stop != PcgStop::Converged {
                    return Err(format!("did not converge: {:?}", res.stop));
                }
                let rel = a.residual(&res.x, b);
                if rel > 1e-3 {
                    return Err(format!("residual {rel}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn preconditioner_reduces_iterations() {
        let mut r = Rng::new(41);
        let n = 48;
        // Ill-conditioned diagonal + noise.
        let mut a = Spd::random(&mut r, n, 1e-3);
        for i in 0..n {
            a.a[i * n + i] += (i as f64 + 1.0) * 10.0;
        }
        let b = prop::vec_f32(&mut r, n, -1.0, 1.0);
        let opts = PcgOptions { rtol: 1e-6, max_iter: 500, ..Default::default() };
        let plain = solve(&b, opts, |p| Ok(a.matvec(p)), |r| Ok(r.to_vec())).unwrap();
        // Jacobi preconditioner.
        let diag: Vec<f64> = (0..n).map(|i| a.a[i * n + i]).collect();
        let pc = solve(
            &b,
            opts,
            |p| Ok(a.matvec(p)),
            |r| Ok(r.iter().enumerate().map(|(i, &x)| (x as f64 / diag[i]) as f32).collect()),
        )
        .unwrap();
        assert!(pc.iters < plain.iters, "pc {} vs plain {}", pc.iters, plain.iters);
    }

    #[test]
    fn identity_converges_in_one_iter() {
        let b = vec![1.0f32, -2.0, 3.0];
        let res = solve(
            &b,
            PcgOptions { rtol: 1e-10, max_iter: 10, ..Default::default() },
            |p| Ok(p.to_vec()),
            |r| Ok(r.to_vec()),
        )
        .unwrap();
        assert_eq!(res.iters, 1);
        assert_eq!(res.x, b);
    }

    #[test]
    fn negative_curvature_detected() {
        // H = -I: first matvec reveals negative curvature; x falls back to
        // the preconditioned gradient.
        let b = vec![1.0f32, 1.0];
        let res = solve(
            &b,
            PcgOptions::default(),
            |p| Ok(p.iter().map(|x| -x).collect()),
            |r| Ok(r.to_vec()),
        )
        .unwrap();
        assert_eq!(res.stop, PcgStop::NegativeCurvature);
        assert_eq!(res.x, b);
    }

    #[test]
    fn reduced_precision_matvec_still_converges() {
        // Emulate the mixed policy: the matvec output passes through f16
        // storage (kernels_ref-style emulation) while PCG's own algebra
        // stays f32/f64. A well-conditioned system still converges to a
        // residual consistent with f16 resolution, and the result records
        // which precision produced it.
        let mut r = Rng::new(44);
        let n = 32usize;
        // Well-conditioned diagonal operator, d in [1, 2] (kappa <= 2).
        let d: Vec<f32> = (0..n).map(|i| 1.0 + i as f32 / (n as f32 - 1.0)).collect();
        let b = prop::vec_f32(&mut r, n, -1.0, 1.0);
        let res = solve(
            &b,
            PcgOptions { rtol: 1e-2, max_iter: 100, matvec_precision: Precision::Mixed },
            |p| {
                Ok(p.iter()
                    .zip(&d)
                    .map(|(&x, &dd)| crate::math::half::f16_round(dd * x))
                    .collect())
            },
            |r| Ok(r.to_vec()),
        )
        .unwrap();
        assert_eq!(res.matvec_precision, Precision::Mixed);
        assert_eq!(res.stop, PcgStop::Converged);
        // Check against the *exact* operator: the f16 matvec noise must not
        // push the true residual far past the forcing tolerance.
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for i in 0..n {
            num += ((d[i] * res.x[i] - b[i]) as f64).powi(2);
            den += (b[i] as f64).powi(2);
        }
        let rel = (num / den).sqrt();
        assert!(rel < 3e-2, "reduced-precision residual {rel}");
    }

    #[test]
    fn respects_max_iter() {
        let mut r = Rng::new(43);
        let a = Spd::random(&mut r, 32, 1e-6);
        let b = prop::vec_f32(&mut r, 32, -1.0, 1.0);
        let res = solve(
            &b,
            PcgOptions { rtol: 1e-14, max_iter: 3, ..Default::default() },
            |p| Ok(a.matvec(p)),
            |r| Ok(r.to_vec()),
        )
        .unwrap();
        assert!(res.iters <= 3);
    }
}
