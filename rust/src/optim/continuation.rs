//! Regularization-parameter continuation (paper section 4.1.2; detailed in
//! Mang & Biros, SIIMS 2015).
//!
//! CLAIRE does not solve directly at the small target beta: it starts from
//! a strongly regularized problem and reduces beta geometrically, warm-
//! starting each level from the previous solution. Intermediate levels run
//! to a loose gradient tolerance; only the final (target) level uses the
//! paper's convergence criteria.

/// One continuation level.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Level {
    pub beta: f64,
    /// Relative gradient tolerance for this level.
    pub gtol_rel: f64,
    /// Gauss-Newton iteration cap for this level.
    pub max_iter: usize,
}

/// Build the beta schedule from `beta_init` down to `beta_target` dividing
/// by `step` per level. The final level carries the target tolerances.
pub fn schedule(
    beta_init: f64,
    beta_target: f64,
    step: f64,
    level_gtol: f64,
    level_max_iter: usize,
    final_gtol: f64,
    final_max_iter: usize,
) -> Vec<Level> {
    assert!(beta_target > 0.0 && step > 1.0);
    let mut levels = Vec::new();
    let mut beta = beta_init;
    while beta > beta_target * (1.0 + 1e-12) {
        levels.push(Level { beta, gtol_rel: level_gtol, max_iter: level_max_iter });
        beta /= step;
    }
    levels.push(Level { beta: beta_target, gtol_rel: final_gtol, max_iter: final_max_iter });
    levels
}

/// The default CLAIRE-style schedule for a target beta (paper: target
/// beta = 5e-4 with continuation; gradient tolerance 5e-2; <= 50 GN iters).
pub fn default_schedule(beta_target: f64) -> Vec<Level> {
    schedule(1e-1, beta_target, 10.0, 2.5e-1, 5, 5e-2, 50)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_reaches_target() {
        let levels = default_schedule(5e-4);
        assert_eq!(levels.last().unwrap().beta, 5e-4);
        assert_eq!(levels.last().unwrap().gtol_rel, 5e-2);
        assert_eq!(levels.last().unwrap().max_iter, 50);
        // 1e-1, 1e-2, 1e-3, then 5e-4
        assert_eq!(levels.len(), 4);
        for w in levels.windows(2) {
            assert!(w[1].beta < w[0].beta);
        }
    }

    #[test]
    fn target_above_init_is_single_level() {
        let levels = schedule(1e-1, 0.5, 10.0, 0.25, 5, 5e-2, 50);
        assert_eq!(levels.len(), 1);
        assert_eq!(levels[0].beta, 0.5);
    }

    #[test]
    fn exact_decade_has_no_duplicate_target() {
        let levels = schedule(1e-1, 1e-3, 10.0, 0.25, 5, 5e-2, 50);
        let betas: Vec<f64> = levels.iter().map(|l| l.beta).collect();
        // 1e-1, 1e-2 as intermediates, then the 1e-3 target exactly once.
        assert_eq!(betas.len(), 3);
        assert!((betas[2] - 1e-3).abs() < 1e-15);
    }
}
