//! Armijo backtracking line search (paper: Gauss-Newton globalized with an
//! Armijo line search; Nocedal & Wright section 3.1).

use crate::error::{Error, Result};

/// Line search options.
#[derive(Clone, Copy, Debug)]
pub struct ArmijoOptions {
    /// Sufficient-decrease constant c1.
    pub c1: f64,
    /// Backtracking factor.
    pub shrink: f64,
    /// Maximum trial steps.
    pub max_trials: usize,
    /// Upper bound for forward expansion. With the default 1.0 the search
    /// is pure backtracking from alpha = 1 (Newton-style). First-order
    /// methods whose directions are not naturally unit-scaled (L-BFGS with
    /// stale curvature, plain GD) set this larger: when alpha = 1 is
    /// accepted immediately, the step doubles while the sufficient-decrease
    /// condition keeps improving.
    pub max_alpha: f64,
}

impl Default for ArmijoOptions {
    fn default() -> Self {
        ArmijoOptions { c1: 1e-4, shrink: 0.5, max_trials: 24, max_alpha: 1.0 }
    }
}

impl ArmijoOptions {
    /// Variant with forward expansion enabled (first-order baselines).
    pub fn expanding() -> Self {
        ArmijoOptions { max_alpha: 1024.0, ..Default::default() }
    }
}

/// Outcome of a line search.
#[derive(Clone, Copy, Debug)]
pub struct LineSearchResult {
    pub alpha: f64,
    pub j_new: f64,
    pub evals: usize,
}

/// Backtrack from alpha=1 until `J(v + alpha dv) <= J + c1 alpha <g, dv>`.
///
/// `eval(alpha)` returns the objective at the trial point (one artifact
/// call per trial). `gdx` must be the directional derivative `<g, dv>`
/// (negative for a descent direction).
pub fn armijo<F>(j0: f64, gdx: f64, opts: ArmijoOptions, mut eval: F) -> Result<LineSearchResult>
where
    F: FnMut(f64) -> Result<f64>,
{
    if gdx >= 0.0 {
        return Err(Error::Solver(format!(
            "line search requires a descent direction (<g,dv> = {gdx:.3e} >= 0)"
        )));
    }
    let mut alpha = 1.0f64;
    for trial in 0..opts.max_trials {
        let j = eval(alpha)?;
        if j.is_finite() && j <= j0 + opts.c1 * alpha * gdx {
            let mut best = LineSearchResult { alpha, j_new: j, evals: trial + 1 };
            if trial == 0 {
                // Forward expansion: keep doubling while the Armijo bound
                // holds at the larger step AND the value keeps improving.
                let mut next = alpha * 2.0;
                while next <= opts.max_alpha && best.evals < opts.max_trials {
                    let jn = eval(next)?;
                    best.evals += 1;
                    if jn.is_finite()
                        && jn <= j0 + opts.c1 * next * gdx
                        && jn < best.j_new
                    {
                        best.alpha = next;
                        best.j_new = jn;
                        next *= 2.0;
                    } else {
                        break;
                    }
                }
            }
            return Ok(best);
        }
        alpha *= opts.shrink;
    }
    Err(Error::Solver(format!(
        "Armijo line search failed after {} trials (J0={j0:.6e}, <g,dv>={gdx:.3e})",
        opts.max_trials
    )))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_step_accepted_on_quadratic() {
        // J(a) = (1-a)^2, J0 = 1, gdx = -2: alpha=1 gives 0 <= 1 - 2e-4.
        let res = armijo(1.0, -2.0, ArmijoOptions::default(), |a| Ok((1.0 - a).powi(2))).unwrap();
        assert_eq!(res.alpha, 1.0);
        assert_eq!(res.evals, 1);
    }

    #[test]
    fn backtracks_on_overshoot() {
        // J(a) = (1 - 4a)^2: full step increases J; needs backtracking.
        let res = armijo(1.0, -8.0, ArmijoOptions::default(), |a| Ok((1.0 - 4.0 * a).powi(2)))
            .unwrap();
        assert!(res.alpha < 1.0);
        assert!(res.j_new < 1.0);
    }

    #[test]
    fn rejects_ascent_direction() {
        assert!(armijo(1.0, 0.5, ArmijoOptions::default(), |_| Ok(0.0)).is_err());
    }

    #[test]
    fn fails_cleanly_when_no_decrease() {
        let res = armijo(1.0, -1.0, ArmijoOptions { max_trials: 5, ..Default::default() }, |_| {
            Ok(2.0)
        });
        assert!(res.is_err());
    }

    #[test]
    fn nan_objective_rejected() {
        // NaN trial values must not be accepted (CFL blowup guard).
        let res = armijo(1.0, -2.0, ArmijoOptions::default(), |a| {
            Ok(if a > 0.1 { f64::NAN } else { 0.5 })
        })
        .unwrap();
        assert!(res.alpha <= 0.1);
    }
}
