//! # CLAIRE-rs
//!
//! A Rust + JAX + Pallas reproduction of *"Fast GPU 3D Diffeomorphic Image
//! Registration"* (Brunn, Himthani, Biros, Mehl, Mang — JPDC 2020): a
//! Gauss-Newton-Krylov solver for stationary-velocity LDDMM registration
//! with optimized scattered-data interpolation and 8th-order finite
//! difference kernels.
//!
//! Architecture (three layers, see DESIGN.md):
//! * **L1** Pallas kernels + **L2** JAX PDE operators are authored in
//!   `python/compile/` and AOT-lowered to HLO text artifacts at build time.
//! * **L3** (this crate) is the coordinator: it loads the artifacts via the
//!   PJRT C API and runs the paper's Algorithm 2.1 — Gauss-Newton outer
//!   loop, PCG on the Gauss-Newton Hessian, Armijo line search, parameter
//!   continuation — plus baseline optimizers, metrics, synthetic data, a
//!   one-shot batch service, and a persistent registration daemon
//!   (`serve/`: priority scheduler, warm operator caches, NDJSON wire
//!   protocol) for the paper's "clinical workflow" setting. Python never
//!   runs at request time.

// The tree is unsafe-free (enforced since the concurrency-correctness
// pass; `cargo xtask lint` / scripts/lint_invariants.py verify the sync
// discipline on top). With local UB impossible, the sanitizer CI stages
// (TSan, Miri) guard dependencies and logic races rather than memory bugs.
#![forbid(unsafe_code)]

pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod field;
pub mod math;
pub mod optim;
pub mod precision;
pub mod registration;
pub mod request;
pub mod runtime;
pub mod serve;
pub mod template;
pub mod util;

pub use error::{Error, ErrorCode, Result};
pub use precision::Precision;
pub use request::JobRequest;
