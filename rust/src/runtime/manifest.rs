//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. No shapes are hard-coded in Rust; everything is read from
//! `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// One tensor signature (name, shape) of an artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One HLO artifact entry.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub key: String,
    pub file: PathBuf,
    pub op: String,
    pub variant: String,
    pub n: usize,
    pub nt: usize,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub nt: usize,
    pub artifacts: BTreeMap<String, Artifact>,
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| Error::Manifest("shape is not an array".into()))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| Error::Manifest("bad shape entry".into())))
        .collect()
}

fn sigs_of(j: &Json, named: bool) -> Result<Vec<TensorSig>> {
    let arr = j.as_arr().ok_or_else(|| Error::Manifest("signatures not an array".into()))?;
    arr.iter()
        .enumerate()
        .map(|(i, e)| {
            let name = if named {
                e.get("name").and_then(Json::as_str).unwrap_or("").to_string()
            } else {
                format!("out{i}")
            };
            let shape =
                shape_of(e.get("shape").ok_or_else(|| Error::Manifest("missing shape".into()))?)?;
            Ok(TensorSig { name, shape })
        })
        .collect()
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Manifest(format!("cannot read {} ({e}); run `make artifacts`", path.display()))
        })?;
        let root = Json::parse(&text)?;
        let nt = root
            .get("nt")
            .and_then(Json::as_usize)
            .ok_or_else(|| Error::Manifest("missing nt".into()))?;
        let arts = root
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| Error::Manifest("missing artifacts map".into()))?;
        let mut artifacts = BTreeMap::new();
        for (key, entry) in arts {
            let get_str = |k: &str| -> Result<String> {
                entry
                    .get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| Error::Manifest(format!("{key}: missing {k}")))
            };
            let art = Artifact {
                key: key.clone(),
                file: dir.join(get_str("file")?),
                op: get_str("op")?,
                variant: get_str("variant")?,
                n: entry
                    .get("n")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| Error::Manifest(format!("{key}: missing n")))?,
                nt: entry.get("nt").and_then(Json::as_usize).unwrap_or(nt),
                inputs: sigs_of(
                    entry.get("inputs").ok_or_else(|| Error::Manifest("missing inputs".into()))?,
                    true,
                )?,
                outputs: sigs_of(
                    entry
                        .get("outputs")
                        .ok_or_else(|| Error::Manifest("missing outputs".into()))?,
                    false,
                )?,
            };
            artifacts.insert(key.clone(), art);
        }
        Ok(Manifest { dir: dir.to_path_buf(), nt, artifacts })
    }

    /// Find the artifact for (op, variant, n). Kernel-level and shared ops
    /// are emitted under the default variant; fall back to any variant that
    /// provides the op at this size.
    pub fn find(&self, op: &str, variant: &str, n: usize) -> Result<&Artifact> {
        let key = format!("{op}__{variant}__n{n}");
        if let Some(a) = self.artifacts.get(&key) {
            return Ok(a);
        }
        self.artifacts
            .values()
            .find(|a| a.op == op && a.n == n)
            .ok_or_else(|| Error::ArtifactNotFound {
                op: op.into(),
                variant: variant.into(),
                n,
            })
    }

    /// All grid sizes present for a given op.
    pub fn sizes_for(&self, op: &str) -> Vec<usize> {
        let mut v: Vec<usize> =
            self.artifacts.values().filter(|a| a.op == op).map(|a| a.n).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// All variants present for a given (op, n).
    pub fn variants_for(&self, op: &str, n: usize) -> Vec<String> {
        let mut v: Vec<String> = self
            .artifacts
            .values()
            .filter(|a| a.op == op && a.n == n)
            .map(|a| a.variant.clone())
            .collect();
        v.sort();
        v.dedup();
        v
    }
}

/// Default artifacts directory: `$CLAIRE_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("CLAIRE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> Option<PathBuf> {
        let d = default_dir();
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn load_real_manifest() {
        let Some(dir) = manifest_dir() else {
            eprintln!("skipping: no artifacts built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.nt, 4);
        assert!(!m.artifacts.is_empty());
        // Every artifact file referenced must exist.
        for a in m.artifacts.values() {
            assert!(a.file.exists(), "missing {}", a.file.display());
        }
    }

    #[test]
    fn find_and_fallback() {
        let Some(dir) = manifest_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let a = m.find("newton_setup", "opt-fd8-cubic", 16).unwrap();
        assert_eq!(a.op, "newton_setup");
        assert_eq!(a.inputs.len(), 4); // v, m0, m1, bg
        assert_eq!(a.inputs[0].shape, vec![3, 16, 16, 16]);
        // kernel op lowered only for the default variant: fallback works
        let k = m.find("grad_fd8", "ref-fft-cubic", 16).unwrap();
        assert_eq!(k.op, "grad_fd8");
        // missing size errors
        assert!(m.find("newton_setup", "opt-fd8-cubic", 1024).is_err());
    }

    #[test]
    fn sizes_and_variants() {
        let Some(dir) = manifest_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let sizes = m.sizes_for("newton_setup");
        assert!(sizes.contains(&16));
        let vars = m.variants_for("newton_setup", 16);
        assert!(vars.iter().any(|v| v == "opt-fd8-cubic"));
        assert!(vars.iter().any(|v| v == "ref-fft-cubic"));
    }
}
