//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. No shapes are hard-coded in Rust; everything is read from
//! `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::precision::Precision;
use crate::util::json::Json;

/// Element type of one artifact tensor at the PJRT boundary. The manifest
/// declares it per signature; `runtime/operator.rs` marshals host f32
/// buffers into the declared storage type. Entries without a `dtype`
/// field are f32 (pre-mixed-precision manifests stay loadable).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DType {
    #[default]
    F32,
    F16,
    Bf16,
}

impl DType {
    pub fn as_str(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::Bf16 => "bf16",
        }
    }

    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "f16" => Ok(DType::F16),
            "bf16" => Ok(DType::Bf16),
            other => Err(Error::Manifest(format!(
                "unknown dtype '{other}' (expected f32, f16 or bf16)"
            ))),
        }
    }

    /// Bytes per element as marshalled on the wire to PJRT.
    pub fn size_bytes(&self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F16 | DType::Bf16 => 2,
        }
    }
}

/// One tensor signature (name, shape, storage dtype) of an artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One HLO artifact entry.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub key: String,
    pub file: PathBuf,
    pub op: String,
    pub variant: String,
    pub n: usize,
    pub nt: usize,
    /// Precision the artifact was lowered at (missing field = full).
    pub precision: Precision,
    /// Leading subject-batch extent (missing field = 1, i.e. the
    /// historical unbatched lowering).
    pub batch: usize,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// Manifest key for (op, variant, n, precision). Full-precision keys keep
/// the historical `op__variant__nN` form; mixed artifacts append a
/// `__mixed` suffix, so the key itself is the registry cache key.
pub fn artifact_key(op: &str, variant: &str, n: usize, precision: Precision) -> String {
    match precision {
        Precision::Full => format!("{op}__{variant}__n{n}"),
        Precision::Mixed => format!("{op}__{variant}__n{n}__mixed"),
    }
}

/// Manifest key for (op, variant, n, precision, batch). Batch 1 is the
/// unbatched key above; B >= 2 appends `__b{B}` after any `__mixed`.
pub fn artifact_key_b(
    op: &str,
    variant: &str,
    n: usize,
    precision: Precision,
    batch: usize,
) -> String {
    let base = artifact_key(op, variant, n, precision);
    if batch <= 1 {
        base
    } else {
        format!("{base}__b{batch}")
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub nt: usize,
    pub artifacts: BTreeMap<String, Artifact>,
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| Error::Manifest("shape is not an array".into()))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| Error::Manifest("bad shape entry".into())))
        .collect()
}

fn sigs_of(j: &Json, named: bool) -> Result<Vec<TensorSig>> {
    let arr = j.as_arr().ok_or_else(|| Error::Manifest("signatures not an array".into()))?;
    arr.iter()
        .enumerate()
        .map(|(i, e)| {
            let name = if named {
                e.get("name").and_then(Json::as_str).unwrap_or("").to_string()
            } else {
                format!("out{i}")
            };
            let shape =
                shape_of(e.get("shape").ok_or_else(|| Error::Manifest("missing shape".into()))?)?;
            // Absent dtype defaults to f32 (back-compat); a present but
            // malformed or unknown dtype is an error — silently marshalling
            // the wrong element width would corrupt every call.
            let dtype = match e.get("dtype") {
                None => DType::F32,
                Some(v) => DType::parse(
                    v.as_str().ok_or_else(|| Error::Manifest("dtype is not a string".into()))?,
                )?,
            };
            Ok(TensorSig { name, shape, dtype })
        })
        .collect()
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Manifest(format!("cannot read {} ({e}); run `make artifacts`", path.display()))
        })?;
        let root = Json::parse(&text)?;
        let nt = root
            .get("nt")
            .and_then(Json::as_usize)
            .ok_or_else(|| Error::Manifest("missing nt".into()))?;
        let arts = root
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| Error::Manifest("missing artifacts map".into()))?;
        let mut artifacts = BTreeMap::new();
        for (key, entry) in arts {
            let get_str = |k: &str| -> Result<String> {
                entry
                    .get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| Error::Manifest(format!("{key}: missing {k}")))
            };
            let precision = match entry.get("precision") {
                None => Precision::Full,
                Some(v) => Precision::parse(
                    v.as_str()
                        .ok_or_else(|| Error::Manifest(format!("{key}: precision not a string")))?,
                )
                .map_err(|e| Error::Manifest(format!("{key}: {e}")))?,
            };
            let art = Artifact {
                key: key.clone(),
                file: dir.join(get_str("file")?),
                op: get_str("op")?,
                variant: get_str("variant")?,
                n: entry
                    .get("n")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| Error::Manifest(format!("{key}: missing n")))?,
                nt: entry.get("nt").and_then(Json::as_usize).unwrap_or(nt),
                precision,
                batch: entry.get("batch").and_then(Json::as_usize).unwrap_or(1),
                inputs: sigs_of(
                    entry.get("inputs").ok_or_else(|| Error::Manifest("missing inputs".into()))?,
                    true,
                )?,
                outputs: sigs_of(
                    entry
                        .get("outputs")
                        .ok_or_else(|| Error::Manifest("missing outputs".into()))?,
                    false,
                )?,
            };
            artifacts.insert(key.clone(), art);
        }
        Ok(Manifest { dir: dir.to_path_buf(), nt, artifacts })
    }

    /// Find the full-precision artifact for (op, variant, n).
    pub fn find(&self, op: &str, variant: &str, n: usize) -> Result<&Artifact> {
        self.find_p(op, variant, n, Precision::Full)
    }

    /// Find the artifact for (op, variant, n, precision). Kernel-level and
    /// shared ops are emitted under the default variant; fall back to any
    /// variant that provides the op at this size *and precision* — a mixed
    /// request never silently degrades to a full-precision artifact (the
    /// solver decides its own fallback policy).
    pub fn find_p(
        &self,
        op: &str,
        variant: &str,
        n: usize,
        precision: Precision,
    ) -> Result<&Artifact> {
        let key = artifact_key(op, variant, n, precision);
        if let Some(a) = self.artifacts.get(&key) {
            return Ok(a);
        }
        // Fallback is batch-scoped too: a batched artifact must never
        // satisfy an unbatched lookup (its shapes carry a leading B dim).
        self.artifacts
            .values()
            .find(|a| a.op == op && a.n == n && a.precision == precision && a.batch == 1)
            .ok_or_else(|| Error::ArtifactNotFound {
                op: op.into(),
                variant: format!("{variant}/{precision}"),
                n,
            })
    }

    /// Find the artifact for (op, variant, n, precision, batch). Batch 1
    /// delegates to `find_p`; B >= 2 resolves `__b{B}` keys with the same
    /// any-variant fallback, scoped to the exact batch extent.
    pub fn find_b(
        &self,
        op: &str,
        variant: &str,
        n: usize,
        precision: Precision,
        batch: usize,
    ) -> Result<&Artifact> {
        if batch <= 1 {
            return self.find_p(op, variant, n, precision);
        }
        let key = artifact_key_b(op, variant, n, precision, batch);
        if let Some(a) = self.artifacts.get(&key) {
            return Ok(a);
        }
        self.artifacts
            .values()
            .find(|a| a.op == op && a.n == n && a.precision == precision && a.batch == batch)
            .ok_or_else(|| Error::ArtifactNotFound {
                op: op.into(),
                variant: format!("{variant}/{precision}/b{batch}"),
                n,
            })
    }

    /// Batch extents (ascending, excluding 1) available for
    /// (op, variant-or-fallback, n, precision). The batched solve path
    /// picks the smallest extent that fits a coalesced group.
    pub fn batches_for(&self, op: &str, n: usize, precision: Precision) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .values()
            .filter(|a| a.op == op && a.n == n && a.precision == precision && a.batch > 1)
            .map(|a| a.batch)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Whether an artifact exists for (op, variant, n, precision).
    pub fn has(&self, op: &str, variant: &str, n: usize, precision: Precision) -> bool {
        self.find_p(op, variant, n, precision).is_ok()
    }

    /// All grid sizes present for a given op.
    pub fn sizes_for(&self, op: &str) -> Vec<usize> {
        let mut v: Vec<usize> =
            self.artifacts.values().filter(|a| a.op == op).map(|a| a.n).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// All variants present for a given (op, n).
    pub fn variants_for(&self, op: &str, n: usize) -> Vec<String> {
        let mut v: Vec<String> = self
            .artifacts
            .values()
            .filter(|a| a.op == op && a.n == n)
            .map(|a| a.variant.clone())
            .collect();
        v.sort();
        v.dedup();
        v
    }
}

/// Default artifacts directory: `$CLAIRE_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("CLAIRE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> Option<PathBuf> {
        let d = default_dir();
        d.join("manifest.json").exists().then_some(d)
    }

    /// Write a synthetic manifest.json into a fresh temp dir and load it.
    fn load_synthetic(name: &str, body: &str) -> Result<Manifest> {
        let dir = std::env::temp_dir().join(format!("claire_manifest_{name}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
        Manifest::load(&dir)
    }

    const MIXED_MANIFEST: &str = r#"{
      "nt": 4,
      "artifacts": {
        "hess_matvec__opt-fd8-cubic__n16": {
          "file": "hess_matvec__opt-fd8-cubic__n16.hlo.txt",
          "op": "hess_matvec", "variant": "opt-fd8-cubic", "n": 16,
          "inputs": [{"name": "vt", "shape": [3,16,16,16]}],
          "outputs": [{"shape": [3,16,16,16], "dtype": "f32"}]
        },
        "hess_matvec__opt-fd8-cubic__n16__mixed": {
          "file": "hess_matvec__opt-fd8-cubic__n16__mixed.hlo.txt",
          "op": "hess_matvec", "variant": "opt-fd8-cubic", "n": 16,
          "precision": "mixed",
          "inputs": [
            {"name": "vt", "shape": [3,16,16,16], "dtype": "f32"},
            {"name": "m_traj", "shape": [5,16,16,16], "dtype": "f16"},
            {"name": "q", "shape": [3,4096], "dtype": "bf16"}
          ],
          "outputs": [{"shape": [3,16,16,16], "dtype": "f32"}]
        }
      }
    }"#;

    #[test]
    fn dtype_parsing_with_backcompat_default() {
        let m = load_synthetic("dtypes", MIXED_MANIFEST).unwrap();
        let full = m.find_p("hess_matvec", "opt-fd8-cubic", 16, Precision::Full).unwrap();
        // Missing dtype field defaults to f32 (pre-dtype manifests load).
        assert_eq!(full.precision, Precision::Full);
        assert_eq!(full.inputs[0].dtype, DType::F32);
        let mixed = m.find_p("hess_matvec", "opt-fd8-cubic", 16, Precision::Mixed).unwrap();
        assert_eq!(mixed.precision, Precision::Mixed);
        assert_eq!(mixed.inputs[0].dtype, DType::F32);
        assert_eq!(mixed.inputs[1].dtype, DType::F16);
        assert_eq!(mixed.inputs[2].dtype, DType::Bf16);
        assert_eq!(mixed.outputs[0].dtype, DType::F32);
        // The two precisions resolve to distinct artifact keys.
        assert_ne!(full.key, mixed.key);
        assert_eq!(mixed.key, artifact_key("hess_matvec", "opt-fd8-cubic", 16, Precision::Mixed));
    }

    #[test]
    fn unknown_dtype_is_rejected() {
        let bad = MIXED_MANIFEST.replace("\"f16\"", "\"f8\"");
        let err = load_synthetic("baddtype", &bad).unwrap_err();
        assert!(err.to_string().contains("unknown dtype"), "{err}");
        // Wrong JSON type for dtype is also an error, not a silent default.
        let bad2 = MIXED_MANIFEST.replace("\"f16\"", "16");
        assert!(load_synthetic("baddtype2", &bad2).is_err());
    }

    #[test]
    fn unknown_precision_is_rejected() {
        let bad = MIXED_MANIFEST.replace("\"mixed\"", "\"half\"");
        let err = load_synthetic("badprec", &bad).unwrap_err();
        assert!(err.to_string().contains("unknown precision"), "{err}");
    }

    #[test]
    fn mixed_lookup_never_degrades_to_full() {
        // An op with only a full-precision entry: a Mixed request must not
        // fall back to it (the solver decides its own fallback policy).
        let only_full = r#"{
          "nt": 4,
          "artifacts": {
            "hess_matvec__opt-fd8-cubic__n16": {
              "file": "hess_matvec__opt-fd8-cubic__n16.hlo.txt",
              "op": "hess_matvec", "variant": "opt-fd8-cubic", "n": 16,
              "inputs": [{"name": "vt", "shape": [3,16,16,16]}],
              "outputs": [{"shape": [3,16,16,16]}]
            }
          }
        }"#;
        let m = load_synthetic("onlyfull", only_full).unwrap();
        assert!(m.find_p("hess_matvec", "opt-fd8-cubic", 16, Precision::Mixed).is_err());
        assert!(m.find_p("hess_matvec", "opt-fd8-cubic", 16, Precision::Full).is_ok());
        assert!(!m.has("hess_matvec", "opt-fd8-cubic", 16, Precision::Mixed));
        assert!(m.has("hess_matvec", "opt-fd8-cubic", 16, Precision::Full));
        // Conversely a mixed-only op must not satisfy a Full request.
        let m2 = load_synthetic("mixedside", MIXED_MANIFEST).unwrap();
        assert!(m2.find_p("newton_setup", "opt-fd8-cubic", 16, Precision::Mixed).is_err());
        // The off-key fallback path stays precision-scoped too.
        let fb = m2.find_p("hess_matvec", "ref-fft-cubic", 16, Precision::Mixed).unwrap();
        assert_eq!(fb.precision, Precision::Mixed);
    }

    #[test]
    fn batched_artifacts_resolve_and_stay_scoped() {
        // One unbatched and one __b4 entry for the same (op, n, precision):
        // the fallback path must keep them apart in both directions.
        let body = r#"{
          "nt": 4,
          "artifacts": {
            "hess_matvec__opt-fd8-cubic__n16": {
              "file": "hess_matvec__opt-fd8-cubic__n16.hlo.txt",
              "op": "hess_matvec", "variant": "opt-fd8-cubic", "n": 16,
              "inputs": [{"name": "vt", "shape": [3,16,16,16]}],
              "outputs": [{"shape": [3,16,16,16]}]
            },
            "hess_matvec__opt-fd8-cubic__n16__b4": {
              "file": "hess_matvec__opt-fd8-cubic__n16__b4.hlo.txt",
              "op": "hess_matvec", "variant": "opt-fd8-cubic", "n": 16,
              "batch": 4,
              "inputs": [{"name": "vt", "shape": [4,3,16,16,16]}],
              "outputs": [{"shape": [4,3,16,16,16]}]
            },
            "hess_matvec__opt-fd8-cubic__n16__b8": {
              "file": "hess_matvec__opt-fd8-cubic__n16__b8.hlo.txt",
              "op": "hess_matvec", "variant": "opt-fd8-cubic", "n": 16,
              "batch": 8,
              "inputs": [{"name": "vt", "shape": [8,3,16,16,16]}],
              "outputs": [{"shape": [8,3,16,16,16]}]
            }
          }
        }"#;
        let m = load_synthetic("batched", body).unwrap();
        // Missing batch field = 1.
        assert_eq!(m.find("hess_matvec", "opt-fd8-cubic", 16).unwrap().batch, 1);
        // Exact-key and off-variant-fallback lookups are batch-scoped.
        let b4 = m.find_b("hess_matvec", "opt-fd8-cubic", 16, Precision::Full, 4).unwrap();
        assert_eq!(b4.batch, 4);
        assert_eq!(b4.inputs[0].shape, vec![4, 3, 16, 16, 16]);
        let fb = m.find_b("hess_matvec", "ref-fft-cubic", 16, Precision::Full, 8).unwrap();
        assert_eq!(fb.batch, 8);
        // An unbatched fallback never lands on a batched artifact even if
        // only batched entries would match the (op, n, precision) triple.
        let unb = m.find_p("hess_matvec", "ref-fft-cubic", 16, Precision::Full).unwrap();
        assert_eq!(unb.batch, 1);
        // Unavailable extents error instead of degrading.
        assert!(m.find_b("hess_matvec", "opt-fd8-cubic", 16, Precision::Full, 2).is_err());
        assert!(m.find_b("hess_matvec", "opt-fd8-cubic", 16, Precision::Mixed, 4).is_err());
        assert_eq!(m.batches_for("hess_matvec", 16, Precision::Full), vec![4, 8]);
        assert!(m.batches_for("hess_matvec", 16, Precision::Mixed).is_empty());
        // Key formatting: __b{B} appends after any __mixed.
        assert_eq!(
            artifact_key_b("hess_matvec", "v", 16, Precision::Mixed, 4),
            "hess_matvec__v__n16__mixed__b4"
        );
        assert_eq!(
            artifact_key_b("hess_matvec", "v", 16, Precision::Full, 1),
            artifact_key("hess_matvec", "v", 16, Precision::Full)
        );
    }

    #[test]
    fn tensor_sig_accounts_marshalled_bytes() {
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::F16.size_bytes(), 2);
        assert_eq!(DType::Bf16.size_bytes(), 2);
        assert!(DType::parse("f64").is_err());
        let sig = TensorSig { name: "v".into(), shape: vec![3, 8, 8, 8], dtype: DType::F16 };
        assert_eq!(sig.elements(), 3 * 512);
        assert_eq!(sig.elements() * sig.dtype.size_bytes(), 3 * 512 * 2);
    }

    #[test]
    fn load_real_manifest() {
        let Some(dir) = manifest_dir() else {
            eprintln!("skipping: no artifacts built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.nt, 4);
        assert!(!m.artifacts.is_empty());
        // Every artifact file referenced must exist.
        for a in m.artifacts.values() {
            assert!(a.file.exists(), "missing {}", a.file.display());
        }
    }

    #[test]
    fn find_and_fallback() {
        let Some(dir) = manifest_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let a = m.find("newton_setup", "opt-fd8-cubic", 16).unwrap();
        assert_eq!(a.op, "newton_setup");
        assert_eq!(a.inputs.len(), 4); // v, m0, m1, bg
        assert_eq!(a.inputs[0].shape, vec![3, 16, 16, 16]);
        // kernel op lowered only for the default variant: fallback works
        let k = m.find("grad_fd8", "ref-fft-cubic", 16).unwrap();
        assert_eq!(k.op, "grad_fd8");
        // missing size errors
        assert!(m.find("newton_setup", "opt-fd8-cubic", 1024).is_err());
    }

    #[test]
    fn sizes_and_variants() {
        let Some(dir) = manifest_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let sizes = m.sizes_for("newton_setup");
        assert!(sizes.contains(&16));
        let vars = m.variants_for("newton_setup", 16);
        assert!(vars.iter().any(|v| v == "opt-fd8-cubic"));
        assert!(vars.iter().any(|v| v == "ref-fft-cubic"));
    }
}
