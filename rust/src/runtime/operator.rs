//! Compiled operator: an HLO artifact loaded, compiled on the PJRT CPU
//! client, and callable with f32 host buffers.
//!
//! This is the runtime half of the AOT bridge (see /opt/xla-example): HLO
//! *text* is parsed with `HloModuleProto::from_text_file` (the text parser
//! reassigns the 64-bit instruction ids jax >= 0.5 emits, which
//! xla_extension 0.5.1 would reject in proto form), compiled once, and
//! executed from the solver hot loop. Python is never involved.

use std::time::Instant;

use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::error::{Error, Result};
use crate::runtime::manifest::Artifact;

/// Runtime counters for one operator (drives the Fig 3/4 breakdowns).
#[derive(Clone, Copy, Debug, Default)]
pub struct OpStats {
    pub calls: u64,
    pub total_s: f64,
}

/// A compiled, executable operator.
pub struct Operator {
    pub art: Artifact,
    exe: PjRtLoadedExecutable,
    stats: std::cell::Cell<OpStats>,
}

fn f32_bytes(xs: &[f32]) -> &[u8] {
    // f32 -> u8 reinterpretation; alignment 4 -> 1 is always valid and the
    // length is exact. Used to build XLA literals without copies.
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) }
}

/// Build an f32 literal of the given shape from a host slice.
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<Literal> {
    let expected: usize = shape.iter().product();
    if data.len() != expected {
        return Err(Error::ShapeMismatch {
            what: "literal".into(),
            expected,
            got: data.len(),
        });
    }
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, f32_bytes(data))?)
}

impl Operator {
    /// Load + compile an artifact on the given client.
    pub fn compile(client: &PjRtClient, art: &Artifact) -> Result<Operator> {
        let proto = xla::HloModuleProto::from_text_file(&art.file)?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Operator { art: art.clone(), exe, stats: Default::default() })
    }

    /// Execute with f32 slices in manifest input order; returns one Vec<f32>
    /// per manifest output. Input shapes are validated against the manifest.
    pub fn call(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let lits = self.literals(inputs)?;
        self.call_literals(&lits)
    }

    /// Pre-build input literals (reusable across calls: the PCG loop reuses
    /// the newton_setup caches for every matvec without re-marshalling).
    pub fn literals(&self, inputs: &[&[f32]]) -> Result<Vec<Literal>> {
        if inputs.len() != self.art.inputs.len() {
            return Err(Error::ShapeMismatch {
                what: format!("{} inputs", self.art.key),
                expected: self.art.inputs.len(),
                got: inputs.len(),
            });
        }
        self.art
            .inputs
            .iter()
            .zip(inputs)
            .map(|(sig, data)| literal_f32(&sig.shape, data))
            .collect()
    }

    /// Execute with pre-built literals (borrowed; reusable).
    pub fn call_literals(&self, lits: &[Literal]) -> Result<Vec<Vec<f32>>> {
        let t0 = Instant::now();
        let result = self.exe.execute::<&Literal>(&lits.iter().collect::<Vec<_>>())?;
        // aot.py lowers with return_tuple=True: one tuple buffer.
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != self.art.outputs.len() {
            return Err(Error::ShapeMismatch {
                what: format!("{} outputs", self.art.key),
                expected: self.art.outputs.len(),
                got: parts.len(),
            });
        }
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        let mut s = self.stats.get();
        s.calls += 1;
        s.total_s += t0.elapsed().as_secs_f64();
        self.stats.set(s);
        Ok(out)
    }

    /// Mixed-literal call where some inputs are cached literals and others
    /// fresh slices: `inputs[i]` overrides cache position i when Some.
    pub fn call_mixed(&self, cached: &[Literal], fresh: &[(usize, &[f32])]) -> Result<Vec<Vec<f32>>> {
        let mut lits: Vec<&Literal> = cached.iter().collect();
        let mut owned: Vec<(usize, Literal)> = Vec::with_capacity(fresh.len());
        for &(idx, data) in fresh {
            let sig = self
                .art
                .inputs
                .get(idx)
                .ok_or_else(|| Error::Manifest(format!("input index {idx} out of range")))?;
            owned.push((idx, literal_f32(&sig.shape, data)?));
        }
        for (idx, lit) in &owned {
            lits[*idx] = lit;
        }
        let t0 = Instant::now();
        let result = self.exe.execute::<&Literal>(&lits)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        let mut s = self.stats.get();
        s.calls += 1;
        s.total_s += t0.elapsed().as_secs_f64();
        self.stats.set(s);
        Ok(out)
    }

    pub fn stats(&self) -> OpStats {
        self.stats.get()
    }

    pub fn reset_stats(&self) {
        self.stats.set(OpStats::default());
    }
}
