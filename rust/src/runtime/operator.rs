//! Compiled operator: an HLO artifact loaded, compiled on the PJRT CPU
//! client, and callable with f32 host buffers.
//!
//! This is the runtime half of the AOT bridge (see /opt/xla-example): HLO
//! *text* is parsed with `HloModuleProto::from_text_file` (the text parser
//! reassigns the 64-bit instruction ids jax >= 0.5 emits, which
//! xla_extension 0.5.1 would reject in proto form), compiled once, and
//! executed from the solver hot loop. Python is never involved.
//!
//! Mixed precision: the host side always works in f32. Each input literal
//! is marshalled at the *manifest-declared* dtype — f16/bf16 tensors are
//! converted at this boundary (`math/half.rs`), so a mixed artifact's
//! per-Newton-iteration caches cost half the literal bytes and the
//! conversion is paid once per cache build, not once per matvec. Outputs
//! are declared f32 by every artifact (reduced precision lives *inside*
//! the kernels; outer quantities stay full precision per paper section 3).

use std::time::Instant;

use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::error::{Error, Result};
use crate::math::half;
use crate::runtime::manifest::{Artifact, DType, TensorSig};

/// Runtime counters for one operator (drives the Fig 3/4 breakdowns).
#[derive(Clone, Copy, Debug, Default)]
pub struct OpStats {
    pub calls: u64,
    pub total_s: f64,
}

/// A compiled, executable operator.
pub struct Operator {
    pub art: Artifact,
    exe: PjRtLoadedExecutable,
    stats: std::cell::Cell<OpStats>,
}

fn f32_bytes(xs: &[f32]) -> Vec<u8> {
    // Native-endian f32 -> u8 marshalling. The crate is #![forbid(unsafe_code)],
    // so this copies instead of reinterpreting; literal creation copies into
    // device layout anyway, so the extra pass is one memcpy-speed sweep.
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_ne_bytes());
    }
    out
}

fn u16_bytes(xs: &[u16]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 2);
    for x in xs {
        out.extend_from_slice(&x.to_ne_bytes());
    }
    out
}

/// Build an f32 literal of the given shape from a host slice.
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<Literal> {
    let expected: usize = shape.iter().product();
    if data.len() != expected {
        return Err(Error::ShapeMismatch {
            what: "literal".into(),
            expected,
            got: data.len(),
        });
    }
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, &f32_bytes(data))?)
}

/// Build a literal from an f32 host slice at the signature's declared
/// storage dtype, converting at the boundary for f16/bf16.
pub fn literal_for(sig: &TensorSig, data: &[f32]) -> Result<Literal> {
    let expected = sig.elements();
    if data.len() != expected {
        return Err(Error::ShapeMismatch {
            what: format!("literal '{}'", sig.name),
            expected,
            got: data.len(),
        });
    }
    Ok(match sig.dtype {
        DType::F32 => Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &sig.shape,
            &f32_bytes(data),
        )?,
        DType::F16 => {
            let bits = half::f16_bits_of(data);
            Literal::create_from_shape_and_untyped_data(
                ElementType::F16,
                &sig.shape,
                &u16_bytes(&bits),
            )?
        }
        DType::Bf16 => {
            let bits = half::bf16_bits_of(data);
            Literal::create_from_shape_and_untyped_data(
                ElementType::Bf16,
                &sig.shape,
                &u16_bytes(&bits),
            )?
        }
    })
}

/// Build a literal for a batched signature from B per-subject slices,
/// concatenated along the leading batch dim. Every part must be the same
/// length and together they must fill the signature exactly; each part is
/// one subject's slot, so the batched solve path marshals B host buffers
/// into one device literal without the caller pre-stacking.
pub fn stacked_literal_for(sig: &TensorSig, parts: &[&[f32]]) -> Result<Literal> {
    let expected = sig.elements();
    if parts.is_empty() || expected % parts.len() != 0 {
        return Err(Error::ShapeMismatch {
            what: format!("stacked literal '{}' parts", sig.name),
            expected: sig.shape.first().copied().unwrap_or(0),
            got: parts.len(),
        });
    }
    let slot = expected / parts.len();
    let mut data = Vec::with_capacity(expected);
    for part in parts {
        if part.len() != slot {
            return Err(Error::ShapeMismatch {
                what: format!("stacked literal '{}' slot", sig.name),
                expected: slot,
                got: part.len(),
            });
        }
        data.extend_from_slice(part);
    }
    literal_for(sig, &data)
}

impl Operator {
    /// Load + compile an artifact on the given client.
    pub fn compile(client: &PjRtClient, art: &Artifact) -> Result<Operator> {
        // Outputs are unmarshalled as f32; reject exotic artifacts up
        // front instead of failing on the first call.
        if let Some(bad) = art.outputs.iter().find(|s| s.dtype != DType::F32) {
            return Err(Error::Manifest(format!(
                "{}: output '{}' is {} — only f32 outputs are marshalled \
                 (reduced precision lives inside the kernels)",
                art.key,
                bad.name,
                bad.dtype.as_str()
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(&art.file)?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Operator { art: art.clone(), exe, stats: Default::default() })
    }

    /// Execute with f32 slices in manifest input order; returns one Vec<f32>
    /// per manifest output. Input shapes are validated against the manifest.
    pub fn call(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let lits = self.literals(inputs)?;
        self.call_literals(&lits)
    }

    /// Pre-build input literals (reusable across calls: the PCG loop reuses
    /// the newton_setup caches for every matvec without re-marshalling).
    /// Each literal is built at its manifest-declared dtype, so mixed
    /// artifacts pay the f32 -> f16 conversion here, once per cache.
    pub fn literals(&self, inputs: &[&[f32]]) -> Result<Vec<Literal>> {
        if inputs.len() != self.art.inputs.len() {
            return Err(Error::ShapeMismatch {
                what: format!("{} inputs", self.art.key),
                expected: self.art.inputs.len(),
                got: inputs.len(),
            });
        }
        self.art
            .inputs
            .iter()
            .zip(inputs)
            .map(|(sig, data)| literal_for(sig, data))
            .collect()
    }

    /// Execute with pre-built literals (borrowed; reusable).
    pub fn call_literals(&self, lits: &[Literal]) -> Result<Vec<Vec<f32>>> {
        let t0 = Instant::now();
        let result = self.exe.execute::<&Literal>(&lits.iter().collect::<Vec<_>>())?;
        // aot.py lowers with return_tuple=True: one tuple buffer.
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != self.art.outputs.len() {
            return Err(Error::ShapeMismatch {
                what: format!("{} outputs", self.art.key),
                expected: self.art.outputs.len(),
                got: parts.len(),
            });
        }
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        let mut s = self.stats.get();
        s.calls += 1;
        s.total_s += t0.elapsed().as_secs_f64();
        self.stats.set(s);
        Ok(out)
    }

    /// Mixed-literal call where some inputs are cached literals and others
    /// fresh slices: `inputs[i]` overrides cache position i when Some.
    pub fn call_mixed(&self, cached: &[Literal], fresh: &[(usize, &[f32])]) -> Result<Vec<Vec<f32>>> {
        let mut lits: Vec<&Literal> = cached.iter().collect();
        let mut owned: Vec<(usize, Literal)> = Vec::with_capacity(fresh.len());
        for &(idx, data) in fresh {
            let sig = self
                .art
                .inputs
                .get(idx)
                .ok_or_else(|| Error::Manifest(format!("input index {idx} out of range")))?;
            owned.push((idx, literal_for(sig, data)?));
        }
        for (idx, lit) in &owned {
            lits[*idx] = lit;
        }
        let t0 = Instant::now();
        let result = self.exe.execute::<&Literal>(&lits)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        let mut s = self.stats.get();
        s.calls += 1;
        s.total_s += t0.elapsed().as_secs_f64();
        self.stats.set(s);
        Ok(out)
    }

    pub fn stats(&self) -> OpStats {
        self.stats.get()
    }

    pub fn reset_stats(&self) {
        self.stats.set(OpStats::default());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(dtype: DType) -> TensorSig {
        TensorSig { name: "x".into(), shape: vec![2, 3], dtype }
    }

    #[test]
    fn literal_for_validates_element_count() {
        let data = [1.0f32; 5];
        for d in [DType::F32, DType::F16, DType::Bf16] {
            let err = literal_for(&sig(d), &data).unwrap_err();
            assert!(matches!(err, Error::ShapeMismatch { expected: 6, got: 5, .. }), "{d:?}");
        }
    }

    #[test]
    fn stacked_literal_concatenates_subject_slots() {
        // A (B=3, 2) batched signature built from 3 per-subject slices.
        let bsig = TensorSig { name: "v".into(), shape: vec![3, 2], dtype: DType::F32 };
        let (a, b, c) = ([1.0f32, 2.0], [3.0f32, 4.0], [5.0f32, 6.0]);
        assert!(stacked_literal_for(&bsig, &[&a, &b, &c]).is_ok());
        // Wrong part count and ragged parts are rejected.
        assert!(stacked_literal_for(&bsig, &[&a, &b]).is_err());
        assert!(stacked_literal_for(&bsig, &[]).is_err());
        let short = [1.0f32];
        assert!(stacked_literal_for(&bsig, &[&a, &b, &short]).is_err());
        // Reduced dtypes convert at the boundary like literal_for.
        let hsig = TensorSig { name: "m".into(), shape: vec![2, 3], dtype: DType::F16 };
        let s0 = [0.5f32, 1.5, -2.0];
        assert!(stacked_literal_for(&hsig, &[&s0, &s0]).is_ok());
    }

    #[test]
    fn reduced_literals_build_at_every_dtype() {
        let data: Vec<f32> = (0..6).map(|i| i as f32 * 0.25).collect();
        for d in [DType::F32, DType::F16, DType::Bf16] {
            assert!(literal_for(&sig(d), &data).is_ok(), "{d:?}");
        }
        // The marshalled byte count is the signature's accounting answer
        // (the literal itself is opaque): f16/bf16 halve the boundary.
        assert_eq!(sig(DType::F32).elements() * DType::F32.size_bytes(), 24);
        assert_eq!(sig(DType::F16).elements() * DType::F16.size_bytes(), 12);
    }
}
