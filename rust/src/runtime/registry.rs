//! Operator registry: lazy compilation cache over the artifact manifest.
//!
//! One PJRT client per process; operators compile on first use and are
//! shared by reference afterwards (executables are stateless; the batch
//! coordinator shares one registry across worker threads via `Mutex`).
//!
//! The cache key is the artifact key, which encodes the full
//! `(op, variant, n, precision)` quadruple (`manifest::artifact_key`): a
//! mixed-precision operator and its full-precision sibling compile and
//! cache independently, so a daemon serving both policies warms both.

use std::collections::BTreeMap;
use std::path::Path;

use xla::PjRtClient;

use crate::error::Result;
use crate::precision::Precision;
use crate::runtime::manifest::Manifest;
use crate::runtime::operator::Operator;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{Arc, Mutex};

/// Lazily compiled operator cache keyed by (op, variant, n, precision).
pub struct OpRegistry {
    pub client: PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<BTreeMap<String, Arc<Operator>>>,
    /// Monotonic statistics, Relaxed per the counter policy in
    /// util/sync.rs — read only for reporting, never for synchronization.
    hits: AtomicU64,
    compiles: AtomicU64,
}

impl OpRegistry {
    /// Open the registry over an artifacts directory.
    pub fn open(dir: &Path) -> Result<OpRegistry> {
        let client = PjRtClient::cpu()?;
        let manifest = Manifest::load(dir)?;
        Ok(OpRegistry {
            client,
            manifest,
            cache: Mutex::new(BTreeMap::new()),
            hits: AtomicU64::new(0),
            compiles: AtomicU64::new(0),
        })
    }

    /// Open at the default artifacts location.
    pub fn open_default() -> Result<OpRegistry> {
        Self::open(&crate::runtime::manifest::default_dir())
    }

    /// Get (compiling on first use) the full-precision operator for
    /// (op, variant, n).
    pub fn get(&self, op: &str, variant: &str, n: usize) -> Result<Arc<Operator>> {
        self.get_p(op, variant, n, Precision::Full)
    }

    /// Get (compiling on first use) the operator for
    /// (op, variant, n, precision). Precisions never share cache entries:
    /// the resolved artifact key encodes the precision.
    pub fn get_p(
        &self,
        op: &str,
        variant: &str,
        n: usize,
        precision: Precision,
    ) -> Result<Arc<Operator>> {
        let art = self.manifest.find_p(op, variant, n, precision)?.clone();
        let mut cache = self.cache.lock().unwrap();
        if let Some(o) = cache.get(&art.key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(o.clone());
        }
        let compiled = Arc::new(Operator::compile(&self.client, &art)?);
        cache.insert(art.key.clone(), compiled.clone());
        self.compiles.fetch_add(1, Ordering::Relaxed);
        Ok(compiled)
    }

    /// Get (compiling on first use) the batched operator for
    /// (op, variant, n, precision, batch). Batch 1 is `get_p`; B >= 2
    /// resolves `__b{B}` artifacts. Every batch extent caches under its
    /// own artifact key, so a daemon serving mixed batch sizes keeps each
    /// executable warm independently.
    pub fn get_b(
        &self,
        op: &str,
        variant: &str,
        n: usize,
        precision: Precision,
        batch: usize,
    ) -> Result<Arc<Operator>> {
        let art = self.manifest.find_b(op, variant, n, precision, batch)?.clone();
        let mut cache = self.cache.lock().unwrap();
        if let Some(o) = cache.get(&art.key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(o.clone());
        }
        let compiled = Arc::new(Operator::compile(&self.client, &art)?);
        cache.insert(art.key.clone(), compiled.clone());
        self.compiles.fetch_add(1, Ordering::Relaxed);
        Ok(compiled)
    }

    /// Number of compiled operators currently cached.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Warm-cache hits: `get` calls served without compiling. The serve
    /// stats endpoint reports this as compiled-operator reuse.
    pub fn cache_hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Number of first-use compilations performed by this registry.
    pub fn cache_compiles(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::default_dir;

    fn registry() -> Option<OpRegistry> {
        let dir = default_dir();
        dir.join("manifest.json").exists().then(|| OpRegistry::open(&dir).unwrap())
    }

    #[test]
    fn compile_and_cache() {
        let Some(reg) = registry() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let a = reg.get("grad_fd8", "opt-fd8-cubic", 16).unwrap();
        let b = reg.get("grad_fd8", "opt-fd8-cubic", 16).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(reg.compiled_count(), 1);
        assert_eq!(reg.cache_compiles(), 1);
        assert_eq!(reg.cache_hits(), 1);
    }

    #[test]
    fn grad_fd8_artifact_matches_rust_reference() {
        let Some(reg) = registry() else {
            return;
        };
        let n = 16usize;
        let h = 2.0 * std::f64::consts::PI / n as f64;
        let op = reg.get("grad_fd8", "opt-fd8-cubic", n).unwrap();
        let mut rng = crate::util::rng::Rng::new(99);
        let f: Vec<f32> = (0..n * n * n).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let out = op.call(&[&f]).unwrap();
        assert_eq!(out.len(), 1);
        let g = &out[0];
        assert_eq!(g.len(), 3 * n * n * n);
        for axis in 0..3 {
            let want = crate::math::kernels_ref::fd8_partial(&f, n, axis, h);
            let got = &g[axis * n * n * n..(axis + 1) * n * n * n];
            let rel = crate::math::stats::rel_l2(got, &want);
            assert!(rel < 1e-5, "axis {axis}: rel {rel}");
        }
    }

    #[test]
    fn interp_lin_artifact_matches_rust_reference() {
        let Some(reg) = registry() else {
            return;
        };
        let n = 16usize;
        let m = n * n * n;
        let op = reg.get("interp_lin", "opt-fd8-cubic", n).unwrap();
        let mut rng = crate::util::rng::Rng::new(7);
        let f: Vec<f32> = (0..m).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let mut q = vec![0f32; 3 * m];
        for x in q.iter_mut() {
            *x = rng.uniform_f32(-(n as f32), 2.0 * n as f32);
        }
        let out = op.call(&[&f, &q]).unwrap();
        let got = &out[0];
        for idx in (0..m).step_by(997) {
            let qp = [q[idx] as f64, q[m + idx] as f64, q[2 * m + idx] as f64];
            let want = crate::math::kernels_ref::interp_linear_at(&f, n, qp);
            assert!(
                (got[idx] as f64 - want).abs() < 1e-4,
                "at {idx}: {} vs {want}",
                got[idx]
            );
        }
    }

    #[test]
    fn precisions_cache_under_distinct_keys() {
        let Some(reg) = registry() else {
            return;
        };
        let n = 16usize;
        if !reg.manifest.has("hess_matvec", "opt-fd8-cubic", n, Precision::Mixed) {
            eprintln!("skipping: artifacts predate mixed precision");
            return;
        }
        let full = reg.get_p("hess_matvec", "opt-fd8-cubic", n, Precision::Full).unwrap();
        let mixed = reg.get_p("hess_matvec", "opt-fd8-cubic", n, Precision::Mixed).unwrap();
        // Same (op, variant, n), different precision: distinct compilations.
        assert!(!Arc::ptr_eq(&full, &mixed));
        assert_ne!(full.art.key, mixed.art.key);
        assert_eq!(reg.compiled_count(), 2);
        assert_eq!(reg.cache_compiles(), 2);
        // Re-fetching either is a warm hit on its own entry.
        let full2 = reg.get_p("hess_matvec", "opt-fd8-cubic", n, Precision::Full).unwrap();
        assert!(Arc::ptr_eq(&full, &full2));
        assert_eq!(reg.cache_hits(), 1);
        assert_eq!(reg.compiled_count(), 2);
        // The mixed artifact declares reduced-storage cache tensors.
        assert!(mixed
            .art
            .inputs
            .iter()
            .any(|s| s.dtype == crate::runtime::manifest::DType::F16));
    }

    #[test]
    fn bad_input_count_is_error() {
        let Some(reg) = registry() else {
            return;
        };
        let op = reg.get("grad_fd8", "opt-fd8-cubic", 16).unwrap();
        assert!(op.call(&[]).is_err());
    }
}
