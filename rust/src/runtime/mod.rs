//! PJRT runtime: manifest-driven loading, compilation and execution of the
//! AOT artifacts produced by `python/compile/aot.py`.

pub mod manifest;
pub mod operator;
pub mod registry;

pub use manifest::{Artifact, Manifest, TensorSig};
pub use operator::{literal_f32, OpStats, Operator};
pub use registry::OpRegistry;
