//! PJRT runtime: manifest-driven loading, compilation and execution of the
//! AOT artifacts produced by `python/compile/aot.py`.

pub mod manifest;
pub mod operator;
pub mod registry;

pub use manifest::{artifact_key, Artifact, DType, Manifest, TensorSig};
pub use operator::{literal_f32, literal_for, OpStats, Operator};
pub use registry::OpRegistry;
