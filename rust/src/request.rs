//! The canonical registration job request.
//!
//! Every surface that can describe a job — the serve wire protocol's
//! `submit`/`submit_batch` verbs, `key = value` config files, and the CLI
//! `register`/`submit` flag sets — builds a [`JobRequest`] and funnels it
//! through the single [`JobRequest::validate`] path to obtain solver
//! parameters. Before this module existed the job-configuration surface
//! was triplicated (wire `JobSpec`, `Config::reg_params`, ad-hoc flag
//! parsing in `main.rs`) with three divergent validation copies; now the
//! adapters are thin:
//!
//! * wire  — [`JobRequest::from_json`] (type-strict decode) + `validate`
//!   at daemon admission time,
//! * config — `Config::job_request` + `validate`,
//! * CLI   — [`JobRequest::from_args`] (flags over optional config file)
//!   + `validate`.
//!
//! Decode is *typing only* (a present field with the wrong JSON type is an
//! error); range and cross-field rules live in `validate`, so all three
//! surfaces accept and reject identical inputs identically.

use crate::config::Config;
use crate::error::{Error, ErrorCode, Result};
use crate::precision::Precision;
use crate::registration::algorithm::AlgorithmKind;
use crate::registration::problem::RegParams;
use crate::util::args::Args;
use crate::util::json::Json;

/// Hard cap on the requestable grid size. The paper's largest runs are
/// 256^3; 512^3 leaves headroom. Without this bound, a typo'd `"n": 5000`
/// would allocate n^3 buffers in the worker (hundreds of GB) before the
/// artifact lookup could reject the size — aborting the daemon, not just
/// failing the job.
pub const MAX_GRID_N: usize = 512;

/// Hard cap on requestable grid-continuation levels: 512 -> 16 is six
/// factor-2 descents, so deeper requests are always typos.
pub const MAX_MULTIRES_LEVELS: usize = 6;

/// Default iteration budget for first-order (`gd`/`lbfgs`) jobs when the
/// request leaves `max_iter` unset. The paper's baselines terminate on an
/// iteration budget rather than a gradient tolerance (section 4.2.2), and
/// need visibly more steps than Gauss-Newton's 50 — this default lives in
/// the single `validate` path so every surface (wire, config, CLI, batch)
/// runs the same budget.
pub const FIRST_ORDER_DEFAULT_MAX_ITER: usize = 100;

/// Cap on client-chosen dedup token length. Tokens are journaled verbatim
/// and held in the daemon's admission map; the cap keeps a buggy client
/// from growing both without bound.
pub const MAX_DEDUP_LEN: usize = 128;

/// Dispatch priority. Higher priorities jump the queue (they do not kill
/// running solves): the paper's emergency clinical scan is served before
/// queued batch research jobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Research / population-study batch work (default).
    Batch = 0,
    /// Interactive clinical sessions.
    Urgent = 1,
    /// Emergency scans: always admitted, dispatched first.
    Emergency = 2,
}

impl Priority {
    pub fn as_str(&self) -> &'static str {
        match self {
            Priority::Batch => "batch",
            Priority::Urgent => "urgent",
            Priority::Emergency => "emergency",
        }
    }

    pub fn parse(s: &str) -> Result<Priority> {
        match s {
            "batch" => Ok(Priority::Batch),
            "urgent" => Ok(Priority::Urgent),
            "emergency" => Ok(Priority::Emergency),
            other => Err(Error::wire(
                ErrorCode::BadRequest,
                format!("unknown priority '{other}'"),
            )),
        }
    }
}

/// Where a job's image pair comes from.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobSource {
    /// The daemon synthesizes a NIREP-analog pair from `subject` — the
    /// status quo default, exactly like the CLI `register`/`batch` paths.
    Synthetic,
    /// Template (`m0`) and reference (`m1`) volumes previously shipped via
    /// the `upload` verb, referenced by content id. Resolved against the
    /// daemon's store at admission time.
    Uploaded { m0: String, m1: String },
}

/// The canonical job request: a synthetic NIREP-analog subject *or* an
/// uploaded volume pair, at a given grid size and kernel variant, plus
/// every solver knob the three request surfaces expose. Optional fields
/// default through [`RegParams::default`] inside [`JobRequest::validate`].
#[derive(Clone, Debug, PartialEq)]
pub struct JobRequest {
    pub subject: String,
    pub n: usize,
    pub variant: String,
    /// Image source. Wire field `"source"`: absent = synthetic (pre-data-
    /// plane clients keep working), `{"m0":"<id>","m1":"<id>"}` = uploaded.
    pub source: JobSource,
    /// Solver precision policy; `mixed` runs the PCG Hessian matvecs
    /// through the reduced-precision artifacts. Wire field `"precision"`.
    pub precision: Precision,
    /// Which optimizer runs the job. Wire field `"algorithm"`: absent =
    /// `gn` (the paper's Gauss-Newton-Krylov; pre-algorithm clients keep
    /// working), `gd`/`lbfgs` select the first-order baselines through
    /// the same `Session` entry point.
    pub algorithm: AlgorithmKind,
    /// Grid-continuation levels. Wire field `"multires"`; absent = single
    /// grid. `Some(k >= 2)` runs `solve_multires` coarse-to-fine.
    pub multires: Option<usize>,
    pub priority: Priority,
    pub max_iter: Option<usize>,
    pub max_krylov: Option<usize>,
    pub beta: Option<f64>,
    pub gamma: Option<f64>,
    pub gtol: Option<f64>,
    pub continuation: Option<bool>,
    pub incompressible: Option<bool>,
    pub verbose: Option<bool>,
    /// Exactly-once submission token. Wire field `"dedup"`: when set, the
    /// daemon remembers `token -> job id` at admission, and a resubmission
    /// carrying the same token returns the original id instead of creating
    /// a duplicate job — so a client that lost the response to a transport
    /// failure can retry safely. `submit_with_retry` fills one in
    /// automatically when the caller left it unset.
    pub dedup: Option<String>,
    /// Initial-velocity content id. Wire field `"warm_start"`: when set,
    /// the daemon resolves it against the store at admission time (a
    /// vector volume previously retained from a solve or uploaded by the
    /// client) and seeds the solver with it instead of `v = 0`. The
    /// template driver threads round `r`'s per-subject velocities into
    /// round `r+1` this way, so later rounds converge in fewer iterations.
    pub warm_start: Option<String>,
}

impl Default for JobRequest {
    fn default() -> Self {
        JobRequest {
            subject: "na02".into(),
            n: 16,
            variant: "opt-fd8-cubic".into(),
            source: JobSource::Synthetic,
            precision: Precision::Full,
            algorithm: AlgorithmKind::GaussNewton,
            multires: None,
            priority: Priority::Batch,
            max_iter: None,
            max_krylov: None,
            beta: None,
            gamma: None,
            gtol: None,
            continuation: None,
            incompressible: None,
            verbose: None,
            dedup: None,
            warm_start: None,
        }
    }
}

impl JobRequest {
    /// Display name used in job records and the journal. Non-default
    /// algorithms carry a `+gd`/`+lbfgs` suffix, mixed-precision jobs
    /// `+mixed` and multires jobs `+mr<levels>`, so status tables and the
    /// journal show the policy at a glance; uploaded-source jobs show
    /// truncated content ids instead of a subject.
    pub fn name(&self) -> String {
        let subject = match &self.source {
            JobSource::Synthetic => self.subject.clone(),
            JobSource::Uploaded { m0, m1 } => {
                let short = |s: &str| s.chars().take(8).collect::<String>();
                format!("up:{}+{}", short(m0), short(m1))
            }
        };
        let mut name = format!("{}@{}^3/{}", subject, self.n, self.variant);
        if self.algorithm != AlgorithmKind::GaussNewton {
            name.push('+');
            name.push_str(self.algorithm.as_str());
        }
        if self.precision == Precision::Mixed {
            name.push_str("+mixed");
        }
        if let Some(levels) = self.multires.filter(|&l| l > 1) {
            name.push_str(&format!("+mr{levels}"));
        }
        name
    }

    /// THE validation path: every request surface ends here. Checks the
    /// job-level ranges (grid size, multires depth, source ids), fills
    /// solver defaults for absent knobs, and runs the numeric invariants
    /// ([`RegParams::check`]). Errors are classified `bad_request`.
    pub fn validate(&self) -> Result<RegParams> {
        let bad = |msg: String| Err(Error::wire(ErrorCode::BadRequest, msg));
        if self.n == 0 || self.n > MAX_GRID_N {
            return bad(format!(
                "job field 'n' = {} out of range (1..={MAX_GRID_N})",
                self.n
            ));
        }
        match &self.source {
            JobSource::Synthetic => {
                if self.subject.is_empty() {
                    return bad("job field 'subject' must be non-empty".into());
                }
            }
            JobSource::Uploaded { m0, m1 } => {
                if m0.is_empty() || m1.is_empty() {
                    return bad(
                        "job field 'source' must carry non-empty 'm0' and 'm1' content ids"
                            .into(),
                    );
                }
            }
        }
        if let Some(tok) = &self.dedup {
            if tok.is_empty() || tok.len() > MAX_DEDUP_LEN {
                return bad(format!(
                    "job field 'dedup' must be 1..={MAX_DEDUP_LEN} bytes, got {}",
                    tok.len()
                ));
            }
        }
        // Content ids share the dedup-token length budget: both are
        // client-chosen strings journaled verbatim.
        if let Some(id) = &self.warm_start {
            if id.is_empty() || id.len() > MAX_DEDUP_LEN {
                return bad(format!(
                    "job field 'warm_start' must be 1..={MAX_DEDUP_LEN} bytes, got {}",
                    id.len()
                ));
            }
        }
        // Solver-knob ranges (multires depth, positive iteration caps,
        // finite positive weights) live in `RegParams::check`, run below —
        // one copy, shared with every direct `RegParams` consumer.
        let d = RegParams::default();
        let p = RegParams {
            algorithm: self.algorithm,
            variant: self.variant.clone(),
            precision: self.precision,
            beta: self.beta.unwrap_or(d.beta),
            gamma: self.gamma.unwrap_or(d.gamma),
            gtol: self.gtol.unwrap_or(d.gtol),
            max_iter: self.max_iter.unwrap_or(match self.algorithm {
                AlgorithmKind::GaussNewton => d.max_iter,
                _ => FIRST_ORDER_DEFAULT_MAX_ITER,
            }),
            max_krylov: self.max_krylov.unwrap_or(d.max_krylov),
            continuation: self.continuation.unwrap_or(d.continuation),
            multires: self.multires.unwrap_or(d.multires),
            incompressible: self.incompressible.unwrap_or(d.incompressible),
            verbose: self.verbose.unwrap_or(d.verbose),
        };
        p.check()?;
        Ok(p)
    }

    /// Batch-coalescing compatibility key: two requests with equal keys
    /// evaluate through the same AOT executables under identical solver
    /// policy, so the scheduler may fuse them into one batched solve. This
    /// is deliberately the executable-selecting subset of the request —
    /// grid size, kernel variant, precision policy, algorithm, and grid
    /// continuation — and must stay in agreement with what
    /// [`validate`](JobRequest::validate) feeds into `RegParams` (pinned by
    /// the coalesce-key property test): requests coalesce iff they
    /// materialize equal solver-relevant `RegParams`. Subject, source,
    /// priority, dedup and verbose never split a batch; every explicitly
    /// overridden solver knob joins the key with its value, so a job never
    /// silently runs under a neighbor's tolerances.
    pub fn coalesce_key(&self) -> String {
        let mut key = format!(
            "n{}/{}/{}/{}/mr{}",
            self.n,
            self.variant,
            self.precision.as_str(),
            self.algorithm.as_str(),
            self.multires.unwrap_or(1)
        );
        // Explicit solver-knob overrides join the key verbatim: jobs only
        // coalesce when they would solve under byte-identical RegParams.
        for (tag, v) in [
            ("mi", self.max_iter.map(|x| x.to_string())),
            ("mk", self.max_krylov.map(|x| x.to_string())),
            ("b", self.beta.map(|x| format!("{x:e}"))),
            ("g", self.gamma.map(|x| format!("{x:e}"))),
            ("t", self.gtol.map(|x| format!("{x:e}"))),
            ("c", self.continuation.map(|x| x.to_string())),
            ("ic", self.incompressible.map(|x| x.to_string())),
        ] {
            if let Some(v) = v {
                key.push_str(&format!("/{tag}={v}"));
            }
        }
        // A warm start is policy too: seeded jobs may only fuse with jobs
        // seeded from the *same* velocity (the batched artifact takes no
        // per-job initial velocity, so mixing seeds would silently drop
        // them — and the executor additionally falls back to per-job
        // solves for any warm batch).
        if let Some(ws) = &self.warm_start {
            key.push_str(&format!("/ws={ws}"));
        }
        key
    }

    /// Wire encoding (the `"job"` object of `submit`). Optional knobs are
    /// emitted only when set, so a default request renders byte-identical
    /// to the pre-v2 encoding.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("subject", Json::str(&self.subject)),
            ("n", Json::num(self.n as f64)),
            ("variant", Json::str(&self.variant)),
            ("precision", Json::str(self.precision.as_str())),
            ("priority", Json::str(self.priority.as_str())),
        ];
        if self.algorithm != AlgorithmKind::GaussNewton {
            pairs.push(("algorithm", Json::str(self.algorithm.as_str())));
        }
        if let JobSource::Uploaded { m0, m1 } = &self.source {
            pairs.push((
                "source",
                Json::object([("m0", Json::str(m0)), ("m1", Json::str(m1))]),
            ));
        }
        if let Some(l) = self.multires {
            pairs.push(("multires", Json::num(l as f64)));
        }
        if let Some(m) = self.max_iter {
            pairs.push(("max_iter", Json::num(m as f64)));
        }
        if let Some(m) = self.max_krylov {
            pairs.push(("max_krylov", Json::num(m as f64)));
        }
        if let Some(b) = self.beta {
            pairs.push(("beta", Json::num(b)));
        }
        if let Some(g) = self.gamma {
            pairs.push(("gamma", Json::num(g)));
        }
        if let Some(g) = self.gtol {
            pairs.push(("gtol", Json::num(g)));
        }
        if let Some(c) = self.continuation {
            pairs.push(("continuation", Json::Bool(c)));
        }
        if let Some(i) = self.incompressible {
            pairs.push(("incompressible", Json::Bool(i)));
        }
        if let Some(v) = self.verbose {
            pairs.push(("verbose", Json::Bool(v)));
        }
        if let Some(t) = &self.dedup {
            pairs.push(("dedup", Json::str(t)));
        }
        if let Some(w) = &self.warm_start {
            pairs.push(("warm_start", Json::str(w)));
        }
        Json::object(pairs)
    }

    /// Type-strict wire decode: absent fields take defaults, but a field
    /// that is present with the wrong type is an error — a clinical daemon
    /// must not silently run a default job because `"n": "32"` was a
    /// string. Range checks happen in [`validate`](JobRequest::validate)
    /// (called at daemon admission), not here.
    pub fn from_json(j: &Json) -> Result<JobRequest> {
        if j.as_obj().is_none() {
            return Err(Error::wire(ErrorCode::BadRequest, "'job' must be an object"));
        }
        fn field<'a, T>(
            j: &'a Json,
            key: &str,
            conv: impl Fn(&'a Json) -> Option<T>,
            what: &str,
        ) -> Result<Option<T>> {
            match j.get(key) {
                None => Ok(None),
                Some(v) => conv(v).map(Some).ok_or_else(|| {
                    Error::wire(
                        ErrorCode::BadRequest,
                        format!("job field '{key}' must be {what}"),
                    )
                }),
            }
        }
        let d = JobRequest::default();
        let n_explicit = field(j, "n", Json::as_index, "a non-negative integer")?;
        // Absent source = synthetic (pre-data-plane clients keep working).
        // An uploaded source must name both volumes and pin `n` explicitly
        // so the daemon can validate content shapes at admission time.
        let source = match j.get("source") {
            None => JobSource::Synthetic,
            Some(s) => {
                // Non-empty enforced at decode (not just validate) so the
                // v1 error bytes for this path stay identical to the
                // pre-v2 decoder's.
                let id_of = |k: &str| -> Result<String> {
                    s.get(k)
                        .and_then(Json::as_str)
                        .filter(|v| !v.is_empty())
                        .map(str::to_string)
                        .ok_or_else(|| {
                            Error::wire(
                                ErrorCode::BadRequest,
                                format!("job field 'source' must carry a non-empty string '{k}'"),
                            )
                        })
                };
                if n_explicit.is_none() {
                    return Err(Error::wire(
                        ErrorCode::BadRequest,
                        "jobs with an uploaded source must specify 'n' explicitly",
                    ));
                }
                JobSource::Uploaded { m0: id_of("m0")?, m1: id_of("m1")? }
            }
        };
        Ok(JobRequest {
            subject: field(j, "subject", Json::as_str, "a string")?
                .map(str::to_string)
                .unwrap_or(d.subject),
            n: n_explicit.map(|x| x as usize).unwrap_or(d.n),
            variant: field(j, "variant", Json::as_str, "a string")?
                .map(str::to_string)
                .unwrap_or(d.variant),
            source,
            multires: field(j, "multires", Json::as_index, "a non-negative integer")?
                .map(|x| x as usize),
            // Absent precision defaults to full (pre-precision clients keep
            // working); a present but unknown value is an error.
            precision: match field(j, "precision", Json::as_str, "a string")? {
                Some(s) => Precision::parse(s).map_err(|_| {
                    Error::wire(ErrorCode::BadRequest, format!("unknown job precision '{s}'"))
                })?,
                None => d.precision,
            },
            // Absent algorithm defaults to GN-Krylov (pre-algorithm
            // clients keep working); unknown names are an error shared
            // verbatim with the config and CLI surfaces.
            algorithm: match field(j, "algorithm", Json::as_str, "a string")? {
                Some(s) => AlgorithmKind::parse(s)?,
                None => d.algorithm,
            },
            priority: match field(j, "priority", Json::as_str, "a string")? {
                Some(s) => Priority::parse(s)?,
                None => d.priority,
            },
            max_iter: field(j, "max_iter", Json::as_index, "a non-negative integer")?
                .map(|x| x as usize),
            max_krylov: field(j, "max_krylov", Json::as_index, "a non-negative integer")?
                .map(|x| x as usize),
            beta: field(j, "beta", Json::as_f64, "a number")?,
            gamma: field(j, "gamma", Json::as_f64, "a number")?,
            gtol: field(j, "gtol", Json::as_f64, "a number")?,
            continuation: field(j, "continuation", Json::as_bool, "a boolean")?,
            incompressible: field(j, "incompressible", Json::as_bool, "a boolean")?,
            verbose: field(j, "verbose", Json::as_bool, "a boolean")?,
            dedup: field(j, "dedup", Json::as_str, "a string")?.map(str::to_string),
            warm_start: field(j, "warm_start", Json::as_str, "a string")?.map(str::to_string),
        })
    }

    /// CLI decode: an optional `--config` file forms the base, explicit
    /// flags override it. Shared verbatim by the `register`, `batch` and
    /// `submit` subcommands so the flag surface cannot drift from the
    /// wire/config surfaces.
    pub fn from_args(args: &Args) -> Result<JobRequest> {
        let mut req = match args.get("config") {
            Some(path) if !path.is_empty() => {
                Config::load(std::path::Path::new(path))?.job_request()?
            }
            _ => JobRequest::default(),
        };
        if let Some(v) = args.get("subject") {
            req.subject = v.to_string();
        }
        req.n = args.get_usize("n", req.n)?;
        if let Some(v) = args.get("variant") {
            req.variant = v.to_string();
        }
        if let Some(v) = args.get("precision") {
            req.precision = Precision::parse(v)?;
        }
        // `--optimizer` is the legacy spelling of `--algorithm`; both are
        // ordinary flags (they override a config-file `algorithm =` key,
        // like every other flag here), with the new spelling winning when
        // both are given. Handled in this shared path so every subcommand
        // that advertises the alias honors it identically.
        if let Some(v) = args.get("algorithm").or_else(|| args.get("optimizer")) {
            req.algorithm = AlgorithmKind::parse(v)?;
        }
        let (m0, m1) = (args.get_or("m0", ""), args.get_or("m1", ""));
        match (m0.is_empty(), m1.is_empty()) {
            (true, true) => {}
            (false, false) => {
                // Mirror the wire decoder: an uploaded source needs an
                // explicit grid size (a default n cannot be shape-checked
                // against store contents).
                if args.get("n").is_none() {
                    return Err(Error::wire(
                        ErrorCode::BadRequest,
                        "jobs with an uploaded source must specify 'n' explicitly",
                    ));
                }
                req.source = JobSource::Uploaded { m0, m1 };
            }
            _ => {
                return Err(Error::wire(
                    ErrorCode::BadRequest,
                    "submit needs both --m0 and --m1 content ids (or neither)",
                ))
            }
        }
        if args.get("multires").is_some() {
            req.multires = Some(args.get_usize("multires", 1)?);
        }
        if let Some(v) = args.get("priority") {
            req.priority = Priority::parse(v)?;
        }
        if args.get("max-iter").is_some() {
            req.max_iter = Some(args.get_usize("max-iter", 0)?);
        }
        // Legacy first-order budget flag: `--max-fo-iter N` acts as
        // `--max-iter N` for gd/lbfgs requests when no explicit
        // `--max-iter` was given (absent both, `validate` applies the
        // shared FIRST_ORDER_DEFAULT_MAX_ITER on every surface).
        if req.max_iter.is_none()
            && req.algorithm != AlgorithmKind::GaussNewton
            && args.get("max-fo-iter").is_some()
        {
            req.max_iter = Some(args.get_usize("max-fo-iter", 0)?);
        }
        if args.get("beta").is_some() {
            req.beta = Some(args.get_f64("beta", 0.0)?);
        }
        if args.get("gamma").is_some() {
            req.gamma = Some(args.get_f64("gamma", 0.0)?);
        }
        if args.get("gtol").is_some() {
            req.gtol = Some(args.get_f64("gtol", 0.0)?);
        }
        if args.flag("no-continuation") {
            req.continuation = Some(false);
        }
        if args.flag("incompressible") {
            req.incompressible = Some(true);
        }
        if args.flag("verbose") {
            req.verbose = Some(true);
        }
        if let Some(v) = args.get("dedup") {
            if !v.is_empty() {
                req.dedup = Some(v.to_string());
            }
        }
        if let Some(v) = args.get("warm-start") {
            if !v.is_empty() {
                req.warm_start = Some(v.to_string());
            }
        }
        Ok(req)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::args::{flag, opt, OptSpec};

    fn cli(raw: &[&str]) -> Args {
        let specs: Vec<OptSpec> = vec![
            opt("subject", "", "na02"),
            opt("n", "", "16"),
            opt("variant", "", "opt-fd8-cubic"),
            opt("precision", "", "full"),
            opt("algorithm", "", "gn"),
            opt("optimizer", "", "gn"),
            opt("m0", "", ""),
            opt("m1", "", ""),
            opt("multires", "", "1"),
            opt("priority", "", "batch"),
            opt("max-iter", "", "50"),
            opt("max-fo-iter", "", "100"),
            opt("beta", "", "5e-4"),
            opt("gamma", "", "1e-4"),
            opt("gtol", "", "5e-2"),
            opt("config", "", ""),
            opt("dedup", "", ""),
            opt("warm-start", "", ""),
            flag("no-continuation", ""),
            flag("incompressible", ""),
            flag("verbose", ""),
        ];
        Args::parse(raw.iter().map(|s| s.to_string()).collect::<Vec<_>>(), &specs).unwrap()
    }

    #[test]
    fn defaults_and_validate_fill_reg_params() {
        let req = JobRequest::default();
        assert_eq!(req.subject, "na02");
        assert_eq!(req.n, 16);
        let p = req.validate().unwrap();
        assert_eq!(p, RegParams::default());
        let with = JobRequest { max_iter: Some(3), continuation: Some(false), ..req };
        let p2 = with.validate().unwrap();
        assert_eq!(p2.max_iter, 3);
        assert!(!p2.continuation);
        assert_eq!(p2.beta, 5e-4, "unset knobs keep paper defaults");
    }

    #[test]
    fn validate_rejects_out_of_range_jobs() {
        let bad_n = JobRequest { n: MAX_GRID_N + 1, ..Default::default() };
        assert!(bad_n.validate().unwrap_err().to_string().contains("out of range"));
        assert!(JobRequest { n: 0, ..Default::default() }.validate().is_err());
        assert!(JobRequest { multires: Some(0), ..Default::default() }.validate().is_err());
        assert!(JobRequest { multires: Some(7), ..Default::default() }.validate().is_err());
        // Multires pyramids are GN-only (baselines run single-grid).
        let gd_mr = JobRequest {
            algorithm: AlgorithmKind::GradientDescent,
            multires: Some(3),
            ..Default::default()
        };
        assert!(gd_mr.validate().unwrap_err().to_string().contains("requires algorithm 'gn'"));
        assert!(JobRequest { max_iter: Some(0), ..Default::default() }.validate().is_err());
        assert!(JobRequest { beta: Some(0.0), ..Default::default() }.validate().is_err());
        assert!(JobRequest { beta: Some(f64::NAN), ..Default::default() }.validate().is_err());
        assert!(JobRequest { gtol: Some(-1.0), ..Default::default() }.validate().is_err());
        assert!(JobRequest { subject: "".into(), ..Default::default() }.validate().is_err());
        let empty_id = JobRequest {
            source: JobSource::Uploaded { m0: "".into(), m1: "b".into() },
            ..Default::default()
        };
        assert!(empty_id.validate().is_err());
        // Every validate failure is a structured bad_request.
        assert_eq!(bad_n.validate().unwrap_err().code(), ErrorCode::BadRequest);
    }

    #[test]
    fn wire_decode_is_type_strict_not_range_strict() {
        // Types are enforced at decode...
        assert!(JobRequest::from_json(&Json::parse(r#"{"n":"32"}"#).unwrap()).is_err());
        assert!(JobRequest::from_json(&Json::parse(r#"{"max_iter":2.5}"#).unwrap()).is_err());
        assert!(JobRequest::from_json(&Json::parse(r#"{"multires":"3"}"#).unwrap()).is_err());
        assert!(JobRequest::from_json(&Json::parse(r#"{"precision":"half"}"#).unwrap()).is_err());
        assert!(JobRequest::from_json(&Json::parse(r#"{"algorithm":"newton"}"#).unwrap()).is_err());
        assert!(JobRequest::from_json(&Json::parse(r#"{"algorithm":5}"#).unwrap()).is_err());
        assert!(JobRequest::from_json(&Json::parse(r#"{"priority":"asap"}"#).unwrap()).is_err());
        assert!(JobRequest::from_json(&Json::parse("5").unwrap()).is_err());
        // ... ranges at validate (the single path shared by all surfaces).
        let decoded = JobRequest::from_json(&Json::parse(r#"{"n":5000}"#).unwrap()).unwrap();
        assert!(decoded.validate().is_err());
        // Uploaded sources must pin n at decode (a wire-encoding rule: the
        // default n cannot be shape-checked against store contents).
        assert!(JobRequest::from_json(
            &Json::parse(r#"{"source":{"m0":"a","m1":"b"}}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn wire_roundtrip_including_v2_knobs() {
        let req = JobRequest {
            subject: "na03".into(),
            n: 32,
            variant: "opt-fd8-linear".into(),
            precision: Precision::Mixed,
            multires: Some(3),
            priority: Priority::Emergency,
            max_iter: Some(7),
            max_krylov: Some(120),
            beta: Some(1e-3),
            gamma: Some(2e-4),
            gtol: Some(1e-1),
            continuation: Some(false),
            incompressible: Some(true),
            verbose: Some(false),
            ..Default::default()
        };
        assert_eq!(JobRequest::from_json(&req.to_json()).unwrap(), req);
        let fo = JobRequest { algorithm: AlgorithmKind::Lbfgs, ..Default::default() };
        assert_eq!(JobRequest::from_json(&fo.to_json()).unwrap(), fo);
        // Optional knobs stay off the wire when unset (v1 byte-compat) —
        // including the default algorithm.
        let line = JobRequest::default().to_json().render();
        for absent in [
            "max_krylov",
            "gamma",
            "incompressible",
            "verbose",
            "multires",
            "algorithm",
            "dedup",
            "warm_start",
        ] {
            assert!(!line.contains(absent), "{absent} leaked into {line}");
        }
    }

    #[test]
    fn dedup_token_roundtrips_and_validates() {
        let req = JobRequest { dedup: Some("client-42/attempt".into()), ..Default::default() };
        let back = JobRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back.dedup.as_deref(), Some("client-42/attempt"));
        assert!(back.validate().is_ok());
        // The CLI surface feeds the same field.
        let cli_req = JobRequest::from_args(&cli(&["--dedup", "tok-1"])).unwrap();
        assert_eq!(cli_req.dedup.as_deref(), Some("tok-1"));
        // Typing enforced at decode, length at validate.
        assert!(JobRequest::from_json(&Json::parse(r#"{"dedup":5}"#).unwrap()).is_err());
        let long = JobRequest { dedup: Some("x".repeat(MAX_DEDUP_LEN + 1)), ..Default::default() };
        assert!(long.validate().is_err());
        let empty = JobRequest { dedup: Some(String::new()), ..Default::default() };
        assert!(empty.validate().is_err());
    }

    #[test]
    fn warm_start_roundtrips_and_validates() {
        let req = JobRequest { warm_start: Some("deadbeef01".into()), ..Default::default() };
        let back = JobRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back.warm_start.as_deref(), Some("deadbeef01"));
        assert!(back.validate().is_ok());
        // The CLI surface feeds the same field.
        let cli_req = JobRequest::from_args(&cli(&["--warm-start", "deadbeef01"])).unwrap();
        assert_eq!(cli_req.warm_start.as_deref(), Some("deadbeef01"));
        // Typing enforced at decode, length at validate (shared budget
        // with dedup tokens).
        assert!(JobRequest::from_json(&Json::parse(r#"{"warm_start":7}"#).unwrap()).is_err());
        let long =
            JobRequest { warm_start: Some("x".repeat(MAX_DEDUP_LEN + 1)), ..Default::default() };
        assert!(long.validate().is_err());
        let empty = JobRequest { warm_start: Some(String::new()), ..Default::default() };
        assert!(empty.validate().is_err());
        // Warm-started jobs never fuse with cold ones, and only fuse with
        // each other under the same seed velocity.
        let cold = JobRequest::default();
        assert_ne!(cold.coalesce_key(), req.coalesce_key());
        let other = JobRequest { warm_start: Some("feedface02".into()), ..Default::default() };
        assert_ne!(req.coalesce_key(), other.coalesce_key());
    }

    #[test]
    fn coalesce_key_tracks_executable_selecting_fields() {
        let a = JobRequest { subject: "na02".into(), ..Default::default() };
        let b = JobRequest {
            subject: "na07".into(),
            priority: Priority::Urgent,
            dedup: Some("tok".into()),
            ..Default::default()
        };
        // Subject, priority and dedup never split a batch...
        assert_eq!(a.coalesce_key(), b.coalesce_key());
        // ... but every executable- or policy-selecting field does.
        for other in [
            JobRequest { n: 32, ..Default::default() },
            JobRequest { variant: "opt-fd8-linear".into(), ..Default::default() },
            JobRequest { precision: Precision::Mixed, ..Default::default() },
            JobRequest { algorithm: AlgorithmKind::GradientDescent, ..Default::default() },
            JobRequest { multires: Some(3), ..Default::default() },
            JobRequest { max_iter: Some(7), ..Default::default() },
            JobRequest { beta: Some(1e-3), ..Default::default() },
            JobRequest { continuation: Some(false), ..Default::default() },
        ] {
            assert_ne!(a.coalesce_key(), other.coalesce_key(), "{other:?}");
        }
        // multires absent and multires=1 select the same single-grid solve.
        let mr1 = JobRequest { multires: Some(1), ..Default::default() };
        assert_eq!(a.coalesce_key(), mr1.coalesce_key());
    }

    #[test]
    fn name_shows_policy_and_source() {
        let req = JobRequest {
            n: 32,
            source: JobSource::Uploaded { m0: "cafe01".into(), m1: "beef02".into() },
            multires: Some(3),
            ..Default::default()
        };
        assert_eq!(req.name(), "up:cafe01+beef02@32^3/opt-fd8-cubic+mr3");
        let mixed = JobRequest { precision: Precision::Mixed, ..Default::default() };
        assert_eq!(mixed.name(), "na02@16^3/opt-fd8-cubic+mixed");
        let mr1 = JobRequest { multires: Some(1), ..Default::default() };
        assert!(!mr1.name().contains("mr"), "{}", mr1.name());
        let gd = JobRequest { algorithm: AlgorithmKind::GradientDescent, ..Default::default() };
        assert_eq!(gd.name(), "na02@16^3/opt-fd8-cubic+gd");
    }

    #[test]
    fn first_order_budget_is_uniform_across_surfaces() {
        // Absent max_iter: GN keeps the paper's 50, first-order requests
        // get the shared 100-iteration budget — from validate(), so wire,
        // config, CLI and batch all agree.
        assert_eq!(JobRequest::default().validate().unwrap().max_iter, 50);
        let gd = JobRequest { algorithm: AlgorithmKind::GradientDescent, ..Default::default() };
        assert_eq!(gd.validate().unwrap().max_iter, FIRST_ORDER_DEFAULT_MAX_ITER);
        // An explicit budget always wins.
        let gd7 = JobRequest { max_iter: Some(7), ..gd };
        assert_eq!(gd7.validate().unwrap().max_iter, 7);
        // The legacy CLI flag feeds the same field (first-order only).
        let fo = JobRequest::from_args(&cli(&["--algorithm", "gd", "--max-fo-iter", "9"]))
            .unwrap();
        assert_eq!(fo.max_iter, Some(9));
        let gn = JobRequest::from_args(&cli(&["--max-fo-iter", "9"])).unwrap();
        assert_eq!(gn.max_iter, None, "GN requests ignore the fo flag");
    }

    #[test]
    fn optimizer_is_a_true_alias_for_algorithm() {
        // The legacy flag selects the algorithm through the shared path...
        let req = JobRequest::from_args(&cli(&["--optimizer", "gd"])).unwrap();
        assert_eq!(req.algorithm, AlgorithmKind::GradientDescent);
        // ... the new spelling wins when both are given...
        let both =
            JobRequest::from_args(&cli(&["--optimizer", "gd", "--algorithm", "lbfgs"])).unwrap();
        assert_eq!(both.algorithm, AlgorithmKind::Lbfgs);
        // ... and unknown names reject through the same parse.
        assert!(JobRequest::from_args(&cli(&["--optimizer", "newton"])).is_err());
    }

    /// The acceptance contract: wire, config and CLI all funnel through
    /// `validate()` — equivalent inputs produce identical `RegParams`,
    /// invalid inputs are rejected with identical errors.
    #[test]
    fn three_surfaces_share_one_validation_path() {
        let wire = JobRequest::from_json(
            &Json::parse(
                r#"{"subject":"na03","n":32,"variant":"opt-fd8-linear","precision":"mixed",
                    "multires":3,"beta":0.001,"max_iter":7,"continuation":false}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let cfg = Config::parse(
            "variant = opt-fd8-linear\nprecision = mixed\nmultires = 3\n\
             beta = 0.001\nmax_iter = 7\ncontinuation = false\n",
        )
        .unwrap()
        .job_request()
        .unwrap();
        let cli_req = JobRequest::from_args(&cli(&[
            "--subject",
            "na03",
            "--n",
            "32",
            "--variant",
            "opt-fd8-linear",
            "--precision",
            "mixed",
            "--multires",
            "3",
            "--beta",
            "0.001",
            "--max-iter",
            "7",
            "--no-continuation",
        ]))
        .unwrap();
        let pw = wire.validate().unwrap();
        let pc = cfg.validate().unwrap();
        let pa = cli_req.validate().unwrap();
        assert_eq!(pw, pc, "wire and config must materialize identical params");
        assert_eq!(pw, pa, "wire and CLI must materialize identical params");

        // Identical rejection: an out-of-range multires fails with the
        // same message on every surface, because it is the same check.
        let e_wire = JobRequest::from_json(&Json::parse(r#"{"multires":7}"#).unwrap())
            .unwrap()
            .validate()
            .unwrap_err();
        let e_cfg = Config::parse("multires = 7\n").unwrap().reg_params().unwrap_err();
        let e_cli = JobRequest::from_args(&cli(&["--multires", "7"]))
            .unwrap()
            .validate()
            .unwrap_err();
        assert_eq!(e_wire.to_string(), e_cfg.to_string());
        assert_eq!(e_wire.to_string(), e_cli.to_string());
        assert_eq!(e_wire.code(), ErrorCode::BadRequest);

        // Unknown precision rejects on all three surfaces at decode.
        assert!(JobRequest::from_json(&Json::parse(r#"{"precision":"fp8"}"#).unwrap()).is_err());
        assert!(Config::parse("precision = fp8\n").unwrap().job_request().is_err());
        assert!(JobRequest::from_args(&cli(&["--precision", "fp8"])).is_err());

        // The algorithm field follows the same contract: one accepted
        // spelling set, identical errors on every surface.
        let w = JobRequest::from_json(&Json::parse(r#"{"algorithm":"lbfgs"}"#).unwrap()).unwrap();
        let c = Config::parse("algorithm = lbfgs\n").unwrap().job_request().unwrap();
        let a = JobRequest::from_args(&cli(&["--algorithm", "lbfgs"])).unwrap();
        assert_eq!(w.validate().unwrap().algorithm, AlgorithmKind::Lbfgs);
        assert_eq!(w, c);
        assert_eq!(w, a);
        let ew = JobRequest::from_json(&Json::parse(r#"{"algorithm":"newton"}"#).unwrap())
            .unwrap_err();
        let ec = Config::parse("algorithm = newton\n").unwrap().job_request().unwrap_err();
        let ea = JobRequest::from_args(&cli(&["--algorithm", "newton"])).unwrap_err();
        assert_eq!(ew.to_string(), ec.to_string());
        assert_eq!(ew.to_string(), ea.to_string());
        assert_eq!(ew.code(), ErrorCode::BadRequest);
    }

    #[test]
    fn cli_flags_build_sources_and_reject_half_pairs() {
        let req = JobRequest::from_args(&cli(&["--m0", "aa", "--m1", "bb", "--n", "8"])).unwrap();
        assert_eq!(req.source, JobSource::Uploaded { m0: "aa".into(), m1: "bb".into() });
        assert_eq!(req.n, 8);
        let err = JobRequest::from_args(&cli(&["--m0", "aa"])).unwrap_err();
        assert!(err.to_string().contains("both --m0 and --m1"), "{err}");
        assert_eq!(err.code(), ErrorCode::BadRequest);
        // Like the wire surface, an uploaded source must pin n explicitly
        // (the default 16 cannot be shape-checked against the store).
        let err = JobRequest::from_args(&cli(&["--m0", "aa", "--m1", "bb"])).unwrap_err();
        assert!(err.to_string().contains("specify 'n' explicitly"), "{err}");
    }
}
