//! Blocked vector algebra for the Krylov hot path.
//!
//! All reductions accumulate in f64 per 4-lane partial sums: the PCG dot
//! products at 64^3 run over 786k f32 values and naive f32 accumulation
//! costs ~3 digits. The 4-way unrolled loops let LLVM vectorize cleanly
//! (verified via `bench_fieldops`; see EXPERIMENTS.md section Perf).

/// y += a * x  (slices must have equal length).
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// y = x + a * y (like BLAS xpay, used in PCG's p-update).
pub fn xpay(x: &[f32], a: f32, y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = xi + a * *yi;
    }
}

/// out = x + a*y (allocation-free ternary update).
pub fn add_scaled(x: &[f32], a: f32, y: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    assert_eq!(x.len(), out.len());
    for ((o, xi), yi) in out.iter_mut().zip(x).zip(y) {
        *o = xi + a * yi;
    }
}

/// Dot product with 4-lane f64 accumulation.
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut acc = [0.0f64; 4];
    let chunks = x.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += x[i] as f64 * y[i] as f64;
        acc[1] += x[i + 1] as f64 * y[i + 1] as f64;
        acc[2] += x[i + 2] as f64 * y[i + 2] as f64;
        acc[3] += x[i + 3] as f64 * y[i + 3] as f64;
    }
    let mut tail = 0.0f64;
    for i in chunks * 4..x.len() {
        tail += x[i] as f64 * y[i] as f64;
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

/// Euclidean norm.
pub fn norm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// Fused axpy + dot of the result with itself: r -= a*q; returns <r, r>.
/// Saves one full pass over r in the PCG inner loop.
pub fn axpy_dot_self(a: f32, q: &[f32], r: &mut [f32]) -> f64 {
    assert_eq!(q.len(), r.len());
    let mut acc = [0.0f64; 4];
    let chunks = r.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        for l in 0..4 {
            let v = r[i + l] + a * q[i + l];
            r[i + l] = v;
            acc[l] += v as f64 * v as f64;
        }
    }
    let mut tail = 0.0f64;
    for i in chunks * 4..r.len() {
        let v = r[i] + a * q[i];
        r[i] = v;
        tail += v as f64 * v as f64;
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

/// x *= a.
pub fn scale(a: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// Sum of squared differences (mismatch numerator).
pub fn sumsq_diff(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        let d = (x - y) as f64;
        acc += d * d;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, Config};

    #[test]
    fn axpy_basics() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn xpay_basics() {
        let x = [1.0f32, 1.0];
        let mut y = [2.0f32, 4.0];
        xpay(&x, 0.5, &mut y);
        assert_eq!(y, [2.0, 3.0]);
    }

    #[test]
    fn dot_matches_naive() {
        prop::check_msg(
            Config { cases: 48, seed: 30 },
            |r| {
                let len = 1 + r.below(257) as usize;
                (prop::vec_f32(r, len, -2.0, 2.0), prop::vec_f32(r, len, -2.0, 2.0))
            },
            |(x, y)| {
                let naive: f64 = x.iter().zip(y).map(|(&a, &b)| a as f64 * b as f64).sum();
                let got = dot(x, y);
                if (got - naive).abs() > 1e-9 * (1.0 + naive.abs()) {
                    return Err(format!("{got} vs {naive}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fused_axpy_dot_matches_separate() {
        prop::check_msg(
            Config { cases: 48, seed: 31 },
            |r| {
                let len = 1 + r.below(130) as usize;
                (
                    r.uniform_f32(-1.0, 1.0),
                    prop::vec_f32(r, len, -2.0, 2.0),
                    prop::vec_f32(r, len, -2.0, 2.0),
                )
            },
            |(a, q, r0)| {
                let mut r1 = r0.clone();
                let rr = axpy_dot_self(*a, q, &mut r1);
                let mut r2 = r0.clone();
                axpy(*a, q, &mut r2);
                let want = dot(&r2, &r2);
                for (u, v) in r1.iter().zip(&r2) {
                    if (u - v).abs() > 1e-6 {
                        return Err(format!("vector mismatch {u} vs {v}"));
                    }
                }
                if (rr - want).abs() > 1e-7 * (1.0 + want.abs()) {
                    return Err(format!("dot mismatch {rr} vs {want}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn dot_accumulates_in_f64() {
        // Summing a million 0.1f32's: naive f32 accumulation drifts by
        // ~0.03%; the f64-accumulating dot must stay exact to ~1e-9.
        let x = vec![1.0f32; 1 << 20];
        let y = vec![0.1f32; 1 << 20];
        let want = (0.1f32 as f64) * (1 << 20) as f64;
        let got = dot(&x, &y);
        assert!((got - want).abs() / want < 1e-9, "{got} vs {want}");
        let f32_sim = y.iter().copied().sum::<f32>() as f64;
        assert!(
            (f32_sim - want).abs() / want > 1e-6,
            "f32 accumulation unexpectedly exact; test vacuous"
        );
    }

    #[test]
    fn norm_and_sumsq() {
        let a = [3.0f32, 4.0];
        assert!((norm2(&a) - 5.0).abs() < 1e-12);
        let b = [0.0f32, 0.0];
        assert!((sumsq_diff(&a, &b) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn add_scaled_into() {
        let x = [1.0f32, 2.0];
        let y = [10.0f32, 20.0];
        let mut out = [0.0f32; 2];
        add_scaled(&x, 0.1, &y, &mut out);
        assert_eq!(out, [2.0, 4.0]);
    }
}
