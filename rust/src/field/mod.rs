//! 3-D field containers and the PCG hot-path vector algebra.
//!
//! The Gauss-Newton-Krylov outer loops live in Rust and operate on velocity
//! fields of 3*N^3 f32 values; the axpy/dot/norm kernels here are the L3
//! analog of PETSc's Vec operations in CLAIRE. They are written as blocked
//! loops with f64 accumulators (dot products over 50M elements in f32 lose
//! digits otherwise) and are benchmarked in `bench_fieldops`.

pub mod ops;

use crate::error::{Error, Result};

/// A scalar field on an N^3 periodic grid, row-major `[x1, x2, x3]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Field3 {
    pub n: usize,
    pub data: Vec<f32>,
}

impl Field3 {
    pub fn zeros(n: usize) -> Field3 {
        Field3 { n, data: vec![0.0; n * n * n] }
    }

    pub fn from_vec(n: usize, data: Vec<f32>) -> Result<Field3> {
        if data.len() != n * n * n {
            return Err(Error::ShapeMismatch {
                what: "Field3".into(),
                expected: n * n * n,
                got: data.len(),
            });
        }
        Ok(Field3 { n, data })
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize, k: usize) -> f32 {
        self.data[(i * self.n + j) * self.n + k]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, k: usize, v: f32) {
        self.data[(i * self.n + j) * self.n + k] = v;
    }

    /// Grid spacing h = 2*pi / n.
    pub fn h(&self) -> f64 {
        2.0 * std::f64::consts::PI / self.n as f64
    }
}

/// A velocity (vector) field: 3 components stored contiguously
/// `[3, N, N, N]`, matching the artifact input layout.
#[derive(Clone, Debug, PartialEq)]
pub struct VecField3 {
    pub n: usize,
    pub data: Vec<f32>,
}

impl VecField3 {
    pub fn zeros(n: usize) -> VecField3 {
        VecField3 { n, data: vec![0.0; 3 * n * n * n] }
    }

    pub fn from_vec(n: usize, data: Vec<f32>) -> Result<VecField3> {
        if data.len() != 3 * n * n * n {
            return Err(Error::ShapeMismatch {
                what: "VecField3".into(),
                expected: 3 * n * n * n,
                got: data.len(),
            });
        }
        Ok(VecField3 { n, data })
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// View of one component.
    pub fn comp(&self, a: usize) -> &[f32] {
        let m = self.n * self.n * self.n;
        &self.data[a * m..(a + 1) * m]
    }

    pub fn comp_mut(&mut self, a: usize) -> &mut [f32] {
        let m = self.n * self.n * self.n;
        &mut self.data[a * m..(a + 1) * m]
    }

    /// Pointwise max |v| over the grid (CFL diagnostics).
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |acc, &x| acc.max(x.abs()))
    }

    pub fn h(&self) -> f64 {
        2.0 * std::f64::consts::PI / self.n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_shape_checked() {
        assert!(Field3::from_vec(4, vec![0.0; 64]).is_ok());
        assert!(Field3::from_vec(4, vec![0.0; 63]).is_err());
        assert!(VecField3::from_vec(4, vec![0.0; 192]).is_ok());
        assert!(VecField3::from_vec(4, vec![0.0; 64]).is_err());
    }

    #[test]
    fn indexing_roundtrip() {
        let mut f = Field3::zeros(4);
        f.set(1, 2, 3, 9.0);
        assert_eq!(f.at(1, 2, 3), 9.0);
        assert_eq!(f.data[(1 * 4 + 2) * 4 + 3], 9.0);
    }

    #[test]
    fn components_disjoint() {
        let mut v = VecField3::zeros(2);
        v.comp_mut(1)[0] = 5.0;
        assert_eq!(v.comp(0).iter().sum::<f32>(), 0.0);
        assert_eq!(v.comp(1)[0], 5.0);
        assert_eq!(v.comp(2).iter().sum::<f32>(), 0.0);
    }

    #[test]
    fn max_abs() {
        let mut v = VecField3::zeros(2);
        v.data[5] = -3.0;
        v.data[10] = 2.0;
        assert_eq!(v.max_abs(), 3.0);
    }
}
