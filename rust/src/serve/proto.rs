//! Wire protocol for the registration daemon: newline-delimited JSON.
//!
//! Every request and every response is one JSON object on one line,
//! built on `util/json.rs` (the offline image has no serde). Responses
//! always carry an `"ok"` boolean; errors carry `"error"`.
//!
//! ## Protocol versions
//!
//! Two protocol levels share this grammar:
//!
//! * **v1** — strictly synchronous request/response; errors are an opaque
//!   string. A connection that never sends `hello` speaks exact v1
//!   semantics, byte-for-byte what the pre-v2 daemon produced.
//! * **v2** — negotiated by the `hello` verb. Adds client-chosen `seq`
//!   request correlation (echoed in every response), server-pushed job
//!   events via `watch`, one-line many-job `submit_batch` with per-job
//!   admission verdicts, structured errors (`code` + `retryable`
//!   from the [`ErrorCode`] registry), and an enriched `ping` that
//!   answers with node identity + queue load (the `probe` feature — the
//!   health probe the fleet router polls).
//!
//! Requests:
//! ```text
//! {"cmd":"ping"}
//! {"cmd":"hello","proto":2}                      negotiate v2
//! {"cmd":"upload","n":16,"data":"<base64 LE f32 samples>"}
//! {"cmd":"submit","job":{"subject":"na02","n":16,"variant":"opt-fd8-cubic",
//!                        "priority":"emergency","max_iter":50}}
//! {"cmd":"submit","job":{"n":32,"source":{"m0":"<id>","m1":"<id>"},
//!                        "multires":3}}
//! {"cmd":"submit_batch","jobs":[{...},{...}]}    v2 only
//! {"cmd":"watch"}                                v2 only: push job events
//! {"cmd":"reduce","jobs":[3,4,5],"scale":-0.5,   v2 only: server-side mean
//!  "apply":"<id>","ref":"<id>","pin":true}       of retained job outputs
//! {"cmd":"reduce","ids":["<id>","<id>"]}         v2 only: mean of volumes
//! {"cmd":"status"}              all jobs
//! {"cmd":"status","id":3}       one job
//! {"cmd":"cancel","id":3}
//! {"cmd":"stats"}
//! {"cmd":"shutdown","drain":true}
//! ```
//! In a v2 session any request may carry `"seq": <u64>`; the daemon echoes
//! it in the response (and in every event of a `watch` stream), so a
//! client may pipeline requests on one connection and correlate answers.
//!
//! Watch events (one per `queued → running → done|failed|cancelled`
//! transition — cancelling a *running* job interrupts it at the next
//! solver iteration boundary — plus one `progress` event per accepted
//! solver iteration, pushed asynchronously on the watching connection):
//! ```text
//! {"event":"job","id":7,"name":"na02@16^3/opt-fd8-cubic","state":"running","seq":4}
//! {"event":"progress","id":7,"name":"...","iter":3,"level":0,"beta":0.0005,
//!  "j":0.012,"grad_rel":0.31,"alpha":1.0,"seq":4}
//! {"event":"job","id":7,"name":"...","state":"done","wall_s":1.25,"seq":4}
//! {"event":"lagged","seq":4}        terminal: subscriber fell behind
//! ```
//!
//! `upload` is the data plane: the volume payload is the `data/io.rs`
//! little-endian f32 byte format, base64-wrapped to stay within the
//! one-line NDJSON discipline, landing in the daemon's content-addressed
//! store (`serve/store.rs`). `submit` then references content ids via
//! `source`, and `multires` selects coarse-to-fine grid continuation.
//!
//! Protocol contract for encoders: an `upload` line must mention its
//! `"cmd":"upload"` key within the first 4096 bytes (natural for every
//! key order except payload-first; this crate's encoder emits `cmd`
//! before `data`). The daemon reads request lines under a small cap and
//! only escalates to the volume-sized bound when that prefix identifies
//! an upload — a payload-first encoding is cut off at the small cap.

use crate::data::io::{f32s_from_le_bytes, f32s_to_le_bytes};
use crate::error::{Error, ErrorCode, Result};
use crate::serve::scheduler::{JobId, JobState, JobView, NodeStats, ServeStats};
use crate::serve::store::StoreStats;
use crate::util::base64;
use crate::util::json::Json;

// The job-description surface is canonical in `crate::request`; the wire
// module re-exports it so protocol users keep one import path. `JobSpec`
// is the historical wire name for what is now the canonical request type.
pub use crate::request::{JobRequest, JobSource, Priority, MAX_GRID_N, MAX_MULTIRES_LEVELS};
pub type JobSpec = JobRequest;

/// Protocol level this daemon speaks when negotiated (`hello`).
pub const PROTO_VERSION: u64 = 2;

/// Feature tags advertised by `hello` — stable strings, clients gate on
/// membership rather than the proto number where possible. `probe` marks
/// a daemon whose v2 `ping` answers with node identity + load (the cheap
/// health probe the fleet router polls); `reduce` marks one that averages
/// retained job outputs / stored volumes server-side (template building).
pub const PROTO_V2_FEATURES: [&str; 6] =
    ["seq", "watch", "submit_batch", "structured_errors", "probe", "reduce"];

/// Hard cap on the job count of one `submit_batch` line (the 4 MiB line
/// cap bounds it physically; this bounds it semantically).
pub const MAX_BATCH_JOBS: usize = 1024;

/// Hard cap on one non-upload protocol line, both directions. Requests
/// are tiny; responses are bounded by the scheduler's record retention.
/// The cap keeps one misbehaving peer from growing an unbounded buffer.
pub const MAX_LINE_BYTES: usize = 4 * 1024 * 1024;

/// Hard cap on one *upload* request line on the daemon side: sized so a
/// 256^3 volume (the paper's largest run; 64 MiB raw, ~86 MiB base64)
/// fits on one line, still bounding what a misbehaving peer can make the
/// daemon buffer. Only lines that look like an `upload` request escalate
/// to this bound (see [`read_request_line_bounded`]); everything else
/// stays under `MAX_LINE_BYTES`, so a non-upload flood cannot pin 96 MiB
/// per connection. Larger grids would need a chunked upload extension.
pub const MAX_UPLOAD_LINE_BYTES: usize = 96 * 1024 * 1024;

/// Largest grid size a one-line `upload` can carry: a 256^3 payload fits
/// `MAX_UPLOAD_LINE_BYTES`; anything larger would die at the line cap, so
/// it is rejected up front with a useful error instead of a connection
/// drop. (`MAX_GRID_N` still bounds *submit* specs — in-process stores
/// fed by embedders are not line-limited.)
pub const MAX_UPLOAD_GRID_N: usize = 256;

/// Read one `\n`-terminated line of at most `cap` bytes. `Ok(None)` on
/// clean EOF; a line exceeding the cap is an `InvalidData` IO error (the
/// caller should answer with a protocol error and drop the connection).
pub fn read_line_bounded<R: std::io::BufRead>(
    r: &mut R,
    cap: usize,
) -> std::io::Result<Option<String>> {
    // Equal tiers = a single flat cap (escalation can never trigger).
    read_request_line_bounded(r, cap, cap)
}

/// Does a buffered request prefix look like an `upload` line? Checked
/// only when a line outgrows the small cap, to decide whether the large
/// (volume-sized) bound applies. Deliberately lenient — any mention of
/// `upload` in the first 4096 bytes qualifies; a non-upload line that
/// sneaks past still fails `Request::parse`, it just got to waste a
/// bigger buffer first. The flip side is a protocol contract (see the
/// module docs): an upload line must mention its verb near the start —
/// an encoder that buries `"cmd":"upload"` megabytes deep behind the
/// payload is cut off at the small cap.
fn looks_like_upload(buf: &[u8]) -> bool {
    let head = &buf[..buf.len().min(4096)];
    head.windows(6).any(|w| w == b"upload")
}

/// Read one request line under a two-tier cap: bounded by `small_cap`
/// unless the buffered prefix looks like an `upload` request (the only
/// verb with a large payload), which escalates the bound to `large_cap`.
/// A non-upload flood is cut off at the small bound; one-line volume
/// uploads still fit.
pub fn read_request_line_bounded<R: std::io::BufRead>(
    r: &mut R,
    small_cap: usize,
    large_cap: usize,
) -> std::io::Result<Option<String>> {
    let mut cap = small_cap.min(large_cap);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let (done, used) = {
            let available = r.fill_buf()?;
            if available.is_empty() {
                (true, 0)
            } else if let Some(pos) = available.iter().position(|&b| b == b'\n') {
                buf.extend_from_slice(&available[..pos]);
                (true, pos + 1)
            } else {
                buf.extend_from_slice(available);
                (false, available.len())
            }
        };
        r.consume(used);
        if buf.len() > cap && cap < large_cap && looks_like_upload(&buf) {
            cap = large_cap;
        }
        if buf.len() > cap {
            // Not re-checked after a *successful* escalation unless one
            // fill chunk jumped straight past large_cap too.
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("protocol line exceeds {cap} bytes"),
            ));
        }
        if done {
            return Ok(if buf.is_empty() && used == 0 {
                None
            } else {
                Some(String::from_utf8_lossy(&buf).into_owned())
            });
        }
    }
}

/// Which retained job output a `reduce` averages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceField {
    /// The stationary velocity fields (log-domain mean — the default).
    Velocity,
    /// The warped subject images (fallback when no velocities were
    /// retained, e.g. stub executors).
    Warped,
}

impl ReduceField {
    pub fn as_str(&self) -> &'static str {
        match self {
            ReduceField::Velocity => "velocity",
            ReduceField::Warped => "warped",
        }
    }

    pub fn parse(s: &str) -> Result<ReduceField> {
        match s {
            "velocity" => Ok(ReduceField::Velocity),
            "warped" => Ok(ReduceField::Warped),
            other => Err(Error::wire(
                ErrorCode::BadRequest,
                format!("unknown reduce field '{other}'"),
            )),
        }
    }
}

/// A `reduce` request: average job-output fields (or stored volumes)
/// server-side, land the result in the content-addressed store, and
/// answer with its content id — volumes never round-trip through the
/// client. Exactly one of `jobs` / `ids` must be non-empty.
#[derive(Clone, Debug, PartialEq)]
pub struct ReduceRequest {
    /// Done jobs whose retained `field` outputs to average.
    pub jobs: Vec<JobId>,
    /// Stored scalar volumes to average directly — the round-0 bootstrap
    /// (the initial template is the plain mean of the subjects).
    pub ids: Vec<String>,
    /// Which retained output to reduce (`jobs` mode). Wire field
    /// `"field"`; absent = velocity.
    pub field: ReduceField,
    /// Scale applied to the mean velocity before exponentiation (velocity
    /// mode with `apply`). Wire field `"scale"`; absent = 1.
    pub scale: Option<f64>,
    /// Content id of a template volume to warp through
    /// `exp(scale * mean)` server-side (velocity mode): the response then
    /// names the *warped template*, not the raw mean velocity.
    pub apply: Option<String>,
    /// Content id of the previous template: the response carries
    /// `delta_rel`, the relative L2 change against it — the driver's
    /// convergence signal without downloading either volume.
    pub ref_id: Option<String>,
    /// Pin the reduced result against LRU eviction (the driver unpins the
    /// previous round's template via `unpin`).
    pub pin: bool,
    /// Content id to unpin after the reduce succeeds.
    pub unpin: Option<String>,
}

impl Default for ReduceRequest {
    fn default() -> Self {
        ReduceRequest {
            jobs: Vec::new(),
            ids: Vec::new(),
            field: ReduceField::Velocity,
            scale: None,
            apply: None,
            ref_id: None,
            pin: false,
            unpin: None,
        }
    }
}

/// One decoded client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Ping,
    /// Negotiate protocol v2 (see module docs). `proto` is the highest
    /// level the client speaks; the daemon answers with the level the
    /// session will use.
    Hello { proto: u64 },
    /// Ship one volume into the daemon's content-addressed store. `data`
    /// holds the n^3 samples; on the wire they travel as base64 of the
    /// `data/io.rs` little-endian f32 byte format.
    Upload { n: usize, data: Vec<f32> },
    Submit(JobRequest),
    /// v2: many jobs on one line, answered with per-job admission
    /// verdicts — a 500-job clinical batch costs one round trip.
    SubmitBatch(Vec<JobRequest>),
    /// `None` lists every job the daemon knows about.
    Status(Option<JobId>),
    Cancel(JobId),
    /// v2: subscribe this connection to server-pushed job events.
    Watch,
    /// v2: average retained job outputs or stored volumes server-side
    /// (the template-building reduction; see [`ReduceRequest`]).
    Reduce(ReduceRequest),
    Stats,
    Shutdown { drain: bool },
}

/// Encode `n`/`data` as an upload request line *without* an owned copy of
/// the sample vector: the little-endian byte image is the only transient
/// allocation besides the line itself (base64 is appended in place).
/// Byte-identical to `Request::Upload { .. }.to_line()` — pinned by test.
pub fn upload_line(n: usize, data: &[f32], seq: Option<u64>) -> String {
    let bytes = f32s_to_le_bytes(data);
    let mut line = String::with_capacity(bytes.len() * 4 / 3 + 64);
    line.push_str("{\"cmd\":\"upload\",\"data\":\"");
    base64::encode_into(&bytes, &mut line);
    drop(bytes);
    line.push_str("\",\"n\":");
    line.push_str(&n.to_string());
    if let Some(s) = seq {
        line.push_str(",\"seq\":");
        line.push_str(&s.to_string());
    }
    line.push('}');
    line
}

impl Request {
    fn to_json(&self) -> Json {
        match self {
            Request::Ping => Json::object([("cmd", Json::str("ping"))]),
            Request::Hello { proto } => Json::object([
                ("cmd", Json::str("hello")),
                ("proto", Json::num(*proto as f64)),
            ]),
            Request::Upload { n, data } => Json::object([
                ("cmd", Json::str("upload")),
                ("n", Json::num(*n as f64)),
                ("data", Json::str(base64::encode(&f32s_to_le_bytes(data)))),
            ]),
            Request::Submit(spec) => {
                Json::object([("cmd", Json::str("submit")), ("job", spec.to_json())])
            }
            Request::SubmitBatch(specs) => Json::object([
                ("cmd", Json::str("submit_batch")),
                ("jobs", Json::Arr(specs.iter().map(JobRequest::to_json).collect())),
            ]),
            Request::Status(None) => Json::object([("cmd", Json::str("status"))]),
            Request::Status(Some(id)) => {
                Json::object([("cmd", Json::str("status")), ("id", Json::num(*id as f64))])
            }
            Request::Cancel(id) => {
                Json::object([("cmd", Json::str("cancel")), ("id", Json::num(*id as f64))])
            }
            Request::Watch => Json::object([("cmd", Json::str("watch"))]),
            Request::Reduce(r) => {
                // Optional knobs ride only when set, like every other v2
                // field on this wire.
                let mut pairs = vec![("cmd", Json::str("reduce"))];
                if !r.jobs.is_empty() {
                    pairs.push((
                        "jobs",
                        Json::Arr(r.jobs.iter().map(|&i| Json::num(i as f64)).collect()),
                    ));
                }
                if !r.ids.is_empty() {
                    pairs.push(("ids", Json::Arr(r.ids.iter().map(|s| Json::str(s)).collect())));
                }
                if r.field != ReduceField::Velocity {
                    pairs.push(("field", Json::str(r.field.as_str())));
                }
                if let Some(s) = r.scale {
                    pairs.push(("scale", Json::num(s)));
                }
                if let Some(a) = &r.apply {
                    pairs.push(("apply", Json::str(a)));
                }
                if let Some(rf) = &r.ref_id {
                    pairs.push(("ref", Json::str(rf)));
                }
                if r.pin {
                    pairs.push(("pin", Json::Bool(true)));
                }
                if let Some(u) = &r.unpin {
                    pairs.push(("unpin", Json::str(u)));
                }
                Json::object(pairs)
            }
            Request::Stats => Json::object([("cmd", Json::str("stats"))]),
            Request::Shutdown { drain } => {
                Json::object([("cmd", Json::str("shutdown")), ("drain", Json::Bool(*drain))])
            }
        }
    }

    pub fn to_line(&self) -> String {
        self.to_json().render()
    }

    /// Encode with an optional v2 correlation `seq`.
    pub fn to_line_with_seq(&self, seq: Option<u64>) -> String {
        let mut j = self.to_json();
        if let (Some(s), Json::Obj(m)) = (seq, &mut j) {
            m.insert("seq".into(), Json::num(s as f64));
        }
        j.render()
    }

    /// Decode one request line plus its v2 correlation envelope. A line
    /// that is not JSON yields `(None, Err(..))`; a JSON line with a bad
    /// request body still surfaces its `seq` so the error response can be
    /// correlated. A `seq` that is not a non-negative integer is ignored.
    pub fn parse_line(line: &str) -> (Option<u64>, Result<Request>) {
        match Json::parse(line.trim()) {
            Err(e) => (None, Err(e)),
            Ok(j) => {
                let seq = j.get("seq").and_then(Json::as_index);
                (seq, Request::from_json(&j))
            }
        }
    }

    pub fn parse(line: &str) -> Result<Request> {
        Request::from_json(&Json::parse(line.trim())?)
    }

    pub fn from_json(j: &Json) -> Result<Request> {
        let bad = |msg: String| Error::wire(ErrorCode::BadRequest, msg);
        let cmd = j
            .get("cmd")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("request missing 'cmd'".into()))?;
        let id_of = |j: &Json| -> Result<JobId> {
            j.get("id")
                .and_then(Json::as_index)
                .ok_or_else(|| bad(format!("'{cmd}' requires an integer 'id'")))
        };
        match cmd {
            "ping" => Ok(Request::Ping),
            "hello" => {
                let proto = match j.get("proto") {
                    None => PROTO_VERSION,
                    Some(v) => match v.as_index() {
                        Some(p) if p >= 1 => p,
                        _ => {
                            return Err(bad(
                                "hello field 'proto' must be an integer >= 1".into(),
                            ))
                        }
                    },
                };
                Ok(Request::Hello { proto })
            }
            "upload" => {
                let n = match j.get("n").and_then(Json::as_index) {
                    Some(x) if (1..=MAX_UPLOAD_GRID_N as u64).contains(&x) => x as usize,
                    Some(x) => {
                        return Err(bad(format!(
                            "upload field 'n' = {x} out of range (1..={MAX_UPLOAD_GRID_N}; \
                             larger volumes need a chunked upload, not yet supported)"
                        )))
                    }
                    None => return Err(bad("upload requires an integer 'n'".into())),
                };
                let b64 = j
                    .get("data")
                    .and_then(Json::as_str)
                    .ok_or_else(|| bad("upload requires a base64 string 'data'".into()))?;
                let bytes =
                    base64::decode(b64).map_err(|e| bad(format!("upload payload: {e}")))?;
                let expected = n * n * n * 4;
                if bytes.len() != expected {
                    return Err(bad(format!(
                        "upload payload is {} bytes, expected {expected} ({n}^3 f32 samples)",
                        bytes.len()
                    )));
                }
                let data = f32s_from_le_bytes(&bytes)?;
                // Reject non-finite voxels at the protocol boundary: a NaN
                // smuggled into m0/m1 would poison every norm and line
                // search of the solve and surface as a cryptic failure.
                if let Some(i) = data.iter().position(|x| !x.is_finite()) {
                    return Err(bad(format!(
                        "upload payload contains a non-finite sample at index {i}"
                    )));
                }
                Ok(Request::Upload { n, data })
            }
            "submit" => {
                let job = j
                    .get("job")
                    .ok_or_else(|| bad("submit requires a 'job' object".into()))?;
                Ok(Request::Submit(JobRequest::from_json(job)?))
            }
            "submit_batch" => {
                let jobs = j
                    .get("jobs")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| bad("submit_batch requires a 'jobs' array".into()))?;
                if jobs.is_empty() || jobs.len() > MAX_BATCH_JOBS {
                    return Err(bad(format!(
                        "submit_batch carries {} jobs, expected 1..={MAX_BATCH_JOBS}",
                        jobs.len()
                    )));
                }
                let specs = jobs
                    .iter()
                    .enumerate()
                    .map(|(i, job)| {
                        JobRequest::from_json(job).map_err(|e| {
                            Error::wire(ErrorCode::BadRequest, format!("jobs[{i}]: {e}"))
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(Request::SubmitBatch(specs))
            }
            // A present-but-malformed id must error, not degrade to "all".
            "status" => match j.get("id") {
                None => Ok(Request::Status(None)),
                Some(_) => Ok(Request::Status(Some(id_of(j)?))),
            },
            "cancel" => Ok(Request::Cancel(id_of(j)?)),
            "watch" => Ok(Request::Watch),
            "reduce" => {
                let jobs: Vec<JobId> = match j.get("jobs") {
                    None => Vec::new(),
                    Some(v) => v
                        .as_arr()
                        .ok_or_else(|| bad("reduce field 'jobs' must be an array".into()))?
                        .iter()
                        .map(|x| {
                            x.as_index().ok_or_else(|| {
                                bad("reduce field 'jobs' must hold integer job ids".into())
                            })
                        })
                        .collect::<Result<_>>()?,
                };
                let ids: Vec<String> = match j.get("ids") {
                    None => Vec::new(),
                    Some(v) => v
                        .as_arr()
                        .ok_or_else(|| bad("reduce field 'ids' must be an array".into()))?
                        .iter()
                        .map(|x| {
                            x.as_str().map(str::to_string).ok_or_else(|| {
                                bad("reduce field 'ids' must hold content-id strings".into())
                            })
                        })
                        .collect::<Result<_>>()?,
                };
                if jobs.is_empty() == ids.is_empty() {
                    return Err(bad(
                        "reduce requires exactly one of 'jobs' (job ids) or 'ids' \
                         (content ids), non-empty"
                            .into(),
                    ));
                }
                if jobs.len() > MAX_BATCH_JOBS || ids.len() > MAX_BATCH_JOBS {
                    return Err(bad(format!(
                        "reduce carries {} inputs, expected 1..={MAX_BATCH_JOBS}",
                        jobs.len().max(ids.len())
                    )));
                }
                let field = match j.get("field") {
                    None => ReduceField::Velocity,
                    Some(v) => ReduceField::parse(v.as_str().ok_or_else(|| {
                        bad("reduce field 'field' must be a string".into())
                    })?)?,
                };
                let str_opt = |k: &str| -> Result<Option<String>> {
                    match j.get(k) {
                        None => Ok(None),
                        Some(v) => v.as_str().map(|s| Some(s.to_string())).ok_or_else(|| {
                            bad(format!("reduce field '{k}' must be a string"))
                        }),
                    }
                };
                let scale = match j.get("scale") {
                    None => None,
                    Some(v) => Some(v.as_f64().ok_or_else(|| {
                        bad("reduce field 'scale' must be a number".into())
                    })?),
                };
                if let Some(s) = scale {
                    if !s.is_finite() {
                        return Err(bad("reduce field 'scale' must be finite".into()));
                    }
                }
                let pin = match j.get("pin") {
                    None => false,
                    Some(v) => v.as_bool().ok_or_else(|| {
                        bad("reduce field 'pin' must be a boolean".into())
                    })?,
                };
                Ok(Request::Reduce(ReduceRequest {
                    jobs,
                    ids,
                    field,
                    scale,
                    apply: str_opt("apply")?,
                    ref_id: str_opt("ref")?,
                    pin,
                    unpin: str_opt("unpin")?,
                }))
            }
            "stats" => Ok(Request::Stats),
            "shutdown" => Ok(Request::Shutdown {
                drain: match j.get("drain") {
                    None => true,
                    Some(v) => v.as_bool().ok_or_else(|| {
                        bad("shutdown field 'drain' must be a boolean".into())
                    })?,
                },
            }),
            other => Err(bad(format!("unknown command '{other}'"))),
        }
    }
}

/// Per-job admission verdict of a `submit_batch` line.
#[derive(Clone, Debug, PartialEq)]
pub enum Verdict {
    Admitted { id: JobId },
    Rejected { code: ErrorCode, retryable: bool, msg: String },
}

impl Verdict {
    /// Build from an admission attempt's outcome.
    pub fn from_result(r: Result<JobId>) -> Verdict {
        match r {
            Ok(id) => Verdict::Admitted { id },
            Err(e) => {
                let code = e.code();
                Verdict::Rejected { code, retryable: code.retryable(), msg: e.to_string() }
            }
        }
    }

    fn to_json(&self) -> Json {
        match self {
            Verdict::Admitted { id } => {
                Json::object([("ok", Json::Bool(true)), ("id", Json::num(*id as f64))])
            }
            Verdict::Rejected { code, retryable, msg } => Json::object([
                ("ok", Json::Bool(false)),
                ("error", Json::str(msg)),
                ("code", Json::str(code.as_str())),
                ("retryable", Json::Bool(*retryable)),
            ]),
        }
    }

    fn from_json(j: &Json) -> Result<Verdict> {
        let ok = j
            .get("ok")
            .and_then(Json::as_bool)
            .ok_or_else(|| Error::Serve("batch verdict missing 'ok'".into()))?;
        if ok {
            let id = j
                .get("id")
                .and_then(Json::as_index)
                .ok_or_else(|| Error::Serve("admitted verdict missing 'id'".into()))?;
            Ok(Verdict::Admitted { id })
        } else {
            let (code, retryable, msg) = wire_error_fields(j);
            Ok(Verdict::Rejected { code, retryable, msg })
        }
    }
}

/// One encoded daemon response.
#[derive(Clone, Debug)]
pub enum Response {
    Ok,
    /// Answer to `hello`: the protocol level this session will use and the
    /// feature tags the daemon supports.
    Hello { proto: u64, features: Vec<String> },
    /// Answer to `ping` in a v2 session (the `probe` feature): stable node
    /// identity plus a load snapshot cheap enough to poll every second.
    /// v1 sessions keep the bare `{"ok":true}` bytes, and pre-probe v2
    /// clients decode this as a plain `Ok` (the extra data nests under an
    /// object key they never look at).
    Pong { node: String, proto: u64, queued: usize, running: usize },
    Submitted { id: JobId },
    /// Answer to `submit_batch`: one admission verdict per job, in
    /// submission order.
    Batch(Vec<Verdict>),
    /// Receipt for an `upload`: the volume's content id (what `submit`
    /// references in `source`) and whether it was already resident.
    Uploaded { id: String, n: usize, dedup: bool },
    /// Receipt for a `reduce`: the content id of the result volume now in
    /// the store, its grid size, kind (`"scalar"` or `"velocity"`), how
    /// many inputs were averaged, the result's byte size, whether it was
    /// already resident, and — when the request named a `ref` — the
    /// relative L2 change against it (the driver's convergence signal).
    Reduced {
        id: String,
        n: usize,
        kind: String,
        count: usize,
        bytes: u64,
        dedup: bool,
        delta_rel: Option<f64>,
    },
    Job(JobView),
    Jobs(Vec<JobView>),
    Stats(ServeStats),
    /// A failed request. In a v1 session only `msg` travels; a v2 session
    /// additionally carries the stable `code` and its `retryable` flag.
    Error { code: ErrorCode, retryable: bool, msg: String },
}

impl Response {
    /// Build the error response for any internal error, classified via
    /// [`Error::code`].
    pub fn from_error(e: &Error) -> Response {
        let code = e.code();
        Response::Error { code, retryable: code.retryable(), msg: e.to_string() }
    }
}

/// Decode (`code`, `retryable`, `msg`) from an `"ok":false` object.
/// Absent code = a v1 daemon: classify `internal`, not retryable, unless
/// the wire explicitly says otherwise. Unknown codes (newer daemon)
/// degrade to `internal` but keep the wire's `retryable` flag.
fn wire_error_fields(j: &Json) -> (ErrorCode, bool, String) {
    let msg = j.get("error").and_then(Json::as_str).unwrap_or("unspecified").to_string();
    let code = j
        .get("code")
        .and_then(Json::as_str)
        .and_then(ErrorCode::parse)
        .unwrap_or(ErrorCode::Internal);
    let retryable =
        j.get("retryable").and_then(Json::as_bool).unwrap_or_else(|| code.retryable());
    (code, retryable, msg)
}

fn opt_num(x: Option<f64>) -> Json {
    x.map(Json::num).unwrap_or(Json::Null)
}

fn job_to_json(v: &JobView) -> Json {
    let mut j = Json::object([
        ("id", Json::num(v.id as f64)),
        ("name", Json::str(&v.name)),
        ("priority", Json::str(v.priority.as_str())),
        ("state", Json::str(v.state.as_str())),
        (
            "iters_done",
            v.iters_done.map(|i| Json::num(i as f64)).unwrap_or(Json::Null),
        ),
        ("grad_rel", opt_num(v.grad_rel)),
        (
            "dispatch_seq",
            v.dispatch_seq.map(|s| Json::num(s as f64)).unwrap_or(Json::Null),
        ),
        ("latency_s", opt_num(v.latency_s)),
        ("wall_s", opt_num(v.wall_s)),
        ("mismatch_rel", opt_num(v.mismatch_rel)),
        (
            "iters",
            v.iters.map(|i| Json::num(i as f64)).unwrap_or(Json::Null),
        ),
        (
            "levels",
            v.levels.map(|l| Json::num(l as f64)).unwrap_or(Json::Null),
        ),
        (
            "converged",
            v.converged.map(Json::Bool).unwrap_or(Json::Null),
        ),
        (
            "error",
            v.error.as_deref().map(Json::str).unwrap_or(Json::Null),
        ),
    ]);
    // Retained output content ids ride only when present: a daemon that
    // retains nothing keeps its pre-template job bytes unchanged.
    if let Json::Obj(m) = &mut j {
        if let Some(vel) = &v.velocity {
            m.insert("velocity".into(), Json::str(vel));
        }
        if let Some(w) = &v.warped {
            m.insert("warped".into(), Json::str(w));
        }
    }
    j
}

fn job_from_json(j: &Json) -> Result<JobView> {
    let miss = |k: &str| Error::Serve(format!("job view missing '{k}'"));
    Ok(JobView {
        id: j.get("id").and_then(Json::as_usize).ok_or_else(|| miss("id"))? as JobId,
        name: j.get("name").and_then(Json::as_str).ok_or_else(|| miss("name"))?.to_string(),
        priority: Priority::parse(
            j.get("priority").and_then(Json::as_str).ok_or_else(|| miss("priority"))?,
        )?,
        state: JobState::parse(
            j.get("state").and_then(Json::as_str).ok_or_else(|| miss("state"))?,
        )?,
        iters_done: j.get("iters_done").and_then(Json::as_usize),
        grad_rel: j.get("grad_rel").and_then(Json::as_f64),
        dispatch_seq: j.get("dispatch_seq").and_then(Json::as_usize).map(|x| x as u64),
        latency_s: j.get("latency_s").and_then(Json::as_f64),
        wall_s: j.get("wall_s").and_then(Json::as_f64),
        mismatch_rel: j.get("mismatch_rel").and_then(Json::as_f64),
        iters: j.get("iters").and_then(Json::as_usize),
        levels: j.get("levels").and_then(Json::as_usize),
        converged: j.get("converged").and_then(Json::as_bool),
        error: j.get("error").and_then(Json::as_str).map(str::to_string),
        velocity: j.get("velocity").and_then(Json::as_str).map(str::to_string),
        warped: j.get("warped").and_then(Json::as_str).map(str::to_string),
    })
}

fn node_stats_to_json(n: &NodeStats) -> Json {
    Json::object([
        ("node", Json::str(&n.node)),
        ("addr", Json::str(&n.addr)),
        ("up", Json::Bool(n.up)),
        ("queued", Json::num(n.queued as f64)),
        ("running", Json::num(n.running as f64)),
        ("completed", Json::num(n.completed as f64)),
        ("routed", Json::num(n.routed as f64)),
    ])
}

fn node_stats_from_json(j: &Json) -> Result<NodeStats> {
    let miss = |k: &str| Error::Serve(format!("node stats missing '{k}'"));
    Ok(NodeStats {
        node: j.get("node").and_then(Json::as_str).ok_or_else(|| miss("node"))?.to_string(),
        addr: j.get("addr").and_then(Json::as_str).ok_or_else(|| miss("addr"))?.to_string(),
        up: j.get("up").and_then(Json::as_bool).ok_or_else(|| miss("up"))?,
        queued: j.get("queued").and_then(Json::as_usize).ok_or_else(|| miss("queued"))?,
        running: j.get("running").and_then(Json::as_usize).ok_or_else(|| miss("running"))?,
        completed: j.get("completed").and_then(Json::as_usize).ok_or_else(|| miss("completed"))?
            as u64,
        routed: j.get("routed").and_then(Json::as_usize).ok_or_else(|| miss("routed"))? as u64,
    })
}

fn stats_to_json(s: &ServeStats) -> Json {
    let mut store = Json::object([
        ("volumes", Json::num(s.store.volumes as f64)),
        ("bytes", Json::num(s.store.bytes as f64)),
        ("uploads", Json::num(s.store.uploads as f64)),
        ("dedup_hits", Json::num(s.store.dedup_hits as f64)),
        ("evictions", Json::num(s.store.evictions as f64)),
    ]);
    // The pin count rides only when a pin is held, keeping an idle
    // daemon's store bytes identical to the pre-template wire.
    if s.store.pinned > 0 {
        if let Json::Obj(m) = &mut store {
            m.insert("pinned".into(), Json::num(s.store.pinned as f64));
        }
    }
    let mut j = Json::object([
        ("submitted", Json::num(s.submitted as f64)),
        ("queued", Json::num(s.queued as f64)),
        ("running", Json::num(s.running as f64)),
        ("completed", Json::num(s.completed as f64)),
        ("failed", Json::num(s.failed as f64)),
        ("cancelled", Json::num(s.cancelled as f64)),
        ("rejected", Json::num(s.rejected as f64)),
        ("prior_completed", Json::num(s.prior_completed as f64)),
        ("workers", Json::num(s.workers as f64)),
        ("cache_compiles", Json::num(s.cache_compiles as f64)),
        ("cache_hits", Json::num(s.cache_hits as f64)),
        ("store", store),
    ]);
    // Per-node breakdown only when one exists (router-merged stats): a
    // single daemon's stats stay byte-identical to the pre-router wire.
    if !s.nodes.is_empty() {
        if let Json::Obj(m) = &mut j {
            m.insert("nodes".into(), Json::Arr(s.nodes.iter().map(node_stats_to_json).collect()));
        }
    }
    // Batch-occupancy counters only when coalescing ever fired, keeping a
    // never-coalescing daemon's stats byte-identical to the pre-batching
    // wire.
    if s.batches > 0 || s.coalesced > 0 {
        if let Json::Obj(m) = &mut j {
            m.insert("batches".into(), Json::num(s.batches as f64));
            m.insert("coalesced".into(), Json::num(s.coalesced as f64));
        }
    }
    j
}

fn stats_from_json(j: &Json) -> Result<ServeStats> {
    let g = |k: &str| -> Result<u64> {
        j.get(k)
            .and_then(Json::as_usize)
            .map(|x| x as u64)
            .ok_or_else(|| Error::Serve(format!("stats missing '{k}'")))
    };
    // Absent store block = zeros (stats from a scheduler embedded without
    // a store, e.g. BatchService, or a pre-data-plane daemon).
    let store = match j.get("store") {
        None => StoreStats::default(),
        Some(s) => {
            let gs = |k: &str| -> Result<u64> {
                s.get(k)
                    .and_then(Json::as_usize)
                    .map(|x| x as u64)
                    .ok_or_else(|| Error::Serve(format!("store stats missing '{k}'")))
            };
            StoreStats {
                volumes: gs("volumes")? as usize,
                bytes: gs("bytes")?,
                uploads: gs("uploads")?,
                dedup_hits: gs("dedup_hits")?,
                evictions: gs("evictions")?,
                // Absent pin count = a daemon holding no pins (or one
                // predating pinning) — zero, not an error.
                pinned: s.get("pinned").and_then(Json::as_usize).unwrap_or(0),
            }
        }
    };
    // Absent nodes block = no per-node breakdown (any single daemon).
    let nodes = match j.get("nodes").and_then(Json::as_arr) {
        None => Vec::new(),
        Some(ns) => ns.iter().map(node_stats_from_json).collect::<Result<_>>()?,
    };
    Ok(ServeStats {
        submitted: g("submitted")?,
        queued: g("queued")? as usize,
        running: g("running")? as usize,
        completed: g("completed")?,
        failed: g("failed")?,
        cancelled: g("cancelled")?,
        rejected: g("rejected")?,
        prior_completed: g("prior_completed")?,
        workers: g("workers")? as usize,
        cache_compiles: g("cache_compiles")?,
        cache_hits: g("cache_hits")?,
        store,
        nodes,
        // Absent batch counters = a daemon that never coalesced (or
        // predates coalescing) — zeros, not an error.
        batches: j.get("batches").and_then(Json::as_usize).unwrap_or(0) as u64,
        coalesced: j.get("coalesced").and_then(Json::as_usize).unwrap_or(0) as u64,
    })
}

impl Response {
    /// v1 JSON form. For errors this is `{"error": msg, "ok": false}` —
    /// byte-identical to the pre-v2 daemon, which is the compat guarantee
    /// for connections that never negotiated.
    fn to_json(&self) -> Json {
        match self {
            Response::Ok => Json::object([("ok", Json::Bool(true))]),
            Response::Hello { proto, features } => Json::object([
                ("ok", Json::Bool(true)),
                ("proto", Json::num(*proto as f64)),
                (
                    "features",
                    Json::Arr(features.iter().map(|f| Json::str(f.as_str())).collect()),
                ),
            ]),
            Response::Pong { node, proto, queued, running } => Json::object([
                ("ok", Json::Bool(true)),
                (
                    "node",
                    Json::object([
                        ("id", Json::str(node)),
                        ("proto", Json::num(*proto as f64)),
                        ("queued", Json::num(*queued as f64)),
                        ("running", Json::num(*running as f64)),
                    ]),
                ),
            ]),
            Response::Submitted { id } => {
                Json::object([("ok", Json::Bool(true)), ("id", Json::num(*id as f64))])
            }
            Response::Batch(verdicts) => Json::object([
                ("ok", Json::Bool(true)),
                ("results", Json::Arr(verdicts.iter().map(Verdict::to_json).collect())),
            ]),
            Response::Uploaded { id, n, dedup } => Json::object([
                ("ok", Json::Bool(true)),
                (
                    "volume",
                    Json::object([
                        ("id", Json::str(id)),
                        ("n", Json::num(*n as f64)),
                        ("dedup", Json::Bool(*dedup)),
                    ]),
                ),
            ]),
            Response::Reduced { id, n, kind, count, bytes, dedup, delta_rel } => {
                let mut r = Json::object([
                    ("id", Json::str(id)),
                    ("n", Json::num(*n as f64)),
                    ("kind", Json::str(kind)),
                    ("count", Json::num(*count as f64)),
                    ("bytes", Json::num(*bytes as f64)),
                    ("dedup", Json::Bool(*dedup)),
                ]);
                // delta_rel rides only when the request named a ref.
                if let (Some(d), Json::Obj(m)) = (delta_rel, &mut r) {
                    m.insert("delta_rel".into(), Json::num(*d));
                }
                Json::object([("ok", Json::Bool(true)), ("reduced", r)])
            }
            Response::Job(v) => Json::object([("ok", Json::Bool(true)), ("job", job_to_json(v))]),
            Response::Jobs(vs) => Json::object([
                ("ok", Json::Bool(true)),
                ("jobs", Json::Arr(vs.iter().map(job_to_json).collect())),
            ]),
            Response::Stats(s) => {
                Json::object([("ok", Json::Bool(true)), ("stats", stats_to_json(s))])
            }
            Response::Error { msg, .. } => {
                Json::object([("ok", Json::Bool(false)), ("error", Json::str(msg))])
            }
        }
    }

    /// v1 encoding (exact legacy bytes — no `code`, `retryable` or `seq`).
    pub fn to_line(&self) -> String {
        self.to_json().render()
    }

    /// v2 encoding: the v1 object plus the structured error fields
    /// (`code`, `retryable`) and the echoed request `seq`.
    pub fn to_line_v2(&self, seq: Option<u64>) -> String {
        let mut j = self.to_json();
        if let Json::Obj(m) = &mut j {
            if let Response::Error { code, retryable, .. } = self {
                m.insert("code".into(), Json::str(code.as_str()));
                m.insert("retryable".into(), Json::Bool(*retryable));
            }
            if let Some(s) = seq {
                m.insert("seq".into(), Json::num(s as f64));
            }
        }
        j.render()
    }

    pub fn parse(line: &str) -> Result<Response> {
        Response::from_json(&Json::parse(line.trim())?)
    }

    pub fn from_json(j: &Json) -> Result<Response> {
        let ok = j
            .get("ok")
            .and_then(Json::as_bool)
            .ok_or_else(|| Error::Serve("response missing 'ok'".into()))?;
        if !ok {
            let (code, retryable, msg) = wire_error_fields(j);
            return Ok(Response::Error { code, retryable, msg });
        }
        if let Some(p) = j.get("proto").and_then(Json::as_index) {
            let features = j
                .get("features")
                .and_then(Json::as_arr)
                .map(|xs| {
                    xs.iter().filter_map(Json::as_str).map(str::to_string).collect()
                })
                .unwrap_or_default();
            return Ok(Response::Hello { proto: p, features });
        }
        if let Some(node) = j.get("node") {
            let miss = |k: &str| Error::Serve(format!("probe response missing '{k}'"));
            return Ok(Response::Pong {
                node: node
                    .get("id")
                    .and_then(Json::as_str)
                    .ok_or_else(|| miss("id"))?
                    .to_string(),
                proto: node.get("proto").and_then(Json::as_index).ok_or_else(|| miss("proto"))?,
                queued: node
                    .get("queued")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| miss("queued"))?,
                running: node
                    .get("running")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| miss("running"))?,
            });
        }
        if let Some(rs) = j.get("results").and_then(Json::as_arr) {
            return Ok(Response::Batch(
                rs.iter().map(Verdict::from_json).collect::<Result<_>>()?,
            ));
        }
        if let Some(s) = j.get("stats") {
            return Ok(Response::Stats(stats_from_json(s)?));
        }
        if let Some(v) = j.get("volume") {
            let miss = |k: &str| Error::Serve(format!("upload receipt missing '{k}'"));
            return Ok(Response::Uploaded {
                id: v.get("id").and_then(Json::as_str).ok_or_else(|| miss("id"))?.to_string(),
                n: v.get("n").and_then(Json::as_usize).ok_or_else(|| miss("n"))?,
                dedup: v.get("dedup").and_then(Json::as_bool).ok_or_else(|| miss("dedup"))?,
            });
        }
        if let Some(r) = j.get("reduced") {
            let miss = |k: &str| Error::Serve(format!("reduce receipt missing '{k}'"));
            return Ok(Response::Reduced {
                id: r.get("id").and_then(Json::as_str).ok_or_else(|| miss("id"))?.to_string(),
                n: r.get("n").and_then(Json::as_usize).ok_or_else(|| miss("n"))?,
                kind: r
                    .get("kind")
                    .and_then(Json::as_str)
                    .ok_or_else(|| miss("kind"))?
                    .to_string(),
                count: r.get("count").and_then(Json::as_usize).ok_or_else(|| miss("count"))?,
                bytes: r.get("bytes").and_then(Json::as_usize).ok_or_else(|| miss("bytes"))?
                    as u64,
                dedup: r.get("dedup").and_then(Json::as_bool).ok_or_else(|| miss("dedup"))?,
                delta_rel: r.get("delta_rel").and_then(Json::as_f64),
            });
        }
        if let Some(v) = j.get("job") {
            return Ok(Response::Job(job_from_json(v)?));
        }
        if let Some(vs) = j.get("jobs").and_then(Json::as_arr) {
            return Ok(Response::Jobs(vs.iter().map(job_from_json).collect::<Result<_>>()?));
        }
        if let Some(id) = j.get("id").and_then(Json::as_usize) {
            return Ok(Response::Submitted { id: id as JobId });
        }
        Ok(Response::Ok)
    }
}

/// One server-pushed watch event as it travels on the wire. Every event
/// echoes the `seq` the subscribing `watch` request carried (if any), so
/// a client multiplexing several streams can tell them apart.
#[derive(Clone, Debug, PartialEq)]
pub enum EventMsg {
    /// A job state transition (`queued`, `running`, then one of
    /// `done`/`failed`/`cancelled`; terminal transitions carry `wall_s`
    /// and — for failures — `error`).
    Job {
        seq: Option<u64>,
        id: JobId,
        name: String,
        state: JobState,
        wall_s: Option<f64>,
        error: Option<String>,
    },
    /// One accepted solver iteration of a running job (`claire watch`
    /// renders these live): iteration count, grid level, continuation
    /// beta, objective J, relative gradient norm and step length.
    Progress {
        seq: Option<u64>,
        id: JobId,
        name: String,
        iter: usize,
        level: usize,
        beta: f64,
        j: f64,
        grad_rel: f64,
        alpha: f64,
    },
    /// Terminal marker: the subscriber fell behind the bounded event
    /// queue and was dropped; no further events will arrive. Re-issue
    /// `watch` (ideally on a drained connection) to resubscribe.
    Lagged { seq: Option<u64> },
}

impl EventMsg {
    /// Whether a decoded protocol line is an event (vs a response): events
    /// carry `"event"`, responses carry `"ok"`.
    pub fn is_event(j: &Json) -> bool {
        j.get("event").is_some()
    }

    pub fn to_line(&self) -> String {
        let mut pairs: Vec<(&str, Json)> = Vec::new();
        match self {
            EventMsg::Job { seq, id, name, state, wall_s, error } => {
                pairs.push(("event", Json::str("job")));
                pairs.push(("id", Json::num(*id as f64)));
                pairs.push(("name", Json::str(name)));
                pairs.push(("state", Json::str(state.as_str())));
                if let Some(w) = wall_s {
                    pairs.push(("wall_s", Json::num(*w)));
                }
                if let Some(e) = error {
                    pairs.push(("error", Json::str(e)));
                }
                if let Some(s) = seq {
                    pairs.push(("seq", Json::num(*s as f64)));
                }
            }
            EventMsg::Progress { seq, id, name, iter, level, beta, j, grad_rel, alpha } => {
                pairs.push(("event", Json::str("progress")));
                pairs.push(("id", Json::num(*id as f64)));
                pairs.push(("name", Json::str(name)));
                pairs.push(("iter", Json::num(*iter as f64)));
                pairs.push(("level", Json::num(*level as f64)));
                pairs.push(("beta", Json::num(*beta)));
                pairs.push(("j", Json::num(*j)));
                pairs.push(("grad_rel", Json::num(*grad_rel)));
                pairs.push(("alpha", Json::num(*alpha)));
                if let Some(s) = seq {
                    pairs.push(("seq", Json::num(*s as f64)));
                }
            }
            EventMsg::Lagged { seq } => {
                pairs.push(("event", Json::str("lagged")));
                if let Some(s) = seq {
                    pairs.push(("seq", Json::num(*s as f64)));
                }
            }
        }
        Json::object(pairs).render()
    }

    pub fn from_json(j: &Json) -> Result<EventMsg> {
        let kind = j
            .get("event")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Serve("event missing 'event'".into()))?;
        let seq = j.get("seq").and_then(Json::as_index);
        match kind {
            "lagged" => Ok(EventMsg::Lagged { seq }),
            "progress" => {
                let miss = |k: &str| Error::Serve(format!("progress event missing '{k}'"));
                let num =
                    |k: &str| j.get(k).and_then(Json::as_f64).ok_or_else(|| miss(k));
                Ok(EventMsg::Progress {
                    seq,
                    id: j.get("id").and_then(Json::as_index).ok_or_else(|| miss("id"))?,
                    name: j
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| miss("name"))?
                        .to_string(),
                    iter: j.get("iter").and_then(Json::as_usize).ok_or_else(|| miss("iter"))?,
                    level: j
                        .get("level")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| miss("level"))?,
                    beta: num("beta")?,
                    j: num("j")?,
                    grad_rel: num("grad_rel")?,
                    alpha: num("alpha")?,
                })
            }
            "job" => {
                let miss = |k: &str| Error::Serve(format!("job event missing '{k}'"));
                Ok(EventMsg::Job {
                    seq,
                    id: j.get("id").and_then(Json::as_index).ok_or_else(|| miss("id"))?,
                    name: j
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| miss("name"))?
                        .to_string(),
                    state: JobState::parse(
                        j.get("state").and_then(Json::as_str).ok_or_else(|| miss("state"))?,
                    )?,
                    wall_s: j.get("wall_s").and_then(Json::as_f64),
                    error: j.get("error").and_then(Json::as_str).map(str::to_string),
                })
            }
            other => Err(Error::Serve(format!("unknown event kind '{other}'"))),
        }
    }

    pub fn parse(line: &str) -> Result<EventMsg> {
        EventMsg::from_json(&Json::parse(line.trim())?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::Precision;

    #[test]
    fn request_roundtrip_all_verbs() {
        let spec = JobSpec {
            subject: "na03".into(),
            n: 32,
            variant: "opt-fd8-linear".into(),
            precision: Precision::Mixed,
            priority: Priority::Emergency,
            max_iter: Some(7),
            beta: Some(1e-3),
            gtol: None,
            continuation: Some(false),
            ..Default::default()
        };
        let uploaded = JobSpec {
            n: 8,
            source: JobSource::Uploaded { m0: "aa11".into(), m1: "bb22".into() },
            multires: Some(3),
            ..Default::default()
        };
        for req in [
            Request::Ping,
            Request::Hello { proto: 2 },
            Request::Upload { n: 2, data: vec![0.0, -1.5, 3.25, 4.0, 5.0, 6.5, 7.0, 8.0] },
            Request::Submit(spec.clone()),
            Request::Submit(uploaded.clone()),
            Request::SubmitBatch(vec![spec, uploaded]),
            Request::Status(None),
            Request::Status(Some(4)),
            Request::Cancel(9),
            Request::Watch,
            Request::Reduce(ReduceRequest { jobs: vec![3, 4, 5], ..Default::default() }),
            Request::Reduce(ReduceRequest {
                jobs: vec![7],
                field: ReduceField::Warped,
                scale: Some(-0.5),
                apply: Some("tpl01".into()),
                ref_id: Some("tpl00".into()),
                pin: true,
                unpin: Some("tplff".into()),
                ..Default::default()
            }),
            Request::Reduce(ReduceRequest {
                ids: vec!["aa".into(), "bb".into()],
                pin: true,
                ..Default::default()
            }),
            Request::Stats,
            Request::Shutdown { drain: false },
        ] {
            let line = req.to_line();
            assert!(!line.contains('\n'), "one line: {line}");
            assert_eq!(Request::parse(&line).unwrap(), req, "{line}");
            // The seq envelope decorates any verb and round-trips.
            let (seq, parsed) = Request::parse_line(&req.to_line_with_seq(Some(41)));
            assert_eq!(seq, Some(41));
            assert_eq!(parsed.unwrap(), req);
        }
    }

    #[test]
    fn seq_envelope_is_tolerant() {
        // No seq -> None; junk seq -> ignored; seq on a broken body still
        // surfaces so the error response can be correlated.
        assert_eq!(Request::parse_line(r#"{"cmd":"ping"}"#).0, None);
        assert_eq!(Request::parse_line(r#"{"cmd":"ping","seq":"x"}"#).0, None);
        assert_eq!(Request::parse_line(r#"{"cmd":"ping","seq":-3}"#).0, None);
        let (seq, parsed) = Request::parse_line(r#"{"cmd":"warp","seq":9}"#);
        assert_eq!(seq, Some(9));
        assert!(parsed.is_err());
        let (seq, parsed) = Request::parse_line("not json at all");
        assert_eq!(seq, None);
        assert!(parsed.is_err());
    }

    #[test]
    fn hello_parses_and_bounds_proto() {
        assert_eq!(
            Request::parse(r#"{"cmd":"hello"}"#).unwrap(),
            Request::Hello { proto: PROTO_VERSION }
        );
        assert_eq!(
            Request::parse(r#"{"cmd":"hello","proto":3}"#).unwrap(),
            Request::Hello { proto: 3 }
        );
        assert!(Request::parse(r#"{"cmd":"hello","proto":0}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"hello","proto":"two"}"#).is_err());
    }

    #[test]
    fn submit_batch_parse_is_bounded_and_indexed() {
        assert!(Request::parse(r#"{"cmd":"submit_batch"}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"submit_batch","jobs":[]}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"submit_batch","jobs":5}"#).is_err());
        // A malformed element fails the whole line, naming the index —
        // encode errors are the client's bug; admission verdicts are only
        // for well-formed jobs.
        let err = Request::parse(r#"{"cmd":"submit_batch","jobs":[{},{"n":"x"}]}"#).unwrap_err();
        assert!(err.to_string().contains("jobs[1]"), "{err}");
        assert_eq!(err.code(), ErrorCode::BadRequest);
    }

    #[test]
    fn reduce_parse_is_validated_and_sparse() {
        // Exactly one of jobs/ids, non-empty.
        assert!(Request::parse(r#"{"cmd":"reduce"}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"reduce","jobs":[],"ids":[]}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"reduce","jobs":[1],"ids":["a"]}"#).is_err());
        // Element and knob typing is strict.
        assert!(Request::parse(r#"{"cmd":"reduce","jobs":["1"]}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"reduce","jobs":[1.5]}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"reduce","ids":[7]}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"reduce","jobs":[1],"field":"images"}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"reduce","jobs":[1],"scale":"x"}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"reduce","jobs":[1],"pin":"yes"}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"reduce","jobs":[1],"apply":3}"#).is_err());
        // Decode failures carry the structured bad_request code.
        assert_eq!(
            Request::parse(r#"{"cmd":"reduce"}"#).unwrap_err().code(),
            ErrorCode::BadRequest
        );
        // Absent knobs take defaults ...
        let min = Request::parse(r#"{"cmd":"reduce","jobs":[1]}"#).unwrap();
        let Request::Reduce(r) = min else { panic!("reduce expected") };
        assert_eq!(r.field, ReduceField::Velocity);
        assert_eq!((r.scale, r.pin), (None, false));
        assert!(r.apply.is_none() && r.ref_id.is_none() && r.unpin.is_none());
        // ... and stay off the wire when unset (emit-only-when-present).
        let line = Request::Reduce(ReduceRequest {
            jobs: vec![1],
            ..Default::default()
        })
        .to_line();
        for absent in ["ids", "field", "scale", "apply", "ref", "pin", "unpin"] {
            assert!(!line.contains(absent), "{absent} leaked into {line}");
        }
    }

    #[test]
    fn upload_requests_are_validated() {
        // Well-formed upload decodes to the exact sample vector.
        let data = vec![1.0f32; 8];
        let line = Request::Upload { n: 2, data: data.clone() }.to_line();
        assert_eq!(Request::parse(&line).unwrap(), Request::Upload { n: 2, data });
        // Shape mismatch: 27 samples under n = 2.
        let bad = Request::Upload { n: 2, data: vec![0.0; 27] }.to_line();
        let err = Request::parse(&bad).unwrap_err();
        assert!(err.to_string().contains("expected 32"), "{err}");
        // Missing / malformed fields.
        assert!(Request::parse(r#"{"cmd":"upload"}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"upload","n":2}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"upload","n":2,"data":"not base64!"}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"upload","n":0,"data":""}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"upload","n":5000,"data":""}"#).is_err());
        // Grids that cannot fit the one-line discipline are rejected with
        // a useful error up front, not a connection drop at the line cap.
        let err = Request::parse(r#"{"cmd":"upload","n":300,"data":""}"#).unwrap_err();
        assert!(err.to_string().contains("chunked"), "{err}");
        // Non-finite samples are rejected at the boundary.
        let nan = Request::Upload { n: 2, data: vec![f32::NAN; 8] }.to_line();
        let err = Request::parse(&nan).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        // Every upload decode failure is a structured bad_request.
        let err = Request::parse(r#"{"cmd":"upload","n":2}"#).unwrap_err();
        assert_eq!(err.code(), ErrorCode::BadRequest);
    }

    #[test]
    fn borrowed_upload_encoder_is_byte_identical() {
        let data: Vec<f32> = (0..27).map(|i| (i as f32 * 0.37).sin()).collect();
        let owned = Request::Upload { n: 3, data: data.clone() }.to_line();
        assert_eq!(upload_line(3, &data, None), owned);
        // With a seq the line parses back to the same request + envelope.
        let (seq, parsed) = Request::parse_line(&upload_line(3, &data, Some(12)));
        assert_eq!(seq, Some(12));
        assert_eq!(parsed.unwrap(), Request::Upload { n: 3, data });
    }

    #[test]
    fn bad_requests_are_errors() {
        assert!(Request::parse("{}").is_err());
        assert!(Request::parse(r#"{"cmd":"warp"}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"cancel"}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"submit"}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"submit","job":{"priority":"asap"}}"#).is_err());
        assert!(Request::parse("not json").is_err());
        // Present-but-malformed status id errors instead of listing all.
        assert!(Request::parse(r#"{"cmd":"status","id":"7"}"#).is_err());
        assert_eq!(Request::parse(r#"{"cmd":"status"}"#).unwrap(), Request::Status(None));
        // Non-integral ids must not truncate onto a different job.
        assert!(Request::parse(r#"{"cmd":"cancel","id":1.9}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"status","id":-1}"#).is_err());
        // Mistyped job fields error instead of silently running defaults.
        assert!(Request::parse(r#"{"cmd":"submit","job":{"n":"32"}}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"submit","job":{"max_iter":2.5}}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"submit","job":5}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"submit","job":{"continuation":"yes"}}"#).is_err());
        // Mistyped drain must not silently become a drain=true shutdown.
        assert!(Request::parse(r#"{"cmd":"shutdown","drain":"false"}"#).is_err());
        // Decode failures carry the bad_request code (structured errors).
        assert_eq!(Request::parse("{}").unwrap_err().code(), ErrorCode::BadRequest);
        // Out-of-range (but well-typed) grid sizes now decode and are
        // rejected by the single validate() path at daemon admission.
        let over = Request::parse(r#"{"cmd":"submit","job":{"n":5000}}"#).unwrap();
        let Request::Submit(spec) = over else { panic!("submit expected") };
        assert!(spec.validate().is_err());
    }

    #[test]
    fn bounded_line_reader() {
        use std::io::BufReader;
        let mut r = BufReader::new(&b"one\ntwo\nlast-no-newline"[..]);
        assert_eq!(read_line_bounded(&mut r, 64).unwrap().as_deref(), Some("one"));
        assert_eq!(read_line_bounded(&mut r, 64).unwrap().as_deref(), Some("two"));
        assert_eq!(
            read_line_bounded(&mut r, 64).unwrap().as_deref(),
            Some("last-no-newline")
        );
        assert_eq!(read_line_bounded(&mut r, 64).unwrap(), None);
        // Over-cap line is an error even without a newline in sight.
        let big = vec![b'a'; 100];
        let mut r = BufReader::new(&big[..]);
        let err = read_line_bounded(&mut r, 64).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    }

    #[test]
    fn two_tier_request_reader_escalates_only_for_uploads() {
        use std::io::BufReader;
        // A garbage line never earns the large cap: cut at the small one.
        let garbage = vec![b'x'; 200];
        let mut r = BufReader::new(&garbage[..]);
        let err = read_request_line_bounded(&mut r, 64, 4096).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("64"), "small bound applied: {err}");
        // An upload-shaped prefix escalates to the large cap and succeeds.
        let mut upload = br#"{"cmd":"upload","data":""#.to_vec();
        upload.extend(vec![b'A'; 300]);
        upload.extend(b"\",\"n\":4}\n");
        let mut r = BufReader::new(&upload[..]);
        let line = read_request_line_bounded(&mut r, 64, 4096).unwrap().unwrap();
        assert_eq!(line.len(), upload.len() - 1, "whole line delivered");
        // ... but the large cap is still a cap.
        let mut huge = br#"{"cmd":"upload","data":""#.to_vec();
        huge.extend(vec![b'A'; 8192]);
        let mut r = BufReader::new(&huge[..]);
        let err = read_request_line_bounded(&mut r, 64, 4096).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        // Small lines pass untouched regardless of content.
        let mut r = BufReader::new(&b"{\"cmd\":\"ping\"}\n"[..]);
        assert_eq!(
            read_request_line_bounded(&mut r, 64, 4096).unwrap().as_deref(),
            Some("{\"cmd\":\"ping\"}")
        );
        assert_eq!(read_request_line_bounded(&mut r, 64, 4096).unwrap(), None);
    }

    #[test]
    fn response_roundtrip() {
        let v = JobView {
            id: 3,
            name: "na02@16^3/opt-fd8-cubic".into(),
            priority: Priority::Urgent,
            state: JobState::Done,
            iters_done: Some(11),
            grad_rel: Some(4.2e-2),
            dispatch_seq: Some(5),
            latency_s: Some(1.25),
            wall_s: Some(0.5),
            mismatch_rel: Some(3e-2),
            iters: Some(11),
            levels: Some(3),
            converged: Some(true),
            error: None,
            velocity: None,
            warped: None,
        };
        // Absent retained outputs stay off the wire entirely (the
        // pre-template job bytes).
        let line = Response::Job(v.clone()).to_line();
        assert!(!line.contains("velocity") && !line.contains("warped"), "{line}");
        // Present ones roundtrip.
        let retained = JobView {
            velocity: Some("vel01".into()),
            warped: Some("img02".into()),
            ..v.clone()
        };
        match Response::parse(&Response::Job(retained).to_line()).unwrap() {
            Response::Job(got) => {
                assert_eq!(got.velocity.as_deref(), Some("vel01"));
                assert_eq!(got.warped.as_deref(), Some("img02"));
            }
            other => panic!("unexpected {other:?}"),
        }
        match Response::parse(&Response::Job(v.clone()).to_line()).unwrap() {
            Response::Job(got) => {
                assert_eq!(got.id, v.id);
                assert_eq!(got.state, JobState::Done);
                assert_eq!(got.dispatch_seq, Some(5));
                assert_eq!(got.iters, Some(11));
                assert_eq!(got.iters_done, Some(11), "live progress travels");
                assert_eq!(got.grad_rel, Some(4.2e-2));
                assert_eq!(got.levels, Some(3), "realized multires depth travels");
            }
            other => panic!("unexpected {other:?}"),
        }
        match Response::parse(&Response::Submitted { id: 12 }.to_line()).unwrap() {
            Response::Submitted { id } => assert_eq!(id, 12),
            other => panic!("unexpected {other:?}"),
        }
        let up = Response::Uploaded { id: "deadbeef".into(), n: 16, dedup: true };
        match Response::parse(&up.to_line()).unwrap() {
            Response::Uploaded { id, n, dedup } => {
                assert_eq!(id, "deadbeef");
                assert_eq!(n, 16);
                assert!(dedup);
            }
            other => panic!("unexpected {other:?}"),
        }
        let s = ServeStats {
            submitted: 8,
            queued: 1,
            running: 2,
            completed: 4,
            failed: 1,
            cancelled: 0,
            rejected: 3,
            prior_completed: 9,
            workers: 2,
            cache_compiles: 6,
            cache_hits: 18,
            store: StoreStats {
                volumes: 3,
                bytes: 786432,
                uploads: 5,
                dedup_hits: 2,
                evictions: 1,
                pinned: 0,
            },
            nodes: Vec::new(),
            batches: 0,
            coalesced: 0,
        };
        // No per-node breakdown: the wire bytes must not mention "nodes"
        // at all (single-daemon stats stay pre-router byte-identical);
        // likewise a never-coalescing daemon's bytes never mention the
        // batch-occupancy counters.
        let line = Response::Stats(s.clone()).to_line();
        assert!(!line.contains("nodes"), "{line}");
        assert!(!line.contains("batches") && !line.contains("coalesced"), "{line}");
        // A pin-free store never mentions the pin counter; a pinning one
        // roundtrips it.
        assert!(!line.contains("pinned"), "{line}");
        let pinning =
            ServeStats { store: StoreStats { pinned: 2, ..s.store }, ..s.clone() };
        match Response::parse(&Response::Stats(pinning).to_line()).unwrap() {
            Response::Stats(got) => assert_eq!(got.store.pinned, 2),
            other => panic!("unexpected {other:?}"),
        }
        // Non-zero batch counters roundtrip.
        let busy = ServeStats { batches: 3, coalesced: 11, ..s.clone() };
        match Response::parse(&Response::Stats(busy.clone()).to_line()).unwrap() {
            Response::Stats(got) => {
                assert_eq!(got.batches, 3);
                assert_eq!(got.coalesced, 11);
            }
            other => panic!("unexpected {other:?}"),
        }
        match Response::parse(&line).unwrap() {
            Response::Stats(got) => {
                assert_eq!(got.cache_hits, 18);
                assert_eq!(got.prior_completed, 9);
                assert_eq!(got.store, s.store, "store counters travel in stats");
                assert!(got.nodes.is_empty());
            }
            other => panic!("unexpected {other:?}"),
        }
        // Router-merged stats carry the per-node breakdown.
        let merged = ServeStats {
            nodes: vec![
                NodeStats {
                    node: "n-a".into(),
                    addr: "127.0.0.1:7464".into(),
                    up: true,
                    queued: 1,
                    running: 2,
                    completed: 7,
                    routed: 9,
                },
                NodeStats {
                    node: String::new(),
                    addr: "127.0.0.1:7465".into(),
                    up: false,
                    queued: 0,
                    running: 0,
                    completed: 0,
                    routed: 3,
                },
            ],
            ..s
        };
        match Response::parse(&Response::Stats(merged.clone()).to_line()).unwrap() {
            Response::Stats(got) => assert_eq!(got.nodes, merged.nodes),
            other => panic!("unexpected {other:?}"),
        }
        // A stats object without a store block (pre-data-plane daemon or a
        // storeless embedding) parses to zeroed store counters.
        let legacy = r#"{"ok":true,"stats":{"submitted":1,"queued":0,"running":0,
            "completed":1,"failed":0,"cancelled":0,"rejected":0,"prior_completed":0,
            "workers":1,"cache_compiles":0,"cache_hits":0}}"#
            .replace('\n', "");
        match Response::parse(&legacy).unwrap() {
            Response::Stats(got) => assert_eq!(got.store, StoreStats::default()),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn error_responses_are_v1_opaque_v2_structured() {
        let resp = Response::Error {
            code: ErrorCode::QueueFull,
            retryable: true,
            msg: "queue full (2 waiting, cap 2)".into(),
        };
        // v1 bytes carry only the message (pre-v2 compatibility).
        assert_eq!(
            resp.to_line(),
            r#"{"error":"queue full (2 waiting, cap 2)","ok":false}"#
        );
        // v2 bytes add code/retryable/seq.
        let v2 = resp.to_line_v2(Some(7));
        assert!(v2.contains(r#""code":"queue_full""#), "{v2}");
        assert!(v2.contains(r#""retryable":true"#), "{v2}");
        assert!(v2.contains(r#""seq":7"#), "{v2}");
        match Response::parse(&v2).unwrap() {
            Response::Error { code, retryable, msg } => {
                assert_eq!(code, ErrorCode::QueueFull);
                assert!(retryable);
                assert_eq!(msg, "queue full (2 waiting, cap 2)");
            }
            other => panic!("unexpected {other:?}"),
        }
        // A v1 error line (no code) classifies internal / not retryable.
        match Response::parse(r#"{"error":"queue full","ok":false}"#).unwrap() {
            Response::Error { code, retryable, .. } => {
                assert_eq!(code, ErrorCode::Internal);
                assert!(!retryable);
            }
            other => panic!("unexpected {other:?}"),
        }
        // Unknown codes (newer daemon) degrade but keep the wire flag.
        match Response::parse(
            r#"{"code":"quota_exceeded","error":"x","ok":false,"retryable":true}"#,
        )
        .unwrap()
        {
            Response::Error { code, retryable, .. } => {
                assert_eq!(code, ErrorCode::Internal);
                assert!(retryable, "wire retryable flag wins for unknown codes");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn hello_and_batch_responses_roundtrip() {
        let hello = Response::Hello {
            proto: 2,
            features: PROTO_V2_FEATURES.iter().map(|s| s.to_string()).collect(),
        };
        match Response::parse(&hello.to_line_v2(Some(1))).unwrap() {
            Response::Hello { proto, features } => {
                assert_eq!(proto, 2);
                assert!(features.contains(&"watch".to_string()));
                assert!(features.contains(&"submit_batch".to_string()));
            }
            other => panic!("unexpected {other:?}"),
        }
        let batch = Response::Batch(vec![
            Verdict::Admitted { id: 4 },
            Verdict::Rejected {
                code: ErrorCode::QueueFull,
                retryable: true,
                msg: "queue full".into(),
            },
        ]);
        match Response::parse(&batch.to_line_v2(Some(2))).unwrap() {
            Response::Batch(vs) => {
                assert_eq!(vs.len(), 2);
                assert_eq!(vs[0], Verdict::Admitted { id: 4 });
                assert_eq!(
                    vs[1],
                    Verdict::Rejected {
                        code: ErrorCode::QueueFull,
                        retryable: true,
                        msg: "queue full".into()
                    }
                );
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn reduced_receipt_roundtrips_and_nests() {
        let r = Response::Reduced {
            id: "tpl01".into(),
            n: 16,
            kind: "velocity".into(),
            count: 4,
            bytes: 49152,
            dedup: false,
            delta_rel: None,
        };
        let line = r.to_line_v2(Some(3));
        // delta_rel rides only when a ref was named.
        assert!(!line.contains("delta_rel"), "{line}");
        // The receipt nests under "reduced": no top-level keys that an
        // older decoder would misread (id -> submitted, job, stats, ...).
        let j = Json::parse(&line).unwrap();
        assert!(j.get("id").is_none() && j.get("job").is_none(), "{line}");
        match Response::parse(&line).unwrap() {
            Response::Reduced { id, n, kind, count, bytes, dedup, delta_rel } => {
                assert_eq!(id, "tpl01");
                assert_eq!((n, count), (16, 4));
                assert_eq!(kind, "velocity");
                assert_eq!(bytes, 49152);
                assert!(!dedup);
                assert_eq!(delta_rel, None);
            }
            other => panic!("unexpected {other:?}"),
        }
        let with_delta = Response::Reduced {
            id: "tpl02".into(),
            n: 16,
            kind: "scalar".into(),
            count: 4,
            bytes: 16384,
            dedup: true,
            delta_rel: Some(0.125),
        };
        match Response::parse(&with_delta.to_line()).unwrap() {
            Response::Reduced { delta_rel, dedup, kind, .. } => {
                assert_eq!(delta_rel, Some(0.125));
                assert!(dedup);
                assert_eq!(kind, "scalar");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn pong_probe_roundtrips_and_degrades_to_ok() {
        let pong =
            Response::Pong { node: "node-a1".into(), proto: 2, queued: 3, running: 1 };
        let line = pong.to_line_v2(Some(4));
        match Response::parse(&line).unwrap() {
            Response::Pong { node, proto, queued, running } => {
                assert_eq!(node, "node-a1");
                assert_eq!(proto, 2);
                assert_eq!(queued, 3);
                assert_eq!(running, 1);
            }
            other => panic!("unexpected {other:?}"),
        }
        // The probe payload nests under "node": no top-level "proto" (would
        // read as a hello) and no top-level "id" (would read as submitted).
        let j = Json::parse(&line).unwrap();
        assert!(j.get("proto").is_none() && j.get("id").is_none(), "{line}");
        // A v1 ping response stays the bare ok object.
        assert_eq!(Response::Ok.to_line(), r#"{"ok":true}"#);
    }

    #[test]
    fn seq_echo_rides_every_v2_response() {
        for (resp, key) in [
            (Response::Ok, r#""seq":9"#),
            (Response::Submitted { id: 3 }, r#""seq":9"#),
        ] {
            let line = resp.to_line_v2(Some(9));
            assert!(line.contains(key), "{line}");
            // And the v1 encoding never carries it.
            assert!(!resp.to_line().contains("seq"), "{}", resp.to_line());
        }
    }

    #[test]
    fn event_messages_roundtrip() {
        let running = EventMsg::Job {
            seq: Some(4),
            id: 7,
            name: "na02@16^3/opt-fd8-cubic".into(),
            state: JobState::Running,
            wall_s: None,
            error: None,
        };
        assert_eq!(EventMsg::parse(&running.to_line()).unwrap(), running);
        let failed = EventMsg::Job {
            seq: None,
            id: 8,
            name: "x".into(),
            state: JobState::Failed,
            wall_s: Some(0.25),
            error: Some("boom".into()),
        };
        assert_eq!(EventMsg::parse(&failed.to_line()).unwrap(), failed);
        let lag = EventMsg::Lagged { seq: Some(4) };
        assert_eq!(EventMsg::parse(&lag.to_line()).unwrap(), lag);
        let progress = EventMsg::Progress {
            seq: Some(4),
            id: 7,
            name: "na02@16^3/opt-fd8-cubic".into(),
            iter: 3,
            level: 1,
            beta: 5e-4,
            j: 0.0125,
            grad_rel: 0.31,
            alpha: 1.0,
        };
        assert_eq!(EventMsg::parse(&progress.to_line()).unwrap(), progress);
        assert!(EventMsg::parse(r#"{"event":"progress","id":7}"#).is_err());
        // Events and responses are distinguishable by key.
        let j = Json::parse(&running.to_line()).unwrap();
        assert!(EventMsg::is_event(&j));
        let r = Json::parse(&Response::Ok.to_line()).unwrap();
        assert!(!EventMsg::is_event(&r));
        assert!(EventMsg::parse(r#"{"event":"meteor"}"#).is_err());
    }
}
