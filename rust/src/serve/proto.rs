//! Wire protocol for the registration daemon: newline-delimited JSON.
//!
//! Every request and every response is one JSON object on one line. The
//! protocol is deliberately small — six verbs plus ping — and builds on
//! `util/json.rs` (the offline image has no serde). Responses always carry
//! an `"ok"` boolean; errors carry `"error"`.
//!
//! Requests:
//! ```text
//! {"cmd":"ping"}
//! {"cmd":"upload","n":16,"data":"<base64 LE f32 samples>"}
//! {"cmd":"submit","job":{"subject":"na02","n":16,"variant":"opt-fd8-cubic",
//!                        "priority":"emergency","max_iter":50}}
//! {"cmd":"submit","job":{"n":32,"source":{"m0":"<id>","m1":"<id>"},
//!                        "multires":3}}
//! {"cmd":"status"}              all jobs
//! {"cmd":"status","id":3}       one job
//! {"cmd":"cancel","id":3}
//! {"cmd":"stats"}
//! {"cmd":"shutdown","drain":true}
//! ```
//!
//! `upload` is the data plane: the volume payload is the `data/io.rs`
//! little-endian f32 byte format, base64-wrapped to stay within the
//! one-line NDJSON discipline, landing in the daemon's content-addressed
//! store (`serve/store.rs`). `submit` then references content ids via
//! `source`, and `multires` selects coarse-to-fine grid continuation.
//!
//! Protocol contract for encoders: an `upload` line must mention its
//! `"cmd":"upload"` key within the first 4096 bytes (natural for every
//! key order except payload-first; this crate's encoder emits `cmd`
//! before `data`). The daemon reads request lines under a small cap and
//! only escalates to the volume-sized bound when that prefix identifies
//! an upload — a payload-first encoding is cut off at the small cap.

use crate::data::io::{f32s_from_le_bytes, f32s_to_le_bytes};
use crate::error::{Error, Result};
use crate::precision::Precision;
use crate::registration::RegParams;
use crate::serve::scheduler::{JobId, JobState, JobView, ServeStats};
use crate::serve::store::StoreStats;
use crate::util::base64;
use crate::util::json::Json;

/// Hard cap on one non-upload protocol line, both directions. Requests
/// are tiny; responses are bounded by the scheduler's record retention.
/// The cap keeps one misbehaving peer from growing an unbounded buffer.
pub const MAX_LINE_BYTES: usize = 4 * 1024 * 1024;

/// Hard cap on one *upload* request line on the daemon side: sized so a
/// 256^3 volume (the paper's largest run; 64 MiB raw, ~86 MiB base64)
/// fits on one line, still bounding what a misbehaving peer can make the
/// daemon buffer. Only lines that look like an `upload` request escalate
/// to this bound (see [`read_request_line_bounded`]); everything else
/// stays under `MAX_LINE_BYTES`, so a non-upload flood cannot pin 96 MiB
/// per connection. Larger grids would need a chunked upload extension.
pub const MAX_UPLOAD_LINE_BYTES: usize = 96 * 1024 * 1024;

/// Largest grid size a one-line `upload` can carry: a 256^3 payload fits
/// `MAX_UPLOAD_LINE_BYTES`; anything larger would die at the line cap, so
/// it is rejected up front with a useful error instead of a connection
/// drop. (`MAX_GRID_N` still bounds *submit* specs — in-process stores
/// fed by embedders are not line-limited.)
pub const MAX_UPLOAD_GRID_N: usize = 256;

/// Hard cap on the wire-submittable grid size. The paper's largest runs
/// are 256^3; 512^3 leaves headroom. Without this bound, a typo'd
/// `"n": 5000` would allocate n^3 buffers in the worker (hundreds of GB)
/// before the artifact lookup could reject the size — aborting the
/// daemon, not just failing the job.
pub const MAX_GRID_N: usize = 512;

/// Hard cap on requestable grid-continuation levels: 512 -> 16 is six
/// factor-2 descents, so deeper requests are always typos.
pub const MAX_MULTIRES_LEVELS: usize = 6;

/// Read one `\n`-terminated line of at most `cap` bytes. `Ok(None)` on
/// clean EOF; a line exceeding the cap is an `InvalidData` IO error (the
/// caller should answer with a protocol error and drop the connection).
pub fn read_line_bounded<R: std::io::BufRead>(
    r: &mut R,
    cap: usize,
) -> std::io::Result<Option<String>> {
    // Equal tiers = a single flat cap (escalation can never trigger).
    read_request_line_bounded(r, cap, cap)
}

/// Does a buffered request prefix look like an `upload` line? Checked
/// only when a line outgrows the small cap, to decide whether the large
/// (volume-sized) bound applies. Deliberately lenient — any mention of
/// `upload` in the first 4096 bytes qualifies; a non-upload line that
/// sneaks past still fails `Request::parse`, it just got to waste a
/// bigger buffer first. The flip side is a protocol contract (see the
/// module docs): an upload line must mention its verb near the start —
/// an encoder that buries `"cmd":"upload"` megabytes deep behind the
/// payload is cut off at the small cap.
fn looks_like_upload(buf: &[u8]) -> bool {
    let head = &buf[..buf.len().min(4096)];
    head.windows(6).any(|w| w == b"upload")
}

/// Read one request line under a two-tier cap: bounded by `small_cap`
/// unless the buffered prefix looks like an `upload` request (the only
/// verb with a large payload), which escalates the bound to `large_cap`.
/// A non-upload flood is cut off at the small bound; one-line volume
/// uploads still fit.
pub fn read_request_line_bounded<R: std::io::BufRead>(
    r: &mut R,
    small_cap: usize,
    large_cap: usize,
) -> std::io::Result<Option<String>> {
    let mut cap = small_cap.min(large_cap);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let (done, used) = {
            let available = r.fill_buf()?;
            if available.is_empty() {
                (true, 0)
            } else if let Some(pos) = available.iter().position(|&b| b == b'\n') {
                buf.extend_from_slice(&available[..pos]);
                (true, pos + 1)
            } else {
                buf.extend_from_slice(available);
                (false, available.len())
            }
        };
        r.consume(used);
        if buf.len() > cap && cap < large_cap && looks_like_upload(&buf) {
            cap = large_cap;
        }
        if buf.len() > cap {
            // Not re-checked after a *successful* escalation unless one
            // fill chunk jumped straight past large_cap too.
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("protocol line exceeds {cap} bytes"),
            ));
        }
        if done {
            return Ok(if buf.is_empty() && used == 0 {
                None
            } else {
                Some(String::from_utf8_lossy(&buf).into_owned())
            });
        }
    }
}

/// Dispatch priority. Higher priorities jump the queue (they do not kill
/// running solves): the paper's emergency clinical scan is served before
/// queued batch research jobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Research / population-study batch work (default).
    Batch = 0,
    /// Interactive clinical sessions.
    Urgent = 1,
    /// Emergency scans: always admitted, dispatched first.
    Emergency = 2,
}

impl Priority {
    pub fn as_str(&self) -> &'static str {
        match self {
            Priority::Batch => "batch",
            Priority::Urgent => "urgent",
            Priority::Emergency => "emergency",
        }
    }

    pub fn parse(s: &str) -> Result<Priority> {
        match s {
            "batch" => Ok(Priority::Batch),
            "urgent" => Ok(Priority::Urgent),
            "emergency" => Ok(Priority::Emergency),
            other => Err(Error::Serve(format!("unknown priority '{other}'"))),
        }
    }
}

/// Where a job's image pair comes from.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobSource {
    /// The daemon synthesizes a NIREP-analog pair from `subject` — the
    /// status quo default, exactly like the CLI `register`/`batch` paths.
    Synthetic,
    /// Template (`m0`) and reference (`m1`) volumes previously shipped via
    /// the `upload` verb, referenced by content id. Resolved against the
    /// daemon's store at admission time.
    Uploaded { m0: String, m1: String },
}

/// A wire-submittable registration job: a synthetic NIREP-analog subject
/// *or* an uploaded volume pair, at a given grid size and kernel variant,
/// with the solver knobs that matter for scheduling experiments.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    pub subject: String,
    pub n: usize,
    pub variant: String,
    /// Image source. Wire field `"source"`: absent = synthetic (pre-data-
    /// plane clients keep working), `{"m0":"<id>","m1":"<id>"}` = uploaded.
    pub source: JobSource,
    /// Solver precision policy; `mixed` runs the PCG Hessian matvecs
    /// through the reduced-precision artifacts. Wire field `"precision"`.
    pub precision: Precision,
    /// Grid-continuation levels. Wire field `"multires"`; absent = single
    /// grid. `Some(k >= 2)` runs `solve_multires` coarse-to-fine.
    pub multires: Option<usize>,
    pub priority: Priority,
    pub max_iter: Option<usize>,
    pub beta: Option<f64>,
    pub gtol: Option<f64>,
    pub continuation: Option<bool>,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            subject: "na02".into(),
            n: 16,
            variant: "opt-fd8-cubic".into(),
            source: JobSource::Synthetic,
            precision: Precision::Full,
            multires: None,
            priority: Priority::Batch,
            max_iter: None,
            beta: None,
            gtol: None,
            continuation: None,
        }
    }
}

impl JobSpec {
    /// Display name used in job records and the journal. Mixed-precision
    /// jobs carry a `+mixed` suffix and multires jobs a `+mr<levels>`
    /// suffix so status tables and the journal show the policy at a
    /// glance; uploaded-source jobs show truncated content ids instead of
    /// a subject.
    pub fn name(&self) -> String {
        let subject = match &self.source {
            JobSource::Synthetic => self.subject.clone(),
            JobSource::Uploaded { m0, m1 } => {
                let short = |s: &str| s.chars().take(8).collect::<String>();
                format!("up:{}+{}", short(m0), short(m1))
            }
        };
        let mut name = format!("{}@{}^3/{}", subject, self.n, self.variant);
        if self.precision == Precision::Mixed {
            name.push_str("+mixed");
        }
        if let Some(levels) = self.multires.filter(|&l| l > 1) {
            name.push_str(&format!("+mr{levels}"));
        }
        name
    }

    /// Solver parameters with the spec's overrides applied.
    pub fn reg_params(&self) -> RegParams {
        let mut p = RegParams {
            variant: self.variant.clone(),
            precision: self.precision,
            ..Default::default()
        };
        if let Some(m) = self.max_iter {
            p.max_iter = m;
        }
        if let Some(b) = self.beta {
            p.beta = b;
        }
        if let Some(g) = self.gtol {
            p.gtol = g;
        }
        if let Some(c) = self.continuation {
            p.continuation = c;
        }
        if let Some(l) = self.multires {
            p.multires = l;
        }
        p
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("subject", Json::str(&self.subject)),
            ("n", Json::num(self.n as f64)),
            ("variant", Json::str(&self.variant)),
            ("precision", Json::str(self.precision.as_str())),
            ("priority", Json::str(self.priority.as_str())),
        ];
        if let JobSource::Uploaded { m0, m1 } = &self.source {
            pairs.push((
                "source",
                Json::object([("m0", Json::str(m0)), ("m1", Json::str(m1))]),
            ));
        }
        if let Some(l) = self.multires {
            pairs.push(("multires", Json::num(l as f64)));
        }
        if let Some(m) = self.max_iter {
            pairs.push(("max_iter", Json::num(m as f64)));
        }
        if let Some(b) = self.beta {
            pairs.push(("beta", Json::num(b)));
        }
        if let Some(g) = self.gtol {
            pairs.push(("gtol", Json::num(g)));
        }
        if let Some(c) = self.continuation {
            pairs.push(("continuation", Json::Bool(c)));
        }
        Json::object(pairs)
    }

    /// Strict decode: absent fields take defaults, but a field that is
    /// present with the wrong type is an error — a clinical daemon must
    /// not silently run a default job because `"n": "32"` was a string.
    pub fn from_json(j: &Json) -> Result<JobSpec> {
        if j.as_obj().is_none() {
            return Err(Error::Serve("'job' must be an object".into()));
        }
        fn field<'a, T>(
            j: &'a Json,
            key: &str,
            conv: impl Fn(&'a Json) -> Option<T>,
            what: &str,
        ) -> Result<Option<T>> {
            match j.get(key) {
                None => Ok(None),
                Some(v) => conv(v)
                    .map(Some)
                    .ok_or_else(|| Error::Serve(format!("job field '{key}' must be {what}"))),
            }
        }
        let d = JobSpec::default();
        let n_explicit = field(j, "n", Json::as_index, "a non-negative integer")?;
        let n = match n_explicit {
            None => d.n,
            Some(x) if (1..=MAX_GRID_N as u64).contains(&x) => x as usize,
            Some(x) => {
                return Err(Error::Serve(format!(
                    "job field 'n' = {x} out of range (1..={MAX_GRID_N})"
                )))
            }
        };
        // Absent source = synthetic (pre-data-plane clients keep working).
        // An uploaded source must name both volumes and pin `n` explicitly
        // so the daemon can validate content shapes at admission time.
        let source = match j.get("source") {
            None => JobSource::Synthetic,
            Some(s) => {
                let id_of = |k: &str| -> Result<String> {
                    s.get(k)
                        .and_then(Json::as_str)
                        .filter(|v| !v.is_empty())
                        .map(str::to_string)
                        .ok_or_else(|| {
                            Error::Serve(format!(
                                "job field 'source' must carry a non-empty string '{k}'"
                            ))
                        })
                };
                if n_explicit.is_none() {
                    return Err(Error::Serve(
                        "jobs with an uploaded source must specify 'n' explicitly".into(),
                    ));
                }
                JobSource::Uploaded { m0: id_of("m0")?, m1: id_of("m1")? }
            }
        };
        let multires = match field(j, "multires", Json::as_index, "a non-negative integer")? {
            None => None,
            Some(x) if (1..=MAX_MULTIRES_LEVELS as u64).contains(&x) => Some(x as usize),
            Some(x) => {
                return Err(Error::Serve(format!(
                    "job field 'multires' = {x} out of range (1..={MAX_MULTIRES_LEVELS})"
                )))
            }
        };
        Ok(JobSpec {
            subject: field(j, "subject", Json::as_str, "a string")?
                .map(str::to_string)
                .unwrap_or(d.subject),
            n,
            variant: field(j, "variant", Json::as_str, "a string")?
                .map(str::to_string)
                .unwrap_or(d.variant),
            source,
            multires,
            // Absent precision defaults to full (pre-precision clients keep
            // working); a present but unknown value is an error.
            precision: match field(j, "precision", Json::as_str, "a string")? {
                Some(s) => Precision::parse(s)
                    .map_err(|_| Error::Serve(format!("unknown job precision '{s}'")))?,
                None => d.precision,
            },
            priority: match field(j, "priority", Json::as_str, "a string")? {
                Some(s) => Priority::parse(s)?,
                None => d.priority,
            },
            max_iter: field(j, "max_iter", Json::as_index, "a non-negative integer")?
                .map(|x| x as usize),
            beta: field(j, "beta", Json::as_f64, "a number")?,
            gtol: field(j, "gtol", Json::as_f64, "a number")?,
            continuation: field(j, "continuation", Json::as_bool, "a boolean")?,
        })
    }
}

/// One decoded client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Ping,
    /// Ship one volume into the daemon's content-addressed store. `data`
    /// holds the n^3 samples; on the wire they travel as base64 of the
    /// `data/io.rs` little-endian f32 byte format.
    Upload { n: usize, data: Vec<f32> },
    Submit(JobSpec),
    /// `None` lists every job the daemon knows about.
    Status(Option<JobId>),
    Cancel(JobId),
    Stats,
    Shutdown { drain: bool },
}

impl Request {
    pub fn to_line(&self) -> String {
        let j = match self {
            Request::Ping => Json::object([("cmd", Json::str("ping"))]),
            Request::Upload { n, data } => Json::object([
                ("cmd", Json::str("upload")),
                ("n", Json::num(*n as f64)),
                ("data", Json::str(base64::encode(&f32s_to_le_bytes(data)))),
            ]),
            Request::Submit(spec) => {
                Json::object([("cmd", Json::str("submit")), ("job", spec.to_json())])
            }
            Request::Status(None) => Json::object([("cmd", Json::str("status"))]),
            Request::Status(Some(id)) => {
                Json::object([("cmd", Json::str("status")), ("id", Json::num(*id as f64))])
            }
            Request::Cancel(id) => {
                Json::object([("cmd", Json::str("cancel")), ("id", Json::num(*id as f64))])
            }
            Request::Stats => Json::object([("cmd", Json::str("stats"))]),
            Request::Shutdown { drain } => {
                Json::object([("cmd", Json::str("shutdown")), ("drain", Json::Bool(*drain))])
            }
        };
        j.render()
    }

    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line.trim())?;
        let cmd = j
            .get("cmd")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Serve("request missing 'cmd'".into()))?;
        let id_of = |j: &Json| -> Result<JobId> {
            j.get("id")
                .and_then(Json::as_index)
                .ok_or_else(|| Error::Serve(format!("'{cmd}' requires an integer 'id'")))
        };
        match cmd {
            "ping" => Ok(Request::Ping),
            "upload" => {
                let n = match j.get("n").and_then(Json::as_index) {
                    Some(x) if (1..=MAX_UPLOAD_GRID_N as u64).contains(&x) => x as usize,
                    Some(x) => {
                        return Err(Error::Serve(format!(
                            "upload field 'n' = {x} out of range (1..={MAX_UPLOAD_GRID_N}; \
                             larger volumes need a chunked upload, not yet supported)"
                        )))
                    }
                    None => {
                        return Err(Error::Serve(
                            "upload requires an integer 'n'".into(),
                        ))
                    }
                };
                let b64 = j.get("data").and_then(Json::as_str).ok_or_else(|| {
                    Error::Serve("upload requires a base64 string 'data'".into())
                })?;
                let bytes = base64::decode(b64)
                    .map_err(|e| Error::Serve(format!("upload payload: {e}")))?;
                let expected = n * n * n * 4;
                if bytes.len() != expected {
                    return Err(Error::Serve(format!(
                        "upload payload is {} bytes, expected {expected} ({n}^3 f32 samples)",
                        bytes.len()
                    )));
                }
                let data = f32s_from_le_bytes(&bytes)?;
                // Reject non-finite voxels at the protocol boundary: a NaN
                // smuggled into m0/m1 would poison every norm and line
                // search of the solve and surface as a cryptic failure.
                if let Some(i) = data.iter().position(|x| !x.is_finite()) {
                    return Err(Error::Serve(format!(
                        "upload payload contains a non-finite sample at index {i}"
                    )));
                }
                Ok(Request::Upload { n, data })
            }
            "submit" => {
                let job = j
                    .get("job")
                    .ok_or_else(|| Error::Serve("submit requires a 'job' object".into()))?;
                Ok(Request::Submit(JobSpec::from_json(job)?))
            }
            // A present-but-malformed id must error, not degrade to "all".
            "status" => match j.get("id") {
                None => Ok(Request::Status(None)),
                Some(_) => Ok(Request::Status(Some(id_of(&j)?))),
            },
            "cancel" => Ok(Request::Cancel(id_of(&j)?)),
            "stats" => Ok(Request::Stats),
            "shutdown" => Ok(Request::Shutdown {
                drain: match j.get("drain") {
                    None => true,
                    Some(v) => v.as_bool().ok_or_else(|| {
                        Error::Serve("shutdown field 'drain' must be a boolean".into())
                    })?,
                },
            }),
            other => Err(Error::Serve(format!("unknown command '{other}'"))),
        }
    }
}

/// One encoded daemon response.
#[derive(Clone, Debug)]
pub enum Response {
    Ok,
    Submitted { id: JobId },
    /// Receipt for an `upload`: the volume's content id (what `submit`
    /// references in `source`) and whether it was already resident.
    Uploaded { id: String, n: usize, dedup: bool },
    Job(JobView),
    Jobs(Vec<JobView>),
    Stats(ServeStats),
    Error(String),
}

fn opt_num(x: Option<f64>) -> Json {
    x.map(Json::num).unwrap_or(Json::Null)
}

fn job_to_json(v: &JobView) -> Json {
    Json::object([
        ("id", Json::num(v.id as f64)),
        ("name", Json::str(&v.name)),
        ("priority", Json::str(v.priority.as_str())),
        ("state", Json::str(v.state.as_str())),
        (
            "dispatch_seq",
            v.dispatch_seq.map(|s| Json::num(s as f64)).unwrap_or(Json::Null),
        ),
        ("latency_s", opt_num(v.latency_s)),
        ("wall_s", opt_num(v.wall_s)),
        ("mismatch_rel", opt_num(v.mismatch_rel)),
        (
            "iters",
            v.iters.map(|i| Json::num(i as f64)).unwrap_or(Json::Null),
        ),
        (
            "levels",
            v.levels.map(|l| Json::num(l as f64)).unwrap_or(Json::Null),
        ),
        (
            "converged",
            v.converged.map(Json::Bool).unwrap_or(Json::Null),
        ),
        (
            "error",
            v.error.as_deref().map(Json::str).unwrap_or(Json::Null),
        ),
    ])
}

fn job_from_json(j: &Json) -> Result<JobView> {
    let miss = |k: &str| Error::Serve(format!("job view missing '{k}'"));
    Ok(JobView {
        id: j.get("id").and_then(Json::as_usize).ok_or_else(|| miss("id"))? as JobId,
        name: j.get("name").and_then(Json::as_str).ok_or_else(|| miss("name"))?.to_string(),
        priority: Priority::parse(
            j.get("priority").and_then(Json::as_str).ok_or_else(|| miss("priority"))?,
        )?,
        state: JobState::parse(
            j.get("state").and_then(Json::as_str).ok_or_else(|| miss("state"))?,
        )?,
        dispatch_seq: j.get("dispatch_seq").and_then(Json::as_usize).map(|x| x as u64),
        latency_s: j.get("latency_s").and_then(Json::as_f64),
        wall_s: j.get("wall_s").and_then(Json::as_f64),
        mismatch_rel: j.get("mismatch_rel").and_then(Json::as_f64),
        iters: j.get("iters").and_then(Json::as_usize),
        levels: j.get("levels").and_then(Json::as_usize),
        converged: j.get("converged").and_then(Json::as_bool),
        error: j.get("error").and_then(Json::as_str).map(str::to_string),
    })
}

fn stats_to_json(s: &ServeStats) -> Json {
    Json::object([
        ("submitted", Json::num(s.submitted as f64)),
        ("queued", Json::num(s.queued as f64)),
        ("running", Json::num(s.running as f64)),
        ("completed", Json::num(s.completed as f64)),
        ("failed", Json::num(s.failed as f64)),
        ("cancelled", Json::num(s.cancelled as f64)),
        ("rejected", Json::num(s.rejected as f64)),
        ("prior_completed", Json::num(s.prior_completed as f64)),
        ("workers", Json::num(s.workers as f64)),
        ("cache_compiles", Json::num(s.cache_compiles as f64)),
        ("cache_hits", Json::num(s.cache_hits as f64)),
        (
            "store",
            Json::object([
                ("volumes", Json::num(s.store.volumes as f64)),
                ("bytes", Json::num(s.store.bytes as f64)),
                ("uploads", Json::num(s.store.uploads as f64)),
                ("dedup_hits", Json::num(s.store.dedup_hits as f64)),
                ("evictions", Json::num(s.store.evictions as f64)),
            ]),
        ),
    ])
}

fn stats_from_json(j: &Json) -> Result<ServeStats> {
    let g = |k: &str| -> Result<u64> {
        j.get(k)
            .and_then(Json::as_usize)
            .map(|x| x as u64)
            .ok_or_else(|| Error::Serve(format!("stats missing '{k}'")))
    };
    // Absent store block = zeros (stats from a scheduler embedded without
    // a store, e.g. BatchService, or a pre-data-plane daemon).
    let store = match j.get("store") {
        None => StoreStats::default(),
        Some(s) => {
            let gs = |k: &str| -> Result<u64> {
                s.get(k)
                    .and_then(Json::as_usize)
                    .map(|x| x as u64)
                    .ok_or_else(|| Error::Serve(format!("store stats missing '{k}'")))
            };
            StoreStats {
                volumes: gs("volumes")? as usize,
                bytes: gs("bytes")?,
                uploads: gs("uploads")?,
                dedup_hits: gs("dedup_hits")?,
                evictions: gs("evictions")?,
            }
        }
    };
    Ok(ServeStats {
        submitted: g("submitted")?,
        queued: g("queued")? as usize,
        running: g("running")? as usize,
        completed: g("completed")?,
        failed: g("failed")?,
        cancelled: g("cancelled")?,
        rejected: g("rejected")?,
        prior_completed: g("prior_completed")?,
        workers: g("workers")? as usize,
        cache_compiles: g("cache_compiles")?,
        cache_hits: g("cache_hits")?,
        store,
    })
}

impl Response {
    pub fn to_line(&self) -> String {
        let j = match self {
            Response::Ok => Json::object([("ok", Json::Bool(true))]),
            Response::Submitted { id } => {
                Json::object([("ok", Json::Bool(true)), ("id", Json::num(*id as f64))])
            }
            Response::Uploaded { id, n, dedup } => Json::object([
                ("ok", Json::Bool(true)),
                (
                    "volume",
                    Json::object([
                        ("id", Json::str(id)),
                        ("n", Json::num(*n as f64)),
                        ("dedup", Json::Bool(*dedup)),
                    ]),
                ),
            ]),
            Response::Job(v) => Json::object([("ok", Json::Bool(true)), ("job", job_to_json(v))]),
            Response::Jobs(vs) => Json::object([
                ("ok", Json::Bool(true)),
                ("jobs", Json::Arr(vs.iter().map(job_to_json).collect())),
            ]),
            Response::Stats(s) => {
                Json::object([("ok", Json::Bool(true)), ("stats", stats_to_json(s))])
            }
            Response::Error(msg) => {
                Json::object([("ok", Json::Bool(false)), ("error", Json::str(msg))])
            }
        };
        j.render()
    }

    pub fn parse(line: &str) -> Result<Response> {
        let j = Json::parse(line.trim())?;
        let ok = j
            .get("ok")
            .and_then(Json::as_bool)
            .ok_or_else(|| Error::Serve("response missing 'ok'".into()))?;
        if !ok {
            let msg = j.get("error").and_then(Json::as_str).unwrap_or("unspecified");
            return Ok(Response::Error(msg.to_string()));
        }
        if let Some(s) = j.get("stats") {
            return Ok(Response::Stats(stats_from_json(s)?));
        }
        if let Some(v) = j.get("volume") {
            let miss = |k: &str| Error::Serve(format!("upload receipt missing '{k}'"));
            return Ok(Response::Uploaded {
                id: v.get("id").and_then(Json::as_str).ok_or_else(|| miss("id"))?.to_string(),
                n: v.get("n").and_then(Json::as_usize).ok_or_else(|| miss("n"))?,
                dedup: v.get("dedup").and_then(Json::as_bool).ok_or_else(|| miss("dedup"))?,
            });
        }
        if let Some(v) = j.get("job") {
            return Ok(Response::Job(job_from_json(v)?));
        }
        if let Some(vs) = j.get("jobs").and_then(Json::as_arr) {
            return Ok(Response::Jobs(vs.iter().map(job_from_json).collect::<Result<_>>()?));
        }
        if let Some(id) = j.get("id").and_then(Json::as_usize) {
            return Ok(Response::Submitted { id: id as JobId });
        }
        Ok(Response::Ok)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip_all_verbs() {
        let spec = JobSpec {
            subject: "na03".into(),
            n: 32,
            variant: "opt-fd8-linear".into(),
            precision: Precision::Mixed,
            priority: Priority::Emergency,
            max_iter: Some(7),
            beta: Some(1e-3),
            gtol: None,
            continuation: Some(false),
            ..Default::default()
        };
        let uploaded = JobSpec {
            n: 8,
            source: JobSource::Uploaded { m0: "aa11".into(), m1: "bb22".into() },
            multires: Some(3),
            ..Default::default()
        };
        for req in [
            Request::Ping,
            Request::Upload { n: 2, data: vec![0.0, -1.5, 3.25, 4.0, 5.0, 6.5, 7.0, 8.0] },
            Request::Submit(spec),
            Request::Submit(uploaded),
            Request::Status(None),
            Request::Status(Some(4)),
            Request::Cancel(9),
            Request::Stats,
            Request::Shutdown { drain: false },
        ] {
            let line = req.to_line();
            assert!(!line.contains('\n'), "one line: {line}");
            assert_eq!(Request::parse(&line).unwrap(), req, "{line}");
        }
    }

    #[test]
    fn upload_requests_are_validated() {
        // Well-formed upload decodes to the exact sample vector.
        let data = vec![1.0f32; 8];
        let line = Request::Upload { n: 2, data: data.clone() }.to_line();
        assert_eq!(Request::parse(&line).unwrap(), Request::Upload { n: 2, data });
        // Shape mismatch: 27 samples under n = 2.
        let bad = Request::Upload { n: 2, data: vec![0.0; 27] }.to_line();
        let err = Request::parse(&bad).unwrap_err();
        assert!(err.to_string().contains("expected 32"), "{err}");
        // Missing / malformed fields.
        assert!(Request::parse(r#"{"cmd":"upload"}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"upload","n":2}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"upload","n":2,"data":"not base64!"}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"upload","n":0,"data":""}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"upload","n":5000,"data":""}"#).is_err());
        // Grids that cannot fit the one-line discipline are rejected with
        // a useful error up front, not a connection drop at the line cap.
        let err = Request::parse(r#"{"cmd":"upload","n":300,"data":""}"#).unwrap_err();
        assert!(err.to_string().contains("chunked"), "{err}");
        // Non-finite samples are rejected at the boundary.
        let nan = Request::Upload { n: 2, data: vec![f32::NAN; 8] }.to_line();
        let err = Request::parse(&nan).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
    }

    #[test]
    fn spec_source_and_multires_wire_fields() {
        // Uploaded source + multires round-trip and shape the job name.
        let j = Json::parse(
            r#"{"n":32,"source":{"m0":"cafe01","m1":"beef02"},"multires":3}"#,
        )
        .unwrap();
        let spec = JobSpec::from_json(&j).unwrap();
        assert_eq!(
            spec.source,
            JobSource::Uploaded { m0: "cafe01".into(), m1: "beef02".into() }
        );
        assert_eq!(spec.multires, Some(3));
        assert_eq!(spec.name(), "up:cafe01+beef02@32^3/opt-fd8-cubic+mr3");
        assert_eq!(spec.reg_params().multires, 3);
        // multires=1 is legal and means single grid (no name suffix).
        let j1 = JobSpec::from_json(&Json::parse(r#"{"multires":1}"#).unwrap()).unwrap();
        assert_eq!(j1.multires, Some(1));
        assert!(!j1.name().contains("mr"), "{}", j1.name());
        // Out-of-range or mistyped multires errors.
        assert!(JobSpec::from_json(&Json::parse(r#"{"multires":0}"#).unwrap()).is_err());
        assert!(JobSpec::from_json(&Json::parse(r#"{"multires":7}"#).unwrap()).is_err());
        assert!(JobSpec::from_json(&Json::parse(r#"{"multires":"3"}"#).unwrap()).is_err());
        // Uploaded source must pin n and name both volumes.
        assert!(JobSpec::from_json(
            &Json::parse(r#"{"source":{"m0":"a","m1":"b"}}"#).unwrap()
        )
        .is_err(), "source without explicit n");
        assert!(JobSpec::from_json(
            &Json::parse(r#"{"n":16,"source":{"m0":"a"}}"#).unwrap()
        )
        .is_err(), "missing m1");
        assert!(JobSpec::from_json(
            &Json::parse(r#"{"n":16,"source":{"m0":"","m1":"b"}}"#).unwrap()
        )
        .is_err(), "empty id");
        // Synthetic default: absent source/multires behave exactly like a
        // pre-data-plane client's submission.
        let legacy = JobSpec::from_json(&Json::parse(r#"{"subject":"na02"}"#).unwrap()).unwrap();
        assert_eq!(legacy.source, JobSource::Synthetic);
        assert_eq!(legacy.multires, None);
        assert_eq!(legacy.reg_params().multires, 1);
    }

    #[test]
    fn spec_defaults_and_params() {
        let spec = JobSpec::from_json(&Json::parse(r#"{"subject":"na10"}"#).unwrap()).unwrap();
        assert_eq!(spec.subject, "na10");
        assert_eq!(spec.n, 16);
        assert_eq!(spec.priority, Priority::Batch);
        // Absent precision defaults to full (pre-precision clients).
        assert_eq!(spec.precision, Precision::Full);
        let p = spec.reg_params();
        assert_eq!(p.variant, "opt-fd8-cubic");
        assert_eq!(p.precision, Precision::Full);
        assert_eq!(p.max_iter, RegParams::default().max_iter);

        let spec2 = JobSpec { max_iter: Some(3), continuation: Some(false), ..spec };
        let p2 = spec2.reg_params();
        assert_eq!(p2.max_iter, 3);
        assert!(!p2.continuation);
    }

    #[test]
    fn spec_precision_wire_field() {
        let spec = JobSpec::from_json(
            &Json::parse(r#"{"subject":"na02","precision":"mixed"}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(spec.precision, Precision::Mixed);
        assert_eq!(spec.reg_params().precision, Precision::Mixed);
        assert_eq!(spec.name(), "na02@16^3/opt-fd8-cubic+mixed");
        // Round-trips through the submit line.
        let line = Request::Submit(spec.clone()).to_line();
        assert!(line.contains(r#""precision":"mixed""#), "{line}");
        assert_eq!(Request::parse(&line).unwrap(), Request::Submit(spec));
        // Unknown or mistyped precision errors instead of running full.
        assert!(JobSpec::from_json(&Json::parse(r#"{"precision":"half"}"#).unwrap()).is_err());
        assert!(JobSpec::from_json(&Json::parse(r#"{"precision":16}"#).unwrap()).is_err());
    }

    #[test]
    fn bad_requests_are_errors() {
        assert!(Request::parse("{}").is_err());
        assert!(Request::parse(r#"{"cmd":"warp"}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"cancel"}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"submit"}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"submit","job":{"priority":"asap"}}"#).is_err());
        assert!(Request::parse("not json").is_err());
        // Present-but-malformed status id errors instead of listing all.
        assert!(Request::parse(r#"{"cmd":"status","id":"7"}"#).is_err());
        assert_eq!(Request::parse(r#"{"cmd":"status"}"#).unwrap(), Request::Status(None));
        // Non-integral ids must not truncate onto a different job.
        assert!(Request::parse(r#"{"cmd":"cancel","id":1.9}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"status","id":-1}"#).is_err());
        // Mistyped job fields error instead of silently running defaults.
        assert!(Request::parse(r#"{"cmd":"submit","job":{"n":"32"}}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"submit","job":{"max_iter":2.5}}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"submit","job":5}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"submit","job":{"continuation":"yes"}}"#).is_err());
        // Mistyped drain must not silently become a drain=true shutdown.
        assert!(Request::parse(r#"{"cmd":"shutdown","drain":"false"}"#).is_err());
        // Grid size is bounded: n^3 allocations must be rejected up front.
        assert!(Request::parse(r#"{"cmd":"submit","job":{"n":5000}}"#).is_err());
        assert!(Request::parse(r#"{"cmd":"submit","job":{"n":0}}"#).is_err());
    }

    #[test]
    fn bounded_line_reader() {
        use std::io::BufReader;
        let mut r = BufReader::new(&b"one\ntwo\nlast-no-newline"[..]);
        assert_eq!(read_line_bounded(&mut r, 64).unwrap().as_deref(), Some("one"));
        assert_eq!(read_line_bounded(&mut r, 64).unwrap().as_deref(), Some("two"));
        assert_eq!(
            read_line_bounded(&mut r, 64).unwrap().as_deref(),
            Some("last-no-newline")
        );
        assert_eq!(read_line_bounded(&mut r, 64).unwrap(), None);
        // Over-cap line is an error even without a newline in sight.
        let big = vec![b'a'; 100];
        let mut r = BufReader::new(&big[..]);
        let err = read_line_bounded(&mut r, 64).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    }

    #[test]
    fn two_tier_request_reader_escalates_only_for_uploads() {
        use std::io::BufReader;
        // A garbage line never earns the large cap: cut at the small one.
        let garbage = vec![b'x'; 200];
        let mut r = BufReader::new(&garbage[..]);
        let err = read_request_line_bounded(&mut r, 64, 4096).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("64"), "small bound applied: {err}");
        // An upload-shaped prefix escalates to the large cap and succeeds.
        let mut upload = br#"{"cmd":"upload","data":""#.to_vec();
        upload.extend(vec![b'A'; 300]);
        upload.extend(b"\",\"n\":4}\n");
        let mut r = BufReader::new(&upload[..]);
        let line = read_request_line_bounded(&mut r, 64, 4096).unwrap().unwrap();
        assert_eq!(line.len(), upload.len() - 1, "whole line delivered");
        // ... but the large cap is still a cap.
        let mut huge = br#"{"cmd":"upload","data":""#.to_vec();
        huge.extend(vec![b'A'; 8192]);
        let mut r = BufReader::new(&huge[..]);
        let err = read_request_line_bounded(&mut r, 64, 4096).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        // Small lines pass untouched regardless of content.
        let mut r = BufReader::new(&b"{\"cmd\":\"ping\"}\n"[..]);
        assert_eq!(
            read_request_line_bounded(&mut r, 64, 4096).unwrap().as_deref(),
            Some("{\"cmd\":\"ping\"}")
        );
        assert_eq!(read_request_line_bounded(&mut r, 64, 4096).unwrap(), None);
    }

    #[test]
    fn response_roundtrip() {
        let v = JobView {
            id: 3,
            name: "na02@16^3/opt-fd8-cubic".into(),
            priority: Priority::Urgent,
            state: JobState::Done,
            dispatch_seq: Some(5),
            latency_s: Some(1.25),
            wall_s: Some(0.5),
            mismatch_rel: Some(3e-2),
            iters: Some(11),
            levels: Some(3),
            converged: Some(true),
            error: None,
        };
        match Response::parse(&Response::Job(v.clone()).to_line()).unwrap() {
            Response::Job(got) => {
                assert_eq!(got.id, v.id);
                assert_eq!(got.state, JobState::Done);
                assert_eq!(got.dispatch_seq, Some(5));
                assert_eq!(got.iters, Some(11));
                assert_eq!(got.levels, Some(3), "realized multires depth travels");
            }
            other => panic!("unexpected {other:?}"),
        }
        match Response::parse(&Response::Submitted { id: 12 }.to_line()).unwrap() {
            Response::Submitted { id } => assert_eq!(id, 12),
            other => panic!("unexpected {other:?}"),
        }
        let up = Response::Uploaded { id: "deadbeef".into(), n: 16, dedup: true };
        match Response::parse(&up.to_line()).unwrap() {
            Response::Uploaded { id, n, dedup } => {
                assert_eq!(id, "deadbeef");
                assert_eq!(n, 16);
                assert!(dedup);
            }
            other => panic!("unexpected {other:?}"),
        }
        match Response::parse(&Response::Error("queue full".into()).to_line()).unwrap() {
            Response::Error(m) => assert_eq!(m, "queue full"),
            other => panic!("unexpected {other:?}"),
        }
        let s = ServeStats {
            submitted: 8,
            queued: 1,
            running: 2,
            completed: 4,
            failed: 1,
            cancelled: 0,
            rejected: 3,
            prior_completed: 9,
            workers: 2,
            cache_compiles: 6,
            cache_hits: 18,
            store: StoreStats {
                volumes: 3,
                bytes: 786432,
                uploads: 5,
                dedup_hits: 2,
                evictions: 1,
            },
        };
        match Response::parse(&Response::Stats(s).to_line()).unwrap() {
            Response::Stats(got) => {
                assert_eq!(got.cache_hits, 18);
                assert_eq!(got.prior_completed, 9);
                assert_eq!(got.store, s.store, "store counters travel in stats");
            }
            other => panic!("unexpected {other:?}"),
        }
        // A stats object without a store block (pre-data-plane daemon or a
        // storeless embedding) parses to zeroed store counters.
        let legacy = r#"{"ok":true,"stats":{"submitted":1,"queued":0,"running":0,
            "completed":1,"failed":0,"cancelled":0,"rejected":0,"prior_completed":0,
            "workers":1,"cache_compiles":0,"cache_hits":0}}"#
            .replace('\n', "");
        match Response::parse(&legacy).unwrap() {
            Response::Stats(got) => assert_eq!(got.store, StoreStats::default()),
            other => panic!("unexpected {other:?}"),
        }
    }
}
