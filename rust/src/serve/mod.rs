//! Persistent registration service: daemon, scheduler, wire protocol.
//!
//! The paper's deployment story ("multiple registration tasks ... in an
//! embarrassingly parallel way", section 5) scaled past one-shot batches in
//! the follow-up multi-node CLAIRE work; this subsystem is the repo's
//! equivalent: a long-lived daemon that amortizes operator compilation
//! across requests instead of paying `OpRegistry` warm-up per invocation.
//!
//! * [`scheduler`] — priority queue with bounded-queue admission control
//!   and the pluggable [`scheduler::Executor`] execution backend (also the
//!   engine under `coordinator::BatchService`).
//! * [`daemon`] — TCP accept loop + worker pool + journal replay, with
//!   per-connection protocol negotiation (`hello` upgrades to v2: `seq`
//!   correlation, `watch` push events, `submit_batch`).
//! * [`proto`] — newline-delimited JSON request/response encoding (v1
//!   byte-compatible; v2 adds structured errors and the event grammar).
//! * [`store`] — content-addressed volume store (the `upload` data plane).
//! * [`client`] — typed synchronous client for the protocol.
//! * [`journal`] — append-only NDJSON job history for restart reporting.
//! * [`router`] — fleet tier: consistent-hash volume placement, affinity
//!   job routing and a federated control plane over N daemons, speaking
//!   the same wire protocol to clients.
//!
//! See DESIGN.md for the wire-protocol reference.

pub mod client;
pub mod daemon;
pub mod journal;
pub mod proto;
pub mod router;
pub mod scheduler;
pub mod store;

pub use client::{Client, ProbeInfo, ReduceReceipt, RetryPolicy};
pub use daemon::{pjrt_factory, Daemon, DaemonConfig, DaemonHandle, ExecutorFactory};
pub use journal::{Journal, JournalEntry};
pub use proto::{
    EventMsg, JobRequest, JobSource, JobSpec, Priority, ReduceField, ReduceRequest, Request,
    Response, Verdict,
};
pub use router::{Ring, Router, RouterConfig, RouterHandle};
pub use scheduler::{
    worker_loop, BusMsg, ExecOutcome, Executor, FailingExecutor, JobId, JobPayload, JobState,
    JobView, NodeStats, PjrtExecutor, Progress, Scheduler, ServeStats, WatchEvent, WatchHandle,
};
pub use store::{content_id, content_id_vec, StoreStats, UploadReceipt, VolumeStore};
