//! The persistent registration daemon.
//!
//! A long-lived process that amortizes operator compilation across
//! requests: N worker threads each own a PJRT client and a shared-warm
//! operator cache (PJRT handles are `!Send`, so the cache is per-worker —
//! the paper's "one device context per task" setting), fed by the priority
//! scheduler, fronted by a TCP accept loop speaking the NDJSON protocol
//! from `proto.rs`. One thread per connection; connections are cheap and
//! clients are few (CLI, batch drivers, monitoring).
//!
//! ## Protocol sessions
//!
//! Every connection starts in **v1** mode: strictly synchronous one-line
//! request / one-line response, opaque error strings — byte-for-byte what
//! the pre-v2 daemon spoke, so old clients never notice the upgrade. A
//! `hello` request negotiates **v2**: responses then echo the request's
//! `seq`, errors carry `code`/`retryable`, `submit_batch` admits many
//! jobs per line, and `watch` subscribes the connection to server-pushed
//! job events. Watch events are written by a forwarder thread that shares
//! the connection's write half behind a mutex with the request loop, so
//! pushes interleave safely with responses; a subscriber that stops
//! reading is dropped with a terminal `lagged` event (bounded queues in
//! the scheduler's bus — workers never block on a slow watcher).
//!
//! Lifecycle: `Daemon::start` binds, spawns workers + accept loop, and
//! returns a handle. Shutdown arrives either over the wire
//! (`{"cmd":"shutdown"}`) or via `DaemonHandle::shutdown`; `drain` finishes
//! queued work first. With a journal configured, every job event is
//! appended to an NDJSON sidecar and replayed on restart so the daemon
//! reports work done by previous incarnations.

use std::collections::HashMap;
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;

use crate::error::{Error, ErrorCode, Result};
use crate::field::{Field3, VecField3};
use crate::registration::groupwise;
use crate::serve::journal::Journal;
use crate::serve::proto::{
    read_request_line_bounded, EventMsg, JobSource, ReduceField, ReduceRequest, Request,
    Response, Verdict, MAX_LINE_BYTES, MAX_UPLOAD_LINE_BYTES, PROTO_V2_FEATURES,
    PROTO_VERSION,
};
use crate::serve::scheduler::{
    worker_loop, BusMsg, Executor, FailingExecutor, JobEvent, JobId, JobPayload, JobState,
    PjrtExecutor, Scheduler, WatchEvent, WatchHandle,
};
use crate::serve::store::{UploadReceipt, VolumeStore};
use crate::util::sync::thread::{self, JoinHandle};
use crate::util::sync::{Arc, Mutex};

/// Store pins held on behalf of admitted jobs: job id -> content ids
/// pinned at admission, released by the event sink when the job reaches a
/// terminal state. Keeps an admitted job's volumes (and warm-start
/// velocity) resident under store pressure for exactly the job's
/// queued+running life.
type JobPins = Arc<Mutex<HashMap<JobId, Vec<String>>>>;

/// Daemon configuration (CLI flags map 1:1 onto these).
#[derive(Clone, Debug)]
pub struct DaemonConfig {
    /// Bind address; use port 0 for an ephemeral port (tests).
    pub addr: String,
    pub workers: usize,
    /// Admission-control bound on *waiting* batch/urgent jobs.
    pub queue_cap: usize,
    /// Job journal path; `None` disables persistence.
    pub journal: Option<PathBuf>,
    /// Byte budget of the content-addressed volume store (`upload` verb);
    /// least-recently-used volumes are evicted beyond it.
    pub store_bytes: u64,
    /// Stable node identity reported by the v2 enriched `ping` (health
    /// probes, per-node stats in a fleet). `None` generates one at start —
    /// fine standalone, but fleet deployments should pin it so the router
    /// recognizes a node across restarts.
    pub node_id: Option<String>,
    /// Maximum subjects coalesced into one batched dispatch; 1 (the
    /// library default) disables coalescing entirely — every job runs
    /// alone, the pre-batching behavior. The `claire serve` CLI opts in
    /// with 8 unless `--coalesce-b` says otherwise.
    pub coalesce_b: usize,
    /// How long a worker dwells after popping a batch-priority job,
    /// waiting for compatible peers to coalesce with, before dispatching
    /// whatever it has. Any urgent arrival interrupts the dwell.
    pub coalesce_ms: u64,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        DaemonConfig {
            addr: "127.0.0.1:7464".into(),
            workers: 2,
            queue_cap: 64,
            journal: None,
            store_bytes: 1 << 30, // 1 GiB: sixteen 256^3 volumes
            node_id: None,
            coalesce_b: 1,
            coalesce_ms: 2,
        }
    }
}

/// FNV-1a-64 over the bound address, pid, and start time: unique enough
/// to tell two unnamed daemons apart, short enough to read in `status`.
fn generated_node_id(addr: &SocketAddr) -> String {
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in addr
        .to_string()
        .bytes()
        .chain(std::process::id().to_ne_bytes())
        .chain(t.to_ne_bytes())
    {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    format!("node-{h:016x}")
}

/// Per-worker executor constructor. Called once on each worker thread; a
/// failing factory degrades that worker to a clean job-failing stub rather
/// than taking the daemon down.
pub type ExecutorFactory = Arc<dyn Fn(usize) -> Result<Box<dyn Executor>> + Send + Sync>;

/// The production factory: each worker opens its own PJRT client + warm
/// operator cache over `artifacts_dir`.
pub fn pjrt_factory(artifacts_dir: PathBuf) -> ExecutorFactory {
    Arc::new(move |_worker| {
        Ok(Box::new(PjrtExecutor::open(&artifacts_dir)?) as Box<dyn Executor>)
    })
}

/// Handle to a started daemon: address, scheduler access, and join.
pub struct DaemonHandle {
    addr: SocketAddr,
    node_id: Arc<str>,
    scheduler: Scheduler,
    store: Arc<VolumeStore>,
    accept_thread: Option<JoinHandle<()>>,
    worker_threads: Vec<JoinHandle<()>>,
}

impl DaemonHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The identity this daemon reports in v2 `ping` probes (configured,
    /// or generated at start).
    pub fn node_id(&self) -> &str {
        &self.node_id
    }

    /// Direct scheduler access for in-process embedding (tests, benches).
    pub fn scheduler(&self) -> &Scheduler {
        &self.scheduler
    }

    /// Direct volume-store access for in-process embedding.
    pub fn store(&self) -> &VolumeStore {
        &self.store
    }

    /// Trigger shutdown from the host process (equivalent to the wire verb).
    pub fn shutdown(&self, drain: bool) {
        self.scheduler.shutdown(drain);
        wake_accept(self.addr);
    }

    /// Wait for workers and the accept loop to exit. Blocks until someone
    /// (wire or host) triggers shutdown.
    pub fn join(mut self) -> Result<()> {
        for t in self.worker_threads.drain(..) {
            t.join().map_err(|_| Error::Serve("worker thread panicked".into()))?;
        }
        if let Some(t) = self.accept_thread.take() {
            t.join().map_err(|_| Error::Serve("accept thread panicked".into()))?;
        }
        Ok(())
    }
}

/// Connect once to the listener so a blocked `accept` re-checks shutdown.
/// Wildcard binds (0.0.0.0 / ::) are not connectable on every platform,
/// so target loopback with the bound port in that case.
pub(crate) fn wake_accept(addr: SocketAddr) {
    let mut target = addr;
    if target.ip().is_unspecified() {
        target.set_ip(match target {
            SocketAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
            SocketAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
        });
    }
    let _ = TcpStream::connect(target);
}

pub struct Daemon;

impl Daemon {
    /// Bind, replay the journal, spawn workers and the accept loop.
    pub fn start(cfg: DaemonConfig, factory: ExecutorFactory) -> Result<DaemonHandle> {
        let scheduler = Scheduler::new(cfg.queue_cap, cfg.workers);
        scheduler.set_coalesce(cfg.coalesce_b, cfg.coalesce_ms);
        let store = Arc::new(VolumeStore::new(cfg.store_bytes));
        let pins: JobPins = Arc::new(Mutex::new(HashMap::new()));

        let journal = if let Some(path) = &cfg.journal {
            let prior = Journal::replay(path)?;
            scheduler.seed_prior_completed(Journal::completed_count(&prior));
            // Seed the id counter past prior incarnations so this run's
            // journal lines never collide with replayed ones on `id`.
            scheduler.seed_next_id(Journal::max_id(&prior) + 1);
            // Reseed exactly-once admission from prior incarnations: a
            // client retrying a submit across a daemon restart still gets
            // the original id back instead of a duplicate solve.
            for e in &prior {
                if e.event == "submitted" {
                    if let Some(tok) = &e.dedup {
                        scheduler.seed_dedup(tok, e.id);
                    }
                }
            }
            Some(Arc::new(Journal::open(path)?))
        } else {
            None
        };
        // One composite sink: journal (when configured) + admission-pin
        // release on terminal transitions. Always installed — pins must
        // drain even on journal-less daemons.
        {
            let pins = pins.clone();
            let store = store.clone();
            scheduler.set_event_sink(Box::new(move |ev| {
                if let Some(j) = &journal {
                    // Journal IO failure must not take down the scheduler;
                    // the journal is an audit trail, not the source of
                    // truth.
                    let _ = j.append(ev);
                }
                if let JobEvent::Finished { id, .. } | JobEvent::Cancelled { id, .. } = ev {
                    if let Some(held) = pins.lock().unwrap().remove(id) {
                        for vid in held {
                            store.unpin(&vid);
                        }
                    }
                }
            }));
        }

        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let node_id: Arc<str> =
            cfg.node_id.clone().unwrap_or_else(|| generated_node_id(&addr)).into();

        let mut worker_threads = Vec::with_capacity(cfg.workers.max(1));
        for w in 0..cfg.workers.max(1) {
            let sched = scheduler.clone();
            let factory = factory.clone();
            let worker_store = store.clone();
            worker_threads.push(thread::spawn(move || match factory(w) {
                Ok(mut exec) => {
                    // Give the executor the data plane so solve outputs
                    // (velocity, warped image) are retained for `reduce`.
                    exec.attach_store(worker_store);
                    worker_loop(&sched, w, exec.as_mut())
                }
                Err(e) => {
                    let mut failing =
                        FailingExecutor { msg: format!("worker {w} init failed: {e}") };
                    worker_loop(&sched, w, &mut failing);
                }
            }));
        }

        let sched = scheduler.clone();
        let accept_store = store.clone();
        let accept_node = node_id.clone();
        let accept_pins = pins.clone();
        let accept_thread = thread::spawn(move || {
            for conn in listener.incoming() {
                if sched.is_shutting_down() {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let sched = sched.clone();
                let store = accept_store.clone();
                let node = accept_node.clone();
                let pins = accept_pins.clone();
                thread::spawn(move || handle_connection(stream, sched, store, pins, addr, node));
            }
        });

        Ok(DaemonHandle {
            addr,
            node_id,
            scheduler,
            store,
            accept_thread: Some(accept_thread),
            worker_threads,
        })
    }
}

/// Write one protocol line (response or event) to a shared connection
/// writer. Returns false when the peer is gone.
pub(crate) fn write_line(writer: &Mutex<TcpStream>, line: &str) -> bool {
    let mut w = writer.lock().unwrap();
    w.write_all(line.as_bytes()).is_ok()
        && w.write_all(b"\n").is_ok()
        && w.flush().is_ok()
}

/// Forward scheduler bus messages to a watching connection until the
/// stream ends (unsubscribed, lagged, or the peer stops accepting
/// writes). Runs on its own thread; shares the connection's write half
/// with the request loop behind the mutex.
fn forward_events(
    handle: WatchHandle,
    writer: Arc<Mutex<TcpStream>>,
    sched: Scheduler,
    seq: Option<u64>,
) {
    while let Some(msg) = handle.recv() {
        let line = match msg {
            BusMsg::Event(ev) => event_to_msg(ev, seq).to_line(),
            BusMsg::Lagged => EventMsg::Lagged { seq }.to_line(),
        };
        if !write_line(&writer, &line) {
            break;
        }
    }
    // Idempotent: the request loop may already have unsubscribed us.
    sched.unwatch(handle.id());
}

fn event_to_msg(ev: WatchEvent, seq: Option<u64>) -> EventMsg {
    // Progress beats travel as their own event kind; lifecycle
    // transitions keep the original `job` grammar.
    if let Some(p) = ev.progress {
        return EventMsg::Progress {
            seq,
            id: ev.id,
            name: ev.name,
            iter: p.iters_done,
            level: p.level,
            beta: p.beta,
            j: p.j,
            grad_rel: p.grad_rel,
            alpha: p.alpha,
        };
    }
    EventMsg::Job {
        seq,
        id: ev.id,
        name: ev.name,
        state: ev.state,
        wall_s: ev.wall_s,
        error: ev.error,
    }
}

/// Serve one client connection: one NDJSON request per line, one NDJSON
/// response per line (v2 sessions additionally receive pushed watch
/// events), until EOF or a shutdown request. Requests are read under a
/// two-tier cap: `MAX_LINE_BYTES` normally, escalating to the
/// upload-sized bound only for lines that look like `upload` requests —
/// so a garbage flood cannot pin the large buffer per connection.
fn handle_connection(
    stream: TcpStream,
    sched: Scheduler,
    store: Arc<VolumeStore>,
    pins: JobPins,
    addr: SocketAddr,
    node_id: Arc<str>,
) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let writer = Arc::new(Mutex::new(stream));
    // Session state: v1 until a `hello` negotiates v2; at most one watch
    // subscription per connection.
    let mut v2 = false;
    let mut watch_sub: Option<u64> = None;
    // Encode a response for the session's protocol level.
    let render = |resp: &Response, v2: bool, seq: Option<u64>| -> String {
        if v2 {
            resp.to_line_v2(seq)
        } else {
            resp.to_line()
        }
    };
    loop {
        let line = match read_request_line_bounded(
            &mut reader,
            MAX_LINE_BYTES,
            MAX_UPLOAD_LINE_BYTES,
        ) {
            Ok(Some(l)) => l,
            Ok(None) => break,
            Err(e) => {
                // Oversized or broken line: answer once, drop the peer.
                let resp = Response::Error {
                    code: ErrorCode::BadRequest,
                    retryable: false,
                    msg: format!("bad request line: {e}"),
                };
                let _ = write_line(&writer, &render(&resp, v2, None));
                break;
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        let (raw_seq, parsed) = Request::parse_line(&line);
        let req = match parsed {
            Ok(r) => r,
            Err(e) => {
                // Malformed lines are always classified bad_request, and
                // never panic or drop the connection. (v1 sessions render
                // the opaque form and ignore `seq` entirely.)
                let resp = Response::Error {
                    code: ErrorCode::BadRequest,
                    retryable: false,
                    msg: e.to_string(),
                };
                let seq = if v2 { raw_seq } else { None };
                if !write_line(&writer, &render(&resp, v2, seq)) {
                    break;
                }
                continue;
            }
        };
        let (response, shutdown) = match req {
            Request::Hello { proto } => {
                // Negotiate min(client, server): a client announcing any
                // level >= 2 gets a v2 session at the highest level this
                // daemon speaks — a future PROTO_VERSION bump must not
                // downgrade already-shipped v2 clients to v1.
                if proto >= 2 {
                    v2 = true;
                    (
                        Response::Hello {
                            proto: proto.min(PROTO_VERSION),
                            features: PROTO_V2_FEATURES.iter().map(|s| s.to_string()).collect(),
                        },
                        None,
                    )
                } else {
                    // The client only speaks v1: the response names the
                    // level the session will use, so honor it — including
                    // downgrading an already-negotiated v2 session (and
                    // releasing its watch, which v1 cannot consume).
                    v2 = false;
                    if let Some(id) = watch_sub.take() {
                        sched.unwatch(id);
                    }
                    (Response::Hello { proto: 1, features: Vec::new() }, None)
                }
            }
            // v2-only verbs keep exact v1 semantics (unknown command) on
            // un-negotiated connections.
            Request::Watch if !v2 => (
                Response::from_error(&Error::wire(
                    ErrorCode::BadRequest,
                    "unknown command 'watch'",
                )),
                None,
            ),
            Request::SubmitBatch(_) if !v2 => (
                Response::from_error(&Error::wire(
                    ErrorCode::BadRequest,
                    "unknown command 'submit_batch'",
                )),
                None,
            ),
            Request::Reduce(_) if !v2 => (
                Response::from_error(&Error::wire(
                    ErrorCode::BadRequest,
                    "unknown command 'reduce'",
                )),
                None,
            ),
            Request::Watch => {
                // A dead subscription (lagged out, or its forwarder hit a
                // write error) no longer counts: the documented recovery
                // from a `lagged` event is to re-issue `watch`.
                if watch_sub.is_some_and(|id| sched.is_watching(id)) {
                    (
                        Response::from_error(&Error::wire(
                            ErrorCode::InvalidState,
                            "this connection is already watching",
                        )),
                        None,
                    )
                } else {
                    let handle = sched.watch();
                    watch_sub = Some(handle.id());
                    let fw_writer = writer.clone();
                    let fw_sched = sched.clone();
                    thread::spawn(move || {
                        forward_events(handle, fw_writer, fw_sched, raw_seq)
                    });
                    (Response::Ok, None)
                }
            }
            Request::SubmitBatch(specs) => {
                let verdicts = specs
                    .into_iter()
                    .map(|spec| Verdict::from_result(admit(spec, &sched, &store, &pins)))
                    .collect();
                (Response::Batch(verdicts), None)
            }
            // v2 ping is a health probe: identity + load, cheap enough to
            // hit every probe interval. v1 ping keeps its exact
            // `{"ok":true}` bytes via the dispatch fallthrough below.
            Request::Ping if v2 => {
                let s = sched.stats();
                (
                    Response::Pong {
                        node: node_id.to_string(),
                        proto: PROTO_VERSION,
                        queued: s.queued,
                        running: s.running,
                    },
                    None,
                )
            }
            other => dispatch(other, &sched, &store, &pins),
        };
        // The gate uses the *post-dispatch* session level, so a `hello`
        // that just upgraded the connection echoes its own `seq`; v1
        // sessions ignore `seq` entirely (exact v1 bytes).
        let seq = if v2 { raw_seq } else { None };
        if !write_line(&writer, &render(&response, v2, seq)) {
            break;
        }
        if let Some(drain) = shutdown {
            sched.shutdown(drain);
            wake_accept(addr);
            break;
        }
    }
    // EOF-driven cleanup: closing the subscription wakes the forwarder,
    // which exits on its next recv.
    if let Some(id) = watch_sub {
        sched.unwatch(id);
    }
}

/// Resolve a submit spec into a scheduler payload. Synthetic jobs pass
/// through; uploaded-source jobs resolve their content ids against the
/// store *now* (admission time), so later eviction cannot invalidate an
/// admitted job, and shape mismatches are rejected before queueing.
///
/// Every resolved id is pinned against LRU eviction before returning;
/// the second tuple element lists those held pins so `admit` can hand
/// them to the terminal-event sink (or release them if submission
/// fails).
fn resolve_submit(
    spec: crate::serve::proto::JobSpec,
    store: &VolumeStore,
) -> Result<(JobPayload, Vec<String>)> {
    match spec.source.clone() {
        JobSource::Synthetic => {
            if spec.warm_start.is_some() {
                return Err(Error::wire(
                    ErrorCode::BadRequest,
                    "warm_start requires an uploaded-source job",
                ));
            }
            Ok((JobPayload::Spec(spec), Vec::new()))
        }
        JobSource::Uploaded { m0, m1 } => {
            let fetch = |id: &str| {
                store.get(id).ok_or_else(|| {
                    Error::wire(
                        ErrorCode::UnknownVolume,
                        format!(
                            "unknown volume id '{id}' (never uploaded, or evicted — re-upload)"
                        ),
                    )
                })
            };
            let f0 = fetch(&m0)?;
            let f1 = fetch(&m1)?;
            if f0.n != spec.n || f1.n != spec.n {
                return Err(Error::wire(
                    ErrorCode::ShapeMismatch,
                    format!(
                        "job n = {} does not match uploaded volumes (m0 {}^3, m1 {}^3)",
                        spec.n, f0.n, f1.n
                    ),
                ));
            }
            let warm_start = match &spec.warm_start {
                None => None,
                Some(ws) => {
                    let v = store.get_vec(ws).ok_or_else(|| {
                        Error::wire(
                            ErrorCode::UnknownVolume,
                            format!(
                                "unknown velocity id '{ws}' (never uploaded, or evicted — re-upload)"
                            ),
                        )
                    })?;
                    if v.n != spec.n {
                        return Err(Error::wire(
                            ErrorCode::ShapeMismatch,
                            format!(
                                "job n = {} does not match warm_start velocity ({}^3)",
                                spec.n, v.n
                            ),
                        ));
                    }
                    Some(v)
                }
            };
            let mut held = vec![m0, m1];
            if let Some(ws) = &spec.warm_start {
                held.push(ws.clone());
            }
            for id in &held {
                store.pin(id);
            }
            Ok((JobPayload::Volumes { spec, m0: f0, m1: f1, warm_start }, held))
        }
    }
}

/// Admit one job: validate (the single `JobRequest::validate` path),
/// resolve its payload against the store (pinning every resolved id),
/// and submit to the scheduler. Shared by `submit` and `submit_batch`.
///
/// Pin lifecycle: the held ids are registered under the job id so the
/// terminal-event sink releases them when the job finishes or is
/// cancelled. Two races are closed here: a dedup hit returns an id
/// whose pins are already registered (the fresh pins are released
/// immediately), and a job can reach a terminal state before its entry
/// lands in the map (checked after registration, released inline).
fn admit(
    spec: crate::serve::proto::JobSpec,
    sched: &Scheduler,
    store: &VolumeStore,
    pins: &JobPins,
) -> Result<crate::serve::scheduler::JobId> {
    spec.validate()?;
    let priority = spec.priority;
    let dedup = spec.dedup.clone();
    let (payload, held) = resolve_submit(spec, store)?;
    match sched.submit_dedup(priority, payload, dedup) {
        Ok(id) => {
            let stale = {
                let mut map = pins.lock().unwrap();
                if map.contains_key(&id) {
                    // Dedup hit: the original admission's pins stand.
                    Some(held)
                } else {
                    map.insert(id, held);
                    None
                }
            };
            if let Some(fresh) = stale {
                for vid in fresh {
                    store.unpin(&vid);
                }
            } else if sched.status(id).is_some_and(|v| v.state.is_terminal()) {
                // Fast-finish race: the sink fired before our insert.
                if let Some(held) = pins.lock().unwrap().remove(&id) {
                    for vid in held {
                        store.unpin(&vid);
                    }
                }
            }
            Ok(id)
        }
        Err(e) => {
            for vid in held {
                store.unpin(&vid);
            }
            Err(e)
        }
    }
}

/// Run one decoded request against the scheduler + store. Returns the
/// response plus `Some(drain)` when the daemon should shut down.
/// (`hello`/`watch`/`submit_batch` are session-level and handled by the
/// connection loop.)
fn dispatch(
    req: Request,
    sched: &Scheduler,
    store: &VolumeStore,
    pins: &JobPins,
) -> (Response, Option<bool>) {
    match req {
        Request::Ping => (Response::Ok, None),
        Request::Upload { n, data } => match store.put(n, data) {
            Ok(r) => (Response::Uploaded { id: r.id, n: r.n, dedup: r.dedup }, None),
            Err(e) => (Response::from_error(&e), None),
        },
        Request::Submit(spec) => match admit(spec, sched, store, pins) {
            Ok(id) => (Response::Submitted { id }, None),
            Err(e) => (Response::from_error(&e), None),
        },
        Request::Reduce(r) => match handle_reduce(r, sched, store) {
            Ok(resp) => (resp, None),
            Err(e) => (Response::from_error(&e), None),
        },
        Request::Status(None) => (Response::Jobs(sched.jobs()), None),
        Request::Status(Some(id)) => match sched.status(id) {
            Some(v) => (Response::Job(v), None),
            // Built directly (no `serve error: ` prefix): the pre-v2
            // daemon formatted this one message inline rather than through
            // `Error::Serve`, and those bytes are the v1 compat surface.
            None => (
                Response::Error {
                    code: ErrorCode::UnknownJob,
                    retryable: false,
                    msg: format!("no such job {id}"),
                },
                None,
            ),
        },
        Request::Cancel(id) => match sched.cancel(id) {
            Ok(()) => (Response::Ok, None),
            Err(e) => (Response::from_error(&e), None),
        },
        Request::Stats => {
            // The scheduler does not own the store; overlay its counters
            // so the wire stats show the whole data plane.
            let mut s = sched.stats();
            s.store = store.stats();
            (Response::Stats(s), None)
        }
        Request::Shutdown { drain } => (Response::Ok, Some(drain)),
        // Session-level verbs never reach here (connection loop handles
        // them); answering bad_request keeps this total, not a panic.
        Request::Hello { .. } | Request::Watch | Request::SubmitBatch(_) => (
            Response::from_error(&Error::wire(
                ErrorCode::BadRequest,
                "session verb outside a connection",
            )),
            None,
        ),
    }
}

/// Collect the retained output ids named by a jobs-mode reduce. Every
/// job must exist, be done, and have retained the requested field (an
/// executor without store retention leaves both fields empty — that is
/// an invalid_state, not a missing volume).
fn job_output_ids(
    jobs: &[crate::serve::scheduler::JobId],
    field: ReduceField,
    sched: &Scheduler,
) -> Result<Vec<String>> {
    let mut out = Vec::with_capacity(jobs.len());
    for &id in jobs {
        let view = sched
            .status(id)
            .ok_or_else(|| Error::wire(ErrorCode::UnknownJob, format!("no such job {id}")))?;
        if view.state != JobState::Done {
            return Err(Error::wire(
                ErrorCode::InvalidState,
                format!("job {id} is {} — reduce requires done jobs", view.state.as_str()),
            ));
        }
        let vid = match field {
            ReduceField::Velocity => view.velocity,
            ReduceField::Warped => view.warped,
        };
        out.push(vid.ok_or_else(|| {
            Error::wire(
                ErrorCode::InvalidState,
                format!("job {id} retained no {} output", field.as_str()),
            )
        })?);
    }
    Ok(out)
}

/// Execute a `reduce` verb: average the named inputs server-side, land
/// the result in the content-addressed store, and answer with its
/// receipt — volumes never round-trip through the client.
///
/// Modes (`jobs` and `ids` are mutually exclusive, enforced at parse):
/// - `ids` — plain mean of stored scalar volumes (round-0 template
///   bootstrap). `scale`/`apply` are meaningless here and rejected.
/// - `jobs` + field `velocity` — log-domain mean of the retained
///   velocities, optionally scaled, then either stored as a velocity or
///   (with `apply`) exponentiated and used to warp the named template,
///   storing the warped scalar.
/// - `jobs` + field `warped` — plain mean of the retained warped
///   images. `scale`/`apply` rejected as in `ids` mode.
///
/// `ref` only makes sense against a scalar result (rel_change is
/// scalar-only); `pin` pins the result, `unpin` releases the previous
/// round's template after success.
fn handle_reduce(r: ReduceRequest, sched: &Scheduler, store: &VolumeStore) -> Result<Response> {
    if r.jobs.is_empty() == r.ids.is_empty() {
        return Err(Error::wire(
            ErrorCode::BadRequest,
            "reduce requires exactly one of 'jobs' or 'ids'",
        ));
    }
    let fetch_scalar = |id: &str, what: &str| {
        store.get(id).ok_or_else(|| {
            Error::wire(
                ErrorCode::UnknownVolume,
                format!("unknown {what} id '{id}' (never uploaded, or evicted — re-upload)"),
            )
        })
    };
    let velocity_mode = r.ids.is_empty() && r.field == ReduceField::Velocity;
    if !velocity_mode && (r.scale.is_some() || r.apply.is_some()) {
        return Err(Error::wire(
            ErrorCode::BadRequest,
            "'scale'/'apply' only apply to a velocity reduce",
        ));
    }
    if r.ref_id.is_some() && velocity_mode && r.apply.is_none() {
        return Err(Error::wire(
            ErrorCode::BadRequest,
            "'ref' requires a scalar result (use 'apply', field 'warped', or 'ids')",
        ));
    }
    let count = r.jobs.len().max(r.ids.len());
    // Compute the result volume: a scalar mean, or a velocity mean that
    // is either stored directly or applied to a template.
    let (receipt, kind): (UploadReceipt, &str) = if velocity_mode {
        let vids = job_output_ids(&r.jobs, ReduceField::Velocity, sched)?;
        let vols: Vec<_> = vids
            .iter()
            .map(|id| {
                store.get_vec(id).ok_or_else(|| {
                    Error::wire(
                        ErrorCode::UnknownVolume,
                        format!("retained velocity '{id}' was evicted — re-run the job"),
                    )
                })
            })
            .collect::<Result<_>>()?;
        let refs: Vec<&VecField3> = vols.iter().map(|a| a.as_ref()).collect();
        let mut mean = groupwise::log_mean(&refs)?;
        if let Some(s) = r.scale {
            mean = groupwise::scale(&mean, s);
        }
        match &r.apply {
            None => (store.put_vec(mean.n, mean.data)?, "velocity"),
            Some(tid) => {
                let template = fetch_scalar(tid, "template")?;
                let phi = groupwise::exponential(&mean);
                let warped = groupwise::warp_scalar(&template, &phi)?;
                (store.put(warped.n, warped.data)?, "scalar")
            }
        }
    } else {
        let ids = if r.ids.is_empty() {
            job_output_ids(&r.jobs, ReduceField::Warped, sched)?
        } else {
            r.ids.clone()
        };
        let vols: Vec<_> = ids
            .iter()
            .map(|id| fetch_scalar(id, "volume"))
            .collect::<Result<_>>()?;
        let refs: Vec<&Field3> = vols.iter().map(|a| a.as_ref()).collect();
        let mean = groupwise::mean_scalar(&refs)?;
        (store.put(mean.n, mean.data)?, "scalar")
    };
    let delta_rel = match &r.ref_id {
        None => None,
        Some(rid) => {
            debug_assert_eq!(kind, "scalar", "ref gated above");
            let reference = fetch_scalar(rid, "ref")?;
            let result = store.get(&receipt.id).ok_or_else(|| {
                Error::wire(
                    ErrorCode::InvalidState,
                    format!("reduce result '{}' evicted before delta", receipt.id),
                )
            })?;
            Some(groupwise::rel_change(&result, &reference)?)
        }
    };
    if r.pin {
        store.pin(&receipt.id);
    }
    if let Some(u) = &r.unpin {
        store.unpin(u);
    }
    Ok(Response::Reduced {
        id: receipt.id,
        n: receipt.n,
        kind: kind.to_string(),
        count,
        bytes: receipt.bytes,
        dedup: receipt.dedup,
        delta_rel,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::client::Client;
    use crate::serve::proto::{JobSpec, Priority};
    use crate::serve::scheduler::{stub_report, JobState};

    /// Instant stub executor with a per-(variant, n) warm cache emulation.
    /// When a store is attached it retains deterministic outputs for
    /// uploaded-source jobs — a constant velocity keyed by the job name
    /// and the midpoint image — so jobs-mode `reduce` is exercisable
    /// without PJRT.
    struct Stub {
        seen: std::collections::BTreeSet<(String, usize)>,
        compiles: u64,
        hits: u64,
        store: Option<Arc<VolumeStore>>,
    }

    impl Executor for Stub {
        fn execute(
            &mut self,
            payload: &JobPayload,
            _cx: &crate::registration::SolveCx,
        ) -> Result<crate::serve::scheduler::ExecOutcome> {
            let (variant, n, name) = match payload {
                JobPayload::Spec(s) | JobPayload::Volumes { spec: s, .. } => {
                    (s.variant.clone(), s.n, s.name())
                }
                JobPayload::Problem { problem, params } => {
                    (params.variant.clone(), problem.n(), problem.name.clone())
                }
            };
            // Each job touches a handful of operators for its (variant, n):
            // first job compiles them, subsequent same-shape jobs hit warm.
            if self.seen.insert((variant, n)) {
                self.compiles += 5;
            } else {
                self.hits += 5;
            }
            let mut report = stub_report(&name);
            // Reflect the multires request the way the real executor's
            // RunReport would (realized == requested for the stub).
            if let JobPayload::Spec(s) | JobPayload::Volumes { spec: s, .. } = payload {
                report.levels = s.multires.unwrap_or(1);
            }
            let mut outcome: crate::serve::scheduler::ExecOutcome = report.into();
            if let (Some(store), JobPayload::Volumes { spec, m0, m1, .. }) =
                (&self.store, payload)
            {
                let seed =
                    (name.bytes().map(u64::from).sum::<u64>() % 7) as f32 * 0.01;
                let vdata = vec![seed; 3 * spec.n * spec.n * spec.n];
                let wdata: Vec<f32> =
                    m0.data.iter().zip(&m1.data).map(|(a, b)| 0.5 * (a + b)).collect();
                outcome.velocity = store.put_vec(spec.n, vdata).ok().map(|r| r.id);
                outcome.warped = store.put(spec.n, wdata).ok().map(|r| r.id);
            }
            Ok(outcome)
        }

        fn attach_store(&mut self, store: Arc<VolumeStore>) {
            self.store = Some(store);
        }

        fn cache_stats(&self) -> (u64, u64) {
            (self.compiles, self.hits)
        }
    }

    fn stub_factory() -> ExecutorFactory {
        Arc::new(|_w| {
            Ok(Box::new(Stub {
                seen: Default::default(),
                compiles: 0,
                hits: 0,
                store: None,
            }) as Box<dyn Executor>)
        })
    }

    fn test_config() -> DaemonConfig {
        DaemonConfig {
            addr: "127.0.0.1:0".into(),
            workers: 1,
            queue_cap: 16,
            ..Default::default()
        }
    }

    #[test]
    fn serve_round_trip_smoke() {
        // The CI smoke test: ping, submit, poll to done, then the data
        // plane (upload pair -> uploaded multires submit -> done), stats,
        // shutdown.
        let handle = Daemon::start(test_config(), stub_factory()).unwrap();
        let mut client = Client::connect(&handle.addr().to_string()).unwrap();
        client.ping().unwrap();
        let id = client
            .submit(&JobSpec { priority: Priority::Urgent, ..Default::default() })
            .unwrap();
        let view = client.wait_terminal(id, 5.0).unwrap();
        assert_eq!(view.state, JobState::Done);
        assert_eq!(view.priority, Priority::Urgent);

        // Data plane: ship a 4^3 pair, register it coarse-to-fine.
        let m0: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let m1: Vec<f32> = (0..64).map(|i| 64.0 - i as f32).collect();
        let r0 = client.upload(4, &m0).unwrap();
        let r1 = client.upload(4, &m1).unwrap();
        assert_ne!(r0.id, r1.id);
        assert!(!r0.dedup && !r1.dedup);
        let up_id = client
            .submit(&JobSpec {
                n: 4,
                source: crate::serve::proto::JobSource::Uploaded {
                    m0: r0.id.clone(),
                    m1: r1.id.clone(),
                },
                multires: Some(2),
                ..Default::default()
            })
            .unwrap();
        let up_view = client.wait_terminal(up_id, 5.0).unwrap();
        assert_eq!(up_view.state, JobState::Done);
        assert!(up_view.name.starts_with("up:"), "{}", up_view.name);
        assert_eq!(up_view.levels, Some(2), "realized multires depth visible");

        let stats = client.stats().unwrap();
        assert_eq!(stats.completed, 2);
        assert_eq!(stats.submitted, 2);
        // 2 wire uploads + the uploaded job's retained velocity + warped
        // outputs (the stub retains like the real executor).
        assert_eq!(stats.store.volumes, 4);
        assert_eq!(stats.store.uploads, 4);
        client.shutdown(true).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn wire_errors_are_reported_not_fatal() {
        let handle = Daemon::start(test_config(), stub_factory()).unwrap();
        let mut client = Client::connect(&handle.addr().to_string()).unwrap();
        // Unknown job id and malformed cancel both produce error responses
        // on a connection that stays usable.
        assert!(client.status(999).is_err());
        assert!(client.cancel(999).is_err());
        client.ping().unwrap();
        client.shutdown(false).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn oversized_request_line_is_rejected_not_buffered() {
        use crate::serve::proto::read_line_bounded;

        let handle = Daemon::start(test_config(), stub_factory()).unwrap();
        let mut s = TcpStream::connect(handle.addr()).unwrap();
        // Stream past the small request cap with no newline and nothing
        // upload-shaped in the prefix: the daemon must cut us off at the
        // *small* bound (a garbage flood never earns the 96 MiB upload
        // buffer). Writes may hit a broken pipe once the daemon gives up —
        // fine.
        let chunk = vec![b'a'; 64 * 1024];
        for _ in 0..((MAX_LINE_BYTES / chunk.len()) + 2) {
            if s.write_all(&chunk).is_err() {
                break;
            }
        }
        let _ = s.flush();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        match read_line_bounded(&mut reader, MAX_LINE_BYTES) {
            Ok(Some(resp)) => {
                assert!(resp.contains("\"ok\":false"), "unexpected response: {resp}")
            }
            // Connection may be reset before the error line reaches us;
            // the property under test is that the daemon cut us off.
            Ok(None) | Err(_) => {}
        }
        handle.shutdown(false);
        handle.join().unwrap();
    }

    #[test]
    fn failing_worker_factory_fails_jobs_cleanly() {
        let factory: ExecutorFactory =
            Arc::new(|_w| Err(Error::Serve("no artifacts here".into())));
        let handle = Daemon::start(test_config(), factory).unwrap();
        let mut client = Client::connect(&handle.addr().to_string()).unwrap();
        let id = client.submit(&JobSpec::default()).unwrap();
        let view = client.wait_terminal(id, 5.0).unwrap();
        assert_eq!(view.state, JobState::Failed);
        assert!(view.error.unwrap().contains("no artifacts here"));
        client.shutdown(true).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn reduce_ids_bootstrap_pins_and_deltas() {
        let handle = Daemon::start(test_config(), stub_factory()).unwrap();
        let mut c = Client::connect(&handle.addr().to_string()).unwrap();
        c.hello().unwrap();
        let a: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..64).map(|i| i as f32 * 3.0).collect();
        let ra = c.upload(4, &a).unwrap();
        let rb = c.upload(4, &b).unwrap();
        // Round-0 bootstrap: the template is the plain mean, pinned.
        let t0 = c
            .reduce(&ReduceRequest {
                ids: vec![ra.id.clone(), rb.id.clone()],
                pin: true,
                ..Default::default()
            })
            .unwrap();
        assert_eq!((t0.kind.as_str(), t0.count, t0.n), ("scalar", 2, 4));
        assert!(t0.delta_rel.is_none());
        assert_eq!(c.stats().unwrap().store.pinned, 1);
        // The mean of the same inputs is content-identical: a dedup
        // receipt and zero relative change against the previous template.
        let t1 = c
            .reduce(&ReduceRequest {
                ids: vec![ra.id.clone(), rb.id.clone()],
                ref_id: Some(t0.id.clone()),
                pin: true,
                unpin: Some(t0.id.clone()),
                ..Default::default()
            })
            .unwrap();
        assert_eq!(t1.id, t0.id);
        assert!(t1.dedup);
        assert_eq!(t1.delta_rel, Some(0.0));
        // pin (+1) then unpin (-1) on the same entry: still pinned once.
        assert_eq!(c.stats().unwrap().store.pinned, 1);
        // scale/apply are velocity-mode knobs; ids mode rejects them.
        let err = c
            .reduce(&ReduceRequest {
                ids: vec![ra.id.clone()],
                scale: Some(0.5),
                ..Default::default()
            })
            .unwrap_err();
        assert_eq!(err.code(), ErrorCode::BadRequest);
        c.shutdown(false).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn reduce_jobs_averages_retained_outputs() {
        let handle = Daemon::start(test_config(), stub_factory()).unwrap();
        let mut c = Client::connect(&handle.addr().to_string()).unwrap();
        c.hello().unwrap();
        let a: Vec<f32> = (0..64).map(|i| (i as f32 * 0.1).sin()).collect();
        let b: Vec<f32> = (0..64).map(|i| (i as f32 * 0.1).cos()).collect();
        let ra = c.upload(4, &a).unwrap();
        let rb = c.upload(4, &b).unwrap();
        let spec = |m0: &str, m1: &str| JobSpec {
            n: 4,
            source: JobSource::Uploaded { m0: m0.into(), m1: m1.into() },
            ..Default::default()
        };
        let j1 = c.submit(&spec(&ra.id, &rb.id)).unwrap();
        let j2 = c.submit(&spec(&rb.id, &ra.id)).unwrap();
        let done1 = c.wait_terminal(j1, 5.0).unwrap();
        let done2 = c.wait_terminal(j2, 5.0).unwrap();
        assert!(done1.velocity.is_some() && done1.warped.is_some(), "stub retains outputs");
        assert!(done2.velocity.is_some());

        // Log-domain mean of the retained velocities, stored as one.
        let vel =
            c.reduce(&ReduceRequest { jobs: vec![j1, j2], ..Default::default() }).unwrap();
        assert_eq!((vel.kind.as_str(), vel.count, vel.n), ("velocity", 2, 4));
        // Apply mode: exp(scale * mean) warps the template server-side,
        // and `ref` reports the drift against the previous template.
        let warped_t = c
            .reduce(&ReduceRequest {
                jobs: vec![j1, j2],
                scale: Some(0.5),
                apply: Some(ra.id.clone()),
                ref_id: Some(ra.id.clone()),
                ..Default::default()
            })
            .unwrap();
        assert_eq!(warped_t.kind, "scalar");
        assert!(warped_t.delta_rel.is_some());
        // Warped-image fallback: plain mean of the retained warps.
        let wm = c
            .reduce(&ReduceRequest {
                jobs: vec![j1, j2],
                field: ReduceField::Warped,
                ..Default::default()
            })
            .unwrap();
        assert_eq!(wm.kind, "scalar");
        // Error surface: unknown job; `ref` against a raw-velocity result.
        let err =
            c.reduce(&ReduceRequest { jobs: vec![999], ..Default::default() }).unwrap_err();
        assert_eq!(err.code(), ErrorCode::UnknownJob);
        let err = c
            .reduce(&ReduceRequest {
                jobs: vec![j1],
                ref_id: Some(ra.id.clone()),
                ..Default::default()
            })
            .unwrap_err();
        assert_eq!(err.code(), ErrorCode::BadRequest);
        c.shutdown(true).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn warm_start_resolves_and_validates_at_admission() {
        let handle = Daemon::start(test_config(), stub_factory()).unwrap();
        let mut c = Client::connect(&handle.addr().to_string()).unwrap();
        c.hello().unwrap();
        let ra = c.upload(4, &(0..64).map(|i| i as f32).collect::<Vec<f32>>()).unwrap();
        let rb = c.upload(4, &vec![1.0f32; 64]).unwrap();
        let base = JobSpec {
            n: 4,
            source: JobSource::Uploaded { m0: ra.id.clone(), m1: rb.id.clone() },
            ..Default::default()
        };
        // Synthetic jobs have no uploaded pair to seed.
        let err = c
            .submit(&JobSpec { warm_start: Some("x".into()), ..Default::default() })
            .unwrap_err();
        assert_eq!(err.code(), ErrorCode::BadRequest);
        // The velocity id must resolve in the store at admission.
        let err = c
            .submit(&JobSpec { warm_start: Some("missing".into()), ..base.clone() })
            .unwrap_err();
        assert_eq!(err.code(), ErrorCode::UnknownVolume);
        // A done job's retained velocity is a valid warm start for the
        // next round; once terminal, every admission pin is released.
        let j1 = c.submit(&base).unwrap();
        let vel = c.wait_terminal(j1, 5.0).unwrap().velocity.unwrap();
        let j2 = c.submit(&JobSpec { warm_start: Some(vel), ..base.clone() }).unwrap();
        assert_eq!(c.wait_terminal(j2, 5.0).unwrap().state, JobState::Done);
        c.wait_idle(5.0).unwrap();
        assert_eq!(c.stats().unwrap().store.pinned, 0, "terminal jobs hold no pins");
        c.shutdown(false).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn out_of_range_submit_is_rejected_at_admission() {
        // Range validation moved from wire decode into the single
        // validate() path — the daemon must still refuse a 5000^3 job
        // before anything is queued or allocated.
        let handle = Daemon::start(test_config(), stub_factory()).unwrap();
        let mut client = Client::connect(&handle.addr().to_string()).unwrap();
        let err = client.submit(&JobSpec { n: 5000, ..Default::default() }).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        let err = client
            .submit(&JobSpec { multires: Some(9), ..Default::default() })
            .unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        assert_eq!(client.stats().unwrap().submitted, 0, "nothing queued");
        client.shutdown(false).unwrap();
        handle.join().unwrap();
    }
}
