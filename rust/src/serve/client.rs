//! Typed client for the registration daemon's NDJSON wire protocol.
//!
//! One TCP connection, synchronous request/response: write one line, read
//! one line. Used by the `submit`/`status`/`shutdown` CLI subcommands and
//! by `examples/clinical_batch.rs` when pointed at a live daemon.

use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::serve::proto::{read_line_bounded, JobSpec, Request, Response, MAX_LINE_BYTES};
use crate::serve::scheduler::{JobId, JobView, ServeStats};
use crate::serve::store::UploadReceipt;
use crate::util::bench::Table;

/// Render job views as an aligned table (shared by the CLI `status`
/// subcommand and the daemon-mode example).
pub fn job_table(jobs: &[JobView]) -> Table {
    let mut t = Table::new(&[
        "id", "job", "prio", "state", "order", "lat[s]", "solve[s]", "mism", "lvls", "err",
    ]);
    let fo = |x: Option<f64>| x.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into());
    for v in jobs {
        t.row(&[
            v.id.to_string(),
            v.name.clone(),
            v.priority.as_str().into(),
            v.state.as_str().into(),
            v.dispatch_seq.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
            fo(v.latency_s),
            fo(v.wall_s),
            v.mismatch_rel.map(|m| format!("{m:.1e}")).unwrap_or_else(|| "-".into()),
            v.levels.map(|l| l.to_string()).unwrap_or_else(|| "-".into()),
            v.error.clone().unwrap_or_else(|| "-".into()),
        ]);
    }
    t
}

pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Connect to `addr` (e.g. "127.0.0.1:7464").
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::Serve(format!("cannot reach daemon at {addr}: {e}")))?;
        let read_half = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(read_half), writer: stream })
    }

    /// One request/response exchange.
    fn call(&mut self, req: &Request) -> Result<Response> {
        self.writer.write_all(req.to_line().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let Some(line) = read_line_bounded(&mut self.reader, MAX_LINE_BYTES)? else {
            return Err(Error::Serve("daemon closed the connection".into()));
        };
        match Response::parse(&line)? {
            Response::Error(msg) => Err(Error::Serve(msg)),
            other => Ok(other),
        }
    }

    pub fn ping(&mut self) -> Result<()> {
        self.call(&Request::Ping).map(|_| ())
    }

    /// Ship one volume (n^3 f32 samples) into the daemon's
    /// content-addressed store; returns the receipt whose `id` a
    /// subsequent `submit` references via `JobSource::Uploaded`.
    /// Re-uploading identical content is cheap (`dedup` flags it).
    pub fn upload(&mut self, n: usize, data: &[f32]) -> Result<UploadReceipt> {
        match self.call(&Request::Upload { n, data: data.to_vec() })? {
            Response::Uploaded { id, n, dedup } => Ok(UploadReceipt {
                id,
                n,
                bytes: (n * n * n * 4) as u64,
                dedup,
            }),
            other => Err(Error::Serve(format!("unexpected upload response: {other:?}"))),
        }
    }

    /// Submit a job; returns the daemon-assigned job id.
    pub fn submit(&mut self, spec: &JobSpec) -> Result<JobId> {
        match self.call(&Request::Submit(spec.clone()))? {
            Response::Submitted { id } => Ok(id),
            other => Err(Error::Serve(format!("unexpected submit response: {other:?}"))),
        }
    }

    pub fn status(&mut self, id: JobId) -> Result<JobView> {
        match self.call(&Request::Status(Some(id)))? {
            Response::Job(v) => Ok(v),
            other => Err(Error::Serve(format!("unexpected status response: {other:?}"))),
        }
    }

    /// All jobs the daemon knows about, id-ordered.
    pub fn jobs(&mut self) -> Result<Vec<JobView>> {
        match self.call(&Request::Status(None))? {
            Response::Jobs(v) => Ok(v),
            other => Err(Error::Serve(format!("unexpected status response: {other:?}"))),
        }
    }

    pub fn cancel(&mut self, id: JobId) -> Result<()> {
        self.call(&Request::Cancel(id)).map(|_| ())
    }

    pub fn stats(&mut self) -> Result<ServeStats> {
        match self.call(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => Err(Error::Serve(format!("unexpected stats response: {other:?}"))),
        }
    }

    pub fn shutdown(&mut self, drain: bool) -> Result<()> {
        self.call(&Request::Shutdown { drain }).map(|_| ())
    }

    /// Poll `status` until the job reaches a terminal state or `timeout_s`
    /// elapses.
    pub fn wait_terminal(&mut self, id: JobId, timeout_s: f64) -> Result<JobView> {
        let t0 = Instant::now();
        loop {
            let view = self.status(id)?;
            if view.state.is_terminal() {
                return Ok(view);
            }
            if t0.elapsed().as_secs_f64() > timeout_s {
                return Err(Error::Serve(format!(
                    "timeout waiting for job {id} (still {})",
                    view.state.as_str()
                )));
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    /// Poll until the daemon is idle (no queued or running jobs) or
    /// `timeout_s` elapses; returns the final stats.
    pub fn wait_idle(&mut self, timeout_s: f64) -> Result<ServeStats> {
        let t0 = Instant::now();
        loop {
            let s = self.stats()?;
            if s.queued == 0 && s.running == 0 {
                return Ok(s);
            }
            if t0.elapsed().as_secs_f64() > timeout_s {
                return Err(Error::Serve(format!(
                    "timeout waiting for idle ({} queued, {} running)",
                    s.queued, s.running
                )));
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }
}
