//! Typed client for the registration daemon's NDJSON wire protocol.
//!
//! One TCP connection. By default the client speaks v1 (write one line,
//! read one line); [`Client::hello`] negotiates protocol v2, after which
//! every request carries a client-chosen `seq` that the daemon echoes in
//! its response (verified here — a desynchronized connection fails loudly
//! instead of mis-pairing answers), errors surface their structured
//! [`ErrorCode`] via [`Error::Wire`], and [`Client::watch`] subscribes the
//! connection to server-pushed job events read with
//! [`Client::next_event`]. Used by the CLI subcommands and by
//! `examples/clinical_batch.rs` when pointed at a live daemon.
//!
//! Timeouts: [`Client::connect_with_timeout`] bounds connect plus every
//! read/write, so a hung daemon fails the call with an I/O error instead
//! of wedging the process forever. A client that hits a read timeout
//! should drop the connection (a partially-read line cannot be resumed).

use std::collections::VecDeque;
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::serve::proto::{
    read_line_bounded, upload_line, EventMsg, JobSpec, ReduceRequest, Request, Response,
    Verdict, MAX_LINE_BYTES, PROTO_VERSION,
};
use crate::serve::scheduler::{JobId, JobView, ServeStats};
use crate::serve::store::UploadReceipt;
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::sync::thread;

/// Jittered-exponential-backoff retry policy for wire-retryable daemon
/// rejections (`queue_full`, `shutting_down`). Attempt `k` sleeps a
/// uniform draw from `[0, min(max_ms, base_ms * 2^k))` — "full jitter",
/// so a burst of clients rejected together does not reconverge on the
/// daemon in lockstep.
///
/// Only [`Error::Wire`] codes whose [`ErrorCode::retryable`] is true are
/// retried: the daemon answered cleanly and the connection is intact.
/// Transport failures are *not* retried here even though scripts treat
/// them as retryable — after a half-read line the connection state is
/// unknown, so the recovery is a reconnect (what the fleet router's
/// backend pool does), not a resend.
///
/// [`ErrorCode::retryable`]: crate::ErrorCode::retryable
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total attempts, including the first (1 = no retry).
    pub attempts: u32,
    /// Backoff scale for the first retry, milliseconds.
    pub base_ms: u64,
    /// Backoff ceiling, milliseconds.
    pub max_ms: u64,
    /// Jitter seed; mixed with the request seq so concurrent clients
    /// sharing a default policy still draw distinct delays.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { attempts: 4, base_ms: 50, max_ms: 2_000, seed: 0xC1A1_2E }
    }
}

impl RetryPolicy {
    /// Backoff before retry `attempt` (1-based), with full jitter.
    pub fn backoff(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let cap = self
            .base_ms
            .saturating_mul(1u64 << attempt.min(20))
            .min(self.max_ms.max(1));
        Duration::from_millis(rng.below(cap.max(1)))
    }
}

/// A daemon's answer to the v2 enriched `ping` (the `probe` feature):
/// stable node identity plus a load snapshot — what the fleet router's
/// health prober reads every interval.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProbeInfo {
    pub node: String,
    pub proto: u64,
    pub queued: usize,
    pub running: usize,
}

/// Receipt for a server-side `reduce`: the result volume is in the
/// daemon's content-addressed store under `id` — it never traveled over
/// this connection. `kind` is `"scalar"` or `"velocity"`; `delta_rel` is
/// the relative L2 change against the request's `ref` volume (the
/// template driver's convergence signal), present only when one was
/// named.
#[derive(Clone, Debug, PartialEq)]
pub struct ReduceReceipt {
    pub id: String,
    pub n: usize,
    pub kind: String,
    pub count: usize,
    pub bytes: u64,
    pub dedup: bool,
    pub delta_rel: Option<f64>,
}

/// Render job views as an aligned table (shared by the CLI `status`
/// subcommand and the daemon-mode example).
pub fn job_table(jobs: &[JobView]) -> Table {
    let mut t = Table::new(&[
        "id", "job", "prio", "state", "it", "|g|rel", "order", "lat[s]", "solve[s]", "mism",
        "lvls", "err",
    ]);
    let fo = |x: Option<f64>| x.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into());
    for v in jobs {
        // Live progress while running (fed by the solve observer); the
        // final report's iteration count once the job is done.
        let iters = v.iters_done.or(v.iters);
        t.row(&[
            v.id.to_string(),
            v.name.clone(),
            v.priority.as_str().into(),
            v.state.as_str().into(),
            iters.map(|i| i.to_string()).unwrap_or_else(|| "-".into()),
            v.grad_rel
                .filter(|g| g.is_finite())
                .map(|g| format!("{g:.1e}"))
                .unwrap_or_else(|| "-".into()),
            v.dispatch_seq.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
            fo(v.latency_s),
            fo(v.wall_s),
            v.mismatch_rel.map(|m| format!("{m:.1e}")).unwrap_or_else(|| "-".into()),
            v.levels.map(|l| l.to_string()).unwrap_or_else(|| "-".into()),
            v.error.clone().unwrap_or_else(|| "-".into()),
        ]);
    }
    t
}

pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Negotiated protocol level: 1 until `hello` succeeds.
    proto: u64,
    /// Monotonic request-correlation counter (v2 sessions).
    seq: u64,
    /// Seq the last request carried (what a `watch` stream echoes).
    last_seq: Option<u64>,
    /// Watch events that arrived interleaved with a response.
    pending_events: VecDeque<EventMsg>,
}

impl Client {
    fn from_stream(stream: TcpStream) -> Result<Client> {
        let read_half = stream.try_clone()?;
        Ok(Client {
            reader: BufReader::new(read_half),
            writer: stream,
            proto: 1,
            seq: 0,
            last_seq: None,
            pending_events: VecDeque::new(),
        })
    }

    /// Connect to `addr` (e.g. "127.0.0.1:7464") with no timeouts: calls
    /// block as long as the daemon does (in-process tests, trusted local
    /// daemons). Interactive callers should prefer
    /// [`connect_with_timeout`](Client::connect_with_timeout).
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::Serve(format!("cannot reach daemon at {addr}: {e}")))?;
        Self::from_stream(stream)
    }

    /// Connect with `timeout` bounding the TCP connect and every
    /// subsequent read/write, so a hung or wedged daemon fails this
    /// client's calls instead of blocking forever. `timeout` must be
    /// non-zero.
    pub fn connect_with_timeout(addr: &str, timeout: Duration) -> Result<Client> {
        if timeout.is_zero() {
            return Err(Error::Config("client timeout must be non-zero".into()));
        }
        let addrs: Vec<SocketAddr> = addr
            .to_socket_addrs()
            .map_err(|e| Error::Serve(format!("cannot resolve daemon address {addr}: {e}")))?
            .collect();
        let mut last: Option<std::io::Error> = None;
        for a in &addrs {
            match TcpStream::connect_timeout(a, timeout) {
                Ok(stream) => {
                    stream.set_read_timeout(Some(timeout))?;
                    stream.set_write_timeout(Some(timeout))?;
                    return Self::from_stream(stream);
                }
                Err(e) => last = Some(e),
            }
        }
        Err(Error::Serve(format!(
            "cannot reach daemon at {addr}: {}",
            last.map(|e| e.to_string()).unwrap_or_else(|| "address resolved to nothing".into())
        )))
    }

    /// Negotiated protocol level (1 until [`hello`](Client::hello)).
    pub fn proto(&self) -> u64 {
        self.proto
    }

    /// Adjust the socket I/O timeout after connect (`None` = block
    /// forever). The dup'd read half shares the underlying socket, so
    /// this governs both directions. `claire watch` clears the timeout
    /// once subscribed: an idle event stream is not a transport failure.
    pub fn set_io_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.writer.set_read_timeout(timeout)?;
        self.writer.set_write_timeout(timeout)?;
        Ok(())
    }

    fn bump_seq(&mut self) -> Option<u64> {
        if self.proto >= 2 {
            self.seq += 1;
            Some(self.seq)
        } else {
            None
        }
    }

    /// Write one request line, read lines until this request's response
    /// arrives (buffering any watch events that interleave), verify the
    /// `seq` echo, and surface protocol errors as [`Error::Wire`].
    fn exchange(&mut self, line: &str, seq: Option<u64>) -> Result<Response> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        loop {
            let Some(line) = read_line_bounded(&mut self.reader, MAX_LINE_BYTES)? else {
                return Err(Error::Serve("daemon closed the connection".into()));
            };
            let j = Json::parse(line.trim())?;
            if EventMsg::is_event(&j) {
                self.pending_events.push_back(EventMsg::from_json(&j)?);
                continue;
            }
            if let Some(expect) = seq {
                let got = j.get("seq").and_then(Json::as_index);
                // An *error* without any seq is legitimate: the daemon
                // omits it when the line failed before the envelope could
                // be read (e.g. the line-size cap). Surface that error
                // rather than masking it as a desynchronized connection.
                let seqless_error =
                    got.is_none() && j.get("ok").and_then(Json::as_bool) == Some(false);
                if got != Some(expect) && !seqless_error {
                    return Err(Error::Serve(format!(
                        "response correlation mismatch: sent seq {expect}, got {got:?}"
                    )));
                }
            }
            return match Response::from_json(&j)? {
                Response::Error { code, msg, .. } => Err(Error::Wire { code, msg }),
                other => Ok(other),
            };
        }
    }

    /// One request/response exchange.
    fn call(&mut self, req: &Request) -> Result<Response> {
        let seq = self.bump_seq();
        self.last_seq = seq;
        self.exchange(&req.to_line_with_seq(seq), seq)
    }

    fn unexpected(what: &str, got: Response) -> Error {
        Error::Serve(format!("unexpected {what} response: {got:?}"))
    }

    /// Negotiate protocol v2. On success the session is upgraded (every
    /// later call carries and verifies `seq`) and the daemon's advertised
    /// feature tags are returned.
    pub fn hello(&mut self) -> Result<Vec<String>> {
        match self.call(&Request::Hello { proto: PROTO_VERSION })? {
            Response::Hello { proto, features } => {
                if proto >= 2 {
                    self.proto = 2;
                }
                Ok(features)
            }
            other => Err(Self::unexpected("hello", other)),
        }
    }

    /// Try to negotiate v2, quietly staying on v1 against a pre-v2 daemon
    /// (which answers `hello` with an unknown-command error). Returns the
    /// protocol level the session ended up on.
    pub fn negotiate(&mut self) -> Result<u64> {
        match self.hello() {
            Ok(_) => Ok(self.proto),
            Err(Error::Wire { msg, .. }) if msg.contains("unknown command") => Ok(1),
            Err(e) => Err(e),
        }
    }

    pub fn ping(&mut self) -> Result<()> {
        self.call(&Request::Ping).map(|_| ())
    }

    /// Enriched ping (v2 `probe` feature): node identity plus queue
    /// depth/running count. Fails against a daemon that answers the
    /// pre-probe plain `{"ok":true}` — callers that only need liveness
    /// should use [`ping`](Client::ping).
    pub fn probe(&mut self) -> Result<ProbeInfo> {
        match self.call(&Request::Ping)? {
            Response::Pong { node, proto, queued, running } => {
                Ok(ProbeInfo { node, proto, queued, running })
            }
            Response::Ok => {
                Err(Error::Serve("daemon did not report node identity (pre-probe build?)".into()))
            }
            other => Err(Self::unexpected("ping", other)),
        }
    }

    /// Run `f` against this client, retrying on wire-retryable rejections
    /// (`queue_full`, `shutting_down`) per `policy` with full-jitter
    /// exponential backoff. Any other error — transport failures included
    /// — is returned immediately (see [`RetryPolicy`] for why).
    pub fn call_with_retry<T>(
        &mut self,
        policy: &RetryPolicy,
        mut f: impl FnMut(&mut Client) -> Result<T>,
    ) -> Result<T> {
        // Mix the session's request counter into the jitter seed so two
        // clients built from the same default policy de-correlate.
        let mut rng = Rng::new(policy.seed ^ self.seq.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let attempts = policy.attempts.max(1);
        let mut attempt = 1;
        loop {
            match f(self) {
                Err(Error::Wire { code, msg }) if code.retryable() && attempt < attempts => {
                    thread::sleep(policy.backoff(attempt, &mut rng));
                    attempt += 1;
                    let _ = msg;
                }
                other => return other,
            }
        }
    }

    /// [`submit`](Client::submit) under a retry policy: a `queue_full`
    /// rejection backs off and resubmits instead of surfacing.
    ///
    /// When the caller did not pick a `dedup` token, one is generated here
    /// and held fixed across every attempt, so a resubmit that races a
    /// response lost in transit returns the originally admitted job id
    /// instead of enqueueing the work twice.
    pub fn submit_with_retry(&mut self, spec: &JobSpec, policy: &RetryPolicy) -> Result<JobId> {
        let spec = if spec.dedup.is_none() {
            let mut s = spec.clone();
            s.dedup = Some(self.generated_dedup_token());
            std::borrow::Cow::Owned(s)
        } else {
            std::borrow::Cow::Borrowed(spec)
        };
        self.call_with_retry(policy, |c| c.submit(&spec))
    }

    /// A token unique enough for exactly-once admission: wall-clock nanos
    /// mixed with this session's request counter (two clients started the
    /// same nanosecond still differ once either has spoken).
    fn generated_dedup_token(&self) -> String {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        format!("auto-{:016x}-{}", nanos ^ self.seq.wrapping_mul(0x9E37_79B9_7F4A_7C15), self.seq)
    }

    /// [`upload`](Client::upload) under a retry policy.
    pub fn upload_with_retry(
        &mut self,
        n: usize,
        data: &[f32],
        policy: &RetryPolicy,
    ) -> Result<UploadReceipt> {
        self.call_with_retry(policy, |c| c.upload(n, data))
    }

    /// Ship one volume (n^3 f32 samples) into the daemon's
    /// content-addressed store; returns the receipt whose `id` a
    /// subsequent `submit` references via `JobSource::Uploaded`.
    /// Re-uploading identical content is cheap (`dedup` flags it). The
    /// request line is encoded straight from the borrowed slice — the
    /// volume is never cloned client-side.
    pub fn upload(&mut self, n: usize, data: &[f32]) -> Result<UploadReceipt> {
        let seq = self.bump_seq();
        let line = upload_line(n, data, seq);
        match self.exchange(&line, seq)? {
            Response::Uploaded { id, n, dedup } => Ok(UploadReceipt {
                id,
                n,
                bytes: (n * n * n * 4) as u64,
                dedup,
            }),
            other => Err(Self::unexpected("upload", other)),
        }
    }

    /// Submit a job; returns the daemon-assigned job id.
    pub fn submit(&mut self, spec: &JobSpec) -> Result<JobId> {
        match self.call(&Request::Submit(spec.clone()))? {
            Response::Submitted { id } => Ok(id),
            other => Err(Self::unexpected("submit", other)),
        }
    }

    /// Submit many jobs on one line (v2): returns one admission verdict
    /// per job, in order. Requires a negotiated v2 session.
    pub fn submit_batch(&mut self, specs: &[JobSpec]) -> Result<Vec<Verdict>> {
        if self.proto < 2 {
            return Err(Error::Serve(
                "submit_batch requires a v2 session (call hello first)".into(),
            ));
        }
        match self.call(&Request::SubmitBatch(specs.to_vec()))? {
            // The protocol promises one verdict per job, in order; enforce
            // it here so no caller can silently treat a truncated reply as
            // all-admitted.
            Response::Batch(vs) if vs.len() == specs.len() => Ok(vs),
            Response::Batch(vs) => Err(Error::Serve(format!(
                "submit_batch returned {} verdicts for {} jobs",
                vs.len(),
                specs.len()
            ))),
            other => Err(Self::unexpected("submit_batch", other)),
        }
    }

    /// [`submit_batch`](Client::submit_batch) under a retry policy: jobs
    /// whose admission verdict is a *retryable* rejection (`queue_full`,
    /// `shutting_down`) are resubmitted after full-jitter backoff; the
    /// returned verdicts stay in original job order. Jobs without a
    /// caller-chosen `dedup` token get one generated here and **held
    /// fixed across every attempt**, so a retry that races a
    /// half-admitted batch (or a response lost in transit) returns the
    /// originally admitted ids instead of double-enqueueing the work.
    pub fn submit_batch_with_retry(
        &mut self,
        specs: &[JobSpec],
        policy: &RetryPolicy,
    ) -> Result<Vec<Verdict>> {
        let mut specs: Vec<JobSpec> = specs.to_vec();
        for (i, s) in specs.iter_mut().enumerate() {
            if s.dedup.is_none() {
                s.dedup = Some(format!("{}-{i}", self.generated_dedup_token()));
            }
        }
        let mut rng = Rng::new(policy.seed ^ self.seq.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut verdicts = self.submit_batch(&specs)?;
        for attempt in 1..policy.attempts.max(1) {
            let pending: Vec<usize> = verdicts
                .iter()
                .enumerate()
                .filter_map(|(i, v)| {
                    matches!(v, Verdict::Rejected { retryable: true, .. }).then_some(i)
                })
                .collect();
            if pending.is_empty() {
                break;
            }
            thread::sleep(policy.backoff(attempt, &mut rng));
            let retry: Vec<JobSpec> = pending.iter().map(|&i| specs[i].clone()).collect();
            for (slot, v) in pending.into_iter().zip(self.submit_batch(&retry)?) {
                verdicts[slot] = v;
            }
        }
        Ok(verdicts)
    }

    /// Server-side reduction (v2 `reduce` feature): average retained job
    /// outputs or stored volumes on the daemon and land the result in
    /// its content-addressed store — the volumes never round-trip
    /// through this client. Requires a negotiated v2 session.
    pub fn reduce(&mut self, req: &ReduceRequest) -> Result<ReduceReceipt> {
        if self.proto < 2 {
            return Err(Error::Serve(
                "reduce requires a v2 session (call hello first)".into(),
            ));
        }
        match self.call(&Request::Reduce(req.clone()))? {
            Response::Reduced { id, n, kind, count, bytes, dedup, delta_rel } => {
                Ok(ReduceReceipt { id, n, kind, count, bytes, dedup, delta_rel })
            }
            other => Err(Self::unexpected("reduce", other)),
        }
    }

    /// Subscribe this connection to server-pushed job events (v2). Events
    /// are read with [`next_event`](Client::next_event); each echoes the
    /// returned subscription `seq`. Requires a negotiated v2 session.
    pub fn watch(&mut self) -> Result<Option<u64>> {
        if self.proto < 2 {
            return Err(Error::Serve("watch requires a v2 session (call hello first)".into()));
        }
        match self.call(&Request::Watch)? {
            Response::Ok => Ok(self.last_seq),
            other => Err(Self::unexpected("watch", other)),
        }
    }

    /// Next server-pushed event on this connection: events buffered while
    /// waiting for responses first, then a blocking read (bounded by the
    /// socket read timeout, when one was configured at connect).
    pub fn next_event(&mut self) -> Result<EventMsg> {
        if let Some(ev) = self.pending_events.pop_front() {
            return Ok(ev);
        }
        let Some(line) = read_line_bounded(&mut self.reader, MAX_LINE_BYTES)? else {
            return Err(Error::Serve("daemon closed the connection".into()));
        };
        EventMsg::parse(&line)
    }

    pub fn status(&mut self, id: JobId) -> Result<JobView> {
        match self.call(&Request::Status(Some(id)))? {
            Response::Job(v) => Ok(v),
            other => Err(Self::unexpected("status", other)),
        }
    }

    /// All jobs the daemon knows about, id-ordered.
    pub fn jobs(&mut self) -> Result<Vec<JobView>> {
        match self.call(&Request::Status(None))? {
            Response::Jobs(v) => Ok(v),
            other => Err(Self::unexpected("status", other)),
        }
    }

    pub fn cancel(&mut self, id: JobId) -> Result<()> {
        self.call(&Request::Cancel(id)).map(|_| ())
    }

    pub fn stats(&mut self) -> Result<ServeStats> {
        match self.call(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => Err(Self::unexpected("stats", other)),
        }
    }

    pub fn shutdown(&mut self, drain: bool) -> Result<()> {
        self.call(&Request::Shutdown { drain }).map(|_| ())
    }

    /// Poll `status` until the job reaches a terminal state or `timeout_s`
    /// elapses.
    pub fn wait_terminal(&mut self, id: JobId, timeout_s: f64) -> Result<JobView> {
        let t0 = Instant::now();
        loop {
            let view = self.status(id)?;
            if view.state.is_terminal() {
                return Ok(view);
            }
            if t0.elapsed().as_secs_f64() > timeout_s {
                return Err(Error::Serve(format!(
                    "timeout waiting for job {id} (still {})",
                    view.state.as_str()
                )));
            }
            thread::sleep(Duration::from_millis(20));
        }
    }

    /// Poll until the daemon is idle (no queued or running jobs) or
    /// `timeout_s` elapses; returns the final stats.
    pub fn wait_idle(&mut self, timeout_s: f64) -> Result<ServeStats> {
        let t0 = Instant::now();
        loop {
            let s = self.stats()?;
            if s.queued == 0 && s.running == 0 {
                return Ok(s);
            }
            if t0.elapsed().as_secs_f64() > timeout_s {
                return Err(Error::Serve(format!(
                    "timeout waiting for idle ({} queued, {} running)",
                    s.queued, s.running
                )));
            }
            thread::sleep(Duration::from_millis(20));
        }
    }
}
