//! Persistent job journal: NDJSON sidecar in the style of `data/io.rs`.
//!
//! Every lifecycle event is appended as one JSON line, so a restarted
//! daemon can report work completed by previous incarnations (the `stats`
//! verb's `prior_completed`) and an operator can audit what a node did
//! with `grep`. Append-only and line-oriented: a torn final line (daemon
//! killed mid-write) is skipped on replay rather than poisoning the file.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::error::Result;
use crate::serve::scheduler::{JobEvent, JobState};
use crate::util::json::Json;
use crate::util::sync::Mutex;

/// One replayed journal entry.
#[derive(Clone, Debug, PartialEq)]
pub struct JournalEntry {
    /// "submitted" | "done" | "failed" | "cancelled".
    pub event: String,
    pub id: u64,
    pub name: String,
    pub unix_s: f64,
    /// Exactly-once token of a `submitted` line, when the client supplied
    /// one. Replay reseeds the scheduler's admission map from these so a
    /// retry across a daemon restart still deduplicates.
    pub dedup: Option<String>,
}

/// Append-only NDJSON journal.
pub struct Journal {
    path: PathBuf,
    file: Mutex<File>,
}

fn now_unix() -> f64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs_f64()).unwrap_or(0.0)
}

impl Journal {
    /// Open (creating if absent) the journal at `path`.
    pub fn open(path: &Path) -> Result<Journal> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(Journal { path: path.to_path_buf(), file: Mutex::new(file) })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one scheduler event as a JSON line. `Started` transitions
    /// and per-iteration `Progress` beats are *not* journaled: running is
    /// transient state that is wrong by definition after a restart,
    /// per-iteration lines would swamp an audit trail, and skipping both
    /// keeps the journal format byte-compatible with pre-watch
    /// incarnations (watch subscribers get them from the live bus
    /// instead). A `Finished` in the cancelled state — a *running* job
    /// interrupted at an iteration boundary — journals as `cancelled`,
    /// same spelling as a queued-job cancellation.
    pub fn append(&self, ev: &JobEvent) -> Result<()> {
        let j = match ev {
            JobEvent::Started { .. } | JobEvent::Progress { .. } => return Ok(()),
            JobEvent::Submitted { id, name, priority, dedup } => {
                let mut pairs = vec![
                    ("event", Json::str("submitted")),
                    ("id", Json::num(*id as f64)),
                    ("name", Json::str(name)),
                    ("priority", Json::str(priority.as_str())),
                ];
                // Emitted only when present, keeping token-less lines
                // byte-identical to pre-dedup incarnations.
                if let Some(tok) = dedup {
                    pairs.push(("dedup", Json::str(tok)));
                }
                pairs.push(("unix_s", Json::num(now_unix())));
                Json::object(pairs)
            }
            JobEvent::Finished { id, name, state, wall_s, .. } => Json::object([
                (
                    "event",
                    Json::str(match state {
                        JobState::Done => "done",
                        JobState::Cancelled => "cancelled",
                        _ => "failed",
                    }),
                ),
                ("id", Json::num(*id as f64)),
                ("name", Json::str(name)),
                ("wall_s", Json::num(*wall_s)),
                ("unix_s", Json::num(now_unix())),
            ]),
            JobEvent::Cancelled { id, name } => Json::object([
                ("event", Json::str("cancelled")),
                ("id", Json::num(*id as f64)),
                ("name", Json::str(name)),
                ("unix_s", Json::num(now_unix())),
            ]),
        };
        let mut f = self.file.lock().unwrap();
        writeln!(f, "{}", j.render())?;
        f.flush()?;
        Ok(())
    }

    /// Replay the journal at `path`. Unparseable lines (torn tail writes,
    /// including writes torn mid-UTF-8-codepoint) are skipped rather than
    /// preventing startup. Missing file = empty history.
    pub fn replay(path: &Path) -> Result<Vec<JournalEntry>> {
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => return Err(e.into()),
        };
        // Lossy decode: a crash mid-write must not poison the whole file.
        let text = String::from_utf8_lossy(&bytes);
        let mut out = Vec::new();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let Ok(j) = Json::parse(line) else { continue };
            let (Some(event), Some(id), Some(name)) = (
                j.get("event").and_then(Json::as_str),
                j.get("id").and_then(Json::as_usize),
                j.get("name").and_then(Json::as_str),
            ) else {
                continue;
            };
            out.push(JournalEntry {
                event: event.to_string(),
                id: id as u64,
                name: name.to_string(),
                unix_s: j.get("unix_s").and_then(Json::as_f64).unwrap_or(0.0),
                dedup: j.get("dedup").and_then(Json::as_str).map(str::to_string),
            });
        }
        Ok(out)
    }

    /// Completed-job count in a replayed history (what a restarted daemon
    /// reports as `prior_completed`).
    pub fn completed_count(entries: &[JournalEntry]) -> u64 {
        entries.iter().filter(|e| e.event == "done").count() as u64
    }

    /// Highest job id in a replayed history (0 when empty). A restarted
    /// daemon seeds its id counter past this so audit lines from different
    /// incarnations never collide on `id`.
    pub fn max_id(entries: &[JournalEntry]) -> u64 {
        entries.iter().map(|e| e.id).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::proto::Priority;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("claire_journal_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn append_replay_roundtrip() {
        let p = tmp("roundtrip.ndjson");
        let journal = Journal::open(&p).unwrap();
        journal
            .append(&JobEvent::Submitted {
                id: 1,
                name: "na02 \"quoted\"\\n".into(),
                priority: Priority::Emergency,
                dedup: None,
            })
            .unwrap();
        journal
            .append(&JobEvent::Finished {
                id: 1,
                name: "na02 \"quoted\"\\n".into(),
                state: JobState::Done,
                wall_s: 1.5,
                error: None,
            })
            .unwrap();
        journal.append(&JobEvent::Cancelled { id: 2, name: "na03".into() }).unwrap();
        journal
            .append(&JobEvent::Finished {
                id: 3,
                name: "na10".into(),
                state: JobState::Failed,
                wall_s: 0.2,
                error: None,
            })
            .unwrap();
        let entries = Journal::replay(&p).unwrap();
        assert_eq!(entries.len(), 4);
        assert_eq!(entries[0].event, "submitted");
        assert_eq!(entries[0].name, "na02 \"quoted\"\\n");
        assert_eq!(entries[1].event, "done");
        assert_eq!(entries[2].event, "cancelled");
        assert_eq!(entries[3].event, "failed");
        assert_eq!(Journal::completed_count(&entries), 1);
        assert_eq!(Journal::max_id(&entries), 3, "id seeding looks past all events");
        assert_eq!(entries[0].dedup, None, "token-less lines replay without a token");
    }

    #[test]
    fn dedup_tokens_roundtrip_through_the_journal() {
        let p = tmp("dedup.ndjson");
        let journal = Journal::open(&p).unwrap();
        journal
            .append(&JobEvent::Submitted {
                id: 9,
                name: "na02".into(),
                priority: Priority::Batch,
                dedup: Some("client-1/try".into()),
            })
            .unwrap();
        journal
            .append(&JobEvent::Submitted {
                id: 10,
                name: "na03".into(),
                priority: Priority::Batch,
                dedup: None,
            })
            .unwrap();
        let entries = Journal::replay(&p).unwrap();
        assert_eq!(entries[0].dedup.as_deref(), Some("client-1/try"));
        assert_eq!(entries[1].dedup, None);
        // Token-less lines stay byte-identical to pre-dedup incarnations.
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(!text.lines().nth(1).unwrap().contains("dedup"));
    }

    #[test]
    fn running_cancel_journals_as_cancelled_and_progress_is_skipped() {
        let p = tmp("cancel_running.ndjson");
        let journal = Journal::open(&p).unwrap();
        journal
            .append(&JobEvent::Progress {
                id: 4,
                name: "x".into(),
                progress: crate::serve::scheduler::Progress {
                    iters_done: 1,
                    level: 0,
                    beta: 5e-4,
                    j: 1.0,
                    grad_rel: 0.5,
                    alpha: 1.0,
                },
            })
            .unwrap();
        journal
            .append(&JobEvent::Finished {
                id: 4,
                name: "x".into(),
                state: JobState::Cancelled,
                wall_s: 0.3,
                error: None,
            })
            .unwrap();
        let entries = Journal::replay(&p).unwrap();
        assert_eq!(entries.len(), 1, "progress beats never hit the audit trail");
        assert_eq!(entries[0].event, "cancelled");
        assert_eq!(Journal::completed_count(&entries), 0);
    }

    #[test]
    fn max_id_of_empty_history_is_zero() {
        assert_eq!(Journal::max_id(&[]), 0);
    }

    #[test]
    fn replay_missing_file_is_empty() {
        let p = tmp("absent.ndjson");
        assert_eq!(Journal::replay(&p).unwrap(), Vec::new());
    }

    #[test]
    fn replay_skips_torn_tail() {
        let p = tmp("torn.ndjson");
        let journal = Journal::open(&p).unwrap();
        journal.append(&JobEvent::Cancelled { id: 7, name: "ok".into() }).unwrap();
        // Simulate a crash mid-write: unterminated garbage tail, torn in
        // the middle of a multi-byte UTF-8 codepoint ("é" = 0xC3 0xA9).
        let mut f = OpenOptions::new().append(true).open(&p).unwrap();
        f.write_all(b"{\"event\":\"done\",\"id\":8,\"name\":\"caf\xC3").unwrap();
        drop(f);
        let entries = Journal::replay(&p).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].id, 7);
    }

    #[test]
    fn reopen_appends_across_incarnations() {
        let p = tmp("reopen.ndjson");
        {
            let j = Journal::open(&p).unwrap();
            j.append(&JobEvent::Finished {
                id: 1,
                name: "a".into(),
                state: JobState::Done,
                wall_s: 0.1,
                error: None,
            })
            .unwrap();
        }
        {
            let j = Journal::open(&p).unwrap();
            j.append(&JobEvent::Finished {
                id: 2,
                name: "b".into(),
                state: JobState::Done,
                wall_s: 0.1,
                error: None,
            })
            .unwrap();
        }
        let entries = Journal::replay(&p).unwrap();
        assert_eq!(Journal::completed_count(&entries), 2);
    }
}
